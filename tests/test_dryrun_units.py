"""Unit tests for the dry-run machinery (no device-count forcing here —
these test the pure helpers; full lowering is exercised by
``python -m repro.launch.dryrun`` and its committed JSON artifacts)."""
import json
import os

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_configs
from repro.launch import specs as SP

# NOTE: repro.launch.dryrun sets XLA_FLAGS at import; importing it in this
# process is safe only because jax is already initialized (the flag then
# has no effect on the live backend). We only use its pure helpers.
from repro.launch.dryrun import (calibration_depths,
                                 collective_bytes_from_hlo,
                                 reduced_depth_cfg)


def test_collective_parser_counts_result_bytes():
    hlo = """
  %ag = bf16[16,512]{1,0} all-gather(bf16[16,32]{1,0} %x), dimensions={1}
  %ar = (f32[8,8]{1,0}, f32[4]{0}) all-reduce(...), to_apply=%add
  %a2a = f32[2,64]{1,0} all-to-all(f32[2,64]{1,0} %y), dimensions={0}
  %cp = u32[10]{0} collective-permute(u32[10]{0} %z)
  %ags = bf16[4,4]{1,0} all-gather-start(bf16[4,2]{1,0} %w)
  %agd = bf16[4,4]{1,0} all-gather-done(bf16[4,4]{1,0} %ags)
  %dot = f32[128,128]{1,0} dot(f32[128,64]{1,0}, f32[64,128]{1,0})
"""
    out = collective_bytes_from_hlo(hlo)
    assert out["all-gather"] == 16 * 512 * 2 + 4 * 4 * 2  # incl -start
    assert out["all-reduce"] == 8 * 8 * 4 + 4 * 4          # tuple summed
    assert out["all-to-all"] == 2 * 64 * 4
    assert out["collective-permute"] == 10 * 4
    assert out["total"] == sum(v for k, v in out.items() if k != "total")


def test_skip_rules_match_assignment():
    """long_500k runs ONLY for SSM / hybrid / SWA archs (7 skips)."""
    skips = [a for a in list_configs()
             if SP.cell_supported(get_config(a), "long_500k")]
    assert sorted(skips) == sorted([
        "whisper-tiny", "qwen3-4b", "nemotron-4-340b", "qwen2-1.5b",
        "deepseek-v2-236b", "phi3.5-moe-42b-a6.6b", "paligemma-3b"])
    for a in ("h2o-danube-1.8b", "recurrentgemma-9b", "mamba2-1.3b"):
        assert SP.cell_supported(get_config(a), "long_500k") is None
    for a in list_configs():
        for shape in ("train_4k", "prefill_32k", "decode_32k"):
            assert SP.cell_supported(get_config(a), shape) is None


@pytest.mark.parametrize("arch", ["qwen3-4b", "deepseek-v2-236b",
                                  "whisper-tiny", "paligemma-3b"])
def test_input_specs_shapes(arch):
    cfg = get_config(arch)
    cell = SP.SHAPES["train_4k"]
    b = SP.batch_specs(cfg, cell)
    assert b["tokens"].shape[0] == 256
    total = b["tokens"].shape[1] + (cfg.frontend_len
                                    if cfg.frontend == "vision" else 0)
    assert total == 4096
    tokens, cache, extras = SP.prefill_specs(cfg, SP.SHAPES["prefill_32k"])
    assert tokens.shape[0] == 32
    td, cd = SP.decode_specs(cfg, SP.SHAPES["decode_32k"])
    assert td.shape == (128,)
    assert int(cd["len"].shape[0]) == 128


def test_ring_capacity_capped_at_window():
    cfg = get_config("h2o-danube-1.8b")
    _, cache = SP.decode_specs(cfg, SP.SHAPES["long_500k"])
    assert cache["kv_pos"].shape[1] == cfg.sliding_window  # 4096, not 524288
    cfgm = get_config("mamba2-1.3b")
    _, cm = SP.decode_specs(cfgm, SP.SHAPES["long_500k"])
    assert "kv_pos" not in cm                              # O(1) state


def test_model_flops_conventions():
    cfg = get_config("qwen3-4b")
    t = SP.model_flops(cfg, SP.SHAPES["train_4k"])
    p = SP.model_flops(cfg, SP.SHAPES["prefill_32k"])
    d = SP.model_flops(cfg, SP.SHAPES["decode_32k"])
    n = cfg.num_active_params()
    assert t == 6.0 * n * 256 * 4096
    assert p == 2.0 * n * 32 * 32768
    assert d == 2.0 * n * 128
    moe = get_config("deepseek-v2-236b")
    assert moe.num_active_params() < 0.2 * moe.num_params()


def test_reduced_depth_cfg_keeps_family():
    for a in list_configs():
        cfg = get_config(a)
        lo, hi = calibration_depths(cfg)
        c0 = reduced_depth_cfg(cfg, lo)
        assert c0.family == cfg.family and c0.num_layers == lo
        assert c0.d_model == cfg.d_model      # only depth changes
        if cfg.encoder:
            assert c0.encoder.num_layers == lo


def test_dryrun_artifacts_green():
    """The committed dry-run results: every cell ok or an assignment SKIP,
    and every OK cell fits the 16 GB v5e chip."""
    d = "experiments/dryrun"
    if not os.path.isdir(d):
        pytest.skip("dry-run not yet executed")
    cells = {}
    for fn in os.listdir(d):
        with open(os.path.join(d, fn)) as f:
            r = json.load(f)
        if r["mesh"] not in ("pod256", "pod512"):
            continue                           # perf-iteration tags
        cells[(r["arch"], r["shape"], r["mesh"])] = r
    assert len(cells) == 80
    for key, r in cells.items():
        assert r["status"] in ("ok", "skip"), (key, r.get("error"))
        if r["status"] == "ok":
            peak = r["memory_analysis"].get("peak_memory_in_bytes", 0)
            assert peak <= 16.5e9, (key, peak)  # fits the 16 GB v5e chip

"""Fused token-budget step (DESIGN.md §11): one round = one launch.

Covers the ISSUE 5 contracts:
- kernel parity: ``paged_prefill_attention`` against the pure-jnp
  oracle across shapes/dtypes (MQA, ragged ``q_lens``, padding rows),
  Q=1 equality with the single-token decode kernel, and the
  striped-slot stats merge that backs the sharded plane;
- a round granting a C-token prefill chunk executes as exactly ONE
  jitted launch on the fused path (the per-token ``_step_fn`` is never
  entered);
- fused vs per-token (``fused_step=False``) differential: bit-exact
  token streams AND event streams on full multi-turn traces — chunked
  prefill with interleaved decode, barge-in mid-chunk, physical
  evict-to-DRAM/reload — as an always-on deterministic sweep plus a
  hypothesis property over random chunk budgets/barge rounds/evictions
  (slow lane), plus the deterministic replay gateway (scheduler,
  frontier cap, barge storms) as a whole-system differential;
- the self-scheduled path passes the scheduler's chunk grants through
  (``step()`` no longer flattens PREFILL grants to one token);
- 8-virtual-device mesh twins stay token-exact (multidev lane).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st
from repro.configs import get_config, reduced
from repro.core.session import Phase
from repro.kernels import ref
from repro.kernels.paged_attention import (paged_attention,
                                           paged_prefill_attention)
from repro.models import init_params
from repro.serving.paged_engine import PagedRealtimeEngine, _q_bucket

NDEV = len(jax.devices())
multidev = pytest.mark.skipif(
    NDEV < 2,
    reason="needs >1 device; run under "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8")

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.fixture(scope="module")
def tiny():
    cfg = reduced(get_config("qwen2-1.5b"), layers=2, d_model=64,
                  vocab=331)
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _case(key, B, Q, Hq, Hkv, D, page, pps, dtype=jnp.float32):
    num_pages = B * pps + 3
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (B, Q, Hq, D), dtype)
    kp = jax.random.normal(ks[1], (num_pages, page, Hkv, D), dtype)
    vp = jax.random.normal(ks[2], (num_pages, page, Hkv, D), dtype)
    bt = jax.random.permutation(
        ks[3], num_pages)[:B * pps].reshape(B, pps).astype(jnp.int32)
    # ragged starts/lengths incl. a zero-history row, a padding-heavy
    # row, and (when B allows) a fully-padded q_lens == 0 row
    qs = jnp.array([(i * 7) % (page * pps - Q) for i in range(B)],
                   jnp.int32)
    ql = jnp.array([0 if (B > 2 and i == B - 1)
                    else 1 + (i * 3) % Q for i in range(B)], jnp.int32)
    return q, kp, vp, bt, qs, ql


# ======================================================================
# kernel parity
# ======================================================================
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Q,Hq,Hkv,D,page,pps",
    [
        (3, 4, 4, 2, 16, 8, 4),      # GQA, mixed q_lens
        (2, 8, 8, 2, 32, 8, 5),      # chunk spans pages
        (1, 7, 4, 1, 16, 4, 6),      # MQA, odd Q
        (4, 5, 6, 3, 16, 5, 4),      # non-pow2 page, padded row
        (2, 1, 4, 2, 16, 8, 4),      # decode-only round (Q=1)
    ])
def test_fused_kernel_matches_ref(B, Q, Hq, Hkv, D, page, pps, dtype):
    tol = TOL[jnp.bfloat16 if dtype == jnp.bfloat16 else jnp.float32]
    q, kp, vp, bt, qs, ql = _case(jax.random.PRNGKey(0), B, Q, Hq, Hkv,
                                  D, page, pps, dtype)
    got = paged_prefill_attention(q, kp, vp, bt, qs, ql, interpret=True)
    want = ref.paged_prefill_attention_ref(q, kp, vp, bt, qs, ql)
    for b in range(B):       # padding tokens are unspecified: skip them
        n = int(ql[b])
        np.testing.assert_allclose(
            np.asarray(got, np.float32)[b, :n],
            np.asarray(want, np.float32)[b, :n], rtol=tol, atol=tol)


def test_fused_kernel_q1_matches_decode_kernel():
    """A decode-only fused round must reproduce the single-token kernel
    bit for bit — the two planes share numerics at Q=1."""
    q, kp, vp, bt, qs, ql = _case(jax.random.PRNGKey(1), 3, 1, 8, 2, 32,
                                  8, 5)
    ql = jnp.ones_like(ql)
    got = paged_prefill_attention(q, kp, vp, bt, qs, ql, interpret=True)
    want = paged_attention(q[:, 0], kp, vp, bt, qs + 1, interpret=True)
    np.testing.assert_array_equal(np.asarray(got[:, 0]), np.asarray(want))


def test_fused_kernel_stats_stripes_merge():
    """The shard-side contract without a mesh: striping each page's
    slots, computing per-stripe (o, m, l) with the shifted q_start, and
    flash-merging reproduces the full intra-chunk causal softmax —
    including rows whose causal limit falls entirely inside one stripe
    (the fully-masked-shard case the finite NEG_INF sentinel covers)."""
    q, kp, vp, bt, qs, ql = _case(jax.random.PRNGKey(2), 3, 6, 4, 2, 16,
                                  8, 4)
    want = ref.paged_prefill_attention_ref(q, kp, vp, bt, qs, ql)
    page = kp.shape[1]
    for S in (2, 4, 8):
        psl = page // S
        outs = []
        for s in range(S):
            o, m, l = paged_prefill_attention(
                q, kp[:, s * psl:(s + 1) * psl],
                vp[:, s * psl:(s + 1) * psl], bt, qs - s * psl, ql,
                pos_stride=page, return_stats=True, interpret=True)
            outs.append((o.astype(jnp.float32), m, l))
        m_star = jnp.max(jnp.stack([m for _, m, _ in outs]), axis=0)
        ws = [l * jnp.exp(m - m_star) for _, m, l in outs]
        den = jnp.maximum(sum(ws), 1e-30)
        got = sum(o * w[..., None] for (o, _, _), w in zip(outs, ws)) \
            / den[..., None]
        for b in range(q.shape[0]):
            n = int(ql[b])
            np.testing.assert_allclose(
                np.asarray(got)[b, :n], np.asarray(want, np.float32)[b, :n],
                rtol=2e-5, atol=2e-5)


def test_q_bucket():
    assert [_q_bucket(n) for n in (0, 1, 2, 3, 4, 5, 8, 9, 16, 17)] == \
        [1, 1, 2, 4, 4, 8, 8, 16, 16, 32]


# ======================================================================
# one round = one launch
# ======================================================================
def test_chunked_round_is_one_launch(tiny):
    """A round granting a C-token prefill chunk plus concurrent decode
    runs as ONE fused launch — no Python-level per-token sub-batches,
    and the per-token step function is never entered."""
    cfg, params = tiny
    rng = np.random.default_rng(0)
    eng = PagedRealtimeEngine(cfg, params, slots=2, page_size=4,
                              pages_per_seq=16)
    eng.add_session("b", rng.integers(0, cfg.vocab_size, size=5),
                    max_new_tokens=30)           # decode participant
    sb = next(i for i, s in eng.slot_state.items() if s is not None)

    def forbidden(*a, **k):
        raise AssertionError("per-token step entered on the fused plane")

    eng._step_fn = forbidden
    sa = eng.submit_turn("a", rng.integers(0, cfg.vocab_size, size=12),
                         max_new_tokens=4)
    launches = eng.fused_launches
    rounds = 0
    while eng.slot_state[sa].request.phase == Phase.PREFILL:
        eng.run_round({sa: 5, sb: 1})
        rounds += 1
        assert eng.fused_launches == launches + rounds, \
            "a C-token chunk must cost exactly one launch per round"
    assert rounds == 3                           # ceil(12 / 5)
    eng.check_invariants()


def test_self_scheduled_step_passes_chunk_grants(tiny):
    """ISSUE 5 satellite: ``step()`` forwards the scheduler's
    ``chunk_for`` grant instead of flattening every slot to one token —
    a PREFILL slot advances a whole chunk per self-scheduled round."""
    cfg, params = tiny
    rng = np.random.default_rng(1)
    eng = PagedRealtimeEngine(cfg, params, slots=4, page_size=8,
                              pages_per_seq=16)
    eng.submit_turn("a", rng.integers(0, cfg.vocab_size, size=11),
                    max_new_tokens=3)
    launches = eng.fused_launches
    eng.step()
    r = next(s for s in eng.slot_state.values()
             if s is not None).request
    # engine's self-scheduler clamps prefill_chunk to the round budget
    # (= slots = 4): one round teacher-forces 4 tokens in one launch
    assert r.prefilled == 4
    assert eng.fused_launches == launches + 1
    eng.run_to_completion()
    eng.check_invariants()


def test_hoisted_lookahead_covers_chunk(tiny):
    """ISSUE 5 satellite: the best-effort lookahead grows once per slot
    per round covering the whole grant plus the boundary page — on a
    roomy pool a mid-prompt chunk round leaves the next page owned."""
    cfg, params = tiny
    rng = np.random.default_rng(2)
    for fused in (True, False):
        eng = PagedRealtimeEngine(cfg, params, slots=2, page_size=4,
                                  pages_per_seq=16, fused_step=fused)
        sa = eng.submit_turn("a", rng.integers(0, cfg.vocab_size,
                                               size=10),
                             max_new_tokens=4)
        eng.run_round({sa: 6})                   # mid-prompt round
        sess = eng.sessions["a"]
        assert sess.kv_len == 6
        assert len(eng.pool.seq("a").pages) >= eng.pool.pages_for(
            sess.kv_len + eng.page_size), \
            f"lookahead page not owned (fused={fused})"
        eng.check_invariants()


# ======================================================================
# fused vs per-token differential
# ======================================================================
def _drive_differential(cfg, params, seed, *, mesh=None,
                        fused: bool = True, max_chunk: int = 5,
                        barge_round: int = 3, evict_pages: int = 6,
                        page_size: int = 4, num_pages: int = 24):
    """One seeded multi-turn trace through ``run_round`` with random
    chunk grants: chunked prefill interleaving decode, a barge-in that
    lands mid-trace, physical evict-to-DRAM + reload across a turn
    boundary, a second/third turn on committed pages. Returns
    (histories, event streams, turn stats) for exact comparison.

    The rng is consumed identically on both planes as long as the
    planes stay token-exact — which is the property under test; any
    drift cascades into the final assertion."""
    rng = np.random.default_rng(seed)
    eng = PagedRealtimeEngine(cfg, params, slots=2, page_size=page_size,
                              pages_per_seq=16, num_pages=num_pages,
                              mesh=mesh, fused_step=fused)
    stream = []

    def drive(live_grants, barge_at=None):
        rounds = 0
        while eng.active() and rounds < 400:
            grants = {}
            for slot, sid in list(live_grants.items()):
                s = eng.slot_state[slot]
                if s is None or s.session_id != sid \
                        or not s.request.is_live():
                    continue
                grants[slot] = int(rng.integers(1, max_chunk + 1))
            if not grants:
                break
            stream.append((rounds, eng.run_round(grants)))
            rounds += 1
            if barge_at is not None and rounds == barge_at:
                eng.barge_in("a")
                stream.append(("barge", rounds))
                return

    pa = rng.integers(0, cfg.vocab_size, size=int(rng.integers(8, 14)))
    pb = rng.integers(0, cfg.vocab_size, size=int(rng.integers(5, 10)))
    sa = eng.submit_turn("a", pa, max_new_tokens=int(rng.integers(5, 9)))
    sb = eng.submit_turn("b", pb, max_new_tokens=int(rng.integers(4, 8)))
    drive({sa: "a", sb: "b"})
    # physical offload of a's suffix; flush makes the DRAM copies
    # durable so the next session's growth really clobbers the slots
    evicted = eng.kv.evict(evict_pages, eng.clock.now())
    eng.flush_transfers()
    stream.append(("evicted", evicted))
    pc = rng.integers(0, cfg.vocab_size, size=8)
    sc = eng.submit_turn("c", pc, max_new_tokens=int(rng.integers(4, 8)))
    drive({sc: "c"})
    # a returns: reload path (zero re-prefill), then a barge mid-decode
    pa2 = rng.integers(0, cfg.vocab_size, size=int(rng.integers(4, 9)))
    sa2 = eng.submit_turn("a", pa2, max_new_tokens=10)
    drive({sa2: "a"}, barge_at=barge_round)
    # turn 3 resumes on exactly the committed tokens
    pa3 = rng.integers(0, cfg.vocab_size, size=int(rng.integers(3, 7)))
    sa3 = eng.submit_turn("a", pa3, max_new_tokens=int(rng.integers(3, 6)))
    drive({sa3: "a"})
    eng.check_invariants()
    hist = {sid: s.history for sid, s in eng.sessions.items()}
    stats = {sid: [(t["re_prefill_tokens"], t["generated"], t["aborted"])
                   for t in s.turn_stats]
             for sid, s in eng.sessions.items()}
    return hist, stream, stats, eng


SWEEP = [(0, 3, 2), (1, 5, 4), (2, 1, 1), (3, 7, 6), (4, 4, 3)]


@pytest.mark.parametrize("seed,max_chunk,barge_round", SWEEP)
def test_fused_vs_tokenwise_deterministic_sweep(tiny, seed, max_chunk,
                                                barge_round):
    """Always-on sweep: identical token streams, event streams, and
    turn stats across the two planes on full traces (barge-in +
    physical evict/reload included)."""
    cfg, params = tiny
    want = _drive_differential(cfg, params, seed, fused=False,
                               max_chunk=max_chunk,
                               barge_round=barge_round)
    got = _drive_differential(cfg, params, seed, fused=True,
                              max_chunk=max_chunk,
                              barge_round=barge_round)
    assert got[0] == want[0], "token histories diverged"
    assert got[1] == want[1], "event streams diverged"
    assert got[2] == want[2], "turn stats diverged"
    # the trace exercised the reload path for real on both planes
    assert got[3].kv.reloaded_blocks >= 1
    assert want[3].kv.reloaded_blocks >= 1


@pytest.mark.slow
@given(seed=st.integers(0, 2 ** 16),
       max_chunk=st.integers(1, 9),
       barge_round=st.integers(1, 8),
       evict_pages=st.integers(2, 8))
@settings(max_examples=15, deadline=None)
def test_fused_vs_tokenwise_property(tiny, seed, max_chunk, barge_round,
                                     evict_pages):
    cfg, params = tiny
    want = _drive_differential(cfg, params, seed, fused=False,
                               max_chunk=max_chunk,
                               barge_round=barge_round,
                               evict_pages=evict_pages)
    got = _drive_differential(cfg, params, seed, fused=True,
                              max_chunk=max_chunk,
                              barge_round=barge_round,
                              evict_pages=evict_pages)
    assert got[:3] == want[:3]


def test_zero_grant_is_not_scheduled_on_both_planes(tiny):
    """Regression (review): ``run_round({slot: 0})`` must advance
    nothing on either plane — a zero grant means "not scheduled this
    round" for DECODE slots too, even mixed with positive grants, so
    the planes' bit-exactness contract covers every run_round input."""
    cfg, params = tiny
    rng = np.random.default_rng(9)
    pa = rng.integers(0, cfg.vocab_size, size=5)
    pb = rng.integers(0, cfg.vocab_size, size=6)
    for fused in (True, False):
        eng = PagedRealtimeEngine(cfg, params, slots=2, page_size=4,
                                  pages_per_seq=16, fused_step=fused)
        sa = eng.add_session("a", pa, max_new_tokens=8)
        sb = eng.submit_turn("b", pb, max_new_tokens=4)
        gen0 = eng.slot_state[sa].request.generated
        assert eng.run_round({sa: 0}) == {sa: []}, fused
        assert eng.slot_state[sa].request.generated == gen0, fused
        # zero grant alongside a positive one: only the granted slot runs
        evs = eng.run_round({sa: 0, sb: 2})
        assert evs[sa] == [] and len(evs[sb]) == 2, (fused, evs)
        assert eng.slot_state[sa].request.generated == gen0, fused
        eng.check_invariants()


def test_sync_paths_parity(tiny):
    """add_session / start_turn route turn-0 and turn-N prefill through
    the fused launch: token streams match the per-token engine across a
    multi-turn conversation driven by the self-scheduled step."""
    cfg, params = tiny
    rng = np.random.default_rng(7)
    turns = [(rng.integers(0, cfg.vocab_size, size=9), 6),
             (rng.integers(0, cfg.vocab_size, size=5), 7),
             (rng.integers(0, cfg.vocab_size, size=4), 5)]

    def drive(fused):
        eng = PagedRealtimeEngine(cfg, params, slots=2, page_size=4,
                                  pages_per_seq=16, fused_step=fused)
        eng.add_session("a", turns[0][0], max_new_tokens=turns[0][1])
        eng.run_to_completion()
        for prompt, n in turns[1:]:
            eng.start_turn("a", prompt, max_new_tokens=n)
            eng.run_to_completion()
        eng.check_invariants()
        return eng.sessions["a"].history

    assert drive(True) == drive(False)


# ======================================================================
# whole-system differential: the deterministic replay gateway
# ======================================================================
def test_replay_gateway_fused_vs_tokenwise(tiny):
    """The full control plane (Algorithm 1, frontier cap, barge storms,
    OutOfPages requeue) over both planes on the same virtual clock:
    the scheduling-visible record — TTFP, completion order, barges,
    token counts — must be identical."""
    from repro.serving.gateway.replay import ReplayConfig, run_replay
    from repro.serving.workload import WorkloadConfig
    cfg, params = tiny
    wl = WorkloadConfig(kind="interactive", num_sessions=4, seed=5,
                        p_barge_in=0.5, arrival="poisson", rate_rps=4.0)

    def run(fused):
        def factory(clock):
            return PagedRealtimeEngine(
                cfg, params, slots=2, page_size=8, pages_per_seq=8,
                clock=clock, fused_step=fused)
        m, gw = run_replay(factory, wl,
                           ReplayConfig(round_token_budget=8,
                                        prefill_chunk=6), seed=5)
        return [(t.session_id, t.turn_index, t.ttfp, t.finish_time,
                 t.completed, t.barged, t.talker_generated)
                for t in m.turns], gw

    want, _ = run(False)
    got, gw = run(True)
    assert got == want
    # at most one launch per executed round (a round whose feeds were
    # all pressure-held launches nothing)
    assert 0 < gw.eng.fused_launches <= gw.rounds


# ======================================================================
# mesh twins (multidev lane; CI multidevice job / full local runs)
# ======================================================================
@multidev
@pytest.mark.parametrize("shape", [(1, 2), (1, 8), (2, 2)])
def test_fused_sharded_engine_token_exact(tiny, shape):
    """heads (1,2 / 2,2) and slots (1,8 — chunk spans several shards'
    slot stripes) layouts: the mesh-sharded fused engine is token-exact
    with the single-device per-token control on the full differential
    trace."""
    if shape[0] * shape[1] > NDEV:
        pytest.skip(f"mesh {shape} > {NDEV} devices")
    cfg, params = tiny
    want = _drive_differential(cfg, params, 11, fused=False,
                               page_size=8)
    mesh = jax.make_mesh(shape, ("data", "model"))
    got = _drive_differential(cfg, params, 11, mesh=mesh, fused=True,
                              page_size=8)
    assert got[:3] == want[:3]
    assert got[3].kv.reloaded_blocks >= 1     # reload ran on the mesh

"""Kernel autotune harness (DESIGN.md §16, ISSUE 10).

- cache round-trip (enable → sweep → save → reload → lookup serves the
  same entry) and the invalidation rules: a format-version bump
  discards the file, the backend key component misses across backends,
  unswept shapes miss to the static defaults;
- tiling exactness: any legal ``kv_block``/``head_block`` is
  output-identical (head_block splits bit-exactly by per-head softmax
  independence; kv_block re-tiles the flash accumulation within
  tolerance of the oracle);
- the sweep is gated by the arithmetic-intensity model and reproducibly
  selects the non-default kv_block=32 for the page=32 decode shape
  (the probe ``benchmarks/autotune_bench.py`` reports);
- ``_resolve`` consults the cache only for unset knobs and only while
  enabled — disabled serving keeps the static defaults (bit-exact
  spec_decode=0 control stays untouched).
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import autotune, ref
from repro.kernels.paged_attention import (_default_kv_block, _resolve,
                                           paged_attention,
                                           paged_prefill_attention)


@pytest.fixture(autouse=True)
def _clean_state():
    """Autotune state is process-global; never leak it across tests."""
    autotune.disable()
    yield
    autotune.disable()


def _decode_case(key, B=3, Hq=4, Hkv=2, D=16, page=32, pps=3):
    ks = jax.random.split(key, 4)
    num_pages = B * pps + 2
    q = jax.random.normal(ks[0], (B, Hq, D), jnp.float32)
    kp = jax.random.normal(ks[1], (num_pages, page, Hkv, D), jnp.float32)
    vp = jax.random.normal(ks[2], (num_pages, page, Hkv, D), jnp.float32)
    bt = jax.random.permutation(
        ks[3], num_pages)[:B * pps].reshape(B, pps).astype(jnp.int32)
    sl = jnp.array([page * pps - 5, 7, 1], jnp.int32)[:B]
    return q, kp, vp, bt, sl


# ======================================================================
# keys, defaults, resolve fallbacks
# ======================================================================
def test_shape_key_is_canonical():
    assert autotune.shape_key(b=1, a=2) == autotune.shape_key(a=2, b=1)
    assert autotune.shape_key(B=4, page=32) == "B=4,page=32"


def test_cache_key_carries_backend():
    k = autotune.cache_key("paged_attention", "B=1", backend="tpu")
    assert k == "paged_attention|B=1|tpu"
    assert autotune.cache_key("paged_attention", "B=1") \
        == f"paged_attention|B=1|{jax.default_backend()}"


def test_default_kv_block_heuristic():
    # whole-page tiles up to 16 slots and for non-16-divisible pages;
    # 16-slot lane sub-tiles otherwise
    assert [_default_kv_block(p) for p in (4, 8, 16, 20, 24, 32, 64)] \
        == [4, 8, 16, 20, 24, 16, 16]


def test_resolve_disabled_uses_static_defaults():
    assert not autotune.enabled()
    dims = dict(B=2, Hq=4, Hkv=2, D=16, page=32, pps=4)
    assert _resolve("paged_attention", None, None, page=32, Hkv=2,
                    dims=dims) == (16, 2)
    # explicit knobs always win
    assert _resolve("paged_attention", 32, 1, page=32, Hkv=2,
                    dims=dims) == (32, 1)
    with pytest.raises(AssertionError):
        _resolve("paged_attention", 7, None, page=32, Hkv=2, dims=dims)


def test_resolve_consults_cache_only_for_unset_knobs(tmp_path):
    autotune.enable(str(tmp_path / "cache.json"))
    dims = dict(B=2, Hq=4, Hkv=2, D=16, page=32, pps=4)
    skey = autotune.shape_key(**dims)
    autotune._STATE["cache"][autotune.cache_key("paged_attention", skey)] \
        = {"kv_block": 8, "head_block": 1}
    assert _resolve("paged_attention", None, None, page=32, Hkv=2,
                    dims=dims) == (8, 1)
    # a set knob is never overridden; the other still fills from cache
    assert _resolve("paged_attention", 32, None, page=32, Hkv=2,
                    dims=dims) == (32, 1)
    # unswept shape: miss, static defaults
    other = dict(dims, B=3)
    assert _resolve("paged_attention", None, None, page=32, Hkv=2,
                    dims=other) == (16, 2)
    s = autotune.stats()
    assert s["hits"] >= 2 and s["misses"] >= 1


# ======================================================================
# cache round-trip + invalidation
# ======================================================================
def test_cache_round_trip(tmp_path):
    path = str(tmp_path / "cache.json")
    assert autotune.enable(path) == 0
    entry = autotune.sweep("paged_attention", B=2, Hq=2, Hkv=1, D=8,
                           page=8, pps=2, reps=1)
    skey = autotune.shape_key(B=2, Hq=2, Hkv=1, D=8, page=8, pps=2)
    assert autotune.lookup("paged_attention", skey) == entry
    assert autotune.save() == path
    autotune.disable()
    assert autotune.lookup("paged_attention", skey) is None
    assert autotune.enable(path) == 1
    got = autotune.lookup("paged_attention", skey)
    assert got == entry
    assert {"kv_block", "head_block", "measured_us", "default_us",
            "model_us", "reps"} <= set(got)


def test_version_bump_discards_cache(tmp_path):
    path = str(tmp_path / "stale.json")
    with open(path, "w") as f:
        json.dump({"__meta__": {"version": autotune.FORMAT_VERSION + 1},
                   "paged_attention|B=1|cpu": {"kv_block": 8,
                                               "head_block": 1}}, f)
    assert autotune.enable(path) == 0
    # a versionless (pre-harness) file is equally stale
    with open(path, "w") as f:
        json.dump({"paged_attention|B=1|cpu": {"kv_block": 8}}, f)
    assert autotune.enable(path) == 0


def test_backend_component_invalidates_across_backends(tmp_path):
    autotune.enable(str(tmp_path / "cache.json"))
    skey = "B=1"
    autotune._STATE["cache"][autotune.cache_key(
        "paged_attention", skey, backend="some-other-backend")] \
        = {"kv_block": 8, "head_block": 1}
    assert autotune.lookup("paged_attention", skey) is None


# ======================================================================
# tiling exactness
# ======================================================================
def test_kv_block_tilings_match_oracle():
    q, kp, vp, bt, sl = _decode_case(jax.random.PRNGKey(0))
    want = np.asarray(ref.paged_attention_ref(q, kp, vp, bt, sl))
    for kv_block in (8, 16, 32):
        got = paged_attention(q, kp, vp, bt, sl, interpret=True,
                              kv_block=kv_block)
        np.testing.assert_allclose(np.asarray(got), want,
                                   rtol=2e-5, atol=2e-5,
                                   err_msg=f"kv_block={kv_block}")


def test_head_block_split_is_bit_exact():
    """Each KV head's softmax never mixes with another's, so the
    head-split launch must reproduce the whole launch bit for bit."""
    q, kp, vp, bt, sl = _decode_case(jax.random.PRNGKey(1))
    whole = paged_attention(q, kp, vp, bt, sl, interpret=True,
                            kv_block=16, head_block=2)
    split = paged_attention(q, kp, vp, bt, sl, interpret=True,
                            kv_block=16, head_block=1)
    np.testing.assert_array_equal(np.asarray(whole), np.asarray(split))


def test_prefill_kernel_tilings_match_oracle():
    key = jax.random.PRNGKey(2)
    B, Q, Hq, Hkv, D, page, pps = 2, 4, 4, 2, 16, 32, 3
    ks = jax.random.split(key, 4)
    num_pages = B * pps + 2
    q = jax.random.normal(ks[0], (B, Q, Hq, D), jnp.float32)
    kp = jax.random.normal(ks[1], (num_pages, page, Hkv, D), jnp.float32)
    vp = jax.random.normal(ks[2], (num_pages, page, Hkv, D), jnp.float32)
    bt = jax.random.permutation(
        ks[3], num_pages)[:B * pps].reshape(B, pps).astype(jnp.int32)
    qs = jnp.array([11, 3], jnp.int32)
    ql = jnp.array([4, 2], jnp.int32)
    want = np.asarray(ref.paged_prefill_attention_ref(
        q, kp, vp, bt, qs, ql), np.float32)
    for kv_block, head_block in ((8, 2), (32, 2), (16, 1)):
        got = paged_prefill_attention(q, kp, vp, bt, qs, ql,
                                      interpret=True, kv_block=kv_block,
                                      head_block=head_block)
        for b in range(B):          # padding rows are unspecified
            n = int(ql[b])
            np.testing.assert_allclose(
                np.asarray(got, np.float32)[b, :n], want[b, :n],
                rtol=2e-5, atol=2e-5,
                err_msg=f"kv_block={kv_block},head_block={head_block}")


# ======================================================================
# the sweep
# ======================================================================
def test_sweep_selects_nondefault_for_page32(tmp_path):
    """Pinned-config regression for the reproducibility probe: on the
    interpret path, one grid step per whole page=32 measurably beats
    the 16-slot default tile, and the sweep must keep finding it (the
    benchmark showed a ~4x margin; acceptance-criterion shape)."""
    autotune.enable(str(tmp_path / "cache.json"))
    entry = autotune.sweep("paged_attention", B=4, Hq=4, Hkv=2, D=16,
                           page=32, pps=4, reps=2)
    assert entry["kv_block"] == 32, entry
    assert entry["measured_us"] < entry["default_us"]


def test_sweep_roofline_gate_blocks_measured_winner(tmp_path):
    """With a gate ratio below 1 every non-default candidate is modeled
    ineligible — the sweep must keep the static default no matter what
    wall-clock says."""
    autotune.enable(str(tmp_path / "cache.json"))
    entry = autotune.sweep("paged_attention", B=4, Hq=4, Hkv=2, D=16,
                           page=32, pps=4, reps=1, gate_ratio=1e-9)
    assert entry["kv_block"] == _default_kv_block(32)
    assert entry["head_block"] == 2


def test_modeled_cost_orders_step_and_launch_overheads():
    kw = dict(B=4, Hkv=2, D=16, page=32, pps=4)
    # smaller tiles -> more grid steps -> strictly costlier model
    assert autotune.modeled_cost_us(kv_block=8, head_block=2, **kw) \
        > autotune.modeled_cost_us(kv_block=16, head_block=2, **kw) \
        > autotune.modeled_cost_us(kv_block=32, head_block=2, **kw)
    # head splitting doubles launch dispatches
    assert autotune.modeled_cost_us(kv_block=32, head_block=1, **kw) \
        > autotune.modeled_cost_us(kv_block=32, head_block=2, **kw)


def test_candidate_space_covers_default_and_whole_page():
    cfgs = autotune.candidate_configs(32, 2)
    kvs = {c["kv_block"] for c in cfgs}
    assert {16, 32} <= kvs          # static default + whole page
    assert all(32 % kb == 0 for kb in kvs)
    assert {c["head_block"] for c in cfgs} == {1, 2}
    assert {c["head_block"] for c in autotune.candidate_configs(8, 1)} \
        == {1}


def test_tuned_lookup_feeds_the_kernel(tmp_path):
    """End-to-end: enable a cache holding a non-default tiling for the
    exact call shape, call the kernel with knobs unset, and the tuned
    config must be consulted (hit counter) while staying correct."""
    q, kp, vp, bt, sl = _decode_case(jax.random.PRNGKey(3))
    B, Hq, D = q.shape
    _, page, Hkv, _ = kp.shape
    dims = dict(B=B, Hq=Hq, Hkv=Hkv, D=D, page=page, pps=bt.shape[1])
    autotune.enable(str(tmp_path / "cache.json"))
    autotune._STATE["cache"][autotune.cache_key(
        "paged_attention", autotune.shape_key(**dims))] \
        = {"kv_block": 32, "head_block": 1}
    hits0 = autotune.stats()["hits"]
    got = paged_attention(q, kp, vp, bt, sl, interpret=True)
    assert autotune.stats()["hits"] > hits0
    want = ref.paged_attention_ref(q, kp, vp, bt, sl)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)

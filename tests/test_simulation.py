"""End-to-end behaviour tests of the serving harness (paper §7 claims as
assertions, reduced scale)."""
import pytest

from repro.core.scheduler import SchedulerConfig
from repro.serving.costmodel import ming_omni_like, qwen3_omni_like
from repro.serving.simulator import Simulation, run_sim
from repro.serving.workload import WorkloadConfig


def _run(kind="sharegpt", policy="liveserve", c=8, n=24, pbi=0.0, gb=6.0,
         seed=3, **kw):
    pipe = qwen3_omni_like(kv_capacity_gb=gb)
    wl = WorkloadConfig(kind=kind, num_sessions=n, concurrency=c,
                        seed=seed, p_barge_in=pbi)
    return run_sim(pipe, wl, policy=policy, until=2000.0, **kw)


def test_all_sessions_complete_and_rtf_below_one():
    m = _run()
    assert m.completed_sessions == 24
    assert all(t.completed or t.barged for t in m.turns)
    s = m.summary()
    assert s["p90_rtf"] < 1.0                    # faster than real time
    assert s["p90_ttfp"] < 2.0


def test_liveserve_beats_fcfs_under_bargein():
    """Fig. 13/16: lower TTFP and much lower token waste with barge-in."""
    mls = _run(pbi=0.5, c=12, n=36)
    mfc = _run(pbi=0.5, c=12, n=36, policy="fcfs")
    assert mls.p90_ttfp() <= mfc.p90_ttfp() * 1.05
    assert mls.waste_ratio() < 0.5 * mfc.waste_ratio()


def test_no_bargein_no_waste():
    m = _run(pbi=0.0)
    assert m.waste_ratio() == 0.0


def test_continuity_high_under_load():
    m = _run(kind="interactive", c=16, n=36)
    assert m.continuity() > 0.9


def test_multiturn_kv_reuse_and_preload():
    """Interactive sessions reuse KV; preload keeps reload off-path."""
    pipe = qwen3_omni_like(kv_capacity_gb=1.0)   # force offload pressure
    wl = WorkloadConfig(kind="interactive", num_sessions=24, concurrency=12,
                        seed=5)
    sim = Simulation(pipe, wl, policy="liveserve")
    m = sim.run(until=2000.0)
    kv = sim.kvs["thinker"]
    assert kv.evicted_blocks > 0                 # pressure actually occurred
    pre = sim.preloaders["thinker"]
    assert pre.stats.triggered > 0
    ls_stall = m.summary()["mean_reload_stall"]

    sim2 = Simulation(pipe, wl, policy="fcfs")
    m2 = sim2.run(until=2000.0)
    fc_stall = m2.summary()["mean_reload_stall"]
    ls_reloaded = m.summary()["mean_reload_stall"] \
        + m.summary()["mean_reload_off_path"]
    # compare only when both policies actually did reload work: the
    # overlap fraction's 0.0 also stands for "never reloaded", which
    # would read as worst-case overlap and fail spuriously
    if fc_stall > 0 and ls_reloaded > 0:
        # the preload's effect is the off-path share, not the raw mean
        # stall (the two policies evict different victims, so they do
        # different amounts of total reload work — comparing means
        # conflated the two and silently leaned on a heap-index bug
        # that under-evicted liveserve sessions): liveserve hides a
        # strictly larger fraction, and never pays a blow-up on-path
        assert m.summary()["reload_overlap_frac"] \
            > m2.summary()["reload_overlap_frac"]
        assert ls_stall <= fc_stall * 1.25


def test_none_policy_recomputes_instead_of_reload():
    pipe = qwen3_omni_like(kv_capacity_gb=1.0)
    wl = WorkloadConfig(kind="interactive", num_sessions=16, concurrency=8,
                        seed=7)
    m = run_sim(pipe, wl, policy="fcfs", kv_policy="none", until=2000.0)
    assert all(t.reload_stall_s == 0 for t in m.turns)  # nothing to reload
    assert m.completed_sessions == 16            # correctness preserved


def test_barged_turns_keep_partial_context():
    pipe = qwen3_omni_like()
    wl = WorkloadConfig(kind="interactive", num_sessions=8, concurrency=4,
                        seed=11, p_barge_in=1.0)
    sim = Simulation(pipe, wl, policy="liveserve")
    sim.run(until=2000.0)
    barged = [t for t in sim.metrics.turns if t.barged]
    assert barged, "p_bi=1.0 must produce barge-ins"
    for t in barged:
        assert t.talker_wasted >= 0
        assert t.talker_wasted <= t.talker_generated
    # sessions continue after interruption and keep context
    multi = [s for s in sim.sessions.values() if s.context_tokens > 0]
    assert multi


def test_ablation_components_are_additive_knobs():
    """Fig. 14: each mechanism can be toggled independently."""
    pipe = qwen3_omni_like(kv_capacity_gb=2.0)
    wl = WorkloadConfig(kind="interactive", num_sessions=16, concurrency=8,
                        seed=13, p_barge_in=0.5)
    variants = {
        "fcfs+lru": dict(policy="fcfs"),
        "sched": dict(policy="liveserve", kv_policy="lru", preload=False),
        "sched+preload": dict(policy="liveserve", kv_policy="lru",
                              preload=True),
        "full": dict(policy="liveserve"),
    }
    res = {k: run_sim(pipe, wl, until=2000.0, **v).summary()
           for k, v in variants.items()}
    assert res["full"]["waste_ratio"] < res["fcfs+lru"]["waste_ratio"]


def test_ming_pipeline_also_works():
    pipe = ming_omni_like()
    wl = WorkloadConfig(kind="sharegpt", num_sessions=12, concurrency=6,
                        seed=17)
    m = run_sim(pipe, wl, policy="liveserve", until=2000.0)
    assert m.completed_sessions == 12
    assert m.summary()["p90_rtf"] < 1.0


def test_deterministic_given_seed():
    a = _run(seed=21).summary()
    b = _run(seed=21).summary()
    assert a == b

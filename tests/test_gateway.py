"""Realtime gateway: the LiveServe control plane driving the real paged
engine over an event protocol under a scaled wall clock (DESIGN.md §4).

Covers the tentpole contracts:
- scheduler-drivable engine API: submit_turn/run_round chunked paged
  prefill produces the same tokens as the dense decode-step reference;
- the integration criteria: >= 8 concurrent barge-in sessions where
  (a) liveserve beats fcfs on P90 TTFP for the same seed, (b) no
  session decodes past the configured playback-frontier margin, and
  (c) the gateway's metrics summary schema is the simulator's;
- event-protocol behavior: barge-in mid-turn aborts and the session
  continues on committed KV; hangup frees pages;
- run_to_completion raises on round exhaustion instead of returning.
"""
import asyncio

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import decode_step, init_cache, init_params
from repro.serving.engine import RealtimeLLMEngine, RoundLimitExceeded
from repro.serving.gateway import (AudioChunk, BargeIn, Hangup,
                                   GatewayConfig, RealtimeGateway,
                                   ScaledWallClock, SessionClosed,
                                   SpeechEnd, SpeechStart, TurnDone,
                                   TurnRequest, run_gateway_workload)
from repro.serving.gateway.harness import build_gateway
from repro.serving.metrics import Metrics
from repro.serving.paged_engine import PagedRealtimeEngine


@pytest.fixture(scope="module")
def tiny():
    cfg = reduced(get_config("qwen2-1.5b"), layers=2, d_model=64,
                  vocab=331)
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


# ------------------------------------------------------------ clock
def test_scaled_wall_clock():
    import time
    clock = ScaledWallClock(scale=100.0)
    t0 = clock.now()
    time.sleep(0.02)
    dt = clock.now() - t0
    assert dt >= 2.0                  # 20ms real >= 2s scaled
    clock.tick(5.0)                   # modelled cost lands on the clock
    assert clock.now() - t0 >= 7.0
    assert clock.real_s(10.0) == pytest.approx(0.1)


# ------------------------------------------------- engine round API
def _dense_reference(cfg, params, prompt, n):
    """Incremental dense decode reference (the §5.2 contract: the paged
    step is token-equivalent to dense decode_step)."""
    cache = init_cache(cfg, 1, 256)
    nxt = None
    for tok in prompt:
        lg, cache = decode_step(cfg, params,
                                jnp.asarray([int(tok)], jnp.int32), cache)
        nxt = int(jnp.argmax(lg[0]))
    toks = [nxt]
    for _ in range(n - 1):
        lg, cache = decode_step(cfg, params,
                                jnp.asarray([toks[-1]], jnp.int32), cache)
        toks.append(int(jnp.argmax(lg[0])))
    return toks


def test_submit_turn_chunked_prefill_parity(tiny):
    """Scheduler-driven chunked prefill through run_round emits the same
    tokens as the dense reference, with interleaving decode present."""
    cfg, params = tiny
    rng = np.random.default_rng(0)
    pa = rng.integers(0, cfg.vocab_size, size=7)
    pb = rng.integers(0, cfg.vocab_size, size=5)
    eng = PagedRealtimeEngine(cfg, params, slots=2, page_size=4,
                              pages_per_seq=16)
    sa = eng.submit_turn("a", pa, max_new_tokens=6)
    sb = eng.submit_turn("b", pb, max_new_tokens=5)
    emitted = {"a": [], "b": []}
    rounds = 0
    while eng.active() and rounds < 100:
        evs = eng.run_round({sa: 2, sb: 3})
        for slot, lst in evs.items():
            sid = "a" if slot == sa else "b"
            emitted[sid] += [v for k, v in lst if k == "token"]
        rounds += 1
    eng.check_invariants()
    assert emitted["a"] == _dense_reference(cfg, params, pa, 6)
    assert emitted["b"] == _dense_reference(cfg, params, pb, 5)
    # emitted streams match the engine's own record
    assert eng.sessions["a"].history == [emitted["a"]]


def test_run_to_completion_raises_on_exhaustion(tiny):
    cfg, params = tiny
    rng = np.random.default_rng(1)
    eng = PagedRealtimeEngine(cfg, params, slots=2, page_size=4,
                              pages_per_seq=16)
    eng.add_session("a", rng.integers(0, cfg.vocab_size, size=5),
                    max_new_tokens=50)
    with pytest.raises(RoundLimitExceeded):
        eng.run_to_completion(max_rounds=3)
    dense = RealtimeLLMEngine(cfg, params, slots=2, capacity=64)
    dense.add_session("a", rng.integers(0, cfg.vocab_size, size=5),
                      max_new_tokens=50)
    with pytest.raises(RoundLimitExceeded):
        dense.run_to_completion(max_rounds=3)


# ------------------------------------------------- event protocol
def test_gateway_barge_in_and_next_turn(tiny):
    """Scripted client: barge in mid-decode, then the next turn resumes
    on the committed pages through the same gateway."""
    cfg, params = tiny
    gw = build_gateway(policy="liveserve", scale=50.0, slots=2,
                       page_size=4, pages_per_seq=16,
                       audio_per_token_s=0.5, round_token_budget=2,
                       model=(cfg, params))
    rng = np.random.default_rng(2)

    async def scenario():
        serve = asyncio.create_task(gw.run())
        h = gw.connect("alice")
        await h.send(SpeechStart("alice", expected_dur_s=0.5))
        await gw.clock.sleep(0.5)
        await h.send(SpeechEnd("alice"))
        await h.send(TurnRequest(
            "alice", prompt=rng.integers(0, cfg.vocab_size, size=6),
            max_new_tokens=12))
        chunks = 0
        while chunks < 3:                       # hear a few chunks
            ev = await asyncio.wait_for(h.recv(), timeout=30)
            if isinstance(ev, AudioChunk):
                chunks += 1
        await h.send(BargeIn("alice", expected_dur_s=0.4))
        while True:
            ev = await asyncio.wait_for(h.recv(), timeout=30)
            if isinstance(ev, TurnDone):
                assert ev.aborted
                break
        # the interrupting utterance becomes the next turn
        await gw.clock.sleep(0.4)
        await h.send(SpeechEnd("alice"))
        await h.send(TurnRequest(
            "alice", prompt=rng.integers(0, cfg.vocab_size, size=4),
            max_new_tokens=4))
        while True:
            ev = await asyncio.wait_for(h.recv(), timeout=30)
            if isinstance(ev, TurnDone):
                assert not ev.aborted
                break
        await h.send(Hangup("alice"))
        while True:
            ev = await asyncio.wait_for(h.recv(), timeout=30)
            if isinstance(ev, SessionClosed):
                break
        gw.stop()
        await serve

    asyncio.run(scenario())
    eng = gw.engine
    sess = eng.sessions["alice"]
    assert sess.turn_stats[0]["aborted"]
    assert not sess.turn_stats[1]["aborted"]
    # turn 2 extended committed KV, never re-prefilled history
    assert sess.turn_stats[1]["context_tokens"] > 0
    assert sess.turn_stats[1]["re_prefill_tokens"] == 0
    assert sess.ended                          # hangup freed the pages
    assert eng.pool.free_pages == eng.num_pages
    m = gw.metrics()
    assert m.turns[0].barged and m.turns[0].talker_wasted >= 0
    assert m.turns[1].completed


# ------------------------------------------------- soak (ISSUE 3)
@pytest.mark.slow
def test_gateway_soak_barge_storm(tiny):
    """16 concurrent sessions with seeded barge-in storms at high tempo:
    engine invariants hold after *every* round, no slot or page leaks
    after all sessions hang up, the frontier cap holds, and every turn
    is accounted (completed or barged) — the leak/cleanup soak for the
    paged data plane under the asyncio gateway."""
    apt = 0.4
    gw = build_gateway(policy="liveserve", scale=16.0, model=tiny,
                       slots=8, page_size=8, pages_per_seq=8,
                       num_pages=40,            # mild pool pressure
                       frontier_cap_s=3.0, round_token_budget=4,
                       audio_per_token_s=apt)
    rounds_checked = 0
    orig_round = gw._round

    def checked_round():
        nonlocal rounds_checked
        ran = orig_round()
        gw.engine.check_invariants()          # clean every round
        rounds_checked += 1
        return ran

    gw._round = checked_round
    m, gw = run_gateway_workload(
        policy="liveserve", sessions=16, barge_in=0.7, seed=3,
        rate_rps=8.0, max_prompt=8, max_response=8, max_turns=2,
        speech_scale=0.5, gateway=gw, timeout_s=300)
    eng = gw.engine
    assert rounds_checked > 0 and gw.rounds > 0
    # no slot leaks: every decode slot returned to the pool
    assert all(s is None for s in eng.slot_state.values())
    # no page leaks: every session hung up, every page back in the pool
    assert all(s.ended for s in eng.sessions.values())
    assert eng.pool.free_pages == eng.num_pages
    assert eng.kv.used_blocks == 0
    assert m.completed_sessions == 16
    # every turn accounted: finished or barged, none lost in the storm
    assert len(m.turns) == 32
    assert all(t.completed or t.barged for t in m.turns)
    assert sum(t.barged for t in m.turns) >= 4   # the storm stormed
    # frontier invariant under the storm
    assert gw.max_over_frontier_s <= apt + 1e-6
    eng.check_invariants()


def test_gateway_surfaces_engine_errors(tiny):
    """RoundLimitExceeded (or any engine failure) mid-serve must
    propagate out of the harness — never be swallowed by the event
    loop or misreported as a load-generator timeout."""
    gw = build_gateway(policy="liveserve", scale=16.0, model=tiny,
                       slots=2, page_size=4, pages_per_seq=8)
    orig = gw.engine.run_round
    calls = {"n": 0}

    def failing(chunks):
        calls["n"] += 1
        if calls["n"] > 2:
            raise RoundLimitExceeded("injected engine live-lock")
        return orig(chunks)

    gw.engine.run_round = failing
    with pytest.raises(RoundLimitExceeded, match="injected"):
        run_gateway_workload(policy="liveserve", sessions=2,
                             barge_in=0.0, seed=0, gateway=gw,
                             timeout_s=120)


# ------------------------------------------------- integration (a-c)
def test_gateway_liveserve_vs_fcfs_integration(tiny):
    """8 concurrent barge-in sessions, scaled clock, real paged engine:
    (a) liveserve P90 TTFP < fcfs on the same seed, (b) the playback
    frontier cap holds, (c) summary schema == simulator's."""
    apt = 0.6
    cap = 3.0

    def run_pair():
        out = {}
        for policy, frontier in (("liveserve", cap), ("fcfs", None)):
            gw = build_gateway(policy=policy, scale=4.0, model=tiny,
                               frontier_cap_s=frontier,
                               round_token_budget=2, pages_per_seq=10,
                               audio_per_token_s=apt)
            m, gw = run_gateway_workload(
                policy=policy, sessions=8, barge_in=0.3, seed=0,
                rate_rps=8.0, max_response=16, max_prompt=12,
                gateway=gw, timeout_s=300)
            out[policy] = (m, gw)
        return out

    out = run_pair()
    if out["liveserve"][0].p90_ttfp() >= out["fcfs"][0].p90_ttfp():
        # the policies run on a real scaled wall clock; a transient CPU
        # stall on a loaded runner can inflate one run's tail. The gap
        # is ~2-3x under normal conditions — one retry absorbs the
        # stall without weakening the policy assertion.
        out = run_pair()
    live_m, live_gw = out["liveserve"]
    fcfs_m, _ = out["fcfs"]
    # every session got served, concurrently, on one engine
    assert len(live_gw._sessions) == 8
    assert live_m.summary()["turns"] == 16          # 2 turns x 8 sessions
    assert live_m.completed_sessions == 8
    # (a) interaction-aware scheduling beats FCFS on tail first-audio
    assert live_m.p90_ttfp() < fcfs_m.p90_ttfp()
    # (b) nobody decoded past frontier cap + one chunk of granularity
    assert live_gw.max_over_frontier_s <= apt + 1e-6
    # (c) identical summary schema -> sim-vs-real is a dict diff
    assert set(live_m.summary()) == set(Metrics().summary())
    # barge-ins actually happened and produced waste accounting
    assert any(t.barged for t in live_m.turns)
    assert live_m.summary()["waste_ratio"] > 0.0
    # engine-level invariants survived the full concurrent run
    live_gw.engine.check_invariants()

"""Quantized KV wire tier (DESIGN.md §14): the shared int8 block
quantizer's round-trip guarantees, the wire-scale threading through the
modeled PCIe channel, and the fp32 identity codec's bit-exactness on
the paged data plane.

Quantizer contracts (shared with distributed/compression.py):

- round-trip error is bounded per element: |decode(encode(x)) - x|
  <= scale/2 with scale = max(|block|, eps)/127 — which requires the
  epsilon to guard the block *max*, not be added after the division
  (the compression.py bug this PR fixes);
- exact zeros survive exactly (round(0) * scale == 0);
- a tail block's pad lanes are zeros, so they never raise that block's
  scale — the partial block quantizes as if it were alone.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.configs import get_config, reduced
from repro.kvcache.quant import (BLOCK, EPS, KVWireCodec, QuantizedPage,
                                 decode_page, encode_page)
from repro.models import init_params
from repro.serving.paged_engine import PagedRealtimeEngine


@pytest.fixture(scope="module")
def tiny():
    cfg = reduced(get_config("qwen2-1.5b"), layers=2, d_model=64,
                  vocab=331)
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _engine(tiny, **kw):
    cfg, params = tiny
    kw.setdefault("slots", 2)
    kw.setdefault("page_size", 4)
    kw.setdefault("pages_per_seq", 8)
    kw.setdefault("num_pages", 32)
    kw.setdefault("chunk_pages", 1)
    return PagedRealtimeEngine(cfg, params, **kw)


def _assert_roundtrip(x: np.ndarray) -> None:
    """The three quantizer guarantees on one array."""
    page = encode_page(x)
    back = decode_page(page)
    assert back.shape == x.shape and back.dtype == x.dtype
    # per-block error bound: expand scales back over elements
    flat = np.asarray(x, np.float32).reshape(-1)
    pad = (-flat.size) % BLOCK
    blocks = np.pad(flat, (0, pad)).reshape(-1, BLOCK)
    scales = np.maximum(np.abs(blocks).max(axis=1), EPS) / 127.0
    err = np.abs(np.asarray(back, np.float32).reshape(-1) - flat)
    bound = np.repeat(scales, BLOCK)[:flat.size]
    assert np.all(err <= bound / 2 + 1e-7), \
        f"max err {err.max()} vs bound {bound.min() / 2}"
    # exact zeros preserved
    np.testing.assert_array_equal(back.reshape(-1)[flat == 0.0], 0.0)


# ===================================================== quantizer core
def test_roundtrip_deterministic_grid():
    """Pinned fallback for the property below (always runs on the fast
    lane even without hypothesis): shapes that exercise exact-multiple,
    sub-block, and ragged-tail padding, over value regimes from
    subnormal-small to large mixed-sign."""
    rng = np.random.default_rng(7)
    shapes = [(BLOCK,), (3, BLOCK), (5,), (BLOCK + 3,),
              (2, 2, BLOCK // 2 + 1), (2, 3, 4, 5)]
    for shape in shapes:
        for scale_mag in (1e-8, 1.0, 1e4):
            x = (rng.standard_normal(shape) * scale_mag) \
                .astype(np.float32)
            _assert_roundtrip(x)
    # all-zero array: eps guard, exact zero round trip
    _assert_roundtrip(np.zeros((BLOCK + 9,), np.float32))
    # mixed zeros and extremes in one block
    x = np.zeros((BLOCK,), np.float32)
    x[0], x[1] = 1e6, -1e6
    _assert_roundtrip(x)


def test_pad_lanes_never_raise_the_tail_scale():
    """A ragged tail's pad lanes are zeros: the tail block's scale is
    set by its real values alone, identical to quantizing the tail as
    its own array."""
    rng = np.random.default_rng(11)
    x = rng.standard_normal(BLOCK + 17).astype(np.float32)
    whole = encode_page(x)
    tail = encode_page(x[BLOCK:])
    assert whole.scales[-1] == tail.scales[0]
    np.testing.assert_array_equal(whole.q[-1], tail.q[0])


def test_max_magnitude_hits_127():
    """The epsilon-placement fix, observable: with the guard on the max
    (not added after the division) the block's max-magnitude element
    quantizes to exactly +/-127. The old `max/127 + eps` form inflated
    every scale, so it never did."""
    rng = np.random.default_rng(13)
    x = rng.standard_normal(BLOCK).astype(np.float32)
    page = encode_page(x)
    i = int(np.argmax(np.abs(x)))
    assert abs(int(page.q.reshape(-1)[i])) == 127
    # and the old form provably violates the scale/2 bound here
    bad_scale = np.abs(x).max() / 127.0 + 1e-3
    bad = np.clip(np.rint(x / bad_scale), -127, 127) * bad_scale
    good_scale = float(page.scales[0])
    assert np.abs(bad - x).max() > good_scale / 2


@pytest.mark.slow
@given(n=st.integers(1, 3 * BLOCK + 7),
       log_mag=st.floats(-8, 6), seed=st.integers(0, 2**31 - 1),
       zero_frac=st.floats(0, 1))
@settings(max_examples=60, deadline=None)
def test_roundtrip_property(n, log_mag, seed, zero_frac):
    """Hypothesis soak of the same three guarantees over arbitrary
    sizes, magnitudes, and zero densities."""
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal(n) * 10.0 ** log_mag).astype(np.float32)
    x[rng.random(n) < zero_frac] = 0.0
    _assert_roundtrip(x)


def test_codec_formats():
    c = KVWireCodec("fp32")
    x = np.ones((4, 3), np.float32)
    assert c.encode(x) is x                        # identity, not a copy
    assert c.decode(x) is x
    assert c.wire_scale(np.float32) == 1.0
    q = KVWireCodec("int8")
    enc = q.encode(x)
    assert isinstance(enc, QuantizedPage)
    np.testing.assert_allclose(q.decode(enc), x, atol=1e-6)
    # int8 payload + one fp32 scale per BLOCK elements, against 4B/elt
    assert q.wire_scale(np.float32) == pytest.approx(
        (1 + 4 / BLOCK) / 4)
    assert q.wire_scale(np.float32) < 0.5          # the ISSUE criterion
    with pytest.raises(ValueError, match="kv_quant"):
        KVWireCodec("int4")


# ================================================= wire-scale threading
def test_channel_prices_compressed_bytes(tiny):
    """kv_quant=int8 threads the codec's wire scale into the modeled
    PCIe channel: transfer_time shrinks by the same factor, so chunk
    sizing and every stall/overlap consumer see compressed bytes;
    block_bytes stays logical for capacity accounting."""
    f32 = _engine(tiny)
    i8 = _engine(tiny, kv_quant="int8")
    ws = i8.codec.wire_scale(np.dtype(i8.cfg.dtype))
    assert f32.kv.channel.wire_scale == 1.0
    assert i8.kv.channel.wire_scale == pytest.approx(ws)
    assert i8.kv.channel.block_bytes == f32.kv.channel.block_bytes
    assert i8.kv.channel.transfer_time(5) == pytest.approx(
        f32.kv.channel.transfer_time(5) * ws)
    assert i8.kv.channel.wire_bytes(5) == pytest.approx(
        5 * i8.kv.channel.block_bytes * ws)


def test_offload_reload_roundtrip_within_tolerance(tiny):
    """int8 engine: evict -> flush -> clobber -> reload; the reloaded
    device pages match the pre-offload contents within the block
    quantizer's error bound, and the ledger reports the wire savings."""
    cfg, _ = tiny
    rng = np.random.default_rng(3)
    eng = _engine(tiny, num_pages=12, kv_quant="int8")
    eng.add_session("a", rng.integers(0, cfg.vocab_size, size=10),
                    max_new_tokens=6)
    eng.run_to_completion()
    seq = eng.pool.seq("a")
    before = {}
    now = eng.clock.now()
    assert eng.kv.evict(2, now) == 2
    eng.flush_transfers()
    assert len(seq.offloaded) == 2 and not seq.offloading
    for li, enc in seq.offloaded.items():
        assert isinstance(enc, QuantizedPage)      # host copies quantized
        before[li] = eng.codec.decode(enc)
    # clobber the freed slots, then reload through the next turn
    eng.add_session("b", rng.integers(0, cfg.vocab_size, size=8),
                    max_new_tokens=2)
    eng.run_to_completion()
    eng.start_turn("a", rng.integers(0, cfg.vocab_size, size=4),
                   max_new_tokens=4)
    eng.run_to_completion()
    eng.check_invariants()
    assert not seq.offloaded
    for li, host in before.items():
        phys = seq.pages[li]
        np.testing.assert_array_equal(
            np.asarray(eng.k_pages[:, phys]), host[0])
        np.testing.assert_array_equal(
            np.asarray(eng.v_pages[:, phys]), host[1])
    st_ = eng.transfer.stats
    assert st_.wire_bytes_saved > 0
    bb = eng.kv.channel.block_bytes
    moved_logical = (st_.offload_pages_completed
                     + eng.kv.reloaded_blocks) * bb
    assert st_.wire_bytes_moved == pytest.approx(
        moved_logical * eng.kv.channel.wire_scale)
    assert st_.reload_wire_bytes <= 0.5 * eng.kv.reloaded_blocks * bb


def test_fp32_engine_ledger_saves_nothing(tiny):
    """The identity codec's ledger twin: same drive, zero savings,
    wire bytes == logical bytes (bit-exactness of the fp32 path itself
    is pinned by the existing differential suites)."""
    cfg, _ = tiny
    rng = np.random.default_rng(3)
    eng = _engine(tiny, num_pages=12)
    eng.add_session("a", rng.integers(0, cfg.vocab_size, size=10),
                    max_new_tokens=6)
    eng.run_to_completion()
    assert eng.kv.evict(2, eng.clock.now()) == 2
    eng.flush_transfers()
    for enc in eng.pool.seq("a").offloaded.values():
        assert isinstance(enc, np.ndarray)         # raw, not quantized
    st_ = eng.transfer.stats
    assert st_.wire_bytes_saved == 0.0
    assert st_.wire_bytes_moved == \
        st_.offload_pages_completed * eng.kv.channel.block_bytes

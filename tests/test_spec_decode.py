"""Speculative multi-token decode (DESIGN.md §16, ISSUE 10).

Covers the speculation invariants:
- proposer units (prompt-lookup n-gram drafting, scripted oracle,
  draft-model config hook);
- losslessness: accepted-token streams are bit-exact vs the
  ``spec_decode=0`` control — deterministic sweep plus a hypothesis
  property over random draft budgets / barge rounds / evictions, and
  a mesh-sharded twin (multidev lane);
- acceptance accounting: ``accepted + rejected == drafted`` under
  forced full rejection and forced partial acceptance;
- KV rollback conservation: no leaked or orphaned pages after
  rejection, including shared-prefix (prefix-cache) sessions;
- generation-budget and frontier-cap correctness under speculation
  (only *accepted* tokens count).

Barge-in comparison protocol: a mid-decode barge lands at a round
boundary, and a spec round commits up to ``1 + K`` tokens — so the
spec plane and the one-token control reach a given emitted-token count
at different rounds (and a spec round can overshoot it). The
differential therefore runs the spec plane first (barging once the
turn has emitted ``barge_emit`` tokens, wherever acceptance actually
lands it), reads how many tokens the aborted turn had emitted, and
replays the control barging at exactly that count — exact, because the
control emits at most one token per round. Identical committed context
⇒ every later turn must match bit for bit.
"""
import jax
import numpy as np
import pytest

from hypothesis_compat import given, settings, st
from repro.configs import get_config, reduced
from repro.core.session import Phase
from repro.models import init_params
from repro.serving.paged_engine import PagedRealtimeEngine
from repro.serving.spec_decode import (DraftModelConfig, NGramProposer,
                                       ScriptedProposer, build_proposer)

NDEV = len(jax.devices())
multidev = pytest.mark.skipif(
    NDEV < 2,
    reason="needs >1 device; run under "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8")


@pytest.fixture(scope="module")
def tiny():
    cfg = reduced(get_config("qwen2-1.5b"), layers=2, d_model=64,
                  vocab=331)
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


# ======================================================================
# proposer units
# ======================================================================
def test_ngram_replays_periodic_history():
    p = NGramProposer(max_ngram=3)
    h = [1, 2, 3] * 3
    assert p.propose(h, 3) == [1, 2, 3]
    assert p.propose(h, 5) == [1, 2, 3, 1, 2]


def test_ngram_prefers_full_continuation():
    # the most recent occurrence of the trailing n-gram sits too close
    # to the end to fill the budget; an older one does
    p = NGramProposer(max_ngram=2)
    h = [1, 2, 3, 4, 9, 1, 2]
    assert p.propose(h, 3) == [3, 4, 9]


def test_ngram_no_match_degrades_to_empty():
    p = NGramProposer()
    assert p.propose([1, 2, 3, 4, 5], 4) == []
    assert p.propose([7], 4) == []          # history too short
    assert p.propose([1, 2, 1, 2], 0) == []


def test_scripted_proposer_cursor_and_budget():
    p = ScriptedProposer({"a": [[5, 6, 7], [8]]})
    p.session_id = "a"
    assert p.propose([0], 2) == [5, 6]      # clipped to the budget
    assert p.propose([0], 4) == [8]
    assert p.propose([0], 4) == []          # script exhausted
    p.session_id = "b"
    assert p.propose([0], 4) == []          # unknown session


def test_build_proposer_dispatch():
    assert isinstance(build_proposer("ngram"), NGramProposer)
    obj = ScriptedProposer()
    assert build_proposer(obj) is obj
    with pytest.raises(NotImplementedError):
        build_proposer(DraftModelConfig(name="toy"))
    with pytest.raises(AssertionError):
        build_proposer(42)


def test_spec_requires_fused_plane(tiny):
    cfg, params = tiny
    with pytest.raises(AssertionError, match="fused"):
        PagedRealtimeEngine(cfg, params, slots=2, page_size=4,
                            pages_per_seq=8, fused_step=False,
                            spec_decode=2)


def _junk_proposer(vocab):
    """Drafts the model's argmax will (almost surely) never confirm —
    guaranteed drafting every decode round, every draft rejected: the
    rollback path runs constantly while the committed stream must stay
    exactly greedy."""

    class _Junk:
        session_id = None

        def propose(self, history, k):
            return [(int(history[-1]) + 1 + i) % vocab for i in range(k)]

    return _Junk()


# ======================================================================
# differential drives
# ======================================================================
def _drive(cfg, params, seed, *, spec, proposer=None, mesh=None,
           max_chunk=4, barge_emit=None, evict_pages=4,
           prefix_cache=False):
    """One seeded multi-turn trace: chunked prefill, decode with random
    grants (the spec plane's decode grants carry the draft budget on
    top), an optional mid-decode barge on turn 2, physical evict +
    reload across a turn boundary. The full interaction script (prompts,
    budgets) is pre-drawn so the two planes replay identical traffic
    even though their round counts differ. Returns (per-session token
    histories, per-slot client event streams, turn stats, evicted-page
    count, engine)."""
    rng = np.random.default_rng(seed)
    grng = np.random.default_rng(seed + 7777)   # grants only
    # periodic prompts so prompt-lookup drafting has material
    unit = rng.integers(0, cfg.vocab_size, size=3)
    pa = np.tile(unit, 4)
    pb = rng.integers(0, cfg.vocab_size, size=int(rng.integers(5, 10)))
    mna = int(rng.integers(6, 10))
    mnb = int(rng.integers(4, 8))
    pa2 = np.tile(unit, 3)
    pa3 = rng.integers(0, cfg.vocab_size, size=int(rng.integers(3, 7)))
    mna3 = int(rng.integers(3, 6))

    eng = PagedRealtimeEngine(cfg, params, slots=2, page_size=4,
                              pages_per_seq=16, num_pages=32, mesh=mesh,
                              fused_step=True, spec_decode=spec,
                              proposer=proposer,
                              prefix_cache=prefix_cache)
    events = {}

    def emitted_of_a():
        """Tokens the live turn of "a" has emitted so far (the
        prefill-completion token plus accepted decode emissions) —
        None once the turn closed."""
        s = next((s for s in eng.slot_state.values()
                  if s is not None and s.session_id == "a"), None)
        return len(s.tokens) if s is not None else None

    def drive(live, barge=False):
        rounds = 0
        while eng.active() and rounds < 500:
            grants = {}
            for slot, sid in list(live.items()):
                s = eng.slot_state[slot]
                if s is None or s.session_id != sid \
                        or not s.request.is_live():
                    continue
                g = int(grng.integers(1, max_chunk + 1))
                if s.request.phase == Phase.DECODE:
                    g += spec               # grant carries draft budget
                grants[slot] = g
            if not grants:
                break
            for slot, evs in eng.run_round(grants).items():
                # ("prefill", n) progress markers track the random
                # grant chunking, which legitimately differs once the
                # planes' round counts diverge; the client contract is
                # the token/finished stream
                events.setdefault(slot, []).extend(
                    e for e in evs if e[0] != "prefill")
            rounds += 1
            if barge and barge_emit is not None:
                e = emitted_of_a()
                if e is not None and e >= barge_emit:
                    eng.barge_in("a")
                    return

    sa = eng.submit_turn("a", pa, max_new_tokens=mna)
    sb = eng.submit_turn("b", pb, max_new_tokens=mnb)
    drive({sa: "a", sb: "b"})
    # physical offload of committed suffix pages across the turn gap
    evicted = eng.kv.evict(evict_pages, eng.clock.now())
    eng.flush_transfers()
    sa2 = eng.submit_turn("a", pa2, max_new_tokens=10)
    drive({sa2: "a"}, barge=True)
    # turn 3 resumes on exactly the committed (post-barge) tokens
    sa3 = eng.submit_turn("a", pa3, max_new_tokens=mna3)
    drive({sa3: "a"})
    eng.check_invariants()
    assert eng.spec_accepted + eng.spec_rejected == eng.spec_drafted
    hist = {sid: s.history for sid, s in eng.sessions.items()}
    stats = {sid: [(t["re_prefill_tokens"], t["generated"], t["aborted"])
                   for t in s.turn_stats]
             for sid, s in eng.sessions.items()}
    return hist, events, stats, evicted, eng


def _differential(cfg, params, seed, *, spec, proposer=None, mesh=None,
                  max_chunk=4, barge_emit=2, evict_pages=4,
                  prefix_cache=False):
    """Run the spec plane, then replay the control barging at the exact
    emitted-token point the spec run aborted at (see module docstring).
    Returns (control, spec) drive results after asserting equality."""
    got = _drive(cfg, params, seed, spec=spec, proposer=proposer,
                 mesh=mesh, max_chunk=max_chunk, barge_emit=barge_emit,
                 evict_pages=evict_pages, prefix_cache=prefix_cache)
    aborted = got[2]["a"][1][2]
    emitted = len(got[0]["a"][1]) if aborted else None
    want = _drive(cfg, params, seed, spec=0, max_chunk=max_chunk,
                  barge_emit=emitted, evict_pages=evict_pages,
                  prefix_cache=prefix_cache)
    assert got[0] == want[0], "token histories diverged"
    assert got[1] == want[1], "client-visible event streams diverged"
    assert got[2] == want[2], "turn stats diverged"
    assert got[3] == want[3], "offloadable-page sets diverged"
    return want, got


SWEEP = [(0, 2), (1, 4), (2, 1), (3, 4), (4, 3)]


@pytest.mark.parametrize("seed,spec", SWEEP)
def test_spec_stream_bit_exact_sweep(tiny, seed, spec):
    """Forced-rejection drafting (junk proposer): every decode round
    drafts, every draft rolls back, and the committed streams / events /
    turn stats stay bit-exact vs the spec_decode=0 control."""
    cfg, params = tiny
    _, got = _differential(cfg, params, seed, spec=spec,
                           proposer=_junk_proposer(cfg.vocab_size))
    eng = got[4]
    assert eng.spec_drafted > 0, "trace never drafted"


@pytest.mark.parametrize("seed", [0, 3])
def test_spec_ngram_stream_bit_exact(tiny, seed):
    """The default self-speculative proposer (whatever it drafts, and
    whatever sticks) is lossless on the same traces."""
    cfg, params = tiny
    _differential(cfg, params, seed, spec=4)


@pytest.mark.slow
@given(seed=st.integers(0, 2 ** 16), spec=st.integers(1, 5),
       barge_emit=st.integers(1, 8), evict_pages=st.integers(2, 8),
       max_chunk=st.integers(1, 6))
@settings(max_examples=15, deadline=None)
def test_spec_stream_property(tiny, seed, spec, barge_emit,
                              evict_pages, max_chunk):
    cfg, params = tiny
    _differential(cfg, params, seed, spec=spec,
                  proposer=_junk_proposer(cfg.vocab_size),
                  barge_emit=barge_emit, evict_pages=evict_pages,
                  max_chunk=max_chunk)


@multidev
@pytest.mark.parametrize("shape", [(1, 2), (1, 8)])
def test_spec_sharded_stream_bit_exact(tiny, shape):
    """The mesh-sharded spec verify step stays token-exact with the
    single-device spec_decode=0 control."""
    if shape[0] * shape[1] > NDEV:
        pytest.skip(f"mesh {shape} > {NDEV} devices")
    cfg, params = tiny
    mesh = jax.make_mesh(shape, ("data", "model"))
    _, got = _differential(cfg, params, 13, spec=3,
                           proposer=_junk_proposer(cfg.vocab_size),
                           mesh=mesh)
    assert got[4].spec_drafted > 0


# ======================================================================
# rollback conservation + partial acceptance
# ======================================================================
def test_spec_rejection_rolls_back_and_conserves_pages(tiny):
    cfg, params = tiny
    prompt = np.random.default_rng(5).integers(0, cfg.vocab_size, size=7)

    def run(spec, proposer=None):
        eng = PagedRealtimeEngine(cfg, params, slots=2, page_size=4,
                                  pages_per_seq=8, num_pages=24,
                                  fused_step=True, spec_decode=spec,
                                  proposer=proposer)
        free0 = eng.pool.free_pages
        eng.add_session("a", prompt, max_new_tokens=9)
        eng.run_to_completion()
        eng.check_invariants()
        hist = eng.sessions["a"].history
        held = len(eng.pool.seq("a").pages)
        eng.end_session("a")
        eng.check_invariants()
        return eng, free0, hist, held

    eng, free0, hist, held = run(4, _junk_proposer(cfg.vocab_size))
    assert eng.spec_rejected > 0, "junk drafts were never rejected"
    assert eng.spec_accepted + eng.spec_rejected == eng.spec_drafted
    # every draft page rolled back / trimmed: ending the session returns
    # the pool to its starting population (no leaked, no orphaned pages)
    assert eng.pool.free_pages == free0
    _, _, want, held0 = run(0)
    # the committed stream is untouched by the rejected drafts, and the
    # spec session holds exactly what the committed tokens need (draft
    # lookahead pages were reclaimed at turn close)
    assert hist == want
    assert held == held0


def test_spec_partial_acceptance_accounting(tiny):
    """A proposer whose first draft token is right and second is wrong
    forces partial acceptance every round; the counters must balance
    exactly and generation stops exactly at max_new_tokens."""
    cfg, params = tiny
    prompt = np.random.default_rng(8).integers(0, cfg.vocab_size, size=6)

    # control run discovers the greedy stream
    eng0 = PagedRealtimeEngine(cfg, params, slots=1, page_size=4,
                               pages_per_seq=8, fused_step=True)
    eng0.add_session("a", prompt, max_new_tokens=8)
    eng0.run_to_completion()
    # history is a list of per-turn emitted-token segments; greedy[0]
    # is the prefill-completion token the first decode round's history
    # already carries as pending
    greedy = list(eng0.sessions["a"].history[-1])

    class _HalfRight:
        session_id = None

        def __init__(self, prompt_len, stream, vocab):
            self.p, self.s, self.v = prompt_len, stream, vocab

        def propose(self, history, k):
            g = len(history) - self.p       # tokens emitted so far
            good = self.s[g:g + 1]
            if not good or k < 2:
                return good
            return [good[0], (good[0] + 1) % self.v]

    eng = PagedRealtimeEngine(
        cfg, params, slots=1, page_size=4, pages_per_seq=8,
        fused_step=True, spec_decode=3,
        proposer=_HalfRight(len(prompt), greedy, cfg.vocab_size))
    eng.add_session("a", prompt, max_new_tokens=8)
    eng.run_to_completion()
    eng.check_invariants()
    assert eng.sessions["a"].history == eng0.sessions["a"].history
    assert eng.spec_accepted + eng.spec_rejected == eng.spec_drafted
    assert eng.spec_rejected > 0 and eng.spec_accepted > 0
    assert eng.sessions["a"].turn_stats[-1]["generated"] == 8


def test_spec_with_prefix_cache_shared_pages(tiny):
    """Speculative drafts with the radix prefix cache live: a second
    session attaching the first one's banked prefix pages decodes (and
    drafts) without perturbing them — streams stay exact vs the
    non-spec control, conservation and the cache charging partition
    hold, and both planes end holding identical pool populations."""
    cfg, params = tiny
    fam = np.tile(np.random.default_rng(11).integers(
        0, cfg.vocab_size, size=4), 3)

    def run(spec):
        eng = PagedRealtimeEngine(
            cfg, params, slots=2, page_size=4, pages_per_seq=8,
            num_pages=32, fused_step=True, prefix_cache=True,
            spec_decode=spec,
            proposer=_junk_proposer(cfg.vocab_size) if spec else None)
        eng.add_session("a", fam, max_new_tokens=6)
        eng.run_to_completion()
        # same family prefix: attaches to a's banked pages
        eng.add_session("b", fam, max_new_tokens=6)
        eng.run_to_completion()
        eng.check_invariants()
        assert eng.prefix_cache.hit_tokens > 0, "prefix never shared"
        hists = (eng.sessions["a"].history, eng.sessions["b"].history)
        eng.end_session("a")
        eng.end_session("b")
        eng.check_invariants()
        return hists, eng.pool.free_pages, eng

    want, free_want, _ = run(0)
    got, free_got, eng = run(3)
    assert got == want
    assert free_got == free_want
    assert eng.spec_rejected > 0
    assert eng.spec_accepted + eng.spec_rejected == eng.spec_drafted


# ======================================================================
# budgets and the frontier cap count accepted tokens only
# ======================================================================
def test_spec_never_overruns_generation_budget(tiny):
    """Draft budgets clamp so a verify round can never emit past
    max_new_tokens, whatever the acceptance pattern."""
    cfg, params = tiny
    rng = np.random.default_rng(3)
    prompt = np.tile(rng.integers(0, cfg.vocab_size, size=3), 3)
    for max_new in (1, 2, 5):
        for prop in (None, _junk_proposer(cfg.vocab_size)):
            eng = PagedRealtimeEngine(cfg, params, slots=1, page_size=4,
                                      pages_per_seq=8, fused_step=True,
                                      spec_decode=4, proposer=prop)
            eng.add_session("a", prompt, max_new_tokens=max_new)
            eng.run_to_completion()
            eng.check_invariants()
            stats = eng.sessions["a"].turn_stats[-1]
            assert stats["generated"] == max_new, (max_new, stats)


def test_frontier_cap_counts_accepted_tokens_only(tiny):
    """Gateway frontier invariant under speculation: the playback
    buffer advances only on emitted (= accepted) tokens, so the worst
    over-frontier excursion is bounded by one round's accepted emission
    — decode_chunk = 1 + K tokens — never by drafted tokens."""
    from repro.serving.gateway.harness import run_gateway_workload
    cfg, params = tiny
    apt = 0.25
    m, gw = run_gateway_workload(
        policy="liveserve", kind="interactive", sessions=3,
        barge_in=0.0, seed=4, scale=16.0, model=(cfg, params),
        spec_decode=4, round_token_budget=16, audio_per_token_s=apt,
        frontier_cap_s=2.0, max_response=14, timeout_s=300)
    s = m.summary()
    assert s["spec_accepted"] + s["spec_rejected"] == s["spec_drafted"]
    assert gw.max_over_frontier_s <= (1 + 4) * apt + 1e-6
    for eng in gw._engines():
        eng.check_invariants()

"""Full-duplex / agentic scenario suite (ISSUE 9).

Three new session shapes exercise the interaction plane end to end:

- **duplex**  — periodic-frame sessions: the turn request fires at
  speech onset and every output token carries a hard frame deadline
  (armed at the request, advancing one period per emitted frame);
- **toolcall** — agentic sessions whose turns end in a tool call: the
  session idles with hot KV (its own protection state, distinct from
  the preload TTL) and resumes without a new utterance or re-prefill;
- **handoff** — sessions that request a transfer to a different model
  config between turns, riding the fleet MIGRATE machinery as a
  targeted plan.

Unit tests cover the satellite bugfixes (burstgpt mean conservation,
preload double-speech-start merge, monitor staleness) and the
scheduler's frame-deadline urgency/pacing interplay. Scenario smokes
replay each shape through the virtual-time twin; live-vs-twin
differentials run one small example per shape in the fast lane with
seeded sweeps behind ``-m slow`` — same comparison discipline as
tests/test_differential.py (trace-determined outcomes, never
wall-clock latencies).
"""
import jax
import numpy as np
import pytest

from repro.core.kv_manager import KVManager
from repro.core.monitor import RuntimeMonitor
from repro.core.preload import Preloader
from repro.core.scheduler import (RoundBudget, SchedulerConfig,
                                  UrgencyScheduler)
from repro.core.session import Phase, Request
from repro.serving.workload import (TOOL_RESUME_GAP_S, WorkloadConfig,
                                    _burst_wave, generate)


class FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def now(self):
        return self.t


@pytest.fixture(scope="module")
def tiny():
    from repro.configs import get_config, reduced
    from repro.models import init_params
    cfg = reduced(get_config("qwen2-1.5b"), layers=2, d_model=64,
                  vocab=331)
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


# ======================================================================
# burstgpt arrivals: the peak/mean contract (satellite bugfix)
# ======================================================================
def _empirical_rate(cfg: WorkloadConfig) -> float:
    times = [s.arrival_time for s in generate(cfg)]
    return len(times) / times[-1]


@pytest.mark.parametrize("bf", [1.5, 2.0, 4.0, 8.0])
def test_burst_wave_mean_identity(bf):
    """duty*peak + (1-duty)*off == rate_rps exactly, for every
    burst_factor — including bf > 1/0.3 where the nominal 0.3 duty
    would have needed a negative off-phase rate (the old clamp-to-0.1
    bug inflated the mean ~27% at bf=4)."""
    cfg = WorkloadConfig(arrival="burstgpt", rate_rps=2.0,
                         burst_factor=bf)
    duty, peak, off = _burst_wave(cfg)
    assert duty * peak + (1.0 - duty) * off \
        == pytest.approx(cfg.rate_rps)
    assert off >= 0.0
    assert peak == pytest.approx(cfg.rate_rps * bf)


@pytest.mark.parametrize("bf", [2.0, 4.0])
def test_burstgpt_empirical_mean_conserved(bf):
    """Regression: at burst_factor=4 the off-phase clamp used to push
    the empirical mean to ~1.27x rate_rps. The hazard-integrated draw
    must keep it within 5% (ISSUE 9 acceptance)."""
    cfg = WorkloadConfig(kind="sharegpt", arrival="burstgpt",
                         rate_rps=2.0, burst_factor=bf,
                         num_sessions=4000, seed=3)
    rate = _empirical_rate(cfg)
    assert abs(rate - cfg.rate_rps) / cfg.rate_rps < 0.05, rate


def test_burstgpt_still_bursty_and_deterministic():
    """The fix must not flatten the process: interarrival CV stays
    well above Poisson's 1.0, and the same seed reproduces the same
    arrival times exactly."""
    cfg = WorkloadConfig(kind="sharegpt", arrival="burstgpt",
                         rate_rps=2.0, burst_factor=4.0,
                         num_sessions=2000, seed=5)
    times = np.array([s.arrival_time for s in generate(cfg)])
    gaps = np.diff(times)
    cv = gaps.std() / gaps.mean()
    assert cv > 1.3, cv
    times2 = np.array([s.arrival_time for s in generate(cfg)])
    assert np.array_equal(times, times2)


def test_duplex_trace_shape():
    """Duplex turns carry a frame period and never barge (the user
    holds the channel); the other kinds stay frame-free."""
    cfg = WorkloadConfig(kind="duplex", num_sessions=50, seed=0,
                         p_barge_in=0.9)
    turns = [t for s in generate(cfg) for t in s.turns]
    assert all(2.0 <= t.frame_period_tokens <= 4.0 for t in turns)
    assert not any(t.barge_in for t in turns)
    cfg2 = WorkloadConfig(kind="interactive", num_sessions=20, seed=0)
    assert all(t.frame_period_tokens == 0.0
               for s in generate(cfg2) for t in s.turns)


def test_toolcall_and_handoff_trace_shapes():
    tc = WorkloadConfig(kind="toolcall", num_sessions=50, seed=1)
    tool_turns = [t for s in generate(tc) for t in s.turns if t.tool_call]
    assert tool_turns
    assert all(0.8 <= t.tool_latency_s <= 8.0 for t in tool_turns)
    # the last turn of a session never starts a tool pause
    for s in generate(tc):
        assert not s.turns[-1].tool_call
    ho = WorkloadConfig(kind="handoff", num_sessions=50, seed=1)
    hand = [t for s in generate(ho) for t in s.turns if t.handoff]
    assert hand
    assert all(0 <= t.handoff_target < 8 for t in hand)
    # a session's first turn has no committed context to hand off
    for s in generate(ho):
        assert not s.turns[0].handoff


# ======================================================================
# monitor: staleness fixes + the new interaction events
# ======================================================================
def test_turn_start_clears_stale_speech_state():
    """Regression: a turn that starts with no SpeechEnd (duplex,
    tool-resume) used to leave ``speaking``/``expected_speech_end``
    from the previous utterance — Eq. 4 then read a stale estimate and
    immediate_reuse protected an idle session forever."""
    clock = FakeClock(0.0)
    mon = RuntimeMonitor(clock)
    mon.on_speech_start("a", expected_dur_s=4.0)
    v = mon.view("a")
    assert v.speaking and v.expected_speech_end == 4.0
    clock.t = 1.0
    mon.on_turn_start("a", 0)          # no SpeechEnd ever arrived
    assert not v.speaking
    assert v.expected_speech_end is None
    assert v.tool_call_until is None
    assert not mon.immediate_reuse("a")


def test_frame_deadline_lifecycle():
    clock = FakeClock(10.0)
    mon = RuntimeMonitor(clock)
    mon.on_frame_turn("a", 2.0)
    v = mon.view("a")
    assert v.frame_period_s == 2.0
    assert v.frame_deadline == 12.0
    # admission (on_turn_start) fires AFTER the request armed the
    # deadline: it must not clear it, or queueing delay would be
    # exempt from miss accounting
    mon.on_turn_start("a", 0)
    assert v.frame_deadline == 12.0
    mon.on_response_complete("a")
    assert v.frame_deadline is None
    mon.on_frame_turn("a", 2.0)
    mon.on_barge_in("a")
    assert v.frame_deadline is None
    assert v.frame_period_s == 2.0     # period is sticky (duplex mark)


def test_tool_call_events_skip_the_reply_gap_ema():
    """Tool latencies are not think time: the pause events must leave
    the reply-gap EMA alone while opening/closing the tool window."""
    clock = FakeClock(0.0)
    mon = RuntimeMonitor(clock)
    v = mon.register("a")
    v.last_playback_end = 0.0
    v.reply_gap_ema = 1.5
    clock.t = 5.0
    mon.on_tool_call_start("a", 3.0)
    assert v.tool_call_until == 8.0
    assert not v.speaking and v.expected_speech_end is None
    assert v.reply_gap_ema == 1.5
    clock.t = 8.0
    mon.on_tool_call_result("a", resume_gap_s=TOOL_RESUME_GAP_S)
    assert v.tool_call_until is None
    # the result opens a preload window exactly one resume gap wide
    assert v.expected_speech_end == pytest.approx(8.0 + TOOL_RESUME_GAP_S)
    assert v.reply_gap_ema == 1.5


# ======================================================================
# KV manager: tool-pause protection (distinct from the preload TTL)
# ======================================================================
def _kv(monitor=None, clock=None, capacity=100):
    clock = clock or FakeClock()
    return KVManager(capacity_blocks=capacity, block_size=16,
                     bytes_per_token=1024.0, monitor=monitor,
                     clock=clock), clock


def _resident(kv, sid, blocks):
    s = kv.session(sid)
    s.total_blocks = blocks
    s.hbm_blocks = blocks
    return s


def test_tool_protection_blocks_eviction_until_expiry():
    mon = RuntimeMonitor(FakeClock(0.0))
    mon.register("a")
    kv, clock = _kv(monitor=mon)
    _resident(kv, "a", 10)
    kv.protect_tool("a", 0.0, expected_latency_s=5.0)
    assert kv.evict(4, 1.0) == 0                # mid-pause: held
    assert kv.evict(4, 5.5) == 4                # tool window lapsed
    assert kv.session("a").hbm_blocks == 6


def test_tool_protection_ttl_caps_runaway_tools():
    kv, _ = _kv()
    kv.tool_protect_ttl_s = 2.0
    _resident(kv, "a", 10)
    kv.protect_tool("a", 0.0, expected_latency_s=500.0)
    assert kv.session("a").tool_protected_until == 2.0
    assert kv.evict(4, 1.0) == 0
    assert kv.evict(4, 2.5) == 4                # TTL beat the tool


def test_clear_tool_protection_lifts_hold():
    kv, _ = _kv()
    _resident(kv, "a", 10)
    kv.protect_tool("a", 0.0, expected_latency_s=50.0)
    assert kv.evict(4, 1.0) == 0
    kv.clear_tool_protection("a", 1.0)
    assert kv.evict(4, 1.0) == 4


def test_next_use_reads_tool_pause_window():
    """Eq. 4 during a pause: next use is the tool's expected return,
    not playback + reply gap; after the window it falls back."""
    clock = FakeClock(0.0)
    mon = RuntimeMonitor(clock)
    v = mon.register("a")
    v.reply_gap_ema = 2.0
    kv, _ = _kv(monitor=mon, clock=clock)
    _resident(kv, "a", 10)
    mon.on_tool_call_start("a", 6.0)
    assert kv.next_use_estimate("a", 1.0) == 6.0
    assert kv.next_use_estimate("a", 7.0) == pytest.approx(9.0)


# ======================================================================
# preloader: double speech-start merges, never orphans (satellite)
# ======================================================================
def test_double_speech_start_merges_pending_preload():
    """Regression: speech -> barge-in before the turn arrived used to
    overwrite the first PendingPreload, orphaning its transfer — the
    settlement then credited only the second transfer's span. Both
    admissions must fold into one entry whose blocks and span cover
    both transfers."""
    clock = FakeClock(0.0)
    mon = RuntimeMonitor(clock)
    v = mon.register("a")
    v.playback.started = True
    v.playback.play_end = 0.0
    v.reply_gap_ema = 1.0
    kv, _ = _kv(monitor=mon)
    s = _resident(kv, "a", 20)
    s.hbm_blocks = 0                             # fully offloaded
    pre = Preloader(kv, mon, speech_prior_s=6.0)
    mon.on_speech_start("a", expected_dur_s=6.0)
    t1 = pre.on_speech_start("a", 0.0)
    assert t1 is not None
    # barge-in: part of the resident reply KV leaves again before the
    # second trigger fires (pool churn), so the re-trigger re-admits
    s.hbm_blocks = 10
    kv.reloaded_blocks -= 10
    clock.t = 1.0
    mon.on_speech_start("a", expected_dur_s=6.0)
    t2 = pre.on_speech_start("a", 1.0)
    assert t2 is not None and t2 is not t1
    p = pre.pending["a"]
    assert p.blocks == t1.blocks + t2.blocks
    assert p.span_s == pytest.approx((t1.done - t1.start)
                                     + (t2.done - t2.start))
    # the later-finishing transfer anchors the settlement
    assert p.transfer is (t2 if t2.done >= t1.done else t1)
    # warm hit after both landed: the off-path credit covers BOTH
    # transfers' seconds (the orphaned-transfer bug dropped t1's)
    clock.t = max(t1.done, t2.done) + 0.1
    assert pre.on_turn_ready("a", clock.t) == 0.0
    assert pre.stats.hits == 1
    on_s, off_s = pre.pop_split("a")
    assert on_s == 0.0
    assert off_s == pytest.approx(p.span_s)


def test_duplex_preload_window_is_one_frame_period():
    """A duplex session has no speech window (the request fires at
    onset): preload admission gets exactly one frame period to hide
    in, and a transfer that cannot is refused."""
    clock = FakeClock(0.0)
    mon = RuntimeMonitor(clock)
    v = mon.register("a")
    v.frame_period_s = 0.5
    kv, _ = _kv(monitor=mon)
    s = _resident(kv, "a", 20)
    s.hbm_blocks = 0
    pre = Preloader(kv, mon, speech_prior_s=30.0)
    t = pre.on_speech_start("a", 0.0)            # tiny transfer: fits
    assert t is not None
    pre.forget_session("a")
    big = _resident(kv, "b", 10 ** 6)
    big.hbm_blocks = 0
    mon.register("b").frame_period_s = 0.5
    assert pre.on_speech_start("b", 0.0) is None # cannot hide in frame
    assert pre.stats.skipped == 1


# ======================================================================
# scheduler: frame deadlines vs pacing (tentpole + test satellite)
# ======================================================================
def _duplex_setup(buffers, frames, *, p_safe=1.0, p_max=3.0, occ=0.0):
    """buffers: sid -> playback buffer s; frames: sid -> (period,
    deadline) armed on the view."""
    clock = FakeClock(100.0)
    mon = RuntimeMonitor(clock)
    for sid, buf in buffers.items():
        mon.register(sid)
        v = mon.view(sid)
        v.playback.started = True
        v.playback.play_end = clock.t + buf
        v.playback.appended_s = buf + 5.0
    for sid, (period, deadline) in frames.items():
        v = mon.register(sid)
        v.frame_period_s = period
        v.frame_deadline = deadline
    cfg = SchedulerConfig(p_safe_s=p_safe, p_max_s=p_max)
    return UrgencyScheduler(cfg, mon, stage="talker",
                            kv_occupancy=lambda: occ), clock


def _decode_req(sid):
    r = Request(session_id=sid, stage="talker", turn_index=0,
                arrival_time=0.0, prompt_len=0, max_new_tokens=100)
    r.phase = Phase.DECODE
    r.generated = 5
    r.first_output_time = 0.0
    return r


def test_frame_slack_promotes_to_u0():
    """A frame due within P_safe outranks a healthy buffer: the session
    joins U0 keyed by slack, ahead of buffer-keyed U0 peers with more
    seconds until trouble."""
    sched, clock = _duplex_setup(
        {"dup": 2.0, "low": 0.8, "easy": 2.0},
        {"dup": (2.0, clock_t := 100.5)})       # slack 0.5 < buffer 0.8
    reqs = {s: _decode_req(s) for s in ("dup", "low", "easy")}
    budget = RoundBudget(token_budget=4096, free_kv_blocks=10 ** 6)
    d = sched.schedule(list(reqs.values()), budget, clock.now())
    assert [r.session_id for r in d.batch] == ["dup", "low", "easy"]
    assert d.classes[reqs["dup"].req_id] == 0
    assert d.classes[reqs["easy"].req_id] == 2


def test_far_frame_deadline_does_not_promote():
    sched, clock = _duplex_setup({"dup": 2.0},
                                 {"dup": (10.0, 100.0 + 8.0)})
    r = _decode_req("dup")
    budget = RoundBudget(token_budget=4096, free_kv_blocks=10 ** 6)
    d = sched.schedule([r], budget, clock.now())
    assert d.classes[r.req_id] == 2             # slack 8 > p_safe: normal


def test_hold_wake_bounded_by_frame_slack():
    """A pace-held duplex session bounds the driver's sleep: it must be
    back before the frame slack shrinks to P_safe, not merely when the
    buffer drains to P_max."""
    sched, clock = _duplex_setup({"dup": 10.0},
                                 {"dup": (3.0, 100.0 + 2.5)})
    r = _decode_req("dup")
    budget = RoundBudget(token_budget=4096, free_kv_blocks=10 ** 6)
    d = sched.schedule([r], budget, clock.now())
    assert [q.session_id for q, _ in d.held] == ["dup"]
    # buffer-only wake would be 10 - 3 = 7s; the frame bound is
    # 2.5 - 1.0 = 1.5s and must win
    assert sched.hold_wake_s(d) == pytest.approx(7.0)
    assert sched.hold_wake_s(d, now=clock.now()) == pytest.approx(1.5)


def test_pacing_never_causes_a_frame_miss():
    """Deterministic sweep (ISSUE 9 satellite): for every (buffer,
    period, slack) shape, a held periodic-frame session is either
    promoted to U0 before its deadline ever arrives, or the hold wake
    lands early enough that classify promotes it with >= 0 slack —
    pacing alone can never turn into a deadline miss when
    pacing_kv_override is not tripped."""
    p_safe, p_max = 1.0, 3.0
    for buf in (3.1, 4.0, 6.0, 10.0):
        for period in (0.5, 1.0, 2.0, 4.0):
            for slack in (0.2, 0.8, 1.5, 3.0, 6.0):
                sched, clock = _duplex_setup(
                    {"dup": buf}, {"dup": (period, 100.0 + slack)},
                    p_safe=p_safe, p_max=p_max)
                r = _decode_req("dup")
                budget = RoundBudget(token_budget=4096,
                                     free_kv_blocks=10 ** 6)
                d = sched.schedule([r], budget, clock.now())
                shape = (buf, period, slack)
                if slack <= p_safe:
                    # due soon: promoted past pacing outright
                    assert d.batch and d.classes[r.req_id] == 0, shape
                    continue
                assert [q.session_id for q, _ in d.held] == ["dup"], shape
                wake = sched.hold_wake_s(d, now=clock.now())
                # woken while the frame still has >= P_safe slack
                # (0.01s floor keeps the driver from busy-spinning)
                assert wake <= max(0.01, slack - p_safe) + 1e-9, shape
                clock.t += wake
                d2 = sched.schedule([r], budget, clock.now())
                mon_v = sched.monitor.view("dup")
                if mon_v.frame_deadline - clock.now() <= p_safe:
                    assert d2.batch, shape      # promoted, not held
                    assert mon_v.frame_deadline >= clock.now(), shape


# ======================================================================
# scenario smokes through the virtual-time twin (fast lane)
# ======================================================================
from repro.serving.gateway.replay import ReplayConfig, run_replay  # noqa: E402
from repro.serving.paged_engine import PagedRealtimeEngine  # noqa: E402

APT = 0.25


def _factory(tiny_model, num_pages=128):
    cfg, params = tiny_model

    def make(clock):
        return PagedRealtimeEngine(cfg, params, slots=2, page_size=8,
                                   pages_per_seq=8, num_pages=num_pages,
                                   clock=clock)
    return make


def _twin(tiny_model, kind, sessions, seed, *, barge=0.0):
    wl = WorkloadConfig(kind=kind, num_sessions=sessions, seed=seed,
                        p_barge_in=barge, arrival="poisson", rate_rps=4.0)
    return run_replay(_factory(tiny_model), wl,
                      ReplayConfig(audio_per_token_s=APT,
                                   frontier_cap_s=3.0), seed=seed)


def test_twin_duplex_smoke(tiny):
    m, gw = _twin(tiny, "duplex", 3, 0)
    s = m.summary()
    assert s["frames"] > 0
    assert 0.0 <= s["deadline_miss_rate"] <= 1.0
    # every duplex turn completes (no barge) and every emitted token
    # was a counted frame
    assert all(t.completed for t in m.turns)
    assert all(t.frames == t.talker_generated for t in m.turns)
    # deadlines disarm between turns: no view left armed at the end
    for v in gw.eng.monitor.sessions.values():
        assert v.frame_deadline is None
    # twin determinism: the comparison surface reproduces exactly
    m2, _ = _twin(tiny, "duplex", 3, 0)
    assert m.summary() == m2.summary()


def test_twin_toolcall_smoke(tiny):
    m, gw = _twin(tiny, "toolcall", 4, 0)
    s = m.summary()
    assert s["tool_pauses"] > 0
    resumed = [t for t in m.turns if t.tool_resumed]
    assert len(resumed) == s["tool_pauses"]
    # resume-without-reprefill: a generous pool + pause protection keep
    # the context hot, so no resumed turn paid a reload stall and the
    # engine never re-prefilled committed tokens
    assert all(t.reload_stall_s == 0.0 for t in resumed)
    assert all(t.completed or t.barged for t in m.turns)
    # no pause leaks protection past its resume
    now = gw.clock.now()
    for sid, skv in gw.eng.kv.sessions.items():
        assert skv.tool_protected_until <= now
    m2, _ = _twin(tiny, "toolcall", 4, 0)
    assert m.summary() == m2.summary()


# ======================================================================
# fleet handoff through the twin (fast lane)
# ======================================================================
from repro.serving.fleet.replay import run_fleet_replay  # noqa: E402

REPLICAS = 3


def _fleet_twin(tiny_model, kind, sessions, seed, *, barge=0.0):
    wl = WorkloadConfig(kind=kind, num_sessions=sessions, seed=seed,
                        p_barge_in=barge, arrival="poisson", rate_rps=2.0)
    return run_fleet_replay(
        _factory(tiny_model), REPLICAS, wl,
        ReplayConfig(max_prompt=6, max_response=6), seed=seed)


def _expected_handoffs(kind, sessions, seed, max_turns=2):
    """Trace-predictable handoff decisions: session i routes to
    i % REPLICAS; its turn-1 handoff lands iff target % REPLICAS is a
    different replica."""
    wl = WorkloadConfig(kind=kind, num_sessions=sessions, seed=seed,
                        p_barge_in=0.0, arrival="poisson", rate_rps=2.0)
    want = {}
    for i, s in enumerate(generate(wl)):
        src = i % REPLICAS
        for turn in s.turns[1:max_turns]:
            if turn.handoff and turn.handoff_target % REPLICAS != src:
                want[s.session_id] = [(src, turn.handoff_target
                                       % REPLICAS)]
    return want


def test_twin_handoff_smoke(tiny):
    sessions, seed = 6, 0
    m, gw = _fleet_twin(tiny, "handoff", sessions, seed)
    want = _expected_handoffs("handoff", sessions, seed)
    assert want, "seed produced no handoffs — pick another"
    got = {}
    for _, sid, src, dst in gw.router.handoff_decisions():
        got.setdefault(sid, []).append((src, dst))
    assert got == want
    # barge-free: every decided handoff ran to DONE as a kind='handoff'
    # plan, and the resumed turn is marked
    assert not gw.migrator.plans
    done = [p for p in gw.migrator.completed() if p.kind == "handoff"]
    assert len(done) == len(want)
    assert m.summary()["handoffs"] == len(want)
    assert {t.session_id for t in m.turns if t.handoff} == set(want)
    # a handoff is a migration underneath: placement flipped, source
    # scrubbed, and the shared migration accounting saw it
    for p in done:
        assert p.session_id not in gw.replicas[p.src].sessions
        assert p.session_id in gw.replicas[p.dst].sessions
    assert m.migrations >= len(want)
    for e in gw.replicas:
        e.flush_transfers()
        e.check_invariants()
        assert e.pool.free_pages == e.num_pages


def test_router_refuses_self_and_draining_handoffs():
    from repro.serving.fleet.router import SessionRouter

    class _Stub(list):
        clock = FakeClock()

        def live_slots(self, i):
            return 0

        def free_pages(self, i):
            return 100

    router = SessionRouter(_Stub([0, 1, 2]))
    router.route("a")                            # -> replica 0
    assert router.request_handoff("a", 3) is None        # 3 % 3 == src
    router.draining.add(1)
    assert router.request_handoff("a", 1) is None        # dst draining
    assert router.request_handoff("a", 2) == 2
    assert router.handoff_decisions() == [("handoff", "a", 0, 2)]


# ======================================================================
# live-vs-twin scenario differentials
# ======================================================================
from repro.serving.fleet.harness import run_fleet_workload  # noqa: E402
from repro.serving.gateway.harness import run_gateway_workload  # noqa: E402


def _outcomes(m):
    """Per-session ordered (turn, outcome, tool_resumed) lists — the
    trace-determined surface both planes must agree on."""
    per = {}
    for t in sorted(m.turns, key=lambda t: (t.session_id, t.turn_index)):
        per.setdefault(t.session_id, []).append(
            (t.turn_index, t.completed, t.barged, t.tool_resumed))
    return per


def check_scenario_differential(tiny_model, kind, sessions, seed,
                                barge=0.0):
    twin_m, twin = _twin(tiny_model, kind, sessions, seed, barge=barge)
    # clamps and engine geometry must match the twin's ReplayConfig
    # defaults exactly (max_prompt/max_response 6, page_size 8) or the
    # two planes serve different traces
    live_m, live = run_gateway_workload(
        kind=kind, sessions=sessions, barge_in=barge, seed=seed,
        scale=40.0, max_turns=2, max_prompt=6, max_response=6,
        rate_rps=4.0, timeout_s=180.0, slots=2, page_size=8,
        pages_per_seq=8, num_pages=128, audio_per_token_s=APT,
        frontier_cap_s=3.0, model=tiny_model)
    assert set(twin_m.summary()) == set(live_m.summary())
    assert _outcomes(twin_m) == _outcomes(live_m)
    assert twin_m.tool_pauses == live_m.tool_pauses
    if kind == "duplex":
        # frames are trace-determined (duplex never barges: every turn
        # emits its full clamped token count, each token one frame);
        # misses are timing and deliberately NOT compared
        assert sum(t.frames for t in twin_m.turns) \
            == sum(t.frames for t in live_m.turns) > 0
    if kind == "toolcall" and barge == 0.0:
        # with barge-in on, a cut reply legitimately cancels its tool
        # pause, so a nonzero count is only trace-guaranteed barge-free
        assert twin_m.tool_pauses > 0
        assert {(t.session_id, t.turn_index)
                for t in twin_m.turns if t.tool_resumed} \
            == {(t.session_id, t.turn_index)
                for t in live_m.turns if t.tool_resumed}


def check_handoff_differential(tiny_model, sessions, seed, barge=0.0):
    twin_m, twin = _fleet_twin(tiny_model, "handoff", sessions, seed,
                               barge=barge)
    live_m, live = run_fleet_workload(
        kind="handoff", sessions=sessions, barge_in=barge, seed=seed,
        scale=40.0, max_turns=2, max_prompt=6, max_response=6,
        timeout_s=180.0, replicas=REPLICAS, slots=2, num_pages=128,
        audio_per_token_s=0.25, model=tiny_model)
    assert set(twin_m.summary()) == set(live_m.summary())

    def per_session(gw):
        per = {}
        for _, sid, src, dst in gw.router.handoff_decisions():
            per.setdefault(sid, []).append((src, dst))
        return per

    assert per_session(twin) == per_session(live)
    assert sorted(twin.router.handoff_decisions()) \
        == sorted(live.router.handoff_decisions())
    if barge == 0.0:
        want = _expected_handoffs("handoff", sessions, seed)
        assert per_session(twin) == want
        for gw, m in ((twin, twin_m), (live, live_m)):
            assert not gw.migrator.plans and not gw.migrator.cancelled()
            assert m.handoffs == len(want)
            assert {t.session_id for t in m.turns if t.handoff} \
                == set(want)
    for gw in (twin, live):
        for e in gw.replicas:
            e.flush_transfers()
            e.check_invariants()
            assert e.pool.free_pages == e.num_pages


# one small example per scenario stays in the fast lane
def test_duplex_differential_smoke(tiny):
    check_scenario_differential(tiny, "duplex", 3, 0)


def test_toolcall_differential_smoke(tiny):
    check_scenario_differential(tiny, "toolcall", 3, 0)


def test_handoff_differential_smoke(tiny):
    check_handoff_differential(tiny, 6, 0)


# seeded soaks ride the slow marker
SOAKS = [(kind, sessions, seed, barge)
         for seed in range(3)
         for kind, sessions, barge in (("duplex", 4, 0.0),
                                       ("toolcall", 5, 0.0),
                                       ("toolcall", 4, 0.5))]


@pytest.mark.slow
@pytest.mark.parametrize("kind,sessions,seed,barge", SOAKS)
def test_scenario_differential_soak(tiny, kind, sessions, seed, barge):
    check_scenario_differential(tiny, kind, sessions, seed, barge)


@pytest.mark.slow
@pytest.mark.parametrize("seed", range(3))
@pytest.mark.parametrize("barge", [0.0, 0.5])
def test_handoff_differential_soak(tiny, seed, barge):
    check_handoff_differential(tiny, 6, seed, barge)

"""Optional-dependency shim: property tests use hypothesis when present
(see requirements-dev.txt) and skip cleanly when it is missing, so the
tier-1 suite always collects and the non-property tests always run."""
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:                            # pragma: no cover
    class _StrategyStub:
        """Stands in for hypothesis.strategies: any strategy constructor
        returns None, which is never consumed because @given skips."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _StrategyStub()

    def given(*_a, **_k):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_a, **_k):
        return lambda f: f

"""KV quantization quality gate (DESIGN.md §14): the tolerance-based
acceptance harness for lossy wire formats.

The gate replays one seeded multi-turn trace through an fp32-wire
control and a candidate engine, forcing every turn's pages through an
evict -> flush -> reload round trip so later turns decode on KV that
crossed the wire. fp32-vs-fp32 must be bit-exact (the differential-twin
contract every other control in this repo holds); int8 must hold the
ISSUE tolerances: token flip rate <= 1%, bounded logit MSE — and the
comparison must be non-vacuous (pages actually moved)."""
import jax
import pytest

from repro.configs import get_config, reduced
from repro.models import init_params
from repro.serving.quality_gate import QualityTolerance, run_quality_gate


@pytest.fixture(scope="module")
def tiny():
    cfg = reduced(get_config("qwen2-1.5b"), layers=2, d_model=64,
                  vocab=331)
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_fp32_control_is_bit_exact(tiny):
    """The identity codec through the full gate: zero flips over every
    compared token and exactly zero logit error — not small, zero."""
    cfg, params = tiny
    r = run_quality_gate(cfg, params, kv_quant="fp32", seed=0)
    assert r.reloaded_pages > 0, "gate drove no pages through the wire"
    assert r.tokens_compared > 0 and r.logit_positions > 0
    assert r.token_flips == 0
    assert r.logit_mse == 0.0
    assert r.wire_bytes_saved == 0.0


def test_int8_holds_the_tolerances(tiny):
    """The ISSUE acceptance: int8 wire format on the seeded trace stays
    under a 1% token flip rate and the logit-MSE bound, while actually
    saving wire bytes."""
    cfg, params = tiny
    tol = QualityTolerance(max_token_flip_rate=0.01, max_logit_mse=1e-2)
    r = run_quality_gate(cfg, params, kv_quant="int8", seed=0, tol=tol)
    assert r.reloaded_pages > 0, "gate drove no pages through the wire"
    assert r.tokens_compared > 0 and r.logit_positions > 0
    assert r.token_flip_rate <= tol.max_token_flip_rate
    assert 0.0 < r.logit_mse <= tol.max_logit_mse
    assert r.wire_bytes_saved > 0.0
    assert r.summary()["quant_token_flip_rate"] == r.token_flip_rate


def test_gate_runs_on_the_per_token_plane(tiny):
    """fused_step=False drives the same gate through the per-token
    differential plane — the logit tap reports identical-length streams
    and the fp32 control stays exact there too."""
    cfg, params = tiny
    r = run_quality_gate(cfg, params, kv_quant="fp32", seed=1,
                         fused_step=False)
    assert r.token_flips == 0 and r.logit_mse == 0.0


@pytest.mark.slow
@pytest.mark.parametrize("seed", range(4))
def test_int8_tolerances_across_seeds(tiny, seed):
    """Seed sweep of the int8 gate (the fast lane pins seed 0)."""
    cfg, params = tiny
    run_quality_gate(cfg, params, kv_quant="int8", seed=seed,
                     tol=QualityTolerance())

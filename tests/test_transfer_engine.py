"""Async chunked KV transfer engine (ISSUE 4): overlap, conservation,
cancellation, and the over-synchronization regression.

The contracts under test (DESIGN.md §10):

- **Overlap**: a preload issued at speech start drains chunk-by-chunk
  across decode rounds; the next turn stalls only for the chunks that
  had not arrived, and the reloaded pages are bit-exact against the
  synchronous (async_transfers=False) plane.
- **Conservation**: under random interleavings of
  speech/preload/barge/evict/hangup/drain/cancel events, every
  session's pages satisfy resident + in-flight + offloaded == committed
  at all times, and nothing leaks after mid-transfer cancellation
  (pool slots, host-store entries, ledger chunks).
- **Cancellation**: hangup drops queued chunks before releasing the
  pool entry; evicting a loading session cancels its in-flight reload
  zero-copy; a reload arriving before a copy-then-free offload drains
  cancels the offload (the bytes never left HBM); the preloader's
  burst cancel rolls accounting back page-exact.
- **Measurement**: the per-chunk reload wall time blocks only on the
  staged chunk buffer, never on the whole page store (which would
  serialize against unrelated decode work).
"""
import random

import jax
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.configs import get_config, reduced
from repro.kvcache.paged import OutOfPages
from repro.models import init_params
from repro.serving.paged_engine import PagedRealtimeEngine


NDEV = len(jax.devices())
multidev = pytest.mark.skipif(
    NDEV < 2,
    reason="needs >1 device; run under "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8")


@pytest.fixture(scope="module")
def tiny():
    cfg = reduced(get_config("qwen2-1.5b"), layers=2, d_model=64,
                  vocab=331)
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _engine(tiny, **kw):
    cfg, params = tiny
    kw.setdefault("slots", 2)
    kw.setdefault("page_size", 4)
    kw.setdefault("pages_per_seq", 8)
    kw.setdefault("num_pages", 32)
    kw.setdefault("chunk_pages", 1)
    return PagedRealtimeEngine(cfg, params, **kw)


def _slow_pcie(cfg, page_size=4):
    """gb/s such that one page takes 1.0 modeled seconds — far beyond
    the virtual clock's millisecond round ticks, so chunks never earn
    the time credit and drains are the only off-path route."""
    import jax.numpy as jnp
    bytes_per_token = 2 * cfg.num_layers * cfg.num_kv_heads \
        * cfg.resolved_head_dim * jnp.dtype(cfg.dtype).itemsize
    return bytes_per_token * page_size / 1e9


# ======================================================================
# overlap: preload drains across rounds, stall covers only the rest
# ======================================================================
def _drive_overlap(eng, prompts, *, rounds_during_speech):
    """Shared script: a's turn 1, b evicts nothing (roomy pool) — we
    evict a's suffix by hand, make the copies durable, then preload
    during a's speech while b decodes ``rounds_during_speech`` rounds;
    finally a's turn 2 runs to completion. Returns a's turn stats."""
    eng.add_session("a", prompts[0], max_new_tokens=6)
    eng.run_to_completion()
    assert eng.kv.evict(4, eng.clock.now()) == 4
    eng.flush_transfers()                       # DRAM copies durable
    assert len(eng.pool.seq("a").offloaded) == 4

    eng.add_session("b", prompts[1], max_new_tokens=20)
    for _ in range(2):
        eng.step()

    # slow channel: the modeled DMA cannot finish inside the utterance,
    # so only chunks physically drained by rounds come off the path
    per_page = eng.kv.channel.transfer_time(1)
    window = (4 * per_page + eng.preloader.encode_delay_s) / 0.8
    t = eng.user_speech_start("a", expected_dur_s=window)
    assert t is not None, "preload must be admitted"
    if eng.async_transfers:
        assert eng.pool.inflight_pages("a") == (4, 0)
    else:                                       # sync control: landed
        assert eng.pool.inflight_pages("a") == (0, 0)

    for _ in range(rounds_during_speech):       # b keeps decoding;
        eng.step()                              # 1 chunk drains per round
    eng.start_turn("a", prompts[2], max_new_tokens=5)
    eng.run_to_completion()
    eng.check_invariants()
    return eng.sessions["a"].turn_stats[-1]


def test_preload_overlaps_decode_rounds(tiny):
    """The headline overlap contract: with chunk_pages=1 a 4-page
    preload drains over >= 3 rounds of another session's decode, and
    the turn-start stall charges exactly the one chunk that had not
    arrived."""
    rng = np.random.default_rng(11)
    cfg, _ = tiny
    prompts = [rng.integers(0, cfg.vocab_size, size=14),
               rng.integers(0, cfg.vocab_size, size=6),
               rng.integers(0, cfg.vocab_size, size=4)]
    eng = _engine(tiny, pcie_gb_s=_slow_pcie(cfg))
    per_page = eng.kv.channel.transfer_time(1)
    assert per_page == pytest.approx(1.0, rel=1e-6)

    st_ = _drive_overlap(eng, prompts, rounds_during_speech=3)
    stats = eng.transfer.stats
    assert stats.reload_pages_off_path == 3     # drained across 3 rounds
    assert stats.reload_pages_on_path == 1      # settled at turn start
    assert st_["reload_stall_s"] == pytest.approx(1 * per_page)
    assert st_["reload_off_path_s"] == pytest.approx(3 * per_page)
    assert st_["re_prefill_tokens"] == 0


def test_chunked_reload_bit_exact_vs_synchronous(tiny):
    """Same trace through the async chunked plane and the synchronous
    (async_transfers=False) control: identical token streams and
    identical reloaded page contents."""
    rng = np.random.default_rng(12)
    cfg, _ = tiny
    prompts = [rng.integers(0, cfg.vocab_size, size=14),
               rng.integers(0, cfg.vocab_size, size=6),
               rng.integers(0, cfg.vocab_size, size=4)]

    def run(async_transfers):
        eng = _engine(tiny, async_transfers=async_transfers,
                      pcie_gb_s=_slow_pcie(cfg))
        _drive_overlap(eng, prompts, rounds_during_speech=3)
        return eng

    a = run(True)
    s = run(False)
    assert a.sessions["a"].history == s.sessions["a"].history
    assert a.sessions["b"].kv_len == s.sessions["b"].kv_len
    # reloaded device pages are bit-identical across the two planes
    # (physical page ids may differ; logical contents must not)
    for sid in ("a", "b"):
        pa, ps = a.pool.seq(sid), s.pool.seq(sid)
        assert [p >= 0 for p in pa.pages] == [p >= 0 for p in ps.pages]
        for la, ls in zip(pa.pages, ps.pages):
            if la < 0:
                continue
            np.testing.assert_array_equal(
                np.asarray(a.k_pages[:, la]), np.asarray(s.k_pages[:, ls]))
            np.testing.assert_array_equal(
                np.asarray(a.v_pages[:, la]), np.asarray(s.v_pages[:, ls]))
    # the async plane hid 3 of 4 pages; the sync plane's only credit is
    # the wall time the modeled DMA ran before the turn (a few ms here)
    assert a.transfer.stats.reload_pages_off_path == 3
    a_st = a.sessions["a"].turn_stats[-1]
    s_st = s.sessions["a"].turn_stats[-1]
    assert s_st["reload_off_path_s"] < 0.1 < a_st["reload_off_path_s"]
    assert s_st["reload_stall_s"] > a_st["reload_stall_s"]


@multidev
def test_chunked_overlap_token_exact_on_mesh(tiny):
    """The same chunked-overlap trace on an 8-virtual-device tensor-
    sharded page store: token streams and stall accounting identical to
    the single-device engine (chunk staging + placement re-commit keep
    the sharded plane bit-exact)."""
    rng = np.random.default_rng(21)
    cfg, _ = tiny
    prompts = [rng.integers(0, cfg.vocab_size, size=14),
               rng.integers(0, cfg.vocab_size, size=6),
               rng.integers(0, cfg.vocab_size, size=4)]
    mesh = jax.make_mesh((1, min(8, NDEV)), ("data", "model"))

    def run(use_mesh):
        eng = _engine(tiny, pcie_gb_s=_slow_pcie(cfg),
                      mesh=mesh if use_mesh else None)
        st_ = _drive_overlap(eng, prompts, rounds_during_speech=3)
        return eng, st_

    plain, st_plain = run(False)
    sharded, st_mesh = run(True)
    sharded.check_invariants()
    assert sharded.sessions["a"].history == plain.sessions["a"].history
    assert st_mesh["reload_stall_s"] == \
        pytest.approx(st_plain["reload_stall_s"])
    assert st_mesh["reload_off_path_s"] == \
        pytest.approx(st_plain["reload_off_path_s"])
    assert sharded.transfer.stats.reload_pages_off_path == 3


def test_time_credit_warm_hit_when_idle(tiny):
    """A fast channel and a long utterance: even with zero rounds run,
    the modeled DMA finishes inside the speech window, so turn start
    settles everything off-path — stall 0, preload hit."""
    rng = np.random.default_rng(13)
    cfg, _ = tiny
    eng = _engine(tiny)                          # default 25 GB/s
    eng.add_session("a", rng.integers(0, cfg.vocab_size, size=12),
                    max_new_tokens=4)
    eng.run_to_completion()
    assert eng.kv.evict(2, eng.clock.now()) == 2
    eng.flush_transfers()
    eng.user_speech_start("a", expected_dur_s=2.0)
    assert eng.pool.inflight_pages("a") == (2, 0)
    eng.clock.tick(2.0)                          # idle utterance
    eng.start_turn("a", rng.integers(0, cfg.vocab_size, size=3),
                   max_new_tokens=3)
    eng.run_to_completion()
    eng.check_invariants()
    st_ = eng.sessions["a"].turn_stats[-1]
    assert st_["reload_stall_s"] == 0.0
    assert st_["reload_off_path_s"] > 0.0
    assert eng.preloader.stats.hits == 1


def test_run_round_respects_chunk_budget(tiny):
    """transfer_chunks_per_round bounds how much DMA one round may
    issue."""
    rng = np.random.default_rng(14)
    cfg, _ = tiny
    eng = _engine(tiny, transfer_chunks_per_round=2,
                  pcie_gb_s=_slow_pcie(cfg))
    eng.add_session("a", rng.integers(0, cfg.vocab_size, size=14),
                    max_new_tokens=4)
    eng.run_to_completion()
    assert eng.kv.evict(4, eng.clock.now()) == 4
    eng.flush_transfers()
    eng.add_session("b", rng.integers(0, cfg.vocab_size, size=6),
                    max_new_tokens=20)
    eng.step()
    eng.user_speech_start("a", expected_dur_s=100.0)
    before = eng.transfer.pending_reload_pages("a")
    assert before == 4
    eng.step()
    assert eng.transfer.pending_reload_pages("a") == before - 2
    eng.step()
    assert eng.transfer.pending_reload_pages("a") == before - 4


# ======================================================================
# copy-then-free offload
# ======================================================================
def test_offload_is_copy_then_free_and_demand_drained(tiny):
    """Eviction defers the device->host copy; the slots free only when
    chunks drain — and allocation pressure forces exactly that."""
    rng = np.random.default_rng(15)
    cfg, _ = tiny
    # rounds get no drain budget: only allocation demand may complete
    # the copies, which is exactly what this test pins down
    eng = _engine(tiny, num_pages=8, transfer_chunks_per_round=0)
    eng.add_session("a", rng.integers(0, cfg.vocab_size, size=14),
                    max_new_tokens=4)            # a owns ~5 pages
    eng.run_to_completion()
    free0 = eng.pool.free_pages
    assert eng.kv.evict(3, eng.clock.now()) == 3
    # accounting freed, physical slots still held (copy-then-free)
    assert eng.kv.free_blocks >= 3
    assert eng.pool.free_pages == free0
    assert eng.pool.inflight_pages("a") == (0, 3)
    eng.check_invariants()
    # a new session demands the slots: the offload chunks drain on
    # demand, and a's copies end up durable in the host store
    eng.add_session("b", rng.integers(0, cfg.vocab_size, size=10),
                    max_new_tokens=3)
    eng.run_to_completion()
    eng.check_invariants()
    assert eng.transfer.stats.demand_drains > 0
    assert len(eng.pool.seq("a").offloaded) \
        + eng.pool.inflight_pages("a")[1] == 3


def test_reload_cancels_inflight_offload_for_free(tiny):
    """A turn arriving before the copy-then-free chunks drain keeps the
    pages resident at zero transfer cost (no bytes ever moved)."""
    rng = np.random.default_rng(16)
    cfg, _ = tiny
    eng = _engine(tiny)
    eng.add_session("a", rng.integers(0, cfg.vocab_size, size=14),
                    max_new_tokens=4)
    eng.run_to_completion()
    assert eng.kv.evict(3, eng.clock.now()) == 3
    assert eng.pool.inflight_pages("a") == (0, 3)   # copies not durable
    eng.start_turn("a", rng.integers(0, cfg.vocab_size, size=3),
                   max_new_tokens=3)
    eng.run_to_completion()
    eng.check_invariants()
    st_ = eng.sessions["a"].turn_stats[-1]
    assert st_["reload_stall_s"] == 0.0
    assert eng.transfer.stats.offload_pages_cancelled == 3
    assert eng.transfer.stats.reload_pages_on_path == 0
    assert not eng.pool.seq("a").offloaded


def test_saturated_turn_with_inflight_offload_requeues(tiny):
    """Regression: a session whose suffix is still *offloading* (chunks
    queued, host-copy dict empty) must not start a turn when its reload
    cannot be admitted — the old guard only looked at `offloaded`, so
    the turn started and a later round's FIFO drain moved the pages to
    DRAM mid-decode, crashing the block-table build. The guard must
    raise the recoverable OutOfPages instead."""
    rng = np.random.default_rng(22)
    cfg, _ = tiny
    eng = _engine(tiny, transfer_chunks_per_round=0)
    eng.add_session("a", rng.integers(0, cfg.vocab_size, size=14),
                    max_new_tokens=4)
    eng.run_to_completion()
    assert eng.kv.evict(3, eng.clock.now()) == 3
    assert eng.pool.inflight_pages("a") == (0, 3)    # copies in flight
    assert not eng.pool.seq("a").offloaded
    # saturate the accounting so a's reload cannot be admitted
    hold = eng.kv.free_blocks
    assert eng.kv.try_allocate_working(hold, eng.clock.now())
    with pytest.raises(OutOfPages):
        eng.start_turn("a", rng.integers(0, cfg.vocab_size, size=3),
                       max_new_tokens=3)
    # recoverable: unpinned, chunks still queued, nothing half-started
    # (check_invariants runs after the synthetic working-block hold is
    # released — the hold itself pairs no physical pages with its
    # accounting, which real allocations always do)
    assert not eng.kv.session("a").pinned
    assert eng.pool.inflight_pages("a") == (0, 3)
    # pressure drains: the same turn now admits and runs clean
    eng.kv.release_working(hold)
    eng.start_turn("a", rng.integers(0, cfg.vocab_size, size=3),
                   max_new_tokens=3)
    eng.run_to_completion()
    eng.check_invariants()
    assert eng.transfer.stats.offload_pages_cancelled == 3


def test_requeued_turn_keeps_settled_reload_split(tiny):
    """Regression: an OutOfPages requeue used to drop the split the
    failed attempt's settlement had banked — the retry overwrote it
    with ~0, so already-done reload work vanished from the overlap
    accounting and TransferStats diverged from the per-turn metrics.
    The settled seconds must carry forward as off-path credit (they
    stalled nothing: the turn they settled for never started) and the
    ledger's page stats must reclassify to match."""
    rng = np.random.default_rng(23)
    cfg, _ = tiny
    eng = _engine(tiny, pcie_gb_s=_slow_pcie(cfg),
                  transfer_chunks_per_round=0)
    per_page = eng.kv.channel.transfer_time(1)
    eng.add_session("a", rng.integers(0, cfg.vocab_size, size=18),
                    max_new_tokens=4)
    eng.run_to_completion()
    assert eng.kv.evict(4, eng.clock.now()) == 4
    eng.flush_transfers()
    window = (4 * per_page + eng.preloader.encode_delay_s) / 0.8
    assert eng.user_speech_start("a", expected_dur_s=window) is not None
    assert eng.pool.inflight_pages("a") == (4, 0)
    # pressure strikes again: 2 of the loading pages are re-evicted
    # (cancelled zero-copy, back to durable DRAM)
    eng.monitor.on_speech_end("a")
    eng.kv.session("a").protected_until = -1.0
    assert eng.kv.evict(2, eng.clock.now()) == 2
    assert eng.pool.inflight_pages("a") == (2, 0)
    hold = eng.kv.free_blocks
    assert eng.kv.try_allocate_working(hold, eng.clock.now())
    with pytest.raises(OutOfPages):
        # settles the 2 in-flight chunks, then fails on the 2 evicted
        eng.start_turn("a", rng.integers(0, cfg.vocab_size, size=3),
                       max_new_tokens=3)
    eng.kv.release_working(hold)
    eng.start_turn("a", rng.integers(0, cfg.vocab_size, size=3),
                   max_new_tokens=3)
    eng.run_to_completion()
    eng.check_invariants()
    st_ = eng.sessions["a"].turn_stats[-1]
    # retry: 2 pages reload on-path; the failed attempt's 2 settled
    # pages ride along as off-path credit instead of vanishing
    assert st_["reload_stall_s"] == pytest.approx(2 * per_page)
    assert st_["reload_off_path_s"] == pytest.approx(2 * per_page)
    stats = eng.transfer.stats
    assert stats.reload_pages_on_path == 2      # reclassified: 4-2
    assert stats.reload_pages_off_path == 2
    assert stats.overlap_fraction() == pytest.approx(
        st_["reload_off_path_s"]
        / (st_["reload_off_path_s"] + st_["reload_stall_s"]))


# ======================================================================
# cancellation: hangup / eviction-of-a-loading-session / burst cancel
# ======================================================================
def _evicted_and_preloading(tiny, rng, *, pages=3):
    cfg, _ = tiny
    eng = _engine(tiny, pcie_gb_s=_slow_pcie(cfg))
    eng.add_session("a", rng.integers(0, cfg.vocab_size, size=14),
                    max_new_tokens=4)
    eng.run_to_completion()
    assert eng.kv.evict(pages, eng.clock.now()) == pages
    eng.flush_transfers()
    per_page = eng.kv.channel.transfer_time(1)
    window = (pages * per_page + eng.preloader.encode_delay_s) / 0.8
    assert eng.user_speech_start("a", expected_dur_s=window) is not None
    assert eng.pool.inflight_pages("a") == (pages, 0)
    return eng


def test_hangup_mid_transfer_leaks_nothing(tiny):
    rng = np.random.default_rng(17)
    eng = _evicted_and_preloading(tiny, rng)
    eng.end_session("a")
    eng.check_invariants()
    assert eng.transfer.idle()
    assert eng.pool.free_pages == eng.num_pages
    assert "a" not in eng.pool.seqs              # host copies gone too
    assert "a" not in eng.preloader.pending


def test_evicting_a_loading_session_cancels_zero_copy(tiny):
    """Pressure evicts the very session whose reload is in flight: the
    queued chunks cancel (their bytes never arrived), the reserved
    slots free immediately, the host copies stay authoritative."""
    rng = np.random.default_rng(18)
    eng = _evicted_and_preloading(tiny, rng, pages=3)
    # strip the preload's protections so the eviction pass can pick it
    eng.monitor.on_speech_end("a")
    eng.kv.session("a").protected_until = -1.0
    freed = eng.kv.evict(3, eng.clock.now())
    assert freed == 3
    eng.check_invariants()
    assert eng.transfer.stats.reload_pages_cancelled == 3
    assert eng.pool.inflight_pages("a") == (0, 0)
    assert len(eng.pool.seq("a").offloaded) == 3   # still durable
    # and the session still comes back bit-consistent on its next turn
    cfg, _ = tiny
    eng.start_turn("a", rng.integers(0, cfg.vocab_size, size=3),
                   max_new_tokens=3)
    eng.run_to_completion()
    eng.check_invariants()


def test_preloader_burst_cancel_rolls_back_page_exact(tiny):
    rng = np.random.default_rng(19)
    eng = _evicted_and_preloading(tiny, rng, pages=3)
    eng.drain_transfers(1)                       # one chunk landed
    hbm_before = eng.kv.session("a").hbm_blocks
    eng.preloader.cancel("a", eng.clock.now())
    eng.check_invariants()
    assert eng.preloader.stats.cancelled == 1
    # only the two un-landed pages rolled back
    assert eng.kv.session("a").hbm_blocks == hbm_before - 2
    assert eng.pool.inflight_pages("a") == (0, 0)
    assert len(eng.pool.seq("a").offloaded) == 2
    assert eng.transfer.stats.reload_pages_cancelled == 2
    # next turn sync-reloads the remainder and decodes fine
    cfg, _ = tiny
    eng.start_turn("a", rng.integers(0, cfg.vocab_size, size=3),
                   max_new_tokens=3)
    eng.run_to_completion()
    eng.check_invariants()


# ======================================================================
# measurement regression: block only on the transferred buffers
# ======================================================================
def test_reload_wall_blocks_only_chunk_buffers(tiny, monkeypatch):
    """The old hook called jax.block_until_ready(self.k_pages) — timing
    the whole page store (and any unrelated queued device work). The
    chunked path must block only on the staged chunk."""
    rng = np.random.default_rng(20)
    cfg, _ = tiny
    eng = _engine(tiny)
    eng.add_session("a", rng.integers(0, cfg.vocab_size, size=14),
                    max_new_tokens=4)
    eng.run_to_completion()
    assert eng.kv.evict(3, eng.clock.now()) == 3
    eng.flush_transfers()

    blocked = []
    real = jax.block_until_ready

    def spy(x):
        blocked.append(x)
        return real(x)

    monkeypatch.setattr(jax, "block_until_ready", spy)
    eng.kv.reload("a", eng.clock.now(), background=False)
    monkeypatch.undo()
    eng.check_invariants()
    assert blocked, "reload path must time the staged buffers"
    store_bytes = eng.k_pages.size * eng.k_pages.dtype.itemsize
    chunk_bytes = 2 * cfg.num_layers * eng.page_size \
        * cfg.num_kv_heads * cfg.resolved_head_dim \
        * eng.k_pages.dtype.itemsize * eng.transfer.chunk_pages
    for arr in blocked:
        nbytes = int(np.prod(arr.shape)) * arr.dtype.itemsize
        assert nbytes <= chunk_bytes, \
            f"blocked on {nbytes}B (> chunk {chunk_bytes}B) — " \
            "over-synchronizing the page store again"
        assert nbytes < store_bytes
    assert len(eng.reload_wall_s) == len(blocked)


# ======================================================================
# conservation property: random interleavings, no leaks
# ======================================================================
OPS = ("turn", "round", "speech", "barge", "evict", "hangup", "drain",
       "cancel", "flush")


def _conservation_driver(tiny, op_codes):
    """Apply a sequence of (op, session) codes to a small engine,
    checking after every op that each session's pages partition into
    resident/in-flight/offloaded and the ledger matches the pool."""
    cfg, params = tiny
    eng = _engine(tiny, num_pages=12, pages_per_seq=6,
                  pcie_gb_s=_slow_pcie(cfg))
    rng = np.random.default_rng(7)
    sids = ["s0", "s1", "s2"]
    ended = set()

    def live_slot(sid):
        return any(s is not None and s.session_id == sid
                   for s in eng.slot_state.values())

    def check():
        eng.check_invariants()
        for sid, s in eng.pool.seqs.items():
            resident = sum(1 for li, p in enumerate(s.pages)
                           if p >= 0 and li not in s.loading
                           and li not in s.offloading)
            inflight = len(s.loading) + len(s.offloading)
            pure_off = len(s.offloaded) - len(s.loading)
            assert resident + inflight + pure_off == len(s.pages)

    for op, si in op_codes:
        sid = sids[si % len(sids)]
        now = eng.clock.now()
        try:
            sess = eng.sessions.get(sid)
            room = sess is None or sess.kv_len + 10 <= eng.max_context
            if op == "turn" and sid not in ended and not live_slot(sid) \
                    and eng.free_slot() is not None and room:
                prompt = rng.integers(0, cfg.vocab_size,
                                      size=int(rng.integers(2, 6)))
                n = int(rng.integers(2, 5))
                if sid in eng.sessions:
                    eng.start_turn(sid, prompt, max_new_tokens=n)
                else:
                    eng.add_session(sid, prompt, max_new_tokens=n)
            elif op == "round":
                eng.step()
            elif op == "speech" and sid not in ended \
                    and sid in eng.sessions and not live_slot(sid):
                eng.user_speech_start(sid, expected_dur_s=float(
                    rng.uniform(0.1, 30.0)))
            elif op == "barge" and live_slot(sid):
                eng.barge_in(sid, expected_dur_s=0.5)
            elif op == "evict":
                eng.kv.evict(int(rng.integers(1, 4)), now)
            elif op == "hangup" and sid not in ended \
                    and sid in eng.sessions:
                if live_slot(sid):
                    eng.abort(sid)
                eng.end_session(sid)
                ended.add(sid)
            elif op == "drain":
                eng.drain_transfers(1)
            elif op == "cancel":
                eng.preloader.cancel(sid, now)
            elif op == "flush":
                eng.flush_transfers()
        except OutOfPages:
            pass                      # recoverable pressure, by contract
        check()

    # teardown: no slot, host-store entry, or ledger chunk may leak
    for sid in sids:
        if sid in eng.sessions and sid not in ended:
            if live_slot(sid):
                eng.abort(sid)
            eng.end_session(sid)
        check()
    assert eng.transfer.idle()
    assert eng.pool.free_pages == eng.num_pages
    assert not any(s.offloaded or s.loading or s.offloading
                   for s in eng.pool.seqs.values())


# always-on deterministic sweep (hypothesis is an optional dep)
@pytest.mark.slow
@pytest.mark.parametrize("seed", range(6))
def test_conservation_random_interleavings(tiny, seed):
    r = random.Random(seed)
    ops = [(r.choice(OPS), r.randrange(3)) for _ in range(40)]
    _conservation_driver(tiny, ops)


@pytest.mark.slow
@given(ops=st.lists(st.tuples(st.sampled_from(OPS), st.integers(0, 2)),
                    min_size=1, max_size=60))
@settings(max_examples=20, deadline=None)
def test_conservation_property(tiny, ops):
    _conservation_driver(tiny, ops)


# ======================================================================
# drain argument validation + the off-path banking contract
# ======================================================================
def _queued_reload(tiny):
    """Engine with a 4-page reload queued on the slow channel (1 s per
    page, chunk_pages=1 -> four 1-page chunks, none drained yet)."""
    rng = np.random.default_rng(21)
    cfg, _ = tiny
    eng = _engine(tiny, pcie_gb_s=_slow_pcie(cfg))
    eng.add_session("a", rng.integers(0, cfg.vocab_size, size=14),
                    max_new_tokens=6)
    eng.run_to_completion()
    assert eng.kv.evict(4, eng.clock.now()) == 4
    eng.flush_transfers()
    eng.user_speech_start("a", expected_dur_s=100.0)
    assert eng.transfer.pending_reload_pages("a") == 4
    return eng


def test_drain_rejects_zero_budget_and_empty_kinds(tiny):
    """A zero/negative chunk budget or empty kinds would return 0 with
    work still queued — and every caller reads 0 as 'queue dry' (the
    demand-drain loop breaks on it). Usage error, not a silent no-op."""
    eng = _queued_reload(tiny)
    now = eng.clock.now()
    with pytest.raises(ValueError, match="max_chunks=0"):
        eng.transfer.drain(now, 0)
    with pytest.raises(ValueError, match="max_chunks=-1"):
        eng.transfer.drain(now, -1)
    with pytest.raises(ValueError, match="kinds"):
        eng.transfer.drain(now, 1, kinds=())
    # nothing drained by the rejected calls...
    assert eng.transfer.pending_reload_pages("a") == 4
    # ...and the legitimate spellings still work
    assert eng.transfer.drain(now, 1) == 1
    assert eng.transfer.drain(now, None) == 3
    assert eng.transfer.drain(now, 1) == 0      # genuinely dry now
    eng.check_invariants()


def test_demand_drain_loop_with_satisfied_predicate(tiny):
    """The demand-drain loop never passes a zero budget: a predicate
    that is already true completes nothing and touches no chunk."""
    eng = _queued_reload(tiny)
    now = eng.clock.now()
    assert eng.transfer.drain_offloads_until(now, lambda: True) == 0
    assert eng.transfer.pending_reload_pages("a") == 4
    # and with offloads queued it drains exactly until satisfied
    assert eng.transfer.drain_offloads_until(now, lambda: False) == 0 \
        and eng.transfer.pending_offload_pages() == 0  # dry -> break


def test_drained_chunk_banks_full_modeled_cost(tiny):
    """The banking contract, pinned at the ledger level (the docstring
    reconciliation satellite): a chunk physically drained by a round
    banks its FULL modeled channel cost off-path even when the drain
    happens long before the chunk's ``modeled_done``; settlement
    charges only the still-queued remainder and never re-charges the
    drained chunk — total charged is exactly the job's modeled cost."""
    eng = _queued_reload(tiny)
    per_page = eng.kv.channel.transfer_time(1)
    now = eng.clock.now()
    # drain one chunk immediately: wall-now is far before even the
    # first chunk's modeled completion (now + 1 s)
    assert eng.drain_transfers(1) == 1
    on, off = eng.transfer.finish_session("a", now)
    assert off == pytest.approx(per_page)       # banked at drain time
    assert on == pytest.approx(3 * per_page)    # queued remainder
    stats = eng.transfer.stats
    assert stats.reload_pages_off_path == 1
    assert stats.reload_pages_on_path == 3
    assert on + off == pytest.approx(4 * per_page)   # no double charge
    assert eng.transfer.pop_split("a") == (pytest.approx(on),
                                           pytest.approx(off))
    eng.check_invariants()

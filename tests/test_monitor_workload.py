"""Property tests: playback timeline invariants + workload generators."""
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core.monitor import PlaybackState, RuntimeMonitor
from repro.serving.workload import WorkloadConfig, generate


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def now(self):
        return self.t


@settings(max_examples=200, deadline=None)
@given(events=st.lists(
    st.tuples(st.floats(0.0, 5.0),      # dt until next append
              st.floats(0.01, 4.0)),    # appended audio seconds
    min_size=1, max_size=30))
def test_playback_invariants(events):
    pb = PlaybackState()
    t = 0.0
    total = 0.0
    for dt, dur in events:
        t += dt
        pb.append(t, dur)
        total += dur
        # buffer never negative, never exceeds appended audio
        assert 0.0 <= pb.buffer_s(t) <= total + 1e-9
        # consumed + buffered == appended
        assert abs(pb.consumed_s(t) + pb.buffer_s(t) - total) < 1e-6
        # gaps only grow, max_gap <= total gap
        assert pb.max_gap_s <= pb.gap_s + 1e-9
    # after the buffer drains, consumed == appended
    assert abs(pb.consumed_s(pb.play_end + 1.0) - total) < 1e-6


def test_monitor_reply_gap_ema_updates():
    clock = FakeClock()
    mon = RuntimeMonitor(clock, workload_reply_gap_prior=2.0)
    assert mon.reply_gap_s("new") == 2.0          # prior fallback
    mon.register("s")
    mon.on_audio("s", 1.0)
    clock.t = 1.0
    mon.on_response_complete("s")
    clock.t = 4.0                                  # 3s think time
    mon.on_speech_start("s")
    assert abs(mon.reply_gap_s("s") - 3.0) < 1e-6
    clock.t = 10.0
    mon.on_response_complete("s")
    clock.t = 11.0                                 # 1s think time
    mon.on_speech_start("s")
    g = mon.reply_gap_s("s")
    assert 1.0 < g < 3.0                           # EMA between samples


def test_barge_in_marks_immediate_reuse():
    clock = FakeClock()
    mon = RuntimeMonitor(clock)
    mon.register("s")
    assert not mon.immediate_reuse("s")
    mon.on_barge_in("s")
    assert mon.immediate_reuse("s")


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 1000), pbi=st.sampled_from([0.0, 0.5, 1.0]))
def test_workload_generator_properties(seed, pbi):
    cfg = WorkloadConfig(kind="interactive", num_sessions=20, seed=seed,
                         p_barge_in=pbi, concurrency=4)
    sessions = generate(cfg)
    assert len(sessions) == 20
    again = generate(cfg)
    for a, b in zip(sessions, again):              # deterministic
        assert a.session_id == b.session_id
        assert [t.prompt_len for t in a.turns] == \
            [t.prompt_len for t in b.turns]
    turns = [t for s in sessions for t in s.turns]
    assert all(3 <= len(s.turns) <= 8 for s in sessions)
    assert all(t.prompt_len >= 20 and t.response_tokens >= 8
               for t in turns)
    if pbi == 0.0:
        assert not any(t.barge_in for t in turns)
    if pbi == 1.0:
        assert all(t.barge_in for t in turns)
        assert all(0 < t.barge_cut_s < 60 for t in turns)


def test_arrival_processes():
    pois = generate(WorkloadConfig(kind="sharegpt", num_sessions=50,
                                   arrival="poisson", rate_rps=5.0, seed=1))
    times = [s.arrival_time for s in pois]
    assert times == sorted(times)
    mean_gap = np.mean(np.diff([0] + times))
    assert 0.05 < mean_gap < 0.6                   # ~1/5 rps
    burst = generate(WorkloadConfig(kind="sharegpt", num_sessions=50,
                                    arrival="burstgpt", rate_rps=5.0,
                                    seed=1))
    gaps = np.diff([0] + [s.arrival_time for s in burst])
    # bursty arrivals: higher dispersion than poisson
    assert np.std(gaps) / np.mean(gaps) > 0.8


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 500))
def test_interarrival_statistics_seeded(seed):
    """Poisson inter-arrivals are exponential (CV ~ 1, mean ~ 1/rate);
    burstgpt's square-wave rate modulation is strictly more dispersed at
    the same mean rate; both are seed-deterministic."""
    n, rate = 400, 4.0
    pois = generate(WorkloadConfig(kind="sharegpt", num_sessions=n,
                                   arrival="poisson", rate_rps=rate,
                                   seed=seed))
    gaps = np.diff([0.0] + [s.arrival_time for s in pois])
    assert abs(np.mean(gaps) - 1.0 / rate) < 0.35 / rate
    cv = np.std(gaps) / np.mean(gaps)
    assert 0.8 < cv < 1.25                         # exponential: CV = 1
    burst = generate(WorkloadConfig(kind="sharegpt", num_sessions=n,
                                    arrival="burstgpt", rate_rps=rate,
                                    seed=seed))
    bgaps = np.diff([0.0] + [s.arrival_time for s in burst])
    bcv = np.std(bgaps) / np.mean(bgaps)
    assert bcv > cv                                # over-dispersed
    again = generate(WorkloadConfig(kind="sharegpt", num_sessions=n,
                                    arrival="burstgpt", rate_rps=rate,
                                    seed=seed))
    assert [s.arrival_time for s in burst] == \
        [s.arrival_time for s in again]


def test_barge_in_cut_anchored_after_ttfp():
    """p_barge_in=1 cuts every turn; the cut is a fraction of the reply
    audio, so driving the simulator shows every barge firing at/after
    the turn's first audio packet — never before TTFP."""
    from repro.serving.costmodel import PIPELINES
    from repro.serving.simulator import run_sim

    for s in generate(WorkloadConfig(kind="interactive", num_sessions=12,
                                     p_barge_in=1.0, seed=5)):
        for t in s.turns:
            assert t.barge_in
            # cut anchored inside the reply's audio span (tokens round
            # down from the drawn audio duration, hence the +1)
            assert 0.0 < t.barge_cut_s \
                < 0.75 * (t.response_tokens + 1) * 0.08 + 1e-9
    pipe = PIPELINES["qwen3-omni-like"](kv_capacity_gb=4.0)
    wl = WorkloadConfig(kind="interactive", num_sessions=8,
                        concurrency=4, p_barge_in=1.0, seed=5)
    m = run_sim(pipe, wl, until=600.0)
    barged = [t for t in m.turns if t.barged]
    assert barged, "p_barge_in=1.0 must produce barge-ins"
    for t in barged:
        assert t.ttfp is not None, "barge fired before first audio"
        # the cut lands at TTFP + barge_cut_s at the earliest
        assert t.finish_time >= t.speech_end + t.ttfp - 1e-9


# ---------------------------------------------------- playback edges
def test_playback_zero_duration_append():
    pb = PlaybackState()
    pb.append(1.0, 0.0)
    assert not pb.started                  # empty packet != first audio
    assert pb.buffer_s(1.0) == 0.0
    pb.append(2.0, 1.0)
    assert pb.started and pb.start_time == 2.0
    # zero-duration append after a drain still accounts the gap once
    pb.append(4.5, 0.0)
    assert pb.n_gaps == 1
    assert pb.gap_s == pytest.approx(1.5)
    assert pb.play_end == 4.5
    assert pb.appended_s == 1.0
    # negative durations never shrink the timeline
    end = pb.play_end
    pb.append(4.6, -3.0)
    assert pb.play_end >= end
    assert pb.appended_s == 1.0


def test_playback_out_of_order_appends():
    """Stale-timestamped appends queue behind the buffer: play_end stays
    monotone, gaps are only ever opened by forward drains, and consumed
    never goes negative."""
    pb = PlaybackState()
    pb.append(1.0, 2.0)                    # plays until 3.0
    pb.append(0.5, 1.0)                    # out-of-order: queues to 4.0
    assert pb.play_end == pytest.approx(4.0)
    assert pb.n_gaps == 0 and pb.gap_s == 0.0
    assert pb.consumed_s(0.2) >= 0.0       # stale query clamps
    pb.append(6.0, 1.0)                    # 2s drain -> one gap
    assert pb.n_gaps == 1 and pb.gap_s == pytest.approx(2.0)
    assert pb.max_gap_s == pytest.approx(2.0)
    assert pb.play_end == pytest.approx(7.0)


@settings(max_examples=200, deadline=None)
@given(events=st.lists(
    st.tuples(st.floats(-3.0, 5.0),     # dt (negative = out-of-order)
              st.floats(0.0, 4.0)),     # appended audio (0 allowed)
    min_size=1, max_size=30))
def test_playback_invariants_adversarial(events):
    """gap/max_gap/n_gaps accounting stays consistent and play_end is
    monotone under out-of-order and zero-duration appends."""
    pb = PlaybackState()
    t = 0.0
    tq = 0.0                 # the monitor's clock is monotone even when
    total = 0.0              # append event timestamps are stale
    last_end = 0.0
    gaps_seen = 0
    for dt, dur in events:
        t = max(0.0, t + dt)
        tq = max(tq, t)
        opens_gap = pb.started and t > pb.play_end
        pb.append(t, dur)
        gaps_seen += bool(opens_gap)
        if pb.started:
            total += dur
        assert pb.play_end >= last_end - 1e-12          # monotone
        last_end = pb.play_end
        assert pb.n_gaps == gaps_seen
        assert 0.0 <= pb.max_gap_s <= pb.gap_s + 1e-9
        assert 0.0 <= pb.buffer_s(tq) <= total + 1e-9
        assert 0.0 <= pb.consumed_s(tq) <= total + 1e-9
        # timeline identity: everything appended is either still
        # buffered or was consumed
        assert abs(pb.consumed_s(tq) + pb.buffer_s(tq) - total) < 1e-6
    assert pb.appended_s == pytest.approx(total)

"""Property tests: playback timeline invariants + workload generators."""
import numpy as np
from hypothesis_compat import given, settings, st

from repro.core.monitor import PlaybackState, RuntimeMonitor
from repro.serving.workload import WorkloadConfig, generate


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def now(self):
        return self.t


@settings(max_examples=200, deadline=None)
@given(events=st.lists(
    st.tuples(st.floats(0.0, 5.0),      # dt until next append
              st.floats(0.01, 4.0)),    # appended audio seconds
    min_size=1, max_size=30))
def test_playback_invariants(events):
    pb = PlaybackState()
    t = 0.0
    total = 0.0
    for dt, dur in events:
        t += dt
        pb.append(t, dur)
        total += dur
        # buffer never negative, never exceeds appended audio
        assert 0.0 <= pb.buffer_s(t) <= total + 1e-9
        # consumed + buffered == appended
        assert abs(pb.consumed_s(t) + pb.buffer_s(t) - total) < 1e-6
        # gaps only grow, max_gap <= total gap
        assert pb.max_gap_s <= pb.gap_s + 1e-9
    # after the buffer drains, consumed == appended
    assert abs(pb.consumed_s(pb.play_end + 1.0) - total) < 1e-6


def test_monitor_reply_gap_ema_updates():
    clock = FakeClock()
    mon = RuntimeMonitor(clock, workload_reply_gap_prior=2.0)
    assert mon.reply_gap_s("new") == 2.0          # prior fallback
    mon.register("s")
    mon.on_audio("s", 1.0)
    clock.t = 1.0
    mon.on_response_complete("s")
    clock.t = 4.0                                  # 3s think time
    mon.on_speech_start("s")
    assert abs(mon.reply_gap_s("s") - 3.0) < 1e-6
    clock.t = 10.0
    mon.on_response_complete("s")
    clock.t = 11.0                                 # 1s think time
    mon.on_speech_start("s")
    g = mon.reply_gap_s("s")
    assert 1.0 < g < 3.0                           # EMA between samples


def test_barge_in_marks_immediate_reuse():
    clock = FakeClock()
    mon = RuntimeMonitor(clock)
    mon.register("s")
    assert not mon.immediate_reuse("s")
    mon.on_barge_in("s")
    assert mon.immediate_reuse("s")


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 1000), pbi=st.sampled_from([0.0, 0.5, 1.0]))
def test_workload_generator_properties(seed, pbi):
    cfg = WorkloadConfig(kind="interactive", num_sessions=20, seed=seed,
                         p_barge_in=pbi, concurrency=4)
    sessions = generate(cfg)
    assert len(sessions) == 20
    again = generate(cfg)
    for a, b in zip(sessions, again):              # deterministic
        assert a.session_id == b.session_id
        assert [t.prompt_len for t in a.turns] == \
            [t.prompt_len for t in b.turns]
    turns = [t for s in sessions for t in s.turns]
    assert all(3 <= len(s.turns) <= 8 for s in sessions)
    assert all(t.prompt_len >= 20 and t.response_tokens >= 8
               for t in turns)
    if pbi == 0.0:
        assert not any(t.barge_in for t in turns)
    if pbi == 1.0:
        assert all(t.barge_in for t in turns)
        assert all(0 < t.barge_cut_s < 60 for t in turns)


def test_arrival_processes():
    pois = generate(WorkloadConfig(kind="sharegpt", num_sessions=50,
                                   arrival="poisson", rate_rps=5.0, seed=1))
    times = [s.arrival_time for s in pois]
    assert times == sorted(times)
    mean_gap = np.mean(np.diff([0] + times))
    assert 0.05 < mean_gap < 0.6                   # ~1/5 rps
    burst = generate(WorkloadConfig(kind="sharegpt", num_sessions=50,
                                    arrival="burstgpt", rate_rps=5.0,
                                    seed=1))
    gaps = np.diff([0] + [s.arrival_time for s in burst])
    # bursty arrivals: higher dispersion than poisson
    assert np.std(gaps) / np.mean(gaps) > 0.8

"""Tensor-sharded paged data plane (DESIGN.md §9).

Kernel-level parity: ``sharded_paged_attention`` / ``sharded_flash_
prefill`` / the shard_map'd ``paged_decode_step`` against the unsharded
kernels and ``kernels/ref.py`` across dtypes, page sizes, ragged
``seq_lens``, and head counts that do / do not divide the 'model' axis
(exercising every layout kind: heads, slots, and the replication
fallback).

Engine-level: the mesh-sharded ``PagedRealtimeEngine`` is **token-
exact** with the single-device engine on the same multi-turn trace —
prefill, decode, physical evict/offload/reload, barge-in — and under
the deterministic ``ReplayGateway`` the full scheduling-visible record
(TTFP rounds, completion order, barges) is identical.

The in-process tests need >1 jax device: run under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI
``multidevice`` job does). On a single-device host a subprocess smoke
keeps kernel parity covered in tier-1.
"""
import os
import subprocess
import sys
import textwrap
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.paged_attention import paged_attention

NDEV = len(jax.devices())
multidev = pytest.mark.skipif(
    NDEV < 2,
    reason="needs >1 device; run under "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8")

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _tol(dtype):
    return TOL[jnp.bfloat16 if dtype == jnp.bfloat16 else jnp.float32]


def _mesh(m):
    return jax.make_mesh((1, m), ("data", "model"))


def _layout(num_kv_heads, page, m):
    from repro.distributed.paged import PagedKVLayout
    return PagedKVLayout(SimpleNamespace(num_kv_heads=num_kv_heads),
                         _mesh(m), page)


def _paged_case(key, B, Hq, Hkv, D, page, pps, dtype):
    num_pages = B * pps + 3
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (B, Hq, D), dtype)
    kp = jax.random.normal(ks[1], (num_pages, page, Hkv, D), dtype)
    vp = jax.random.normal(ks[2], (num_pages, page, Hkv, D), dtype)
    bt = jax.random.permutation(
        ks[3], num_pages)[:B * pps].reshape(B, pps).astype(jnp.int32)
    # ragged lengths incl. a partially-filled last page and a 1-token row
    sl = jnp.array([(i * 7) % (page * pps) + 1 for i in range(B)],
                   jnp.int32)
    return q, kp, vp, bt, sl


# ======================================================================
# kernel: position remap + stats (single device — always runs)
# ======================================================================
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_stats_merge_matches_ref(dtype):
    """The shard-side contract without a mesh: slicing each page's slots
    into S stripes, computing per-stripe (o, m, l) with the position
    remap, and flash-merging reproduces the full softmax exactly."""
    q, kp, vp, bt, sl = _paged_case(jax.random.PRNGKey(0), 3, 4, 2, 16,
                                    8, 4, dtype)
    want = ref.paged_attention_ref(q, kp, vp, bt, sl)
    for S in (2, 4):
        psl = kp.shape[1] // S
        outs = []
        for s in range(S):
            o, m, l = paged_attention(
                q, kp[:, s * psl:(s + 1) * psl],
                vp[:, s * psl:(s + 1) * psl], bt, sl - s * psl,
                pos_stride=kp.shape[1], return_stats=True, interpret=True)
            outs.append((o.astype(jnp.float32), m, l))
        m_star = jnp.max(jnp.stack([m for _, m, _ in outs]), axis=0)
        ws = [l * jnp.exp(m - m_star) for _, m, l in outs]
        den = jnp.maximum(sum(ws), 1e-30)
        got = sum(o * w[..., None] for (o, _, _), w in zip(outs, ws)) \
            / den[..., None]
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=_tol(dtype), atol=_tol(dtype))


def test_paged_attention_default_unchanged():
    """No stats, no remap: byte-compatible with the pre-sharding API."""
    q, kp, vp, bt, sl = _paged_case(jax.random.PRNGKey(1), 2, 8, 2, 32,
                                    8, 5, jnp.float32)
    out = paged_attention(q, kp, vp, bt, sl, interpret=True)
    want = ref.paged_attention_ref(q, kp, vp, bt, sl)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ======================================================================
# shard_map kernel parity (multi-device)
# ======================================================================
@multidev
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Hq,Hkv,D,page,pps,m,kind",
    [
        (2, 8, 4, 16, 8, 4, 2, "heads"),     # Hkv % M == 0
        (3, 8, 2, 16, 8, 4, 4, "slots"),     # heads don't divide, page does
        (2, 4, 2, 32, 8, 5, 8, "slots"),
        (2, 6, 3, 16, 5, 4, 2, "replicated"),  # neither divides
        (1, 4, 1, 16, 16, 3, 8, "slots"),    # MQA, 1-token rows
    ])
def test_sharded_paged_attention_parity(B, Hq, Hkv, D, page, pps, m,
                                        kind, dtype):
    if m > NDEV:
        pytest.skip(f"mesh model={m} > {NDEV} devices")
    from repro.distributed.paged import sharded_paged_attention
    layout = _layout(Hkv, page, m)
    assert layout.kind == kind, layout
    q, kp, vp, bt, sl = _paged_case(jax.random.PRNGKey(2), B, Hq, Hkv, D,
                                    page, pps, dtype)
    got = sharded_paged_attention(layout, q, kp, vp, bt, sl,
                                  interpret=True)
    want = ref.paged_attention_ref(q, kp, vp, bt, sl)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=_tol(dtype), atol=_tol(dtype))


@multidev
@pytest.mark.parametrize("Hq,Hkv,m", [(8, 2, 2), (4, 1, 2), (6, 3, 2)])
def test_sharded_flash_prefill_parity(Hq, Hkv, m):
    if m > NDEV:
        pytest.skip(f"mesh model={m} > {NDEV} devices")
    from repro.distributed.paged import sharded_flash_prefill
    layout = _layout(Hkv, 8, m)
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (2, Hq, 32, 16))
    k = jax.random.normal(ks[1], (2, Hkv, 96, 16))
    v = jax.random.normal(ks[2], (2, Hkv, 96, 16))
    got = sharded_flash_prefill(layout, q, k, v, q_offset=64, block_q=16,
                                block_kv=16, interpret=True)
    want = ref.flash_prefill_ref(q, k, v, q_offset=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ======================================================================
# shard_map'd decode step vs the single-device step (multi-device)
# ======================================================================
@pytest.fixture(scope="module")
def tiny():
    from repro.configs import get_config, reduced
    from repro.models import init_params
    cfg = reduced(get_config("qwen2-1.5b"), layers=2, d_model=64,
                  vocab=331)
    return cfg, init_params(cfg, jax.random.PRNGKey(0))


def _step_case(cfg, B, page, pps, key):
    hd = cfg.resolved_head_dim
    num_pages = B * pps
    ks = jax.random.split(key, 4)
    kp = jax.random.normal(
        ks[0], (cfg.num_layers, num_pages + 1, page, cfg.num_kv_heads, hd))
    vp = jax.random.normal(ks[1], kp.shape)
    perm = np.asarray(jax.random.permutation(ks[2], num_pages))
    bt = perm[:B * pps].reshape(B, pps).astype(np.int32)
    written = np.array([(i * 11) % (page * (pps - 1)) for i in range(B)],
                       np.int32)
    tokens = np.asarray(
        jax.random.randint(ks[3], (B,), 0, cfg.vocab_size), np.int32)
    wp = np.array([bt[i, written[i] // page] for i in range(B)], np.int32)
    ws = written % page
    return (tokens, written, kp, vp, bt.astype(np.int32), written + 1,
            wp, ws)


@multidev
@pytest.mark.parametrize("page,m", [(8, 2), (8, 4), (8, 8), (6, 4)])
def test_sharded_decode_step_matches_unsharded(tiny, page, m):
    """The shard_map'd step — page writes included — against the plain
    jitted step on identical inputs. (8, 2) runs the heads layout,
    (8, 4/8) slots, (6, 4) the replication fallback."""
    if m > NDEV:
        pytest.skip(f"mesh model={m} > {NDEV} devices")
    import functools
    from repro.distributed.paged import PagedKVLayout, make_sharded_step
    from repro.serving.paged_engine import paged_decode_step
    cfg, params = tiny
    layout = PagedKVLayout(cfg, _mesh(m), page)
    tokens, written, kp, vp, bt, sl, wp, ws = _step_case(
        cfg, 3, page, 4, jax.random.PRNGKey(4))
    plain = jax.jit(functools.partial(paged_decode_step, cfg,
                                      interpret=True))
    lg0, k0, v0 = plain(params, tokens, written, kp, vp, bt, sl, wp, ws)
    sharded = make_sharded_step(cfg, layout, interpret=True)
    kp_s = jax.device_put(kp, layout.page_sharding())
    vp_s = jax.device_put(vp, layout.page_sharding())
    lg1, k1, v1 = sharded(params, jnp.asarray(tokens),
                          jnp.asarray(written), kp_s, vp_s,
                          jnp.asarray(bt), jnp.asarray(sl),
                          jnp.asarray(wp), jnp.asarray(ws))
    np.testing.assert_allclose(np.asarray(lg0), np.asarray(lg1),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(k0), np.asarray(k1),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(v0), np.asarray(v1),
                               rtol=2e-5, atol=2e-5)


# ======================================================================
# engine differential (multi-device): the acceptance criterion
# ======================================================================
def _drive_trace(eng, cfg):
    """Prefill + decode + physical evict/reload + barge-in, multi-turn."""
    rng = np.random.default_rng(3)
    p1 = rng.integers(0, cfg.vocab_size, size=10)
    p2 = rng.integers(0, cfg.vocab_size, size=6)
    pb = rng.integers(0, cfg.vocab_size, size=8)
    eng.add_session("a", p1, max_new_tokens=6)
    eng.run_to_completion()
    now = eng.clock.now()
    assert eng.kv.evict(2, now) == 2          # physical offload via hook
    eng.add_session("b", pb, max_new_tokens=4)  # clobber freed pages
    eng.run_to_completion()
    eng.start_turn("a", p2, max_new_tokens=8)   # reload path
    for _ in range(3):
        eng.step()
    eng.barge_in("a")
    eng.start_turn("a", rng.integers(0, cfg.vocab_size, size=4),
                   max_new_tokens=5)
    eng.run_to_completion()
    eng.check_invariants()
    return {sid: s.history for sid, s in eng.sessions.items()}


@multidev
@pytest.mark.parametrize("shape", [(1, 2), (1, 4), (1, 8), (2, 2)])
def test_sharded_engine_token_exact_full_trace(tiny, shape):
    if shape[0] * shape[1] > NDEV:
        pytest.skip(f"mesh {shape} > {NDEV} devices")
    from repro.serving.paged_engine import PagedRealtimeEngine
    cfg, params = tiny
    kw = dict(slots=2, page_size=8, pages_per_seq=16, num_pages=6)
    want = _drive_trace(PagedRealtimeEngine(cfg, params, **kw), cfg)
    mesh = jax.make_mesh(shape, ("data", "model"))
    eng = PagedRealtimeEngine(cfg, params, mesh=mesh, **kw)
    got = _drive_trace(eng, cfg)
    assert got == want
    # reloaded pages really round-tripped through DRAM on the sharded
    # store (the offload/evict happened physically, not just in books)
    assert eng.kv.reloaded_blocks >= 2
    assert eng.offload_events


@multidev
def test_sharded_replay_differential_matches_unsharded(tiny):
    """The full deterministic replay (scheduler + frontier cap + barge
    storms) on a sharded engine produces the identical scheduling-
    visible record as the single-device engine."""
    from repro.serving.gateway.replay import ReplayConfig, run_replay
    from repro.serving.paged_engine import PagedRealtimeEngine
    from repro.serving.workload import WorkloadConfig
    cfg, params = tiny
    wl = WorkloadConfig(kind="interactive", num_sessions=4, seed=5,
                        p_barge_in=0.5, arrival="poisson", rate_rps=4.0)
    mesh = jax.make_mesh((1, min(8, NDEV)), ("data", "model"))

    def run(use_mesh):
        def factory(clock):
            return PagedRealtimeEngine(
                cfg, params, slots=2, page_size=8, pages_per_seq=8,
                clock=clock, mesh=mesh if use_mesh else None)
        m, gw = run_replay(factory, wl, ReplayConfig(), seed=5)
        return [(t.session_id, t.turn_index, t.ttfp, t.finish_time,
                 t.completed, t.barged, t.talker_generated)
                for t in m.turns], gw

    plain, _ = run(False)
    sharded, gw = run(True)
    assert sharded == plain
    assert gw.max_over_frontier_s <= ReplayConfig().audio_per_token_s + 1e-6


@multidev
def test_live_gateway_on_sharded_engine(tiny):
    """The asyncio gateway end to end (warm-up compile included) over a
    mesh-sharded engine: sessions complete, barges ack, pages free."""
    from repro.serving.gateway import run_gateway_workload
    from repro.serving.gateway.harness import build_gateway
    mesh = jax.make_mesh((1, min(8, NDEV)), ("data", "model"))
    gw = build_gateway(policy="liveserve", scale=16.0, model=tiny,
                       slots=4, page_size=8, pages_per_seq=8, mesh=mesh,
                       frontier_cap_s=3.0)
    assert gw.engine.layout is not None
    m, gw = run_gateway_workload(
        policy="liveserve", sessions=4, barge_in=0.5, seed=1,
        max_prompt=8, max_response=8, max_turns=2, speech_scale=0.5,
        gateway=gw, timeout_s=300)
    eng = gw.engine
    assert m.completed_sessions == 4
    assert all(t.completed or t.barged for t in m.turns)
    assert all(s is None for s in eng.slot_state.values())
    assert eng.pool.free_pages == eng.num_pages
    eng.check_invariants()


# ======================================================================
# single-device tier-1 smoke: kernel parity in an 8-device subprocess
# ======================================================================
def test_sharded_kernels_subprocess_smoke():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src"
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from types import SimpleNamespace
        from repro.distributed.paged import (PagedKVLayout,
                                             sharded_paged_attention)
        from repro.kernels import ref
        assert len(jax.devices()) == 8
        for Hkv, page, m, kind in ((4, 8, 2, "heads"), (2, 8, 8, "slots"),
                                   (3, 5, 4, "replicated")):
            layout = PagedKVLayout(SimpleNamespace(num_kv_heads=Hkv),
                                   jax.make_mesh((1, m),
                                                 ("data", "model")), page)
            assert layout.kind == kind, (layout.kind, kind)
            B, Hq, D, pps = 2, 2 * Hkv, 16, 3
            P = B * pps + 2
            ks = jax.random.split(jax.random.PRNGKey(0), 4)
            q = jax.random.normal(ks[0], (B, Hq, D))
            kp = jax.random.normal(ks[1], (P, page, Hkv, D))
            vp = jax.random.normal(ks[2], (P, page, Hkv, D))
            bt = jax.random.permutation(ks[3], P)[:B * pps] \\
                .reshape(B, pps).astype(jnp.int32)
            sl = jnp.array([1, page * pps - 2], jnp.int32)
            got = sharded_paged_attention(layout, q, kp, vp, bt, sl,
                                          interpret=True)
            want = ref.paged_attention_ref(q, kp, vp, bt, sl)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=2e-5, atol=2e-5)
        print("OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=600,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-4000:]
    assert "OK" in r.stdout

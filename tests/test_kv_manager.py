"""Unit + property tests for the KV manager (paper §5) and preloader."""
import pytest
from hypothesis_compat import given, settings, st

from repro.core.kv_manager import KVManager
from repro.core.monitor import RuntimeMonitor
from repro.core.preload import Preloader


class FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def now(self):
        return self.t


def mk(capacity=100, policy="next_use", index_mode="heap", monitor=None,
       clock=None):
    clock = clock or FakeClock()
    return KVManager(capacity_blocks=capacity, block_size=16,
                     bytes_per_token=1024.0, monitor=monitor,
                     policy=policy, index_mode=index_mode,
                     clock=clock), clock


def add_session(kv, sid, blocks, last_access=0.0):
    s = kv.session(sid)
    s.total_blocks = blocks
    s.hbm_blocks = blocks
    s.last_access = last_access
    return s


def mon_with_playback(clock, sessions):
    """sessions: sid -> (remaining_playback_s, reply_gap_s)."""
    mon = RuntimeMonitor(clock)
    for sid, (play, gap) in sessions.items():
        mon.register(sid)
        v = mon.view(sid)
        v.playback.started = True
        v.playback.play_end = clock.now() + play
        v.playback.appended_s = play + 1
        v.reply_gap_ema = gap
    return mon


# ---------------------------------------------------------------- eviction
def test_next_use_evicts_farthest_first():
    clock = FakeClock(0.0)
    mon = mon_with_playback(clock, {
        "near": (1.0, 1.0),    # next use ~2s
        "far": (50.0, 5.0),    # next use ~55s
    })
    kv, _ = mk(capacity=20, monitor=mon, clock=clock)
    add_session(kv, "near", 10)
    add_session(kv, "far", 10)
    freed = kv.evict(5, clock.now())
    assert freed == 5
    assert kv.session("far").hbm_blocks == 5       # farthest evicted
    assert kv.session("near").hbm_blocks == 10     # near-reuse kept


def test_lru_evicts_oldest_access():
    kv, clock = mk(policy="lru", index_mode="scan")
    add_session(kv, "old", 10, last_access=1.0)
    add_session(kv, "new", 10, last_access=9.0)
    kv.evict(5, 10.0)
    assert kv.session("old").hbm_blocks == 5
    assert kv.session("new").hbm_blocks == 10


def test_suffix_evicted_prefix_kept():
    """Within a session, eviction shrinks the HBM range from the tail:
    the resident range stays a prefix (prefix continuity, §5.1)."""
    clock = FakeClock(0.0)
    mon = mon_with_playback(clock, {"a": (5.0, 2.0)})
    kv, _ = mk(capacity=10, monitor=mon, clock=clock)
    add_session(kv, "a", 10)
    kv.evict(4, 0.0)
    s = kv.session("a")
    assert s.hbm_blocks == 6 and s.dram_blocks == 4
    # reload brings back exactly the suffix
    t = kv.reload("a", 0.0, background=False)
    assert t.blocks == 4
    assert s.hbm_blocks == 10


def test_pinned_and_speaking_sessions_protected():
    clock = FakeClock(0.0)
    mon = mon_with_playback(clock, {"a": (1.0, 1.0), "b": (1.0, 1.0)})
    mon.on_speech_start("b")                      # immediate reuse
    kv, _ = mk(capacity=20, monitor=mon, clock=clock)
    add_session(kv, "a", 10).pinned = True
    add_session(kv, "b", 10)
    freed = kv.evict(5, 0.0)
    assert freed == 0                             # nothing evictable
    assert kv.session("a").hbm_blocks == 10
    assert kv.session("b").hbm_blocks == 10


def test_none_policy_discards_requiring_recompute():
    kv, clock = mk(policy="none", index_mode="scan")
    add_session(kv, "a", 10)
    kv.evict(4, 0.0)
    s = kv.session("a")
    assert s.discarded and s.total_blocks == 6
    assert kv.recompute_tokens("a") == 0 or True  # dram empty under 'none'
    assert kv.reload("a", 0.0, background=False) is None


def test_heap_and_scan_select_identical_victims():
    """Table 1 equivalence: indexed eviction == tail scan, only faster."""
    clock = FakeClock(0.0)
    sessions = {f"s{i}": (float(i * 3 % 17), 1.0 + i % 5)
                for i in range(25)}
    results = {}
    for mode in ("heap", "scan"):
        mon = mon_with_playback(FakeClock(0.0), sessions)
        kv, _ = mk(capacity=1000, monitor=mon, clock=FakeClock(0.0))
        for sid in sessions:
            add_session(kv, sid, 4)
        kv.evict(30, 0.0)
        results[mode] = {sid: kv.session(sid).hbm_blocks
                         for sid in sessions}
    assert results["heap"] == results["scan"]


def test_heap_reseeds_after_protection_ttl_lapses():
    """Regression (ISSUE 4): a session protected at preload time whose
    every subsequent refresh happened while still protected used to
    leave only stale heap entries behind — heap-mode eviction then
    never found it again even though it was evictable. The eviction
    pass must re-seed such sessions."""
    clock = FakeClock(0.0)
    mon = mon_with_playback(clock, {"a": (0.0, 1.0)})
    kv, _ = mk(capacity=100, monitor=mon, clock=clock)
    add_session(kv, "a", 8)
    kv.evict(0, 0.0)                    # seeds the heap with a
    kv.protect("a", 0.0)                # TTL protection (preload path)
    # refresh while protected: evictable==0, so nothing is pushed and
    # the pop below leaves no live entry for a
    kv.refresh_session("a", 1.0)
    assert kv.evict(2, 1.0) == 0        # protected: correctly spared
    clock.t = kv.protect_ttl_s + 1.0
    freed = kv.evict(2, clock.t)        # TTL lapsed: must find a again
    assert freed == 2
    assert kv.session("a").hbm_blocks == 6


@settings(max_examples=100, deadline=None)
@given(
    blocks=st.lists(st.integers(1, 20), min_size=2, max_size=15),
    need=st.integers(1, 100),
)
def test_eviction_accounting_invariants(blocks, need):
    clock = FakeClock(0.0)
    sessions = {f"s{i}": (float(i), 1.0) for i in range(len(blocks))}
    mon = mon_with_playback(clock, sessions)
    kv, _ = mk(capacity=sum(blocks), monitor=mon, clock=clock)
    for i, b in enumerate(blocks):
        add_session(kv, f"s{i}", b)
    before = kv.used_blocks
    freed = kv.evict(need, 0.0)
    assert freed == min(need, before)             # frees exactly what exists
    assert kv.used_blocks == before - freed
    for s in kv.sessions.values():
        assert 0 <= s.hbm_blocks <= s.total_blocks


# ---------------------------------------------------------------- preload
def test_preload_admitted_when_window_hides_transfer():
    clock = FakeClock(0.0)
    mon = mon_with_playback(clock, {"a": (0.0, 1.0)})
    kv, _ = mk(capacity=100, monitor=mon, clock=clock)
    s = add_session(kv, "a", 20)
    s.hbm_blocks = 0                              # fully offloaded
    pre = Preloader(kv, mon, speech_prior_s=5.0)
    mon.on_speech_start("a", expected_dur_s=5.0)
    t = pre.on_speech_start("a", 0.0)
    assert t is not None and pre.stats.admitted == 1
    # turn arrives after the transfer completed -> warm hit, zero stall
    clock.t = t.done + 0.1
    assert pre.on_turn_ready("a", clock.t) == 0.0
    assert pre.stats.hits == 1


def test_preload_skipped_when_window_too_short():
    clock = FakeClock(0.0)
    mon = mon_with_playback(clock, {"a": (0.0, 1.0)})
    kv, _ = mk(capacity=10**6, monitor=mon, clock=clock)
    s = add_session(kv, "a", 500000)              # huge KV, slow transfer
    s.hbm_blocks = 0
    pre = Preloader(kv, mon, speech_prior_s=0.01)
    mon.on_speech_start("a", expected_dur_s=0.01)
    t = pre.on_speech_start("a", 0.0)
    assert t is None and pre.stats.skipped == 1
    # sync fallback pays the on-path stall
    stall = pre.on_turn_ready("a", 1.0)
    assert stall > 0
    assert pre.stats.sync_fallbacks == 1


def test_preload_cancel_falls_back_to_sync():
    clock = FakeClock(0.0)
    mon = mon_with_playback(clock, {"a": (0.0, 1.0)})
    kv, _ = mk(capacity=100, monitor=mon, clock=clock)
    s = add_session(kv, "a", 20)
    s.hbm_blocks = 0
    pre = Preloader(kv, mon, speech_prior_s=10.0)
    mon.on_speech_start("a", expected_dur_s=10.0)
    t = pre.on_speech_start("a", 0.0)
    assert t is not None
    pre.cancel("a", 0.5)
    assert pre.stats.cancelled == 1
    assert kv.session("a").hbm_blocks == 0        # accounting rolled back
    stall = pre.on_turn_ready("a", 1.0)
    assert stall > 0                              # sync reload on-path


def test_transfer_channel_serializes():
    kv, clock = mk(capacity=1000)
    add_session(kv, "a", 100).hbm_blocks = 0
    add_session(kv, "b", 100).hbm_blocks = 0
    t1 = kv.reload("a", 0.0, background=True)
    t2 = kv.reload("b", 0.0, background=False)
    assert t2.start >= t1.done                    # PCIe contention modelled

"""Real-model engine integration: LiveServe scheduling over actual JAX
decode. The correctness contract (paper §5.2 / DESIGN §3): scheduling
policy affects WHEN tokens appear, never WHICH tokens."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.monitor import RuntimeMonitor
from repro.core.scheduler import SchedulerConfig, UrgencyScheduler
from repro.models import decode_step, forward, init_cache, init_params, \
    prefill
from repro.serving.engine import RealtimeLLMEngine


@pytest.fixture(scope="module")
def tiny():
    cfg = reduced(get_config("qwen3-4b"), layers=2, d_model=64, vocab=331)
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _greedy_reference(cfg, params, prompt, n):
    """Plain single-sequence greedy decode."""
    cache = init_cache(cfg, 1, 128)
    logits, cache = prefill(cfg, params, jnp.asarray(prompt)[None, :],
                            cache)
    toks = [int(jnp.argmax(logits[0]))]
    for _ in range(n - 1):
        lg, cache = decode_step(cfg, params,
                                jnp.asarray([toks[-1]], jnp.int32), cache)
        toks.append(int(jnp.argmax(lg[0])))
    return toks


def test_engine_matches_greedy_reference(tiny):
    cfg, params = tiny
    rng = np.random.default_rng(0)
    prompts = {f"s{i}": rng.integers(0, cfg.vocab_size, size=ln)
               for i, ln in enumerate((7, 11, 5))}
    eng = RealtimeLLMEngine(cfg, params, slots=4, capacity=128)
    for sid, p in prompts.items():
        eng.add_session(sid, p, max_new_tokens=10)
    out = eng.run_to_completion()
    for sid, p in prompts.items():
        want = _greedy_reference(cfg, params, p, 10)
        assert out[sid] == want, sid


def test_scheduling_changes_timing_not_tokens(tiny):
    """A pacing scheduler that holds sessions produces identical tokens."""
    cfg, params = tiny
    rng = np.random.default_rng(1)
    prompts = {f"s{i}": rng.integers(0, cfg.vocab_size, size=6)
               for i in range(3)}

    class EveryOther(UrgencyScheduler):
        """Adversarial policy: admits a rotating single session."""
        def __init__(self, monitor):
            super().__init__(SchedulerConfig(), monitor, stage="t")
            self.i = 0

        def schedule(self, ready, budget, now):
            self.i += 1
            d = super().schedule(ready, budget, now)
            keep = [d.batch[self.i % max(1, len(d.batch))]] \
                if d.batch else []
            d.batch = keep
            d.chunks = {r.req_id: 1 for r in keep}
            return d

    eng = RealtimeLLMEngine(cfg, params, slots=4, capacity=128)
    eng.scheduler = EveryOther(eng.monitor)
    for sid, p in prompts.items():
        eng.add_session(sid, p, max_new_tokens=8)
    out = eng.run_to_completion(max_rounds=200)
    for sid, p in prompts.items():
        assert out[sid] == _greedy_reference(cfg, params, p, 8), sid


def test_turn_commit_releases_working_blocks(tiny):
    """Working blocks become committed session KV on turn end — leaving
    them allocated too would double-count and starve admission."""
    cfg, params = tiny
    rng = np.random.default_rng(7)
    eng = RealtimeLLMEngine(cfg, params, slots=2, capacity=128)
    eng.add_session("a", rng.integers(0, cfg.vocab_size, size=7), 5)
    eng.run_to_completion()
    assert eng.kv.working_blocks == 0
    assert eng.kv.session("a").total_blocks == eng.kv.blocks_of(12)
    eng.add_session("b", rng.integers(0, cfg.vocab_size, size=7), 50)
    eng.step()
    eng.abort("b")
    assert eng.kv.working_blocks == 0


def test_abort_frees_slot_for_new_session(tiny):
    cfg, params = tiny
    rng = np.random.default_rng(2)
    eng = RealtimeLLMEngine(cfg, params, slots=2, capacity=128)
    eng.add_session("a", rng.integers(0, cfg.vocab_size, size=5), 50)
    eng.add_session("b", rng.integers(0, cfg.vocab_size, size=5), 6)
    for _ in range(3):
        eng.step()
    eng.abort("a")                       # barge-in on a
    assert eng.free_slot() is not None
    p3 = rng.integers(0, cfg.vocab_size, size=4)
    eng.add_session("c", p3, 6)
    out = eng.run_to_completion(max_rounds=100)
    assert out["c"] == _greedy_reference(cfg, params, p3, 6)
    # aborted session's committed KV is tracked by the manager
    assert eng.kv.session("a").total_blocks > 0

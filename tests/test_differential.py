"""Differential sim-vs-real property harness (ISSUE 3 satellite).

The same seeded workload trace is replayed through both planes:

- **sim**  — ``serving/simulator.py``: the LiveServe control plane on a
  virtual clock with cost-model stage timings;
- **real** — ``PagedRealtimeEngine`` driven by the deterministic
  virtual-time ``ReplayGateway`` (``gateway/replay.py``), running the
  same Algorithm 1 scheduler, KV manager, and preloader over real paged
  JAX state.

Wall-clock latencies differ by construction; *scheduling-visible*
invariants must not:

- the shared metrics schema is identical (``summary()`` keys);
- per-session turn completion order is the turn order, in both planes;
- every turn either completes or is barged exactly as the trace says,
  and only after producing first audio;
- the playback-frontier cap is never exceeded by more than one token
  of audio (chunk granularity);
- every eviction victim agrees with the sim's next-use policy (Eq. 4):
  victims' next-use estimates dominate every spared candidate's — the
  oracle recomputes fresh estimates at decision time (``index_mode=
  'scan'`` in both planes so the lazily-refreshed heap isn't part of
  the contract under test).

The hypothesis property runs when hypothesis is installed; a
27-example deterministic sweep always runs, so the differential
coverage never silently disappears with the optional dep.
"""
import jax
import pytest
from hypothesis_compat import given, settings, st

from repro.configs import get_config, reduced
from repro.models import init_params
from repro.serving.costmodel import PIPELINES
from repro.serving.gateway.replay import ReplayConfig, run_replay
from repro.serving.metrics import Metrics
from repro.serving.paged_engine import PagedRealtimeEngine
from repro.serving.simulator import Simulation
from repro.serving.workload import WorkloadConfig

APT = 0.25               # audio seconds per output token (replay side)


@pytest.fixture(scope="module")
def tiny():
    cfg = reduced(get_config("qwen2-1.5b"), layers=2, d_model=64,
                  vocab=331)
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


# ======================================================================
# the next-use eviction oracle
# ======================================================================
def install_eviction_oracle(kv):
    """Wrap ``kv.evict`` so every eviction pass is checked against a
    freshly-computed Eq. 4 ranking: each victim's next-use estimate must
    dominate (>=, with fp slack) every candidate that was spared.
    Returns the violations list (empty == policy agreement)."""
    violations = []
    orig_evict = kv.evict

    def evict(need_blocks, now):
        cands = {}
        for sid, s in kv.sessions.items():
            if s.evictable(now) <= 0:
                continue
            if kv.monitor is not None and kv.monitor.immediate_reuse(sid):
                continue
            cands[sid] = kv.next_use_estimate(sid, now)
        victims = []
        orig_es = kv._evict_session

        def spy(sid, want, now2):
            victims.append(sid)
            return orig_es(sid, want, now2)

        kv._evict_session = spy
        try:
            freed = orig_evict(need_blocks, now)
        finally:
            kv._evict_session = orig_es
        vset = set(victims)
        for v in vset:
            if v not in cands:
                violations.append(("illegal-victim", now, v, dict(cands)))
        spared = [est for sid, est in cands.items() if sid not in vset]
        if vset and spared:
            lo = min(cands[v] for v in vset if v in cands)
            if lo + 1e-9 < max(spared):
                violations.append(("ranking", now, victims, dict(cands)))
        return freed

    kv.evict = evict
    return violations


# ======================================================================
# the two planes
# ======================================================================
def _workload(seed, kind, sessions, barge):
    return WorkloadConfig(kind=kind, num_sessions=sessions, seed=seed,
                          p_barge_in=barge, arrival="poisson",
                          rate_rps=4.0)


def _run_real(tiny_model, wl, seed, *, num_pages=None):
    cfg, params = tiny_model

    def factory(clock):
        eng = PagedRealtimeEngine(cfg, params, slots=2, page_size=4,
                                  pages_per_seq=8, num_pages=num_pages,
                                  clock=clock)
        eng.kv.index_mode = "scan"      # fresh Eq. 4 ranking per pass
        return eng

    clockbox = {}

    def wrapped(clock):
        eng = factory(clock)
        clockbox["violations"] = install_eviction_oracle(eng.kv)
        return eng

    metrics, gw = run_replay(wrapped, wl,
                             ReplayConfig(audio_per_token_s=APT,
                                          frontier_cap_s=3.0),
                             seed=seed)
    gw.eng.check_invariants()
    return metrics, gw, clockbox["violations"]


def _run_sim(wl, seed, *, kv_gb=6.0):
    # the sim models paper-scale costs: capacity must hold the largest
    # single prompt of the trace or its stage engine starves (no paging
    # of a single request's working set) — 6 GB covers every kind;
    # the eviction-pressure test below shrinks it deliberately
    pipe = PIPELINES["qwen3-omni-like"](kv_capacity_gb=kv_gb)
    sim = Simulation(pipe, wl, policy="liveserve", eviction_index="scan",
                     seed=seed)
    violations = []
    for kv in sim.kvs.values():
        violations += [install_eviction_oracle(kv)]
    metrics = sim.run(until=3600.0)
    return metrics, sim, [v for lst in violations for v in lst]


# ======================================================================
# invariants
# ======================================================================
def _completion_order(metrics: Metrics):
    per = {}
    for t in sorted(metrics.turns, key=lambda t: (t.finish_time,
                                                  t.turn_index)):
        if t.finish_time:
            per.setdefault(t.session_id, []).append(t.turn_index)
    return per


def _check_plane(metrics: Metrics, *, require_outcome: bool):
    order = _completion_order(metrics)
    for sid, idxs in order.items():
        assert idxs == sorted(idxs), \
            f"{sid}: turns completed out of order: {idxs}"
    if require_outcome:
        for t in metrics.turns:
            assert t.completed or t.barged, \
                f"{t.session_id}/{t.turn_index} lost (neither completed " \
                "nor barged)"
            assert t.ttfp is not None, \
                f"{t.session_id}/{t.turn_index} never produced audio"
    return order


def _trace_barges(wl, max_turns):
    from repro.serving.workload import generate
    return {(s.session_id, ti)
            for s in generate(wl)
            for ti, turn in enumerate(s.turns[:max_turns])
            if turn.barge_in}


def check_differential(tiny_model, seed, kind, sessions, barge):
    wl = _workload(seed, kind, sessions, barge)
    real_m, gw, real_viol = _run_real(tiny_model, wl, seed)
    sim_m, sim, sim_viol = _run_sim(wl, seed)

    # shared schema: sim-vs-real comparison is a dict diff by
    # construction
    assert set(real_m.summary()) == set(sim_m.summary())

    # per-plane invariants
    real_order = _check_plane(real_m, require_outcome=True)
    _check_plane(sim_m, require_outcome=False)

    # the real plane served the whole clamped trace
    max_turns = ReplayConfig().max_turns
    want_keys = {(s.session_id, ti) for s in sim.sessions.values()
                 for ti in range(min(len(s.turns), max_turns))}
    real_keys = {(t.session_id, t.turn_index) for t in real_m.turns}
    assert real_keys == want_keys
    assert real_m.completed_sessions == sessions

    # barge outcomes are trace-determined and must agree across planes
    barges = _trace_barges(wl, max_turns)
    real_barged = {(t.session_id, t.turn_index)
                   for t in real_m.turns if t.barged}
    sim_barged = {(t.session_id, t.turn_index)
                  for t in sim_m.turns
                  if t.barged and t.turn_index < max_turns}
    assert sim_m.completed_sessions == sessions   # sim didn't starve
    assert real_barged == barges, (real_barged, barges)
    assert sim_barged == barges, (sim_barged, barges)

    # frontier cap: never exceeded beyond one audio chunk of granularity
    assert gw.max_over_frontier_s <= APT + 1e-6

    # eviction victims agree with the next-use policy in BOTH planes
    assert not real_viol, real_viol
    assert not sim_viol, sim_viol

    # on-path vs off-path reload accounting (ISSUE 4): both planes
    # report the split through the one shared schema, sanely bounded...
    for m in (real_m, sim_m):
        s = m.summary()
        assert s["mean_reload_stall"] >= 0.0
        assert s["mean_reload_off_path"] >= 0.0
        assert 0.0 <= s["reload_overlap_frac"] <= 1.0
    # ...and on the real plane the gateway's TurnRecords carry exactly
    # the stalls the engine's own turn stats charged (record_admit is
    # the only coupling — a drift here would let the serving metrics
    # disagree with the data plane about what was on the critical path)
    eng_on = sum(st["reload_stall_s"]
                 for sess in gw.eng.sessions.values()
                 for st in sess.turn_stats)
    eng_off = sum(st["reload_off_path_s"]
                  for sess in gw.eng.sessions.values()
                  for st in sess.turn_stats)
    rec_on = sum(t.reload_stall_s for t in real_m.turns)
    rec_off = sum(t.reload_off_path_s for t in real_m.turns)
    assert rec_on == pytest.approx(eng_on), (rec_on, eng_on)
    assert rec_off == pytest.approx(eng_off), (rec_off, eng_off)
    return real_order


# 27 deterministic examples — runs with or without hypothesis, so the
# acceptance bar (>= 25 differential examples) never depends on an
# optional dep being installed
EXAMPLES = [(seed, kind, sessions, barge)
            for seed in range(3)
            for kind in ("interactive", "sharegpt", "mixed")
            for sessions, barge in ((2, 0.0), (3, 0.5), (4, 0.8))]


@pytest.mark.slow
@pytest.mark.parametrize("seed,kind,sessions,barge", EXAMPLES)
def test_sim_vs_real_differential(tiny, seed, kind, sessions, barge):
    check_differential(tiny, seed, kind, sessions, barge)


# one smoke example stays in the fast lane so a broken differential
# harness is caught even when -m "not slow" deselects the sweep
def test_sim_vs_real_differential_smoke(tiny):
    check_differential(tiny, 0, "interactive", 3, 0.5)


@pytest.mark.slow
@given(seed=st.integers(0, 2 ** 16), kind=st.sampled_from(
    ["interactive", "sharegpt", "mixed"]),
    sessions=st.integers(2, 5), barge=st.floats(0.0, 0.8))
@settings(max_examples=25, deadline=None)
def test_sim_vs_real_differential_property(tiny, seed, kind, sessions,
                                           barge):
    check_differential(tiny, seed, kind, sessions, barge)


# ======================================================================
# eviction-pressure example: victims must be exercised, not just vacuous
# ======================================================================
def test_differential_exercises_evictions(tiny):
    """A tight pool + multi-turn sessions force real physical evictions;
    the oracle must see them and agree with the next-use ranking."""
    wl = _workload(7, "interactive", 5, 0.4)
    real_m, gw, viol = _run_real(tiny, wl, 7, num_pages=14)
    assert gw.eng.kv.evicted_blocks > 0, \
        "pool was never under pressure — tighten num_pages"
    assert not viol, viol
    _check_plane(real_m, require_outcome=True)
    gw.eng.check_invariants()

    # the sim under the same trace with a deliberately small pool: some
    # sessions may starve (the cost-model engine does not page a single
    # request's working set), but every eviction it does take must obey
    # the same ranking
    sim_m, sim, sim_viol = _run_sim(wl, 7, kv_gb=0.5)
    assert any(kv.evicted_blocks > 0 for kv in sim.kvs.values()), \
        "sim pool was never under pressure — shrink kv_capacity_gb"
    assert not sim_viol, sim_viol

"""Replica fleet: router policy, migration protocol, straggler
mitigation, and the multi-replica soak (ISSUE 6).

Three layers:

- **coordinator units** — two or three engines on a driver-owned
  clock, migrations driven state-by-state through the
  ``MigrationCoordinator``: natural drain -> handoff -> landing,
  barge-in cancel, hangup cancel, demanded completion (with its
  on-path reclassification), destination-pressure cancel, and the
  token-exactness of a decode that resumes on the destination.
- **router units** — pressure routing, ring-order destinations, the
  last-healthy-replica drain refusal, rebalance-margin migrations, and
  the hardened ``StragglerMitigator`` (alternating slow/fast still
  accumulates; consecutive good rounds forgive; ``forget`` wipes).
- **soaks** — 24+ sessions over 3 replicas under barge storms with
  forced straggler injection (live, real mitigator) and tight-pool
  pressure with mid-migration hangups (virtual-time twin): page
  conservation per replica, no leaks, the drained replica ends empty.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.distributed.fault_tolerance import StragglerMitigator
from repro.models import init_params
from repro.serving.fleet.harness import build_fleet_gateway, \
    run_fleet_workload
from repro.serving.fleet.migration import (CANCELLED, DONE, DRAINING,
                                           LANDING, NETWORK,
                                           MigrationCoordinator)
from repro.serving.fleet.replay import run_fleet_replay
from repro.serving.fleet.replica_set import ReplicaSet
from repro.serving.fleet.router import SessionRouter
from repro.serving.gateway.replay import ReplayClock, ReplayConfig
from repro.serving.metrics import Metrics
from repro.serving.paged_engine import PagedRealtimeEngine
from repro.serving.workload import WorkloadConfig


@pytest.fixture(scope="module")
def tiny():
    cfg = reduced(get_config("qwen2-1.5b"), layers=2, d_model=64,
                  vocab=331)
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


# ======================================================================
# coordinator units: a hand-held two-replica fleet
# ======================================================================
def _fleet(tiny_model, n=2, *, num_pages=(32, 32),
           interconnect_gb_s=50.0):
    cfg, params = tiny_model
    clock = ReplayClock()
    engines = [PagedRealtimeEngine(cfg, params, slots=2, page_size=4,
                                   pages_per_seq=8, num_pages=num_pages[i],
                                   clock=clock)
               for i in range(n)]
    rs = ReplicaSet(engines, interconnect_gb_s=interconnect_gb_s)
    router = SessionRouter(rs)
    metrics = Metrics()
    return rs, router, MigrationCoordinator(rs, router, metrics), metrics


def _seed_session(rs, router, sid, *, prompt_len=9, n_tokens=4, seed=0):
    """Route ``sid``, run one full turn on its replica, leave it idle
    with committed KV. Returns (replica_index, produced tokens)."""
    i = router.route(sid)
    rng = np.random.default_rng(seed)
    prompt = rng.integers(0, 331, size=prompt_len)
    eng = rs[i]
    eng.add_session(sid, prompt, max_new_tokens=n_tokens)
    toks = eng.run_to_completion()[sid]
    eng.check_invariants()
    assert eng.sessions[sid].kv_len > 0
    return i, toks


def _drain_all(eng, clock):
    while eng.drain_transfers(4):
        clock.tick(1e-4)


def test_migration_natural_lifecycle(tiny):
    """DRAINING -> NETWORK -> LANDING -> DONE, with the session record
    transplanted wholesale and the source scrubbed at handoff."""
    rs, router, mig, m = _fleet(tiny)
    src, _ = _seed_session(rs, router, "a")
    clock = rs.clock
    pages_before = rs[src].pool.resident_pages("a")

    plan = mig.start("a", src, 1 - src, clock.now())
    assert plan.state == DRAINING
    assert plan.pages == pages_before > 0
    # pages marked offloading: accounting freed, physically resident
    assert rs[src].kv.sessions["a"].hbm_blocks == 0
    rs[src].check_invariants()

    _drain_all(rs[src], clock)
    mig.pump(clock.now())
    assert plan.state == NETWORK
    assert router.placement["a"] == 1 - src        # flipped at handoff
    assert "a" not in rs[src].sessions             # source scrubbed
    assert rs[src].pool.free_pages == rs[src].num_pages
    dst = rs[1 - src]
    assert dst.sessions["a"].kv_len > 0
    assert dst.kv.sessions["a"].hbm_blocks == 0    # host-resident
    for e in rs:
        e.check_invariants()
    assert m.migrations == 1 and m.migration_bytes > 0

    clock.advance_to(plan.net_done + 1e-6)
    mig.pump(clock.now())
    assert plan.state == LANDING
    # the landing page-in is an ordinary speech-time preload
    assert dst.transfer.pending_reload_pages("a") > 0 \
        or dst.kv.sessions["a"].hbm_blocks > 0
    _drain_all(dst, clock)
    assert dst.kv.sessions["a"].hbm_blocks == plan.pages
    dst.check_invariants()


def test_migration_resumes_decode_token_exact(tiny):
    """The destination continues the conversation bit-exactly: same
    tokens a never-migrated engine produces for turn 2."""
    cfg, params = tiny
    rs, router, mig, _ = _fleet(tiny)
    src, _ = _seed_session(rs, router, "a")
    clock = rs.clock
    rng = np.random.default_rng(42)
    prompt2 = rng.integers(0, 331, size=5)

    # reference: same two turns on one engine, no migration
    ref = PagedRealtimeEngine(cfg, params, slots=2, page_size=4,
                              pages_per_seq=8, num_pages=32,
                              clock=ReplayClock())
    rngr = np.random.default_rng(0)
    ref.add_session("a", rngr.integers(0, 331, size=9), max_new_tokens=4)
    ref.run_to_completion()
    ref.start_turn("a", prompt2, max_new_tokens=4)
    want = ref.run_to_completion()["a"]

    plan = mig.start("a", src, 1 - src, clock.now())
    _drain_all(rs[src], clock)
    mig.pump(clock.now())
    clock.advance_to(plan.net_done + 1e-6)
    mig.pump(clock.now())
    dst = rs[1 - src]
    _drain_all(dst, clock)
    dst.start_turn("a", prompt2, max_new_tokens=4)
    got = dst.run_to_completion()["a"]
    assert got == want
    dst.check_invariants()


def test_migration_barge_cancel_zero_copy(tiny):
    """Barge-in mid-drain: queued chunks drop, pages return resident,
    and the interrupting turn runs on the source immediately."""
    rs, router, mig, m = _fleet(tiny)
    src, _ = _seed_session(rs, router, "a")
    clock = rs.clock
    eng = rs[src]
    moved0 = eng.transfer.stats.migration_pages_moved

    plan = mig.start("a", src, 1 - src, clock.now())
    mig.on_barge("a", clock.now())
    assert plan.state == CANCELLED and plan.reason == "barge"
    assert not mig.plans and mig.cancelled() == [plan]
    # zero-copy: nothing moved, everything resident again
    assert eng.transfer.stats.migration_pages_moved == moved0
    assert eng.kv.sessions["a"].hbm_blocks == plan.pages
    assert router.placement["a"] == src
    eng.check_invariants()
    assert m.migrations == 0 and m.migration_bytes == 0.0

    rng = np.random.default_rng(3)
    eng.start_turn("a", rng.integers(0, 331, size=4), max_new_tokens=3)
    assert len(eng.run_to_completion()["a"]) == 3
    eng.check_invariants()


def test_migration_hangup_cancel_leaks_nothing(tiny):
    """Hangup mid-drain cancels the plan; the ordinary hangup path then
    frees every page and host copy."""
    rs, router, mig, _ = _fleet(tiny)
    src, _ = _seed_session(rs, router, "a")
    plan = mig.start("a", src, 1 - src, rs.clock.now())
    rs[src].drain_transfers(1)                 # a chunk already moved
    mig.on_hangup("a", rs.clock.now())
    assert plan.state == CANCELLED and plan.reason == "hangup"
    rs[src].end_session("a")
    router.on_session_end("a")
    for e in rs:
        e.flush_transfers()
        e.check_invariants()
        assert e.pool.free_pages == e.num_pages


def test_migration_hangup_mid_network_completes(tiny):
    """Post-handoff hangup is not a cancel: the bytes moved, the
    session is the destination's, and its hangup there frees all."""
    rs, router, mig, _ = _fleet(tiny, interconnect_gb_s=1e-4)
    src, _ = _seed_session(rs, router, "a")
    clock = rs.clock
    plan = mig.start("a", src, 1 - src, clock.now())
    _drain_all(rs[src], clock)
    mig.pump(clock.now())
    assert plan.state == NETWORK and clock.now() < plan.net_done
    mig.on_hangup("a", clock.now())
    assert plan.state == DONE and not mig.plans
    dst = rs[1 - src]
    dst.end_session("a")
    router.on_session_end("a")
    for e in rs:
        e.flush_transfers()
        e.check_invariants()
        assert e.pool.free_pages == e.num_pages


def test_migration_demand_complete_charges_on_path(tiny):
    """A turn request mid-drain forces the migration through, charging
    the drain residual + network window on-path via the clock — the
    sync-reload convention."""
    rs, router, mig, m = _fleet(tiny)
    src, _ = _seed_session(rs, router, "a")
    clock = rs.clock
    t0 = clock.now()
    plan = mig.start("a", src, 1 - src, t0)
    assert rs[src].migrate_out_pending("a") == plan.pages
    mig.demand_complete("a", clock.now())
    assert plan.state == LANDING
    assert clock.now() > t0                      # stall charged
    assert m.migration_on_path_s > 0.0
    assert m.migration_on_path_s + m.migration_off_path_s == \
        pytest.approx(clock.now() - t0 + (plan.net_done - plan.net_done))
    dst = rs[1 - src]
    dst.start_turn("a", np.arange(3, dtype=np.int64), max_new_tokens=2)
    assert len(dst.run_to_completion()["a"]) == 2
    dst.check_invariants()
    assert mig.plans                             # DONE needs admission
    assert plan.state == LANDING


def test_migration_dst_pressure_cancels(tiny):
    """The destination must have room at handoff; otherwise the plan
    cancels and the session stays on the source, its drained pages
    host-resident until the next turn reloads them."""
    rs, router, mig, m = _fleet(tiny, num_pages=(32, 2))
    src, _ = _seed_session(rs, router, "a")    # pressure-routes to 0
    assert src == 0
    clock = rs.clock
    plan = mig.start("a", 0, 1, clock.now())
    assert plan.pages > 2                      # cannot fit on replica 1
    _drain_all(rs[0], clock)
    mig.pump(clock.now())
    assert plan.state == CANCELLED and plan.reason == "dst_pressure"
    assert router.placement["a"] == 0
    assert m.migrations == 0
    # fully host-resident on the source; the next turn reloads
    assert rs[0].kv.sessions["a"].hbm_blocks == 0
    rng = np.random.default_rng(5)
    rs[0].start_turn("a", rng.integers(0, 331, size=4), max_new_tokens=3)
    assert len(rs[0].run_to_completion()["a"]) == 3
    for e in rs:
        e.check_invariants()


# ======================================================================
# router units
# ======================================================================
def test_router_routes_by_pressure(tiny):
    rs, router, _, _ = _fleet(tiny)
    assert [router.route(f"s{i}") for i in range(4)] == [0, 1, 0, 1]
    router.on_session_end("s0")
    router.on_session_end("s2")
    assert router.route("s4") == 0             # lightest replica


def test_router_never_drains_last_replica(tiny):
    rs, router, _, _ = _fleet(tiny)
    router.drain(0)
    assert router.draining == {0}
    router.drain(1)                            # refused: someone serves
    assert router.draining == {0}
    assert router.route("a") == 1
    router.recover(0)
    assert not router.draining
    assert [d[0] for d in router.decisions] == ["drain", "route",
                                                "recover"]


def test_router_ring_next_skips_draining(tiny):
    cfg, params = tiny
    clock = ReplayClock()
    engines = [PagedRealtimeEngine(cfg, params, slots=2, page_size=4,
                                   pages_per_seq=8, num_pages=16,
                                   clock=clock) for _ in range(3)]
    router = SessionRouter(ReplicaSet(engines))
    router.drain(1)
    assert router.ring_next(0) == 2
    assert router.ring_next(1) == 2
    assert router.ring_next(2) == 0


def test_router_rebalance_margin(tiny):
    rs, router, _, _ = _fleet(tiny)
    router.rebalance_margin = 2
    for i in range(4):
        router.route(f"s{i}")                  # 2 / 2
    assert router.maybe_migrate("s0") is None  # balanced
    router.on_session_end("s1")
    router.on_session_end("s3")                # 2 / 0
    assert router.maybe_migrate("s0") == 1
    router.rebalance_margin = None
    assert router.maybe_migrate("s2") is None  # live-only knob off


def test_router_straggler_drain_and_recovery(tiny):
    """Deadline blowouts drain the replica through the mitigator; its
    consecutive-good-round forgiveness lifts the drain again."""
    rs, router, _, _ = _fleet(tiny)
    router.mitigator = StragglerMitigator(deadline_factor=2.0,
                                          min_samples=4,
                                          recover_after=2)
    router.strike_threshold = 2
    for _ in range(4):
        router.observe_round(1, 0.01)          # healthy baseline
    router.observe_round(0, 0.5)
    assert not router.draining                 # one strike is noise
    router.observe_round(0, 0.5)
    assert router.draining == {0}
    assert ("drain", 0) in router.decisions
    # recovery: two consecutive good rounds forgive, the drain lifts
    router.observe_round(0, 0.01)
    assert router.draining == {0}
    router.observe_round(0, 0.01)
    assert not router.draining
    assert ("recover", 0) in router.decisions


def test_straggler_mitigator_alternating_still_accumulates():
    sm = StragglerMitigator(deadline_factor=2.0, min_samples=4,
                            recover_after=3)
    for _ in range(6):
        sm.observe("w0", 1.0)
    # slow/fast alternation: single good rounds never erase the record
    for _ in range(3):
        sm.observe("w1", 10.0)
        sm.observe("w1", 1.0)
    assert sm.should_evict("w1", 3)


def test_straggler_mitigator_recovers_and_forgets():
    sm = StragglerMitigator(deadline_factor=2.0, min_samples=4,
                            recover_after=2)
    for _ in range(6):
        sm.observe("w0", 1.0)
    sm.observe("w1", 10.0)
    sm.observe("w1", 10.0)
    assert "w1" in sm.strikes
    sm.observe("w1", 1.0)
    assert "w1" in sm.strikes                  # streak of 1: not yet
    sm.observe("w1", 1.0)
    assert "w1" not in sm.strikes              # clean slate
    sm.observe("w2", 10.0)
    sm.forget("w2")
    assert "w2" not in sm.strikes and "w2" not in sm.good_streak


# ======================================================================
# soaks
# ======================================================================
def _assert_fleet_clean(gw):
    for e in gw.replicas:
        e.flush_transfers()
        e.check_invariants()
        assert e.pool.free_pages == e.num_pages, "leaked pages"
        assert all(s.ended for s in e.sessions.values())
        assert not any(e.slot_state.values())
    assert not gw.migrator.plans
    assert not gw.router.placement


@pytest.mark.slow
def test_fleet_soak_live_straggler_barge_storm(tiny):
    """24 sessions / 3 replicas under a barge storm, with replica 0
    forced to blow its round deadline (injected lag feeding a real
    mitigator): it must be drained, its sessions migrated off, and
    every replica must end clean."""
    gw = build_fleet_gateway(replicas=3, scale=40.0, slots=4,
                             num_pages=96, model=tiny,
                             audio_per_token_s=0.25,
                             mitigator=StragglerMitigator(
                                 deadline_factor=2.0, min_samples=6),
                             strike_threshold=3)
    gw.round_lag_s[0] = 5.0                    # the forced straggler
    m, gw = run_fleet_workload(kind="mixed", sessions=24, barge_in=0.6,
                               seed=2, scale=40.0, max_turns=3,
                               max_prompt=8, max_response=8,
                               timeout_s=300.0, gateway=gw)
    assert 0 in gw.router._straggler_drained or 0 in gw.router.draining
    assert ("drain", 0) in gw.router.decisions
    assert m.migrations > 0
    assert all(d[2] == 0 for d in gw.router.migration_decisions())
    assert m.completed_sessions == 24
    assert len(m.replica_occupancy) == 3
    assert m.summary()["migration_off_path"] >= 0.0
    _assert_fleet_clean(gw)


@pytest.mark.slow
def test_fleet_soak_twin_pressure_and_hangups(tiny):
    """Virtual-time soak under tight pools: 27 sessions / 3 replicas
    with barges and a mid-trace drain. dst-pressure cancels are
    allowed; leaks are not."""
    cfg, params = tiny

    def factory(clock):
        return PagedRealtimeEngine(cfg, params, slots=2, page_size=4,
                                   pages_per_seq=12, num_pages=28,
                                   clock=clock)

    # rate 1 rps keeps the *pending-protected* working set under the
    # pool (pending turns are immune to Eq. 4 eviction — total
    # over-commit of protected pages would deadlock any replica, fleet
    # or not); idle sessions still pile up enough to force evictions
    wl = WorkloadConfig(kind="mixed", num_sessions=27, seed=5,
                        p_barge_in=0.7, arrival="poisson", rate_rps=1.0)
    m, gw = run_fleet_replay(factory, 3, wl,
                             ReplayConfig(max_turns=3),
                             seed=5, drain_after_routes=(0, 9))
    # routes after the drain avoid replica 0
    routed = [d[2] for d in gw.router.decisions if d[0] == "route"]
    assert 0 not in routed[9:]
    assert gw.router.migration_decisions()
    done, cancelled = gw.migrator.completed(), gw.migrator.cancelled()
    assert len(done) + len(cancelled) \
        == len(gw.router.migration_decisions())
    # the tight pools were genuinely under pressure
    assert any(e.kv.evicted_blocks > 0 for e in gw.replicas)
    assert m.completed_sessions == 27
    _assert_fleet_clean(gw)


def test_fleet_soak_twin_smoke(tiny):
    """Fast-lane miniature of the twin soak."""
    cfg, params = tiny

    def factory(clock):
        return PagedRealtimeEngine(cfg, params, slots=2, page_size=4,
                                   pages_per_seq=8, num_pages=24,
                                   clock=clock)

    wl = WorkloadConfig(kind="interactive", num_sessions=6, seed=0,
                        p_barge_in=0.5, arrival="poisson", rate_rps=4.0)
    m, gw = run_fleet_replay(factory, 3, wl, ReplayConfig(),
                             seed=0, drain_after_routes=(0, 6))
    assert m.completed_sessions == 6
    _assert_fleet_clean(gw)

"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step + prefill/decode on CPU; asserts shapes and no NaNs.

Also checks prefill+decode consistency against teacher-forcing forward.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_configs, reduced
from repro.models import (decode_step, forward, init_cache, init_params,
                          loss_fn, prefill)

ARCHS = [
    "whisper-tiny", "h2o-danube-1.8b", "qwen3-4b", "nemotron-4-340b",
    "qwen2-1.5b", "recurrentgemma-9b", "mamba2-1.3b", "deepseek-v2-236b",
    "phi3.5-moe-42b-a6.6b", "paligemma-3b",
]

B, S = 2, 24


def _batch(cfg, key):
    ks = jax.random.split(key, 3)
    tokens = jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens,
             "labels": jnp.roll(tokens, -1, axis=1)}
    if cfg.frontend == "vision":
        batch["patches"] = jax.random.normal(
            ks[1], (B, cfg.frontend_len, cfg.d_model), jnp.float32)
        batch["prefix_len"] = jnp.full((B,), cfg.frontend_len, jnp.int32)
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            ks[2], (B, cfg.encoder.num_frames, cfg.d_model), jnp.float32)
    return batch


def test_all_archs_registered():
    assert sorted(ARCHS) == list_configs()


@pytest.mark.parametrize("arch", ARCHS)
def test_config_param_count_positive(arch):
    cfg = get_config(arch)
    n = cfg.num_params()
    na = cfg.num_active_params()
    assert n > 0 and 0 < na <= n
    # sanity: the headline sizes are roughly right (within 2x)
    expected = {"nemotron-4-340b": 340e9, "deepseek-v2-236b": 236e9,
                "phi3.5-moe-42b-a6.6b": 42e9, "qwen3-4b": 4e9,
                "qwen2-1.5b": 1.5e9, "h2o-danube-1.8b": 1.8e9,
                "mamba2-1.3b": 1.3e9, "recurrentgemma-9b": 9e9,
                "paligemma-3b": 2.6e9}
    if arch in expected:
        assert 0.5 < n / expected[arch] < 2.0, (arch, n)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_loss(arch):
    cfg = reduced(get_config(arch))
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    logits, aux = forward(cfg, params, batch["tokens"],
                          frontend_embeds=batch.get("patches"),
                          enc_frames=batch.get("frames"),
                          prefix_len=batch.get("prefix_len"))
    S_total = S + (cfg.frontend_len if cfg.frontend == "vision" else 0)
    assert logits.shape == (B, S_total, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    loss, metrics = loss_fn(cfg, params, batch)
    assert np.isfinite(float(loss))
    assert float(metrics["ce"]) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_no_nans(arch):
    cfg = reduced(get_config(arch))
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    g = jax.grad(lambda p: loss_fn(cfg, p, batch)[0])(params)
    leaves = jax.tree.leaves(g)
    assert leaves
    for leaf in leaves:
        assert np.isfinite(np.asarray(leaf, dtype=np.float32)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    """Greedy continuation from prefill must match teacher-forcing logits."""
    cfg = reduced(get_config(arch))
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    tokens = batch["tokens"]
    enc_frames = batch.get("frames")
    fe = batch.get("patches")
    capacity = S + 8
    cache = init_cache(cfg, B, capacity,
                       enc_frames=cfg.encoder.num_frames
                       if cfg.family == "encdec" else 0)
    # prefill on the first S-1 tokens, then decode token S-1
    last, cache = prefill(cfg, params, tokens[:, :S - 1], cache,
                          frontend_embeds=fe,
                          prefix_len=batch.get("prefix_len"),
                          enc_frames=enc_frames)
    dec_logits, cache = decode_step(cfg, params, tokens[:, S - 1], cache)
    full, _ = forward(cfg, params, tokens, frontend_embeds=fe,
                      enc_frames=enc_frames,
                      prefix_len=batch.get("prefix_len"))
    # last prefill logits == forward at index S-2; decode == forward at S-1
    np.testing.assert_allclose(np.asarray(last),
                               np.asarray(full[:, -2]), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full[:, -1]), rtol=2e-4, atol=2e-4)
    expect = S + (cfg.frontend_len if cfg.frontend == "vision" else 0)
    assert int(cache["len"][0]) == expect


@pytest.mark.parametrize("arch", ["h2o-danube-1.8b", "recurrentgemma-9b"])
def test_ring_buffer_windowed_decode(arch):
    """Decode past the window: ring wraps, mask stays exact."""
    cfg = reduced(get_config(arch))
    win = (cfg.sliding_window if cfg.sliding_window
           else cfg.rglru.local_window)
    params = init_params(cfg, jax.random.PRNGKey(0))
    T = win + 8
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, T), 0,
                                cfg.vocab_size)
    cache = init_cache(cfg, B, T)
    assert cache["kv_pos"].shape[1] == win  # ring capped at window
    last, cache = prefill(cfg, params, tokens[:, :4], cache)
    outs = []
    for t in range(4, T):
        lg, cache = decode_step(cfg, params, tokens[:, t], cache)
        outs.append(lg)
    full, _ = forward(cfg, params, tokens)
    np.testing.assert_allclose(np.asarray(outs[-1]),
                               np.asarray(full[:, -1]), rtol=5e-4, atol=5e-4)

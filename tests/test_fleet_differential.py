"""Fleet router differential: live gateway vs virtual-time twin
(ISSUE 6 satellite).

The same seeded multi-replica trace is replayed through both planes:

- **twin** — ``fleet/replay.py``: the fleet gateway on a driver-owned
  virtual clock, routing the whole trace up front and pumping migration
  plans between event delivery and rounds;
- **live** — ``fleet/gateway.py``: the asyncio fleet gateway under real
  in-process clients on a ``ScaledWallClock``.

Wall-clock latencies differ by construction; *router decisions* must
not. The comparison surface is the router's decision log:

- the route list is identical and identically ordered (connects happen
  in trace order in both planes — the asyncio clients connect before
  their first await);
- drain/recover entries are identical and identically ordered (the
  differential injects drains deterministically via
  ``drain_after_routes``; the straggler mitigator stays off because
  wall time is the one signal the twin cannot reproduce);
- migration decisions agree as a multiset and per-session as ordered
  lists (cross-session order is not comparable: two speech starts that
  are near-simultaneous on the wall clock may swap);
- on barge-free traces the migrate set is exactly predictable from the
  trace alone: every >=2-turn session round-robin-routed to the drained
  replica, destination = ring-next.

Migration *completions* are deliberately not compared: whether a barge
lands before or after handoff is timing, not policy, and the
cancellation rules (DESIGN.md §12) make both orders correct.

A 27-example deterministic sweep runs under ``-m slow``; one smoke
example stays in the fast lane.
"""
import jax
import pytest

from repro.configs import get_config, reduced
from repro.models import init_params
from repro.serving.fleet.harness import run_fleet_workload
from repro.serving.fleet.replay import run_fleet_replay
from repro.serving.gateway.replay import ReplayConfig
from repro.serving.paged_engine import PagedRealtimeEngine
from repro.serving.workload import WorkloadConfig, generate

REPLICAS = 3
NUM_PAGES = 128          # generous: dst_pressure cancels are a policy
                         # the unit tests force; here they would make
                         # completion timing-sensitive


@pytest.fixture(scope="module")
def tiny():
    cfg = reduced(get_config("qwen2-1.5b"), layers=2, d_model=64,
                  vocab=331)
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _workload(seed, kind, sessions, barge):
    return WorkloadConfig(kind=kind, num_sessions=sessions, seed=seed,
                          p_barge_in=barge, arrival="poisson",
                          rate_rps=2.0)


def _run_twin(tiny_model, wl, seed, sessions):
    cfg, params = tiny_model

    def factory(clock):
        return PagedRealtimeEngine(cfg, params, slots=2, page_size=8,
                                   pages_per_seq=8, num_pages=NUM_PAGES,
                                   clock=clock)

    return run_fleet_replay(
        factory, REPLICAS, wl,
        ReplayConfig(max_prompt=6, max_response=6), seed=seed,
        drain_after_routes=(0, sessions))


def _run_live(tiny_model, seed, kind, sessions, barge):
    return run_fleet_workload(
        kind=kind, sessions=sessions, barge_in=barge, seed=seed,
        scale=40.0, max_turns=2, max_prompt=6, max_response=6,
        timeout_s=180.0, replicas=REPLICAS, slots=2,
        num_pages=NUM_PAGES, audio_per_token_s=0.25,
        model=tiny_model, drain_after_routes=(0, sessions))


# ======================================================================
# decision-log views
# ======================================================================
def _routes(gw):
    return [d for d in gw.router.decisions if d[0] == "route"]


def _drains(gw):
    return [d for d in gw.router.decisions if d[0] in ("drain",
                                                       "recover")]


def _per_session_migrations(gw):
    per = {}
    for _, sid, src, dst in gw.router.migration_decisions():
        per.setdefault(sid, []).append((src, dst))
    return per


def check_fleet_differential(tiny_model, seed, kind, sessions, barge):
    wl = _workload(seed, kind, sessions, barge)
    twin_m, twin = _run_twin(tiny_model, wl, seed, sessions)
    live_m, live = _run_live(tiny_model, seed, kind, sessions, barge)

    # shared schema: twin-vs-live comparison is a dict diff
    assert set(twin_m.summary()) == set(live_m.summary())

    # routes: identical, identically ordered — and round-robin, since
    # every replica is pristine at connect time
    tr, lr = _routes(twin), _routes(live)
    assert tr == lr, (tr, lr)
    assert [r[2] for r in tr] == [i % REPLICAS for i in range(sessions)]

    # drains: deterministic injection fires at the same route count
    assert _drains(twin) == _drains(live)

    # migrations: multiset + per-session ordered lists
    assert sorted(twin.router.migration_decisions()) \
        == sorted(live.router.migration_decisions())
    assert _per_session_migrations(twin) == _per_session_migrations(live)

    # the migrate set is trace-predictable: every >=2-turn session that
    # round-robin landed on the drained replica, and nothing else,
    # bound for the healthy replica its admission index picks in ring
    # order (1, 2, 1, 2, ... for drained replica 0 of 3)
    want = {s.session_id: [(0, [1, 2][i % 2])]
            for i, s in enumerate(generate(wl))
            if i % REPLICAS == 0 and len(s.turns) >= 2}
    got = _per_session_migrations(twin)
    assert got == want, (got, want)

    # on barge-free traces completion is decision: every decided
    # migration ran to DONE in both planes (turn requests force a
    # demanded completion; only barge/hangup/pressure may cancel)
    if barge == 0.0:
        for gw, m in ((twin, twin_m), (live, live_m)):
            assert not gw.migrator.plans
            assert not gw.migrator.cancelled()
            assert len(gw.migrator.completed()) == len(want)
            assert m.migrations == len(want)
            if want:
                assert m.migration_bytes > 0
                assert sum(1 for t in m.turns if t.migrated) == len(want)
                # destinations spread over the healthy replicas
                if len(want) >= 2:
                    assert len({d for v in want.values()
                                for _, d in v}) > 1

    # both fleets end clean: invariants green, every pool empty (the
    # drained replica's sessions migrated away or hung up — ended
    # sessions persist as history records, pages released)
    for gw in (twin, live):
        for e in gw.replicas:
            e.flush_transfers()
            e.check_invariants()
            assert e.pool.free_pages == e.num_pages
            assert all(s.ended for s in e.sessions.values())
        # a completed migration scrubbed the source wholesale: the
        # session record lives only on its destination
        for p in gw.migrator.completed():
            assert p.session_id not in gw.replicas[p.src].sessions
            assert p.session_id in gw.replicas[p.dst].sessions


# 27 deterministic examples (3 seeds x 3 kinds x 3 shapes), mirroring
# the sim-vs-real differential's sweep structure
EXAMPLES = [(seed, kind, sessions, barge)
            for seed in range(3)
            for kind in ("interactive", "sharegpt", "mixed")
            for sessions, barge in ((3, 0.0), (4, 0.5), (6, 0.8))]


@pytest.mark.slow
@pytest.mark.parametrize("seed,kind,sessions,barge", EXAMPLES)
def test_fleet_differential(tiny, seed, kind, sessions, barge):
    check_fleet_differential(tiny, seed, kind, sessions, barge)


# one smoke example stays in the fast lane so a broken fleet harness is
# caught even when -m "not slow" deselects the sweep
def test_fleet_differential_smoke(tiny):
    check_fleet_differential(tiny, 0, "interactive", 4, 0.5)


def test_fleet_twin_is_deterministic(tiny):
    """Two twin runs of the same trace produce byte-identical decision
    logs — the precondition for comparing anything against it."""
    wl = _workload(1, "mixed", 5, 0.5)
    _, a = _run_twin(tiny, wl, 1, 5)
    _, b = _run_twin(tiny, wl, 1, 5)
    assert a.router.decisions == b.router.decisions
    assert a.router.decisions

"""Distribution substrate tests: sharding rule validity, checkpoint
roundtrip + elastic restore, gradient compression, small-mesh lowering
(multi-device bits run in a subprocess so the main test process keeps its
single CPU device)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.launch import specs as SP
from repro.training import optimizer as opt_mod
from repro.training.checkpoint import (latest_step, restore_checkpoint,
                                       save_checkpoint)
from repro.distributed.fault_tolerance import (StragglerMitigator,
                                               run_resilient)

ARCHS = ["qwen3-4b", "deepseek-v2-236b", "mamba2-1.3b", "recurrentgemma-9b",
         "whisper-tiny", "paligemma-3b", "nemotron-4-340b",
         "phi3.5-moe-42b-a6.6b", "h2o-danube-1.8b", "qwen2-1.5b"]


def _subprocess_mesh(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(__file__)),
                       timeout=600)
    assert r.returncode == 0, r.stderr[-4000:]
    return r.stdout


# ------------------------------------------------------------ rule validity
@pytest.mark.parametrize("arch", ARCHS)
def test_sharding_specs_cover_all_params(arch):
    """Every param/cache leaf gets a spec whose sharded dims divide."""
    out = _subprocess_mesh(f"""
        import jax
        from repro.configs import get_config
        from repro.distributed.sharding import ShardingRules
        from repro.launch import specs as SP
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = get_config("{arch}")
        rules = ShardingRules(cfg, mesh)
        p = SP.param_shapes(cfg)
        sh = rules.params(p)
        n = 0
        for sds, s in zip(jax.tree.leaves(p), jax.tree.leaves(sh)):
            # constructing the sharded aval raises if indivisible
            s.shard_shape(sds.shape)
            n += 1
        cache = SP.cache_shapes(cfg, 8, 64)
        csh = rules.cache(cache)
        for sds, s in zip(jax.tree.leaves(cache), jax.tree.leaves(csh)):
            s.shard_shape(sds.shape)
        print("OK", n)
    """)
    assert "OK" in out


def test_small_mesh_train_step_lowers_and_runs():
    """Reduced qwen3 train step executes on a real 8-device host mesh."""
    out = _subprocess_mesh("""
        import jax, jax.numpy as jnp
        from repro.configs import get_config, reduced
        from repro.distributed.sharding import ShardingRules
        from repro.models import model as M
        from repro.training import optimizer as opt_mod
        from repro.training.train_loop import TrainConfig, build_train_step
        cfg = reduced(get_config("qwen3-4b"))
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        rules = ShardingRules(cfg, mesh, fsdp=True)
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        opt = opt_mod.OptConfig(kind="adamw")
        state = opt_mod.opt_init(opt, params)
        step = build_train_step(cfg, opt, TrainConfig(remat=True,
                                                      microbatches=2),
                                mesh=mesh)
        B, S = 8, 32
        batch = {"tokens": jnp.ones((B, S), jnp.int32),
                 "labels": jnp.ones((B, S), jnp.int32)}
        from repro.launch.mesh import mesh_context
        with mesh_context(mesh):
            p_sh = rules.params(jax.eval_shape(lambda: params))
            o_sh = rules.opt_state(jax.eval_shape(lambda: state))
            b_sh = rules.batch(jax.eval_shape(lambda: batch))
            params = jax.device_put(params, p_sh)
            state = jax.device_put(state, o_sh)
            batch = jax.device_put(batch, b_sh)
            jf = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh))
            p2, s2, m = jf(params, state, batch)
            assert jnp.isfinite(m["loss"])
        print("LOSS", float(m["loss"]))
    """)
    assert "LOSS" in out


def test_moe_ep_shard_map_matches_local():
    """Expert-parallel MoE (a2a path) == single-device reference.

    Capacity is set drop-free: with finite capacity the EP path drops
    per-shard rather than globally (expected divergence)."""
    out = _subprocess_mesh("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config, reduced
        from repro.models import moe as moe_mod
        from repro.models.model import init_params
        cfg = reduced(get_config("phi3.5-moe-42b-a6.6b"))
        cfg = cfg.replace(moe=dataclasses.replace(
            cfg.moe, capacity_factor=8.0))
        key = jax.random.PRNGKey(0)
        p = moe_mod.moe_init(key, cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, cfg.d_model))
        y_local, aux_local = moe_mod.moe_local(p, x, cfg)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        from repro.launch.mesh import mesh_context
        with mesh_context(mesh):
            y_ep, aux_ep = moe_mod.moe_ep(p, x, cfg, mesh)
        np.testing.assert_allclose(np.asarray(y_local), np.asarray(y_ep),
                                   rtol=2e-4, atol=2e-4)
        print("MOE_OK", float(aux_local), float(aux_ep))
    """)
    assert "MOE_OK" in out


def test_compressed_psum_matches_mean():
    out = _subprocess_mesh("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.compression import compressed_psum
        mesh = jax.make_mesh((8,), ("pod",))
        g = {"w": jax.random.normal(jax.random.PRNGKey(0), (8, 64, 33))}
        mean, err = compressed_psum(g, mesh, "pod")
        true = jnp.mean(g["w"], axis=0)
        rel = float(jnp.max(jnp.abs(mean["w"] - true))
                    / (jnp.max(jnp.abs(true)) + 1e-9))
        assert rel < 0.02, rel          # int8 quantization error bound
        assert err["w"].shape == g["w"].shape
        # error feedback: residual bounded by one quantization step
        print("COMP_OK", rel)
    """)
    assert "COMP_OK" in out


# ------------------------------------------------------------ checkpoints
def test_checkpoint_roundtrip(tmp_path):
    cfg = reduced(get_config("qwen2-1.5b"))
    from repro.models import init_params
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = opt_mod.OptConfig()
    state = opt_mod.opt_init(opt, params)
    save_checkpoint(str(tmp_path), 7, params, state)
    assert latest_step(str(tmp_path)) == 7
    tree, step = restore_checkpoint(str(tmp_path))
    assert step == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(tree["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_async_and_gc(tmp_path):
    x = {"w": jnp.arange(10.0)}
    threads = [save_checkpoint(str(tmp_path), s, x, async_save=True,
                               keep_last=2) for s in (1, 2, 3)]
    for t in threads:
        t.join()
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(steps) <= 2 and latest_step(str(tmp_path)) == 3


def test_checkpoint_atomic_no_partial(tmp_path):
    """A .tmp dir without manifest is never considered a checkpoint."""
    os.makedirs(tmp_path / "step_00000005.tmp")
    assert latest_step(str(tmp_path)) is None


def test_run_resilient_restarts_from_checkpoint(tmp_path):
    calls = {"n": 0}

    def train_once(start):
        calls["n"] += 1
        save_checkpoint(str(tmp_path), calls["n"], {"w": jnp.ones(3)})
        if calls["n"] < 3:
            raise RuntimeError("simulated node failure")
        return start + 100

    def on_failure(e, restarts):
        return latest_step(str(tmp_path))

    out = run_resilient(train_once, max_restarts=5, on_failure=on_failure)
    assert calls["n"] == 3 and out == 2 + 100


def test_elastic_restore_to_different_mesh(tmp_path):
    """Checkpoint saved unsharded restores onto an 8-device mesh."""
    x = {"w": jnp.ones((16, 8)), "b": jnp.zeros((8,))}
    save_checkpoint(str(tmp_path), 1, x)
    out = _subprocess_mesh(f"""
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.training.checkpoint import restore_checkpoint
        mesh = jax.make_mesh((8,), ("data",))
        sh = {{"params": {{"w": jax.NamedSharding(mesh, P("data", None)),
                           "b": jax.NamedSharding(mesh, P(None))}}}}
        tree, step = restore_checkpoint({str(tmp_path)!r}, shardings=sh)
        w = tree["params"]["w"]
        assert len(w.sharding.device_set) == 8
        print("ELASTIC_OK", step, w.shape)
    """)
    assert "ELASTIC_OK" in out


def test_straggler_mitigator():
    sm = StragglerMitigator(deadline_factor=2.0)
    for _ in range(10):
        assert not sm.observe("w0", 1.0)
    assert sm.observe("w3", 10.0)
    assert sm.observe("w3", 10.0)
    assert sm.observe("w3", 11.0)
    assert sm.should_evict("w3")
    assert not sm.should_evict("w0")

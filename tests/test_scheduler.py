"""Unit + property tests for the urgency scheduler (paper §4)."""
from hypothesis_compat import given, settings, st

from repro.core.monitor import RuntimeMonitor
from repro.core.scheduler import (FCFSScheduler, RoundBudget,
                                  SchedulerConfig, UrgencyScheduler)
from repro.core.session import Phase, Request


class FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def now(self):
        return self.t


def mk_req(sid, stage="talker", arrival=0.0, prompt=0, gen=0, target=100):
    r = Request(session_id=sid, stage=stage, turn_index=0,
                arrival_time=arrival, prompt_len=prompt,
                max_new_tokens=target)
    if prompt == 0:
        r.phase = Phase.DECODE
    r.generated = gen
    if gen:
        r.first_output_time = arrival
    return r


def setup(buffers, *, p_safe=1.0, p_max=3.0, occ=0.0, started=None):
    """buffers: sid -> playback buffer seconds (None = no telemetry)."""
    clock = FakeClock(100.0)
    mon = RuntimeMonitor(clock)
    started = started or {}
    for sid, buf in buffers.items():
        if buf is None:
            continue
        mon.register(sid)
        v = mon.view(sid)
        if started.get(sid, True):
            v.playback.started = True
            v.playback.play_end = clock.t + buf
            v.playback.appended_s = buf + 5.0
    cfg = SchedulerConfig(p_safe_s=p_safe, p_max_s=p_max)
    sched = UrgencyScheduler(
        cfg, mon, stage="talker",
        kv_occupancy=lambda: occ)
    return sched, clock


def test_u0_beats_u1_beats_u2():
    sched, clock = setup({"a": 0.5, "b": 2.0})
    # a: started, buffer 0.5 <= p_safe -> U0
    ra = mk_req("a", gen=10)
    # b: started, buffer 2.0 -> U2
    rb = mk_req("b", gen=10)
    # c: no playback yet -> U1
    rc = mk_req("c", arrival=50.0, prompt=100)
    budget = RoundBudget(token_budget=4096, free_kv_blocks=10**6)
    d = sched.schedule([rb, rc, ra], budget, clock.now())
    assert [r.session_id for r in d.batch] == ["a", "c", "b"]
    assert d.classes[ra.req_id] == 0
    assert d.classes[rc.req_id] == 1
    assert d.classes[rb.req_id] == 2


def test_u0_sorted_by_buffer_ascending():
    sched, clock = setup({"a": 0.9, "b": 0.1, "c": 0.5})
    reqs = [mk_req(s, gen=5) for s in ("a", "b", "c")]
    budget = RoundBudget(token_budget=4096, free_kv_blocks=10**6)
    d = sched.schedule(reqs, budget, clock.now())
    assert [r.session_id for r in d.batch] == ["b", "c", "a"]


def test_u1_fcfs_aging_oldest_first():
    sched, clock = setup({})
    r1 = mk_req("a", arrival=10.0, prompt=64)
    r2 = mk_req("b", arrival=5.0, prompt=64)
    budget = RoundBudget(token_budget=4096, free_kv_blocks=10**6)
    d = sched.schedule([r1, r2], budget, clock.now())
    assert [r.session_id for r in d.batch] == ["b", "a"]


def test_pacing_holds_far_ahead_sessions():
    sched, clock = setup({"a": 10.0, "b": 2.0})
    ra, rb = mk_req("a", gen=5), mk_req("b", gen=5)
    budget = RoundBudget(token_budget=4096, free_kv_blocks=10**6)
    d = sched.schedule([ra, rb], budget, clock.now())
    assert [r.session_id for r in d.batch] == ["b"]
    assert d.classes[ra.req_id] == 3
    assert [r.session_id for r, _ in d.held] == ["a"]


def test_pacing_overridden_under_kv_pressure():
    sched, clock = setup({"a": 10.0}, occ=0.95)
    ra = mk_req("a", gen=5)
    budget = RoundBudget(token_budget=4096, free_kv_blocks=10**6)
    d = sched.schedule([ra], budget, clock.now())
    assert [r.session_id for r in d.batch] == ["a"]


def test_u2_utility_kv_relief_vs_barge_exposure():
    """Eq. 1-3: big-KV request wins when pool crowded; far-ahead request
    penalized."""
    sched, clock = setup({"big": 2.5, "small": 1.5}, occ=0.8)
    big = mk_req("big", gen=50)
    small = mk_req("small", gen=2)
    sched._kv_of = lambda r: 100.0 if r.session_id == "big" else 1.0
    budget = RoundBudget(token_budget=4096, free_kv_blocks=10**6)
    d = sched.schedule([small, big], budget, clock.now())
    assert [r.session_id for r in d.batch] == ["big", "small"]
    assert d.utilities[big.req_id] > d.utilities[small.req_id]


def test_missing_telemetry_fails_closed_to_u1():
    """Fail-closed (§6): unknown session -> first-audio path, not dropped."""
    sched, clock = setup({})
    r = mk_req("ghost", gen=5)
    budget = RoundBudget(token_budget=4096, free_kv_blocks=10**6)
    d = sched.schedule([r], budget, clock.now())
    assert d.batch == [r]
    assert d.classes[r.req_id] == 1


def test_budget_admission_stops_at_first_misfit():
    sched, clock = setup({})
    r1 = mk_req("a", arrival=0.0, prompt=600)
    r2 = mk_req("b", arrival=1.0, prompt=10)
    budget = RoundBudget(token_budget=520, free_kv_blocks=10**6)
    d = sched.schedule([r1, r2], budget, clock.now())
    # r1 admits a 512 chunk; r2's 10 tokens exceed the remaining 8 -> stop
    assert [r.session_id for r in d.batch] == ["a"]


def test_fcfs_baseline_ignores_urgency():
    mon = RuntimeMonitor(FakeClock(100.0))
    sched = FCFSScheduler(mon, stage="talker")
    r1 = mk_req("a", arrival=2.0, gen=5)
    r2 = mk_req("b", arrival=1.0, prompt=64)
    budget = RoundBudget(token_budget=4096, free_kv_blocks=10**6)
    d = sched.schedule([r1, r2], budget, FakeClock(100.0).now())
    assert [r.session_id for r in d.batch] == ["b", "a"]


# ---------------------------------------------------------------- property
@settings(max_examples=200, deadline=None)
@given(
    bufs=st.lists(
        st.one_of(st.none(), st.floats(0.0, 20.0)),
        min_size=1, max_size=12),
    token_budget=st.integers(1, 4096),
    occ=st.floats(0.0, 1.0),
)
def test_schedule_invariants(bufs, token_budget, occ):
    buffers = {f"s{i}": b for i, b in enumerate(bufs)}
    sched, clock = setup(buffers, occ=occ)
    reqs = [mk_req(f"s{i}", arrival=float(i), gen=1 if b is not None else 0,
                   prompt=0 if b is not None else 64)
            for i, b in enumerate(bufs)]
    budget = RoundBudget(token_budget=token_budget, free_kv_blocks=10**6)
    d = sched.schedule(list(reqs), budget, clock.now())
    # 1. no duplicates, batch subset of ready
    ids = [r.req_id for r in d.batch]
    assert len(set(ids)) == len(ids)
    assert set(ids) <= {r.req_id for r in reqs}
    # 2. admitted chunks respect the token budget
    assert sum(d.chunks.values()) <= token_budget
    # 3. class ordering is monotone in the batch (0 <= 1 <= 2)
    cls_seq = [d.classes[r.req_id] for r in d.batch]
    assert cls_seq == sorted(cls_seq)
    # 4. held requests never admitted
    assert not ({r.req_id for r, _ in d.held} & set(ids))
    # 5. U0 appear sorted by buffer ascending
    u0 = [r for r in d.batch if d.classes[r.req_id] == 0]
    u0_bufs = [sched._buffer(r) for r in u0]
    assert u0_bufs == sorted(u0_bufs)

"""Per-kernel allclose sweeps vs the pure-jnp oracles (interpret=True on
CPU), plus model-integration checks (kernel output == model attention)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_prefill import flash_prefill
from repro.kernels.paged_attention import paged_attention
from repro.kernels.ssd_scan import ssd_scan
from repro.kernels import ref

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _tol(dtype):
    return TOL[jnp.bfloat16 if dtype == jnp.bfloat16 else jnp.float32]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Hq,Hkv,Sq,Skv,D,bq,bkv,window,q_offset",
    [
        (1, 2, 2, 32, 32, 16, 8, 8, None, 0),       # MHA causal
        (2, 8, 2, 64, 64, 32, 16, 16, None, 0),     # GQA
        (1, 4, 1, 128, 128, 64, 32, 32, None, 0),   # MQA larger
        (2, 4, 4, 64, 64, 16, 16, 16, 24, 0),       # sliding window
        (1, 8, 2, 32, 96, 32, 16, 16, None, 64),    # chunked prefill offset
        (1, 4, 2, 16, 80, 16, 8, 16, 32, 64),       # offset + window
    ])
def test_flash_prefill_sweep(B, Hq, Hkv, Sq, Skv, D, bq, bkv, window,
                             q_offset, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Hq, Sq, D), dtype)
    k = jax.random.normal(ks[1], (B, Hkv, Skv, D), dtype)
    v = jax.random.normal(ks[2], (B, Hkv, Skv, D), dtype)
    out = flash_prefill(q, k, v, causal=True, window=window,
                        q_offset=q_offset, block_q=bq, block_kv=bkv,
                        interpret=True)
    want = ref.flash_prefill_ref(q, k, v, causal=True, window=window,
                                 q_offset=q_offset)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        rtol=_tol(dtype), atol=_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Hq,Hkv,D,page,pps",
    [
        (1, 2, 2, 16, 8, 2),
        (3, 8, 2, 32, 8, 5),
        (2, 4, 1, 64, 16, 4),
        (4, 16, 8, 32, 4, 8),
    ])
def test_paged_attention_sweep(B, Hq, Hkv, D, page, pps, dtype):
    num_pages = B * pps + 3
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    q = jax.random.normal(ks[0], (B, Hq, D), dtype)
    kp = jax.random.normal(ks[1], (num_pages, page, Hkv, D), dtype)
    vp = jax.random.normal(ks[2], (num_pages, page, Hkv, D), dtype)
    bt = jax.random.permutation(
        ks[3], num_pages)[:B * pps].reshape(B, pps).astype(jnp.int32)
    # ragged lengths incl. partially-filled last page and a 1-token seq
    sl = jnp.array([(i * 7) % (page * pps) + 1 for i in range(B)], jnp.int32)
    out = paged_attention(q, kp, vp, bt, sl, interpret=True)
    want = ref.paged_attention_ref(q, kp, vp, bt, sl)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        rtol=_tol(dtype), atol=_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,l,h,p,n,chunk",
    [
        (1, 64, 1, 8, 8, 16),
        (2, 128, 3, 16, 8, 32),
        (1, 256, 2, 64, 128, 64),   # production-shaped head
        (2, 96, 4, 32, 16, 32),     # chunk not power-of-two multiple
    ])
def test_ssd_scan_sweep(b, l, h, p, n, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    X = (jax.random.normal(ks[0], (b, l, h, p)) * 0.5).astype(dtype)
    dA = -jnp.abs(jax.random.normal(ks[1], (b, l, h))) * 0.3
    B = (jax.random.normal(ks[2], (b, l, h, n)) * 0.5).astype(dtype)
    C = (jax.random.normal(ks[3], (b, l, h, n)) * 0.5).astype(dtype)
    Y, st = ssd_scan(X, dA.astype(dtype), B, C, chunk=chunk, interpret=True)
    Yr, str_ = ref.ssd_scan_ref(X.astype(jnp.float32), dA,
                                B.astype(jnp.float32),
                                C.astype(jnp.float32))
    tol = _tol(dtype) * 4  # recurrence accumulates error over l
    np.testing.assert_allclose(np.asarray(Y, np.float32),
                               np.asarray(Yr, np.float32),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(st), np.asarray(str_),
                               rtol=tol, atol=tol)


def test_ssd_kernel_matches_model_ssd():
    """The kernel agrees with the chunked jnp SSD used by the mamba2 model."""
    from repro.models.ssm import ssd_chunked
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    b, l, h, p, n = 2, 128, 2, 16, 8
    X = jax.random.normal(ks[0], (b, l, h, p)) * 0.5
    dA = -jnp.abs(jax.random.normal(ks[1], (b, l, h))) * 0.3
    B = jax.random.normal(ks[2], (b, l, h, n)) * 0.5
    C = jax.random.normal(ks[3], (b, l, h, n)) * 0.5
    Yk, stk = ssd_scan(X, dA, B, C, chunk=32, interpret=True)
    Ym, stm = ssd_chunked(X, dA, B, C, chunk=32)
    np.testing.assert_allclose(np.asarray(Yk), np.asarray(Ym),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(stk), np.asarray(stm),
                               rtol=1e-4, atol=1e-4)


def test_flash_matches_model_attention():
    """Kernel output == the model's einsum GQA attention path."""
    from repro.models.layers import attention_mask, gqa_attention
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    B, Hq, Hkv, S, D = 2, 8, 2, 64, 32
    q = jax.random.normal(ks[0], (B, S, Hq, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    mask = attention_mask(pos, pos, causal=True, window=24)
    want = gqa_attention(q, k, v, mask)
    got = flash_prefill(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                        v.transpose(0, 2, 1, 3), causal=True, window=24,
                        block_q=16, block_kv=16,
                        interpret=True).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)

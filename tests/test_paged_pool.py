"""Paged pool tests: allocation/eviction/reload round-trips are bit-exact
and the block tables drive the Pallas paged_attention kernel correctly
end-to-end (pool -> tables -> kernel == dense oracle)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.kernels.paged_attention import paged_attention
from repro.kernels.ref import paged_attention_ref
from repro.kvcache.paged import OutOfPages, PagedPool


def test_alloc_grow_release():
    pool = PagedPool(num_pages=10, page_size=4)
    new = pool.ensure_capacity("a", 9)          # 3 pages
    assert len(new) == 3 and pool.free_pages == 7
    assert pool.ensure_capacity("a", 10) == []  # fits in page 3
    assert len(pool.ensure_capacity("a", 13)) == 1
    pool.release("a")
    assert pool.free_pages == 10


def test_out_of_pages_raises():
    pool = PagedPool(num_pages=2, page_size=4)
    pool.ensure_capacity("a", 8)
    with pytest.raises(OutOfPages):
        pool.ensure_capacity("b", 1)


def test_offload_reload_roundtrip_bit_exact():
    pool = PagedPool(num_pages=8, page_size=4)
    kv = jax.random.normal(jax.random.PRNGKey(0), (8, 4, 2, 8))
    pool.ensure_capacity("a", 16)               # 4 pages
    before = np.asarray(kv[np.array(pool.seq("a").pages)])
    freed = pool.offload_suffix("a", 2, kv)     # suffix pages out
    assert freed == 2 and pool.free_pages == 6
    assert pool.resident_pages("a") == 2
    with pytest.raises(RuntimeError):
        pool.block_table(["a"], 4)              # offloaded -> must reload
    # pool pressure: another seq takes the freed pages, then releases
    pool.ensure_capacity("b", 8)
    kv = kv.at[np.array(pool.seq("b").pages)].set(-1.0)  # clobber
    pool.release("b")
    kv, loaded = pool.reload("a", kv)
    assert loaded == 2
    after = np.asarray(kv[np.array(pool.seq("a").pages)])
    np.testing.assert_array_equal(before, after)  # contents restored


def test_pool_drives_paged_kernel():
    """Pages allocated out-of-order + partial last page == dense oracle."""
    page, Hkv, D, Hq = 8, 2, 16, 4
    pool = PagedPool(num_pages=32, page_size=page)
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    k_pages = jax.random.normal(ks[0], (32, page, Hkv, D))
    v_pages = jax.random.normal(ks[1], (32, page, Hkv, D))
    # interleaved allocation -> non-contiguous page lists
    lens = {"s0": 19, "s1": 8, "s2": 27}
    for t in range(27):
        for sid, ln in lens.items():
            if t < ln:
                pool.ensure_capacity(sid, t + 1)
    sids = list(lens)
    pps = max(pool.pages_for(v) for v in lens.values())
    bt = jnp.asarray(pool.block_table(sids, pps))
    sl = jnp.asarray(pool.seq_lens(sids))
    assert sl.tolist() == [19, 8, 27]
    q = jax.random.normal(ks[2], (len(sids), Hq, D))
    out = paged_attention(q, k_pages, v_pages, bt, sl, interpret=True)
    want = paged_attention_ref(q, k_pages, v_pages, bt, sl)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=50, deadline=None)
@given(ops=st.lists(st.tuples(st.integers(0, 4),      # seq id
                              st.integers(1, 30)),    # grow to length
                    min_size=1, max_size=40))
def test_pool_invariants(ops):
    pool = PagedPool(num_pages=64, page_size=4)
    for sid, ln in ops:
        try:
            pool.ensure_capacity(f"s{sid}", ln)
        except OutOfPages:
            pool.release(f"s{sid}")
    # physical pages are never double-owned
    owned = [p for s in pool.seqs.values() for p in s.pages if p >= 0]
    assert len(owned) == len(set(owned))
    assert set(owned).isdisjoint(pool.free)
    assert len(owned) + pool.free_pages == 64

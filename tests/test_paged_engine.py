"""Paged realtime engine: the LiveServe KV policies on real JAX state.

Covers the tentpole contracts:
- token-for-token parity with the dense RealtimeLLMEngine, under both
  the default and an adversarial scheduler (scheduling moves WHEN, never
  WHICH — paper §5.2);
- multi-turn decode matches a single dense-cache reference (no
  re-prefill of committed context);
- evict-to-DRAM -> clobber -> reload -> decode continues bit-exact
  across a turn boundary;
- barge-in mid-decode keeps committed pages and frees in-flight ones;
- pool/accounting invariants hold throughout.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.scheduler import SchedulerConfig, UrgencyScheduler
from repro.models import decode_step, init_cache, init_params, prefill
from repro.serving.engine import RealtimeLLMEngine
from repro.serving.paged_engine import PagedRealtimeEngine


@pytest.fixture(scope="module")
def tiny():
    cfg = reduced(get_config("qwen2-1.5b"), layers=2, d_model=64,
                  vocab=331)
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _decode_feed(cfg, params, cache, token):
    lg, cache = decode_step(cfg, params,
                            jnp.asarray([token], jnp.int32), cache)
    return int(jnp.argmax(lg[0])), cache


def _reference_turns(cfg, params, turns):
    """Dense single-sequence reference over a multi-turn conversation.
    turns: [(prompt, n_tokens), ...]. Returns per-turn token lists."""
    cache = init_cache(cfg, 1, 256)
    out = []
    last = None
    for t, (prompt, n) in enumerate(turns):
        if t == 0:
            logits, cache = prefill(cfg, params,
                                    jnp.asarray(prompt)[None, :], cache)
            nxt = int(jnp.argmax(logits[0]))
        else:
            # the engine writes the last produced token's KV when it is
            # fed on the final round of the previous turn
            nxt, cache = _decode_feed(cfg, params, cache, last)
            for tok in prompt:
                nxt, cache = _decode_feed(cfg, params, cache, int(tok))
        toks = [nxt]
        for _ in range(n - 1):
            nxt, cache = _decode_feed(cfg, params, cache, toks[-1])
            toks.append(nxt)
        last = toks[-1]
        out.append(toks)
    return out


# ----------------------------------------------------------- parity (a)
def test_parity_with_dense_engine(tiny):
    cfg, params = tiny
    rng = np.random.default_rng(0)
    prompts = {f"s{i}": rng.integers(0, cfg.vocab_size, size=ln)
               for i, ln in enumerate((7, 11, 5))}
    dense = RealtimeLLMEngine(cfg, params, slots=4, capacity=128)
    paged = PagedRealtimeEngine(cfg, params, slots=4, page_size=8,
                                pages_per_seq=16)
    for sid, p in prompts.items():
        dense.add_session(sid, p, max_new_tokens=10)
        paged.add_session(sid, p, max_new_tokens=10)
    want = dense.run_to_completion()
    got = paged.run_to_completion()
    paged.check_invariants()
    for sid in prompts:
        assert got[sid] == want[sid], sid


def test_adversarial_schedule_changes_timing_not_tokens(tiny):
    """A rotating single-admission scheduler: paged rows held out of the
    batch are padded to the scratch page; tokens must not change."""
    cfg, params = tiny
    rng = np.random.default_rng(1)
    prompts = {f"s{i}": rng.integers(0, cfg.vocab_size, size=6)
               for i in range(3)}

    class EveryOther(UrgencyScheduler):
        def __init__(self, monitor):
            super().__init__(SchedulerConfig(), monitor, stage="t")
            self.i = 0

        def schedule(self, ready, budget, now):
            self.i += 1
            d = super().schedule(ready, budget, now)
            keep = [d.batch[self.i % max(1, len(d.batch))]] \
                if d.batch else []
            d.batch = keep
            d.chunks = {r.req_id: 1 for r in keep}
            return d

    dense = RealtimeLLMEngine(cfg, params, slots=4, capacity=128)
    for sid, p in prompts.items():
        dense.add_session(sid, p, max_new_tokens=8)
    want = dense.run_to_completion()

    paged = PagedRealtimeEngine(cfg, params, slots=4, page_size=8,
                                pages_per_seq=16)
    paged.scheduler = EveryOther(paged.monitor)
    for sid, p in prompts.items():
        paged.add_session(sid, p, max_new_tokens=8)
    got = paged.run_to_completion(max_rounds=400)
    paged.check_invariants()
    for sid in prompts:
        assert got[sid] == want[sid], sid


# ------------------------------------------------------ multi-turn (b)
def test_multiturn_matches_dense_reference(tiny):
    cfg, params = tiny
    rng = np.random.default_rng(2)
    turns = [(rng.integers(0, cfg.vocab_size, size=9), 6),
             (rng.integers(0, cfg.vocab_size, size=5), 7),
             (rng.integers(0, cfg.vocab_size, size=4), 5)]
    want = _reference_turns(cfg, params, turns)

    eng = PagedRealtimeEngine(cfg, params, slots=2, page_size=4,
                              pages_per_seq=16)
    eng.add_session("a", turns[0][0], max_new_tokens=turns[0][1])
    eng.run_to_completion()
    for prompt, n in turns[1:]:
        eng.start_turn("a", prompt, max_new_tokens=n)
        eng.run_to_completion()
    eng.check_invariants()
    assert eng.sessions["a"].history == want
    # committed context is never re-prefilled
    for st in eng.sessions["a"].turn_stats:
        assert st["re_prefill_tokens"] == 0


def test_evict_reload_bit_exact_across_turn(tiny):
    """Offload to DRAM, clobber the freed HBM pages with another
    session, reload, decode the next turn: page contents round-trip
    bit-exactly and the token stream matches a never-evicted control."""
    cfg, params = tiny
    rng = np.random.default_rng(3)
    p1 = rng.integers(0, cfg.vocab_size, size=10)
    p2 = rng.integers(0, cfg.vocab_size, size=6)
    pb = rng.integers(0, cfg.vocab_size, size=8)

    def drive(eng, evict):
        eng.add_session("a", p1, max_new_tokens=6)
        eng.run_to_completion()
        snapshot = None
        if evict:
            now = eng.clock.now()
            assert eng.kv.evict(2, now) == 2      # physical via hook
            seq = eng.pool.seq("a")
            # copy-then-free: the pages stay usable until the chunked
            # device->host copy drains; flush to make the host copies
            # durable before another session clobbers the slots
            assert len(seq.offloading) + len(seq.offloaded) == 2
            eng.flush_transfers()
            assert len(seq.offloaded) == 2 and not seq.offloading
            snapshot = {li: np.array(c) for li, c in seq.offloaded.items()}
            # clobber the freed pages with a second session
            eng.add_session("b", pb, max_new_tokens=2)
            eng.run_to_completion()
        eng.start_turn("a", p2, max_new_tokens=6)
        eng.run_to_completion()
        eng.check_invariants()
        return eng, snapshot

    control, _ = drive(PagedRealtimeEngine(
        cfg, params, slots=2, page_size=4, pages_per_seq=16,
        num_pages=64), evict=False)
    victim, snapshot = drive(PagedRealtimeEngine(
        cfg, params, slots=2, page_size=4, pages_per_seq=16,
        num_pages=12), evict=True)

    # turn-2 tokens identical although the victim's pages went to DRAM
    # and back through different physical page ids
    assert victim.sessions["a"].history == control.sessions["a"].history
    # reloaded device pages hold bit-identical contents
    seq = victim.pool.seq("a")
    assert not seq.offloaded
    for li, host in snapshot.items():
        phys = seq.pages[li]
        np.testing.assert_array_equal(
            np.asarray(victim.k_pages[:, phys]), host[0])
        np.testing.assert_array_equal(
            np.asarray(victim.v_pages[:, phys]), host[1])
    # the reloaded turn paid a reload stall but zero re-prefill
    st = victim.sessions["a"].turn_stats[-1]
    assert st["re_prefill_tokens"] == 0
    assert st["reload_stall_s"] > 0.0          # sync fallback path
    assert victim.kv.reloaded_blocks == 2


def test_speech_preload_reloads_before_turn(tiny):
    """Speech-triggered preload physically reloads pages during the
    utterance; the next turn starts warm (stall 0, hit counted)."""
    cfg, params = tiny
    rng = np.random.default_rng(4)
    eng = PagedRealtimeEngine(cfg, params, slots=2, page_size=4,
                              pages_per_seq=16, num_pages=32)
    eng.add_session("a", rng.integers(0, cfg.vocab_size, size=10),
                    max_new_tokens=6)
    eng.run_to_completion()
    assert eng.kv.evict(2, eng.clock.now()) == 2
    eng.flush_transfers()                      # copies now durably in DRAM
    assert len(eng.pool.seq("a").offloaded) == 2
    eng.user_speech_start("a", expected_dur_s=2.0)
    # async plane: admission reserves the slots and queues the chunks
    # (ledger in-flight); the bytes land across rounds/idle drains or,
    # at the latest, at turn-start settlement — with zero stall here,
    # because the modeled DMA finishes well inside the 2 s utterance
    assert eng.pool.inflight_pages("a") == (2, 0)
    assert eng.transfer.pending_reload_pages("a") == 2
    eng.clock.tick(2.0)                        # utterance completes
    eng.start_turn("a", rng.integers(0, cfg.vocab_size, size=4),
                   max_new_tokens=4)
    eng.run_to_completion()
    eng.check_invariants()
    st = eng.sessions["a"].turn_stats[-1]
    assert st["reload_stall_s"] == 0.0
    assert st["re_prefill_tokens"] == 0
    assert eng.preloader.stats.admitted == 1
    assert eng.preloader.stats.hits == 1


# -------------------------------------------------------- barge-in (c)
def test_barge_in_keeps_committed_frees_inflight(tiny):
    cfg, params = tiny
    rng = np.random.default_rng(5)
    eng = PagedRealtimeEngine(cfg, params, slots=2, page_size=4,
                              pages_per_seq=16, num_pages=32)
    p = rng.integers(0, cfg.vocab_size, size=5)
    eng.add_session("a", p, max_new_tokens=20)
    for _ in range(3):
        eng.step()
    sess = eng.sessions["a"]
    assert sess.kv_len == 8                    # 5 prompt + 3 decoded
    # decode lookahead owns pages beyond the committed 2 (in-flight)
    inflight = len(eng.pool.seq("a").pages) - eng.pool.pages_for(8)
    assert inflight > 0
    free_before = eng.pool.free_pages
    eng.barge_in("a")
    # in-flight pages returned; committed pages kept resident
    assert eng.pool.free_pages == free_before + inflight
    assert eng.pool.resident_pages("a") == eng.pool.pages_for(8) == 2
    assert eng.kv.session("a").total_blocks == 2
    assert not eng.kv.session("a").pinned
    assert eng.free_slot() is not None
    eng.check_invariants()
    # the next turn continues from the committed pages bit-exactly
    p2 = rng.integers(0, cfg.vocab_size, size=4)
    eng.start_turn("a", p2, max_new_tokens=4)
    eng.run_to_completion()
    eng.check_invariants()
    # dense reference: the aborted turn's last produced token (t3) was
    # pending at barge-in, so its KV is never written — turn 2 feeds the
    # new prompt right after t2's KV
    cache = init_cache(cfg, 1, 256)
    logits, cache = prefill(cfg, params, jnp.asarray(p)[None, :], cache)
    toks1 = [int(jnp.argmax(logits[0]))]
    for _ in range(3):
        nxt, cache = _decode_feed(cfg, params, cache, toks1[-1])
        toks1.append(nxt)
    nxt = None
    for tok in p2:
        nxt, cache = _decode_feed(cfg, params, cache, int(tok))
    toks2 = [nxt]
    for _ in range(3):
        nxt, cache = _decode_feed(cfg, params, cache, toks2[-1])
        toks2.append(nxt)
    assert sess.history == [toks1, toks2]


def test_speech_session_becomes_evictable_after_turn(tiny):
    """The utterance ends when its turn reaches the LLM: a session that
    once spoke must not stay immediate_reuse forever, or its idle KV
    would be permanently unevictable and wedge a full pool."""
    cfg, params = tiny
    rng = np.random.default_rng(7)
    eng = PagedRealtimeEngine(cfg, params, slots=2, page_size=4,
                              pages_per_seq=16, num_pages=32)
    eng.add_session("a", rng.integers(0, cfg.vocab_size, size=8),
                    max_new_tokens=4)
    eng.run_to_completion()
    eng.user_speech_start("a", expected_dur_s=1.0)
    eng.clock.tick(1.0)
    eng.start_turn("a", rng.integers(0, cfg.vocab_size, size=4),
                   max_new_tokens=4)
    eng.run_to_completion()
    eng.clock.tick(eng.kv.protect_ttl_s)   # preload protection lapses
    now = eng.clock.now()
    assert eng.kv.reclaimable_blocks(now) > 0
    assert eng.kv.evict(1, now) == 1
    eng.check_invariants()


def test_barge_in_trim_during_chunked_prefill(tiny):
    """Regression (ISSUE 3): a barge-in trim landing while a submit_turn
    prompt is only partially teacher-forced must leave pool/accounting
    bounds intact — for every trim point and page alignment — and the
    interrupting turn must resume on exactly the committed tokens."""
    cfg, params = tiny
    rng = np.random.default_rng(11)
    pa = rng.integers(0, cfg.vocab_size, size=10)
    pb = rng.integers(0, cfg.vocab_size, size=5)
    pa2 = rng.integers(0, cfg.vocab_size, size=4)
    for page in (4, 8):
        for trim_round in (0, 1, 2, 3):
            eng = PagedRealtimeEngine(cfg, params, slots=2, page_size=page,
                                      pages_per_seq=16, num_pages=12)
            sa = eng.submit_turn("a", pa, max_new_tokens=6)
            sb = eng.submit_turn("b", pb, max_new_tokens=5)
            for _ in range(trim_round):
                eng.run_round({sa: 3, sb: 3})
                eng.check_invariants()
            fed = eng.sessions["a"].kv_len       # partially prefilled
            eng.barge_in("a")
            eng.check_invariants()
            assert eng.sessions["a"].kv_len == fed
            assert eng.pool.resident_pages("a") == eng.pool.pages_for(fed)
            assert not eng.kv.session("a").pinned
            # the interrupting turn extends the committed prefix
            sa2 = eng.submit_turn("a", pa2, max_new_tokens=4)
            rounds = 0
            while eng.active() and rounds < 120:
                eng.run_round({sa2: 3, sb: 3})
                eng.check_invariants()
                rounds += 1
            assert not eng.active()
            st = eng.sessions["a"].turn_stats
            assert st[0]["aborted"] and not st[1]["aborted"]
            assert st[1]["re_prefill_tokens"] == 0


def test_submit_turn_on_saturated_pool_raises_recoverable(tiny):
    """Regression (ISSUE 3): when a session's offloaded pages cannot be
    reloaded (pool full of pinned live turns), submit_turn must raise
    OutOfPages *without* corrupting turn bookkeeping — and succeed once
    pressure drains, bit-exact with a never-pressured control."""
    from repro.kvcache.paged import OutOfPages
    cfg, params = tiny
    rng = np.random.default_rng(12)
    pa = rng.integers(0, cfg.vocab_size, size=10)
    p2 = rng.integers(0, cfg.vocab_size, size=4)
    pb = rng.integers(0, cfg.vocab_size, size=10)
    pc = rng.integers(0, cfg.vocab_size, size=9)

    def saturate(eng):
        eng.add_session("a", pa, max_new_tokens=2)
        eng.run_to_completion()
        assert eng.kv.evict(2, eng.clock.now()) == 2
        # two live turns pin the rest of the pool
        sb = eng.submit_turn("b", pb, max_new_tokens=20)
        sc = eng.submit_turn("c", pc, max_new_tokens=20)
        for _ in range(12):
            eng.run_round({sb: 4, sc: 4})
        return sb, sc

    eng = PagedRealtimeEngine(cfg, params, slots=3, page_size=4,
                              pages_per_seq=8, num_pages=10)
    sb, sc = saturate(eng)
    before = eng.sessions["a"].turn_index
    with pytest.raises(OutOfPages):
        eng.submit_turn("a", p2, max_new_tokens=4)
    eng.check_invariants()
    assert eng.sessions["a"].turn_index == before   # nothing half-started
    assert not eng.kv.session("a").pinned
    assert eng.pool.seq("a").offloaded              # still safely in DRAM
    # pressure drains: b's user hangs up, freeing its pages
    eng.abort("b")
    eng.end_session("b")
    slot = eng.submit_turn("a", p2, max_new_tokens=4)
    while eng.active():
        eng.run_round({slot: 2, sc: 1})
    eng.check_invariants()
    got = eng.sessions["a"].history[-1]
    st = eng.sessions["a"].turn_stats[-1]
    assert st["re_prefill_tokens"] == 0             # reload, not recompute

    control = PagedRealtimeEngine(cfg, params, slots=3, page_size=4,
                                  pages_per_seq=8, num_pages=64)
    control.add_session("a", pa, max_new_tokens=2)
    control.run_to_completion()
    slot = control.submit_turn("a", p2, max_new_tokens=4)
    while control.active():
        control.run_round({slot: 2})
    assert got == control.sessions["a"].history[-1]


def test_run_round_holds_feed_on_pressure_then_recovers(tiny):
    """Regression (ISSUE 3): a mid-chunk allocation failure (nothing
    evictable at page-boundary growth) holds the feed for the round —
    visible in ``pressure_holds`` — instead of crashing, and decode
    resumes with unchanged tokens once pressure lifts."""
    cfg, params = tiny
    rng = np.random.default_rng(13)
    pa = rng.integers(0, cfg.vocab_size, size=6)
    pb = rng.integers(0, cfg.vocab_size, size=6)

    def drive(num_pages, relieve):
        eng = PagedRealtimeEngine(cfg, params, slots=2, page_size=4,
                                  pages_per_seq=4, num_pages=num_pages)
        sa = eng.submit_turn("a", pa, max_new_tokens=6)
        sb = eng.submit_turn("b", pb, max_new_tokens=6)
        rounds = 0
        while eng.active() and rounds < 200:
            if relieve and eng.pressure_holds > 0 \
                    and eng.slot_state[sb] is not None:
                eng.abort("b")              # b's user hangs up: pressure
                eng.end_session("b")        # drains mid-run
            eng.run_round({sa: 2, sb: 2})
            eng.check_invariants()
            rounds += 1
        return eng

    eng = drive(num_pages=4, relieve=True)
    assert eng.pressure_holds > 0, "pool never hit the mid-chunk bound"
    assert not eng.active()                 # a finished after relief
    got = eng.sessions["a"].history[-1]
    control = drive(num_pages=64, relieve=False)
    assert control.pressure_holds == 0
    assert got == control.sessions["a"].history[-1]


def test_end_session_returns_pages(tiny):
    cfg, params = tiny
    rng = np.random.default_rng(6)
    eng = PagedRealtimeEngine(cfg, params, slots=2, page_size=4,
                              pages_per_seq=16, num_pages=32)
    eng.add_session("a", rng.integers(0, cfg.vocab_size, size=9),
                    max_new_tokens=5)
    eng.run_to_completion()
    assert eng.pool.free_pages < eng.num_pages
    eng.end_session("a")
    assert eng.pool.free_pages == eng.num_pages
    assert eng.kv.used_blocks == 0
    eng.check_invariants()

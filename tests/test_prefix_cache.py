"""Shared-prefix KV subsystem tests (ISSUE 7, DESIGN.md §13).

Three layers:

- **pool** — refcounted attach / copy-on-write / detach / release
  bookkeeping on ``PagedPool``, including the hard guarantees that a
  shared (refcount>1) page is never offloadable and the release report
  classifies orphans exactly;
- **radix** — ``PrefixCache`` lookup/register/forget/reclaim semantics:
  longest-prefix match across sessions' chains, partial-tail promotion,
  subtree forget on offload, and farthest-banked-next-use reclaim order
  (min-over-sharers Eq. 4 once every sharer detached);
- **engine** — the differential contract: with ``p_barge_in=0`` the
  ``prefix_cache=True`` engine is *bit-exact* in token values and
  client-visible event streams against the ``prefix_cache=False`` twin
  on full multi-turn replay traces (sharing changes timing, never
  content), refcount conservation (``sum(refcounts) == live block-table
  references``) holds after every round even under barge storms, the
  eviction-victim choice still agrees with a fresh Eq. 4 oracle (shared
  pinned pages excluded from the evictable budget), and a fixed pool
  holds strictly more resident sessions when one prompt family shares
  its prefix.
"""
import jax
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

try:                                   # deterministic fallback below
    import hypothesis                  # noqa: F401
    HAS_HYPOTHESIS = True
except ImportError:                    # pragma: no cover
    HAS_HYPOTHESIS = False
from test_differential import install_eviction_oracle

from repro.configs import get_config, reduced
from repro.kvcache.paged import OutOfPages, PagedPool
from repro.kvcache.prefix_cache import PrefixCache
from repro.models import init_params
from repro.serving.gateway.replay import (ReplayClock, ReplayConfig,
                                          ReplayGateway, run_replay)
from repro.serving.paged_engine import PagedRealtimeEngine
from repro.serving.workload import WorkloadConfig

NDEV = len(jax.devices())
multidev = pytest.mark.skipif(
    NDEV < 2,
    reason="needs >1 device; run under "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8")


@pytest.fixture(scope="module")
def tiny():
    cfg = reduced(get_config("qwen2-1.5b"), layers=2, d_model=64,
                  vocab=331)
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


# ======================================================================
# pool: refcounted attach / COW / detach
# ======================================================================
def test_attach_refcounts_and_release_report():
    pool = PagedPool(num_pages=8, page_size=4)
    pool.ensure_capacity("a", 8)                 # 2 private pages
    pages = list(pool.seq("a").pages)
    pool.attach_prefix("b", pages, 8)
    assert pool.seq("b").pages == pages and pool.seq("b").length == 8
    assert all(pool.refcount[p] == 2 for p in pages)
    assert pool.free_pages == 6                  # no new pages allocated
    # owner hangs up first: its pages orphan (charged to the cache side)
    rep = pool.release("a")
    assert rep == {"freed_own": 0, "freed_orphan": 0, "orphaned": 2}
    assert all(pool.refcount[p] == 1 for p in pages)
    assert all(pool.page_owner[p] is None for p in pages)
    # last sharer detaches; nothing holds the pages -> freed as orphans
    rep = pool.release("b")
    assert rep == {"freed_own": 0, "freed_orphan": 2, "orphaned": 0}
    assert pool.free_pages == 8 and not pool.refcount


def test_cache_held_pages_survive_release():
    pool = PagedPool(num_pages=8, page_size=4)
    pool.ensure_capacity("a", 8)
    pages = list(pool.seq("a").pages)
    pool.cache_held.update(pages)                # radix index holds them
    rep = pool.release("a")
    assert rep == {"freed_own": 0, "freed_orphan": 0, "orphaned": 2}
    # refcount 0 but still allocated: the index keeps them reclaimable
    assert all(pool.refcount[p] == 0 for p in pages)
    assert pool.free_pages == 6
    assert pool.cache_release(pages) == 2
    assert pool.free_pages == 8


def test_cow_reassigns_ownership():
    pool = PagedPool(num_pages=8, page_size=4)
    pool.ensure_capacity("a", 6)                 # page 1 partially filled
    pages = list(pool.seq("a").pages)
    pool.attach_prefix("b", pages, 6)
    # the attacher writes into the shared tail page -> COW
    old, new, was_owner = pool.cow("b", 1)
    assert old == pages[1] and new not in pages and not was_owner
    assert pool.refcount[old] == 1 and pool.refcount[new] == 1
    assert pool.page_owner[new] == "b" and pool.page_owner[old] == "a"
    assert pool.seq("b").pages[1] == new
    # the owner writing its own shared page also COWs, orphaning it
    pool.attach_prefix("c", pages[:1] + [new], 6)
    old2, new2, was_owner2 = pool.cow("a", 0)
    assert was_owner2 and pool.page_owner[old2] is None
    assert pool.page_owner[new2] == "a"


def test_shared_pages_never_offloadable():
    pool = PagedPool(num_pages=8, page_size=4)
    pool.ensure_capacity("a", 12)                # 3 pages
    pages = list(pool.seq("a").pages)
    pool.attach_prefix("b", pages[:2], 8)
    # suffix walk stops at the shared boundary: only the private page
    assert pool.evictable_suffix("a", 3) == ([], [2])
    with pytest.raises(AssertionError):
        pool.mark_offloading("a", [0])           # refcount 2
    pool.cache_held.add(pages[2])
    with pytest.raises(AssertionError):
        pool.mark_offloading("a", [2])           # indexed in the radix
    pool.cache_held.discard(pages[2])
    pool.mark_offloading("a", [2])               # private again: fine


def test_attacher_cannot_offload_orphaned_prefix():
    pool = PagedPool(num_pages=8, page_size=4)
    pool.ensure_capacity("a", 8)
    pages = list(pool.seq("a").pages)
    pool.attach_prefix("b", pages, 8)
    pool.release("a")                            # orphan: rc 1, owner None
    # the attacher's evictable suffix excludes pages it does not own
    # (they are charged to the cache, and it has no host copy of them)
    assert pool.evictable_suffix("b", 2) == ([], [])


def test_conservation_under_random_pool_ops():
    rng = np.random.default_rng(7)
    pool = PagedPool(num_pages=24, page_size=4)
    lengths = {}
    for step in range(300):
        sid = f"s{rng.integers(0, 6)}"
        op = rng.random()
        try:
            if sid not in lengths:
                donors = [d for d in lengths if lengths[d] >= 4]
                if op < 0.5 and donors:
                    d = donors[int(rng.integers(0, len(donors)))]
                    n_phys = int(rng.integers(1, lengths[d] // 4 + 1))
                    phys = pool.seq(d).pages[:n_phys]
                    pool.attach_prefix(sid, phys, n_phys * 4)
                    lengths[sid] = n_phys * 4
                else:
                    n = int(rng.integers(1, 9))
                    pool.ensure_capacity(sid, n)
                    lengths[sid] = n
            elif op < 0.5:
                lengths[sid] += int(rng.integers(1, 6))
                pool.ensure_capacity(sid, lengths[sid])
                li = (lengths[sid] - 1) // 4
                p = pool.seq(sid).pages[li]
                if pool.refcount[p] > 1:
                    pool.cow(sid, li)
            elif op < 0.8:
                pool.release(sid)
                del lengths[sid]
        except OutOfPages:
            if lengths:
                victim = sorted(lengths)[0]
                pool.release(victim)
                del lengths[victim]
        # the conservation invariant, every step
        from collections import Counter
        refs = Counter(p for sid2 in lengths
                       for p in pool.seq(sid2).pages if p >= 0)
        assert dict(refs) == {p: c for p, c in pool.refcount.items()
                              if c > 0}
        assert all(c >= 0 for c in pool.refcount.values())
        assert len(pool.refcount) + pool.free_pages == pool.num_pages


# ======================================================================
# radix index
# ======================================================================
def test_radix_lookup_register_roundtrip():
    c = PrefixCache(page_size=4)
    toks = list(range(10))
    newly = c.register(toks, [3, 7, 9])
    assert newly == [3, 7, 9] and len(c) == 3
    m, phys = c.lookup(toks)
    assert m == 10 and phys == [3, 7, 9]
    # partial match inside the tail page
    m, phys = c.lookup(toks[:9] + [99])
    assert m == 9 and phys == [3, 7, 9]
    # diverging in page 1: only page 0 matches
    m, phys = c.lookup([0, 1, 2, 3, 99, 5])
    assert m == 4 and phys == [3]
    m, phys = c.lookup([50, 51])
    assert m == 0 and phys == []


def test_radix_cross_session_chain():
    """A deeper chain registered by another session extends the match:
    KV for the same token prefix is bit-identical (PR 5), so lookups
    may mix pages from different registering sessions."""
    c = PrefixCache(page_size=4)
    c.register(list(range(4)), [1])
    newly = c.register(list(range(8)), [2, 5])   # page 0 already indexed
    assert newly == [5]                          # existing node wins
    m, phys = c.lookup(list(range(8)))
    assert m == 8 and phys == [1, 5]


def test_radix_partial_promotes_when_page_fills():
    c = PrefixCache(page_size=4)
    c.register([0, 1, 2, 3, 4, 5], [8, 9])       # page 9 partial (2 toks)
    m, phys = c.lookup([0, 1, 2, 3, 4, 5, 6])
    assert m == 6 and phys == [8, 9]
    # same physical page committed further -> the partial extends
    c.register([0, 1, 2, 3, 4, 5, 6], [8, 9])
    assert c.lookup([0, 1, 2, 3, 4, 5, 6, 7])[0] == 7
    # and promotes to a full node when it fills (the re-index reports
    # the page as newly held again; the caller's set-update is
    # idempotent)
    newly = c.register([0, 1, 2, 3, 4, 5, 6, 7], [8, 9])
    assert newly == [9]
    root_kids = c.root.children
    node = root_kids[(0, 1, 2, 3)]
    assert node.partial is None and (4, 5, 6, 7) in node.children
    assert c.lookup(list(range(8)))[0] == 8


def test_radix_forget_drops_subtree():
    c = PrefixCache(page_size=2)
    c.register([0, 1, 2, 3, 4, 5], [10, 11, 12])
    dropped = c.forget_phys([11])                # interior node
    assert sorted(dropped) == [11, 12]           # subtree goes with it
    assert c.lookup([0, 1, 2, 3])[0] == 2        # page 0 still indexed
    assert len(c) == 1


def test_radix_reclaim_order_and_protection():
    c = PrefixCache(page_size=2)
    c.register([0, 1, 2, 3], [5, 6])
    c.register([8, 9], [7])
    rc = {5: 1, 6: 0, 7: 0}                      # page 5 still attached
    c.on_detach([6], est=100.0, protect=-1.0)
    c.on_detach([7], est=50.0, protect=-1.0)
    # farthest banked next-use first; a referenced page never reclaims
    assert c.reclaim(3, now=0.0, refcount=rc) == [6, 7]
    assert len(c) == 1
    c2 = PrefixCache(page_size=2)
    c2.register([0, 1], [3])
    c2.on_detach([3], est=10.0, protect=5.0)
    assert c2.reclaim(1, now=4.0, refcount={3: 0}) == []   # protected
    assert c2.reclaimable(4.0, {3: 0}) == 0
    assert c2.reclaimable(6.0, {3: 0}) == 1
    assert c2.reclaim(1, now=6.0, refcount={3: 0}) == [3]


def test_radix_reclaimable_counts_whole_free_subtrees():
    c = PrefixCache(page_size=2)
    c.register([0, 1, 2, 3], [5, 6])
    # leaf free, root of the chain still referenced: only the leaf
    assert c.reclaimable(0.0, {5: 2, 6: 0}) == 1
    assert c.reclaimable(0.0, {5: 0, 6: 0}) == 2


# ======================================================================
# engine: differential bit-exactness + conservation + capacity
# ======================================================================
class _Recording(ReplayGateway):
    """Captures the client-visible event stream (token values, turn
    completions) in dispatch order for stream-exactness assertions.
    Internal prefill-progress events are excluded: skipping prefill of
    cached tokens is exactly what the subsystem does, so the cached
    plane emits fewer of them by design — what the client hears must
    still be identical."""

    def __init__(self, *a, **k):
        self.stream = []
        super().__init__(*a, **k)

    def _dispatch(self, events, sids):
        for slot in sorted(events):
            for kind, val in events[slot]:
                if kind in ("token", "finished"):
                    self.stream.append((sids[slot], kind, int(val)))
        super()._dispatch(events, sids)

    def per_session(self):
        """Per-session ordered event streams: cross-session
        interleaving is scheduling timing (skip-ahead finishes a
        cached prefill in fewer rounds), what each client receives is
        the contract."""
        out = {}
        for sid, kind, val in self.stream:
            out.setdefault(sid, []).append((kind, val))
        return out


def _replay(tiny_model, wl, seed, *, prefix, num_pages=64, mesh=None,
            slots=4, pages_per_seq=12, record=False, scan=False,
            rcfg=None):
    cfg, params = tiny_model
    clock = ReplayClock()
    eng = PagedRealtimeEngine(cfg, params, slots=slots, page_size=8,
                              pages_per_seq=pages_per_seq,
                              num_pages=num_pages, clock=clock,
                              mesh=mesh, fused_step=True,
                              prefix_cache=prefix)
    if scan:
        eng.kv.index_mode = "scan"
    cls = _Recording if record else ReplayGateway
    gw = cls(eng, wl, rcfg or ReplayConfig(max_turns=2, max_prompt=8),
             seed=seed)
    gw.run(check_every_round=eng.check_invariants)
    return gw


def _family_wl(seed, sessions=6, families=1, prefix_len=36, barge=0.0):
    return WorkloadConfig(kind="interactive", num_sessions=sessions,
                          seed=seed, p_barge_in=barge, arrival="poisson",
                          rate_rps=4.0, prompt_families=families,
                          family_prefix_len=prefix_len)


def _assert_bit_exact(tiny_model, seed, **wl_kw):
    wl = _family_wl(seed, **wl_kw)
    cached = _replay(tiny_model, wl, seed, prefix=True, record=True)
    control = _replay(tiny_model, wl, seed, prefix=False, record=True)
    hist = {sid: s.history for sid, s in cached.eng.sessions.items()}
    want = {sid: s.history for sid, s in control.eng.sessions.items()}
    assert hist == want                      # per-turn token values
    assert cached.per_session() == control.per_session()
    return cached


@pytest.mark.parametrize("seed", [0, 3, 5])
def test_prefix_cache_bit_exact_vs_control(tiny, seed):
    """Full multi-turn traces, one shared family, non-page-aligned
    prefix (COW on the shared tail page), tight enough pool for
    evict/reload churn: token values and event streams must be
    identical with sharing on and off. Sharing may only change timing
    and residency — with ``p_barge_in=0`` even timing-sensitive outputs
    coincide."""
    cached = _assert_bit_exact(tiny, seed)
    s = cached.metrics.summary()
    assert s["prefix_hit_tokens"] > 0        # sharing actually happened
    assert cached.eng.peak_shared_pages > 0


def test_prefix_cache_bit_exact_under_eviction(tiny):
    """A pool sized to force evictions mid-trace: reloads of private
    pages interleave with shared attaches, still bit-exact."""
    wl = _family_wl(9, sessions=6, prefix_len=32)
    cached = _replay(tiny, wl, 9, prefix=True, num_pages=28, record=True)
    control = _replay(tiny, wl, 9, prefix=False, num_pages=28,
                      record=True)
    assert {s: e.history for s, e in cached.eng.sessions.items()} \
        == {s: e.history for s, e in control.eng.sessions.items()}
    assert cached.per_session() == control.per_session()


@multidev
@pytest.mark.parametrize("shape", [(1, 2), (1, 8)])
def test_prefix_cache_bit_exact_on_mesh(tiny, shape):
    """Sharing is placement-stable (distributed/paged.py): the sharded
    engine with the prefix cache matches the unsharded control
    bit-exactly — attach only repoints block tables at physical ids
    every shard already serves."""
    if shape[0] * shape[1] > NDEV:
        pytest.skip(f"mesh {shape} > {NDEV} devices")
    wl = _family_wl(4, sessions=4, prefix_len=20)
    mesh = jax.make_mesh(shape, ("data", "model"))
    cached = _replay(tiny, wl, 4, prefix=True, mesh=mesh, record=True)
    control = _replay(tiny, wl, 4, prefix=False, record=True)
    assert {s: e.history for s, e in cached.eng.sessions.items()} \
        == {s: e.history for s, e in control.eng.sessions.items()}
    assert cached.per_session() == control.per_session()
    assert cached.eng.peak_shared_pages > 0


@pytest.mark.parametrize("seed,barge", [(1, 0.5), (6, 0.3), (11, 0.7)])
def test_refcount_conservation_under_barge_storms(tiny, seed, barge):
    """Barge-ins abort turns mid-prefill and mid-decode while sessions
    attach/detach/COW/evict; ``check_invariants`` (which asserts
    ``sum(refcounts) == live block-table references`` plus the full
    charging partition) runs after every round. Timing diverges under
    barges, so only conservation — not bit-exactness — is asserted."""
    gw = _replay(tiny, _family_wl(seed, sessions=6, prefix_len=36,
                                  barge=barge),
                 seed, prefix=True, num_pages=40)
    gw.eng.check_invariants()
    assert gw.metrics.summary()["prefix_hit_tokens"] > 0


def _conservation_property(tiny, seed, sessions, prefix_len, barge,
                           pages):
    """Random attach/detach/COW/evict/barge interleavings: conservation
    after every round, and with barges off the token streams also match
    the no-sharing control."""
    wl = _family_wl(seed, sessions=sessions, prefix_len=prefix_len,
                    barge=barge)
    gw = _replay(tiny, wl, seed, prefix=True, num_pages=pages)
    gw.eng.check_invariants()
    if barge == 0.0:
        control = _replay(tiny, wl, seed, prefix=False, num_pages=pages)
        assert {s: e.history for s, e in gw.eng.sessions.items()} \
            == {s: e.history for s, e in control.eng.sessions.items()}


if HAS_HYPOTHESIS:
    @pytest.mark.slow
    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 10_000), sessions=st.integers(3, 7),
           prefix_len=st.integers(8, 40),
           barge=st.sampled_from([0.0, 0.3, 0.6]),
           pages=st.sampled_from([32, 40, 64]))
    def test_refcount_conservation_property(tiny, seed, sessions,
                                            prefix_len, barge, pages):
        _conservation_property(tiny, seed, sessions, prefix_len, barge,
                               pages)
else:
    # hypothesis is optional (requirements-dev.txt); rather than skip,
    # the property runs over a pinned corner-case grid so the soak is
    # always-on in tier-1
    @pytest.mark.slow
    @pytest.mark.parametrize(
        "seed,sessions,prefix_len,barge,pages",
        [(0, 3, 8, 0.0, 32), (7, 5, 21, 0.3, 40),
         (123, 7, 40, 0.6, 64)])
    def test_refcount_conservation_property(tiny, seed, sessions,
                                            prefix_len, barge, pages):
        _conservation_property(tiny, seed, sessions, prefix_len, barge,
                               pages)


def test_eviction_oracle_with_shared_pages(tiny):
    """Victim choice under sharing still agrees with a fresh Eq. 4
    ranking: shared-pinned pages are excluded from every session's
    evictable budget (they are not offloadable), and the remaining
    ranking is the same min-next-use policy the differential harness
    checks on the private plane."""
    cfg, params = tiny
    clock = ReplayClock()
    eng = PagedRealtimeEngine(cfg, params, slots=4, page_size=8,
                              pages_per_seq=12, num_pages=18,
                              clock=clock, fused_step=True,
                              prefix_cache=True)
    eng.kv.index_mode = "scan"
    violations = install_eviction_oracle(eng.kv)
    wl = _family_wl(2, sessions=8, prefix_len=32)
    gw = ReplayGateway(eng, wl, ReplayConfig(max_turns=2, max_prompt=8),
                       seed=2)
    gw.run(check_every_round=eng.check_invariants)
    assert eng.offload_events, "pool never under pressure: test is vacuous"
    assert violations == []


def test_fixed_pool_holds_more_sessions_with_sharing(tiny):
    """The acceptance criterion: >=8 sessions of one prompt family on a
    fixed pool — the prefix-cache engine keeps strictly more sessions
    fully resident (pinned hot) than the no-sharing control before
    ``OutOfPages``."""
    cfg, params = tiny
    fam = np.random.default_rng(0).integers(0, cfg.vocab_size,
                                            size=32).astype(np.int32)
    suffix = np.random.default_rng(1).integers(
        0, cfg.vocab_size, size=(16, 4)).astype(np.int32)

    def admit_until_full(prefix):
        eng = PagedRealtimeEngine(cfg, params, slots=2, page_size=8,
                                  pages_per_seq=8, num_pages=16,
                                  fused_step=True, prefix_cache=prefix)
        resident = 0
        for i in range(16):
            sid = f"s{i}"
            try:
                eng.add_session(sid, np.concatenate([fam, suffix[i]]),
                                max_new_tokens=2)
            except OutOfPages:
                break
            eng.run_to_completion()
            eng.kv.pin(sid)          # hold every finished session hot
            eng.check_invariants()
            resident += 1
        return resident, eng

    n_cached, eng_c = admit_until_full(True)
    n_control, _ = admit_until_full(False)
    assert n_cached >= 8
    assert n_cached > n_control
    assert eng_c.prefix_cache.hit_tokens > 0


def test_migration_resolves_shared_pages(tiny):
    """Fleet live-migration of sessions attached to shared pages:
    draining replica 0 migrates its sessions mid-trace, so the source
    deep-copies each attached prefix into the migration payload and the
    destination rebuilds a private context; invariants (including
    conservation and the charging partition) hold on both replicas
    after every round."""
    from repro.serving.fleet.replay import run_fleet_replay
    cfg, params = tiny

    def factory(clock):
        return PagedRealtimeEngine(cfg, params, slots=2, page_size=8,
                                   pages_per_seq=12, num_pages=48,
                                   clock=clock, fused_step=True,
                                   prefix_cache=True)

    wl = _family_wl(3, sessions=6, families=1, prefix_len=24)
    m, gw = run_fleet_replay(
        factory, 2, wl, ReplayConfig(max_turns=2, max_prompt=8),
        seed=3, drain_after_routes=(0, 6))
    for e in gw.replicas:
        e.check_invariants()
    assert m.migrations > 0
    assert any(e.peak_shared_pages > 0 for e in gw.replicas)

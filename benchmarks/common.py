"""Shared benchmark helpers. Every benchmark emits CSV rows
(name, us_per_call, derived) via ``rows``; ``us_per_call`` is the mean
virtual-clock (or wall-clock where stated) cost of the benchmarked unit,
``derived`` a compact metric string tied to the paper artifact."""
from __future__ import annotations

import time

from repro.core.scheduler import SchedulerConfig
from repro.serving.costmodel import PIPELINES
from repro.serving.simulator import run_sim
from repro.serving.workload import WorkloadConfig

SYSTEMS = {
    # baseline naming follows the paper (§7.1)
    "vllm-omni-wo": dict(policy="fcfs", kv_policy="none", preload=False),
    "vllm-omni": dict(policy="fcfs", kv_policy="lru", preload=False),
    "liveserve": dict(policy="liveserve"),
}


def sim(model: str, kind: str, *, system: str = "liveserve", c: int = 8,
        n: int = 24, pbi: float = 0.0, seed: int = 3, gb: float = 4.0,
        until: float = 2500.0, arrival=None, rate=None, **kw):
    pipe = PIPELINES[model](kv_capacity_gb=gb)
    wcfg = dict(kind=kind, num_sessions=n, seed=seed, p_barge_in=pbi)
    if arrival is None:
        wcfg["concurrency"] = c
    else:
        wcfg.update(arrival=arrival, rate_rps=rate or 2.0)
    wl = WorkloadConfig(**wcfg)
    opts = dict(SYSTEMS[system])
    opts.update(kw)
    return run_sim(pipe, wl, until=until, **opts)


def fmt(v, nd=3):
    try:
        return f"{v:.{nd}f}"
    except (TypeError, ValueError):
        return str(v)


# every row() lands here so drivers can serialize a whole run
# (benchmarks/run.py --json-out; the CI smoke artifact)
ROWS: list = []


def row(name: str, us_per_call, derived: str) -> str:
    ROWS.append({"name": name, "us_per_call": us_per_call,
                 "derived": derived})
    line = f"{name},{fmt(us_per_call, 1)},{derived}"
    print(line, flush=True)
    return line

"""Kernel benchmarks: allclose vs oracle + wall time of the jnp oracle
path on CPU (the Pallas kernels execute in interpret mode here — Mosaic
timings only exist on real TPUs, so the derived metric reports achieved
correctness + oracle-path throughput, and the roofline table carries the
TPU-side projections)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import fmt, row
from repro.kernels import ref
from repro.kernels.flash_prefill import flash_prefill
from repro.kernels.paged_attention import (paged_attention,
                                           paged_prefill_attention)
from repro.kernels.ssd_scan import ssd_scan


def _time(fn, *args, reps=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def run(quick=False):
    out = []
    ks = jax.random.split(jax.random.PRNGKey(0), 8)

    # flash prefill
    B, Hq, Hkv, S, D = 1, 8, 2, 512, 64
    q = jax.random.normal(ks[0], (B, Hq, S, D))
    k = jax.random.normal(ks[1], (B, Hkv, S, D))
    v = jax.random.normal(ks[2], (B, Hkv, S, D))
    got = flash_prefill(q, k, v, causal=True, block_q=128, block_kv=128,
                        interpret=True)
    want = ref.flash_prefill_ref(q, k, v, causal=True)
    err = float(jnp.max(jnp.abs(got - want)))
    us = _time(jax.jit(lambda *a: ref.flash_prefill_ref(*a)), q, k, v)
    flops = 4 * B * Hq * S * S * D
    out.append(row("kernel/flash_prefill", us,
                   f"maxerr={err:.2e};oracle_gflops={fmt(flops/us/1e3)}"))

    # paged attention decode
    B, Hq, Hkv, D, page, pps = 32, 8, 2, 64, 16, 16
    npages = B * pps + 8
    q = jax.random.normal(ks[3], (B, Hq, D))
    kp = jax.random.normal(ks[4], (npages, page, Hkv, D))
    vp = jax.random.normal(ks[5], (npages, page, Hkv, D))
    bt = jax.random.permutation(ks[6], npages)[:B * pps].reshape(
        B, pps).astype(jnp.int32)
    sl = jnp.full((B,), page * pps - 3, jnp.int32)
    got = paged_attention(q, kp, vp, bt, sl, interpret=True)
    want = ref.paged_attention_ref(q, kp, vp, bt, sl)
    err = float(jnp.max(jnp.abs(got - want)))
    us = _time(jax.jit(lambda *a: ref.paged_attention_ref(*a)),
               q, kp, vp, bt, sl)
    out.append(row("kernel/paged_attention", us,
                   f"maxerr={err:.2e};kv_bytes={kp.nbytes * 2}"))

    # fused paged prefill/verify: tokens/s vs Q bucket (the shape the
    # fused round and the speculative verify step launch — autotune's
    # target; DESIGN.md §16)
    for Q in (1, 4) if quick else (1, 4, 8):
        kq = jax.random.split(jax.random.PRNGKey(Q), 2)
        qq = jax.random.normal(kq[0], (B, Q, Hq, D))
        q_lens = jnp.full((B,), Q, jnp.int32)
        q_start = sl - Q
        got = paged_prefill_attention(qq, kp, vp, bt, q_start, q_lens,
                                      interpret=True)
        want = ref.paged_prefill_attention_ref(qq, kp, vp, bt, q_start,
                                               q_lens)
        err = float(jnp.max(jnp.abs(got - want)))
        us = _time(jax.jit(lambda *a: ref.paged_prefill_attention_ref(*a)),
                   qq, kp, vp, bt, q_start, q_lens)
        out.append(row(
            f"kernel/paged_prefill_attention/q{Q}", us,
            f"maxerr={err:.2e};tokens_s={fmt(B * Q / (us / 1e6))}"))

    # ssd scan
    b, l, h, p, n = 1, 512, 4, 64, 128
    X = jax.random.normal(ks[7], (b, l, h, p)) * 0.5
    dA = -jnp.abs(jax.random.normal(ks[0], (b, l, h))) * 0.3
    Bm = jax.random.normal(ks[1], (b, l, h, n)) * 0.5
    Cm = jax.random.normal(ks[2], (b, l, h, n)) * 0.5
    Y, st = ssd_scan(X, dA, Bm, Cm, chunk=64, interpret=True)
    Yr, str_ = ref.ssd_scan_ref(X, dA, Bm, Cm)
    err = float(jnp.max(jnp.abs(Y - Yr)))
    us = _time(jax.jit(lambda *a: ref.ssd_scan_ref(*a)[0]), X, dA, Bm, Cm)
    out.append(row("kernel/ssd_scan", us, f"maxerr={err:.2e};chunk=64"))
    return out

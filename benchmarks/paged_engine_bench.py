"""Real-data-plane microbenchmarks: paged vs dense decode-step latency
and DRAM->HBM reload time per page.

Section ``paged_engine`` of benchmarks/run.py. These are wall-clock
numbers for the CPU container (Pallas interpret mode) — a perf
trajectory for future PRs on the paged engine, not absolutes; on TPU the
paged step runs the Mosaic kernel.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import fmt, row


def _mean_step_us(eng, steps: int):
    t0 = time.perf_counter()
    n = 0
    for _ in range(steps):
        if not eng.step():
            break
        n += 1
    return (time.perf_counter() - t0) / max(1, n) * 1e6, n


def run(quick: bool = False) -> None:
    from repro.configs import get_config, reduced
    from repro.models import init_params
    from repro.serving.engine import RealtimeLLMEngine
    from repro.serving.paged_engine import PagedRealtimeEngine

    cfg = reduced(get_config("qwen2-1.5b"), layers=2, d_model=64,
                  vocab=512)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    slots = 4
    steps = 8 if quick else 32

    def admit(eng):
        for i in range(slots):
            eng.add_session(f"s{i}",
                            rng.integers(0, cfg.vocab_size, size=16),
                            max_new_tokens=steps + 16)

    dense = RealtimeLLMEngine(cfg, params, slots=slots, capacity=256)
    admit(dense)
    dense.step()
    dense.step()                               # warm the jit cache
    us, n = _mean_step_us(dense, steps)
    row("paged_engine/dense_step", us, f"slots={slots};rounds={n}")

    paged = PagedRealtimeEngine(cfg, params, slots=slots, page_size=16,
                                pages_per_seq=16)
    admit(paged)
    paged.step()
    paged.step()
    us, n = _mean_step_us(paged, steps)
    row("paged_engine/paged_step", us, f"slots={slots};rounds={n}")

    # tensor-sharded data plane (DESIGN.md §9): decode step time and
    # tokens/s vs mesh shape. Needs >1 device — on CPU run under
    # XLA_FLAGS=--xla_force_host_platform_device_count=8 (the CI
    # multidevice job does); on a single device the section reports a
    # skip row so the JSON artifact stays schema-stable.
    ndev = len(jax.devices())
    mesh_shapes = [(1, m) for m in (2, 4, 8) if m <= ndev]
    if ndev >= 4:
        mesh_shapes.append((2, 2))
    if not mesh_shapes:
        row("paged_engine/sharded_step", 0.0,
            f"skipped;devices={ndev};need>=2")
    for d, m in mesh_shapes:
        mesh = jax.make_mesh((d, m), ("data", "model"))
        sharded = PagedRealtimeEngine(cfg, params, slots=slots,
                                      page_size=16, pages_per_seq=16,
                                      mesh=mesh)
        admit(sharded)
        sharded.step()
        sharded.step()                     # warm the sharded jit cache
        us, n = _mean_step_us(sharded, steps)
        tok_s = slots / (us * 1e-6) if us else 0.0
        row(f"paged_engine/sharded_step_{d}x{m}", us,
            f"kind={sharded.layout.kind};slots={slots};rounds={n};"
            f"tokens_s={tok_s:.0f}")

    # DRAM->HBM reload path: finish the turns (unpin), offload suffix
    # pages via the manager (flushed so the copies are durably in DRAM
    # — otherwise copy-then-free would hand them back for free), then
    # time the physical reload per page (the engine's per-chunk io
    # records the staged host->device wall time)
    paged.run_to_completion()
    want = 4 if quick else 8
    freed = paged.kv.evict(want, paged.clock.now())
    paged.flush_transfers()
    paged.reload_wall_s.clear()
    reloaded = 0
    for sid in list(paged.kv.sessions):
        n = paged.kv.missing_blocks(sid)
        if n > 0:
            paged.kv.reload(sid, paged.clock.now(), background=False)
            reloaded += n
    us_page = sum(paged.reload_wall_s) / max(1, reloaded) * 1e6
    page_kb = np.prod(paged.k_pages.shape[2:]) * 2 \
        * paged.k_pages.dtype.itemsize * cfg.num_layers / 1024.0
    row("paged_engine/reload_per_page", us_page,
        f"pages={reloaded};evicted={freed};page_kb={page_kb:.1f}")

    _fused_prefill_section(cfg, params, quick)
    _spec_decode_section(cfg, params, quick)
    _overlap_section(cfg, params, quick)
    _prefix_section(cfg, params, quick)


class _StreamOracle:
    """Draft proposer that replays a known greedy stream — the
    perfect-acceptance upper bound (self-speculation with an oracle).
    Verification is lossless either way; this isolates the *launch*
    economics of speculative decode from proposer quality."""

    def __init__(self, prompt_len: int, stream):
        self.prompt_len = prompt_len
        self.stream = [int(t) for t in stream]
        self.session_id = None            # set by the engine

    def propose(self, history, k):
        if self.session_id != "s":
            return [0] * k                # warm turns: any tokens do —
            #                               rejected drafts only compile
            #                               the verify bucket
        g = len(history) - self.prompt_len
        return self.stream[g:g + k]


def _spec_decode_section(cfg, params, quick: bool) -> None:
    """Speculative multi-token decode (DESIGN.md §16, the ISSUE 10
    acceptance row): the same seeded turn decodes on the plain fused
    plane (one committed token per launch) and under ``spec_decode=4``
    with an oracle proposer replaying the greedy stream (the perfect-
    acceptance bound: 1+K committed tokens per launch). Accepted
    streams must be bit-exact; accepted tokens per wall-second is the
    headline (acceptance: >= 2x at K=4)."""
    from repro.core.session import Phase
    from repro.serving.paged_engine import PagedRealtimeEngine

    rng = np.random.default_rng(4)
    K = 4
    P, N = (16, 24) if quick else (16, 48)
    prompt = rng.integers(0, cfg.vocab_size, size=P)
    warm_prompt = rng.integers(0, cfg.vocab_size, size=P)

    def decode_turn(spec: int, proposer=None):
        eng = PagedRealtimeEngine(cfg, params, slots=2, page_size=16,
                                  pages_per_seq=16, fused_step=True,
                                  spec_decode=spec, proposer=proposer)
        grant = 1 + spec
        # throwaway turn compiles the prefill bucket and the decode /
        # verify bucket outside the timed window (max_new large enough
        # that the draft budget is not clamped below K on round one —
        # otherwise the full verify bucket compiles inside the timing)
        warm = eng.submit_turn("warm", warm_prompt, max_new_tokens=12)
        while eng.active():
            s = eng.slot_state[warm]
            g = P if s.request.phase == Phase.PREFILL else grant
            eng.run_round({warm: g})
        # the warm turn's junk drafts land in the counters; the reported
        # accounting should cover the measured turn only
        eng.spec_drafted = eng.spec_accepted = 0
        eng.spec_rejected = eng.spec_rounds = 0
        slot = eng.submit_turn("s", prompt, max_new_tokens=N)
        toks = []
        while eng.slot_state[slot].request.phase == Phase.PREFILL:
            for ev in eng.run_round({slot: P})[slot]:
                if ev[0] == "token":
                    toks.append(ev[1])
        t0 = time.perf_counter()
        launches = 0
        while eng.active():
            for ev in eng.run_round({slot: grant})[slot]:
                if ev[0] == "token":
                    toks.append(ev[1])
            launches += 1
        wall = time.perf_counter() - t0
        eng.check_invariants()
        return toks, wall, launches, eng

    base_toks, base_wall, base_launch, _ = decode_turn(0)
    oracle = _StreamOracle(P, base_toks)
    spec_toks, spec_wall, spec_launch, eng = decode_turn(K, oracle)
    assert spec_toks == base_toks, "spec stream drifted from control"
    assert eng.spec_accepted + eng.spec_rejected == eng.spec_drafted
    base_tps = len(base_toks) / base_wall
    spec_tps = len(spec_toks) / spec_wall
    row("paged_engine/spec_decode_off", base_wall / len(base_toks) * 1e6,
        f"tokens_s={base_tps:.0f};launches={base_launch};tokens={N}")
    row("paged_engine/spec_decode_k4", spec_wall / len(spec_toks) * 1e6,
        f"tokens_s={spec_tps:.0f};speedup={spec_tps / base_tps:.2f};"
        f"launches={spec_launch};"
        f"accept_rate={eng.spec_accepted / max(1, eng.spec_drafted):.2f};"
        f"tokens_per_launch="
        f"{(eng.spec_rounds + eng.spec_accepted) / max(1, eng.spec_rounds):.2f};"
        f"bit_exact=1")


def _fused_prefill_section(cfg, params, quick: bool) -> None:
    """Fused vs per-token chunked prefill (DESIGN.md §11, the ISSUE 5
    acceptance row): the same long prompt is teacher-forced through
    ``run_round`` under identical 16-token chunk grants on both planes.
    The fused plane runs each grant as ONE jitted launch; the per-token
    control pays one launch per prompt token — the measured tokens/s
    gap is the point of the fused refactor."""
    from repro.core.session import Phase
    from repro.serving.paged_engine import PagedRealtimeEngine

    rng = np.random.default_rng(2)
    P = 64 if quick else 128
    chunk = 16
    prompt = rng.integers(0, cfg.vocab_size, size=P)
    stats = {}
    for fused in (True, False):
        eng = PagedRealtimeEngine(cfg, params, slots=2, page_size=16,
                                  pages_per_seq=16, fused_step=fused)
        # a throwaway turn warms every compiled shape outside the
        # timed window (Q=chunk and Q=1 buckets on the fused plane)
        warm = eng.submit_turn(
            "warm", rng.integers(0, cfg.vocab_size, size=chunk),
            max_new_tokens=2)
        while eng.active():
            eng.run_round({warm: chunk})
        slot = eng.submit_turn("s", prompt, max_new_tokens=2)
        launches0 = eng.fused_launches
        t0 = time.perf_counter()
        rounds = 0
        while eng.slot_state[slot].request.phase == Phase.PREFILL:
            eng.run_round({slot: chunk})
            rounds += 1
        wall = time.perf_counter() - t0
        eng.check_invariants()
        name = "fused" if fused else "tokenwise"
        stats[name] = P / wall
        launches = (eng.fused_launches - launches0) if fused \
            else P                       # one jitted launch per token
        row(f"paged_engine/prefill_{name}", wall / P * 1e6,
            f"tokens_s={P / wall:.0f};prompt={P};chunk={chunk};"
            f"rounds={rounds};launches={launches}")
    row("paged_engine/prefill_fused_speedup",
        (1.0 / stats["tokenwise"] - 1.0 / stats["fused"]) * 1e6,
        f"fused_over_tokenwise={stats['fused'] / stats['tokenwise']:.2f};"
        f"prompt={P};chunk={chunk}")


def _prefix_section(cfg, params, quick: bool) -> None:
    """Shared-prefix KV capacity (ISSUE 7, DESIGN.md §13): sessions of
    one prompt family (identical 32-token system prompt, unique
    4-token suffix) are admitted and pinned hot until the fixed pool
    refuses the next one. With the radix prefix cache each new session
    attaches to the family's committed pages and pays only its private
    suffix; without it every session carries full private copies. The
    row reports resident sessions cached vs control (the ISSUE 7
    acceptance: strictly more) and the attach-time prefill saving."""
    from repro.kvcache.paged import OutOfPages
    from repro.serving.paged_engine import PagedRealtimeEngine

    rng = np.random.default_rng(3)
    fam = rng.integers(0, cfg.vocab_size, size=32)
    suffixes = rng.integers(0, cfg.vocab_size, size=(16, 4))

    def fill(prefix: bool):
        eng = PagedRealtimeEngine(cfg, params, slots=2, page_size=8,
                                  pages_per_seq=8, num_pages=16,
                                  fused_step=True, prefix_cache=prefix)
        resident, ttfp = 0, []
        for i in range(16):
            t0 = time.perf_counter()
            try:
                eng.add_session(f"s{i}",
                                np.concatenate([fam, suffixes[i]]),
                                max_new_tokens=2)
            except OutOfPages:
                break
            ttfp.append(time.perf_counter() - t0)
            eng.run_to_completion()
            eng.kv.pin(f"s{i}")          # hold every session hot
            resident += 1
        eng.check_invariants()
        # sessions after the first skip the family prefill entirely;
        # the second is excluded too — the first attacher pays the
        # one-time jit compile of the small suffix-only query bucket
        later = ttfp[2:] or [0.0]
        return resident, sum(later) / len(later) * 1e6, eng

    n_cached, us_cached, eng = fill(True)
    n_control, us_control, _ = fill(False)
    hit = eng.prefix_cache.hit_tokens
    lookups = eng.prefix_cache.lookups
    row("paged_engine/prefix_resident_sessions", us_cached,
        f"cached={n_cached};control={n_control};pool_pages=16;"
        f"family_prefix=32;hit_tokens={hit};lookups={lookups}")
    row("paged_engine/prefix_attach_turn_start",
        us_cached,
        f"control_us={fmt(us_control, 1)};"
        f"speedup={us_control / max(us_cached, 1e-9):.2f};"
        f"cow_copies={eng.cow_copies};"
        f"peak_shared={eng.peak_shared_pages}")


def _overlap_drive(cfg, params, quick: bool, kv_quant: str):
    """Shared overlap workload (one drive per wire format): a's
    speech-time preloads drain chunk-by-chunk between b's decode
    rounds across ``turns`` evict/reload cycles."""
    import jax.numpy as jnp
    from repro.serving.paged_engine import PagedRealtimeEngine

    rng = np.random.default_rng(1)
    page_size = 8
    bytes_per_token = 2 * cfg.num_layers * cfg.num_kv_heads \
        * cfg.resolved_head_dim * jnp.dtype(cfg.dtype).itemsize
    # ~0.2 modeled s per fp32 page: slow enough that the time credit
    # never fires inside the bench's millisecond rounds — every
    # off-path page got there by a real drain between decode
    # sub-batches. int8 shrinks per-page channel time by its wire scale.
    eng = PagedRealtimeEngine(
        cfg, params, slots=2, page_size=page_size, pages_per_seq=12,
        num_pages=64, chunk_pages=1,
        pcie_gb_s=bytes_per_token * page_size / 0.2e9,
        kv_quant=kv_quant)
    per_page_s = eng.kv.channel.transfer_time(1)
    turns = 2 if quick else 3
    evict_pages = 4
    t0 = time.perf_counter()
    eng.add_session("a", rng.integers(0, cfg.vocab_size, size=24),
                    max_new_tokens=6)
    eng.run_to_completion()
    eng.add_session("b", rng.integers(0, cfg.vocab_size, size=8),
                    max_new_tokens=12 * turns + 6)
    for _ in range(turns):
        # idle gap long enough to lapse the previous preload's
        # protection TTL, so the eviction pass can pick a again
        eng.clock.tick(12.0)
        assert eng.kv.evict(evict_pages, eng.clock.now()) == evict_pages
        eng.flush_transfers()                # copies durable in DRAM
        window = (evict_pages + 2) * per_page_s / 0.8
        eng.user_speech_start("a", expected_dur_s=window)
        for _ in range(evict_pages + 2):     # b decodes; chunks drain
            eng.step()
        eng.start_turn("a", rng.integers(0, cfg.vocab_size, size=4),
                       max_new_tokens=3)
        # drive only a's turn to completion (b keeps its budget)
        while any(s is not None and s.session_id == "a"
                  and s.request.is_live()
                  for s in eng.slot_state.values()):
            eng.step()
    eng.check_invariants()
    return eng, turns, time.perf_counter() - t0


def _overlap_section(cfg, params, quick: bool) -> None:
    """Async chunked transfer overlap (ISSUE 4): the fraction of
    preloaded reload bytes completed off the turn critical path
    (acceptance: >= 0.70) plus the mean per-chunk drain wall time —
    then the same workload on the int8 KV wire tier (DESIGN.md §14):
    identical trace, ~4x less modeled PCIe per page, so the overlap
    fraction must hold or improve while reload wire bytes drop under
    0.5x of fp32 (the quantized acceptance rows)."""
    results = {}
    for kv_quant in ("fp32", "int8"):
        eng, turns, wall = _overlap_drive(cfg, params, quick, kv_quant)
        st = eng.transfer.stats
        stalls = [t["reload_stall_s"]
                  for t in eng.sessions["a"].turn_stats[1:]]
        results[kv_quant] = (eng, st)
        suffix = "" if kv_quant == "fp32" else "_int8"
        row(f"paged_engine/reload_overlap_frac{suffix}",
            st.overlap_fraction() * 100.0,
            f"off_path={st.reload_pages_off_path};"
            f"on_path={st.reload_pages_on_path};turns={turns};"
            f"mean_stall_ms="
            f"{fmt(1e3 * sum(stalls) / max(1, len(stalls)))};"
            f"wall_s={fmt(wall, 2)}")
        if kv_quant == "fp32":
            walls = eng.reload_wall_s            # per-chunk staged io
            row("paged_engine/transfer_chunk_drain",
                sum(walls) / max(1, len(walls)) * 1e6,
                f"chunks={st.chunks_drained};"
                f"reload_chunks={len(walls)};"
                f"chunk_pages={eng.transfer.chunk_pages}")

    # quantized wire + DRAM-capacity rows: same trace, so the logical
    # page flow is identical and the byte ratios are pure codec effect
    eng8, st8 = results["int8"]
    _, st32 = results["fp32"]
    bb = eng8.kv.channel.block_bytes
    ratio = st8.reload_wire_bytes / max(1e-9, st32.reload_wire_bytes)
    row("paged_engine/quant_reload_wire_bytes", st8.reload_wire_bytes,
        f"fp32_bytes={st32.reload_wire_bytes:.0f};"
        f"int8_over_fp32={ratio:.3f};"
        f"wire_bytes_saved={st8.wire_bytes_saved:.0f}")
    # the offload tier's capacity win: host-store bytes per offloaded
    # page (the DRAM tier holds ~1/wire_scale more sessions per GB)
    kb8 = bb * eng8.kv.channel.wire_scale / 1024.0
    kb32 = bb / 1024.0
    row("paged_engine/quant_dram_page_kb", kb8,
        f"fp32_kb={fmt(kb32)};"
        f"pages_per_gb_int8={int(1e9 / (kb8 * 1024))};"
        f"pages_per_gb_fp32={int(1e9 / (kb32 * 1024))}")

"""Real-data-plane microbenchmarks: paged vs dense decode-step latency
and DRAM->HBM reload time per page.

Section ``paged_engine`` of benchmarks/run.py. These are wall-clock
numbers for the CPU container (Pallas interpret mode) — a perf
trajectory for future PRs on the paged engine, not absolutes; on TPU the
paged step runs the Mosaic kernel.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import row


def _mean_step_us(eng, steps: int):
    t0 = time.perf_counter()
    n = 0
    for _ in range(steps):
        if not eng.step():
            break
        n += 1
    return (time.perf_counter() - t0) / max(1, n) * 1e6, n


def run(quick: bool = False) -> None:
    from repro.configs import get_config, reduced
    from repro.models import init_params
    from repro.serving.engine import RealtimeLLMEngine
    from repro.serving.paged_engine import PagedRealtimeEngine

    cfg = reduced(get_config("qwen2-1.5b"), layers=2, d_model=64,
                  vocab=512)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    slots = 4
    steps = 8 if quick else 32

    def admit(eng):
        for i in range(slots):
            eng.add_session(f"s{i}",
                            rng.integers(0, cfg.vocab_size, size=16),
                            max_new_tokens=steps + 16)

    dense = RealtimeLLMEngine(cfg, params, slots=slots, capacity=256)
    admit(dense)
    dense.step()
    dense.step()                               # warm the jit cache
    us, n = _mean_step_us(dense, steps)
    row("paged_engine/dense_step", us, f"slots={slots};rounds={n}")

    paged = PagedRealtimeEngine(cfg, params, slots=slots, page_size=16,
                                pages_per_seq=16)
    admit(paged)
    paged.step()
    paged.step()
    us, n = _mean_step_us(paged, steps)
    row("paged_engine/paged_step", us, f"slots={slots};rounds={n}")

    # tensor-sharded data plane (DESIGN.md §9): decode step time and
    # tokens/s vs mesh shape. Needs >1 device — on CPU run under
    # XLA_FLAGS=--xla_force_host_platform_device_count=8 (the CI
    # multidevice job does); on a single device the section reports a
    # skip row so the JSON artifact stays schema-stable.
    ndev = len(jax.devices())
    mesh_shapes = [(1, m) for m in (2, 4, 8) if m <= ndev]
    if ndev >= 4:
        mesh_shapes.append((2, 2))
    if not mesh_shapes:
        row("paged_engine/sharded_step", 0.0,
            f"skipped;devices={ndev};need>=2")
    for d, m in mesh_shapes:
        mesh = jax.make_mesh((d, m), ("data", "model"))
        sharded = PagedRealtimeEngine(cfg, params, slots=slots,
                                      page_size=16, pages_per_seq=16,
                                      mesh=mesh)
        admit(sharded)
        sharded.step()
        sharded.step()                     # warm the sharded jit cache
        us, n = _mean_step_us(sharded, steps)
        tok_s = slots / (us * 1e-6) if us else 0.0
        row(f"paged_engine/sharded_step_{d}x{m}", us,
            f"kind={sharded.layout.kind};slots={slots};rounds={n};"
            f"tokens_s={tok_s:.0f}")

    # DRAM->HBM reload path: finish the turns (unpin), offload suffix
    # pages via the manager, then time the physical reload per page (the
    # engine's hook records the host->device wall time)
    paged.run_to_completion()
    want = 4 if quick else 8
    freed = paged.kv.evict(want, paged.clock.now())
    paged.reload_wall_s.clear()
    reloaded = 0
    for sid in list(paged.kv.sessions):
        n = paged.kv.missing_blocks(sid)
        if n > 0:
            paged.kv.reload(sid, paged.clock.now(), background=False)
            reloaded += n
    us_page = sum(paged.reload_wall_s) / max(1, reloaded) * 1e6
    page_kb = np.prod(paged.k_pages.shape[2:]) * 2 \
        * paged.k_pages.dtype.itemsize * cfg.num_layers / 1024.0
    row("paged_engine/reload_per_page", us_page,
        f"pages={reloaded};evicted={freed};page_kb={page_kb:.1f}")

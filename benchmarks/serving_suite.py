"""Paper-figure benchmarks (Figs. 10-18) on the interaction harness.

Each function reproduces one figure's experiment shape at laptop scale:
the policies under test are the real LiveServe implementation; baselines
are the substrate behaviors (FCFS + LRU / no-offload)."""
from __future__ import annotations

from benchmarks.common import SYSTEMS, fmt, row, sim
from repro.core.scheduler import SchedulerConfig
from repro.serving.costmodel import PIPELINES
from repro.serving.simulator import Simulation, run_sim
from repro.serving.workload import WorkloadConfig


def frontier(quick=False):
    """Fig. 10: throughput-latency frontier, 2 models x 3 workloads."""
    out = []
    models = ["qwen3-omni-like"] if quick else list(PIPELINES)
    kinds = ["sharegpt", "interactive"] if quick else \
        ["sharegpt", "interactive", "mixed"]
    cs = [4, 8] if quick else [2, 4, 8, 12, 16]
    for model in models:
        for kind in kinds:
            for system in ("vllm-omni-wo", "vllm-omni", "liveserve"):
                for c in cs:
                    m = sim(model, kind, system=system, c=c,
                            n=4 * c, pbi=0.3)
                    s = m.summary()
                    out.append(row(
                        f"frontier/{model}/{kind}/{system}/c{c}",
                        s["p90_ttfp"] * 1e6,
                        f"rps={fmt(s['completed_rps'])}"
                        f";p90ttfp={fmt(s['p90_ttfp'])}"))
    return out


def tail_latency(quick=False):
    """Fig. 11 left: TTFP distribution at fixed c=8, no barge-in."""
    out = []
    for system in ("vllm-omni", "liveserve"):
        m = sim("qwen3-omni-like", "sharegpt", system=system, c=8, n=32,
                gb=2.0)
        s = m.summary()
        out.append(row(
            f"tail_latency/{system}", s["p90_ttfp"] * 1e6,
            f"p50={fmt(s['p50_ttfp'])};p90={fmt(s['p90_ttfp'])}"
            f";p95={fmt(s['p95_ttfp'])}"))
    return out


def continuity(quick=False):
    """Fig. 11 right: playback continuity under concurrency pressure."""
    out = []
    for c in ([8, 12] if quick else [8, 12, 16]):
        for system in ("vllm-omni-wo", "vllm-omni", "liveserve"):
            m = sim("qwen3-omni-like", "sharegpt", system=system, c=c,
                    n=3 * c, gb=2.0)
            out.append(row(
                f"continuity/{system}/c{c}", m.p90_ttfp() * 1e6,
                f"continuity={fmt(m.continuity())}"))
    return out


def arrivals(quick=False):
    """Fig. 12: Poisson vs BurstGPT open-loop arrivals."""
    out = []
    for arrival in ("poisson", "burstgpt"):
        for system in ("vllm-omni", "liveserve"):
            m = sim("qwen3-omni-like", "sharegpt", system=system,
                    arrival=arrival, rate=4.0, n=32, gb=2.0)
            s = m.summary()
            out.append(row(
                f"arrivals/{arrival}/{system}", s["p90_ttfp"] * 1e6,
                f"rps={fmt(s['completed_rps'])}"
                f";p90ttfp={fmt(s['p90_ttfp'])}"))
    return out


def bargein_sensitivity(quick=False):
    """Fig. 13: sweep configured barge-in probability."""
    out = []
    pbis = [0.0, 0.5, 1.0] if quick else [0.0, 0.3, 0.5, 0.7, 1.0]
    for pbi in pbis:
        for system in ("vllm-omni", "liveserve"):
            m = sim("qwen3-omni-like", "sharegpt", system=system, c=8,
                    n=32, pbi=pbi)
            s = m.summary()
            out.append(row(
                f"bargein/p{pbi}/{system}", s["p90_ttfp"] * 1e6,
                f"rps={fmt(s['completed_rps'])}"
                f";waste={fmt(s['waste_ratio'])}"))
    return out


def ablation(quick=False):
    """Fig. 14: add components one by one (scheduler / +eviction /
    +preload), with and without barge-in."""
    variants = [
        ("base", dict(policy="fcfs", kv_policy="lru", preload=False)),
        ("+sched", dict(policy="liveserve", kv_policy="lru",
                        preload=False)),
        ("+evict", dict(policy="liveserve", kv_policy="next_use",
                        preload=False)),
        ("+preload(full)", dict(policy="liveserve")),
    ]
    out = []
    for pbi in (0.0, 0.5):
        for name, kw in variants:
            pipe = PIPELINES["qwen3-omni-like"](kv_capacity_gb=1.5)
            wl = WorkloadConfig(kind="interactive", num_sessions=24,
                                concurrency=12, seed=3, p_barge_in=pbi)
            m = run_sim(pipe, wl, until=2500.0, **kw)
            s = m.summary()
            out.append(row(
                f"ablation/pbi{pbi}/{name}", s["p90_ttfp"] * 1e6,
                f"rps={fmt(s['completed_rps'])}"
                f";waste={fmt(s['waste_ratio'])}"
                f";stall_ms={fmt(s['mean_reload_stall'] * 1000, 1)}"))
    return out


def rtf_pacing(quick=False):
    """Fig. 15: RTF stays < 1 while generation stretches toward playback."""
    out = []
    for system in ("vllm-omni", "liveserve"):
        m = sim("qwen3-omni-like", "sharegpt", system=system, c=8, n=32,
                pbi=0.5)
        s = m.summary()
        spans = [(t.gen_span_s, t.audio_delivered_s) for t in m.turns
                 if t.completed and t.audio_delivered_s > 20]
        stretch = (sum(a / b for a, b in spans) / len(spans)
                   if spans else float("nan"))
        out.append(row(
            f"rtf_pacing/{system}", s["p90_ttfp"] * 1e6,
            f"p50rtf={fmt(s['p50_rtf'])};p90rtf={fmt(s['p90_rtf'])}"
            f";genspan_frac={fmt(stretch)}"))
    return out


def token_waste(quick=False):
    """Fig. 16 left: generated-but-unheard tokens vs barge-in prob."""
    out = []
    for pbi in (0.3, 0.7, 1.0):
        base = sim("qwen3-omni-like", "sharegpt", system="vllm-omni",
                   c=8, n=32, pbi=pbi).waste_ratio()
        live = sim("qwen3-omni-like", "sharegpt", system="liveserve",
                   c=8, n=32, pbi=pbi).waste_ratio()
        cut = 1 - live / base if base else 0.0
        out.append(row(
            f"token_waste/p{pbi}", 0.0,
            f"baseline={fmt(base)};liveserve={fmt(live)}"
            f";waste_cut={fmt(cut)}"))
    return out


def reload_path(quick=False):
    """Fig. 16 right: KV reload on/off the next-turn critical path."""
    out = []
    for system in ("vllm-omni", "liveserve"):
        pipe = PIPELINES["qwen3-omni-like"](kv_capacity_gb=0.75)
        wl = WorkloadConfig(kind="interactive", num_sessions=24,
                            concurrency=12, seed=5)
        s = Simulation(pipe, wl, **SYSTEMS[system])
        m = s.run(until=2500.0)
        stalls = [t.reload_stall_s for t in m.turns if t.turn_index > 0]
        onpath = sum(stalls) / max(1, len(stalls))
        pre = s.preloaders["thinker"].stats
        out.append(row(
            f"reload_path/{system}", onpath * 1e6,
            f"onpath_ms={fmt(onpath * 1000, 2)}"
            f";preload_hits={pre.hits};sync={pre.sync_fallbacks}"))
    return out


def kv_residency(quick=False):
    """Fig. 17: thinker GPU KV residency under KV-aware U2 ordering."""
    out = []
    for name, kw in (("kv-unaware", dict(policy="liveserve",
                                         sched_cfg=SchedulerConfig(
                                             enable_u2_utility=False))),
                     ("kv-aware", dict(policy="liveserve"))):
        pipe = PIPELINES["qwen3-omni-like"](kv_capacity_gb=1.5)
        wl = WorkloadConfig(kind="interactive", num_sessions=24,
                            concurrency=12, seed=7)
        s = Simulation(pipe, wl, **kw)
        m = s.run(until=2500.0)
        log = s.kvs["thinker"].residency_log
        mean_res = (sum(v for _, v in log) / len(log)) if log else 0
        peak = max((v for _, v in log), default=0)
        out.append(row(
            f"kv_residency/{name}", m.p90_ttfp() * 1e6,
            f"mean_blocks={mean_res:.0f};peak_blocks={peak}"
            f";rps={fmt(m.completed_rps())}"))
    return out


def continuity_timeline(quick=False):
    """Fig. 18: continuity under BurstGPT arrivals, with/without barge."""
    out = []
    for pbi in (0.0, 0.5):
        for system in ("vllm-omni", "liveserve"):
            m = sim("qwen3-omni-like", "sharegpt", system=system,
                    arrival="burstgpt", rate=6.0, n=32, pbi=pbi, gb=2.0)
            out.append(row(
                f"continuity_timeline/pbi{pbi}/{system}",
                m.p90_ttfp() * 1e6,
                f"continuity={fmt(m.continuity())}"
                f";waste={fmt(m.waste_ratio())}"))
    return out

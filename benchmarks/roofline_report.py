"""§Roofline table: reads experiments/dryrun/*.json (produced by
``python -m repro.launch.dryrun``) and prints per-cell roofline terms.

Runnable directly: ``python -m benchmarks.roofline_report
[--out-dir DIR]`` prints the same CSV rows the bench driver collects.
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from benchmarks.common import fmt, row


def load(out_dir="experiments/dryrun"):
    cells = []
    for fn in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(fn) as f:
            cells.append(json.load(f))
    return cells


def run(quick=False, out_dir="experiments/dryrun"):
    out = []
    for c in load(out_dir):
        name = f"roofline/{c['arch']}/{c['shape']}/{c['mesh']}"
        if c["status"] == "skip":
            out.append(row(name, 0.0, "SKIP:" + c["reason"][:40]))
            continue
        if c["status"] != "ok":
            out.append(row(name, 0.0, "ERROR:" + c.get("error", "?")[:60]))
            continue
        dom_s = max(c["compute_term_s"], c["memory_term_s"],
                    c["collective_term_s"])
        uf = c.get("useful_flops_fraction")
        out.append(row(
            name, dom_s * 1e6,
            f"dom={c['dominant']};c={c['compute_term_s']:.2e}"
            f";m={c['memory_term_s']:.2e}"
            f";coll={c['collective_term_s']:.2e}"
            f";useful={fmt(uf) if uf else 'n/a'}"
            f";peak_gb={c['memory_analysis'].get('peak_memory_in_bytes', 0)/1e9:.1f}"))
    if not out:
        # actionable instead of silent: say whether the dir is missing
        # or merely has no cell JSONs, and what produces them
        state = ("no such dir" if not os.path.isdir(out_dir)
                 else "dir has no *.json cells")
        out.append(row(
            "roofline/none", 0.0,
            f"{state}:{out_dir};run python -m repro.launch.dryrun first"))
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="experiments/dryrun",
                    help="dryrun cell directory to report on")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(out_dir=args.out_dir)          # row() prints each line


if __name__ == "__main__":
    main()

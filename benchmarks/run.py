"""Benchmark driver — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. ``--quick`` shrinks sweeps.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    args = ap.parse_args()

    from benchmarks import eviction_index, kernel_bench, \
        paged_engine_bench, roofline_report
    from benchmarks import serving_suite as S

    benches = {
        "frontier": S.frontier,                      # Fig. 10
        "tail_latency": S.tail_latency,              # Fig. 11 (left)
        "continuity": S.continuity,                  # Fig. 11 (right)
        "arrivals": S.arrivals,                      # Fig. 12
        "bargein_sensitivity": S.bargein_sensitivity,  # Fig. 13
        "ablation": S.ablation,                      # Fig. 14
        "rtf_pacing": S.rtf_pacing,                  # Fig. 15
        "token_waste": S.token_waste,                # Fig. 16 (left)
        "reload_path": S.reload_path,                # Fig. 16 (right)
        "kv_residency": S.kv_residency,              # Fig. 17
        "continuity_timeline": S.continuity_timeline,  # Fig. 18
        "eviction_index": eviction_index.run,        # Table 1
        "paged_engine": paged_engine_bench.run,      # real data plane
        "kernels": kernel_bench.run,
        "roofline": roofline_report.run,             # §Roofline
    }
    only = set(args.only.split(",")) if args.only else None
    print("name,us_per_call,derived")
    t0 = time.time()
    for name, fn in benches.items():
        if only and name not in only:
            continue
        t1 = time.time()
        try:
            fn(quick=args.quick)
        except Exception as e:                       # noqa: BLE001
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}",
                  file=sys.stderr)
            print(f"{name}/ERROR,0.0,{type(e).__name__}")
        print(f"# {name} done in {time.time()-t1:.1f}s", flush=True)
    print(f"# total {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()

"""Benchmark driver — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. ``--quick`` shrinks sweeps.
``--smoke`` is the CI perf-trajectory job: only the real-data-plane
sections (paged_engine + gateway) on tiny configs. ``--json-out FILE``
additionally serializes every row (plus per-section timings) as JSON —
the artifact the smoke workflow uploads so a perf history accumulates.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

SMOKE_SECTIONS = ("paged_engine", "gateway")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-config real-data-plane sections only "
                         "(implies --quick)")
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    ap.add_argument("--json-out", default=None,
                    help="write all rows + timings to this JSON file")
    args = ap.parse_args()

    from benchmarks import autotune_bench, common, eviction_index, \
        gateway_bench, kernel_bench, paged_engine_bench, roofline_report
    from benchmarks import serving_suite as S

    benches = {
        "frontier": S.frontier,                      # Fig. 10
        "tail_latency": S.tail_latency,              # Fig. 11 (left)
        "continuity": S.continuity,                  # Fig. 11 (right)
        "arrivals": S.arrivals,                      # Fig. 12
        "bargein_sensitivity": S.bargein_sensitivity,  # Fig. 13
        "ablation": S.ablation,                      # Fig. 14
        "rtf_pacing": S.rtf_pacing,                  # Fig. 15
        "token_waste": S.token_waste,                # Fig. 16 (left)
        "reload_path": S.reload_path,                # Fig. 16 (right)
        "kv_residency": S.kv_residency,              # Fig. 17
        "continuity_timeline": S.continuity_timeline,  # Fig. 18
        "eviction_index": eviction_index.run,        # Table 1
        "paged_engine": paged_engine_bench.run,      # real data plane
        "gateway": gateway_bench.run,                # DESIGN.md §4
        "kernels": kernel_bench.run,
        "autotune": autotune_bench.run,              # DESIGN.md §16
        "roofline": roofline_report.run,             # §Roofline
    }
    only = set(args.only.split(",")) if args.only else None
    quick = args.quick or args.smoke
    if args.smoke:
        only = set(SMOKE_SECTIONS) & (only or set(SMOKE_SECTIONS))
        if not only:
            ap.error(f"--only selects no smoke sections "
                     f"(smoke runs {','.join(SMOKE_SECTIONS)})")
    print("name,us_per_call,derived")
    t0 = time.time()
    timings = {}
    errors = 0
    for name, fn in benches.items():
        if only and name not in only:
            continue
        t1 = time.time()
        try:
            fn(quick=quick)
        except Exception as e:                       # noqa: BLE001
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}",
                  file=sys.stderr)
            # through row() so the crash also lands in the JSON artifact
            common.row(f"{name}/ERROR", 0.0, type(e).__name__)
            errors += 1
        timings[name] = time.time() - t1
        print(f"# {name} done in {timings[name]:.1f}s", flush=True)
    total = time.time() - t0
    print(f"# total {total:.1f}s")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump({"rows": common.ROWS, "section_s": timings,
                       "total_s": total,
                       "mode": ("smoke" if args.smoke
                                else "quick" if args.quick else "full")},
                      f, indent=1)
        print(f"# wrote {len(common.ROWS)} rows to {args.json_out}",
              flush=True)
    if errors and args.smoke:
        # the CI smoke job must go red when a section breaks — a green
        # run with ERROR rows would silently stop measuring
        sys.exit(1)


if __name__ == "__main__":
    main()

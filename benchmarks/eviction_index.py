"""Table 1: heap-based eviction index vs tail scanning — REAL wall-clock
(host-side CPU work in both the paper and here)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import fmt, row
from repro.core.kv_manager import KVManager
from repro.core.monitor import RuntimeMonitor


class _Clock:
    t = 0.0

    def now(self):
        return self.t


def _setup(index_mode: str, n_sessions: int):
    clock = _Clock()
    mon = RuntimeMonitor(clock)
    kv = KVManager(capacity_blocks=n_sessions * 8, block_size=16,
                   bytes_per_token=1e5, monitor=mon, policy="next_use",
                   index_mode=index_mode, clock=clock)
    rng = np.random.default_rng(0)
    for i in range(n_sessions):
        sid = f"s{i}"
        mon.register(sid)
        v = mon.view(sid)
        v.playback.started = True
        v.playback.play_end = float(rng.uniform(0, 60))
        v.playback.appended_s = v.playback.play_end + 1
        v.reply_gap_ema = float(rng.uniform(0.5, 5))
        s = kv.session(sid)
        s.total_blocks = s.hbm_blocks = int(rng.integers(2, 9))
    return kv, clock


def run(quick=False):
    out = []
    n_sessions = 1000 if quick else 4000
    rounds = 400 if quick else 2000
    for mode in ("heap", "scan"):
        kv, clock = _setup(mode, n_sessions)
        overheads = []
        for i in range(rounds):
            clock.t += 0.01
            kv.evict(2, clock.t)
            # sessions come back (commit re-adds blocks + re-ranks)
            sid = f"s{i % n_sessions}"
            kv.commit_turn(sid, 6 * kv.block_size, clock.t)
        oh = np.array(kv.eviction_overhead_s) * 1000.0
        out.append(row(
            f"eviction_index/{mode}/n{n_sessions}",
            float(oh.mean()) * 1000.0,
            f"avg_ms={fmt(float(oh.mean()))};"
            f"p90_ms={fmt(float(np.percentile(oh, 90)))}"))
    return out

"""Kernel autotune sweep (DESIGN.md §16): tune the paged-attention
tiling knobs per shape, round-trip the JSON cache, and report what was
picked. Interpret-mode timings on CPU rank *relative* candidate cost
(grid-step count dominates there exactly as launch overhead does on
TPU); the roofline gate keeps a noisy timing from promoting a config
the arithmetic-intensity model prices absurdly.

The page=32 decode shape is the reproducibility probe: its static
default is kv_block=16 (``_default_kv_block`` caps pow2 pages at a
16-slot tile), while one grid step per whole page measurably wins in
interpret mode — so a correct sweep reproducibly selects the
non-default kv_block=32 (pinned by tests/test_autotune.py).
"""
from __future__ import annotations

import os

from benchmarks.common import fmt, row

CACHE = "experiments/autotune_cache.json"

# (kind, dims) swept per run; quick keeps the two decode shapes
SHAPES = [
    ("paged_attention",
     dict(B=4, Hq=4, Hkv=2, D=16, page=16, pps=4)),
    ("paged_attention",
     dict(B=4, Hq=4, Hkv=2, D=16, page=32, pps=4)),   # non-default probe
    ("paged_prefill_attention",
     dict(B=4, Hq=4, Hkv=2, D=16, page=16, pps=4, Q=4)),
]


def run(quick=False):
    from repro.kernels import autotune

    out = []
    shapes = SHAPES[:2] if quick else SHAPES
    os.makedirs(os.path.dirname(CACHE), exist_ok=True)
    autotune.enable(CACHE)
    try:
        for kind, dims in shapes:
            entry = autotune.sweep(kind, reps=2 if quick else 3, **dims)
            skey = autotune.shape_key(**dims)
            out.append(row(
                f"autotune/{kind}/page{dims['page']}",
                entry["measured_us"],
                f"kv_block={entry['kv_block']}"
                f";head_block={entry['head_block']}"
                f";default_us={fmt(entry['default_us'], 1)}"
                f";speedup={fmt(entry['default_us'] / entry['measured_us'])}"
                f";model_us={fmt(entry['model_us'], 1)}"))
            # the cache must actually serve the entry it just stored
            assert autotune.lookup(kind, skey) == entry
        path = autotune.save()
        n = autotune.enable(path)                    # round-trip reload
        out.append(row("autotune/cache", 0.0,
                       f"entries={n};path={path}"))
        # the cache persists across runs by design (>= this sweep);
        # every shape swept just now must be served back verbatim
        assert n >= len(shapes), (n, len(shapes))
        for kind, dims in shapes:
            assert autotune.lookup(kind, autotune.shape_key(**dims)) \
                is not None, (kind, dims)
    finally:
        autotune.disable()
    return out

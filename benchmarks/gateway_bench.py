"""Realtime-gateway benchmark: liveserve vs fcfs on the real paged data
plane under open-loop barge-in load (DESIGN.md §4).

Section ``gateway`` of benchmarks/run.py. The same seeded workload is
replayed through two gateways (same model, same engine geometry, one
compiled step shared); rows report tail TTFP, continuity, token waste,
and completed-turn throughput per policy, plus mean round wall time —
the perf trajectory the smoke CI job accumulates. Wall-clock numbers
for the CPU container (Pallas interpret mode); on TPU the step runs the
Mosaic kernel.
"""
from __future__ import annotations

import time

from benchmarks.common import fmt, row


def run(quick: bool = False) -> dict:
    from repro.serving.gateway.harness import (build_gateway,
                                               run_gateway_workload,
                                               tiny_model)

    sessions = 4 if quick else 8
    max_response = 10 if quick else 16
    apt = 0.6
    model = tiny_model(0)
    out = {}
    for policy, cap in (("liveserve", 3.0), ("fcfs", None)):
        gw = build_gateway(policy=policy, scale=4.0, model=model,
                           frontier_cap_s=cap, round_token_budget=2,
                           pages_per_seq=10, audio_per_token_s=apt)
        t0 = time.perf_counter()
        m, gw = run_gateway_workload(
            policy=policy, sessions=sessions, barge_in=0.3, seed=0,
            rate_rps=8.0, max_response=max_response, max_prompt=12,
            gateway=gw, timeout_s=600)
        wall = time.perf_counter() - t0
        s = m.summary()
        out[policy] = s
        row(f"gateway/{policy}_p90_ttfp", s["p90_ttfp"] * 1e6,
            f"turns={s['turns']};continuity={fmt(s['continuity'], 2)};"
            f"waste={fmt(s['waste_ratio'], 3)};"
            f"rps={fmt(s['completed_rps'], 3)}")
        row(f"gateway/{policy}_round", wall / max(1, gw.rounds) * 1e6,
            f"rounds={gw.rounds};sessions={sessions};"
            f"over_frontier={fmt(gw.max_over_frontier_s, 3)}")
    if out["liveserve"]["p90_ttfp"] < out["fcfs"]["p90_ttfp"]:
        verdict = "liveserve_wins"
    else:
        verdict = "fcfs_wins"          # worth noticing in the artifact
    ratio = out["fcfs"]["p90_ttfp"] / max(1e-9,
                                          out["liveserve"]["p90_ttfp"])
    # value column is the p90 gap in us (schema-honest); the raw
    # speedup ratio rides in the derived field
    row("gateway/p90_ttfp_gap",
        (out["fcfs"]["p90_ttfp"] - out["liveserve"]["p90_ttfp"]) * 1e6,
        f"{verdict};fcfs_over_liveserve={fmt(ratio, 2)}")

    # reload-overlap workload (ISSUE 4 acceptance): a pool sized below
    # the aggregate KV of a multi-turn conversation set, so idle
    # sessions get evicted and every later turn rides the speech-time
    # preload. The row reports the fraction of modeled reload seconds
    # the async chunked transfer engine kept off the turn critical
    # path (target >= 70%).
    gw = build_gateway(policy="liveserve", scale=4.0, model=model,
                       frontier_cap_s=3.0, round_token_budget=2,
                       pages_per_seq=8, num_pages=12 if quick else 20,
                       slots=4, audio_per_token_s=apt,
                       preload_chunks=2)
    # per-turn sizes bounded so three turns fit the 64-token context
    # (pages_per_seq * page_size) with decode lookahead to spare
    m, gw = run_gateway_workload(
        policy="liveserve", sessions=3 if quick else 6, barge_in=0.2,
        seed=1, rate_rps=2.0, max_turns=3, max_prompt=8,
        max_response=8, gateway=gw, timeout_s=600)
    s = m.summary()
    ts = gw.engine.transfer.stats
    out["overlap"] = s
    row("gateway/reload_overlap_frac", s["reload_overlap_frac"] * 100.0,
        f"off_pages={ts.reload_pages_off_path};"
        f"on_pages={ts.reload_pages_on_path};"
        f"cancelled={ts.reload_pages_cancelled};"
        f"mean_stall_us={fmt(s['mean_reload_stall'] * 1e6, 1)};"
        f"mean_off_us={fmt(s['mean_reload_off_path'] * 1e6, 1)};"
        f"turns={s['turns']}")

    # long-prompt TTFT (ISSUE 5): tail first-audio when every prompt is
    # an order of magnitude longer than an utterance transcript — the
    # end-to-end number the fused one-launch chunked prefill
    # (DESIGN.md §11) moves. The 96-token clamp bites: interactive
    # trace prompts draw lognormal(median 120).
    gw = build_gateway(policy="liveserve", scale=4.0, model=model,
                       frontier_cap_s=3.0, round_token_budget=16,
                       prefill_chunk=16, pages_per_seq=16,
                       audio_per_token_s=apt)
    m, gw = run_gateway_workload(
        policy="liveserve", sessions=2 if quick else 4, barge_in=0.0,
        seed=2, rate_rps=2.0, max_turns=1, max_prompt=96,
        max_response=4, gateway=gw, timeout_s=600)
    s = m.summary()
    out["long_prompt"] = s
    row("gateway/long_prompt_ttfp", s["p90_ttfp"] * 1e6,
        f"p50_ttfp_us={fmt(s['p50_ttfp'] * 1e6, 1)};"
        f"turns={s['turns']};max_prompt=96;"
        f"fused_launches={gw.engine.fused_launches}")
    return out

"""Realtime-gateway benchmark: liveserve vs fcfs on the real paged data
plane under open-loop barge-in load (DESIGN.md §4).

Section ``gateway`` of benchmarks/run.py. The same seeded workload is
replayed through two gateways (same model, same engine geometry, one
compiled step shared); rows report tail TTFP, continuity, token waste,
and completed-turn throughput per policy, plus mean round wall time —
the perf trajectory the smoke CI job accumulates. Wall-clock numbers
for the CPU container (Pallas interpret mode); on TPU the step runs the
Mosaic kernel.
"""
from __future__ import annotations

import time

from benchmarks.common import fmt, row


def run(quick: bool = False) -> dict:
    from repro.serving.gateway.harness import (build_gateway,
                                               run_gateway_workload,
                                               tiny_model)

    sessions = 4 if quick else 8
    max_response = 10 if quick else 16
    apt = 0.6
    model = tiny_model(0)
    out = {}
    for policy, cap in (("liveserve", 3.0), ("fcfs", None)):
        gw = build_gateway(policy=policy, scale=4.0, model=model,
                           frontier_cap_s=cap, round_token_budget=2,
                           pages_per_seq=10, audio_per_token_s=apt)
        t0 = time.perf_counter()
        m, gw = run_gateway_workload(
            policy=policy, sessions=sessions, barge_in=0.3, seed=0,
            rate_rps=8.0, max_response=max_response, max_prompt=12,
            gateway=gw, timeout_s=600)
        wall = time.perf_counter() - t0
        s = m.summary()
        out[policy] = s
        row(f"gateway/{policy}_p90_ttfp", s["p90_ttfp"] * 1e6,
            f"turns={s['turns']};continuity={fmt(s['continuity'], 2)};"
            f"waste={fmt(s['waste_ratio'], 3)};"
            f"rps={fmt(s['completed_rps'], 3)}")
        row(f"gateway/{policy}_round", wall / max(1, gw.rounds) * 1e6,
            f"rounds={gw.rounds};sessions={sessions};"
            f"over_frontier={fmt(gw.max_over_frontier_s, 3)}")
    if out["liveserve"]["p90_ttfp"] < out["fcfs"]["p90_ttfp"]:
        verdict = "liveserve_wins"
    else:
        verdict = "fcfs_wins"          # worth noticing in the artifact
    ratio = out["fcfs"]["p90_ttfp"] / max(1e-9,
                                          out["liveserve"]["p90_ttfp"])
    # value column is the p90 gap in us (schema-honest); the raw
    # speedup ratio rides in the derived field
    row("gateway/p90_ttfp_gap",
        (out["fcfs"]["p90_ttfp"] - out["liveserve"]["p90_ttfp"]) * 1e6,
        f"{verdict};fcfs_over_liveserve={fmt(ratio, 2)}")

    # reload-overlap workload (ISSUE 4 acceptance): a pool sized below
    # the aggregate KV of a multi-turn conversation set, so idle
    # sessions get evicted and every later turn rides the speech-time
    # preload. The row reports the fraction of modeled reload seconds
    # the async chunked transfer engine kept off the turn critical
    # path (target >= 70%).
    # the same seeded workload runs once per KV wire format
    # (DESIGN.md §14): the int8 tier must keep the overlap fraction at
    # or above the fp32 run while its modeled reload wire bytes drop
    # under 0.5x — the quantized acceptance rows.
    for kv_quant in ("fp32", "int8"):
        gw = build_gateway(policy="liveserve", scale=4.0, model=model,
                           frontier_cap_s=3.0, round_token_budget=2,
                           pages_per_seq=8,
                           num_pages=12 if quick else 20,
                           slots=4, audio_per_token_s=apt,
                           preload_chunks=2, kv_quant=kv_quant)
        # per-turn sizes bounded so three turns fit the 64-token
        # context (pages_per_seq * page_size) with lookahead to spare
        m, gw = run_gateway_workload(
            policy="liveserve", sessions=3 if quick else 6,
            barge_in=0.2, seed=1, rate_rps=2.0, max_turns=3,
            max_prompt=8, max_response=8, gateway=gw, timeout_s=600)
        s = m.summary()
        ts = gw.engine.transfer.stats
        suffix = "" if kv_quant == "fp32" else "_int8"
        out[f"overlap{suffix}"] = s
        row(f"gateway/reload_overlap_frac{suffix}",
            s["reload_overlap_frac"] * 100.0,
            f"off_pages={ts.reload_pages_off_path};"
            f"on_pages={ts.reload_pages_on_path};"
            f"cancelled={ts.reload_pages_cancelled};"
            f"mean_stall_us={fmt(s['mean_reload_stall'] * 1e6, 1)};"
            f"mean_off_us={fmt(s['mean_reload_off_path'] * 1e6, 1)};"
            f"turns={s['turns']}")
    i8 = gw.engine.transfer.stats                 # the int8 run's ledger
    row("gateway/kv_wire_bytes_saved",
        out["overlap_int8"]["kv_wire_bytes_saved"],
        f"reload_wire_bytes={i8.reload_wire_bytes:.0f};"
        f"int8_over_fp32={gw.engine.kv.channel.wire_scale:.3f};"
        f"fp32_saved={out['overlap']['kv_wire_bytes_saved']:.0f}")

    # long-prompt TTFT (ISSUE 5): tail first-audio when every prompt is
    # an order of magnitude longer than an utterance transcript — the
    # end-to-end number the fused one-launch chunked prefill
    # (DESIGN.md §11) moves. The 96-token clamp bites: interactive
    # trace prompts draw lognormal(median 120).
    gw = build_gateway(policy="liveserve", scale=4.0, model=model,
                       frontier_cap_s=3.0, round_token_budget=16,
                       prefill_chunk=16, pages_per_seq=16,
                       audio_per_token_s=apt)
    m, gw = run_gateway_workload(
        policy="liveserve", sessions=2 if quick else 4, barge_in=0.0,
        seed=2, rate_rps=2.0, max_turns=1, max_prompt=96,
        max_response=4, gateway=gw, timeout_s=600)
    s = m.summary()
    out["long_prompt"] = s
    row("gateway/long_prompt_ttfp", s["p90_ttfp"] * 1e6,
        f"p50_ttfp_us={fmt(s['p50_ttfp'] * 1e6, 1)};"
        f"turns={s['turns']};max_prompt=96;"
        f"fused_launches={gw.engine.fused_launches}")

    # ------------------------------------------------- duplex / toolcall
    # full-duplex periodic-frame load (ISSUE 9 acceptance): every output
    # token carries a hard frame deadline (trace frame periods of 2-4
    # token-durations, armed at the turn request, advancing one period
    # per emitted frame). deadline_miss_rate at this concurrency is the
    # acceptance number (target <= 1%).
    gw = build_gateway(policy="liveserve", scale=4.0, model=model,
                       frontier_cap_s=3.0, round_token_budget=4,
                       pages_per_seq=10, audio_per_token_s=apt)
    m, gw = run_gateway_workload(
        policy="liveserve", kind="duplex", sessions=3 if quick else 4,
        barge_in=0.0, seed=6, rate_rps=4.0, max_prompt=12,
        max_response=max_response, gateway=gw, timeout_s=600)
    s = m.summary()
    out["duplex"] = s
    row("gateway/duplex_deadline_miss", s["deadline_miss_rate"] * 100.0,
        f"frames={s['frames']};turns={s['turns']};"
        f"p90_ttfp_us={fmt(s['p90_ttfp'] * 1e6, 1)};"
        f"continuity={fmt(s['continuity'], 2)}")

    # the same duplex trace under speculative decode (DESIGN.md §16),
    # identical geometry — the only delta is spec_decode=4 (the round
    # budget clamps decode grants, so drafts ride inside the same
    # budget): drafts verify in the same launch, so per-frame deadlines
    # can only gain slack — the row pins miss-with-spec <= non-spec
    gw = build_gateway(policy="liveserve", scale=4.0, model=model,
                       frontier_cap_s=3.0, round_token_budget=4,
                       pages_per_seq=10, audio_per_token_s=apt,
                       spec_decode=4)
    m, gw = run_gateway_workload(
        policy="liveserve", kind="duplex", sessions=3 if quick else 4,
        barge_in=0.0, seed=6, rate_rps=4.0, max_prompt=12,
        max_response=max_response, gateway=gw, timeout_s=600)
    ss = m.summary()
    out["duplex_spec"] = ss
    row("gateway/duplex_deadline_miss_spec",
        ss["deadline_miss_rate"] * 100.0,
        f"nonspec_miss={fmt(s['deadline_miss_rate'] * 100.0)};"
        f"frames={ss['frames']};turns={ss['turns']};"
        f"accept_rate={fmt(ss['spec_accept_rate'], 2)}")
    row("gateway/spec_tokens_per_launch",
        ss["spec_tokens_per_launch"],
        f"drafted={ss['spec_drafted']};accepted={ss['spec_accepted']};"
        f"rejected={ss['spec_rejected']};k=4")

    # agentic tool-call pauses: the session idles with hot KV while the
    # external tool runs. Protection covers min(tool latency, TTL); the
    # bench shrinks the TTL below the trace's 0.8-8s tool latencies so
    # long pauses lose the hot-KV guarantee under this under-sized pool
    # and the resume has to reload — the acceptance number is the share
    # of those resume reload pages the ToolCallResult-time preload kept
    # off the turn critical path, hidden in the fixed resume gap
    # (target >= 70%).
    gw = build_gateway(policy="liveserve", scale=4.0, model=model,
                       frontier_cap_s=3.0, round_token_budget=2,
                       pages_per_seq=8, num_pages=12 if quick else 16,
                       slots=4, audio_per_token_s=apt, preload_chunks=2)
    gw.engine.kv.tool_protect_ttl_s = 1.0
    m, gw = run_gateway_workload(
        policy="liveserve", kind="toolcall", sessions=3 if quick else 6,
        barge_in=0.0, seed=7, rate_rps=2.0, max_turns=3, max_prompt=8,
        max_response=8, gateway=gw, timeout_s=600)
    s = m.summary()
    out["toolcall"] = s
    row("gateway/toolcall_resume_off_path",
        s["tool_resume_off_path"] * 100.0,
        f"tool_pauses={s['tool_pauses']};"
        f"resume_reloads={s['tool_pause_reloads']};"
        f"turns={s['turns']};"
        f"p90_ttfp_us={fmt(s['p90_ttfp'] * 1e6, 1)}")

    # ------------------------------------------------------------ fleet
    # (ISSUE 6) capacity scaling: one replica under S sessions vs three
    # identical replicas under ceil(2.5*S) at 2.5x the arrival rate —
    # per-replica intensity slightly BELOW the single run, so "equal
    # P90 at >=2.5x the session count" is what near-linear data-parallel
    # scaling must deliver.
    from math import ceil
    from repro.serving.fleet.harness import (build_fleet_gateway,
                                             run_fleet_workload)
    single_s = 4 if quick else 6
    fleet_s = ceil(2.5 * single_s)
    geom = dict(scale=4.0, model=model, frontier_cap_s=3.0,
                round_token_budget=4, slots=4, pages_per_seq=10,
                audio_per_token_s=apt)
    # one process time-slices the three replicas' control rounds; a 3x
    # slower clock restores the per-replica round cadence a real fleet
    # (replicas on their own hosts) would have
    fgeom = dict(geom, scale=geom["scale"] / 3)
    gw = build_gateway(policy="liveserve", **geom)
    m, gw = run_gateway_workload(
        policy="liveserve", sessions=single_s, barge_in=0.3, seed=3,
        rate_rps=6.0, max_prompt=12, max_response=max_response,
        gateway=gw, timeout_s=600)
    single = m.summary()
    gw = build_fleet_gateway(replicas=3, policy="liveserve", **fgeom)
    m, gw = run_fleet_workload(
        policy="liveserve", sessions=fleet_s, barge_in=0.3, seed=3,
        rate_rps=15.0, max_prompt=12, max_response=max_response,
        gateway=gw, timeout_s=600)
    fleet = m.summary()
    out["fleet_single"], out["fleet"] = single, fleet
    routed = gw.router.routed
    row("gateway/fleet_capacity_p90_ttfp", fleet["p90_ttfp"] * 1e6,
        f"single_p90_us={fmt(single['p90_ttfp'] * 1e6, 1)};"
        f"sessions={fleet_s}v{single_s};"
        f"p90_ratio={fmt(fleet['p90_ttfp'] / max(1e-9, single['p90_ttfp']), 2)};"
        f"capacity_x={fmt(fleet_s / single_s, 2)}")
    # load skew across replicas: max/mean routed sessions (1.0 = even)
    row("gateway/fleet_load_skew",
        max(routed) / max(1e-9, sum(routed) / len(routed)),
        f"routed={','.join(str(r) for r in routed)};"
        f"peak_occ={','.join(fmt(o, 2) for o in fleet['replica_occupancy'])}")

    # forced-migration scenario: replica 0 drains once every session has
    # routed, so each of its sessions live-migrates at its next speech
    # start. Long utterances (speech_scale) give the MIGRATE drain +
    # interconnect hop room to hide; the off-path share of migration
    # seconds is the acceptance number (target >= 0.7), and migrated
    # turns' TTFP rides next to their non-migrated peers'.
    gw = build_fleet_gateway(replicas=3, policy="liveserve",
                             preload_chunks=2,
                             drain_after_routes=(0, 3 * single_s),
                             **fgeom)
    m, gw = run_fleet_workload(
        policy="liveserve", sessions=3 * single_s, barge_in=0.0, seed=4,
        rate_rps=6.0, max_prompt=12, max_response=max_response,
        speech_scale=3.0, gateway=gw, timeout_s=600)
    s = m.summary()
    out["fleet_migration"] = s
    mig_ttfp = [t.ttfp for t in m.turns
                if t.migrated and t.ttfp is not None]
    base_ttfp = [t.ttfp for t in m.turns
                 if not t.migrated and t.turn_index >= 1
                 and t.ttfp is not None]
    mean = lambda xs: sum(xs) / len(xs) if xs else 0.0   # noqa: E731
    row("gateway/fleet_migration_off_path",
        s["migration_off_path"] * 100.0,
        f"migrations={s['migrations']};"
        f"bytes={fmt(s['migration_bytes'], 0)};"
        f"off_s={fmt(s['migration_off_path_s'], 6)};"
        f"cancelled={len(gw.migrator.cancelled())}")
    row("gateway/fleet_migrated_ttfp", mean(mig_ttfp) * 1e6,
        f"migrated_turns={len(mig_ttfp)};"
        f"non_migrated_ttfp_us={fmt(mean(base_ttfp) * 1e6, 1)};"
        f"ratio={fmt(mean(mig_ttfp) / max(1e-9, mean(base_ttfp)), 2)}")

    # -------------------------------------------------------- prefix
    # shared-prefix workload (ISSUE 7 acceptance): >=8 sessions in one
    # prompt family (48-token shared system prompt), barge-in off so
    # both runs see the same trace content. The cached gateway attaches
    # each later session to the family's committed pages — the
    # prefix_hit_frac row is the acceptance number (target >= 0.5),
    # and turn-start TTFP rides next to the no-sharing control's.
    pfx_kw = dict(policy="liveserve", sessions=8, barge_in=0.0, seed=5,
                  rate_rps=4.0, max_turns=2, max_prompt=8,
                  max_response=6, prompt_families=1, family_prefix_len=48,
                  timeout_s=600)
    pfx_geom = dict(scale=4.0, model=model, frontier_cap_s=3.0,
                    round_token_budget=16, prefill_chunk=16,
                    page_size=8, pages_per_seq=12, slots=4,
                    audio_per_token_s=apt)
    gw = build_gateway(prefix_cache=True, **pfx_geom)
    m, gw = run_gateway_workload(gateway=gw, **pfx_kw)
    cached = m.summary()
    gw2 = build_gateway(prefix_cache=False, **pfx_geom)
    m2, gw2 = run_gateway_workload(gateway=gw2, **pfx_kw)
    control = m2.summary()
    out["prefix_cached"], out["prefix_control"] = cached, control
    row("gateway/prefix_hit_frac", cached["prefix_hit_frac"] * 100.0,
        f"hit_tokens={cached['prefix_hit_tokens']};"
        f"pages_shared={cached['pages_shared']};"
        f"cow_copies={gw.engine.cow_copies};"
        f"sessions=8;family_prefix=48;"
        f"control_hit_frac={fmt(control['prefix_hit_frac'], 3)}")
    row("gateway/prefix_turn_start_ttfp", cached["p90_ttfp"] * 1e6,
        f"control_p90_us={fmt(control['p90_ttfp'] * 1e6, 1)};"
        f"p50_us={fmt(cached['p50_ttfp'] * 1e6, 1)};"
        f"control_p50_us={fmt(control['p50_ttfp'] * 1e6, 1)};"
        f"turns={cached['turns']}")
    return out

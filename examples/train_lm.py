"""Train a small LM on the synthetic pipeline with the production train
step (grad accumulation, remat, checkpointing + restart).

Defaults are sized for a CPU container (~15M params, 60 steps); pass
``--steps 300 --d-model 768 --layers 12`` for the ~100M-param run on real
hardware. Loss must fall — the synthetic stream has learnable structure.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 60]
"""
import argparse
import os

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models import init_params
from repro.training import optimizer as opt_mod
from repro.training.checkpoint import latest_step, restore_checkpoint
from repro.training.data import synthetic_batches
from repro.training.train_loop import TrainConfig, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--vocab", type=int, default=4096)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = reduced(get_config("qwen3-4b"), layers=args.layers,
                  d_model=args.d_model, vocab=args.vocab)
    cfg = cfg.replace(num_heads=max(4, args.d_model // 64),
                      num_kv_heads=max(2, args.d_model // 128),
                      head_dim=64, d_ff=args.d_model * 4)
    print(f"training {cfg.num_params()/1e6:.1f}M params, "
          f"{args.steps} steps, batch {args.batch} x seq {args.seq}")

    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = opt_mod.OptConfig(kind="adamw", lr=1e-3)
    state = opt_mod.opt_init(opt, params)
    start = 0
    if args.resume and latest_step(args.ckpt_dir) is not None:
        tree, start = restore_checkpoint(args.ckpt_dir)
        params, state = tree["params"], tree["opt_state"]
        print(f"resumed from step {start}")

    data = synthetic_batches(cfg.vocab_size, args.batch, args.seq)
    params, state, hist = train_loop(
        cfg, params, state, data, steps=args.steps, opt=opt,
        tc=TrainConfig(microbatches=2, remat=False),
        checkpoint_every=max(10, args.steps // 4), ckpt_dir=args.ckpt_dir,
        log_every=max(1, args.steps // 12))
    for step, loss in hist:
        print(f"step {step:5d}  loss {loss:.4f}")
    first, last = hist[0][1], hist[-1][1]
    print(f"loss {first:.3f} -> {last:.3f} "
          f"({'FELL ✓' if last < first else 'did not fall ✗'})")


if __name__ == "__main__":
    main()

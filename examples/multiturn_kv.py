"""Multi-turn KV management walk-through: eviction ordering + speech-
triggered preload on a single session timeline (paper §5, Fig. 16-right
mechanism shown step by step).

Run:  PYTHONPATH=src python examples/multiturn_kv.py
"""
from repro.core.kv_manager import KVManager
from repro.core.monitor import RuntimeMonitor
from repro.core.preload import Preloader


class Clock:
    t = 0.0

    def now(self):
        return self.t


def main():
    clock = Clock()
    mon = RuntimeMonitor(clock)
    kv = KVManager(capacity_blocks=100, block_size=16,
                   bytes_per_token=147456.0,   # qwen3-class KV/token
                   monitor=mon, policy="next_use", clock=clock,
                   pcie_gb_s=25.0)
    pre = Preloader(kv, mon, speech_prior_s=2.5)

    # two sessions finish turns; "listener" has 40s of audio left to play,
    # "quiet" finished playback and will speak again soon
    for sid, play_left in (("listener", 40.0), ("quiet", 0.5)):
        mon.register(sid)
        v = mon.view(sid)
        v.playback.started = True
        v.playback.appended_s = 60.0
        v.playback.play_end = clock.t + play_left
        v.reply_gap_ema = 2.0
        kv.commit_turn(sid, 40 * 16, clock.t)       # 40 blocks each
    print(f"occupancy: {kv.occupancy():.2f} "
          f"({kv.used_blocks}/{kv.capacity} blocks)")
    for sid in ("listener", "quiet"):
        print(f"  T_next({sid}) = {kv.next_use_estimate(sid, clock.t):.1f}s")

    # HBM pressure: a new turn needs 30 blocks -> evict by next-use
    print("\n-- pressure: need 30 blocks --")
    kv.evict(30, clock.t)
    for sid in ("listener", "quiet"):
        s = kv.session(sid)
        print(f"  {sid}: hbm={s.hbm_blocks} dram={s.dram_blocks} "
              f"(LRU would have evicted 'quiet' — the WRONG victim)")

    # the listener barges in -> speech-triggered preload of its suffix
    print("\n-- barge-in on 'listener' at t=5s --")
    clock.t = 5.0
    mon.on_barge_in("listener")
    t = pre.on_speech_start("listener", clock.t)
    if t:
        print(f"  preload admitted: {t.blocks} blocks, "
              f"done at t={t.done:.2f}s (transfer "
              f"{(t.done-t.start)*1000:.0f} ms hidden under speech)")
    clock.t = 8.0   # user finished speaking; turn reaches the LLM stage
    stall = pre.on_turn_ready("listener", clock.t)
    print(f"  next-turn on-path reload stall: {stall*1000:.1f} ms "
          f"(sync fallback would pay the full transfer)")
    print(f"  preload stats: {pre.stats}")


if __name__ == "__main__":
    main()

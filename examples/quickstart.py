"""Quickstart: a REAL tiny model served end-to-end on CPU with the
LiveServe control plane making the scheduling decisions.

Three concurrent "sessions" prefill + decode against an actual JAX model
(reduced qwen3 family config); each decode round asks the
UrgencyScheduler which sessions run, with the KV manager tracking
block residency.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.core.kv_manager import KVManager
from repro.core.monitor import RuntimeMonitor
from repro.core.scheduler import RoundBudget, SchedulerConfig, \
    UrgencyScheduler
from repro.core.session import Phase, Request
from repro.models import decode_step, init_cache, init_params, prefill


class WallClock:
    def __init__(self):
        self.t0 = time.monotonic()

    def now(self):
        return time.monotonic() - self.t0


def main():
    cfg = reduced(get_config("qwen3-4b"), layers=2, d_model=64, vocab=512)
    print(f"model: {cfg.name} ({cfg.num_params()/1e3:.0f}K params)")
    params = init_params(cfg, jax.random.PRNGKey(0))

    clock = WallClock()
    monitor = RuntimeMonitor(clock)
    kv = KVManager(capacity_blocks=64, block_size=16, bytes_per_token=1024,
                   monitor=monitor, policy="next_use", clock=clock)
    sched = UrgencyScheduler(SchedulerConfig(), monitor, stage="thinker",
                             kv_occupancy=kv.occupancy)

    # three sessions, one decode slot batch (B=3 padded decode)
    B, prompt_len, gen_len = 3, 12, 16
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, prompt_len),
                                 0, cfg.vocab_size)
    cache = init_cache(cfg, B, prompt_len + gen_len)
    logits, cache = prefill(cfg, params, prompts, cache)
    print(f"prefill done: cache len = {cache['len'].tolist()}")

    reqs = []
    for i in range(B):
        monitor.register(f"s{i}")
        r = Request(session_id=f"s{i}", stage="thinker", turn_index=0,
                    arrival_time=clock.now(), prompt_len=prompt_len,
                    max_new_tokens=gen_len)
        r.phase = Phase.DECODE
        r.prefilled = prompt_len
        reqs.append(r)

    tokens = jnp.argmax(logits, axis=-1)
    outputs = [[int(tokens[i])] for i in range(B)]
    for step in range(gen_len - 1):
        budget = RoundBudget(token_budget=64, free_kv_blocks=kv.free_blocks)
        decision = sched.schedule(reqs, budget, clock.now())
        run_ids = {r.req_id for r in decision.batch}
        # decode the whole slot-batch; scheduler decides whose token counts
        logits, cache = decode_step(cfg, params, tokens, cache)
        tokens = jnp.argmax(logits, axis=-1)
        for i, r in enumerate(reqs):
            if r.req_id in run_ids and r.generated < gen_len:
                r.generated += 1
                if r.first_output_time is None:
                    r.first_output_time = clock.now()
                outputs[i].append(int(tokens[i]))
        kv.log_residency(clock.now())
    for i, toks in enumerate(outputs):
        print(f"s{i}: {len(toks)} tokens -> {toks[:10]}...")
    print(f"kv used blocks: {kv.used_blocks}, evicted: {kv.evicted_blocks}")
    print("OK")


if __name__ == "__main__":
    main()

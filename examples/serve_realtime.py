"""End-to-end driver: realtime Omni serving under interactive clients.

Simulated speech clients (VAD, 1x playback, barge-in, multi-turn) against
the full LiveServe pipeline (thinker -> talker -> vocoder engines with
urgency scheduling + interaction-aware KV management), compared with the
vLLM-Omni-style baselines — the laptop-scale version of the paper's §7.

Run:  PYTHONPATH=src python examples/serve_realtime.py [--sessions 32]

``--engine real`` instead drives a multi-turn barge-in conversation
through the PagedRealtimeEngine: a qwen2-1.5b-class reduced config on
actual paged JAX KV state, with physical evict-to-DRAM, speech-time
preload reload, and zero re-prefill on reloaded turns (DESIGN.md §3).
"""
import argparse

from repro.serving.costmodel import qwen3_omni_like
from repro.serving.simulator import run_sim
from repro.serving.workload import WorkloadConfig

SYSTEMS = {
    "vLLM-Omni-wo": dict(policy="fcfs", kv_policy="none", preload=False),
    "vLLM-Omni   ": dict(policy="fcfs", kv_policy="lru", preload=False),
    "LiveServe   ": dict(policy="liveserve"),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", default="sim", choices=["sim", "real"])
    ap.add_argument("--sessions", type=int, default=32)
    ap.add_argument("--concurrency", type=int, default=12)
    ap.add_argument("--barge-in", type=float, default=0.5)
    ap.add_argument("--workload", default="interactive",
                    choices=["sharegpt", "interactive", "mixed"])
    args = ap.parse_args()

    if args.engine == "real":
        from repro.serving.paged_engine import run_multiturn_demo
        run_multiturn_demo()
        print("\n(real paged data plane: reloaded turns pay zero "
              "re-prefill tokens; the preload hit hides the reload "
              "under user speech.)")
        return

    pipe = qwen3_omni_like(kv_capacity_gb=2.0)
    wl = WorkloadConfig(kind=args.workload, num_sessions=args.sessions,
                        concurrency=args.concurrency, seed=0,
                        p_barge_in=args.barge_in)
    print(f"workload={args.workload} sessions={args.sessions} "
          f"c={args.concurrency} p_bi={args.barge_in}")
    print(f"{'system':14s} {'P90 TTFP':>9s} {'contin.':>8s} "
          f"{'waste':>6s} {'RPS':>6s} {'reload(ms)':>10s}")
    for name, kw in SYSTEMS.items():
        m = run_sim(pipe, wl, until=3000.0, **kw)
        s = m.summary()
        print(f"{name:14s} {s['p90_ttfp']:8.3f}s {s['continuity']:8.3f} "
              f"{s['waste_ratio']:6.3f} {s['completed_rps']:6.3f} "
              f"{s['mean_reload_stall']*1000:10.2f}")
    print("\n(LiveServe should show lower TTFP, much lower waste, and "
          "reload moved off the critical path.)")


if __name__ == "__main__":
    main()

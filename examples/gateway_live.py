"""Realtime gateway demo: concurrent voice sessions with barge-in on
the real paged data plane (DESIGN.md §4).

  PYTHONPATH=src python examples/gateway_live.py

Six open-loop sessions (poisson arrivals, 30% barge-in) replayed in
scaled real time through the asyncio gateway. The LiveServe scheduler —
not the engine — decides every round's admission, first-audio priority,
and playback-frontier cap; the engine executes exactly that decision on
paged JAX KV state. Prints the per-policy serving summary (the same
schema the virtual-clock simulator reports) so you can eyeball
liveserve against the FCFS baseline.
"""
from repro.serving.gateway.harness import (build_gateway,
                                           run_gateway_workload,
                                           tiny_model)


def main() -> None:
    model = tiny_model(0)
    summaries = {}
    for policy, cap in (("liveserve", 3.0), ("fcfs", None)):
        print(f"--- {policy}: 6 sessions, poisson arrivals, "
              f"30% barge-in, clock x4 ---")
        gw = build_gateway(policy=policy, scale=4.0, model=model,
                           frontier_cap_s=cap, round_token_budget=2,
                           pages_per_seq=10, audio_per_token_s=0.6)
        metrics, gw = run_gateway_workload(
            policy=policy, sessions=6, barge_in=0.3, seed=0,
            rate_rps=6.0, max_response=14, max_prompt=12, gateway=gw,
            timeout_s=600)
        s = metrics.summary()
        summaries[policy] = s
        for k, v in s.items():
            print(f"  {k:20s} {v:.4f}" if isinstance(v, float)
                  else f"  {k:20s} {v}")
        print(f"  {'rounds':20s} {gw.rounds}")
        print(f"  {'over_frontier_s':20s} {gw.max_over_frontier_s:.3f}")
    faster = (summaries['fcfs']['p90_ttfp']
              / max(1e-9, summaries['liveserve']['p90_ttfp']))
    print(f"\nliveserve p90 TTFP is {faster:.2f}x faster than fcfs "
          f"on this trace")


if __name__ == "__main__":
    main()

"""jax version compatibility.

The deployment containers pin different jax versions; newer jax promoted
some experimental APIs to the top-level namespace with renamed kwargs.
These shims pick whichever spelling the installed jax provides — runtime
behavior is identical.
"""
from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None):
    """jax.shard_map (jax >= 0.5, ``check_vma``) or
    jax.experimental.shard_map.shard_map (0.4.x, ``check_rep``)."""
    if hasattr(jax, "shard_map"):
        kw = {} if check_vma is None else {"check_vma": check_vma}
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    kw = {} if check_vma is None else {"check_rep": check_vma}
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **kw)

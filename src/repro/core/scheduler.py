"""Interaction-aware request scheduling (paper §4, Algorithm 1).

Urgency classes per scheduling round:
  U0 playback urgency   — started playback, buffer <= P_safe; sort buffer asc.
  U1 first-audio        — no first output yet; sort by ready age (FCFS aging).
  U2 efficiency         — utility U = beta*U_kv - alpha*C_barge (Eqs. 1-3),
                          sorted descending.

Batch formation scans Concat(U0, U1, U2) against the round budgets
(token budget + free KV blocks). Fail-closed: a request whose session has
no playback telemetry classifies as U1 (first-audio path) and missing U2
utility inputs reduce U2 to ready-age order — matching §6.

The scheduler is clock-agnostic: ``now`` is whatever the caller's clock
says, so the same Algorithm 1 runs under the simulator's virtual clock
and the realtime gateway's scaled wall clock (DESIGN.md §4). Pacing
(class 3) is the playback-frontier generation cap: a session whose
client buffer exceeds ``p_max_s`` is held until the buffer drains, so
decode never runs more than the configured margin ahead of playback.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Optional

from repro.core.session import Phase, Request


@dataclass
class SchedulerConfig:
    p_safe_s: float = 1.0            # minimum safe playback buffer (s)
    p_max_s: float = 3.0             # pacing cap: hold U2 beyond this buffer
    alpha: float = 1.0               # barge-in exposure weight (Eq. 1)
    beta: float = 1.0                # KV-pressure relief weight (Eq. 1)
    enable_urgency: bool = True      # False -> pure FCFS (baseline)
    enable_u2_utility: bool = True   # False -> U2 by ready age (ablation)
    enable_pacing: bool = True       # False -> never hold far-ahead work
    pacing_kv_override: float = 0.9  # KV occupancy beyond which far-ahead
    #   sessions run anyway (KV-pressure relief beats pacing — the paper's
    #   alpha/beta tradeoff under memory pressure, §4.1 / Fig. 8)


@dataclass
class RoundBudget:
    token_budget: int                # prefill+decode tokens this round
    free_kv_blocks: int              # allocatable KV blocks at this stage
    max_batch: int = 256
    block_size: int = 16
    # batch rows available for NEW bindings this round (None = untracked).
    # A queued turn (``req.slot_bound`` False) needs one to enter the
    # engine; without this credit an urgent queued turn could outrank
    # every live decode slot yet bind nowhere — eating the whole batch
    # while the slots it is waiting on are never scheduled to finish
    free_slots: Optional[int] = None

    def need_blocks(self, req: Request, chunk: int) -> int:
        """KV blocks this round actually allocates: prefill chunks round
        up; a decode token needs a new block only when its position
        crosses a block boundary — charging one per token would let a
        full pool of live sessions starve decode that needs no growth."""
        if req.phase == Phase.DECODE:
            # blocks newly crossed by growing tc -> tc + chunk (chunk==1
            # reduces to the old boundary test: 1 iff tc % bs == 0)
            tc, bs = req.total_context, self.block_size
            return (tc + chunk + bs - 1) // bs - (tc + bs - 1) // bs
        return -(-chunk // self.block_size)

    def fits(self, req: Request, chunk: int) -> bool:
        if self.max_batch <= 0:
            return False
        if chunk > self.token_budget:
            return False
        return self.need_blocks(req, chunk) <= self.free_kv_blocks

    def admit(self, req: Request, chunk: int) -> None:
        self.token_budget -= chunk
        self.free_kv_blocks -= self.need_blocks(req, chunk)
        self.max_batch -= 1


@dataclass
class ScheduleDecision:
    batch: List[Request]
    chunks: dict                     # req_id -> tokens this round
    classes: dict                    # req_id -> 0/1/2/3 (telemetry/debug)
    utilities: dict = field(default_factory=dict)
    held: list = field(default_factory=list)   # (req, buffer) paced out


class UrgencyScheduler:
    """One instance per stage engine (stage-specific buffer estimator)."""

    def __init__(self, cfg: SchedulerConfig, monitor, *,
                 stage: str,
                 buffer_estimator: Optional[Callable] = None,
                 kv_occupancy: Optional[Callable] = None,
                 kv_of_request: Optional[Callable] = None,
                 prefill_chunk: int = 512,
                 decode_chunk: int = 1):
        self.cfg = cfg
        self.monitor = monitor
        self.stage = stage
        self._buffer = buffer_estimator or self._default_buffer
        self._kv_occ = kv_occupancy or (lambda: 0.0)
        self._kv_of = kv_of_request or (lambda r: float(r.total_context))
        self.prefill_chunk = prefill_chunk
        # decode grant per round: 1 + draft budget under speculative
        # decode (DESIGN.md §16). Callers must clamp this to the round
        # token budget — a grant the budget can never fit would stall
        # at Algorithm 1's admission break every round (head-of-line)
        self.decode_chunk = decode_chunk

    # ------------------------------------------------------------ signals
    def _default_buffer(self, req: Request) -> Optional[float]:
        """Stage-aware playback buffer P_i^s (audio stages: client buffer)."""
        return self.monitor.playback_buffer_s(req.session_id)

    def classify(self, req: Request, now: float):
        """Returns (class, sort_key, buffer). class 3 = held (pacing)."""
        cfg = self.cfg
        buf = self._buffer(req)
        view = self.monitor.view(req.session_id)
        deadline = getattr(view, "frame_deadline", None) \
            if view is not None else None
        if deadline is not None:
            # periodic-frame (full-duplex) session: urgency is the
            # slack to the next frame deadline, not the playback buffer
            # — a frame due within P_safe joins U0 (its key, seconds
            # until trouble, sorts compatibly with buffer seconds)
            slack = deadline - now
            if slack <= cfg.p_safe_s:
                return 0, slack, buf
        started = bool(view and view.playback.started
                       and not view.playback.complete)
        if not started or buf is None:
            # no first playable audio packet yet for this turn (U1), or
            # telemetry missing (fail-closed -> first-audio path)
            return 1, now - req.arrival_time, buf
        if buf <= cfg.p_safe_s:
            return 0, buf, buf
        if cfg.enable_pacing and buf > cfg.p_max_s \
                and self._kv_occ() < cfg.pacing_kv_override:
            # generation far beyond the playback frontier: delay (§4)
            return 3, buf, buf
        return 2, 0.0, buf

    def utility(self, req: Request, buf: Optional[float]) -> float:
        """Eq. 1: U = beta * U_kv - alpha * C_barge."""
        cfg = self.cfg
        if not cfg.enable_u2_utility or buf is None:
            return 0.0
        c_barge = max(0.0, buf - cfg.p_safe_s) / max(cfg.p_safe_s, 1e-9)
        u_kv = self._kv_of(req) * self._kv_occ()
        return cfg.beta * u_kv - cfg.alpha * c_barge

    # ------------------------------------------------------------ rounds
    def chunk_for(self, req: Request) -> int:
        if req.phase == Phase.PREFILL and not req.done_prefill:
            return min(self.prefill_chunk, req.prompt_len - req.prefilled)
        # decode: pending token + up to decode_chunk-1 draft tokens,
        # never past the turn's remaining generation budget
        return max(1, min(self.decode_chunk,
                          req.max_new_tokens - req.generated))

    def schedule(self, ready: List[Request], budget: RoundBudget,
                 now: float) -> ScheduleDecision:
        classes, utilities = {}, {}
        held = []
        if not self.cfg.enable_urgency:
            order = sorted(ready, key=lambda r: (r.arrival_time, r.req_id))
        else:
            c0, c1, c2 = [], [], []
            for r in ready:
                cls, key, buf = self.classify(r, now)
                classes[r.req_id] = cls
                if cls == 0:
                    c0.append((key, r.req_id, r))
                elif cls == 1:
                    c1.append((-key, r.req_id, r))   # oldest first
                elif cls == 3:
                    held.append((r, key))            # paced out this round
                else:
                    u = self.utility(r, buf)
                    utilities[r.req_id] = u
                    c2.append((-u, r.req_id, r))
            c0.sort(key=lambda t: t[:2])
            c1.sort(key=lambda t: t[:2])
            c2.sort(key=lambda t: t[:2])
            order = [t[2] for t in c0 + c1 + c2]

        batch, chunks = [], {}
        for r in order:
            needs_slot = budget.free_slots is not None \
                and not r.slot_bound
            if needs_slot and budget.free_slots <= 0:
                # no batch row can bind this turn: skip, don't break —
                # slots are a different resource from the token budget,
                # and stopping here would starve the live decode slots
                # this very turn is waiting on (head-of-line livelock)
                continue
            chunk = self.chunk_for(r)
            if not budget.fits(r, chunk):
                break                 # Algorithm 1: admission stops
            budget.admit(r, chunk)
            if needs_slot:
                budget.free_slots -= 1
            batch.append(r)
            chunks[r.req_id] = chunk
            r.last_scheduled = now
        return ScheduleDecision(batch=batch, chunks=chunks, classes=classes,
                                utilities=utilities, held=held)

    def hold_wake_s(self, decision: ScheduleDecision,
                    now: Optional[float] = None) -> Optional[float]:
        """How long (in clock seconds) until the earliest pace-held
        session drains back to the pacing threshold — playback consumes
        buffer at 1 s/s, so a driver with nothing else to run can sleep
        this long instead of spinning. None when nothing is held.

        With ``now``, a held periodic-frame session also bounds the wake
        by its frame slack: the driver must be back before the deadline
        slack shrinks to P_safe (when classify promotes the session to
        U0), so a hold can never turn into a frame miss by itself."""
        if not decision.held:
            return None
        wakes = []
        for req, buf in decision.held:
            wake = buf - self.cfg.p_max_s
            if now is not None:
                view = self.monitor.view(req.session_id)
                deadline = getattr(view, "frame_deadline", None) \
                    if view is not None else None
                if deadline is not None:
                    wake = min(wake, deadline - now - self.cfg.p_safe_s)
            wakes.append(max(0.01, wake))
        return min(wakes)


class FCFSScheduler(UrgencyScheduler):
    """Baseline: vLLM-Omni default ordering."""

    def __init__(self, monitor, *, stage: str, **kw):
        super().__init__(SchedulerConfig(enable_urgency=False), monitor,
                         stage=stage, **kw)

"""Session / turn / request state shared by the interaction plane and the
stage engines."""
from __future__ import annotations

import enum
import itertools
from dataclasses import dataclass, field
from typing import Optional

_req_counter = itertools.count()


class RequestState(enum.Enum):
    WAITING = "waiting"          # arrived, not yet admitted
    RUNNING = "running"          # in the engine's running set
    PREEMPTED = "preempted"      # admitted before, currently descheduled
    FINISHED = "finished"
    ABORTED = "aborted"          # barge-in


class Phase(enum.Enum):
    PREFILL = "prefill"
    DECODE = "decode"


@dataclass
class Request:
    """One turn's work at one stage."""
    session_id: str
    stage: str
    turn_index: int
    arrival_time: float
    prompt_len: int                     # new tokens to prefill this turn
    context_len: int = 0                # cached history tokens (prior turns)
    max_new_tokens: int = 0             # sim oracle; engines don't read it
    req_id: int = field(default_factory=lambda: next(_req_counter))
    state: RequestState = RequestState.WAITING
    phase: Phase = Phase.PREFILL
    prefilled: int = 0                  # prompt tokens processed so far
    generated: int = 0                  # tokens decoded so far
    first_output_time: Optional[float] = None
    finish_time: Optional[float] = None
    # audio accounting (talker-stage requests)
    audio_per_token_s: float = 0.0      # seconds of audio per output token
    # bookkeeping for scheduling
    last_scheduled: float = -1.0
    reload_stall_s: float = 0.0         # on-path KV reload charged to TTFP
    reload_off_path_s: float = 0.0      # reload seconds hidden off-path
    prefix_hit_tokens: int = 0          # prompt tokens served from the
    #                                     shared prefix cache (skip-ahead)
    slot_bound: bool = True             # already holds a batch row; False
    #                                     for queued turns that still need
    #                                     a free slot to bind

    @property
    def total_context(self) -> int:
        return self.context_len + self.prefilled + self.generated

    @property
    def done_prefill(self) -> bool:
        return self.prefilled >= self.prompt_len

    def is_live(self) -> bool:
        return self.state in (RequestState.WAITING, RequestState.RUNNING,
                              RequestState.PREEMPTED)


@dataclass
class Turn:
    index: int
    speech_start: float          # user starts speaking (VAD trigger)
    speech_end: float            # utterance complete
    prompt_len: int
    response_tokens: int         # oracle: talker tokens of the reply
    barge_in: bool = False
    barge_cut_s: float = 0.0     # played-audio seconds at which user barges
    # full-duplex: > 0 marks a periodic-frame turn whose per-frame
    # deadline is this many output-token durations (dimensionless so the
    # serving side can scale by its own audio_per_token_s)
    frame_period_tokens: float = 0.0
    # agentic: the turn ends in a tool call — the session idles with hot
    # KV for ~tool_latency_s, then resumes without a new utterance
    tool_call: bool = False
    tool_latency_s: float = 0.0
    # agent handoff: before this turn's speech, the client requests the
    # session move to the model config / replica ``handoff_target``
    handoff: bool = False
    handoff_target: int = 0


@dataclass
class Session:
    session_id: str
    turns: list
    arrival_time: float
    think_time_s: float = 2.0    # gap between playback end and next speech
    current_turn: int = 0
    # cumulative context tokens cached at the LLM stage after each turn
    context_tokens: int = 0
    kv_bytes_per_token: float = 0.0
    # shared-system-prompt family (-1: none): sessions in the same
    # family open with an identical seeded prefix, so seeded traces
    # exercise cross-session prefix sharing deterministically
    family: int = -1

"""Asynchronous chunked host<->device KV transfer engine (DESIGN.md §10).

The LiveServe claim this makes real: *most KV reload work moves off the
next-turn critical path*. The blocking hooks the paged engine used to
run (`_reload_pages` / `_offload_pages`) moved every page synchronously,
so a speech-time preload only hid latency in the simulator's virtual
clock, never on real JAX state. This module turns both directions into
chunked, round-interleaved jobs:

- **Chunking.** A transfer is split into page-group chunks sized by the
  modeled PCIe channel: ``chunk_pages`` defaults to however many pages
  fit in ``target_chunk_s`` of channel time, so one chunk is roughly
  one decode round's worth of DMA (Metronome's bounded periodic-task
  framing: transfer work is scheduled against the token cadence, never
  as one blocking call).
- **Draining.** ``PagedRealtimeEngine.run_round`` (and both gateways'
  idle loops) call ``drain`` with a per-round chunk budget; each drained
  chunk physically moves its pages via the engine-registered io
  callbacks. A preload issued at ``user_speech_start`` therefore lands
  across the rounds where the user is still speaking.
- **Turn-start settlement.** ``finish_session`` completes whatever is
  still queued for a session when its next turn reaches the LLM stage.
  Chunks already drained cost the turn nothing — their full modeled
  cost was banked off-path at drain time (the bytes physically landed
  during a round, so the turn can never stall on them); chunks whose
  channel-modeled completion instant has passed are late-materialized
  for free (the modeled DMA finished during the speech window — only
  our host-side bookkeeping was lazy); the true remainder is charged
  on-path at its chunk-serial channel cost. That split is the on-path
  vs off-path reload accounting the shared metrics schema reports.
- **Copy-then-free offload.** An evicted page stays resident (usable,
  attendable) until its chunk is durably in the host store; only then
  is the physical slot freed. Allocation pressure *demands* completion
  (the engine drains offload chunks until the pool can satisfy it), and
  a reload arriving before the copy drains simply cancels it — the
  bytes never left HBM.
- **Ledger + cancellation.** Every in-flight page is tracked per
  session and cross-checked against the pool's ``loading``/
  ``offloading`` marks (``check``). Barge-in burst cancellation,
  hangup, and eviction-of-a-loading-session all cancel queued chunks
  without leaking pool slots or host-store entries (the conservation
  property in tests/test_transfer_engine.py).

This module is pure host-side bookkeeping: the physical page movement
lives in the io callbacks the engine registers (``set_io``), so the
ledger is reusable by any data plane that owns a page store.

Shared pages (DESIGN.md §13) never enter the ledger: a page another
live session is attached to must stay hot, so the engine's offload
picker skips refcount>1 pages and ``PagedPool.mark_offloading`` asserts
refcount==1 — by the time a chunk is enqueued here its pages are
provably private. Fleet migration deep-copies shared pages to host
stacks *before* building its MIGRATE chunks for the same reason.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

RELOAD = "reload"
OFFLOAD = "offload"

# chunk tag marking replica-to-replica migration legs (serving/fleet):
# the source's migrate-out offload and the destination's page-in both
# ride the normal RELOAD/OFFLOAD machinery, but tagged chunks are
# separately countable so migration traffic is observable (and its
# zero-copy cancellation provable) without a third transfer kind
MIGRATE = "migrate"

# default chunk sizing target: one chunk ~ one decode round of DMA
TARGET_CHUNK_S = 0.005


@dataclass
class TransferChunk:
    """One page-group of one direction for one session."""
    chunk_id: int
    session_id: str
    kind: str                        # RELOAD | OFFLOAD
    logical: List[int]               # logical page indices (pool order)
    modeled_done: float              # channel-modeled completion instant
    state: str = "queued"            # queued | done | cancelled
    tag: Optional[str] = None        # e.g. MIGRATE — observability only

    @property
    def pages(self) -> int:
        return len(self.logical)


@dataclass
class TransferStats:
    """Ledger telemetry; the bench's overlap fraction reads this."""
    reload_pages_off_path: int = 0   # drained during rounds / time-credit
    reload_pages_on_path: int = 0    # settled at turn start (stalled)
    reload_pages_cancelled: int = 0
    offload_pages_completed: int = 0
    offload_pages_cancelled: int = 0
    chunks_drained: int = 0
    demand_drains: int = 0           # offload chunks forced by allocation
    migration_pages_moved: int = 0   # MIGRATE-tagged pages that drained
    migration_pages_cancelled: int = 0   # MIGRATE-tagged zero-copy drops
    # wire-format telemetry (DESIGN.md §14): modeled bytes completed
    # chunks put on the channel, and the bytes the codec saved against
    # the logical (uncompressed) payload. Cancelled chunks count in
    # neither — their bytes never moved.
    wire_bytes_moved: float = 0.0
    wire_bytes_saved: float = 0.0
    reload_wire_bytes: float = 0.0   # RELOAD-only share of wire_bytes_moved

    def overlap_fraction(self) -> float:
        """Off-path share of reloaded pages; 0.0 when nothing reloaded
        (the page counters disambiguate, and it keeps JSON artifacts
        strict — no NaN)."""
        moved = self.reload_pages_off_path + self.reload_pages_on_path
        if moved == 0:
            return 0.0
        return self.reload_pages_off_path / moved


class TransferEngine:
    """Chunked async transfer ledger over one modeled PCIe channel."""

    def __init__(self, channel, *, chunk_pages: Optional[int] = None,
                 target_chunk_s: float = TARGET_CHUNK_S):
        self.channel = channel
        if chunk_pages is None:
            per_page = max(1e-12, channel.transfer_time(1))
            chunk_pages = max(1, int(target_chunk_s / per_page))
        assert chunk_pages >= 1
        self.chunk_pages = chunk_pages
        self._queue: List[TransferChunk] = []     # FIFO across sessions
        self._ids = itertools.count()
        self._io_reload: Optional[Callable] = None
        self._io_offload: Optional[Callable] = None
        # per-session (on_s, off_s) accumulated by finish_session, read
        # once by the preloader via pop_split
        self._split_acc: Dict[str, List[float]] = {}
        self._off_s_acc: Dict[str, float] = {}    # off-path modeled s
        # on-path page count of the most recent settlement, kept until
        # the turn either commits or is requeued: a requeued turn's
        # settlement stalled nothing, so its pages reclassify (the
        # seconds side is carried by the preloader's requeue_split)
        self._finish_on: Dict[str, int] = {}
        self.stats = TransferStats()

    # ------------------------------------------------------------ wiring
    def set_io(self, *, reload_chunk: Callable[[str, List[int]], None],
               offload_chunk: Callable[[str, List[int]], None]) -> None:
        """Register the physical movers. ``reload_chunk(sid, logical)``
        scatters the chunk's host copies into reserved device pages;
        ``offload_chunk(sid, logical)`` copies device pages to the host
        store and frees the slots. Both run synchronously when called —
        *when* they are called is this ledger's whole job."""
        self._io_reload = reload_chunk
        self._io_offload = offload_chunk

    # ------------------------------------------------------------ submit
    def _chunks_of(self, logical: List[int]) -> List[List[int]]:
        return [logical[i:i + self.chunk_pages]
                for i in range(0, len(logical), self.chunk_pages)]

    def submit_reload(self, sid: str, logical: List[int],
                      transfer=None, *,
                      tag: Optional[str] = None) -> List[TransferChunk]:
        """Queue a host->device job. ``transfer`` is the KVManager's
        aggregate modeled Transfer; per-chunk modeled completion times
        interpolate its [start, done] span (the serialized channel
        finishes chunk i before chunk i+1)."""
        if not logical:
            return []
        groups = self._chunks_of(logical)
        out = []
        done_pages = 0
        total = len(logical)
        for g in groups:
            done_pages += len(g)
            if transfer is not None:
                md = transfer.start + (transfer.done - transfer.start) \
                    * (done_pages / total)
            else:
                md = float("inf")
            c = TransferChunk(next(self._ids), sid, RELOAD, list(g), md,
                              tag=tag)
            self._queue.append(c)
            out.append(c)
        return out

    def submit_offload(self, sid: str, logical: List[int], *,
                       tag: Optional[str] = None) -> List[TransferChunk]:
        """Queue a device->host job (copy-then-free: the caller keeps
        the pages usable until each chunk drains). Offloads are not
        stall-modeled — they never sit on a turn's critical path; the
        demand path (`drain_offloads_until`) completes them when
        allocation needs the slots."""
        if not logical:
            return []
        out = []
        for g in self._chunks_of(logical):
            c = TransferChunk(next(self._ids), sid, OFFLOAD, list(g),
                              float("-inf"), tag=tag)
            self._queue.append(c)
            out.append(c)
        return out

    # ------------------------------------------------------------ drain
    def _complete(self, chunk: TransferChunk) -> None:
        assert chunk.state == "queued", chunk
        if chunk.kind == RELOAD:
            self._io_reload(chunk.session_id, chunk.logical)
        else:
            self._io_offload(chunk.session_id, chunk.logical)
            self.stats.offload_pages_completed += chunk.pages
        if chunk.tag == MIGRATE:
            self.stats.migration_pages_moved += chunk.pages
        ch = self.channel
        wire = ch.wire_bytes(chunk.pages)
        self.stats.wire_bytes_moved += wire
        self.stats.wire_bytes_saved += \
            chunk.pages * ch.block_bytes - wire
        if chunk.kind == RELOAD:
            self.stats.reload_wire_bytes += wire
        chunk.state = "done"

    def drain(self, now: float, max_chunks: Optional[int] = None, *,
              kinds: Tuple[str, ...] = (RELOAD, OFFLOAD)) -> int:
        """Physically complete up to ``max_chunks`` queued chunks (FIFO).
        Returns chunks drained; 0 therefore means the queue holds no
        chunk of ``kinds`` — callers (``drain_offloads_until``'s break,
        the engines' round budgets) rely on that reading, so a zero
        ``max_chunks`` or empty ``kinds`` (which would return 0 with
        the queue full) is rejected as a usage error instead of
        masquerading as "queue dry". Pass ``max_chunks=None`` for
        unbounded; callers with a possibly-zero budget guard the call
        (``if budget > 0``).

        Banking contract (pinned by tests/test_transfer_engine.py): a
        reload chunk drained here banks its FULL modeled channel cost
        as off-path seconds, regardless of ``now`` vs the chunk's
        ``modeled_done``. Draining means the bytes physically landed
        during a round — the next turn can never stall on them — so
        the whole modeled cost was hidden in the speech window; the
        ``modeled_done`` instant only matters for chunks still queued
        at turn-start settlement (``finish_session``), which never
        re-charges a drained chunk."""
        if max_chunks is not None and max_chunks <= 0:
            raise ValueError(
                f"drain(max_chunks={max_chunks}): a non-positive chunk "
                "budget would return 0 with work still queued — callers "
                "treat 0 as 'queue dry'; guard the call instead")
        if not kinds:
            raise ValueError(
                "drain(kinds=()): empty kinds matches nothing and would "
                "return 0 with work still queued")
        drained = 0
        i = 0
        while i < len(self._queue):
            if max_chunks is not None and drained >= max_chunks:
                break
            c = self._queue[i]
            if c.kind not in kinds:
                i += 1
                continue
            self._queue.pop(i)
            self._complete(c)
            drained += 1
            self.stats.chunks_drained += 1
            if c.kind == RELOAD:
                self.stats.reload_pages_off_path += c.pages
                self._off_s_acc[c.session_id] = \
                    self._off_s_acc.get(c.session_id, 0.0) \
                    + self.channel.transfer_time(c.pages)
        return drained

    def drain_offloads_until(self, now: float,
                             predicate: Callable[[], bool]) -> int:
        """Demand path: complete offload chunks until ``predicate()``
        (e.g. 'pool has enough free slots') or the queue runs dry."""
        n = 0
        while not predicate():
            if not self.drain(now, 1, kinds=(OFFLOAD,)):
                break
            n += 1
            self.stats.demand_drains += 1
        return n

    # ------------------------------------------------------------ settle
    def finish_session(self, sid: str, now: float) -> Tuple[float, float]:
        """Turn-start settlement: complete every reload chunk of
        ``sid`` *still queued* at ``now``. Queued chunks whose modeled
        DMA finished by ``now`` settle off-path (the modeled channel
        completed them during the speech window — only our host-side
        bookkeeping was lazy); the rest are charged on-path at
        chunk-serial channel cost. Chunks already drained by earlier
        rounds are not re-charged: their full modeled cost was banked
        off-path at drain time (see ``drain``'s banking contract) and
        rides along in the returned split. Accumulates and returns
        (on_path_s, off_path_s)."""
        on_s = 0.0
        off_s = self._off_s_acc.pop(sid, 0.0)
        for c in [c for c in self._queue
                  if c.session_id == sid and c.kind == RELOAD]:
            self._queue.remove(c)
            self._complete(c)
            self.stats.chunks_drained += 1
            cost = self.channel.transfer_time(c.pages)
            if c.modeled_done <= now:
                off_s += cost
                self.stats.reload_pages_off_path += c.pages
            else:
                on_s += cost
                self.stats.reload_pages_on_path += c.pages
                self._finish_on[sid] = \
                    self._finish_on.get(sid, 0) + c.pages
        acc = self._split_acc.setdefault(sid, [0.0, 0.0])
        acc[0] += on_s
        acc[1] += off_s
        return on_s, off_s

    def pop_split(self, sid: str) -> Tuple[float, float]:
        on, off = self._split_acc.pop(sid, (0.0, 0.0))
        return on, off

    def requeue_settlement(self, sid: str) -> None:
        """The turn whose start settled these chunks was requeued
        (saturated pool): the settlement stalled nothing, so its
        on-path pages reclassify as off-path — by the time the turn
        eventually runs, those bytes were long resident. Keeps the
        ledger's overlap stats agreeing with the per-turn metrics,
        which carry the same seconds forward as off-path credit."""
        pages = self._finish_on.pop(sid, 0)
        self.stats.reload_pages_on_path -= pages
        self.stats.reload_pages_off_path += pages

    def settlement_committed(self, sid: str) -> None:
        """The settled turn really started: the on-path classification
        stands; drop the reclassification record."""
        self._finish_on.pop(sid, None)

    # ------------------------------------------------------------ cancel
    def _cancel_pages(self, sid: str, kind: str,
                      logical: Optional[List[int]]) -> int:
        """Drop pages of one direction from the session's queued chunks
        (``logical=None`` drops them all); emptied chunks leave the
        queue. Returns pages dropped — the caller reverts the pool
        marks and any accounting."""
        want = None if logical is None else set(logical)
        dropped = 0
        for c in list(self._queue):
            if c.session_id != sid or c.kind != kind:
                continue
            if want is None:
                keep = []
            else:
                keep = [li for li in c.logical if li not in want]
            hit = c.pages - len(keep)
            dropped += hit
            if c.tag == MIGRATE:
                self.stats.migration_pages_cancelled += hit
            c.logical = keep
            if not keep:
                c.state = "cancelled"
                self._queue.remove(c)
        return dropped

    def cancel_reload_pages(self, sid: str,
                            logical: Optional[List[int]] = None) -> int:
        """Drop pages from queued reload chunks (eviction of a loading
        session, burst cancel)."""
        dropped = self._cancel_pages(sid, RELOAD, logical)
        self.stats.reload_pages_cancelled += dropped
        return dropped

    def cancel_offload_pages(self, sid: str,
                             logical: Optional[List[int]] = None) -> int:
        """Drop pages from queued offload chunks — the copy-then-free
        win: a reload (or turn) arriving before the copy drained keeps
        the pages resident at zero transfer cost."""
        dropped = self._cancel_pages(sid, OFFLOAD, logical)
        self.stats.offload_pages_cancelled += dropped
        return dropped

    def cancel_session(self, sid: str) -> Dict[str, int]:
        """Hangup: drop every queued chunk of the session. The caller
        releases the pool entry (which frees reserved slots and host
        copies), so nothing leaks mid-transfer."""
        out = {RELOAD: self.cancel_reload_pages(sid),
               OFFLOAD: self.cancel_offload_pages(sid)}
        self._split_acc.pop(sid, None)
        self._off_s_acc.pop(sid, None)
        self._finish_on.pop(sid, None)
        return out

    # ------------------------------------------------------------ ledger
    def pending_offload_pages(self, sid: Optional[str] = None) -> int:
        return sum(c.pages for c in self._queue if c.kind == OFFLOAD
                   and (sid is None or c.session_id == sid))

    def pending_reload_pages(self, sid: Optional[str] = None) -> int:
        return sum(c.pages for c in self._queue if c.kind == RELOAD
                   and (sid is None or c.session_id == sid))

    def idle(self) -> bool:
        return not self._queue

    # ------------------------------------------------------------ checks
    def check(self, pool) -> None:
        """Ledger <-> pool bijection: every queued reload page is marked
        ``loading`` (and vice versa); every queued offload page is
        marked ``offloading`` (and vice versa); no page appears in two
        queued chunks."""
        by = {}
        for c in self._queue:
            for li in c.logical:
                key = (c.session_id, c.kind, li)
                assert key not in by, f"page queued twice: {key}"
                by[key] = c
        for sid, s in pool.seqs.items():
            qr = {li for (s2, k, li) in by if s2 == sid and k == RELOAD}
            qo = {li for (s2, k, li) in by if s2 == sid and k == OFFLOAD}
            assert qr == set(s.loading), \
                f"{sid}: queued reloads {qr} != pool loading {s.loading}"
            assert qo == set(s.offloading), \
                f"{sid}: queued offloads {qo} != pool offloading " \
                f"{s.offloading}"
        for (sid, _, _li) in by:
            assert sid in pool.seqs, f"chunk for released session {sid}"

"""Speech-triggered KV preloading (paper §5.2).

Speech start / barge-in fire a best-effort background DRAM->HBM preload.
Admission requires the transfer to hide inside the predicted window before
LLM-stage execution (remaining utterance + encode delay), under current
channel pressure. Admitted preloads protect the session KV from eviction
for a bounded TTL; cancellation or admission failure falls back to the
synchronous on-path load — latency is affected, correctness never is.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.kv_manager import KVManager, Transfer


@dataclass
class PreloadStats:
    triggered: int = 0
    admitted: int = 0
    skipped: int = 0          # admission check failed
    cancelled: int = 0
    hits: int = 0             # next turn found warm KV
    sync_fallbacks: int = 0


@dataclass
class PendingPreload:
    session_id: str
    transfer: Transfer
    deadline: float


class Preloader:
    def __init__(self, kv: KVManager, monitor, *,
                 encode_delay_s: float = 0.15,
                 speech_prior_s: float = 2.0,
                 safety_margin: float = 0.9,
                 pressure_cap: float = 0.9,
                 enabled: bool = True):
        self.kv = kv
        self.monitor = monitor
        self.encode_delay_s = encode_delay_s
        self.speech_prior_s = speech_prior_s
        self.safety_margin = safety_margin
        self.pressure_cap = pressure_cap
        self.enabled = enabled
        self.pending: Dict[str, PendingPreload] = {}
        self.stats = PreloadStats()

    # ------------------------------------------------------------ trigger
    def on_speech_start(self, sid: str, now: float) -> Optional[Transfer]:
        """Called on VAD speech-start or barge-in for the session."""
        if not self.enabled:
            return None
        self.stats.triggered += 1
        # always protect resident KV of a speaking session (§5.2)
        self.kv.protect(sid, now)
        self.kv.refresh_session(sid, now)
        missing = self.kv.missing_blocks(sid)
        if missing <= 0:
            return None
        view = self.monitor.view(sid)
        if view is not None and view.expected_speech_end is not None:
            window = max(0.0, view.expected_speech_end - now) \
                + self.encode_delay_s
        else:
            window = self.speech_prior_s + self.encode_delay_s
        cost = self.kv.channel.transfer_time(missing) \
            + self.kv.channel.queue_delay(now)
        if cost > window * self.safety_margin:
            self.stats.skipped += 1
            return None
        # bounded background work (§5.2): never preload into a pool under
        # pressure — the eviction it would force hurts live requests more
        # than the hidden transfer helps this one
        if self.kv.occupancy() > self.pressure_cap \
                and missing > self.kv.free_blocks:
            self.stats.skipped += 1
            return None
        transfer = self.kv.reload(sid, now, background=True)
        if transfer is None:
            self.stats.skipped += 1
            return None
        self.stats.admitted += 1
        self.pending[sid] = PendingPreload(sid, transfer, now + window)
        return transfer

    def cancel(self, sid: str, now: float) -> None:
        """Burst pressure: engine cancels background preloads (§6)."""
        p = self.pending.pop(sid, None)
        if p is None:
            return
        if self.kv.physical_pages:
            # a physical data plane reloads pages at admission time —
            # the bytes already moved, so there is nothing to revert;
            # dropping the pending entry just forfeits the 'hit' credit
            return
        p.transfer.cancelled = True
        kv = self.kv.session(sid)
        kv.hbm_blocks = max(0, kv.hbm_blocks - p.transfer.blocks)
        self.kv.reloaded_blocks -= p.transfer.blocks
        self.stats.cancelled += 1

    # ------------------------------------------------------------ turn
    def on_turn_ready(self, sid: str, now: float) -> float:
        """Next-turn request reached the LLM stage. Returns the on-path
        reload stall in seconds (0.0 on a warm preload hit)."""
        p = self.pending.pop(sid, None)
        if p is not None and not p.transfer.cancelled:
            if p.transfer.done <= now:
                self.stats.hits += 1
                return 0.0
            # transfer still in flight: wait only the residual
            self.stats.sync_fallbacks += 1
            return p.transfer.done - now
        missing = self.kv.missing_blocks(sid)
        if missing <= 0 and self.kv.recompute_tokens(sid) == 0:
            return 0.0
        transfer = self.kv.reload(sid, now, background=False)
        if transfer is None:
            return 0.0                # 'none' policy: engine re-prefills
        self.stats.sync_fallbacks += 1
        return transfer.done - now


# Paper naming (§5.2): the speech-triggered preloader. When the KVManager
# carries page hooks (PagedRealtimeEngine), an admitted preload physically
# reloads pages at trigger time; ``cancel`` then only forfeits the pending
# hit (it cannot un-move pages, and doesn't pretend to).
SpeechPreloader = Preloader

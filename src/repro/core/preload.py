"""Speech-triggered KV preloading (paper §5.2).

Speech start / barge-in fire a best-effort background DRAM->HBM preload.
Admission requires the transfer to hide inside the predicted window before
LLM-stage execution (remaining utterance + encode delay), under current
channel pressure. Admitted preloads protect the session KV from eviction
for a bounded TTL; cancellation or admission failure falls back to the
synchronous on-path load — latency is affected, correctness never is.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.kv_manager import KVManager, Transfer


@dataclass
class PreloadStats:
    triggered: int = 0
    admitted: int = 0
    skipped: int = 0          # admission check failed
    cancelled: int = 0
    hits: int = 0             # next turn found warm KV
    sync_fallbacks: int = 0


@dataclass
class PendingPreload:
    session_id: str
    transfer: Transfer
    deadline: float
    # merge accounting: a second admission before the turn arrives
    # (speech -> barge-in) folds into the same logical entry instead of
    # orphaning the first transfer — `blocks` and `span_s` accumulate
    # across the merged transfers so cancel rollback and the off-path
    # split stay page- and second-exact
    blocks: int = 0
    span_s: float = 0.0


class Preloader:
    def __init__(self, kv: KVManager, monitor, *,
                 encode_delay_s: float = 0.15,
                 speech_prior_s: float = 2.0,
                 safety_margin: float = 0.9,
                 pressure_cap: float = 0.9,
                 enabled: bool = True):
        self.kv = kv
        self.monitor = monitor
        self.encode_delay_s = encode_delay_s
        self.speech_prior_s = speech_prior_s
        self.safety_margin = safety_margin
        self.pressure_cap = pressure_cap
        self.enabled = enabled
        self.pending: Dict[str, PendingPreload] = {}
        self.stats = PreloadStats()
        # per-turn (on_path_s, off_path_s) reload split, recorded by
        # on_turn_ready and read once by the engine via pop_split — the
        # shared metrics schema reports both halves (DESIGN.md §10)
        self._last_split: Dict[str, tuple] = {}
        # how the last on_turn_ready classified the turn, so a
        # saturated-pool requeue can undo the count (the retry will
        # classify the same logical turn again)
        self._last_class: Dict[str, str] = {}

    # ------------------------------------------------------------ trigger
    def on_speech_start(self, sid: str, now: float) -> Optional[Transfer]:
        """Called on VAD speech-start or barge-in for the session."""
        if not self.enabled:
            return None
        self.stats.triggered += 1
        # always protect resident KV of a speaking session (§5.2)
        self.kv.protect(sid, now)
        self.kv.refresh_session(sid, now)
        missing = self.kv.missing_blocks(sid)
        if missing <= 0:
            return None
        view = self.monitor.view(sid)
        if view is not None and view.expected_speech_end is not None:
            window = max(0.0, view.expected_speech_end - now) \
                + self.encode_delay_s
        elif view is not None \
                and getattr(view, "frame_period_s", 0.0) > 0.0:
            # full duplex: the turn request fires at speech start, so
            # the only window is one frame period — honest admission
            # (a transfer that cannot hide in a frame is refused)
            window = view.frame_period_s + self.encode_delay_s
        else:
            window = self.speech_prior_s + self.encode_delay_s
        # only blocks whose bytes truly sit on the host cross the
        # channel (in-flight copy-then-free offloads cancel for free)
        cost = self.kv.channel.transfer_time(self.kv.transfer_blocks(sid)) \
            + self.kv.channel.queue_delay(now)
        if cost > window * self.safety_margin:
            self.stats.skipped += 1
            return None
        # bounded background work (§5.2): never preload into a pool under
        # pressure — the eviction it would force hurts live requests more
        # than the hidden transfer helps this one
        if self.kv.occupancy() > self.pressure_cap \
                and missing > self.kv.free_blocks:
            self.stats.skipped += 1
            return None
        transfer = self.kv.reload(sid, now, background=True)
        if transfer is None:
            self.stats.skipped += 1
            return None
        self.stats.admitted += 1
        span = transfer.done - transfer.start
        prior = self.pending.get(sid)
        if prior is not None and not prior.transfer.cancelled:
            # double speech-start (speech -> barge-in) before the turn
            # arrived: merge with the still-pending entry instead of
            # overwriting it. The later-finishing transfer anchors the
            # hit/fallback settlement, the deadline follows the newest
            # speech estimate, and the accumulated blocks/span keep
            # cancel and the overlap split exact for both transfers.
            keep = transfer if transfer.done >= prior.transfer.done \
                else prior.transfer
            self.pending[sid] = PendingPreload(
                sid, keep, now + window,
                blocks=prior.blocks + transfer.blocks,
                span_s=prior.span_s + span)
        else:
            self.pending[sid] = PendingPreload(
                sid, transfer, now + window,
                blocks=transfer.blocks, span_s=span)
        return transfer

    def cancel(self, sid: str, now: float) -> None:
        """Burst pressure: engine cancels background preloads (§6)."""
        p = self.pending.pop(sid, None)
        if p is None:
            return
        if self.kv.async_transfers:
            # the chunked transfer engine can revert whatever has not
            # landed yet: queued chunks are dropped, their slots return
            # to the pool, and the accounting rolls back page-exact
            # (chunks that already drained stay resident — partial
            # cancellation, no un-moving of bytes)
            if self.kv.cancel_reload(sid, now) > 0:
                p.transfer.cancelled = True
                self.stats.cancelled += 1
            return
        if self.kv.physical_pages:
            # a synchronous physical plane reloads pages at admission
            # time — the bytes already moved, so there is nothing to
            # revert; dropping the pending entry forfeits the 'hit'
            return
        p.transfer.cancelled = True
        kv = self.kv.session(sid)
        kv.hbm_blocks = max(0, kv.hbm_blocks - p.blocks)
        self.kv.reloaded_blocks -= p.blocks
        self.stats.cancelled += 1

    # ------------------------------------------------------------ turn
    def on_turn_ready(self, sid: str, now: float) -> float:
        """Next-turn request reached the LLM stage. Returns the on-path
        reload stall in seconds (0.0 on a warm preload hit); the
        on/off-path split is banked for ``pop_split``."""
        if self.kv.async_transfers:
            return self._on_turn_ready_ledger(sid, now)
        p = self.pending.pop(sid, None)
        if p is not None and not p.transfer.cancelled:
            span = p.span_s
            if p.transfer.done <= now:
                self.stats.hits += 1
                self._last_class[sid] = "hit"
                self._bank_split(sid, 0.0, span)
                return 0.0
            # transfer still in flight: wait only the residual
            self.stats.sync_fallbacks += 1
            self._last_class[sid] = "fallback"
            stall = p.transfer.done - now
            self._bank_split(sid, stall, max(0.0, span - stall))
            return stall
        missing = self.kv.missing_blocks(sid)
        if missing <= 0 and self.kv.recompute_tokens(sid) == 0:
            return 0.0
        transfer = self.kv.reload(sid, now, background=False)
        if transfer is None:
            return 0.0                # 'none' policy: engine re-prefills
        self.stats.sync_fallbacks += 1
        self._last_class[sid] = "fallback"
        stall = transfer.done - now
        self._bank_split(sid, stall, 0.0)
        return stall

    def _on_turn_ready_ledger(self, sid: str, now: float) -> float:
        """Async data plane: the stall is what the *ledger* says is
        still in flight — chunks drained during earlier rounds (or
        whose modeled DMA finished inside the speech window) are off
        the critical path; only the remainder is charged."""
        p = self.pending.pop(sid, None)
        on_s, off_s = self.kv.finish_transfers(sid, now)
        fell_back = False
        if self.kv.missing_blocks(sid) > 0 \
                and self.kv.recompute_tokens(sid) == 0:
            # pages offloaded with no preload covering them (or evicted
            # after admission): the classic synchronous fallback, now a
            # queue-and-settle pair through the same chunked path
            transfer = self.kv.reload(sid, now, background=False)
            if transfer is not None:
                on2, off2 = self.kv.finish_transfers(sid, now)
                fell_back = on2 > 0.0
                on_s += on2
                off_s += off2
        # classify the turn exactly once: a warm hit XOR a fallback —
        # never both, never a double fallback count (a requeued
        # attempt's classification is undone by ``requeue_split``)
        if p is not None:
            if on_s <= 0.0:
                self.stats.hits += 1
                self._last_class[sid] = "hit"
            else:
                self.stats.sync_fallbacks += 1
                self._last_class[sid] = "fallback"
        elif fell_back:
            self.stats.sync_fallbacks += 1
            self._last_class[sid] = "fallback"
        self._bank_split(sid, on_s, off_s)
        return on_s

    def _bank_split(self, sid: str, on_s: float, off_s: float) -> None:
        """Record the turn's split, folding in any off-path credit a
        requeued earlier attempt carried over (``requeue_split``)."""
        carry = sum(self._last_split.pop(sid, (0.0, 0.0)))
        self._last_split[sid] = (on_s, off_s + carry)

    def requeue_split(self, sid: str) -> None:
        """The turn whose arrival settled this split was requeued
        (saturated pool) before the engine could read it: the settled
        seconds stalled nothing, so they carry forward as off-path
        credit for the attempt that eventually starts — without this,
        a requeue silently dropped already-done reload work from the
        overlap accounting. The attempt's hit/fallback count is undone
        too: the retry re-classifies the same logical turn."""
        on, off = self._last_split.pop(sid, (0.0, 0.0))
        if on + off > 0.0:
            self._last_split[sid] = (0.0, on + off)
        cls = self._last_class.pop(sid, None)
        if cls == "hit":
            self.stats.hits -= 1
        elif cls == "fallback":
            self.stats.sync_fallbacks -= 1

    def pop_split(self, sid: str):
        """(on_path_s, off_path_s) of the last on_turn_ready for the
        session; read-once (the engine stamps it onto the turn)."""
        return self._last_split.pop(sid, (0.0, 0.0))

    def forget_session(self, sid: str) -> None:
        """Session ended: drop any pending preload and unread split."""
        self.pending.pop(sid, None)
        self._last_split.pop(sid, None)
        self._last_class.pop(sid, None)


# Paper naming (§5.2): the speech-triggered preloader. When the KVManager
# carries the async transfer hooks (PagedRealtimeEngine), an admitted
# preload *queues* chunked page reloads that drain across decode rounds
# while the user speaks; ``on_turn_ready`` settles the remainder
# on-path and ``cancel`` rolls back page-exact whatever has not landed.
# A synchronous physical plane (async_transfers=False) still moves
# everything at trigger time, so its ``cancel`` only forfeits the hit.
SpeechPreloader = Preloader

"""Interaction-aware hierarchical KV cache management (paper §5).

Host-side block accounting over an HBM tier and a DRAM tier:

- Blocks of a session are ordered; HBM always holds a *prefix* range
  [0, hbm_blocks) and DRAM the suffix — because eviction takes suffix
  blocks first (§5.1: prefix blocks are shared by future turns and more
  expensive to reconstruct).
- Eviction candidates are idle multi-turn sessions ranked by predicted
  next use  T_next = now + T_play + T_reply  (Eq. 4), farthest first.
  Sessions with speech-start/barge-in are immediate-reuse and protected.
- A lazy-deletion heap keeps candidate selection O(log n) (the paper's
  eviction index, Table 1); ``index_mode='scan'`` reproduces the tail-scan
  baseline for the microbenchmark.
- ``policy='lru'`` reproduces the substrate baseline; ``policy='none'``
  models vLLM-Omni-wo (no offload: eviction discards KV, next turn must
  re-prefill). Missing monitor telemetry falls back to LRU order
  (fail-closed, §6).
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


@dataclass
class SessionKV:
    session_id: str
    total_blocks: int = 0        # context blocks cached for the session
    hbm_blocks: int = 0          # resident prefix range [0, hbm_blocks)
    pinned: bool = False         # a live request is using this KV
    protected_until: float = -1.0  # preload protection TTL
    # tool-pause protection (distinct state, distinct TTL): the session
    # idles mid-turn while an external tool runs; its next use is the
    # tool's expected return, not the reply-gap EMA, and its hot KV must
    # not be evicted out from under the resume
    tool_protected_until: float = -1.0
    last_access: float = 0.0
    discarded: bool = False      # 'none' policy: KV dropped, must re-prefill
    # Shared-prefix accounting (DESIGN.md §13): `shared_blocks` are
    # attached prefix blocks charged to another accountant (the owner
    # session or the prefix cache); `shared_pinned_blocks` are OWN
    # resident blocks some other session shares — a page a sharer still
    # needs hot never offloads, so they leave the evictable budget.
    shared_blocks: int = 0
    shared_pinned_blocks: int = 0

    @property
    def dram_blocks(self) -> int:
        return max(0, self.total_blocks - self.shared_blocks
                   - self.hbm_blocks)

    def evictable(self, now: float) -> int:
        if self.pinned or now < self.protected_until \
                or now < self.tool_protected_until:
            return 0
        return max(0, self.hbm_blocks - self.shared_pinned_blocks)


@dataclass
class Transfer:
    session_id: str
    blocks: int
    start: float
    done: float
    background: bool
    cancelled: bool = False


class TransferChannel:
    """Serialized DRAM<->HBM path (PCIe-style shared bandwidth).

    ``wire_scale`` is the wire-format compression factor (DESIGN.md
    §14): wire bytes per logical block byte, 1.0 for the fp32 control
    and ~0.25 for int8 KV pages. It multiplies into ``transfer_time``
    here — the single point every modeled cost flows through — so
    chunk sizing, preload admission, turn-start stall settlement, and
    fleet migration all price the compressed payload without knowing
    the codec exists. ``block_bytes`` stays the *logical* size (pool
    capacity math never compresses)."""

    def __init__(self, gb_per_s: float, block_bytes: float,
                 wire_scale: float = 1.0):
        self.gb_per_s = gb_per_s
        self.block_bytes = block_bytes
        self.wire_scale = wire_scale
        self.busy_until = 0.0
        self.log: List[Transfer] = []

    def wire_bytes(self, blocks: int) -> float:
        """Bytes a transfer of ``blocks`` actually puts on the wire."""
        return blocks * self.block_bytes * self.wire_scale

    def transfer_time(self, blocks: int) -> float:
        return self.wire_bytes(blocks) / (self.gb_per_s * 1e9)

    def submit(self, session_id: str, blocks: int, now: float,
               background: bool) -> Transfer:
        start = max(now, self.busy_until)
        done = start + self.transfer_time(blocks)
        self.busy_until = done
        t = Transfer(session_id, blocks, start, done, background)
        self.log.append(t)
        return t

    def queue_delay(self, now: float) -> float:
        return max(0.0, self.busy_until - now)


class KVManager:
    def __init__(self, *, capacity_blocks: int, block_size: int,
                 bytes_per_token: float, monitor=None,
                 policy: str = "next_use", index_mode: str = "heap",
                 pcie_gb_s: float = 25.0,
                 protect_ttl_s: float = 10.0,
                 tool_protect_ttl_s: float = 30.0,
                 protected_cap_blocks: Optional[int] = None,
                 clock=None):
        assert policy in ("next_use", "lru", "none")
        assert index_mode in ("heap", "scan")
        self.capacity = capacity_blocks
        self.block_size = block_size
        self.bytes_per_token = bytes_per_token
        self.monitor = monitor
        self.policy = policy
        self.index_mode = index_mode
        self.clock = clock
        self.protect_ttl_s = protect_ttl_s
        self.tool_protect_ttl_s = tool_protect_ttl_s
        self.protected_cap = protected_cap_blocks or max(
            1, capacity_blocks // 4)
        self.sessions: Dict[str, SessionKV] = {}
        self.channel = TransferChannel(pcie_gb_s,
                                       block_size * bytes_per_token)
        # lazy-deletion heap of (-t_next, tiebreak, session_id, version)
        self._heap: List[Tuple[float, int, str, int]] = []
        self._version: Dict[str, int] = {}
        # whether a session's *current* version is live in the heap —
        # a session that becomes evictable again with no interaction
        # event (e.g. its preload-protection TTL lapses) must be
        # re-seeded by the next eviction pass, or heap mode silently
        # never finds it again
        self._in_heap: Dict[str, bool] = {}
        self._tiebreak = itertools.count()
        # working blocks owned by live requests (decode growth etc.)
        self.working_blocks = 0
        # data-plane hooks: a physical engine (PagedRealtimeEngine)
        # registers these so accounting decisions move real pages
        self._on_evict_pages = None
        self._on_reload_pages = None
        self._on_cancel_reload = None
        self._on_finish_transfers = None
        self._pending_offload = None
        # prefix-cache hooks (DESIGN.md §13): blocks kept alive purely
        # by the radix index (refcount 0, owner None) are charged here
        self._cache_reclaim = None
        self._cache_reclaimable = None
        self.cached_blocks = 0
        # telemetry
        self.evicted_blocks = 0
        self.reloaded_blocks = 0
        self.eviction_overhead_s: List[float] = []
        self.residency_log: List[Tuple[float, int]] = []

    # ------------------------------------------------------------- hooks
    def set_page_hooks(self, *, on_evict=None, on_reload=None,
                       on_cancel_reload=None, on_finish_transfers=None,
                       pending_offload=None) -> None:
        """Register the narrow data-plane hooks (DESIGN.md §3, §10):
        this manager stays pure accounting, but a paged engine can make
        every eviction/reload decision move physical pages.

        on_evict(sid, blocks): called after a session's HBM range shrank
        by `blocks` — the engine offloads that many suffix pages to its
        DRAM tier (chunked copy-then-free under the async transfer
        engine). on_reload(sid, blocks, background=..., transfer=...):
        called after a reload was admitted — the engine queues (or, on
        the synchronous path, immediately moves) the offloaded pages
        back; `transfer` carries the channel-modeled [start, done] span
        the chunks interpolate. The async hooks:

        on_cancel_reload(sid) -> pages: drop queued reload chunks (burst
        cancel); the manager reverts its accounting by the returned page
        count. on_finish_transfers(sid, now) -> (on_s, off_s): settle a
        session's queued chunks at turn start, returning the on-path
        stall and the off-path seconds already hidden. pending_offload
        (sid) -> pages: copy-then-free offloads still in flight — a
        reload cancels those for free, so the modeled transfer shrinks
        by that many blocks.
        """
        self._on_evict_pages = on_evict
        self._on_reload_pages = on_reload
        self._on_cancel_reload = on_cancel_reload
        self._on_finish_transfers = on_finish_transfers
        self._pending_offload = pending_offload

    def set_cache_hooks(self, *, reclaim=None, reclaimable=None) -> None:
        """Prefix-cache hooks: reclaim(n, now) -> blocks frees up to n
        orphaned cache-held pages (cheapest victims: no live owner, no
        host copy to write, only a future prefix miss); reclaimable(now)
        -> blocks reports how many it *could* free, counted by
        admission control next to session-evictable blocks."""
        self._cache_reclaim = reclaim
        self._cache_reclaimable = reclaimable

    @property
    def physical_pages(self) -> bool:
        """True when a data plane moves real pages on our decisions."""
        return (self._on_evict_pages is not None
                or self._on_reload_pages is not None)

    @property
    def async_transfers(self) -> bool:
        """True when the data plane settles transfers chunk-by-chunk
        (the preloader then charges stalls from the physical ledger,
        not from the modeled Transfer alone)."""
        return self._on_finish_transfers is not None

    # ------------------------------------------------------------- state
    def session(self, sid: str) -> SessionKV:
        kv = self.sessions.get(sid)
        if kv is None:
            kv = SessionKV(session_id=sid)
            self.sessions[sid] = kv
        return kv

    @property
    def used_blocks(self) -> int:
        return sum(s.hbm_blocks for s in self.sessions.values()) \
            + self.working_blocks + self.cached_blocks

    @property
    def free_blocks(self) -> int:
        return self.capacity - self.used_blocks

    def occupancy(self) -> float:
        """R_{s,occ} of Eq. 3."""
        return min(1.0, self.used_blocks / max(1, self.capacity))

    def reclaimable_blocks(self, now: float) -> int:
        """Idle HBM blocks the eviction policy could free right now.
        Admission control counts these as available — allocation evicts
        on demand (§5.1), so a full pool with idle sessions must not
        starve live decode."""
        total = 0
        for sid, kv in self.sessions.items():
            if self.monitor is not None and self.monitor.immediate_reuse(sid):
                continue
            total += kv.evictable(now)
        if self._cache_reclaimable is not None:
            total += self._cache_reclaimable(now)
        return total

    def blocks_of(self, tokens: int) -> int:
        return -(-tokens // self.block_size)

    def log_residency(self, now: float) -> None:
        self.residency_log.append((now, self.used_blocks))

    # ------------------------------------------------------------- Eq. 4
    def next_use_estimate(self, sid: str, now: float) -> float:
        if self.monitor is None:
            return now                      # fail-closed: behaves like LRU
        if self.monitor.immediate_reuse(sid):
            return now                      # immediate reuse: protect
        view = self.monitor.view(sid)
        tool_until = getattr(view, "tool_call_until", None) \
            if view is not None else None
        if tool_until is not None and tool_until > now:
            # mid-turn tool pause: next use is the tool's expected
            # return, not the playback + reply-gap estimate
            return tool_until
        t_play = self.monitor.remaining_playback_s(sid)
        t_reply = self.monitor.reply_gap_s(sid)
        return now + t_play + t_reply

    def _push_index(self, sid: str, now: float) -> None:
        t_next = self.next_use_estimate(sid, now)
        v = self._version.get(sid, 0) + 1
        self._version[sid] = v
        self._in_heap[sid] = True
        heapq.heappush(self._heap, (-t_next, next(self._tiebreak), sid, v))

    def refresh_session(self, sid: str, now: float) -> None:
        """Re-rank a session after an interaction event."""
        if self.policy == "next_use" and self.index_mode == "heap":
            if self.session(sid).evictable(now) > 0:
                self._push_index(sid, now)

    # ------------------------------------------------------------- order
    def _candidates_scan(self, now: float) -> List[str]:
        """Tail-scan baseline: full linear pass, sorted farthest-first."""
        items = []
        for sid, kv in self.sessions.items():
            if kv.evictable(now) <= 0:
                continue
            if self.monitor is not None and self.monitor.immediate_reuse(sid):
                continue          # speaking/barge-in sessions are protected
            if self.policy == "next_use":
                key = self.next_use_estimate(sid, now)
            else:                            # lru: oldest access first
                key = -kv.last_access
            items.append((key, sid))
        items.sort(reverse=True)
        return [sid for _, sid in items]

    def _pop_heap_candidate(self, now: float) -> Optional[str]:
        while self._heap:
            neg_t, _, sid, v = heapq.heappop(self._heap)
            if self._version.get(sid) != v:
                continue                     # stale entry (lazy deletion)
            self._in_heap[sid] = False       # current entry leaves heap
            kv = self.sessions.get(sid)
            if kv is None or kv.evictable(now) <= 0:
                continue
            # protect sessions whose estimate moved to immediate reuse
            if self.monitor is not None and self.monitor.immediate_reuse(sid):
                continue
            return sid
        return None

    # ------------------------------------------------------------- evict
    def evict(self, need_blocks: int, now: float) -> int:
        """Free >= need_blocks from idle resident KV. Returns blocks freed.

        Suffix blocks of the selected session go first; the session's HBM
        range shrinks from the tail (prefix continuity preserved).
        """
        import time as _time
        t0 = _time.perf_counter()
        freed = 0
        if self.policy == "next_use" and self.index_mode == "heap":
            # seed the heap lazily: unseen evictable sessions, plus
            # sessions evictable again without an interaction event
            # (protection TTL lapsed, a candidate pop rejected them
            # earlier) whose current version is no longer live in it
            for sid, kv in self.sessions.items():
                if kv.evictable(now) > 0 \
                        and not self._in_heap.get(sid, False):
                    self._push_index(sid, now)
            while freed < need_blocks:
                sid = self._pop_heap_candidate(now)
                if sid is None:
                    break
                freed += self._evict_session(sid, need_blocks - freed, now)
        else:
            for sid in self._candidates_scan(now):
                if freed >= need_blocks:
                    break
                freed += self._evict_session(sid, need_blocks - freed, now)
        self.eviction_overhead_s.append(_time.perf_counter() - t0)
        return freed

    def _evict_session(self, sid: str, want: int, now: float) -> int:
        kv = self.sessions[sid]
        take = min(kv.evictable(now), want)
        if take <= 0:
            return 0
        kv.hbm_blocks -= take
        self.evicted_blocks += take
        if self.policy == "none":
            # no offload tier: KV is discarded, next turn re-prefens
            kv.total_blocks -= take
            kv.discarded = True
        if kv.evictable(now) > 0 and self.policy == "next_use" \
                and self.index_mode == "heap":
            self._push_index(sid, now)      # partial eviction: re-rank rest
        if self._on_evict_pages is not None and self.policy != "none":
            self._on_evict_pages(sid, take)
        return take

    # ------------------------------------------------------------- alloc
    def _make_room(self, blocks: int, now: float) -> bool:
        """Free capacity for `blocks`: reclaim orphaned prefix-cache
        pages first (zero transfer cost, only a future prefix miss —
        strictly cheaper than evicting a session that must reload),
        then run the Eq.4 eviction pass. Session-victim *order* is
        unchanged by the cache tier."""
        if self.free_blocks < blocks and self._cache_reclaim is not None:
            self.cached_blocks -= self._cache_reclaim(
                blocks - self.free_blocks, now)
        if self.free_blocks < blocks:
            self.evict(blocks - self.free_blocks, now)
        return self.free_blocks >= blocks

    def try_allocate_working(self, blocks: int, now: float) -> bool:
        """Blocks for live request growth (pinned until released)."""
        if not self._make_room(blocks, now):
            return False
        self.working_blocks += blocks
        return True

    def release_working(self, blocks: int) -> None:
        self.working_blocks = max(0, self.working_blocks - blocks)

    def release_session(self, sid: str) -> None:
        """Session ended (user hung up): drop its KV accounting — the
        data plane frees the physical pages."""
        self.sessions.pop(sid, None)
        self._version.pop(sid, None)
        self._in_heap.pop(sid, None)

    def pin(self, sid: str) -> None:
        self.session(sid).pinned = True

    def unpin(self, sid: str, now: float) -> None:
        kv = self.session(sid)
        kv.pinned = False
        kv.last_access = now
        self.refresh_session(sid, now)

    def commit_turn(self, sid: str, context_tokens: int, now: float) -> None:
        """After a turn finishes: working KV becomes idle session KV."""
        kv = self.session(sid)
        blocks = self.blocks_of(context_tokens)
        grow = blocks - kv.total_blocks
        kv.total_blocks = blocks
        # own resident blocks can never exceed what isn't an attached
        # shared prefix (those stay charged to their owner / the cache)
        kv.hbm_blocks = min(kv.hbm_blocks + max(0, grow),
                            blocks - kv.shared_blocks)
        kv.pinned = False
        kv.discarded = False
        kv.last_access = now
        self.refresh_session(sid, now)

    # ------------------------------------------------------------- reload
    def missing_blocks(self, sid: str) -> int:
        kv = self.session(sid)
        return kv.dram_blocks

    def recompute_tokens(self, sid: str) -> int:
        """'none' policy: tokens whose KV was discarded (re-prefill cost)."""
        kv = self.session(sid)
        return kv.dram_blocks * self.block_size if kv.discarded else 0

    def transfer_blocks(self, sid: str) -> int:
        """Blocks a reload would actually move over the channel: the
        offloaded suffix minus copy-then-free offloads still in flight
        (cancelling those restores the pages without a transfer)."""
        n = self.session(sid).dram_blocks
        if n > 0 and self._pending_offload is not None:
            n -= min(n, self._pending_offload(sid))
        return max(0, n)

    def reload(self, sid: str, now: float, *, background: bool):
        """Bring the offloaded suffix back. Returns Transfer or None."""
        kv = self.session(sid)
        n = kv.dram_blocks
        if n <= 0 or self.policy == "none":
            return None
        if self.free_blocks < n:
            # pin across the eviction pass: the session being brought
            # back must never be selected as its own victim
            was_pinned = kv.pinned
            kv.pinned = True
            self._make_room(n, now)
            kv.pinned = was_pinned
        if self.free_blocks < n:
            return None
        # only blocks whose bytes are truly on the host cross the
        # channel; cancellable in-flight offloads come back for free
        t = self.channel.submit(sid, self.transfer_blocks(sid), now,
                                background)
        # blocks become resident on completion; account them now so
        # concurrent admissions see the pressure
        kv.hbm_blocks += n
        self.reloaded_blocks += n
        if self._on_reload_pages is not None:
            self._on_reload_pages(sid, n, background=background,
                                  transfer=t)
        return t

    def cancel_reload(self, sid: str, now: float) -> int:
        """Burst cancel: drop the session's queued reload chunks and
        revert the admission-time accounting for exactly the pages that
        had not yet landed. Returns blocks cancelled (0 without an
        async data plane — bytes already moved)."""
        if self._on_cancel_reload is None:
            return 0
        n = self._on_cancel_reload(sid)
        if n > 0:
            kv = self.session(sid)
            kv.hbm_blocks = max(0, kv.hbm_blocks - n)
            self.reloaded_blocks -= n
            self.refresh_session(sid, now)
        return n

    def finish_transfers(self, sid: str, now: float):
        """Turn-start settlement (async data plane): physically complete
        the session's queued reload chunks; returns (on_path_s,
        off_path_s). (0.0, 0.0) without an async plane."""
        if self._on_finish_transfers is None:
            return 0.0, 0.0
        return self._on_finish_transfers(sid, now)

    def protect(self, sid: str, now: float) -> None:
        """Preload-protection TTL (§5.3). Shared-prefix rule (DESIGN.md
        §13): a shared page is protected as long as ANY sharer needs it
        — while sharers live that is structural (`shared_pinned_blocks`
        keeps the page out of every evictable budget, regardless of
        TTLs), and when the last sharer detaches the radix index banks
        ``max`` over the sharers' `protected_until` values, so the
        orphaned page honors the longest outstanding TTL before
        `reclaim` may free it."""
        kv = self.session(sid)
        protected = sum(1 for s in self.sessions.values()
                        if s.protected_until > now)
        if protected * self.block_size < self.protected_cap:
            kv.protected_until = now + self.protect_ttl_s

    def protect_tool(self, sid: str, now: float,
                     expected_latency_s: float) -> None:
        """Tool-pause protection: hold the session's KV resident until
        the tool's expected return (capped by its own TTL so a tool that
        never comes back cannot squat on the pool). Distinct from the
        preload TTL — the two states expire independently and either one
        alone keeps the blocks unevictable."""
        kv = self.session(sid)
        kv.tool_protected_until = now + min(max(0.0, expected_latency_s),
                                            self.tool_protect_ttl_s)

    def clear_tool_protection(self, sid: str, now: float) -> None:
        """The tool returned (or the session resumed): lift the hold and
        re-rank the session under its refreshed next-use estimate."""
        kv = self.session(sid)
        kv.tool_protected_until = -1.0
        self.refresh_session(sid, now)

"""Runtime monitor — the interaction plane (paper §3).

Turns client-side signals (playback progress, speech activity, barge-in)
into a compact per-session view read by the scheduler and KV manager.
All fields are optional-by-design: policies that find missing telemetry
fall back to substrate behavior (fail-closed operation, §6).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

REPLY_GAP_EMA = 0.3              # weight of newest observation


@dataclass
class PlaybackState:
    """Client playback as a piecewise timeline.

    ``play_end`` is the wall-clock instant buffered audio runs out;
    appending audio at time t extends it (opening a gap if t > play_end).

    Robust to degenerate client reports: a zero/negative-duration chunk
    never marks playback as started (an empty packet is not first
    audio), out-of-order appends (t below an earlier append's t) queue
    behind the existing buffer without rewinding the timeline, and
    ``play_end`` is monotone non-decreasing throughout.
    """
    started: bool = False
    start_time: float = 0.0
    appended_s: float = 0.0          # total audio delivered to the client
    play_end: float = 0.0            # when the buffer drains
    gap_s: float = 0.0               # cumulative stall time
    max_gap_s: float = 0.0
    n_gaps: int = 0
    complete: bool = False           # server finished generating the reply

    def append(self, now: float, dur_s: float) -> None:
        if dur_s <= 0.0 and not self.started:
            return                   # empty chunk cannot start playback
        if not self.started:
            self.started = True
            self.start_time = now
            self.play_end = now
        elif now > self.play_end:
            gap = now - self.play_end
            self.gap_s += gap
            self.max_gap_s = max(self.max_gap_s, gap)
            self.n_gaps += 1
            self.play_end = now
        self.appended_s += max(0.0, dur_s)
        self.play_end += max(0.0, dur_s)

    def buffer_s(self, now: float) -> float:
        """Playable audio waiting at the client (the P_i^s of audio stages)."""
        if not self.started:
            return 0.0
        return max(0.0, self.play_end - now)

    def consumed_s(self, now: float) -> float:
        """Audio the client has heard by ``now``; clamped non-negative so
        an out-of-order (stale-timestamped) query after a gap cannot
        report negative consumption."""
        if not self.started:
            return 0.0
        return max(0.0, self.appended_s - self.buffer_s(now))


@dataclass
class SessionView:
    """What the monitor exposes to engine policies."""
    session_id: str
    turn_index: int = 0
    playback: PlaybackState = field(default_factory=PlaybackState)
    speaking: bool = False
    speech_start_time: Optional[float] = None
    barge_in: bool = False           # interruption observed this response
    playback_end_estimate: Optional[float] = None
    reply_gap_ema: Optional[float] = None   # user think-time estimate (s)
    last_playback_end: Optional[float] = None
    expected_speech_end: Optional[float] = None
    # full-duplex frame cadence: a periodic-frame session's per-frame
    # deadline walks forward one period per emitted token. The period is
    # sticky across turns (it marks the session as duplex for preload
    # admission); the deadline only lives while a response streams.
    frame_period_s: float = 0.0
    frame_deadline: Optional[float] = None
    # mid-turn tool pause: the wall-clock instant the external tool is
    # expected to return — Eq. 4 next-use reads this instead of the
    # reply-gap EMA while it is in the future.
    tool_call_until: Optional[float] = None
    # physical KV placement (reported by the paged engine's data plane)
    resident_pages: int = 0
    offloaded_pages: int = 0


class RuntimeMonitor:
    """Tracks live session state; the single source the policies read."""

    def __init__(self, clock, *, workload_reply_gap_prior: float = 2.0):
        self.clock = clock
        self.sessions: Dict[str, SessionView] = {}
        self.reply_gap_prior = workload_reply_gap_prior

    # ----------------------------------------------------------- events
    def register(self, session_id: str) -> SessionView:
        view = self.sessions.get(session_id)
        if view is None:
            view = SessionView(session_id=session_id)
            self.sessions[session_id] = view
        return view

    def on_turn_start(self, session_id: str, turn_index: int) -> None:
        v = self.register(session_id)
        v.turn_index = turn_index
        v.barge_in = False
        v.playback = PlaybackState()
        # a turn can start without a SpeechEnd (full duplex, tool-call
        # resume): clear the previous utterance's state here so Eq. 4
        # next-use and the preload window never read last turn's
        # estimate as if it were current. frame_deadline stays — it was
        # armed by THIS turn's request (on_frame_turn) and anchors the
        # miss accounting at frame arrival, queueing delay included.
        v.speaking = False
        v.expected_speech_end = None
        v.tool_call_until = None

    def on_audio(self, session_id: str, dur_s: float) -> None:
        v = self.register(session_id)
        v.playback.append(self.clock.now(), dur_s)

    def on_response_complete(self, session_id: str) -> None:
        v = self.register(session_id)
        v.playback.complete = True
        v.last_playback_end = max(v.playback.play_end, self.clock.now())
        v.frame_deadline = None

    def on_speech_start(self, session_id: str,
                        expected_dur_s: Optional[float] = None) -> None:
        now = self.clock.now()
        v = self.register(session_id)
        v.speaking = True
        v.speech_start_time = now
        v.expected_speech_end = (now + expected_dur_s
                                 if expected_dur_s else None)
        # update think-time EMA: playback end -> speech start
        if v.last_playback_end is not None and not v.barge_in:
            gap = max(0.0, now - v.last_playback_end)
            if v.reply_gap_ema is None:
                v.reply_gap_ema = gap
            else:
                v.reply_gap_ema = ((1 - REPLY_GAP_EMA) * v.reply_gap_ema
                                   + REPLY_GAP_EMA * gap)

    def on_speech_end(self, session_id: str) -> None:
        v = self.register(session_id)
        v.speaking = False

    def on_barge_in(self, session_id: str) -> None:
        v = self.register(session_id)
        v.barge_in = True
        v.speaking = True
        v.speech_start_time = self.clock.now()
        v.playback.complete = True
        v.last_playback_end = self.clock.now()
        v.frame_deadline = None

    def on_frame_turn(self, session_id: str, frame_period_s: float) -> None:
        """A periodic-frame (full-duplex) turn was requested: arm the
        frame clock. The first frame is due one period from now; every
        emitted token advances the deadline by one period."""
        v = self.register(session_id)
        v.frame_period_s = frame_period_s
        v.frame_deadline = self.clock.now() + frame_period_s

    def on_tool_call_start(self, session_id: str,
                           expected_latency_s: float) -> None:
        """The turn ended in a tool call: the session idles with hot KV
        until roughly now + expected_latency_s. Not a speech event — the
        reply-gap EMA must not learn tool latencies as think time."""
        v = self.register(session_id)
        v.tool_call_until = self.clock.now() + max(0.0, expected_latency_s)
        v.speaking = False
        v.expected_speech_end = None

    def on_tool_call_result(self, session_id: str,
                            resume_gap_s: float = 0.0) -> None:
        """The tool returned: the resume turn arrives in ~resume_gap_s.
        Opens a preload window of that width (expected_speech_end) so an
        evicted session's reload hides in the gap, again without
        touching the speech state or the reply-gap EMA."""
        v = self.register(session_id)
        v.tool_call_until = None
        v.expected_speech_end = self.clock.now() + max(0.0, resume_gap_s)

    def on_page_movement(self, session_id: str, *, resident: int,
                         offloaded: int) -> None:
        """Data-plane report: where a session's KV pages physically live
        (HBM-resident vs DRAM-offloaded). Fed by the paged engine after
        every prefill/evict/reload/trim so dashboards and policies can
        read real placement instead of accounting estimates."""
        v = self.register(session_id)
        v.resident_pages = resident
        v.offloaded_pages = offloaded

    def forget(self, session_id: str) -> Optional[SessionView]:
        """Drop (and return) a session's view — the session left this
        monitor's engine (migrated away or fully released)."""
        return self.sessions.pop(session_id, None)

    def adopt(self, session_id: str, view: SessionView) -> None:
        """Install a view transplanted from another engine's monitor so
        interaction state (reply-gap EMA, speaking flag, expected speech
        end) survives a cross-replica migration — Eq. 4 and the preload
        window keep working on the destination without a cold start."""
        assert session_id not in self.sessions, session_id
        self.sessions[session_id] = view

    # ----------------------------------------------------------- queries
    def view(self, session_id: str) -> Optional[SessionView]:
        return self.sessions.get(session_id)

    def playback_buffer_s(self, session_id: str) -> Optional[float]:
        v = self.sessions.get(session_id)
        if v is None:
            return None
        return v.playback.buffer_s(self.clock.now())

    def remaining_playback_s(self, session_id: str) -> float:
        """T_play of Eq. 4 — audio still to be heard (buffered only; the
        paper's fallback uses progress counters when generation is live)."""
        v = self.sessions.get(session_id)
        if v is None:
            return 0.0
        return v.playback.buffer_s(self.clock.now())

    def reply_gap_s(self, session_id: str) -> float:
        """T_reply of Eq. 4 — per-session EMA, workload prior fallback."""
        v = self.sessions.get(session_id)
        if v is None or v.reply_gap_ema is None:
            return self.reply_gap_prior
        return v.reply_gap_ema

    def immediate_reuse(self, session_id: str) -> bool:
        v = self.sessions.get(session_id)
        return bool(v and (v.speaking or v.barge_in))

    def page_counts(self, session_id: str):
        """(resident, offloaded) physical page counts, (0, 0) unknown."""
        v = self.sessions.get(session_id)
        if v is None:
            return 0, 0
        return v.resident_pages, v.offloaded_pages

"""ShapeDtypeStruct input specs for every (arch x input-shape) cell.

Weak-type-correct, shardable, no device allocation — the dry-run lowers
against these. Modality frontends are STUBS: whisper gets precomputed
frame embeddings, paligemma precomputed patch embeddings (system prompt
contract).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import model as M

SDS = jax.ShapeDtypeStruct


@dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str              # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}


def cell_supported(cfg, shape_name: str) -> Optional[str]:
    """None if runnable; otherwise the skip reason (recorded in the table)."""
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        return ("full quadratic attention: 500k-token decode needs "
                "sub-quadratic state (DESIGN.md §6)")
    return None


def param_shapes(cfg):
    return jax.eval_shape(
        functools.partial(M.init_params, cfg), jax.random.PRNGKey(0))


def cache_shapes(cfg, batch: int, capacity: int):
    enc = cfg.encoder.num_frames if cfg.family == "encdec" else 0
    return jax.eval_shape(
        functools.partial(M.init_cache, cfg, batch, capacity,
                          enc_frames=enc))


def batch_specs(cfg, cell: ShapeCell) -> dict:
    """Training-batch ShapeDtypeStructs."""
    B, S = cell.global_batch, cell.seq_len
    d = cfg.d_model
    if cfg.frontend == "vision":
        P = cfg.frontend_len
        return {
            "tokens": SDS((B, S - P), jnp.int32),
            "labels": SDS((B, S - P), jnp.int32),
            "patches": SDS((B, P, d), jnp.dtype(cfg.dtype)),
            "prefix_len": SDS((B,), jnp.int32),
        }
    out = {"tokens": SDS((B, S), jnp.int32),
           "labels": SDS((B, S), jnp.int32)}
    if cfg.family == "encdec":
        out["frames"] = SDS((B, cfg.encoder.num_frames, d),
                            jnp.dtype(cfg.dtype))
    return out


def prefill_specs(cfg, cell: ShapeCell):
    """(tokens, cache, extras) ShapeDtypeStructs for a prefill step."""
    B, S = cell.global_batch, cell.seq_len
    d = cfg.d_model
    extras = {}
    S_text = S
    if cfg.frontend == "vision":
        P = cfg.frontend_len
        S_text = S - P
        extras["frontend_embeds"] = SDS((B, P, d), jnp.dtype(cfg.dtype))
        extras["prefix_len"] = SDS((B,), jnp.int32)
    if cfg.family == "encdec":
        extras["enc_frames"] = SDS((B, cfg.encoder.num_frames, d),
                                   jnp.dtype(cfg.dtype))
    tokens = SDS((B, S_text), jnp.int32)
    cache = cache_shapes(cfg, B, S)
    return tokens, cache, extras


def decode_specs(cfg, cell: ShapeCell):
    """(tokens, cache) for a single decode step over a seq_len-deep cache."""
    B, S = cell.global_batch, cell.seq_len
    tokens = SDS((B,), jnp.int32)
    cache = cache_shapes(cfg, B, S)
    return tokens, cache


def model_flops(cfg, cell: ShapeCell) -> float:
    """Reference useful-FLOPs: 6*N_active*D for training, 2*N_active*D for
    inference (D = tokens processed in the lowered step)."""
    n = cfg.num_active_params()
    if cell.kind == "train":
        return 6.0 * n * cell.global_batch * cell.seq_len
    if cell.kind == "prefill":
        return 2.0 * n * cell.global_batch * cell.seq_len
    return 2.0 * n * cell.global_batch          # decode: one token per seq

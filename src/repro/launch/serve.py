"""Serving launcher — runs the realtime interaction pipeline.

Simulated pipeline (paper-scale policies on the virtual clock):

  PYTHONPATH=src python -m repro.launch.serve --model qwen3-omni-like \
      --workload interactive --concurrency 12 --barge-in 0.5 \
      --system liveserve

Real engine (paged data plane on actual JAX state, CPU-runnable):

  PYTHONPATH=src python -m repro.launch.serve --engine real

runs a multi-turn barge-in conversation through PagedRealtimeEngine —
physical evict/offload/preload-reload — and reports per-turn TTFT,
reload stall, and re-prefill tokens (zero on reloaded turns).
"""
from __future__ import annotations

import argparse
import json


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", default="sim", choices=["sim", "real"],
                    help="sim: event-driven simulator; real: paged JAX "
                         "data plane (DESIGN.md §3)")
    ap.add_argument("--model", default="qwen3-omni-like",
                    choices=["qwen3-omni-like", "ming-omni-like"])
    ap.add_argument("--workload", default="interactive",
                    choices=["sharegpt", "interactive", "mixed"])
    ap.add_argument("--system", default="liveserve",
                    choices=["liveserve", "vllm-omni", "vllm-omni-wo"])
    ap.add_argument("--sessions", type=int, default=32)
    ap.add_argument("--concurrency", type=int, default=8)
    ap.add_argument("--barge-in", type=float, default=0.0)
    ap.add_argument("--kv-gb", type=float, default=4.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()

    if args.engine == "real":
        from repro.serving.paged_engine import run_multiturn_demo
        out = run_multiturn_demo(
            seed=args.seed,
            log=(lambda *_a, **_k: None) if args.json else print)
        if args.json:
            print(json.dumps(out, indent=1, default=str))
        return

    from repro.serving.costmodel import PIPELINES
    from repro.serving.simulator import run_sim
    from repro.serving.workload import WorkloadConfig

    systems = {
        "liveserve": dict(policy="liveserve"),
        "vllm-omni": dict(policy="fcfs", kv_policy="lru", preload=False),
        "vllm-omni-wo": dict(policy="fcfs", kv_policy="none",
                             preload=False),
    }
    pipe = PIPELINES[args.model](kv_capacity_gb=args.kv_gb)
    wl = WorkloadConfig(kind=args.workload, num_sessions=args.sessions,
                        concurrency=args.concurrency, seed=args.seed,
                        p_barge_in=args.barge_in)
    m = run_sim(pipe, wl, until=3600.0, **systems[args.system])
    s = m.summary()
    if args.json:
        print(json.dumps(s, indent=1))
    else:
        for k, v in s.items():
            print(f"{k:20s} {v:.4f}" if isinstance(v, float)
                  else f"{k:20s} {v}")


if __name__ == "__main__":
    main()

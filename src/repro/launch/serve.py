"""Serving launcher — runs the realtime interaction pipeline.

Simulated pipeline (paper-scale policies on the virtual clock):

  PYTHONPATH=src python -m repro.launch.serve --model qwen3-omni-like \
      --workload interactive --concurrency 12 --barge-in 0.5 \
      --system liveserve

Live gateway (event-driven front-end over the real paged JAX data
plane, scaled wall clock, CPU-runnable — DESIGN.md §4):

  PYTHONPATH=src python -m repro.launch.serve --engine live \
      --workload interactive --sessions 8 --barge-in 0.3 \
      --system liveserve --clock-scale 4

Real engine demo (scripted multi-turn conversation, no gateway):

  PYTHONPATH=src python -m repro.launch.serve --engine real

walks evict/offload/preload-reload/barge-in through
PagedRealtimeEngine and reports per-turn TTFT, reload stall, and
re-prefill tokens. Workload/system flags only apply to --engine
sim|live; passing them with --engine real is an error, not a silent
no-op.
"""
from __future__ import annotations

import argparse
import json

# flags meaningful only for the sim / live engines; --engine real must
# reject them explicitly instead of silently ignoring them
_WORKLOAD_FLAGS = ("workload", "system", "sessions", "concurrency",
                   "barge_in", "kv_gb")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", default="sim",
                    choices=["sim", "real", "live"],
                    help="sim: event-driven simulator; live: asyncio "
                         "gateway over the paged JAX data plane "
                         "(DESIGN.md §4); real: scripted paged-engine "
                         "demo (DESIGN.md §3)")
    ap.add_argument("--model", default=None,
                    choices=["qwen3-omni-like", "ming-omni-like"],
                    help="sim engine only; live/real serve the reduced "
                         "CPU-runnable config")
    ap.add_argument("--workload", default=None,
                    choices=["sharegpt", "interactive", "mixed",
                             "duplex", "toolcall", "handoff"],
                    help="duplex: full-duplex periodic-frame sessions "
                         "(per-token deadlines, deadline_miss_rate); "
                         "toolcall: agentic tool-call pauses (hot-KV "
                         "idle + resume without re-prefill); handoff: "
                         "mid-conversation transfer to another model "
                         "config (use with --replicas >= 2). These "
                         "three need --engine live")
    ap.add_argument("--system", default=None,
                    choices=["liveserve", "vllm-omni", "vllm-omni-wo"])
    ap.add_argument("--sessions", type=int, default=None)
    ap.add_argument("--concurrency", type=int, default=None)
    ap.add_argument("--barge-in", type=float, default=None)
    ap.add_argument("--kv-gb", type=float, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", action="store_true")
    # --engine real | live (the paged data plane)
    ap.add_argument("--fused-step", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="real/live engines: run each round's whole "
                         "token budget (prefill chunks + decode) as one "
                         "jitted launch (DESIGN.md §11). "
                         "--no-fused-step serves on the per-token "
                         "differential-control plane")
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="real/live engines: shard the paged KV plane "
                         "over a ('data','model') mesh, e.g. 1x8 "
                         "(DESIGN.md §9). On CPU set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N")
    # --engine live only
    ap.add_argument("--prefix-cache", default=None,
                    action=argparse.BooleanOptionalAction,
                    help="live engine: share committed KV pages across "
                         "sessions with identical prompt prefixes — "
                         "refcounted copy-on-write pages behind a radix "
                         "prefix index (DESIGN.md §13). Off by default "
                         "(the bit-exact no-sharing control)")
    ap.add_argument("--prompt-families", type=int, default=None,
                    help="live engine: assign sessions round-robin to K "
                         "shared-system-prompt families (workload knob "
                         "that makes --prefix-cache hits observable)")
    ap.add_argument("--family-prefix-len", type=int, default=None,
                    help="live engine: shared prefix tokens per family "
                         "(with --prompt-families)")
    ap.add_argument("--clock-scale", type=float, default=None,
                    help="live engine: wall-clock speedup factor")
    ap.add_argument("--slots", type=int, default=None,
                    help="live engine: decode batch rows")
    ap.add_argument("--kv-pages", type=int, default=None,
                    help="live engine: KV pool size in pages")
    ap.add_argument("--preload-chunks", type=int, default=None,
                    help="live engine: transfer chunks each round may "
                         "drain between decode sub-batches — the async "
                         "chunked KV transfer budget that lands "
                         "speech-time preloads off the turn critical "
                         "path (DESIGN.md §10)")
    ap.add_argument("--kv-quant", default=None,
                    choices=["fp32", "int8"],
                    help="live engine: KV wire format on the offload "
                         "path (DESIGN.md §14). int8 block-quantizes "
                         "host copies (~4x less modeled PCIe per page, "
                         "tolerance-gated quality); fp32 is the "
                         "bit-exact default")
    ap.add_argument("--spec-decode", type=int, default=None,
                    metavar="K",
                    help="live engine: draft up to K tokens per decode "
                         "slot per round and verify them in the same "
                         "fused launch (self-speculative prompt-lookup "
                         "drafts; DESIGN.md §16). Lossless: accepted "
                         "streams are bit-exact vs K=0. Needs "
                         "--fused-step; composes with --mesh, "
                         "--replicas, --prefix-cache, --kv-quant")
    ap.add_argument("--autotune", default=None, metavar="CACHE.json",
                    help="live engine: consult (and require) a kernel "
                         "autotune cache JSON at jit time — build one "
                         "with benchmarks/autotune_bench.py "
                         "(DESIGN.md §16)")
    ap.add_argument("--replicas", type=int, default=None,
                    help="live engine: N data-parallel engine replicas "
                         "behind one gateway, with live cross-replica "
                         "KV migration (DESIGN.md §12). Composes with "
                         "--mesh: every replica shards its page store "
                         "over the same mesh")
    args = ap.parse_args()

    if args.engine != "live":
        live_only = [f"--{f.replace('_', '-')}" for f in
                     ("clock_scale", "slots", "kv_pages",
                      "preload_chunks", "replicas", "prefix_cache",
                      "prompt_families", "family_prefix_len",
                      "kv_quant", "spec_decode", "autotune")
                     if getattr(args, f) is not None]
        if live_only:
            ap.error(f"{', '.join(live_only)} only apply to "
                     f"--engine live")
    if args.engine == "sim" and args.mesh is not None:
        ap.error("--mesh shards the real paged data plane; the simulator "
                 "models costs, not placement (use --engine real|live)")
    if args.engine == "sim" and not args.fused_step:
        ap.error("--no-fused-step selects the paged data plane's "
                 "per-token control; the simulator has no data plane "
                 "(use --engine real|live)")
    mesh = None
    if args.mesh is not None:
        from repro.launch.mesh import make_serving_mesh
        try:
            mesh = make_serving_mesh(args.mesh)
        except ValueError as e:
            ap.error(str(e))
    if args.engine != "sim" and args.model is not None:
        ap.error("--model only applies to --engine sim; live/real run "
                 "the reduced CPU-runnable config")

    if args.engine == "real":
        given = [f"--{f.replace('_', '-')}" for f in _WORKLOAD_FLAGS
                 if getattr(args, f) is not None]
        if given:
            ap.error(
                f"--engine real runs a fixed scripted demo and does not "
                f"take {', '.join(given)}; use --engine live (real data "
                f"plane under load) or --engine sim (paper-scale "
                f"simulation)")
        from repro.serving.paged_engine import run_multiturn_demo
        out = run_multiturn_demo(
            seed=args.seed, mesh=mesh, fused_step=args.fused_step,
            log=(lambda *_a, **_k: None) if args.json else print)
        if args.json:
            print(json.dumps(out, indent=1, default=str))
        return

    # shared workload defaults for sim and live
    workload = args.workload or "interactive"
    if args.engine != "live" \
            and workload in ("duplex", "toolcall", "handoff"):
        ap.error(f"--workload {workload} drives gateway-level "
                 f"interaction events (frame deadlines, tool pauses, "
                 f"handoffs); use --engine live")
    system = args.system or "liveserve"
    sessions = args.sessions if args.sessions is not None else 32
    barge_in = args.barge_in if args.barge_in is not None else 0.0

    if args.engine == "live":
        bad = [n for n, v in (("--kv-gb", args.kv_gb),
                              ("--concurrency", args.concurrency))
               if v is not None]
        if bad:
            ap.error(f"--engine live is open-loop on a page pool; "
                     f"{', '.join(bad)} do not apply (use --kv-pages "
                     f"for pool size)")
        policies = {"liveserve": "liveserve", "vllm-omni": "fcfs"}
        if system not in policies:
            ap.error(f"--engine live supports --system "
                     f"{'|'.join(policies)} (the paged data plane needs "
                     f"an offload tier; 'vllm-omni-wo' discards KV — "
                     f"use --engine sim for that baseline)")
        replicas = args.replicas if args.replicas is not None else 1
        if replicas < 1:
            ap.error("--replicas must be >= 1")
        spec_decode = args.spec_decode if args.spec_decode is not None \
            else 0
        if spec_decode < 0:
            ap.error("--spec-decode must be >= 0")
        if spec_decode > 0 and not args.fused_step:
            ap.error("--spec-decode verifies drafts in one fused launch "
                     "and cannot run on the per-token control plane; "
                     "drop --no-fused-step (DESIGN.md §16)")
        run_kw = dict(
            policy=policies[system], kind=workload, sessions=sessions,
            barge_in=barge_in, seed=args.seed,
            scale=(args.clock_scale
                   if args.clock_scale is not None else 4.0),
            slots=args.slots if args.slots is not None else 8,
            num_pages=args.kv_pages, mesh=mesh,
            preload_chunks=(args.preload_chunks
                            if args.preload_chunks is not None else 1),
            fused_step=args.fused_step,
            prefix_cache=bool(args.prefix_cache),
            kv_quant=args.kv_quant or "fp32",
            spec_decode=spec_decode,
            autotune=args.autotune,
            prompt_families=(args.prompt_families
                             if args.prompt_families is not None else 0),
            family_prefix_len=(args.family_prefix_len
                               if args.family_prefix_len is not None
                               else 0),
            # the family prefix rides on top of the per-turn prompt
            # draw, so grow each session's context window to fit it
            # (page_size 8, default pages_per_seq 8)
            pages_per_seq=8 + -(-(args.family_prefix_len or 0) // 8),
            frontier_cap_s=3.0 if system == "liveserve" else None)
        if replicas > 1:
            from repro.serving.fleet import run_fleet_workload
            m, gw = run_fleet_workload(replicas=replicas, **run_kw)
            engines = list(gw.replicas)
        else:
            from repro.serving.gateway import run_gateway_workload
            m, gw = run_gateway_workload(**run_kw)
            engines = [gw.engine]
        s = m.summary()
        s["rounds"] = gw.rounds
        s["max_over_frontier_s"] = gw.max_over_frontier_s
        off = sum(e.transfer.stats.reload_pages_off_path
                  for e in engines)
        on = sum(e.transfer.stats.reload_pages_on_path for e in engines)
        s["transfer_overlap_frac"] = off / (off + on) if off + on else 0.0
        if replicas > 1:
            done = gw.migrator.completed()
            for i in range(replicas):
                mig_in = sum(1 for p in done if p.dst == i)
                mig_out = sum(1 for p in done if p.src == i)
                s[f"replica{i}"] = (
                    f"routed={gw.router.routed[i]} "
                    f"migrated_in={mig_in} migrated_out={mig_out} "
                    f"peak_occupancy={m.replica_occupancy[i]:.3f}"
                    + (" [drained]" if i in gw.router.draining else ""))
    else:
        from repro.serving.costmodel import PIPELINES
        from repro.serving.simulator import run_sim
        from repro.serving.workload import WorkloadConfig

        systems = {
            "liveserve": dict(policy="liveserve"),
            "vllm-omni": dict(policy="fcfs", kv_policy="lru",
                              preload=False),
            "vllm-omni-wo": dict(policy="fcfs", kv_policy="none",
                                 preload=False),
        }
        pipe = PIPELINES[args.model or "qwen3-omni-like"](
            kv_capacity_gb=args.kv_gb if args.kv_gb is not None else 4.0)
        wl = WorkloadConfig(
            kind=workload, num_sessions=sessions,
            concurrency=(args.concurrency
                         if args.concurrency is not None else 8),
            seed=args.seed, p_barge_in=barge_in)
        m = run_sim(pipe, wl, until=3600.0, **systems[system])
        s = m.summary()

    if args.json:
        print(json.dumps(s, indent=1))
    else:
        for k, v in s.items():
            print(f"{k:20s} {v:.4f}" if isinstance(v, float)
                  else f"{k:20s} {v}")


if __name__ == "__main__":
    main()

import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run (deliverable e) + roofline extraction (deliverable g).

For every (architecture x input shape x mesh): build ShapeDtypeStruct
inputs, pjit-lower the step with the ShardingRules specs, ``compile()``,
and record memory_analysis / cost_analysis / collective bytes parsed from
the optimized HLO into experiments/dryrun/<cell>.json.

The 512 placeholder host devices exist ONLY in this process (the env var
above is set before any jax import); smoke tests and benchmarks see 1
device.

Usage:
  python -m repro.launch.dryrun --arch qwen3-4b --shape decode_32k
  python -m repro.launch.dryrun --all [--multi-pod] [--out experiments/dryrun]
"""
import argparse
import functools
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_configs
from repro.distributed.sharding import ShardingRules
from repro.launch import specs as SP
from repro.launch.mesh import make_production_mesh, mesh_context
from repro.models import model as M
from repro.training import optimizer as opt_mod
from repro.training.train_loop import TrainConfig, build_train_step

# v5e hardware constants (roofline denominators)
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / link (ICI)

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_RE = re.compile(
    r"=\s*(\(?[^=()]*?\)?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Per-device bytes moved by collectives, summed per op kind."""
    out = {}
    for m in _COLL_RE.finditer(hlo_text):
        types, kind = m.group(1), m.group(2)
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(types):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        out[kind] = out.get(kind, 0) + nbytes
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


def reduced_depth_cfg(cfg, L0: int):
    """Same architecture at depth L0 (calibration compile)."""
    import dataclasses
    kw = {"num_layers": L0}
    if cfg.encoder is not None:
        kw["encoder"] = dataclasses.replace(cfg.encoder, num_layers=L0)
    return cfg.replace(**kw)


def calibration_depths(cfg):
    if cfg.family == "hybrid":
        pat = len(cfg.rglru.block_pattern)
        return pat, 2 * pat            # keep the block pattern intact
    lo = 2 if (cfg.moe is None or not cfg.moe.first_dense_layers) else 2
    return lo, lo + 2


def cost_calibrated(cfg, cell, mesh, *, fsdp, microbatches):
    """HLO cost terms via reduced-depth UNROLLED compiles + linear
    extrapolation over layer count.

    XLA's HloCostAnalysis counts while-loop bodies once, so exact totals
    need unrolled scans — but a full-depth unrolled train graph doesn't
    compile in reasonable time on one CPU core. Layer stacks are
    homogeneous, so cost(L) = a + b*L exactly; two shallow unrolled
    compiles recover (a, b) and the full-depth totals follow.
    """
    l_lo, l_hi = calibration_depths(cfg)
    samples = []
    for L0 in (l_lo, l_hi):
        c0 = reduced_depth_cfg(cfg, L0)
        rules = ShardingRules(c0, mesh, mode=cell.kind, fsdp=fsdp)
        step, args, in_sh, donate, out_sh = build_step(
            c0, cell, mesh, rules, microbatches=microbatches)
        M.set_scan_unroll(True)
        try:
            fresh = lambda *a: step(*a)
            compiled = jax.jit(fresh, in_shardings=in_sh,
                               out_shardings=out_sh,
                               donate_argnums=donate).lower(*args).compile()
        finally:
            M.set_scan_unroll(1)
        cost = compiled.cost_analysis()
        coll = collective_bytes_from_hlo(compiled.as_text())
        samples.append({
            "L": L0,
            "flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            **{f"coll_{k}": float(v) for k, v in coll.items()},
        })
    lo, hi = samples
    L = cfg.num_layers
    out = {}
    for k in set(lo) | set(hi):
        if k == "L":
            continue
        a, b = lo.get(k, 0.0), hi.get(k, 0.0)
        slope = (b - a) / (hi["L"] - lo["L"])
        out[k] = max(0.0, a + slope * (L - lo["L"]))
    out["calibration"] = samples
    return out


def microbatches_for(cfg, cell, mesh) -> int:
    """Grad-accum so per-device live attention logits stay within ~1.5 GB
    (the dry-run lowers einsum attention, which materializes
    [B_dev/mb, H_dev, S, S] f32 logits; the TPU runtime path streams KV
    tiles through the Pallas flash kernel instead)."""
    if cell.kind != "train":
        return 1
    M = mesh.shape["model"]
    D = mesh.size // M
    h = cfg.num_heads
    h_dev = h // M if h % M == 0 else h
    s = cell.seq_len
    if cfg.family == "hybrid":
        s = min(s, cfg.rglru.local_window)  # mask bounds the live window
    b_dev = max(1, cell.global_batch // D)
    logits_bytes = b_dev * h_dev * cell.seq_len * s * 4
    mb = max(1, -(-logits_bytes // int(1.5e9)))
    # round to a divisor of the per-device batch
    while b_dev % mb:
        mb += 1
    return min(mb, b_dev)


def build_step(cfg, cell, mesh, rules, *, microbatches=None):
    """Returns (fn, args_sds, in_shardings, donate_argnums)."""
    if cell.kind == "train":
        opt = opt_mod.select_optimizer(cfg)
        mb = (microbatches if microbatches is not None
              else microbatches_for(cfg, cell, mesh))
        tc = TrainConfig(microbatches=mb, remat=True,
                         seq_shard_activations=rules.fsdp,
                         bf16_grad_reduce=os.environ.get(
                             "REPRO_BF16_GRAD", "") == "1")
        step = build_train_step(cfg, opt, tc, mesh=mesh)
        p_sds = SP.param_shapes(cfg)
        o_sds = jax.eval_shape(
            functools.partial(opt_mod.opt_init, opt), p_sds)
        b_sds = SP.batch_specs(cfg, cell)
        in_sh = (rules.params(p_sds), rules.opt_state(o_sds),
                 rules.batch(b_sds))
        # params/opt_state are consumed -> donated (in-place update)
        out_sh = (rules.params(p_sds), rules.opt_state(o_sds), None)
        return step, (p_sds, o_sds, b_sds), in_sh, (0, 1), out_sh
    if cell.kind == "prefill":
        tokens, cache, extras = SP.prefill_specs(cfg, cell)

        def step(params, tokens, cache, extras):
            return M.prefill(cfg, params, tokens, cache, mesh=mesh,
                             **extras)
        p_sds = SP.param_shapes(cfg)
        in_sh = (rules.params(p_sds), rules.batch({"tokens": tokens}
                                                  )["tokens"],
                 rules.cache(cache), rules.batch(extras))
        # pin the returned cache to the input layout — the element-wise
        # fresh-cache write otherwise lets the output inherit the
        # activation sharding (seq-unsharded: 8x output blow-up)
        out_sh = (rules.logits_sharding(cell.global_batch),
                  rules.cache(cache))
        return step, (p_sds, tokens, cache, extras), in_sh, (2,), out_sh
    # decode
    tokens, cache = SP.decode_specs(cfg, cell)

    def step(params, tokens, cache):
        return M.decode_step(cfg, params, tokens, cache, mesh=mesh)
    p_sds = SP.param_shapes(cfg)
    in_sh = (rules.params(p_sds),
             rules.token_sharding(tokens.shape[0]),
             rules.cache(cache))
    out_sh = (rules.logits_sharding(cell.global_batch),
              rules.cache(cache))
    return step, (p_sds, tokens, cache), in_sh, (2,), out_sh


def run_cell(arch: str, shape: str, *, multi_pod: bool = False,
             out_dir: str = "experiments/dryrun", fsdp=None,
             calibrate: bool = True, verbose: bool = True,
             attention_impl: str = "einsum", microbatches=None,
             expert_tp: bool = False, tag: str = "") -> dict:
    cfg = get_config(arch)
    if attention_impl != "einsum":
        cfg = cfg.replace(attention_impl=attention_impl)
    cell = SP.SHAPES[shape]
    mesh_tag = ("pod512" if multi_pod else "pod256") + tag
    result = {"arch": arch, "shape": shape, "mesh": mesh_tag,
              "status": "ok", "attention_impl": attention_impl,
              "expert_tp": expert_tp}
    skip = SP.cell_supported(cfg, shape)
    if skip:
        result.update(status="skip", reason=skip)
        _write(out_dir, result)
        return result
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        chips = mesh.size
        rules = ShardingRules(cfg, mesh, mode=cell.kind, fsdp=fsdp,
                              expert_tp=expert_tp)
        if expert_tp:
            from repro.models import moe as moe_mod
            moe_mod.set_expert_tp(True)
        step, args, in_sh, donate, out_sh = build_step(
            cfg, cell, mesh, rules, microbatches=microbatches)
        with mesh_context(mesh):
            # 1) production program: layer scans (O(1) HLO, fast compile);
            #    memory_analysis of THIS artifact proves the cell fits.
            jfn = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                          donate_argnums=donate)
            lowered = jfn.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            # 2) cost terms via reduced-depth unrolled calibration
            #    (XLA counts while-loop bodies once; see cost_calibrated)
            mb = (microbatches if microbatches is not None
                  else microbatches_for(cfg, cell, mesh))
            if calibrate:
                cal = cost_calibrated(cfg, cell, mesh, fsdp=rules.fsdp,
                                      microbatches=mb)
            else:   # multi-pod pass proves compile+fit only (roofline
                    # table is single-pod); fall back to raw counts
                cost = compiled.cost_analysis()
                cal = {"flops": float(cost.get("flops", 0.0)),
                       "bytes": float(cost.get("bytes accessed", 0.0))}
                for k, v in collective_bytes_from_hlo(
                        compiled.as_text()).items():
                    cal[f"coll_{k}"] = float(v)
            t_unroll = time.time() - t0 - t_lower - t_compile
        coll = {k.replace("coll_", ""): v for k, v in cal.items()
                if k.startswith("coll_")}
        coll.setdefault("total", 0.0)
        flops_dev = cal["flops"]
        bytes_dev = cal["bytes"]
        mf = SP.model_flops(cfg, cell)
        compute_t = flops_dev / PEAK_FLOPS
        memory_t = bytes_dev / HBM_BW
        coll_t = coll["total"] / LINK_BW
        dominant = max((("compute", compute_t), ("memory", memory_t),
                        ("collective", coll_t)), key=lambda kv: kv[1])[0]
        result.update(
            chips=chips,
            fsdp=rules.fsdp,
            lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
            unroll_compile_s=round(t_unroll, 2),
            flops_per_device=flops_dev,
            bytes_per_device=bytes_dev,
            collective_bytes_per_device=coll,
            compute_term_s=compute_t,
            memory_term_s=memory_t,
            collective_term_s=coll_t,
            dominant=dominant,
            model_flops_global=mf,
            useful_flops_fraction=(
                mf / (flops_dev * chips) if flops_dev else None),
            memory_analysis=_mem_dict(mem),
            calibration=cal.get("calibration"),
            microbatches=mb,
        )
        if verbose:
            print(f"[{arch} x {shape} x {mesh_tag}] OK "
                  f"compile={t_compile:.1f}s dominant={dominant} "
                  f"c/m/coll={compute_t:.2e}/{memory_t:.2e}/{coll_t:.2e}s")
            print("  memory_analysis:", result["memory_analysis"])
            print("  cost_analysis: flops/device=%.3e bytes/device=%.3e"
                  % (flops_dev, bytes_dev))
    except Exception as e:                       # noqa: BLE001
        result.update(status="error", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-2000:])
        if verbose:
            print(f"[{arch} x {shape} x {mesh_tag}] FAIL {e}")
    finally:
        if expert_tp:
            from repro.models import moe as moe_mod
            moe_mod.set_expert_tp(False)
    _write(out_dir, result)
    return result


def _mem_dict(mem) -> dict:
    if mem is None:
        return {}
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes",
            "peak_memory_in_bytes")
    out = {}
    for k in keys:
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def _write(out_dir: str, result: dict) -> None:
    os.makedirs(out_dir, exist_ok=True)
    fn = f"{result['arch']}_{result['shape']}_{result['mesh']}.json"
    with open(os.path.join(out_dir, fn), "w") as f:
        json.dump(result, f, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--no-calibration", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--attn-impl", default="einsum",
                    choices=["einsum", "surrogate"])
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--expert-tp", action="store_true")
    ap.add_argument("--tag", default="",
                    help="suffix for output JSONs (perf iterations)")
    args = ap.parse_args()

    cells = []
    archs = list_configs() if (args.all or not args.arch) else [args.arch]
    shapes = (list(SP.SHAPES) if (args.all or not args.shape)
              else [args.shape])
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                cells.append((arch, shape, mp))
    n_ok = n_fail = 0
    for arch, shape, mp in cells:
        tag = f"{arch}_{shape}_{'pod512' if mp else 'pod256'}"
        if args.skip_existing and os.path.exists(
                os.path.join(args.out, tag + ".json")):
            with open(os.path.join(args.out, tag + ".json")) as f:
                if json.load(f).get("status") in ("ok", "skip"):
                    print(f"[{tag}] cached")
                    continue
        r = run_cell(arch, shape, multi_pod=mp, out_dir=args.out,
                     calibrate=not args.no_calibration,
                     attention_impl=args.attn_impl,
                     microbatches=args.microbatches,
                     expert_tp=args.expert_tp, tag=args.tag)
        n_ok += r["status"] in ("ok", "skip")
        n_fail += r["status"] == "error"
    print(f"dry-run complete: {n_ok} ok/skip, {n_fail} failed")


if __name__ == "__main__":
    main()

"""Training launcher.

Single-host CPU runs use real (reduced) configs; on a TPU pod slice the
same entrypoint initializes jax.distributed and uses the production mesh.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --reduced \
      --steps 50 --batch 8 --seq 256
"""
from __future__ import annotations

import argparse
import os


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--mesh", default=None,
                    help="'DxM' data x model mesh (default: single device)")
    ap.add_argument("--distributed", action="store_true",
                    help="initialize jax.distributed (TPU pod slice)")
    args = ap.parse_args()

    import jax
    if args.distributed:
        jax.distributed.initialize()

    from repro.configs import get_config, reduced
    from repro.distributed.sharding import ShardingRules
    from repro.models import init_params
    from repro.training import optimizer as opt_mod
    from repro.training.checkpoint import latest_step, restore_checkpoint
    from repro.training.data import synthetic_batches
    from repro.training.train_loop import TrainConfig, train_loop

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    mesh = None
    if args.mesh:
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = jax.make_mesh((d, m), ("data", "model"))

    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = opt_mod.select_optimizer(cfg)
    state = opt_mod.opt_init(opt, params)
    if args.resume and args.ckpt_dir and latest_step(args.ckpt_dir):
        shardings = None
        if mesh is not None:
            rules = ShardingRules(cfg, mesh)
            shardings = {"params": rules.params(jax.eval_shape(lambda: params)),
                         "opt_state": rules.opt_state(
                             jax.eval_shape(lambda: state))}
        tree, start = restore_checkpoint(args.ckpt_dir,
                                         shardings=shardings)
        params, state = tree["params"], tree["opt_state"]
        print(f"resumed from step {start}")

    data = synthetic_batches(cfg.vocab_size, args.batch, args.seq)
    params, state, hist = train_loop(
        cfg, params, state, data, steps=args.steps, opt=opt,
        tc=TrainConfig(microbatches=args.microbatches, remat=False),
        mesh=mesh, checkpoint_every=20 if args.ckpt_dir else 0,
        ckpt_dir=args.ckpt_dir)
    for step, loss in hist[-5:]:
        print(f"step {step:5d} loss {loss:.4f}")


if __name__ == "__main__":
    main()

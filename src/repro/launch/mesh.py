"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state; callers (dryrun, launchers) decide when devices materialize.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds a 2-pod 'pod' DP axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 2, model: int = 2):
    """Small mesh for CPU lowering tests (run under forced host devices)."""
    return jax.make_mesh((data, model), ("data", "model"))


def data_axes(mesh) -> tuple:
    """Every non-'model' axis is a data/batch axis ('pod' included)."""
    return tuple(n for n in mesh.axis_names if n != "model")


def mesh_context(mesh):
    """``jax.set_mesh(mesh)`` where available (jax >= 0.5); on jax 0.4.x
    the Mesh's own context manager provides the global-mesh semantics."""
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh

"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state; callers (dryrun, launchers) decide when devices materialize.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds a 2-pod 'pod' DP axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 2, model: int = 2):
    """Small mesh for CPU lowering tests (run under forced host devices)."""
    return jax.make_mesh((data, model), ("data", "model"))


def data_axes(mesh) -> tuple:
    """Every non-'model' axis is a data/batch axis ('pod' included)."""
    return tuple(n for n in mesh.axis_names if n != "model")

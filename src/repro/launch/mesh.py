"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state; callers (dryrun, launchers) decide when devices materialize.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds a 2-pod 'pod' DP axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 2, model: int = 2):
    """Small mesh for CPU lowering tests (run under forced host devices)."""
    return jax.make_mesh((data, model), ("data", "model"))


def make_serving_mesh(spec: str):
    """Parse a ``DxM`` string (``--mesh 1x8``) into a ('data','model')
    mesh for the paged serving plane. Raises ValueError with the
    available device count when the shape doesn't fit — on a CPU host,
    run under ``XLA_FLAGS=--xla_force_host_platform_device_count=N``."""
    try:
        d, m = (int(x) for x in spec.lower().split("x"))
    except (TypeError, ValueError):
        raise ValueError(f"--mesh wants DxM (e.g. 1x8), got {spec!r}")
    if d < 1 or m < 1:
        raise ValueError(f"--mesh dims must be >= 1, got {d}x{m}")
    n = len(jax.devices())
    if d * m > n:
        raise ValueError(
            f"mesh {d}x{m} needs {d * m} devices but only {n} present; "
            "set XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{d * m} for a virtual host mesh")
    return jax.make_mesh((d, m), ("data", "model"))


def data_axes(mesh) -> tuple:
    """Every non-'model' axis is a data/batch axis ('pod' included)."""
    return tuple(n for n in mesh.axis_names if n != "model")


def mesh_context(mesh):
    """``jax.set_mesh(mesh)`` where available (jax >= 0.5); on jax 0.4.x
    the Mesh's own context manager provides the global-mesh semantics."""
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh

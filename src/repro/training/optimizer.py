"""Optimizers as pure pytree transforms (no external deps).

- adamw: fp32 master copy + two fp32 moments (small/medium configs).
- adafactor: fp32 master + factored second moment (row/col statistics) —
  the production choice for the >=100B assigned configs, cutting optimizer
  HBM from 12 bytes/param to ~4 bytes/param (DESIGN.md §8).

State layouts mirror parameter layouts, so the ShardingRules param specs
apply verbatim (ZeRO-style sharding falls out of FSDP at-rest specs).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    kind: str = "adamw"              # adamw | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # adafactor
    decay_rate: float = 0.8
    factored_min_dim: int = 64


def select_optimizer(cfg) -> OptConfig:
    """Adafactor for >=40B-param configs (HBM), AdamW otherwise."""
    if cfg.num_params() >= 40e9:
        return OptConfig(kind="adafactor")
    return OptConfig(kind="adamw")


# ---------------------------------------------------------------- adamw
def adamw_init(params):
    f32 = lambda p: p.astype(jnp.float32)
    return {
        "step": jnp.zeros((), jnp.int32),
        "master": jax.tree.map(f32, params),
        "mu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "nu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
    }


def _global_norm(grads):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(grads)))


def adamw_update(opt: OptConfig, grads, state, params):
    step = state["step"] + 1
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, opt.grad_clip / (gnorm + 1e-9))

    def upd(g, m, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = opt.b1 * mu + (1 - opt.b1) * g
        nu = opt.b2 * nu + (1 - opt.b2) * jnp.square(g)
        mu_hat = mu / (1 - opt.b1 ** step)
        nu_hat = nu / (1 - opt.b2 ** step)
        u = mu_hat / (jnp.sqrt(nu_hat) + opt.eps)
        if m.ndim >= 2:
            u = u + opt.weight_decay * m
        m = m - opt.lr * u
        return m, mu, nu

    flat = jax.tree.map(upd, grads, state["master"], state["mu"],
                        state["nu"],
                        is_leaf=lambda x: isinstance(x, jax.Array))
    master = jax.tree.map(lambda t: t[0], flat,
                          is_leaf=lambda x: isinstance(x, tuple))
    mu = jax.tree.map(lambda t: t[1], flat,
                      is_leaf=lambda x: isinstance(x, tuple))
    nu = jax.tree.map(lambda t: t[2], flat,
                      is_leaf=lambda x: isinstance(x, tuple))
    new_params = jax.tree.map(lambda m, p: m.astype(p.dtype), master, params)
    return new_params, {"step": step, "master": master, "mu": mu, "nu": nu}, \
        {"grad_norm": gnorm}


# ------------------------------------------------------------- adafactor
def _factored(shape, min_dim) -> bool:
    return len(shape) >= 2 and shape[-1] >= min_dim and shape[-2] >= min_dim


def adafactor_init(params, *, min_dim: int = 128):
    def vstate(p):
        if _factored(p.shape, min_dim):
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                    jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return {
        "step": jnp.zeros((), jnp.int32),
        "master": jax.tree.map(lambda p: p.astype(jnp.float32), params),
        "v": jax.tree.map(vstate, params),
    }


def adafactor_update(opt: OptConfig, grads, state, params):
    step = state["step"] + 1
    beta2 = 1.0 - jnp.power(step.astype(jnp.float32), -opt.decay_rate)
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, opt.grad_clip / (gnorm + 1e-9))

    def upd(g, m, v):
        g = g.astype(jnp.float32) * scale
        g2 = jnp.square(g) + 1e-30
        if "vr" in v:
            vr = beta2 * v["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
            vc = beta2 * v["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
            denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True),
                                1e-30)[..., None]        # [..., 1, 1]
            u = g * jax.lax.rsqrt(vr[..., None] / denom) \
                * jax.lax.rsqrt(vc[..., None, :])
            nv = {"vr": vr, "vc": vc}
        else:
            nv = {"v": beta2 * v["v"] + (1 - beta2) * g2}
            u = g * jax.lax.rsqrt(nv["v"] + 1e-30)
        # update clipping (RMS <= 1) per the adafactor recipe
        rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
        u = u / jnp.maximum(1.0, rms)
        if m.ndim >= 2:
            u = u + opt.weight_decay * m
        return m - opt.lr * u, nv

    is_v = lambda x: isinstance(x, dict) and ("v" in x or "vr" in x)
    pairs = jax.tree.map(upd, grads, state["master"], state["v"],
                         is_leaf=lambda x: isinstance(x, jax.Array) or is_v(x))
    is_pair = lambda x: isinstance(x, tuple)
    master = jax.tree.map(lambda t: t[0], pairs, is_leaf=is_pair)
    v = jax.tree.map(lambda t: t[1], pairs, is_leaf=is_pair)
    new_params = jax.tree.map(lambda m, p: m.astype(p.dtype), master, params)
    return new_params, {"step": step, "master": master, "v": v}, \
        {"grad_norm": gnorm}


# ---------------------------------------------------------------- facade
def opt_init(opt: OptConfig, params):
    if opt.kind == "adamw":
        return adamw_init(params)
    return adafactor_init(params, min_dim=opt.factored_min_dim)


def opt_update(opt: OptConfig, grads, state, params):
    if opt.kind == "adamw":
        return adamw_update(opt, grads, state, params)
    return adafactor_update(opt, grads, state, params)

"""Synthetic token pipeline (offline container: no external corpora).

Generates a deterministic mixture of structured sequences (copy runs,
arithmetic-progression spans, Zipf-sampled vocabulary) so a ~100M model
shows a real, falling loss curve within a few hundred steps — not pure
noise, not memorizable constants.
"""
from __future__ import annotations

import numpy as np


def synthetic_batches(vocab_size: int, batch: int, seq: int, *,
                      seed: int = 0):
    rng = np.random.default_rng(seed)
    zipf_p = 1.0 / np.arange(1, vocab_size + 1) ** 1.1
    zipf_p /= zipf_p.sum()
    while True:
        toks = rng.choice(vocab_size, size=(batch, seq), p=zipf_p)
        # structure: repeat spans (copy task) make next-token predictable
        for b in range(batch):
            n_spans = rng.integers(2, 6)
            for _ in range(n_spans):
                ln = int(rng.integers(8, 32))
                src = int(rng.integers(0, seq - 2 * ln))
                dst = int(rng.integers(src + ln, seq - ln))
                toks[b, dst:dst + ln] = toks[b, src:src + ln]
        tokens = toks.astype(np.int32)
        labels = np.roll(tokens, -1, axis=1)
        weights = np.ones_like(tokens, np.float32)
        weights[:, -1] = 0.0
        yield {"tokens": tokens, "labels": labels, "weights": weights}

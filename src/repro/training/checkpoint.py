"""Checkpoint / restart — the fault-tolerance substrate.

Atomic-manifest checkpoints: every leaf saved as its own .npy under a
step directory, manifest written LAST (a crash mid-save never yields a
readable-but-corrupt checkpoint). An async mode moves the host-side write
off the training step (overlap with compute). ``restore_checkpoint``
re-shards onto whatever mesh the restart runs with — including a
*different* device count (elastic rescale, DESIGN.md §8): leaves are
host-side numpy, placement happens via the target shardings.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Optional

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat):
    root: dict = {}
    for key, v in flat.items():
        parts = key.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return _fix_lists(root)


def _fix_lists(node):
    if not isinstance(node, dict):
        return node
    keys = list(node.keys())
    if keys and all(k.isdigit() for k in keys):
        return [_fix_lists(node[str(i)]) for i in range(len(keys))]
    return {k: _fix_lists(v) for k, v in node.items()}


def save_checkpoint(ckpt_dir: str, step: int, params, opt_state=None, *,
                    async_save: bool = False,
                    keep_last: int = 3) -> Optional[threading.Thread]:
    """Write step checkpoint; manifest last (atomic)."""
    tree = {"params": params}
    if opt_state is not None:
        tree["opt_state"] = opt_state
    flat = _flatten(tree)
    host = {k: np.asarray(v) for k, v in flat.items()}   # device -> host

    def _write():
        step_dir = os.path.join(ckpt_dir, f"step_{step:08d}.tmp")
        os.makedirs(step_dir, exist_ok=True)
        names = {}
        for i, (k, v) in enumerate(host.items()):
            fn = f"leaf_{i:05d}.npy"
            np.save(os.path.join(step_dir, fn), v)
            names[k] = {"file": fn, "dtype": str(v.dtype),
                        "shape": list(v.shape)}
        manifest = {"step": step, "leaves": names}
        with open(os.path.join(step_dir, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(step_dir, final)
        _gc(ckpt_dir, keep_last)

    if async_save:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def _gc(ckpt_dir: str, keep_last: int) -> None:
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep_last]:
        shutil.rmtree(os.path.join(ckpt_dir, d))


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")
             and os.path.exists(os.path.join(ckpt_dir, d, "manifest.json"))]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, step: Optional[int] = None, *,
                       shardings=None):
    """Load (tree, step). ``shardings``: optional pytree of NamedSharding
    to place leaves onto a (possibly different-size) mesh — elastic
    restart path."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    step_dir = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(step_dir, "manifest.json")) as f:
        manifest = json.load(f)
    flat = {}
    for k, info in manifest["leaves"].items():
        flat[k] = np.load(os.path.join(step_dir, info["file"]))
    tree = _unflatten(flat)
    if shardings is not None:
        flat_s = _flatten(shardings)
        flat_t = _flatten(tree)
        placed = {k: jax.device_put(v, flat_s[k]) if k in flat_s else v
                  for k, v in flat_t.items()}
        tree = _unflatten(placed)
    return tree, step

"""Distributed training step builder + loop.

``build_train_step`` produces the pjit-able function the dry-run lowers:
loss -> grads (grad-accum microbatching, remat) -> optimizer update.
The same builder powers the runnable example (tiny config, 1 CPU device)
and the 512-chip dry-run — only the mesh and shardings differ.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import model as M
from repro.training import optimizer as opt_mod


@dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1            # gradient accumulation steps
    remat: bool = True
    seq_shard_activations: bool = False  # Megatron-SP residual constraint
    bf16_grad_reduce: bool = False   # barrier grads in bf16 so XLA cannot
    # hoist the optimizer's f32 cast ahead of the DP all-reduce (halves
    # gradient-reduction wire bytes; error bounded by bf16 rounding of an
    # already-bf16-computed gradient)


def _microbatch_stack(batch, n, mesh):
    """[B, ...] -> [n, B/n, ...] so the grad-accum scan slices STATICALLY,
    with the batch shard kept on the SECOND dim (without the constraint
    SPMD moves the 'data' shard onto the microbatch dim and every device
    recomputes the full microbatch — 16x replicated compute)."""
    def f(x):
        y = x.reshape((n, x.shape[0] // n) + x.shape[1:])
        if mesh is not None:
            da = tuple(a for a in mesh.axis_names if a != "model")
            spec = P(None, da, *([None] * (y.ndim - 2)))
            y = jax.lax.with_sharding_constraint(
                y, jax.NamedSharding(mesh, spec))
        return y
    return jax.tree.map(f, batch)


def build_train_step(cfg, opt: opt_mod.OptConfig, tc: TrainConfig,
                     mesh=None):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics)."""

    seq_spec = None
    if tc.seq_shard_activations and mesh is not None:
        da = tuple(a for a in mesh.axis_names if a != "model")
        seq_spec = jax.NamedSharding(mesh, P(da, "model", None))

    def loss_of(params, mb):
        loss, metrics = M.loss_fn(cfg, params, mb, mesh=mesh,
                                  remat=tc.remat, seq_spec=seq_spec)
        return loss, metrics

    def train_step(params, opt_state, batch):
        if tc.microbatches <= 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_of, has_aux=True)(params, batch)
        else:
            def accum(carry, mb):
                gsum, lsum = carry
                (l, _), g = jax.value_and_grad(loss_of, has_aux=True)(
                    params, mb)
                gsum = jax.tree.map(jnp.add, gsum, g)
                return (gsum, lsum + l), None
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = M._scan(
                accum, (zero, jnp.zeros(())),
                _microbatch_stack(batch, tc.microbatches, mesh))
            grads = jax.tree.map(lambda g: g / tc.microbatches, gsum)
            loss = lsum / tc.microbatches
            metrics = {}
        if tc.bf16_grad_reduce:
            grads = jax.lax.optimization_barrier(
                jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads))
        new_params, new_opt, om = opt_mod.opt_update(
            opt, grads, opt_state, params)
        out = {"loss": loss, **om}
        return new_params, new_opt, out

    return train_step


def train_loop(cfg, params, opt_state, data_iter, *, steps: int,
               opt: opt_mod.OptConfig, tc: Optional[TrainConfig] = None,
               mesh=None, checkpoint_every: int = 0, ckpt_dir=None,
               log_every: int = 10):
    """Simple driver used by examples; checkpointing is async-friendly."""
    from repro.training.checkpoint import save_checkpoint
    tc = tc or TrainConfig(remat=False)
    step_fn = jax.jit(build_train_step(cfg, opt, tc, mesh=mesh))
    history = []
    for step in range(steps):
        batch = next(data_iter)
        params, opt_state, m = step_fn(params, opt_state, batch)
        if step % log_every == 0 or step == steps - 1:
            history.append((step, float(m["loss"])))
        if checkpoint_every and ckpt_dir and \
                (step + 1) % checkpoint_every == 0:
            save_checkpoint(ckpt_dir, step + 1, params, opt_state)
    return params, opt_state, history

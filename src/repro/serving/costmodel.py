"""Stage execution cost models + the two Omni pipeline stand-ins.

The paper's testbed models (Qwen3-Omni, Ming-Flash-Omni 2.0) are not
available offline; these pipeline specs preserve the relevant structure —
stage graph, chunked hand-off, audio codec rate, per-token KV footprint —
with per-round costs calibrated so a solo session reproduces the paper's
Fig. 15 example (≈8 s generation for ≈66 s of audio on the baseline).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass(frozen=True)
class StageCost:
    round_overhead_s: float
    prefill_token_s: float
    decode_token_s: float          # per decode request per round


@dataclass(frozen=True)
class StageSpec:
    name: str
    cost: StageCost
    kv_bytes_per_token: float = 0.0
    kv_capacity_blocks: int = 0
    block_size: int = 16
    token_budget: int = 2048       # per scheduling round
    max_batch: int = 64


@dataclass(frozen=True)
class PipelineSpec:
    name: str
    stages: List[StageSpec]
    # cross-stage coupling
    thinker_chunk: int = 8         # thinker tokens per talker hand-off chunk
    speech_per_text: int = 4       # talker tokens per thinker token
    vocoder_chunk: int = 16        # talker tokens per audio fragment
    vocoder_chunk_s: float = 0.004
    audio_per_token_s: float = 0.08
    encode_delay_s: float = 0.15   # utterance -> embeddings -> orchestrator
    pcie_gb_s: float = 25.0

    def stage(self, name: str) -> StageSpec:
        return next(s for s in self.stages if s.name == name)


def qwen3_omni_like(kv_capacity_gb: float = 6.0) -> PipelineSpec:
    """3-stage pipeline: encoder colocated with thinker; vocoder with
    talker (paper §7.1 footnote). DP replicas are folded into the
    stage-level cost constants."""
    kv_tok = 147_456.0   # 36L*2*8kv*128hd*2B — qwen3-4b-class backbone
    talker_tok = 36_864.0
    cap = int(kv_capacity_gb * 1e9 / (kv_tok * 16))
    return PipelineSpec(
        name="qwen3-omni-like",
        stages=[
            StageSpec("thinker",
                      StageCost(round_overhead_s=0.010,
                                prefill_token_s=0.00004,
                                decode_token_s=0.002),
                      kv_bytes_per_token=kv_tok,
                      kv_capacity_blocks=cap, block_size=16),
            StageSpec("talker",
                      StageCost(round_overhead_s=0.004,
                                prefill_token_s=0.00002,
                                decode_token_s=0.004),
                      kv_bytes_per_token=talker_tok,
                      kv_capacity_blocks=cap * 2, block_size=16),
        ],
    )


def ming_omni_like(kv_capacity_gb: float = 6.0) -> PipelineSpec:
    """2-stage pipeline (TP=2,DP=2 thinker + DP=4 talker): heavier MoE
    thinker, faster talker."""
    kv_tok = 196_608.0
    talker_tok = 49_152.0
    cap = int(kv_capacity_gb * 1e9 / (kv_tok * 16))
    return PipelineSpec(
        name="ming-omni-like",
        stages=[
            StageSpec("thinker",
                      StageCost(round_overhead_s=0.014,
                                prefill_token_s=0.00005,
                                decode_token_s=0.0025),
                      kv_bytes_per_token=kv_tok,
                      kv_capacity_blocks=cap, block_size=16),
            StageSpec("talker",
                      StageCost(round_overhead_s=0.003,
                                prefill_token_s=0.00002,
                                decode_token_s=0.003),
                      kv_bytes_per_token=talker_tok,
                      kv_capacity_blocks=cap * 2, block_size=16),
        ],
        thinker_chunk=8, speech_per_text=4,
    )


PIPELINES = {
    "qwen3-omni-like": qwen3_omni_like,
    "ming-omni-like": ming_omni_like,
}

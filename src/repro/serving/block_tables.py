"""Block-table assembly for the paged realtime engine (DESIGN.md §3).

Bridges the host-side ``PagedPool`` bookkeeping and the Pallas
``paged_attention`` kernel: per-round [B, pages_per_seq] int32 tables for
a *fixed-size* decode batch — inactive rows point at a reserved scratch
page so the batch shape (and therefore the compiled step function) never
changes across rounds — plus the layer-stacked K/V page-store adapter the
pool's DRAM tier moves page contents through.

Tables are **replicated** across every mesh axis in the sharded data
plane (DESIGN.md §9): they index the unsharded physical-page dim, so
one table drives all shards; ``LayerStackedPages`` works unchanged on a
sharded store because its reads gather (``np.asarray``) and its writes
are functional updates whose placement the engine re-commits.

Shared-prefix attach (DESIGN.md §13) needs nothing new here: a session
that attached to cached pages simply lists those physical ids in its
block table like any other pages, and the fused plane's per-row
``q_start`` already renders prefill rows from an arbitrary offset — the
attacher's first prefilled token lands mid-sequence with the shared
pages attended read-only, no kernel or table-shape change.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np

from repro.kvcache.paged import PagedPool


@dataclass
class BatchTables:
    """One decode round's kernel inputs, host-side (cheap int32 arrays)."""
    block_tables: np.ndarray     # [B, pages_per_seq] i32 physical pages
    seq_lens: np.ndarray         # [B] i32 attention length (post-write)
    positions: np.ndarray        # [B] i32 absolute position of new token
    write_page: np.ndarray       # [B] i32 physical page the token writes
    write_slot: np.ndarray       # [B] i32 slot within that page
    active: np.ndarray           # [B] bool — padded rows are False


def assemble(pool: PagedPool, rows: List[Optional[Tuple[str, int]]],
             pages_per_seq: int, scratch_page: int) -> BatchTables:
    """Build the tables for one decode round.

    ``rows[i]`` is ``(seq_id, tokens_written)`` for the session served by
    batch row i, or None for a padding row. Padding rows write to (and
    attend over one slot of) ``scratch_page`` — a physical page outside
    the pool's managed range — so their lanes compute finite garbage that
    is discarded, and real pages are never clobbered.

    Every active sequence must be fully HBM-resident (§5.2 sync-fallback
    contract) and must already own the page its next token writes into.
    """
    B = len(rows)
    bt = np.full((B, pages_per_seq), scratch_page, np.int32)
    seq_lens = np.ones((B,), np.int32)
    positions = np.zeros((B,), np.int32)
    write_page = np.full((B,), scratch_page, np.int32)
    write_slot = np.zeros((B,), np.int32)
    active = np.zeros((B,), bool)
    for i, row in enumerate(rows):
        if row is None:
            continue
        sid, written = row
        s = pool.seq(sid)
        if s.offloaded:
            raise RuntimeError(
                f"{sid} has offloaded pages; reload before scheduling")
        n = len(s.pages)
        if n > pages_per_seq:
            raise ValueError(f"{sid}: {n} pages > table width "
                             f"{pages_per_seq}")
        bt[i, :n] = s.pages
        page_idx, slot = divmod(written, pool.page_size)
        if page_idx >= n:
            raise RuntimeError(
                f"{sid}: page {page_idx} for token {written} not "
                f"allocated (owns {n})")
        write_page[i] = s.pages[page_idx]
        write_slot[i] = slot
        positions[i] = written
        seq_lens[i] = written + 1
        active[i] = True
    return BatchTables(bt, seq_lens, positions, write_page, write_slot,
                       active)


@dataclass
class FusedBatchTables:
    """One fused round's kernel inputs (DESIGN.md §11): every batch row
    carries up to Q consecutive tokens of one sequence."""
    block_tables: np.ndarray     # [B, pages_per_seq] i32 physical pages
    q_start: np.ndarray          # [B] i32 first token's absolute position
    q_lens: np.ndarray           # [B] i32 valid tokens this row (0 = pad)
    positions: np.ndarray        # [B, Q] i32 absolute position per token
    write_pages: np.ndarray      # [B, Q] i32 physical page per token
    write_slots: np.ndarray      # [B, Q] i32 slot within that page


def assemble_fused(pool: PagedPool,
                   rows: List[Optional[Tuple[str, int, int]]], q_tokens: int,
                   pages_per_seq: int, scratch_page: int) -> FusedBatchTables:
    """Build the tables for one fused round.

    ``rows[i]`` is ``(seq_id, tokens_written, n_tokens)`` — the session
    served by batch row i feeds ``n_tokens`` consecutive tokens starting
    at absolute position ``tokens_written`` — or None for a padding row.
    ``q_tokens`` is the (bucketed) query-axis width; token slots past
    ``n_tokens`` and whole padding rows point at ``scratch_page`` with
    ``q_lens`` masking them out of attention, so their lanes compute
    finite garbage that is discarded and real pages are never clobbered.

    Every active sequence must be fully HBM-resident and must already
    own every page its chunk writes into (the caller grew the sequence
    for the whole grant before packing — the §5.2 contract unchanged).
    """
    B = len(rows)
    bt = np.full((B, pages_per_seq), scratch_page, np.int32)
    q_start = np.zeros((B,), np.int32)
    q_lens = np.zeros((B,), np.int32)
    positions = np.zeros((B, q_tokens), np.int32)
    write_pages = np.full((B, q_tokens), scratch_page, np.int32)
    # padded token slots spread over the scratch page so one launch's
    # scatter has as few duplicate targets as possible (their contents
    # are garbage either way; nothing ever attends to them)
    write_slots = np.tile(np.arange(q_tokens, dtype=np.int32)[None, :]
                          % max(1, pool.page_size), (B, 1))
    for i, row in enumerate(rows):
        if row is None:
            continue
        sid, written, n_tok = row
        assert 0 < n_tok <= q_tokens, (sid, n_tok, q_tokens)
        s = pool.seq(sid)
        if s.offloaded:
            raise RuntimeError(
                f"{sid} has offloaded pages; reload before scheduling")
        n = len(s.pages)
        if n > pages_per_seq:
            raise ValueError(f"{sid}: {n} pages > table width "
                             f"{pages_per_seq}")
        bt[i, :n] = s.pages
        q_start[i] = written
        q_lens[i] = n_tok
        pos = written + np.arange(n_tok)
        page_idx = pos // pool.page_size
        if page_idx[-1] >= n:
            raise RuntimeError(
                f"{sid}: page {page_idx[-1]} for token {pos[-1]} not "
                f"allocated (owns {n})")
        positions[i, :n_tok] = pos
        write_pages[i, :n_tok] = np.asarray(s.pages, np.int64)[page_idx]
        write_slots[i, :n_tok] = pos % pool.page_size
    return FusedBatchTables(bt, q_start, q_lens, positions, write_pages,
                            write_slots)


class LayerStackedPages:
    """Adapts layer-major K/V page arrays ([L, P, page, Hkv, hd], the
    scan-friendly layout the decode step wants) to the PagedPool's
    page-major offload/reload interface (``kv_pages[phys]`` -> host copy;
    ``kv_pages.at[phys].set(copy)`` -> updated store).

    A host copy is the stacked ``[2, L, page, Hkv, hd]`` (k, v) contents
    of one physical page — what the DRAM tier stores per page.
    """

    def __init__(self, k, v):
        self.k = k
        self.v = v

    def __getitem__(self, phys: int) -> np.ndarray:
        return np.stack([np.asarray(self.k[:, phys]),
                         np.asarray(self.v[:, phys])])

    @property
    def at(self) -> "_StoreAt":
        return _StoreAt(self)


class _StoreAt:
    def __init__(self, store: LayerStackedPages):
        self._store = store

    def __getitem__(self, phys: int) -> "_StoreSet":
        return _StoreSet(self._store, phys)


class _StoreSet:
    def __init__(self, store: LayerStackedPages, phys):
        self._store = store
        self._phys = phys

    def set(self, host_copy) -> LayerStackedPages:
        """Scalar phys takes one [2, L, page, ...] copy; an index array
        takes the stacked [n, 2, L, page, ...] batch (the pool's batched
        reload) — either way a single functional update per component."""
        s, p = self._store, self._phys
        hc = np.asarray(host_copy)
        if np.ndim(p) == 0:
            k_new, v_new = hc[0], hc[1]
        else:
            k_new = np.moveaxis(hc[:, 0], 0, 1)   # [L, n, page, Hkv, hd]
            v_new = np.moveaxis(hc[:, 1], 0, 1)
        return LayerStackedPages(s.k.at[:, p].set(k_new),
                                 s.v.at[:, p].set(v_new))

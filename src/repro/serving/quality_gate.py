"""KV-quantization quality gate (DESIGN.md §14).

Replays one seeded multi-turn trace through two engines with identical
geometry and weights — an fp32-wire control and a candidate wire
format — and forces each turn's committed pages through an
evict -> flush -> reload round trip between turns, so every later turn
decodes on KV that crossed the wire in the candidate's format. The
gate then compares what the two engines computed:

- ``token_flip_rate``: committed-token mismatches / tokens compared,
  censored at the first divergence per turn — after a flip the two
  contexts differ, so later mismatches measure drift compounding, not
  codec error.
- ``logit_mse``: mean squared logit error over tap positions strictly
  before each turn's first argmax flip (contexts provably identical
  there, so the difference is purely quantization noise).

``fp32`` vs ``fp32`` is the control's control: the identity codec must
reproduce the trace bit-exactly (flip rate 0.0, MSE 0.0) — the same
contract every other differential twin in this repo holds
(``async_transfers=False``, ``fused_step=False``, ``prefix_cache=False``).
``int8`` is the repo's first tolerance-based tier: it must hold
``QualityTolerance`` (token flips <= 1% by default).

Scheduling is value-blind: round composition depends on token *counts*
and page geometry, never token *values*, so the two engines stay in
lockstep (identical tap streams position-by-position) even after a
flip — which is what makes the censored comparison well-defined.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np


@dataclass
class QualityTolerance:
    """Acceptance thresholds for a lossy KV wire format."""
    max_token_flip_rate: float = 0.01
    max_logit_mse: float = 1e-2


@dataclass
class QualityReport:
    kv_quant: str
    token_flips: int = 0
    tokens_compared: int = 0
    logit_mse: float = 0.0
    logit_positions: int = 0
    reloaded_pages: int = 0        # pages that crossed the wire (candidate)
    wire_bytes_saved: float = 0.0  # candidate engine ledger
    per_turn_flips: List[int] = field(default_factory=list)

    @property
    def token_flip_rate(self) -> float:
        return (self.token_flips / self.tokens_compared
                if self.tokens_compared else 0.0)

    def within(self, tol: QualityTolerance) -> bool:
        return (self.token_flip_rate <= tol.max_token_flip_rate
                and self.logit_mse <= tol.max_logit_mse)

    def summary(self) -> dict:
        return {
            "kv_quant": self.kv_quant,
            "quant_token_flip_rate": self.token_flip_rate,
            "quant_logit_mse": self.logit_mse,
            "tokens_compared": self.tokens_compared,
            "reloaded_pages": self.reloaded_pages,
            "kv_wire_bytes_saved": self.wire_bytes_saved,
        }


def _build_engine(cfg, params, kv_quant: str, *, fused_step: bool):
    from repro.serving.paged_engine import PagedRealtimeEngine
    return PagedRealtimeEngine(cfg, params, slots=2, page_size=4,
                               pages_per_seq=8, num_pages=32,
                               fused_step=fused_step, kv_quant=kv_quant)


def _drive_turn(eng, sid: str, prompt, gen: int) -> List[np.ndarray]:
    """Run one turn to completion, collecting every fed row's logits."""
    taps: List[np.ndarray] = []
    eng.logit_tap = lambda s, lg: taps.append(np.array(lg))
    try:
        if sid in eng.sessions:
            eng.start_turn(sid, prompt, max_new_tokens=gen)
        else:
            eng.add_session(sid, prompt, max_new_tokens=gen)
        eng.run_to_completion()
    finally:
        eng.logit_tap = None
    return taps


def _wire_pressure(eng, sid: str) -> None:
    """Force the session's committed pages through the offload tier:
    evict everything evictable, flush (host copies durable in wire
    format), then a speech window so the next turn reloads them."""
    now = eng.clock.now()
    n = eng.kv.reclaimable_blocks(now)
    if n:
        assert eng.kv.evict(n, now) == n
        eng.flush_transfers()           # copy-then-free drains; durable
    eng.user_speech_start(sid, expected_dur_s=1.0)
    eng.clock.tick(1.0)


def run_quality_gate(cfg, params, *, kv_quant: str = "int8",
                     seed: int = 0, turns: int = 3,
                     fused_step: bool = True,
                     tol: Optional[QualityTolerance] = None
                     ) -> QualityReport:
    """Replay the seeded trace on fp32-control and candidate engines;
    returns the comparison (pass ``tol`` to also assert it)."""
    rng = np.random.default_rng(seed)
    # sized to the control geometry: 8 pages * 4 tokens context budget
    trace = [(rng.integers(0, cfg.vocab_size, size=int(rng.integers(4, 7))),
              4) for _ in range(turns)]

    control = _build_engine(cfg, params, "fp32", fused_step=fused_step)
    candidate = _build_engine(cfg, params, kv_quant, fused_step=fused_step)

    report = QualityReport(kv_quant=kv_quant)
    sq_err, sq_n = 0.0, 0
    for prompt, gen in trace:
        taps_c = _drive_turn(control, "q", prompt, gen)
        taps_q = _drive_turn(candidate, "q", prompt, gen)
        hist_c = control.sessions["q"].history[-1]
        hist_q = candidate.sessions["q"].history[-1]

        # committed tokens, censored at the turn's first divergence
        n = min(len(hist_c), len(hist_q))
        flip_at = next((i for i in range(n) if hist_c[i] != hist_q[i]), n)
        flips = 1 if flip_at < n else 0
        report.token_flips += flips
        report.tokens_compared += flip_at + flips
        report.per_turn_flips.append(flips)

        # logits, strictly before the first argmax flip in the tap
        # stream (identical contexts up to there; the streams align
        # because scheduling is value-blind)
        m = min(len(taps_c), len(taps_q))
        tap_flip = next(
            (i for i in range(m)
             if int(np.argmax(taps_c[i])) != int(np.argmax(taps_q[i]))), m)
        for i in range(tap_flip):
            d = taps_c[i].astype(np.float64) - taps_q[i].astype(np.float64)
            sq_err += float(np.mean(d * d))
            sq_n += 1

        _wire_pressure(control, "q")
        _wire_pressure(candidate, "q")

    control.check_invariants()
    candidate.check_invariants()
    report.logit_mse = sq_err / sq_n if sq_n else 0.0
    report.logit_positions = sq_n
    report.reloaded_pages = candidate.kv.reloaded_blocks
    report.wire_bytes_saved = candidate.transfer.stats.wire_bytes_saved
    if tol is not None:
        assert report.within(tol), (
            f"kv_quant={kv_quant} failed the quality gate: "
            f"flip_rate={report.token_flip_rate:.4f} "
            f"(max {tol.max_token_flip_rate}), "
            f"logit_mse={report.logit_mse:.3e} (max {tol.max_logit_mse})")
    return report

"""Draft proposers for speculative multi-token decode (DESIGN.md §16).

A proposer is anything with ``propose(history, k) -> list[int]``: given
the session's committed token history (prompt + accepted output,
*including* the pending token about to be fed), return up to ``k``
guessed next tokens. The engine feeds ``[pending] + drafts`` as one
fused multi-token row, verifies every position in the same launch via
``paged_prefill_attention``'s intra-chunk causal mask, and accepts the
longest prefix of drafts matching the model's own argmax — so any
proposer, however bad, is *lossless*: a wrong guess costs KV writes
that are rolled back, never a wrong token.

``NGramProposer`` is the self-speculative default (prompt lookup, the
"assisted generation" trick): find the most recent earlier occurrence
of the history's trailing n-gram and replay what followed it. Sessions
replaying structured prompts (tool-call scaffolding, shared system
prefixes) hit long runs; random traffic degrades to zero-length drafts,
i.e. plain decode.

``DraftModelConfig`` is the hook for a small draft LM: the engine
accepts any proposer object, so wiring a real draft model is config +
a propose() adapter, no engine changes.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence


class NGramProposer:
    """Prompt-lookup drafting over the session's own history."""

    def __init__(self, max_ngram: int = 3, min_ngram: int = 1):
        assert max_ngram >= min_ngram >= 1
        self.max_ngram = max_ngram
        self.min_ngram = min_ngram

    def propose(self, history: Sequence[int], k: int) -> List[int]:
        h = list(history)
        n_hist = len(h)
        if k <= 0 or n_hist < self.min_ngram + 1:
            return []
        for n in range(min(self.max_ngram, n_hist - 1),
                       self.min_ngram - 1, -1):
            suffix = h[-n:]
            # most recent earlier occurrence of the trailing n-gram
            # whose continuation fills the whole draft budget; a match
            # too close to the end (short continuation) only wins when
            # no older occurrence does better
            best: List[int] = []
            for i in range(n_hist - n - 1, -1, -1):
                cont = h[i + n:i + n + k]
                if h[i:i + n] == suffix and len(cont) > len(best):
                    best = cont
                    if len(best) == k:
                        break
            if best:
                return best
        return []


class ScriptedProposer:
    """Deterministic per-session draft scripts — the test/bench oracle
    (a script replaying the model's own greedy outputs yields 100%
    acceptance; a corrupted script exercises rollback)."""

    def __init__(self, scripts: Optional[dict] = None):
        self.scripts = scripts or {}      # sid -> list of draft lists
        self._cursor: dict = {}
        self.session_id: Optional[str] = None   # set by the engine

    def propose(self, history: Sequence[int], k: int) -> List[int]:
        sid = self.session_id
        script = self.scripts.get(sid)
        if not script:
            return []
        i = self._cursor.get(sid, 0)
        if i >= len(script):
            return []
        self._cursor[sid] = i + 1
        return list(script[i])[:k]


@dataclass
class DraftModelConfig:
    """Configuration hook for a small draft LM proposer. Not wired to a
    real model yet: building one raises, keeping the dependency surface
    explicit until a draft checkpoint exists."""
    name: str = ""
    max_draft_tokens: int = 4

    def build(self):
        raise NotImplementedError(
            "draft-model speculation is a config hook only; use the "
            "self-speculative NGramProposer (the default) or any object "
            "with propose(history, k)")


def build_proposer(spec="ngram", **kw):
    """``"ngram"`` | an existing proposer object | a DraftModelConfig."""
    if spec == "ngram":
        return NGramProposer(**kw)
    if isinstance(spec, DraftModelConfig):
        return spec.build()
    assert hasattr(spec, "propose"), f"not a proposer: {spec!r}"
    return spec

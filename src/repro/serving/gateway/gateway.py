"""The realtime session gateway (DESIGN.md §4).

An asyncio front-end holding many concurrent duplex sessions against one
``PagedRealtimeEngine``. The inversion that matters: the *control plane*
owns the step loop. Each round the gateway

1. drains client events (speech, turn requests, barge-in, hangup) into
   the monitor/preloader — the interaction plane;
2. builds the candidate set: every live slot request plus every queued
   turn not yet bound to a slot, minus decode slots past the hard
   playback-frontier cap;
3. asks ``core/scheduler.py`` (Algorithm 1) for the round's admission:
   which turns attach to slots, which slots advance, what prefill chunk
   each gets, who is pace-held behind the playback frontier;
4. executes exactly that decision via ``engine.run_round`` and streams
   the resulting audio chunks back to clients, feeding each session's
   playback clock (``monitor.on_audio``).

The engine never schedules for itself here — ``engine.step()`` is the
self-driving demo path; the gateway calls ``submit_turn``/``run_round``
with its own scheduler's output, so the same Algorithm 1 implementation
that runs under the simulator's virtual clock runs against real paged
JAX state under a scaled wall clock.

Single-threaded asyncio discipline: every engine call happens on the
event loop with no await inside, so rounds, barge-in aborts, and turn
admissions are atomic with respect to each other — that is the
"async-safe" contract, not locks.
"""
from __future__ import annotations

import asyncio
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.scheduler import (FCFSScheduler, RoundBudget,
                                  SchedulerConfig, UrgencyScheduler)
from repro.core.session import Phase, Request, RequestState
from repro.kvcache.paged import OutOfPages
from repro.serving.gateway.clock import ScaledWallClock
from repro.serving.gateway.events import (AudioChunk, BargeIn, Hangup,
                                          HandoffRequest, SessionClosed,
                                          SessionEvent, SpeechEnd,
                                          SpeechStart, ToolCallResult,
                                          ToolCallStart, TurnDone,
                                          TurnRequest, UserAudio)
from repro.serving.metrics import Metrics, TurnRecord


@dataclass
class GatewayConfig:
    policy: str = "liveserve"            # liveserve | fcfs
    audio_per_token_s: float = 0.08      # playable audio per output token
    # Algorithm 1 per-round budget / prompt tokens per granted round.
    # Retuned for the fused data plane (DESIGN.md §11): a 16-token
    # prefill chunk costs one launch, not 16, so chunks are sized for
    # scheduling granularity alone (the pre-fused default was 4 only
    # because a chunk cost C sequential launches).
    round_token_budget: int = 16
    prefill_chunk: int = 16
    # hard generation cap beyond the playback frontier (seconds of client
    # buffer). None = rely on the scheduler's pacing class alone; set it
    # to enforce the cap even under the KV-pressure pacing override.
    frontier_cap_s: Optional[float] = None
    sched: Optional[SchedulerConfig] = None
    idle_sleep_s: float = 0.05           # scaled-clock wait when idle
    # transfer chunks drained per idle pass (run_round drains its own
    # per-round budget; this keeps preloads moving when nothing decodes)
    idle_transfer_chunks: int = 2


@dataclass
class PendingTurn:
    """A TurnRequest the scheduler has not yet admitted to a slot."""
    session_id: str
    prompt: np.ndarray
    max_new_tokens: int
    request: Request


@dataclass
class GatewaySession:
    session_id: str
    outbox: asyncio.Queue
    turn_no: int = -1                    # last TurnRequest's index
    closed: bool = False


class SessionHandle:
    """Client side of one duplex session (in-process transport)."""

    def __init__(self, gateway: "RealtimeGateway", gs: GatewaySession):
        self._gw = gateway
        self._gs = gs
        self.session_id = gs.session_id

    async def send(self, ev: SessionEvent) -> None:
        ev.t = self._gw.clock.now()
        await self._gw._inbox.put(ev)

    async def recv(self) -> SessionEvent:
        return await self._gs.outbox.get()


def build_scheduler(policy: str, monitor, kv_occupancy, *, chunk: int,
                    decode_chunk: int = 1,
                    sc: Optional[SchedulerConfig] = None):
    """One engine's round scheduler — shared by the asyncio gateway,
    the replay twin, and the fleet gateways (each replica gets its own
    scheduler over its own monitor/KV pressure). ``decode_chunk`` > 1
    turns decode grants into "up to K draft tokens" budgets for the
    speculative plane (DESIGN.md §16)."""
    if policy == "liveserve":
        return UrgencyScheduler(sc or SchedulerConfig(), monitor,
                                stage="thinker",
                                kv_occupancy=kv_occupancy,
                                prefill_chunk=chunk,
                                decode_chunk=decode_chunk)
    return FCFSScheduler(monitor, stage="thinker", prefill_chunk=chunk,
                         decode_chunk=decode_chunk)


def frame_token_tick(monitor, rec, sid: str, now: float) -> None:
    """Per-emitted-token frame accounting for periodic (full-duplex)
    sessions — shared by both gateway twins so the deadline-miss
    counters cannot drift between the live loop and the replay. The
    deadline walks one period per token from the turn request (hard
    periodic-task semantics: falling behind accumulates misses, it does
    not re-anchor the schedule)."""
    v = monitor.view(sid)
    if v is None or v.frame_deadline is None:
        return
    if now > v.frame_deadline + 1e-9:
        rec.deadline_misses += 1
    rec.frames += 1
    v.frame_deadline += v.frame_period_s


def record_admitted_turn(rec, r: Request) -> None:
    """Copy the admission-time reload accounting from the Request onto
    the TurnRecord — the one coupling between the engine's turn stats
    and the serving metrics, shared by both gateway twins so the
    sim/real differential cannot drift field-by-field."""
    rec.reload_stall_s = r.reload_stall_s
    rec.reload_off_path_s = r.reload_off_path_s
    rec.prefix_hit_tokens = r.prefix_hit_tokens
    # prompt_len counts only the tokens left to prefill after a prefix
    # attach; the record keeps the client-visible total
    rec.prompt_tokens = r.prompt_len + r.prefix_hit_tokens


def control_round(eng, scheduler, pending, *, token_budget: int,
                  frontier_cap_s: Optional[float], record_admit):
    """One Algorithm-1 control round over a paged engine — the single
    source of truth shared by the asyncio ``RealtimeGateway`` and the
    deterministic ``ReplayGateway`` (gateway/replay.py), so the replay
    twin used by the differential harness cannot drift from the real
    serving loop. Builds the candidate set (live slots minus decode
    slots past the frontier cap, plus queued turns), asks the scheduler
    for the round's admission, binds admitted pending turns to slots
    (requeueing on a saturated-pool ``OutOfPages``), and returns
    ``(decision, chunks, admitted)``; ``decision`` is None when nothing
    was ready. ``record_admit(sid, request)`` fires per admitted turn.
    """
    now = eng.clock.now()
    ready: List[Request] = []
    owner: Dict[int, tuple] = {}

    def over_frontier(sid: str) -> bool:
        if frontier_cap_s is None:
            return False
        buf = eng.monitor.playback_buffer_s(sid)
        return buf is not None and buf > frontier_cap_s

    for i, s in eng.slot_state.items():
        if s is None or not s.request.is_live():
            continue
        if s.request.generated >= s.request.max_new_tokens:
            continue
        if s.request.phase == Phase.DECODE \
                and over_frontier(s.session_id):
            continue                         # hard frontier cap (§4)
        s.request.slot_bound = True
        ready.append(s.request)
        owner[s.request.req_id] = ("slot", i)
    for sid, p in pending.items():
        p.request.slot_bound = False
        ready.append(p.request)
        owner[p.request.req_id] = ("pending", sid)
    if not ready:
        return None, {}, False
    budget = RoundBudget(
        token_budget=token_budget,
        free_kv_blocks=eng.kv.free_blocks
        + eng.kv.reclaimable_blocks(now),
        max_batch=eng.slots, block_size=eng.page_size,
        free_slots=sum(1 for s in eng.slot_state.values() if s is None))
    decision = scheduler.schedule(ready, budget, now)
    chunks: Dict[int, int] = {}
    admitted = False
    for r in decision.batch:
        kind, key = owner[r.req_id]
        if kind == "slot":
            chunks[key] = decision.chunks[r.req_id]
            continue
        if eng.free_slot() is None:
            continue                         # all slots busy; stay queued
        p = pending.pop(key)
        try:
            eng.submit_turn(key, p.prompt, p.max_new_tokens,
                            request=r)       # reload path runs here
        except OutOfPages:
            # saturated pool: the session's offloaded pages cannot be
            # reloaded yet (everything else pinned/protected). Keep the
            # turn queued — pressure drains as turns finish or barge-ins
            # trim
            pending[key] = p
            continue
        record_admit(key, r)
        admitted = True                      # prefill starts next round
    return decision, chunks, admitted


class RealtimeGateway:
    def __init__(self, engine, *, cfg: Optional[GatewayConfig] = None):
        self.engine = engine
        self.cfg = cfg or GatewayConfig()
        self.clock = engine.clock
        self._init_common()
        self.scheduler = build_scheduler(
            self.cfg.policy, engine.monitor, engine.kv.occupancy,
            chunk=self.sched_chunk(), decode_chunk=self.decode_chunk(),
            sc=self.cfg.sched)

    def sched_chunk(self) -> int:
        # a prefill chunk larger than the round budget can never be
        # admitted — Algorithm 1's head-of-line break would then hold it
        # (and everything behind it) forever
        return max(1, min(self.cfg.prefill_chunk,
                          self.cfg.round_token_budget))

    def decode_chunk(self) -> int:
        # pending token + the engine's draft budget, clamped to the
        # round budget for the same head-of-line reason as sched_chunk
        return max(1, min(1 + getattr(self.engine, "spec_decode", 0),
                          self.cfg.round_token_budget))

    def _init_common(self) -> None:
        assert hasattr(self.clock, "real_s"), \
            "gateway needs a ScaledWallClock-like clock on the engine " \
            "(sim time and wall time must be the same timeline)"
        self._inbox: asyncio.Queue = asyncio.Queue()
        self._sessions: Dict[str, GatewaySession] = {}
        self._pending: Dict[str, PendingTurn] = {}
        self._recs: Dict[Tuple[str, int], TurnRecord] = {}
        self._metrics = Metrics()
        self._stopping = False
        self._force_stop = False
        self.rounds = 0
        # frontier telemetry: worst observed client buffer beyond the
        # configured cap at token-emission time (the §4 invariant)
        self.max_over_frontier_s = 0.0

    # engine indirection: the fleet gateway (serving/fleet) overrides
    # these two so every per-session path below runs against the
    # replica the router placed the session on
    def _eng(self, sid: str):
        return self.engine

    def _engines(self):
        return (self.engine,)

    # ------------------------------------------------------------ clients
    def connect(self, session_id: str) -> SessionHandle:
        assert session_id not in self._sessions, session_id
        gs = GatewaySession(session_id, asyncio.Queue())
        self._sessions[session_id] = gs
        return SessionHandle(self, gs)

    def stop(self, force: bool = False) -> None:
        """Finish in-flight work, then exit the serve loop. ``force``
        exits at the next idle point even with work still queued (the
        harness uses it when the load's deadline lapses)."""
        self._stopping = True
        self._force_stop = self._force_stop or force

    def metrics(self) -> Metrics:
        self._metrics.sim_end = self.clock.now()
        self._metrics.pages_shared = max(
            (getattr(e, "peak_shared_pages", 0) for e in self._engines()),
            default=0)
        self._metrics.kv_wire_bytes_saved = sum(
            e.transfer.stats.wire_bytes_saved for e in self._engines())
        for f in ("spec_drafted", "spec_accepted", "spec_rejected",
                  "spec_rounds"):
            setattr(self._metrics, f,
                    sum(getattr(e, f, 0) for e in self._engines()))
        return self._metrics

    # ------------------------------------------------------------ records
    def _rec(self, sid: str) -> TurnRecord:
        gs = self._sessions[sid]
        key = (sid, gs.turn_no)
        rec = self._recs.get(key)
        if rec is None:
            rec = TurnRecord(session_id=sid, turn_index=gs.turn_no)
            self._recs[key] = rec
            self._metrics.turns.append(rec)
        return rec

    # ------------------------------------------------------------ events
    def _handle(self, ev: SessionEvent) -> None:
        sid = ev.session_id
        eng = self._eng(sid)
        if isinstance(ev, SpeechStart):
            # fires the §5.2 speech-time preload while the user talks
            eng.user_speech_start(sid, expected_dur_s=ev.expected_dur_s)
        elif isinstance(ev, UserAudio):
            pass    # audio payload is transport metadata; the VAD
            #         events (SpeechStart/End) carry the policy signal
        elif isinstance(ev, SpeechEnd):
            eng.monitor.on_speech_end(sid)
        elif isinstance(ev, TurnRequest):
            self._on_turn_request(ev)
        elif isinstance(ev, BargeIn):
            self._on_barge_in(ev)
        elif isinstance(ev, ToolCallStart):
            self._metrics.tool_pauses += 1
            eng.tool_call_start(sid, ev.expected_latency_s)
        elif isinstance(ev, ToolCallResult):
            eng.tool_call_result(sid, ev.resume_gap_s)
        elif isinstance(ev, HandoffRequest):
            self._on_handoff(ev)
        elif isinstance(ev, Hangup):
            self._on_hangup(sid)

    def _on_turn_request(self, ev: TurnRequest) -> None:
        sid = ev.session_id
        gs = self._sessions[sid]
        gs.turn_no += 1
        now = self.clock.now()
        sess = self._eng(sid).sessions.get(sid)
        req = Request(session_id=sid, stage="thinker",
                      turn_index=gs.turn_no, arrival_time=now,
                      prompt_len=int(len(ev.prompt)),
                      context_len=sess.kv_len if sess else 0,
                      max_new_tokens=ev.max_new_tokens,
                      audio_per_token_s=self.cfg.audio_per_token_s)
        self._pending[sid] = PendingTurn(sid, np.asarray(ev.prompt,
                                                         np.int32),
                                         ev.max_new_tokens, req)
        rec = self._rec(sid)
        rec.speech_end = now
        if ev.frame_period_s > 0.0:
            self._eng(sid).monitor.on_frame_turn(sid, ev.frame_period_s)
        rec.tool_resumed = ev.tool_resume

    def _on_handoff(self, ev: HandoffRequest) -> None:
        """Single-engine gateway: there is nowhere to move the session;
        acknowledge-and-stay (the fleet gateway overrides this with a
        targeted migration)."""

    def _slot_of(self, sid: str) -> Optional[int]:
        for i, s in self._eng(sid).slot_state.items():
            if s is not None and s.session_id == sid:
                return i
        return None

    def _on_barge_in(self, ev: BargeIn) -> None:
        sid = ev.session_id
        eng = self._eng(sid)
        now = self.clock.now()
        slot = self._slot_of(sid)
        gs = self._sessions[sid]
        rec = self._recs.get((sid, gs.turn_no))
        view = eng.monitor.view(sid)
        drained = rec is not None and rec.completed and (
            view is None or view.playback.buffer_s(now) <= 0)
        if drained and slot is None and sid not in self._pending:
            # mirror the simulator: a barge-in after playback fully
            # drained is a pure no-op — it must not mark the session
            # interrupted (that would skip the reply-gap EMA and keep
            # its idle KV immediate-reuse-protected)
            return
        pend = self._pending.pop(sid, None)
        if pend is not None:
            pend.request.state = RequestState.ABORTED
        if rec is not None and not drained:
            # during decode or playback the barge cuts the turn
            rec.barged = True
            heard = view.playback.consumed_s(now) if view else 0.0
            rec.audio_heard_s = heard
            heard_tokens = int(heard / self.cfg.audio_per_token_s)
            rec.talker_wasted = max(0, rec.talker_generated - heard_tokens)
            rec.finish_time = now
        # aborts the live turn (keeping committed pages) and fires the
        # barge-in preload trigger; no-op on the slot if none is live
        eng.barge_in(sid, expected_dur_s=ev.expected_dur_s)
        if slot is None:
            eng.monitor.on_barge_in(sid)     # slot path already did it
        if slot is not None or pend is not None:
            gs.outbox.put_nowait(TurnDone(
                sid, t=now, turn_index=gs.turn_no, aborted=True,
                generated=rec.talker_generated if rec else 0))

    def _on_hangup(self, sid: str) -> None:
        eng = self._eng(sid)
        gs = self._sessions[sid]
        if self._slot_of(sid) is not None:
            eng.abort(sid)
        self._pending.pop(sid, None)
        if sid in eng.sessions and not eng.sessions[sid].ended:
            eng.end_session(sid)
        gs.closed = True
        self._metrics.completed_sessions += 1
        gs.outbox.put_nowait(SessionClosed(sid, t=self.clock.now()))

    # ------------------------------------------------------------ rounds
    def _record_admit(self, sid: str, r: Request) -> None:
        record_admitted_turn(self._rec(sid), r)

    def _round(self) -> bool:
        """One scheduler-driven round. Returns True if any work ran."""
        eng = self.engine
        decision, chunks, admitted = control_round(
            eng, self.scheduler, self._pending,
            token_budget=self.cfg.round_token_budget,
            frontier_cap_s=self.cfg.frontier_cap_s,
            record_admit=self._record_admit)
        if decision is None:
            return False
        self.last_decision = decision
        if not chunks:
            return admitted
        sids = {i: eng.slot_state[i].session_id for i in chunks}
        events = eng.run_round(chunks)
        self.rounds += 1
        self._dispatch(events, sids)
        return True

    def _dispatch(self, events: Dict[int, List[tuple]],
                  sids: Dict[int, str]) -> None:
        apt = self.cfg.audio_per_token_s
        for slot, evs in events.items():
            sid = sids[slot]
            eng = self._eng(sid)
            gs = self._sessions[sid]
            rec = self._rec(sid)
            for kind, val in evs:
                now = self.clock.now()
                if kind == "token":
                    if rec.ttfp is None:
                        rec.ttfp = now - rec.speech_end
                        rec.text_ttft = rec.ttfp
                    frame_token_tick(eng.monitor, rec, sid, now)
                    eng.monitor.on_audio(sid, apt)
                    rec.audio_delivered_s += apt
                    rec.talker_generated += 1
                    if self.cfg.frontier_cap_s is not None:
                        buf = eng.monitor.playback_buffer_s(sid) or 0.0
                        self.max_over_frontier_s = max(
                            self.max_over_frontier_s,
                            buf - self.cfg.frontier_cap_s)
                    gs.outbox.put_nowait(AudioChunk(
                        sid, t=now, turn_index=gs.turn_no, dur_s=apt,
                        token=val))
                elif kind == "finished":
                    v = eng.monitor.view(sid)
                    rec.max_gap_s = (v.playback.max_gap_s
                                     if v.playback.gap_s else 0.0)
                    rec.n_gaps = v.playback.n_gaps
                    rec.gen_span_s = now - rec.speech_end - (rec.ttfp or 0.0)
                    rec.completed = True
                    rec.finish_time = now
                    gs.outbox.put_nowait(TurnDone(
                        sid, t=now, turn_index=gs.turn_no, aborted=False,
                        generated=val))

    # ------------------------------------------------------------ serve
    def _drain(self) -> int:
        n = 0
        while True:
            try:
                ev = self._inbox.get_nowait()
            except asyncio.QueueEmpty:
                break
            self._handle(ev)
            n += 1
        return n

    def _live_work(self) -> bool:
        if self._pending:
            return True
        return any(s is not None and s.request.is_live()
                   for eng in self._engines()
                   for s in eng.slot_state.values())

    def _pump(self) -> None:
        """Per-iteration control-plane work beyond event handling; the
        fleet gateway advances its migration plans here (atomic with
        rounds under the single-threaded asyncio contract)."""

    def _idle_drain(self) -> None:
        if self.cfg.idle_transfer_chunks <= 0:   # budget 0 = drains off
            return
        for eng in self._engines():
            eng.drain_transfers(self.cfg.idle_transfer_chunks)

    def _hold_wake(self) -> Optional[float]:
        ld = getattr(self, "last_decision", None)
        if not ld:
            return None
        return self.scheduler.hold_wake_s(ld, self.clock.now())

    async def run(self) -> None:
        """Serve until ``stop()`` is called and in-flight work drains."""
        while True:
            self._drain()
            self._pump()
            if self._round():
                await asyncio.sleep(0)       # let client tasks react
                continue
            if self._force_stop:
                return
            if self._stopping and self._inbox.empty() \
                    and not self._live_work():
                return
            # idle: nothing decodes this instant, but queued transfer
            # chunks (a speech-time preload, a copy-then-free offload, a
            # migrate-out drain) still progress — this is exactly the
            # window the paper hides reload work in (DESIGN.md §10)
            self._idle_drain()
            wake = self.cfg.idle_sleep_s
            held = self._hold_wake()
            if held is not None:
                wake = min(wake, held)
            try:
                ev = await asyncio.wait_for(
                    self._inbox.get(), timeout=self.clock.real_s(wake))
                self._handle(ev)
            except asyncio.TimeoutError:
                pass

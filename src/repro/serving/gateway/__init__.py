"""Realtime session gateway — the event-driven serving front-end that
puts the LiveServe control plane in charge of the real paged engine
(DESIGN.md §4).

Layout:
  events.py   typed duplex event protocol (client <-> gateway)
  clock.py    scaled wall clock shared by engine, monitor, and policies
  gateway.py  asyncio gateway: session registry + scheduler-driven
              continuous-batching step loop over PagedRealtimeEngine
  client.py   in-process clients: load generator replaying
              serving/workload.py traces in scaled real time
  harness.py  one-call end-to-end runner (serve.py --engine live,
              benchmarks/gateway_bench.py, tests, examples)
  replay.py   deterministic virtual-time replay twin of gateway.py —
              the differential sim-vs-real harness (DESIGN.md §9)
"""
from repro.serving.gateway.clock import ScaledWallClock
from repro.serving.gateway.events import (AudioChunk, BargeIn, Hangup,
                                          SessionClosed, SpeechEnd,
                                          SpeechStart, TurnDone,
                                          TurnRequest, UserAudio)
from repro.serving.gateway.gateway import GatewayConfig, RealtimeGateway
from repro.serving.gateway.client import LoadGenConfig, run_load
from repro.serving.gateway.harness import run_gateway_workload
from repro.serving.gateway.replay import (ReplayClock, ReplayConfig,
                                          ReplayGateway, run_replay)

__all__ = [
    "AudioChunk", "BargeIn", "Hangup", "SessionClosed", "SpeechEnd",
    "SpeechStart", "TurnDone", "TurnRequest", "UserAudio",
    "GatewayConfig", "RealtimeGateway", "ScaledWallClock",
    "LoadGenConfig", "run_load", "run_gateway_workload",
    "ReplayClock", "ReplayConfig", "ReplayGateway", "run_replay",
]

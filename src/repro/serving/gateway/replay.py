"""Deterministic virtual-time gateway replay (DESIGN.md §4, §9).

The asyncio ``RealtimeGateway`` runs the control plane against a scaled
*wall* clock — great for end-to-end realism, useless for property-based
differential testing, where an example must be bit-reproducible and
fast. This module replays the same ``serving/workload.py`` traces
through the same ``PagedRealtimeEngine`` round API
(``submit_turn``/``run_round``/``barge_in``/``end_session``) and the
same ``core/scheduler.py`` Algorithm 1, but on a virtual clock the
driver owns: rounds cost a fixed ``round_dt`` of virtual seconds, idle
time jumps straight to the next client event, and the client state
machine (speak → turn request → listen → barge/think → speak) is the
synchronous mirror of ``gateway/client.py``.

Scheduling-visible behavior — which turns complete in which order,
what the playback-frontier cap holds, which sessions the KV policy
evicts — is therefore a pure function of (workload seed, engine
geometry), directly comparable against ``serving/simulator.py`` on the
same trace. That comparison is the differential harness in
``tests/test_differential.py``.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.scheduler import (FCFSScheduler, SchedulerConfig,
                                  UrgencyScheduler)
from repro.core.session import Request, RequestState
from repro.serving.engine import RoundLimitExceeded
from repro.serving.gateway.gateway import (control_round,
                                           frame_token_tick,
                                           record_admitted_turn)
from repro.serving.metrics import Metrics, TurnRecord
from repro.serving.workload import (TOOL_RESUME_GAP_S, WorkloadConfig,
                                    family_prefix, generate)


class ReplayClock:
    """Driver-owned virtual time. The engine's per-round ``tick()`` is
    free; the driver charges ``round_dt`` per executed round and jumps
    over idle gaps."""

    def __init__(self):
        self.t = 0.0

    def now(self) -> float:
        return self.t

    def tick(self, dt: float = 0.0) -> None:
        self.t += dt

    def advance_to(self, t: float) -> None:
        self.t = max(self.t, t)


@dataclass
class ReplayConfig:
    policy: str = "liveserve"            # liveserve | fcfs
    audio_per_token_s: float = 0.25
    round_token_budget: int = 4
    prefill_chunk: int = 2
    frontier_cap_s: Optional[float] = 3.0
    round_dt: float = 0.02               # virtual cost of one round
    max_turns: int = 2                   # trace clamps (as client.py)
    max_prompt: int = 6
    max_response: int = 6
    sched: Optional[SchedulerConfig] = None


@dataclass
class _Pending:
    session_id: str
    prompt: np.ndarray
    max_new_tokens: int
    request: Request


class ReplayGateway:
    """Synchronous, virtually-clocked twin of ``RealtimeGateway``."""

    def __init__(self, engine, workload: WorkloadConfig,
                 cfg: Optional[ReplayConfig] = None, *, seed: int = 0):
        self.eng = engine
        self.cfg = cfg or ReplayConfig()
        self.clock = engine.clock
        assert isinstance(self.clock, ReplayClock), \
            "build the engine on a ReplayClock (driver owns time)"
        sc = self.cfg.sched or SchedulerConfig()
        chunk = max(1, min(self.cfg.prefill_chunk,
                           self.cfg.round_token_budget))
        dchunk = max(1, min(1 + getattr(engine, "spec_decode", 0),
                            self.cfg.round_token_budget))
        if self.cfg.policy == "liveserve":
            self.scheduler = UrgencyScheduler(
                sc, engine.monitor, stage="thinker",
                kv_occupancy=engine.kv.occupancy, prefill_chunk=chunk,
                decode_chunk=dchunk)
        else:
            self.scheduler = FCFSScheduler(
                engine.monitor, stage="thinker", prefill_chunk=chunk,
                decode_chunk=dchunk)
        self.metrics = Metrics()
        self._recs: Dict[Tuple[str, int], TurnRecord] = {}
        self._pending: Dict[str, _Pending] = {}
        self._turn_no: Dict[str, int] = {}
        self._events: List[tuple] = []       # (t, seq, fn)
        self._seq = itertools.count()
        self.rounds = 0
        self.max_over_frontier_s = 0.0
        self._admit_trace(workload, seed)

    # ----------------------------------------------------- fleet hooks
    # The fleet replay twin (serving/fleet/replay.py) overrides these so
    # every per-session path below runs against the replica its router
    # placed the session on — the same seam RealtimeGateway exposes.
    def _eng(self, sid: str):
        return self.eng

    def _engines(self):
        return (self.eng,)

    def _pump(self) -> None:
        """Fleet migration plans advance here, between event delivery
        and the round — the virtual-time mirror of the asyncio
        gateway's ``_pump``."""

    def _idle_transfer(self) -> bool:
        did = False
        for e in self._engines():
            did = bool(e.drain_transfers(1)) or did
        return did

    # ------------------------------------------------------------ trace
    def _admit_trace(self, workload: WorkloadConfig, seed: int) -> None:
        """Clamp the trace exactly like ``gateway/client.py`` (one rng
        draw per turn, stream keyed [seed, session-index]) so the same
        (workload, seed) yields identical prompts here and in the
        asyncio gateway. All draws happen up front: replay scheduling
        order can never perturb them."""
        self._trace = generate(workload)
        self._by_sid = {s.session_id: s for s in self._trace}
        self._turns: Dict[str, list] = {}
        for i, s in enumerate(self._trace):
            rng = np.random.default_rng([seed, i])
            fam = (family_prefix(workload, s.family,
                                 self.eng.cfg.vocab_size, seed)
                   if s.family >= 0 and workload.family_prefix_len > 0
                   else None)
            lst = []
            for turn in s.turns[:self.cfg.max_turns]:
                prompt = rng.integers(
                    0, self.eng.cfg.vocab_size,
                    size=max(1, min(turn.prompt_len, self.cfg.max_prompt)))
                if fam is not None and turn.index == 0:
                    # the shared system prompt rides UNCLAMPED ahead of
                    # the per-turn draw — same splice as client.py
                    prompt = np.concatenate([fam, prompt])
                n_tokens = max(2, min(turn.response_tokens,
                                      self.cfg.max_response))
                speech_dur = max(0.05, turn.speech_end - turn.speech_start)
                cut_s = None
                if turn.barge_in:
                    apt = self.cfg.audio_per_token_s
                    frac = turn.barge_cut_s / max(
                        1e-9, turn.response_tokens * apt)
                    cut_s = max(apt, min(frac, 0.9) * n_tokens * apt)
                lst.append((np.asarray(prompt, np.int32), n_tokens,
                            speech_dur, cut_s, turn))
            self._turns[s.session_id] = lst
            self._push(s.arrival_time, self._speech_start, s, 0)

    def _push(self, t: float, fn, *args) -> None:
        heapq.heappush(self._events, (t, next(self._seq), fn, args))

    def _rec(self, sid: str) -> TurnRecord:
        key = (sid, self._turn_no[sid])
        rec = self._recs.get(key)
        if rec is None:
            rec = TurnRecord(session_id=sid, turn_index=key[1])
            self._recs[key] = rec
            self.metrics.turns.append(rec)
        return rec

    # ----------------------------------------------------- client events
    def _clamped_turn(self, s, ti: int):
        return self._turns[s.session_id][ti]

    def _handoff_request(self, sid: str, target: int) -> None:
        """Single-engine replay: nowhere to move the session —
        acknowledge-and-stay (the fleet twin overrides this with a
        targeted migration, mirroring the fleet gateway)."""

    def _speech_start(self, s, ti: int) -> None:
        sid = s.session_id
        _, _, speech_dur, _, turn = self._clamped_turn(s, ti)
        if turn.handoff:
            self._handoff_request(sid, turn.handoff_target)
        if turn.frame_period_tokens > 0.0:
            # full duplex: the request fires at speech onset, with no
            # duration estimate and no SpeechEnd gate (client.py mirror)
            self._eng(sid).user_speech_start(sid)
            self._push(self.clock.now(), self._turn_request, s, ti)
        else:
            self._eng(sid).user_speech_start(sid,
                                             expected_dur_s=speech_dur)
            self._push(self.clock.now() + speech_dur, self._turn_request,
                       s, ti)

    def _turn_request(self, s, ti: int, resume: bool = False) -> None:
        sid = s.session_id
        prompt, n_tokens, _, _, turn = self._clamped_turn(s, ti)
        eng = self._eng(sid)
        duplex = turn.frame_period_tokens > 0.0
        if not duplex and not resume:
            # the client sends SpeechEnd just before TurnRequest only on
            # the half-duplex speech path (no utterance gates a duplex
            # or tool-resume turn)
            eng.monitor.on_speech_end(sid)
        self._turn_no[sid] = ti
        now = self.clock.now()
        sess = eng.sessions.get(sid)
        req = Request(session_id=sid, stage="thinker", turn_index=ti,
                      arrival_time=now, prompt_len=int(len(prompt)),
                      context_len=sess.kv_len if sess else 0,
                      max_new_tokens=n_tokens,
                      audio_per_token_s=self.cfg.audio_per_token_s)
        self._pending[sid] = _Pending(sid, np.asarray(prompt, np.int32),
                                      n_tokens, req)
        rec = self._rec(sid)
        rec.speech_end = now
        if duplex:
            eng.monitor.on_frame_turn(
                sid, turn.frame_period_tokens * self.cfg.audio_per_token_s)
        rec.tool_resumed = resume

    def _barge(self, s, ti: int) -> None:
        """The trace's cut point (anchored post-TTFP, like client.py):
        interrupt playback, then the interrupting utterance becomes the
        next turn immediately."""
        sid = s.session_id
        eng = self._eng(sid)
        now = self.clock.now()
        rec = self._recs.get((sid, ti))
        view = eng.monitor.view(sid)
        slot = self._slot_of(sid)
        drained = rec is not None and rec.completed and (
            view is None or view.playback.buffer_s(now) <= 0)
        if not (drained and slot is None and sid not in self._pending):
            pend = self._pending.pop(sid, None)
            if pend is not None:
                pend.request.state = RequestState.ABORTED
            if rec is not None and not drained:
                rec.barged = True
                heard = view.playback.consumed_s(now) if view else 0.0
                rec.audio_heard_s = heard
                heard_tokens = int(heard / self.cfg.audio_per_token_s)
                rec.talker_wasted = max(0, rec.talker_generated
                                        - heard_tokens)
                rec.finish_time = now
            nturns = self._turns[sid]
            speech_dur = (nturns[ti + 1][2] if ti + 1 < len(nturns)
                          else None)
            eng.barge_in(sid, expected_dur_s=speech_dur)
            if slot is None:
                eng.monitor.on_barge_in(sid)
        self._next_or_hangup(s, ti, at=now)

    def _turn_done(self, s, ti: int) -> None:
        sid = s.session_id
        eng = self._eng(sid)
        now = self.clock.now()
        turn = self._clamped_turn(s, ti)[4]
        if turn.frame_period_tokens > 0.0:
            # full-duplex utterance closes with the turn (client.py
            # sends its SpeechEnd on TurnDone)
            eng.monitor.on_speech_end(sid)
        if turn.tool_call and ti + 1 < len(self._turns[sid]):
            self._tool_pause(s, ti)
            return
        v = eng.monitor.view(sid)
        drain = v.playback.buffer_s(now) if v else 0.0
        self._next_or_hangup(s, ti,
                             at=now + drain + (s.think_time_s or 0.0))

    def _tool_pause(self, s, ti: int) -> None:
        """The reply ended in a tool call: idle with hot KV for the
        tool's latency, then resume without a new utterance — the
        synchronous mirror of client.py's ToolCallStart/Result flow."""
        sid = s.session_id
        turn = self._clamped_turn(s, ti)[4]
        self.metrics.tool_pauses += 1
        self._eng(sid).tool_call_start(sid, turn.tool_latency_s)
        self._push(self.clock.now() + turn.tool_latency_s,
                   self._tool_result, s, ti)

    def _tool_result(self, s, ti: int) -> None:
        sid = s.session_id
        self._eng(sid).tool_call_result(sid, TOOL_RESUME_GAP_S)
        self._push(self.clock.now() + TOOL_RESUME_GAP_S,
                   self._turn_request, s, ti + 1, True)

    def _next_or_hangup(self, s, ti: int, *, at: float) -> None:
        nxt = ti + 1
        if nxt < len(self._turns[s.session_id]):
            self._push(at, self._speech_start, s, nxt)
        else:
            self._push(at, self._hangup, s)

    def _hangup(self, s) -> None:
        sid = s.session_id
        eng = self._eng(sid)
        if self._slot_of(sid) is not None:
            eng.abort(sid)
        self._pending.pop(sid, None)
        if sid in eng.sessions and not eng.sessions[sid].ended:
            eng.end_session(sid)
        self.metrics.completed_sessions += 1

    def _slot_of(self, sid: str) -> Optional[int]:
        for i, st in self._eng(sid).slot_state.items():
            if st is not None and st.session_id == sid:
                return i
        return None

    def _record_admit(self, sid: str, r: Request) -> None:
        record_admitted_turn(self._rec(sid), r)

    # ------------------------------------------------------------ rounds
    def _round(self) -> bool:
        """One scheduler round: the shared ``control_round`` body (the
        very same code the asyncio gateway runs — candidate set,
        frontier cap, OutOfPages requeue), executed synchronously."""
        eng = self.eng
        decision, chunks, admitted = control_round(
            eng, self.scheduler, self._pending,
            token_budget=self.cfg.round_token_budget,
            frontier_cap_s=self.cfg.frontier_cap_s,
            record_admit=self._record_admit)
        if decision is None:
            return False
        if not chunks:
            return admitted
        sids = {i: eng.slot_state[i].session_id for i in chunks}
        events = eng.run_round(chunks)
        self.rounds += 1
        self._dispatch(events, sids)
        return True

    def _dispatch(self, events: Dict[int, List[tuple]],
                  sids: Dict[int, str]) -> None:
        apt = self.cfg.audio_per_token_s
        for slot, evs in events.items():
            sid = sids[slot]
            eng = self._eng(sid)
            s = self._by_sid[sid]
            ti = self._turn_no[sid]
            rec = self._rec(sid)
            for kind, val in evs:
                now = self.clock.now()
                if kind == "token":
                    first = rec.ttfp is None
                    if first:
                        rec.ttfp = now - rec.speech_end
                        rec.text_ttft = rec.ttfp
                    frame_token_tick(eng.monitor, rec, sid, now)
                    eng.monitor.on_audio(sid, apt)
                    rec.audio_delivered_s += apt
                    rec.talker_generated += 1
                    if self.cfg.frontier_cap_s is not None:
                        buf = eng.monitor.playback_buffer_s(sid) or 0.0
                        self.max_over_frontier_s = max(
                            self.max_over_frontier_s,
                            buf - self.cfg.frontier_cap_s)
                    if first:
                        # the trace's barge cut anchors at first audio
                        cut_s = self._clamped_turn(s, ti)[3]
                        if cut_s is not None:
                            self._push(now + cut_s, self._barge, s, ti)
                elif kind == "finished":
                    v = eng.monitor.view(sid)
                    rec.max_gap_s = (v.playback.max_gap_s
                                     if v.playback.gap_s else 0.0)
                    rec.n_gaps = v.playback.n_gaps
                    rec.gen_span_s = now - rec.speech_end \
                        - (rec.ttfp or 0.0)
                    rec.completed = True
                    rec.finish_time = now
                    cut_s = self._clamped_turn(s, ti)[3]
                    if cut_s is None:
                        self._turn_done(s, ti)
                    # else: the scheduled barge advances the session

    # ------------------------------------------------------------ run
    def _live_work(self) -> bool:
        if self._pending:
            return True
        return any(st is not None and st.request.is_live()
                   and st.request.generated < st.request.max_new_tokens
                   for e in self._engines()
                   for st in e.slot_state.values())

    def run(self, *, max_rounds: int = 200_000,
            check_every_round=None) -> Metrics:
        """Drive the full trace to completion. ``check_every_round``
        (e.g. ``engine.check_invariants``) runs after every executed
        round. Raises ``RoundLimitExceeded`` — never swallows it — if
        the schedule live-locks."""
        idle = 0
        while self._events or self._live_work():
            while self._events and self._events[0][0] <= self.clock.now():
                _, _, fn, args = heapq.heappop(self._events)
                fn(*args)
            self._pump()
            if self._round():
                self.clock.tick(self.cfg.round_dt)
                idle = 0
                if check_every_round is not None:
                    check_every_round()
                if self.rounds > max_rounds:
                    raise RoundLimitExceeded(
                        f"replay still live after {max_rounds} rounds")
                continue
            # idle gap: queued transfer chunks drain before time jumps
            # to the next client event — the deterministic mirror of
            # the asyncio gateway's idle-loop drain, so a speech-time
            # preload lands during the (virtual) utterance
            if self._idle_transfer():
                self.clock.tick(self.cfg.round_dt)
                if check_every_round is not None:
                    check_every_round()
                continue
            if self._events:
                self.clock.advance_to(self._events[0][0])
                continue
            if self._live_work():
                # paced/held work with no client events: playback must
                # drain (or pressure lift) before anything schedules
                self.clock.tick(max(self.cfg.round_dt, 0.05))
                idle += 1
                if idle > max_rounds:
                    raise RoundLimitExceeded(
                        "replay wedged: live work that never reschedules")
                continue
        self.metrics.sim_end = self.clock.now()
        self.metrics.pages_shared = max(
            (getattr(e, "peak_shared_pages", 0) for e in self._engines()),
            default=0)
        self.metrics.kv_wire_bytes_saved = sum(
            e.transfer.stats.wire_bytes_saved for e in self._engines())
        for f in ("spec_drafted", "spec_accepted", "spec_rejected",
                  "spec_rounds"):
            setattr(self.metrics, f,
                    sum(getattr(e, f, 0) for e in self._engines()))
        return self.metrics


def run_replay(engine_factory, workload: WorkloadConfig,
               cfg: Optional[ReplayConfig] = None, *, seed: int = 0,
               check_invariants: bool = True):
    """Build engine on a ReplayClock via ``engine_factory(clock)``,
    replay the workload, return (metrics, ReplayGateway)."""
    clock = ReplayClock()
    eng = engine_factory(clock)
    gw = ReplayGateway(eng, workload, cfg, seed=seed)
    gw.run(check_every_round=eng.check_invariants
           if check_invariants else None)
    return gw.metrics, gw

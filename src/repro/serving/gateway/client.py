"""In-process gateway clients (DESIGN.md §4).

``run_load`` replays ``serving/workload.py`` traces against a
``RealtimeGateway`` in scaled real time: per-session asyncio tasks speak
(SpeechStart → UserAudio → SpeechEnd), submit the encoded turn
(TurnRequest), consume AudioChunks into a client-side playback estimate,
barge in at the trace's cut point — anchored after the first audio
packet, like the simulator — think, and speak again. The same arrival
processes (poisson / burstgpt) and Bernoulli barge-in used for the
paper-scale simulations therefore drive the real paged data plane.

Trace lengths are clamped (``max_prompt`` / ``max_response`` /
``max_turns``) so laptop-scale engine contexts can serve the
distribution's shape without its tails.
"""
from __future__ import annotations

import asyncio
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core.session import Session
from repro.serving.gateway.events import (AudioChunk, BargeIn, Hangup,
                                          HandoffRequest, SessionClosed,
                                          SpeechEnd, SpeechStart,
                                          ToolCallResult, ToolCallStart,
                                          TurnDone, TurnRequest, UserAudio)
from repro.serving.workload import (TOOL_RESUME_GAP_S, WorkloadConfig,
                                    family_prefix, generate)


@dataclass
class LoadGenConfig:
    workload: WorkloadConfig = field(default_factory=WorkloadConfig)
    vocab: int = 331                 # token ids drawn uniform [0, vocab)
    max_prompt: int = 16             # clamp trace prompt lengths
    max_response: int = 12           # clamp trace response tokens
    max_turns: int = 2               # clamp turns per session
    audio_per_token_s: float = 0.08  # must match GatewayConfig
    speech_scale: float = 1.0        # shrink utterances for fast tests
    seed: int = 0


async def _drive_session(gateway, clock, s: Session,
                         cfg: LoadGenConfig, rng) -> None:
    handle = gateway.connect(s.session_id)
    sid = s.session_id
    await clock.sleep(max(0.0, s.arrival_time - clock.now()))
    turns = s.turns[:cfg.max_turns]
    fam = (family_prefix(cfg.workload, s.family, cfg.vocab, cfg.seed)
           if s.family >= 0 and cfg.workload.family_prefix_len > 0
           else None)
    tool_resume = False
    for ti, turn in enumerate(turns):
        duplex = turn.frame_period_tokens > 0.0
        prompt = rng.integers(0, cfg.vocab,
                              size=max(1, min(turn.prompt_len,
                                              cfg.max_prompt)))
        if fam is not None and ti == 0:
            # shared system prompt rides unclamped ahead of the draw —
            # the exact splice the replay twin performs
            prompt = np.concatenate([fam, prompt.astype(np.int32)])
        n_tokens = max(2, min(turn.response_tokens, cfg.max_response))
        speech_dur = max(0.05, (turn.speech_end - turn.speech_start)
                         * cfg.speech_scale)
        if turn.handoff:
            # requested while idle (between turns), before this turn's
            # utterance — the move hides in speech, like a migration
            await handle.send(HandoffRequest(
                sid, target=turn.handoff_target))
        if tool_resume:
            # tool-pause resume: the tool result IS the turn input —
            # no new utterance, no SpeechStart/End
            await handle.send(TurnRequest(sid, prompt=prompt,
                                          max_new_tokens=n_tokens,
                                          tool_resume=True))
        elif duplex:
            # full duplex: the request fires at speech onset; the user
            # keeps talking while the model answers, so no duration
            # estimate and no SpeechEnd gate the turn
            await handle.send(SpeechStart(sid))
            await handle.send(UserAudio(sid, dur_s=speech_dur))
            await handle.send(TurnRequest(
                sid, prompt=prompt, max_new_tokens=n_tokens,
                frame_period_s=(turn.frame_period_tokens
                                * cfg.audio_per_token_s)))
        else:
            await handle.send(SpeechStart(sid, expected_dur_s=speech_dur))
            await handle.send(UserAudio(sid, dur_s=speech_dur))
            await clock.sleep(speech_dur)
            await handle.send(SpeechEnd(sid))
            await handle.send(TurnRequest(sid, prompt=prompt,
                                          max_new_tokens=n_tokens))
        # barge cut re-anchored to the clamped reply length so short
        # test replies still get cut mid-playback
        cut_s: Optional[float] = None
        if turn.barge_in:
            frac = turn.barge_cut_s / max(
                1e-9, turn.response_tokens * cfg.audio_per_token_s)
            cut_s = max(cfg.audio_per_token_s,
                        min(frac, 0.9) * n_tokens * cfg.audio_per_token_s)
        play_end = clock.now()           # client-side playback estimate
        deadline = None                  # barge-in instant (post-TTFP)
        done = False                     # server closed the turn
        barged = False
        while True:
            timeout = None
            if deadline is not None and not barged:
                timeout = max(0.0, clock.real_s(deadline - clock.now()))
            try:
                if timeout is None:
                    ev = await handle.recv()
                else:
                    ev = await asyncio.wait_for(handle.recv(), timeout)
            except asyncio.TimeoutError:
                # the trace's barge point: interrupt playback. The next
                # utterance starts now, so its expected duration rides
                # along for the preloader's admission window.
                barged = True
                await handle.send(BargeIn(
                    sid, expected_dur_s=speech_dur))
                if done:
                    break                # server already closed the turn
                continue
            if isinstance(ev, AudioChunk):
                if deadline is None and cut_s is not None:
                    deadline = clock.now() + cut_s
                play_end = max(play_end, clock.now()) + ev.dur_s
            elif isinstance(ev, TurnDone):
                done = True
                if ev.aborted or barged or deadline is None:
                    break
                if clock.now() >= deadline:
                    # TurnDone raced past the barge deadline: the cut
                    # still happens (mid-playback barge on a completed
                    # turn), it just gets no abort ack
                    barged = True
                    await handle.send(BargeIn(
                        sid, expected_dur_s=speech_dur))
                    break
                # completed, but a barge is still scheduled mid-playback:
                # keep waiting for the deadline
        last = ti == len(turns) - 1
        if duplex and not barged:
            await handle.send(SpeechEnd(sid))   # utterance over with turn
        tool_resume = False
        if turn.tool_call and not barged and not last:
            # the reply ended in a tool invocation: idle with hot KV for
            # the tool's latency, then resume after a short result gap
            await handle.send(ToolCallStart(
                sid, expected_latency_s=turn.tool_latency_s))
            await clock.sleep(turn.tool_latency_s)
            await handle.send(ToolCallResult(
                sid, resume_gap_s=TOOL_RESUME_GAP_S))
            await clock.sleep(TOOL_RESUME_GAP_S)
            tool_resume = True
        elif not barged:
            # listen to the rest of the reply, think, then speak again
            drain = max(0.0, play_end - clock.now())
            await clock.sleep(drain + (0.0 if last else s.think_time_s))
    await handle.send(Hangup(sid))
    while True:                          # drain until the close ack
        ev = await handle.recv()
        if isinstance(ev, SessionClosed):
            return


async def run_load(gateway, cfg: LoadGenConfig) -> None:
    """Replay the workload against the gateway; returns when every
    session has hung up and been acknowledged."""
    sessions = generate(cfg.workload)
    # per-session streams: prompt token draws stay deterministic no
    # matter how the event loop interleaves the session tasks
    tasks = [asyncio.create_task(
        _drive_session(gateway, gateway.clock, s, cfg,
                       np.random.default_rng([cfg.seed, i])))
        for i, s in enumerate(sessions)]
    await asyncio.gather(*tasks)

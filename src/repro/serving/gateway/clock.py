"""Scaled wall clock — real time for the whole serving stack
(DESIGN.md §4).

The control plane (monitor, scheduler, KV manager, preloader) is
clock-agnostic: it reads ``clock.now()``. Under the simulator that is a
virtual clock; under the gateway it is this one — monotonic wall time
multiplied by ``scale`` so a 2.5 s utterance takes 2.5/scale real
seconds while every policy still sees paper-scale durations (playback
drains at 1 clock-second per clock-second by construction).

``tick(dt)`` keeps the engines' modelled-cost contract: synchronous
paths charge modelled time (e.g. the on-path KV reload residual from the
TransferChannel) by advancing a constant offset — time the data plane
did not physically spend but the policy plane must account for. Real
compute (prefill/decode steps) advances the clock by actually taking
wall time, so the engine's default per-round ``tick()`` is a no-op here.
"""
from __future__ import annotations

import asyncio
import time


class ScaledWallClock:
    def __init__(self, scale: float = 1.0):
        assert scale > 0.0
        self.scale = scale
        self._t0 = time.perf_counter()
        self._offset = 0.0

    def now(self) -> float:
        """Scaled seconds since construction, plus modelled-cost offset."""
        return (time.perf_counter() - self._t0) * self.scale + self._offset

    def tick(self, dt: float = 0.0) -> None:
        """Charge ``dt`` scaled seconds of modelled (non-physical) cost.
        The engines call ``tick()`` once per round purely to advance
        step clocks; under wall time that is free, hence default 0."""
        self._offset += dt

    async def sleep(self, dt_s: float) -> None:
        """Sleep ``dt_s`` *scaled* seconds (dt_s / scale real seconds)."""
        if dt_s > 0:
            await asyncio.sleep(dt_s / self.scale)

    def real_s(self, dt_s: float) -> float:
        """Convert a scaled-clock duration to real seconds."""
        return dt_s / self.scale

    def restart(self) -> None:
        """Rewind to t=0 — called once after engine warm-up so the jit
        compile's wall time doesn't pollute serving metrics."""
        self._t0 = time.perf_counter()
        self._offset = 0.0

"""One-call end-to-end gateway runner (DESIGN.md §4).

Shared by ``launch/serve.py --engine live``, ``benchmarks/
gateway_bench.py``, the examples, and the integration tests: build a
laptop-scale model + ``PagedRealtimeEngine`` on a ``ScaledWallClock``,
put a ``RealtimeGateway`` with the requested policy in front of it, and
replay a ``serving/workload.py`` trace through in-process clients.
Returns the same ``Metrics`` object the simulator produces, so
sim-vs-real comparisons are a dict-diff away.
"""
from __future__ import annotations

import asyncio
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.scheduler import SchedulerConfig
from repro.serving.gateway.client import LoadGenConfig, run_load
from repro.serving.gateway.clock import ScaledWallClock
from repro.serving.gateway.gateway import GatewayConfig, RealtimeGateway
from repro.serving.metrics import Metrics
from repro.serving.workload import WorkloadConfig


def tiny_model(seed: int = 0, vocab: int = 331) -> Tuple[object, dict]:
    """The CPU-runnable reduced config the live data plane serves."""
    from repro.configs import get_config, reduced
    from repro.models import init_params
    cfg = reduced(get_config("qwen2-1.5b"), layers=2, d_model=64,
                  vocab=vocab)
    return cfg, init_params(cfg, jax.random.PRNGKey(seed))


def _warm_engine(eng, prefill_chunk: int = 1) -> None:
    """Compile the fixed-shape paged step before the clock starts: a
    padded all-scratch round exercises the exact signature every serving
    round uses, so multi-second jit time never lands in TTFP. On the
    fused plane this warms every query-axis bucket up to the gateway's
    prefill chunk (the fused step compiles one executable per power-of-
    two bucket — DESIGN.md §11)."""
    from repro.serving.paged_engine import _q_bucket
    B = eng.slots
    scratch = np.full((B,), eng.scratch_page, np.int32)
    if not eng.fused_step:
        out = eng._step_fn(
            eng.params, jnp.zeros((B,), jnp.int32),
            jnp.zeros((B,), jnp.int32), eng.k_pages, eng.v_pages,
            jnp.full((B, eng.pages_per_seq), eng.scratch_page, jnp.int32),
            jnp.ones((B,), jnp.int32), jnp.asarray(scratch),
            jnp.zeros((B,), jnp.int32))
        jax.block_until_ready(out[0])        # scratch-page writes only
        return
    # a spec engine runs _spec_fn on EVERY fused round, so that is the
    # executable to warm; drafts also raise the largest decode row to
    # 1 + spec_decode tokens, so warm that bucket too
    fn = eng._spec_fn if getattr(eng, "_spec_fn", None) is not None \
        else eng._fused_fn
    top = max(prefill_chunk, 1 + getattr(eng, "spec_decode", 0))
    q = 1
    while True:
        out = fn(
            eng.params, jnp.zeros((B, q), jnp.int32),
            jnp.zeros((B, q), jnp.int32), eng.k_pages, eng.v_pages,
            jnp.full((B, eng.pages_per_seq), eng.scratch_page, jnp.int32),
            jnp.zeros((B,), jnp.int32), jnp.zeros((B,), jnp.int32),
            jnp.full((B, q), eng.scratch_page, jnp.int32),
            jnp.tile(jnp.arange(q, dtype=jnp.int32) % eng.page_size,
                     (B, 1)))
        jax.block_until_ready(out[0])        # scratch-page writes only
        if q >= _q_bucket(top):
            break
        q *= 2


def build_gateway(*, policy: str = "liveserve", scale: float = 8.0,
                  slots: int = 8, page_size: int = 8,
                  pages_per_seq: int = 8, num_pages: Optional[int] = None,
                  audio_per_token_s: float = 0.25,
                  round_token_budget: int = 16, prefill_chunk: int = 16,
                  frontier_cap_s: Optional[float] = None,
                  sched_cfg: Optional[SchedulerConfig] = None,
                  model: Optional[tuple] = None,
                  mesh=None, seed: int = 0,
                  preload_chunks: int = 1,
                  fused_step: bool = True,
                  prefix_cache: bool = False,
                  kv_quant: str = "fp32",
                  spec_decode: int = 0,
                  proposer=None,
                  autotune: Optional[str] = None) -> RealtimeGateway:
    """``mesh``: a ('data','model') jax mesh shards the engine's page
    store over 'model' (DESIGN.md §9) — on a laptop run under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` for a
    virtual host-platform mesh; everything above the engine is
    mesh-agnostic. ``preload_chunks``: transfer chunks each round may
    drain between decode sub-batches (the serve flag of the same name;
    DESIGN.md §10). ``fused_step=False`` serves on the per-token
    differential-control plane (one launch per token — DESIGN.md §11).
    ``spec_decode=K`` drafts up to K tokens per decode slot per round
    and verifies them in the same fused launch (DESIGN.md §16);
    ``autotune`` names a kernel-config cache JSON to consult at jit
    time (``repro.kernels.autotune``)."""
    from repro.serving.paged_engine import PagedRealtimeEngine
    if autotune:
        from repro.kernels import autotune as at
        at.enable(autotune)
    cfg, params = model if model is not None else tiny_model(seed)
    clock = ScaledWallClock(scale)
    eng = PagedRealtimeEngine(cfg, params, slots=slots,
                              page_size=page_size,
                              pages_per_seq=pages_per_seq,
                              num_pages=num_pages, clock=clock,
                              mesh=mesh,
                              transfer_chunks_per_round=preload_chunks,
                              fused_step=fused_step,
                              prefix_cache=prefix_cache,
                              kv_quant=kv_quant,
                              spec_decode=spec_decode,
                              proposer=proposer)
    _warm_engine(eng, min(prefill_chunk, round_token_budget))
    gw = RealtimeGateway(eng, cfg=GatewayConfig(
        policy=policy, audio_per_token_s=audio_per_token_s,
        round_token_budget=round_token_budget,
        prefill_chunk=prefill_chunk, frontier_cap_s=frontier_cap_s,
        sched=sched_cfg))
    return gw


def run_gateway_workload(*, policy: str = "liveserve",
                         kind: str = "interactive", sessions: int = 8,
                         barge_in: float = 0.0, seed: int = 0,
                         arrival: str = "poisson", rate_rps: float = 2.0,
                         scale: float = 8.0, max_turns: int = 2,
                         max_prompt: int = 16, max_response: int = 12,
                         speech_scale: float = 1.0,
                         prompt_families: int = 0,
                         family_prefix_len: int = 0,
                         gateway: Optional[RealtimeGateway] = None,
                         timeout_s: Optional[float] = None,
                         **gw_kw) -> Tuple[Metrics, RealtimeGateway]:
    """Replay an open-loop workload through a gateway; returns
    (metrics, gateway). Pass ``gateway`` to use a pre-built (and
    pre-compiled, but not yet run) stack; otherwise ``gw_kw`` goes to
    ``build_gateway``. A gateway serves exactly one workload — its
    session registry and metrics are single-run state.
    """
    if gateway is not None:
        assert not gw_kw, "gateway already built; engine kwargs ignored"
        assert gateway.cfg.policy == policy, \
            f"gateway was built for {gateway.cfg.policy!r}, not {policy!r}"
        assert not gateway._stopping and not gateway._sessions, \
            "a RealtimeGateway serves one workload; build a fresh one"
        gw = gateway
    else:
        gw = build_gateway(policy=policy, scale=scale, seed=seed,
                           **gw_kw)
    wl = WorkloadConfig(kind=kind, num_sessions=sessions, seed=seed,
                        p_barge_in=barge_in, arrival=arrival,
                        rate_rps=rate_rps,
                        prompt_families=prompt_families,
                        family_prefix_len=family_prefix_len)
    lcfg = LoadGenConfig(workload=wl, vocab=gw.engine.cfg.vocab_size,
                         max_prompt=max_prompt, max_response=max_response,
                         max_turns=max_turns,
                         audio_per_token_s=gw.cfg.audio_per_token_s,
                         speech_scale=speech_scale, seed=seed)

    async def main():
        gw.clock.restart()
        serve = asyncio.create_task(gw.run())
        load = asyncio.create_task(run_load(gw, lcfg))
        try:
            done, _ = await asyncio.wait(
                {serve, load}, timeout=timeout_s,
                return_when=asyncio.FIRST_COMPLETED)
            if serve in done and load not in done:
                # the serve loop died under live clients: surface its
                # error instead of letting every client block forever
                serve.result()
                raise RuntimeError("gateway serve loop exited early")
            if load not in done:
                raise asyncio.TimeoutError(
                    f"load generator exceeded {timeout_s}s")
            load.result()                # propagate client errors
        except BaseException:
            gw.stop(force=True)
            load.cancel()
            await asyncio.gather(serve, load, return_exceptions=True)
            raise
        gw.stop()
        await serve                      # surface late serve errors

    asyncio.run(main())
    return gw.metrics(), gw

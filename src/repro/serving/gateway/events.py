"""Typed duplex event protocol between in-process clients and the
realtime gateway (DESIGN.md §4).

Client -> gateway (interaction signals, paper §3):
  UserAudio    raw mic audio reaching the gateway (metadata only here —
               duration, not samples; keeps the VAD/playback bookkeeping
               honest without shipping waveforms through the test rig)
  SpeechStart  VAD speech onset; fires the §5.2 speech-time KV preload
  SpeechEnd    utterance complete (ASR/encode follows)
  TurnRequest  the encoded utterance reaches the LLM stage: token
               prompt + response budget. Admission from here on is the
               scheduler's call, not the transport's.
  BargeIn      user interrupts playback: abort the in-flight turn
  ToolCallStart   the turn's reply ended in a tool invocation: the
               session idles with hot KV while the external tool runs
               (KV gains tool-pause protection with its own TTL; Eq. 4
               next-use becomes the tool's expected return)
  ToolCallResult  the tool returned; the resume turn follows in
               ``resume_gap_s`` — an evicted session's reload hides in
               that gap (resume-without-reprefill)
  HandoffRequest  transfer the session's committed context to a
               different model config/replica (rides the fleet MIGRATE
               machinery; single-replica gateways acknowledge and stay)
  Hangup       session over; KV pages are released

Gateway -> client:
  AudioChunk     one playable fragment (one decode token's worth of
                 speech); the client's playback clock consumes these
  TurnDone       the turn finished (or was barge-in aborted) server-side
  SessionClosed  gateway confirmed the hangup

Events carry the *session-local* wall-clock timestamp ``t`` stamped by
whoever created them; the gateway re-stamps arrival against its own
scaled clock, so clients cannot skew serving-side metrics.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np


@dataclass
class SessionEvent:
    session_id: str
    t: float = 0.0                  # sender-side scaled-clock timestamp


# --------------------------------------------------- client -> gateway
@dataclass
class UserAudio(SessionEvent):
    dur_s: float = 0.0


@dataclass
class SpeechStart(SessionEvent):
    expected_dur_s: Optional[float] = None


@dataclass
class SpeechEnd(SessionEvent):
    pass


@dataclass
class TurnRequest(SessionEvent):
    prompt: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int32))
    max_new_tokens: int = 0
    # full duplex: > 0 arms a hard per-frame output deadline of this
    # many (serving-clock) seconds per token
    frame_period_s: float = 0.0
    # this request resumes a tool-call pause (telemetry: its reload
    # split is the resume-without-reprefill cost)
    tool_resume: bool = False


@dataclass
class BargeIn(SessionEvent):
    expected_dur_s: Optional[float] = None


@dataclass
class ToolCallStart(SessionEvent):
    expected_latency_s: float = 0.0


@dataclass
class ToolCallResult(SessionEvent):
    resume_gap_s: float = 0.0


@dataclass
class HandoffRequest(SessionEvent):
    target: int = 0                 # requested model config / replica


@dataclass
class Hangup(SessionEvent):
    pass


# --------------------------------------------------- gateway -> client
@dataclass
class AudioChunk(SessionEvent):
    turn_index: int = 0
    dur_s: float = 0.0
    token: int = -1


@dataclass
class TurnDone(SessionEvent):
    turn_index: int = 0
    aborted: bool = False
    generated: int = 0


@dataclass
class SessionClosed(SessionEvent):
    pass

"""Paged multi-turn realtime engine — the LiveServe data plane on real
paged JAX state (DESIGN.md §3).

Where ``RealtimeLLMEngine`` keeps a dense per-slot ring cache and lives
for one turn, this engine runs the paper's full KV story on physical
pages:

- KV lives in a ``PagedPool``-managed page store ([L, P+1, page, Hkv, hd]
  per K and V; physical page P is a scratch page for padded batch rows).
  Decode attends through the Pallas ``paged_attention`` kernel via
  per-round block tables; prefill writes pages through the pool.
- Sessions are **multi-turn**: when a turn ends (or is barged-in via
  ``abort``), committed pages stay owned by the session. ``KVManager``
  eviction decisions *physically* offload suffix pages to the pool's
  host-numpy DRAM tier (bit-exact round-trip), and the
  ``SpeechPreloader`` reloads them during user speech so the next turn
  resumes with warm KV and zero re-prefill tokens.
- The control plane decides; the engine executes. Two driving modes
  share one data path: ``step()`` lets the engine's own
  ``UrgencyScheduler`` pick the round (scripted demos), while the
  realtime gateway (DESIGN.md §4) calls ``submit_turn``/``run_round``
  with *its* scheduler's decision — per-round candidate set, per-slot
  chunk budgets, chunked paged prefill interleaved with decode. Either
  way scheduling affects *when* tokens appear, never *which* (the §5.2
  correctness contract, shared with the dense engine and verified in
  tests/test_paged_engine.py and tests/test_gateway.py).

The decode batch is a fixed ``slots``-row batch (one compiled step for
the whole run): unscheduled/empty rows are padded onto the scratch page,
so — unlike the dense engine — holding a slot needs no cache-length
rewind; nothing the padded row writes is ever addressed again.

Families: global-attention stacks (dense / moe / vlm; no MLA, no sliding
window) — pages hold full-context KV, which is what the LiveServe
offload hierarchy manages.
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kv_manager import KVManager
from repro.core.monitor import RuntimeMonitor
from repro.core.preload import SpeechPreloader
from repro.core.scheduler import SchedulerConfig, UrgencyScheduler
from repro.core.session import Phase, Request, RequestState
from repro.core.transfer_engine import MIGRATE, TransferEngine
from repro.kernels.paged_attention import paged_attention, \
    paged_prefill_attention
from repro.kvcache.paged import OutOfPages, PagedPool
from repro.kvcache.quant import KVWireCodec
from repro.models import init_cache, prefill
from repro.models import layers as L
from repro.models.model import _embed, _logits, _mlp_block
from repro.serving.block_tables import BatchTables, FusedBatchTables, \
    LayerStackedPages, assemble, assemble_fused
from repro.serving.engine import RoundLimitExceeded, _StepClock, \
    schedule_round


# ======================================================================
# jitted data plane
# ======================================================================
def paged_decode_step(cfg, params, tokens, positions, k_pages, v_pages,
                      block_tables, seq_lens, write_page, write_slot,
                      *, interpret: bool = False, plane=None):
    """One token per batch row through the paged KV store.

    tokens/positions/write_page/write_slot [B] i32;
    k_pages/v_pages [L, P+1, page, Hkv, hd]; block_tables [B, pps] i32;
    seq_lens [B] i32 (post-write attention lengths).
    Returns (logits [B, V], k_pages, v_pages).

    ``plane`` swaps the page write + attention strategy: None is the
    single-device path; a ``distributed.paged.PagedKVLayout`` makes this
    the per-shard body of a shard_map over the 'model' axis (local page
    shards, replicated everything else — DESIGN.md §9). Same code path
    either way, so sharded and unsharded engines cannot drift.
    """
    x = _embed(cfg, params, tokens[:, None])
    pos = positions[:, None]                            # [B, 1]

    def body(carry, xs):
        lp, kc, vc = xs
        h = L.rms_norm(carry, lp["ln1"], cfg.rms_eps)
        q, k, v = L.attn_project_qkv(lp["attn"], cfg, h, pos)
        if plane is None:
            kc = kc.at[write_page, write_slot].set(k[:, 0])
            vc = vc.at[write_page, write_slot].set(v[:, 0])
            a = paged_attention(q[:, 0], kc, vc, block_tables, seq_lens,
                                interpret=interpret)
        else:
            kc, vc = plane.write_token(kc, vc, k[:, 0], v[:, 0],
                                       write_page, write_slot)
            a = plane.attend(q[:, 0], kc, vc, block_tables, seq_lens,
                             interpret=interpret)
        h = carry + L.attn_output(lp["attn"], a[:, None])
        h, _ = _mlp_block(cfg, lp, h, None)
        return h, (kc, vc)

    npre = len(params.get("layers_pre", []))
    for i, lp in enumerate(params.get("layers_pre", [])):
        x, (kc, vc) = body(x, (lp, k_pages[i], v_pages[i]))
        k_pages = k_pages.at[i].set(kc)
        v_pages = v_pages.at[i].set(vc)
    x, (kcs, vcs) = jax.lax.scan(
        body, x, (params["layers"], k_pages[npre:], v_pages[npre:]))
    k_pages = jnp.concatenate([k_pages[:npre], kcs]) if npre else kcs
    v_pages = jnp.concatenate([v_pages[:npre], vcs]) if npre else vcs
    return _logits(cfg, params, x)[:, 0], k_pages, v_pages


def paged_fused_step(cfg, params, tokens, positions, k_pages, v_pages,
                     block_tables, q_start, q_lens, write_pages,
                     write_slots, *, interpret: bool = False, plane=None,
                     spec: bool = False):
    """One fused round: up to Q consecutive tokens per batch row through
    the paged KV store in a single launch (DESIGN.md §11).

    tokens/positions/write_pages/write_slots [B, Q] i32;
    q_start/q_lens [B] i32 (first absolute position / valid tokens per
    row — 0 marks a padding row); k_pages/v_pages [L, P+1, page, Hkv,
    hd]; block_tables [B, pps] i32. Returns (logits [B, V] of each
    row's *last valid* token, k_pages, v_pages); with ``spec`` (the
    speculative verify variant, DESIGN.md §16) the result is (logits,
    outs [B, Q] i32, k_pages, v_pages) where ``outs[b, t]`` is the
    argmax after position t — fed tokens are ``[pending, d_1..d_m]``,
    so ``outs[b, j] == tokens[b, j+1]`` accepts draft j+1, and the
    committed stream stays exactly the greedy one.

    Per layer the whole chunk's K/V is scattered into the pages first,
    then every query token attends causally over history + chunk prefix
    via ``paged_prefill_attention`` — so a PREFILL slot's C-token grant
    and every DECODE slot's single token share one compiled step.
    ``plane`` swaps the write/attend strategy exactly as in
    ``paged_decode_step`` (None = single device; a ``PagedKVLayout``
    makes this the per-shard body of a shard_map — same code path, so
    sharded and unsharded engines cannot drift).
    """
    x = _embed(cfg, params, tokens)                     # [B, Q, d]

    def body(carry, xs):
        lp, kc, vc = xs
        h = L.rms_norm(carry, lp["ln1"], cfg.rms_eps)
        q, k, v = L.attn_project_qkv(lp["attn"], cfg, h, positions)
        if plane is None:
            kc = kc.at[write_pages, write_slots].set(k)
            vc = vc.at[write_pages, write_slots].set(v)
            a = paged_prefill_attention(q, kc, vc, block_tables,
                                        q_start, q_lens,
                                        interpret=interpret)
        else:
            kc, vc = plane.write_chunk(kc, vc, k, v, write_pages,
                                       write_slots)
            a = plane.attend_chunk(q, kc, vc, block_tables, q_start,
                                   q_lens, interpret=interpret)
        h = carry + L.attn_output(lp["attn"], a)
        h, _ = _mlp_block(cfg, lp, h, None)
        return h, (kc, vc)

    npre = len(params.get("layers_pre", []))
    for i, lp in enumerate(params.get("layers_pre", [])):
        x, (kc, vc) = body(x, (lp, k_pages[i], v_pages[i]))
        k_pages = k_pages.at[i].set(kc)
        v_pages = v_pages.at[i].set(vc)
    x, (kcs, vcs) = jax.lax.scan(
        body, x, (params["layers"], k_pages[npre:], v_pages[npre:]))
    k_pages = jnp.concatenate([k_pages[:npre], kcs]) if npre else kcs
    v_pages = jnp.concatenate([v_pages[:npre], vcs]) if npre else vcs
    last = jnp.maximum(q_lens - 1, 0)
    if spec:
        # the verify step consumes every position's argmax, so the full
        # [B, Q, V] logits materialize here; per-position unembeds are
        # independent dot products, so the last-valid slice is the same
        # values the non-spec step computes (the bit-exactness seam)
        full = _logits(cfg, params, x)                  # [B, Q, V]
        outs = jnp.argmax(full, axis=-1).astype(jnp.int32)
        logits = jnp.take_along_axis(
            full, last[:, None, None], axis=1)[:, 0]
        return logits, outs, k_pages, v_pages
    # only each row's last valid token's logits are consumed (the next
    # decode token / first output token); slice before the unembed so
    # the launch never materializes [B, Q, V]
    xl = jnp.take_along_axis(x, last[:, None, None], axis=1)
    return _logits(cfg, params, xl)[:, 0], k_pages, v_pages


# one jitted step per (config, interpret, mesh layout) shared across
# engine instances — a policy-comparison harness (gateway liveserve vs
# fcfs on the same model) pays the XLA compile once, not per engine.
# Values retain cfg so the id() key can never be recycled; the cache is
# LRU-bounded so a long-lived process churning through configs doesn't
# pin every compiled executable forever (engines keep their own _step_fn
# reference, so eviction only forfeits future sharing).
_STEP_FN_CACHE: Dict[tuple, tuple] = {}
_STEP_FN_CACHE_MAX = 8


def _jitted_step(cfg, interpret: bool, layout=None, *,
                 fused: bool = False, spec: bool = False):
    assert fused or not spec, "spec is a fused-plane variant"
    lkey = None if layout is None else (layout.mesh, layout.kind,
                                        layout.page_size)
    key = (id(cfg), interpret, lkey, fused, spec)
    hit = _STEP_FN_CACHE.pop(key, None)
    if hit is None:
        if layout is None:
            body = paged_fused_step if fused else paged_decode_step
            if spec:
                body = functools.partial(body, spec=True)
            fn = jax.jit(functools.partial(body, cfg,
                                           interpret=interpret))
        elif spec:
            from repro.distributed.paged import make_sharded_spec_step
            fn = make_sharded_spec_step(cfg, layout, interpret=interpret)
        elif fused:
            from repro.distributed.paged import make_sharded_fused_step
            fn = make_sharded_fused_step(cfg, layout, interpret=interpret)
        else:
            from repro.distributed.paged import make_sharded_step
            fn = make_sharded_step(cfg, layout, interpret=interpret)
        hit = (cfg, fn)
    _STEP_FN_CACHE[key] = hit                  # re-insert: LRU order
    while len(_STEP_FN_CACHE) > _STEP_FN_CACHE_MAX:
        _STEP_FN_CACHE.pop(next(iter(_STEP_FN_CACHE)))
    return hit[1]


def _q_bucket(n: int) -> int:
    """Round a round's query-axis width up to a power of two so the
    fused step compiles O(log max_chunk) executables, not one per
    distinct grant size."""
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


# ======================================================================
# host-side session state
# ======================================================================
@dataclass
class PagedSlot:
    """A live decode slot (one in-flight turn)."""
    session_id: str
    request: Request
    pending_token: int              # next token to feed
    tokens: List[int] = field(default_factory=list)
    # prompt tokens still to be teacher-forced (scheduler-driven chunked
    # prefill via submit_turn/run_round; None on the synchronous paths)
    prompt: Optional[np.ndarray] = None


@dataclass
class PagedSession:
    """Survives across turns: the multi-turn identity that owns pages."""
    session_id: str
    kv_len: int = 0                 # tokens whose KV is written
    base_pages: int = 0             # pages owned when current turn began
    turn_index: int = 0
    turn_arrival: float = 0.0
    reload_stall_s: float = 0.0     # on-path stall charged to this turn
    reload_off_path_s: float = 0.0  # reload seconds hidden off-path
    ended: bool = False             # user hung up; pages released
    history: List[List[int]] = field(default_factory=list)
    turn_stats: List[dict] = field(default_factory=list)
    # the committed token-id history (len == kv_len): the radix prefix
    # cache keys on it, and it migrates with the session
    token_ids: List[int] = field(default_factory=list)


class PagedRealtimeEngine:
    def __init__(self, cfg, params, *, slots: int = 4, page_size: int = 16,
                 pages_per_seq: int = 16, num_pages: Optional[int] = None,
                 clock=None, scheduler: Optional[UrgencyScheduler] = None,
                 kv: Optional[KVManager] = None, kv_policy: str = "next_use",
                 pcie_gb_s: float = 25.0, preload: bool = True,
                 interpret: Optional[bool] = None, mesh=None,
                 async_transfers: bool = True,
                 chunk_pages: Optional[int] = None,
                 transfer_chunks_per_round: int = 1,
                 fused_step: bool = True,
                 prefix_cache: bool = False,
                 kv_quant: str = "fp32",
                 spec_decode: int = 0,
                 proposer=None):
        assert cfg.family in ("dense", "moe", "vlm") and cfg.mla is None \
            and cfg.sliding_window is None, \
            "paged engine serves global-attention KV families"
        assert kv_policy in ("next_use", "lru"), \
            "the physical data plane needs an offload tier ('none' " \
            "discards pages; use the simulator for that baseline)"
        self.cfg = cfg
        self.slots = slots
        self.page_size = page_size
        self.pages_per_seq = pages_per_seq
        self.max_context = pages_per_seq * page_size
        self.num_pages = num_pages or 2 * slots * pages_per_seq
        self.scratch_page = self.num_pages     # physical page beyond pool
        self.clock = clock or _StepClock()
        self.monitor = RuntimeMonitor(self.clock)
        # KV wire format (DESIGN.md §14): int8 block-quantizes every host
        # copy on the offload path; fp32 is the bit-exact control.
        self.kv_quant = kv_quant
        self.codec = KVWireCodec(kv_quant)
        self.pool = PagedPool(self.num_pages, page_size, codec=self.codec)

        # tensor-sharded page store (DESIGN.md §9): pages shard KV heads
        # (or page slots) over the mesh's 'model' axis; weights, block
        # tables, and the decode batch stay replicated, so every host-
        # side policy/pool path below is mesh-agnostic.
        self.mesh = mesh
        self.layout = None
        if mesh is not None:
            from repro.distributed.paged import PagedKVLayout
            self.layout = PagedKVLayout(cfg, mesh, page_size)
            params = jax.device_put(params, self.layout.replicated)
        self.params = params

        hd = cfg.resolved_head_dim
        dtype = jnp.dtype(cfg.dtype)
        shape = (cfg.num_layers, self.num_pages + 1, page_size,
                 cfg.num_kv_heads, hd)
        self.k_pages = jnp.zeros(shape, dtype)
        self.v_pages = jnp.zeros(shape, dtype)
        self._place_pages()
        bytes_per_token = 2 * cfg.num_layers * cfg.num_kv_heads * hd \
            * dtype.itemsize
        self.kv = kv or KVManager(
            capacity_blocks=self.num_pages, block_size=page_size,
            bytes_per_token=float(bytes_per_token), monitor=self.monitor,
            policy=kv_policy, pcie_gb_s=pcie_gb_s, clock=self.clock)
        assert self.kv.capacity == self.num_pages \
            and self.kv.block_size == page_size, \
            "KVManager accounting must be 1:1 with pool pages"
        # price the wire format into the modeled PCIe channel before the
        # transfer engine sizes its chunks off transfer_time(1): every
        # consumer (chunk sizing, preload admission, stall settlement,
        # migration) then sees compressed bytes. block_bytes stays the
        # logical page size for capacity accounting.
        self.kv.channel.wire_scale = self.codec.wire_scale(dtype)
        # the async chunked transfer engine (DESIGN.md §10): DRAM<->HBM
        # movement queues as page-group chunks drained by run_round (and
        # the gateways' idle loops); async_transfers=False degrades to
        # the synchronous move-at-decision-time plane (the differential
        # control for bit-exactness tests)
        self.async_transfers = async_transfers
        self.transfer_chunks_per_round = transfer_chunks_per_round
        self.transfer = TransferEngine(self.kv.channel,
                                       chunk_pages=chunk_pages)
        self.transfer.set_io(reload_chunk=self._io_reload_chunk,
                             offload_chunk=self._io_offload_chunk)
        self.kv.set_page_hooks(
            on_evict=self._offload_pages, on_reload=self._reload_pages,
            on_cancel_reload=self._cancel_reload_pages,
            on_finish_transfers=(self._finish_transfers
                                 if async_transfers else None),
            pending_offload=self.transfer.pending_offload_pages)
        self.preloader = SpeechPreloader(self.kv, self.monitor,
                                         enabled=preload)
        # speculative multi-token decode (DESIGN.md §16): a decode slot
        # feeds [pending, d_1..d_K] drafts as one fused row and the
        # verify launch's per-position argmax accepts the longest
        # matching prefix — lossless by construction (the committed
        # stream is exactly the greedy one; spec_decode=0 keeps today's
        # one-token plane as the bit-exact differential control, the
        # async_transfers=False pattern).
        assert spec_decode >= 0
        assert spec_decode == 0 or fused_step, \
            "spec_decode verifies drafts in one fused launch; it " \
            "cannot run on the per-token control plane " \
            "(fused_step=False)"
        self.spec_decode = int(spec_decode)
        self.proposer = None
        if self.spec_decode > 0:
            from repro.serving.spec_decode import build_proposer
            self.proposer = build_proposer(
                proposer if proposer is not None else "ngram")
        # prefill_chunk clamps to the self-scheduled round budget
        # (= slots*(1+K) tokens) exactly as the gateway clamps its own —
        # a bigger chunk could never be admitted (Algorithm 1
        # head-of-line); decode grants become "up to 1+K" draft budgets
        self.scheduler = scheduler or UrgencyScheduler(
            SchedulerConfig(), self.monitor, stage="thinker",
            kv_occupancy=self.kv.occupancy,
            prefill_chunk=max(1, slots),
            decode_chunk=1 + self.spec_decode)

        self.sessions: Dict[str, PagedSession] = {}
        self.slot_state: Dict[int, Optional[PagedSlot]] = {
            i: None for i in range(slots)}
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        self._step_fn = _jitted_step(cfg, interpret, self.layout)
        # the fused token-budget plane (DESIGN.md §11): one launch per
        # round, C-token prefill chunks included. fused_step=False keeps
        # the per-token plane as the differential control (the role
        # async_transfers=False plays for the transfer engine).
        self.fused_step = fused_step
        self._fused_fn = _jitted_step(cfg, interpret, self.layout,
                                      fused=True) if fused_step else None
        # with speculation on, EVERY fused round runs the spec variant
        # (prefill rows simply ignore the per-position argmaxes) so the
        # engine compiles one executable family, not two
        self._spec_fn = _jitted_step(cfg, interpret, self.layout,
                                     fused=True, spec=True) \
            if self.spec_decode > 0 else None
        # shared-prefix KV subsystem (DESIGN.md §13): a radix index over
        # committed pages + refcounted attach/COW in the pool.
        # prefix_cache=False keeps today's private-pages behavior as the
        # bit-exact differential twin (the async_transfers=False /
        # fused_step=False pattern).
        self.prefix_cache = None
        if prefix_cache:
            from repro.kvcache.prefix_cache import PrefixCache
            self.prefix_cache = PrefixCache(page_size)
            self.kv.set_cache_hooks(reclaim=self._reclaim_cached,
                                    reclaimable=self._cached_reclaimable)
        self._pending_hit: Dict[str, int] = {}
        # telemetry
        self.reload_wall_s: List[float] = []   # measured host->device time
        self.offload_events: List[tuple] = []
        self.pressure_holds = 0                # feeds held mid-round
        self.fused_launches = 0                # fused-plane step launches
        self.peak_shared_pages = 0             # max pages with refcount>1
        self.cow_copies = 0                    # copy-on-write page copies
        # speculation accounting (the §16 invariant:
        # accepted + rejected == drafted, always)
        self.spec_drafted = 0                  # draft tokens verified
        self.spec_accepted = 0                 # drafts matching argmax
        self.spec_rejected = 0                 # drafts rolled back
        self.spec_rounds = 0                   # verify rows with drafts
        # quality-gate tap: when set, called as logit_tap(sid, logits)
        # for every fed row (fused rows report last-valid-token logits —
        # the ones the argmax commits)
        self.logit_tap = None

    # ------------------------------------------------------------ pages
    def _place_pages(self) -> None:
        """Re-commit the page store to its mesh sharding. Host-driven
        page updates (DRAM reload scatter, dense-prefill graft) run
        outside the jitted step and may leave the result on inferred
        shardings; the jitted shard_map expects the layout's exact
        placement, so re-place after every such update (a no-op copy
        when the sharding already matches, and always a no-op without a
        mesh)."""
        if self.layout is not None:
            sh = self.layout.page_sharding()
            self.k_pages = jax.device_put(self.k_pages, sh)
            self.v_pages = jax.device_put(self.v_pages, sh)

    def _sync_page_counts(self, sid: str) -> None:
        # read-only bounds: a session released from the pool (hangup) or
        # never admitted must report 0/0, not have `pool.seq` re-create a
        # ghost entry for it (check_invariants iterates pool.seqs)
        s = self.pool.seqs.get(sid)
        # resident = usable on device (offloading pages still count: the
        # copy-then-free slot holds valid contents); offloaded = host
        # copy is authoritative (loading pages still count: contents
        # have not landed yet) — the two partitions sum to committed
        self.monitor.on_page_movement(
            sid, resident=self.pool.resident_pages(sid),
            offloaded=len(s.offloaded) if s else 0)

    def _offload_pages(self, sid: str, blocks: int) -> None:
        """KVManager eviction hook: queue suffix pages for DRAM
        (copy-then-free — slots stay usable until each chunk drains;
        allocation pressure demand-drains via ``_demand_free_pages``).
        Suffix pages whose *reload* is still in flight are cancelled
        instead: freeing them needs no copy, their bytes never left the
        host store (the eviction-of-a-loading-session rule)."""
        cancel_lis, offload_lis = self.pool.evictable_suffix(sid, blocks)
        assert len(cancel_lis) + len(offload_lis) == blocks, \
            f"accounting evicted {blocks} but only " \
            f"{len(cancel_lis) + len(offload_lis)} evictable ({sid})"
        if cancel_lis:
            dropped = self.transfer.cancel_reload_pages(sid, cancel_lis)
            assert dropped == len(cancel_lis), (sid, cancel_lis)
            self.pool.cancel_loading(sid, cancel_lis)
        if offload_lis:
            if self.prefix_cache is not None:
                # about to leave HBM: forget these pages (and their
                # unreachable subtrees) in the radix index first — the
                # never-offload-shared rule is then an assert, not a
                # hope (rc>1 pages were excluded by evictable_suffix)
                seq = self.pool.seq(sid)
                self._forget_cached([seq.pages[li] for li in offload_lis])
            self.pool.mark_offloading(sid, offload_lis)
            self.transfer.submit_offload(sid, offload_lis)
            if not self.async_transfers:
                self.transfer.drain(self.clock.now(),
                                    kinds=("offload",))
        self.offload_events.append((self.clock.now(), sid, blocks))
        self._sync_page_counts(sid)

    def _reload_pages(self, sid: str, blocks: int, *, background: bool,
                      transfer=None) -> None:
        """KVManager reload hook: queue the offloaded pages as chunked
        host->device transfers. In-flight offloads cancel for free
        (copy-then-free); slots for the rest are reserved now (the
        pool's ``loading`` marks), contents land as chunks drain — or
        at turn-start settlement for the on-path remainder."""
        cancelled = self.pool.cancel_offloading(sid)
        if cancelled:
            self.transfer.cancel_offload_pages(sid, cancelled)
        # reserving slots may need room the accounting freed but the
        # copy-then-free plane has not physically drained yet
        s = self.pool.seq(sid)
        need = sum(1 for li in s.offloaded if li not in s.loading)
        self._demand_free_pages(need)
        lis = self.pool.begin_reload(sid)
        assert len(lis) + len(cancelled) == blocks, \
            f"accounting reloaded {blocks} but pool restored " \
            f"{len(lis)} + cancelled {len(cancelled)} ({sid})"
        self.transfer.submit_reload(sid, lis, transfer)
        if not background or not self.async_transfers:
            # synchronous path: settle immediately; the preloader (or
            # direct kv.reload caller) reads the split via the ledger
            self.transfer.finish_session(sid, self.clock.now())
        self._sync_page_counts(sid)

    def _cancel_reload_pages(self, sid: str) -> int:
        """KVManager burst-cancel hook: drop the session's queued
        reload chunks, free their reserved slots (host copies stay
        authoritative). Returns pages cancelled."""
        dropped = self.transfer.cancel_reload_pages(sid)
        if dropped:
            lis = sorted(self.pool.seq(sid).loading)
            assert len(lis) == dropped, (sid, lis, dropped)
            self.pool.cancel_loading(sid, lis)
            self._sync_page_counts(sid)
        return dropped

    def _finish_transfers(self, sid: str, now: float):
        """KVManager settlement hook (turn start): complete the
        session's queued reload chunks; (on_path_s, off_path_s)."""
        self.transfer.finish_session(sid, now)
        return self.transfer.pop_split(sid)

    # ------------------------------------------------------ transfer io
    def _io_reload_chunk(self, sid: str, lis: List[int]) -> None:
        """Physically land one reload chunk. The host stack is staged
        to the device and *only that buffer* is blocked on for the
        wall-time measurement — blocking on the whole page store would
        over-synchronize unrelated decode work (ISSUE 4 satellite)."""
        s = self.pool.seq(sid)
        host = np.stack([self.codec.decode(s.offloaded[li]) for li in lis])
        t0 = time.perf_counter()
        if self.layout is not None:
            staged = self.layout.stage_host_chunk(host)
        else:
            staged = jnp.asarray(host)
        jax.block_until_ready(staged)
        self.reload_wall_s.append(time.perf_counter() - t0)
        store = self.pool.complete_reload(
            sid, lis, LayerStackedPages(self.k_pages, self.v_pages),
            staged=staged)
        self.k_pages, self.v_pages = store.k, store.v
        self._place_pages()
        self._sync_page_counts(sid)

    def _io_offload_chunk(self, sid: str, lis: List[int]) -> None:
        """Physically land one offload chunk: gather the device pages
        to host copies, then free the slots (copy-then-free step 2)."""
        s = self.pool.seq(sid)
        phys = np.asarray([s.pages[li] for li in lis], np.int64)
        hk = np.asarray(self.k_pages[:, phys])     # [L, n, page, Hkv, hd]
        hv = np.asarray(self.v_pages[:, phys])
        self.pool.complete_offload(
            sid, {li: self.codec.encode(np.stack([hk[:, i], hv[:, i]]))
                  for i, li in enumerate(lis)})
        self._sync_page_counts(sid)

    def drain_transfers(self, max_chunks: Optional[int] = None) -> int:
        """Complete up to ``max_chunks`` queued transfer chunks (both
        directions, FIFO). run_round calls this with the per-round
        budget; the gateways call it from their idle loops so preloads
        progress even when nothing is decoding."""
        return self.transfer.drain(self.clock.now(), max_chunks)

    def flush_transfers(self) -> int:
        """Drain everything (tests / shutdown)."""
        return self.transfer.drain(self.clock.now(), None)

    def _demand_free_pages(self, need: int) -> None:
        """Allocation needs physical slots the accounting already freed:
        complete queued offload chunks until the pool can satisfy it."""
        self.transfer.drain_offloads_until(
            self.clock.now(), lambda: self.pool.free_pages >= need)

    # ---------------------------------------------------- shared prefix
    # (DESIGN.md §13.) The radix cache holds NON-refcount references:
    # registering marks pages `cache_held` in the pool without touching
    # refcounts, so `sum(refcounts) == live block-table references`
    # stays the conservation invariant. Charging: every allocated page
    # bills exactly one accountant — its owner session (kv.hbm_blocks)
    # or, once the owner released/COW'd it away, the prefix cache
    # (kv.cached_blocks, pool.page_owner[p] is None).

    def _refresh_shared_pins(self) -> None:
        """Recompute every session's shared-pinned block count (own
        resident pages some other session references — never
        offloadable) after any refcount 1<->2+ transition. Sessions per
        engine are few; recomputing all of them keeps every call site
        trivially correct."""
        if self.prefix_cache is None:
            return
        for sid, kvs in self.kv.sessions.items():
            kvs.shared_pinned_blocks = self.pool.shared_charged_pages(sid)
        self.peak_shared_pages = max(self.peak_shared_pages,
                                     self.pool.shared_pages())

    def _attach_prefix(self, sess: PagedSession,
                       prompt: np.ndarray) -> np.ndarray:
        """Session birth: walk the radix index for the prompt's longest
        cached prefix and attach to it — the block table points at the
        shared pages, kv_len skips ahead, and prefill starts at the
        first uncached token (the fused kernel's per-row q_start
        renders from any offset; no kernel math changes). Returns the
        remaining (uncached) prompt."""
        if self.prefix_cache is None or sess.kv_len > 0:
            return prompt
        sid = sess.session_id
        matched, phys = self.prefix_cache.lookup(prompt)
        # the last prompt token always prefills: its logits are the
        # turn's first output token
        matched = min(matched, int(prompt.shape[0]) - 1)
        if matched <= 0:
            return prompt
        n_phys = self.pool.pages_for(matched)
        self.pool.attach_prefix(sid, phys[:n_phys], matched)
        sess.kv_len = matched
        sess.token_ids = [int(t) for t in prompt[:matched]]
        kvs = self.kv.session(sid)
        kvs.total_blocks = n_phys
        kvs.shared_blocks = n_phys      # charged to owners / the cache
        kvs.hbm_blocks = 0
        self._pending_hit[sid] = matched
        self.prefix_cache.hits += 1
        self.prefix_cache.hit_tokens += matched
        self._refresh_shared_pins()
        self._sync_page_counts(sid)
        return prompt[matched:]

    def _ensure_writable(self, sid: str) -> None:
        """Copy-on-write before a write lands: the next token's target
        page may be shared (an attached partial tail, or this session's
        own committed tail another session attached to). Allocate a
        private copy, repoint, copy the bytes. Only the FIRST page of a
        write region can be shared — everything past it is freshly
        allocated. Raises OutOfPages recoverably (same contract as
        _grow)."""
        if self.prefix_cache is None:
            return
        sess = self.sessions[sid]
        s = self.pool.seqs.get(sid)
        if s is None:
            return
        li = sess.kv_len // self.page_size
        if li >= len(s.pages):
            return
        phys = s.pages[li]
        if phys < 0 or self.pool.refcount[phys] <= 1:
            return
        now = self.clock.now()
        if not self.kv.try_allocate_working(1, now):
            raise OutOfPages(f"{sid}: no page free for copy-on-write")
        self._demand_free_pages(1)
        old, new, was_owner = self.pool.cow(sid, li)
        self.kv.release_working(1)
        kvs = self.kv.session(sid)
        if was_owner:
            # the old page stays for its sharers, now charged to the
            # cache; our new private copy replaces it 1:1 in hbm
            self.kv.cached_blocks += 1
        else:
            # an attached page became a private one
            kvs.shared_blocks -= 1
            kvs.hbm_blocks += 1
        self.k_pages = self.k_pages.at[:, new].set(self.k_pages[:, old])
        self.v_pages = self.v_pages.at[:, new].set(self.v_pages[:, old])
        self._place_pages()
        self.cow_copies += 1
        self._refresh_shared_pins()

    def _register_prefix(self, sid: str) -> None:
        """Turn close: index the session's committed chain — full pages
        as interior radix nodes, the partially-filled tail as this
        node's partial child. Newly indexed pages become `cache_held`
        (kept allocated even at refcount 0 until forgotten/reclaimed);
        charging is unchanged — this session still owns them."""
        sess = self.sessions[sid]
        s = self.pool.seqs.get(sid)
        if s is None or sess.kv_len <= 0:
            return
        assert len(sess.token_ids) == sess.kv_len, \
            f"{sid}: token history {len(sess.token_ids)} != " \
            f"kv_len {sess.kv_len}"
        now = self.clock.now()
        newly = self.prefix_cache.register(
            sess.token_ids, s.pages,
            est=self.kv.next_use_estimate(sid, now),
            protect=self.kv.session(sid).protected_until)
        self.pool.cache_held.update(newly)

    def _forget_cached(self, phys: List[int]) -> None:
        """Drop pages (and their now-unreachable radix subtrees) from
        the index before they offload/migrate; orphans whose last
        reference was the index free immediately."""
        dropped = self.prefix_cache.forget_phys(phys)
        self.kv.cached_blocks -= self.pool.cache_release(dropped)

    def _reclaim_cached(self, n: int, now: float) -> int:
        """KVManager cache hook: free up to n orphaned cached pages
        (leaves-first, farthest banked next-use first). Returns blocks
        freed; the manager adjusts cached_blocks."""
        phys = self.prefix_cache.reclaim(n, now, self.pool.refcount)
        freed = self.pool.cache_release(phys)
        assert freed == len(phys), (phys, freed)
        return freed

    def _cached_reclaimable(self, now: float) -> int:
        return self.prefix_cache.reclaimable(now, self.pool.refcount)

    def _bank_detach(self, sid: str, now: float) -> None:
        """A sharer is leaving (hangup or migration): bank its Eq.4
        next-use estimate and protection TTL on every indexed/shared
        page it references — reclaim order for the eventual orphans is
        min-over-sharers next-use (last detacher wins) with protection
        extended to the max over sharers' TTLs."""
        s = self.pool.seqs.get(sid)
        if s is None:
            return
        held = [p for p in s.pages
                if p >= 0 and (p in self.pool.cache_held
                               or self.pool.refcount[p] > 1)]
        if held:
            self.prefix_cache.on_detach(
                held, est=self.kv.next_use_estimate(sid, now),
                protect=self.kv.session(sid).protected_until)

    def _grow(self, sid: str, token_capacity: int, *,
              best_effort: bool = False) -> bool:
        """Own enough pages for token_capacity tokens; KVManager evicts
        idle sessions (physically, via the hook) when the pool is short."""
        token_capacity = min(token_capacity, self.max_context)
        need = self.pool.pages_for(token_capacity) \
            - len(self.pool.seq(sid).pages)
        if need <= 0:
            return True
        now = self.clock.now()
        if best_effort and (self.kv.free_blocks < need
                            or self.pool.free_pages
                            + self.transfer.pending_offload_pages()
                            < need):
            return False
        if not self.kv.try_allocate_working(need, now):
            raise OutOfPages(
                f"{sid}: need {need} pages, {self.kv.free_blocks} free "
                "and nothing evictable")
        # accounting freed the blocks; copy-then-free may still hold the
        # physical slots until its chunks drain — demand them now
        self._demand_free_pages(need)
        if best_effort and self.pool.free_pages < need:
            self.kv.release_working(need)     # undo the allocation above
            return False
        self.pool.ensure_capacity(sid, token_capacity)
        return True

    # ------------------------------------------------------------ admit
    def free_slot(self) -> Optional[int]:
        for i, s in self.slot_state.items():
            if s is None:
                return i
        return None

    def add_session(self, session_id: str, prompt: np.ndarray,
                    max_new_tokens: int) -> int:
        """Turn 0, synchronous path: prefill the prompt into pool pages
        before returning; returns slot id."""
        sess = self._prep_first_turn(session_id)
        prompt = self._attach_prefix(sess, np.asarray(prompt, np.int32))
        return self._begin_turn(sess, prompt, max_new_tokens, first=True)

    def start_turn(self, session_id: str, prompt: np.ndarray,
                   max_new_tokens: int) -> int:
        """A later turn reaches the LLM stage (synchronous path): reload
        whatever KV is still offloaded (warm no-op on a preload hit),
        then extend the paged context with the new prompt — the
        committed history is never re-prefilled."""
        sess = self._prep_next_turn(session_id)
        return self._begin_turn(sess, np.asarray(prompt, np.int32),
                                max_new_tokens, first=False)

    def submit_turn(self, session_id: str, prompt: np.ndarray,
                    max_new_tokens: int, *,
                    request: Optional[Request] = None) -> int:
        """Scheduler-drivable turn admission (DESIGN.md §4): bind a free
        slot and run the reload path, but leave the request in PREFILL —
        prompt tokens are teacher-forced through the shared fixed-batch
        step as ``run_round`` chunks grant them (chunked paged prefill
        that interleaves with other sessions' decode), and the first
        output token appears the round the last prompt token is fed.
        A pre-built ``request`` lets the control plane rank the turn
        while it was still queued (its arrival_time is the instant the
        utterance reached the gateway, preserving queue wait in TTFP).
        Works for turn 0 and later turns alike."""
        prompt = np.asarray(prompt, np.int32)
        if session_id not in self.sessions:
            sess = self._prep_first_turn(session_id)
            prompt = self._attach_prefix(sess, prompt)
        else:
            sess = self._prep_next_turn(session_id)
        if request is not None:
            sess.turn_arrival = min(sess.turn_arrival,
                                    request.arrival_time)
        slot = self.free_slot()
        assert slot is not None, "no free decode slot"
        req = self._make_request(sess, prompt, max_new_tokens,
                                 request=request)
        self.slot_state[slot] = PagedSlot(session_id, req, -1, [],
                                          prompt=prompt)
        self._sync_page_counts(session_id)
        return slot

    def _prep_first_turn(self, session_id: str) -> PagedSession:
        assert session_id not in self.sessions, \
            "session exists — use start_turn/submit_turn for later turns"
        self.monitor.register(session_id)
        self.monitor.on_turn_start(session_id, 0)
        sess = PagedSession(session_id)
        self.sessions[session_id] = sess
        sess.turn_arrival = self.clock.now()
        sess.reload_stall_s = 0.0
        sess.reload_off_path_s = 0.0
        return sess

    def _prep_next_turn(self, session_id: str) -> PagedSession:
        sess = self.sessions[session_id]
        assert not sess.ended, f"{session_id} ended; KV pages are gone"
        # reload FIRST, before any turn bookkeeping mutates: on a
        # saturated pool (every other session pinned or speech-protected)
        # the sync-fallback reload can fail to fit, and that must surface
        # as recoverable pressure the control plane can retry — not as a
        # half-started turn. Pin before the reload path: its eviction
        # pass must never pick the session being brought back as its own
        # victim.
        self.kv.pin(session_id)
        stall = self.preloader.on_turn_ready(session_id, self.clock.now())
        # the accounting view (dram blocks), not the host-copy dict, is
        # the guard: under copy-then-free a saturated-pool session can
        # have its suffix still *offloading* (chunks queued, `offloaded`
        # empty) — starting its turn anyway would let a later round's
        # FIFO drain move the pages to DRAM mid-decode and crash the
        # block-table build instead of requeueing recoverably
        if self.kv.missing_blocks(session_id) > 0:
            self.kv.session(session_id).pinned = False
            # the settlement that just ran stalled nothing (this turn is
            # requeued): its seconds carry forward as off-path credit
            # and its pages reclassify, so the overlap accounting never
            # drops already-done reload work on a requeue
            self.preloader.requeue_split(session_id)
            self.transfer.requeue_settlement(session_id)
            raise OutOfPages(
                f"{session_id}: pool too saturated to restore "
                f"{self.kv.missing_blocks(session_id)} non-resident "
                "blocks; keep the turn queued and retry")
        self.transfer.settlement_committed(session_id)
        assert self.pool.inflight_pages(session_id) == (0, 0) \
            and not self.pool.seq(session_id).offloaded, \
            f"{session_id}: turn starting with pages still in flight"
        sess.turn_index += 1
        # the utterance is over once its turn reaches the LLM stage —
        # clear `speaking` or the session stays immediate_reuse forever
        # and its idle KV becomes permanently unevictable
        self.monitor.on_speech_end(session_id)
        self.monitor.on_turn_start(session_id, sess.turn_index)
        sess.turn_arrival = self.clock.now()
        if stall > 0:
            self.clock.tick(stall)          # on-path sync reload residual
        sess.reload_stall_s = stall
        _, sess.reload_off_path_s = self.preloader.pop_split(session_id)
        return sess

    def _make_request(self, sess: PagedSession, prompt: np.ndarray,
                      max_new_tokens: int, *,
                      request: Optional[Request] = None) -> Request:
        sid = sess.session_id
        P = int(prompt.shape[0])
        assert sess.kv_len + P + max_new_tokens <= self.max_context, \
            f"{sid}: turn would exceed pages_per_seq*page_size context"
        self.kv.pin(sid)
        sess.base_pages = len(self.pool.seq(sid).pages)
        re_prefill = self.kv.recompute_tokens(sid)
        if request is None:
            req = Request(session_id=sid, stage="thinker",
                          turn_index=sess.turn_index,
                          arrival_time=sess.turn_arrival, prompt_len=P,
                          context_len=sess.kv_len,
                          max_new_tokens=max_new_tokens)
        else:
            req = request
            req.turn_index = sess.turn_index
            req.prompt_len = P
            req.context_len = sess.kv_len
            req.max_new_tokens = max_new_tokens
        req.reload_stall_s = sess.reload_stall_s
        req.reload_off_path_s = sess.reload_off_path_s
        req.prefix_hit_tokens = self._pending_hit.pop(sid, 0)
        sess.turn_stats.append({
            "turn": sess.turn_index,
            "context_tokens": req.context_len,
            "prompt_tokens": P,
            "ttft_s": None,                 # set at first output token
            "reload_stall_s": sess.reload_stall_s,
            "reload_off_path_s": sess.reload_off_path_s,
            "re_prefill_tokens": re_prefill,
            "prefix_hit_tokens": req.prefix_hit_tokens,
            "generated": 0,
            "aborted": False,
        })
        return req

    def _begin_turn(self, sess: PagedSession, prompt: np.ndarray,
                    max_new_tokens: int, *, first: bool) -> int:
        sid = sess.session_id
        slot = self.free_slot()
        assert slot is not None, "no free decode slot"
        req = self._make_request(sess, prompt, max_new_tokens)
        self._grow(sid, sess.kv_len + req.prompt_len)
        self._ensure_writable(sid)
        if self.fused_step:
            # turn 0 (the former dense-prefill graft) and turn-N
            # extension share the one fused path (DESIGN.md §11)
            tok = self._prefill_fused(slot, sess, prompt)
        elif first and sess.kv_len == 0:
            # the dense graft writes whole pages from position 0 — only
            # valid when nothing (no attached prefix) precedes it
            tok = self._prefill_dense(sess, prompt)
        else:
            tok = self._prefill_paged(slot, sess, prompt)
        req.phase = Phase.DECODE
        req.prefilled = req.prompt_len
        req.first_output_time = self.clock.now()
        self.slot_state[slot] = PagedSlot(sid, req, tok, [tok])
        sess.turn_stats[-1]["ttft_s"] = self.clock.now() - sess.turn_arrival
        self._sync_page_counts(sid)
        return slot

    def _prefill_dense(self, sess: PagedSession, prompt: np.ndarray) -> int:
        """Turn-0 fast path: one dense B=1 prefill, grafted into the
        session's pool pages (page-aligned scatter)."""
        sid = sess.session_id
        P = int(prompt.shape[0])
        npages = self.pool.pages_for(P)
        cap = npages * self.page_size
        c1 = init_cache(self.cfg, 1, cap)
        logits, c1 = prefill(self.cfg, self.params,
                             jnp.asarray(prompt, jnp.int32)[None, :], c1)
        phys = np.asarray(self.pool.seq(sid).pages[:npages], np.int64)
        kl = c1["k"][:, 0].reshape(self.cfg.num_layers, npages,
                                   self.page_size, *c1["k"].shape[3:])
        vl = c1["v"][:, 0].reshape(kl.shape)
        self.k_pages = self.k_pages.at[:, phys].set(kl)
        self.v_pages = self.v_pages.at[:, phys].set(vl)
        self._place_pages()
        sess.kv_len = P
        sess.token_ids = [int(t) for t in prompt]
        self.clock.tick()
        return int(jnp.argmax(logits[0]))

    def _prefill_fused(self, slot: int, sess: PagedSession,
                       prompt: np.ndarray) -> int:
        """Synchronous prefill on the fused plane: the whole prompt is
        one multi-token launch — turn 0 lands in fresh pages, turn N
        extends the committed context (never re-prefilled) — and the
        last token's logits are the first output token."""
        logits = self._run_chunk_rows(
            {slot: (sess.session_id,
                    np.asarray(prompt, np.int32))})[0][slot]
        sess.kv_len += int(prompt.shape[0])
        sess.token_ids += [int(t) for t in prompt]
        self.clock.tick()
        return int(np.argmax(logits))

    def _prefill_paged(self, slot: int, sess: PagedSession,
                       prompt: np.ndarray) -> int:
        """Turn-N extension on the per-token plane (``fused_step=False``
        differential control): teacher-force the new prompt through the
        paged step so its KV lands behind the committed context — no
        re-prefill of history.

        Like the dense engine's add_session, this runs synchronously:
        concurrent decode holds for prompt_len rounds (turn prompts are
        short utterance transcripts); the fused plane collapses this to
        one launch (DESIGN.md §11)."""
        logits = None
        for t in prompt:
            logits = self._run_rows({slot: (sess.session_id, int(t))})[slot]
            sess.kv_len += 1
            sess.token_ids.append(int(t))
            self.clock.tick()
        return int(np.argmax(logits))

    # ------------------------------------------------------------ speech
    def user_speech_start(self, session_id: str,
                          expected_dur_s: Optional[float] = None):
        """VAD speech-start: update telemetry and fire the speech-time
        preload (§5.2) — admitted preloads physically reload pages via
        the KVManager hook while the user is still speaking."""
        self.monitor.on_speech_start(session_id, expected_dur_s)
        return self.preloader.on_speech_start(session_id, self.clock.now())

    def barge_in(self, session_id: str,
                 expected_dur_s: Optional[float] = None):
        """User interrupts playback: abort the in-flight turn (keeping
        committed pages) and treat the interruption as speech start."""
        self.abort(session_id)
        if expected_dur_s is not None:
            self.monitor.register(session_id).expected_speech_end = \
                self.clock.now() + expected_dur_s
        return self.preloader.on_speech_start(session_id, self.clock.now())

    def tool_call_start(self, session_id: str,
                        expected_latency_s: float = 0.0) -> None:
        """The turn's reply ended in a tool invocation: the session goes
        idle mid-conversation with hot KV. Protect it under the
        tool-pause TTL and point Eq. 4 next-use at the tool's expected
        return instead of the reply-gap EMA."""
        now = self.clock.now()
        self.monitor.on_tool_call_start(session_id, expected_latency_s)
        self.kv.protect_tool(session_id, now, expected_latency_s)
        self.kv.refresh_session(session_id, now)

    def tool_call_result(self, session_id: str,
                         resume_gap_s: float = 0.0):
        """The tool returned; the resume turn arrives in ~resume_gap_s.
        Lift the tool-pause protection and fire the ordinary speech-time
        preload machinery over the gap, so a session whose pages were
        evicted anyway (TTL lapse, pool pressure) reloads off-path and
        resumes without re-prefill."""
        now = self.clock.now()
        self.monitor.on_tool_call_result(session_id, resume_gap_s)
        self.kv.clear_tool_protection(session_id, now)
        return self.preloader.on_speech_start(session_id, now)

    def end_session(self, session_id: str) -> None:
        """User hung up: free the session's pages (HBM and DRAM copies)
        and its accounting. History/turn stats stay readable."""
        assert all(s is None or s.session_id != session_id
                   for s in self.slot_state.values()), \
            "abort the live turn before ending the session"
        # drop queued transfer chunks first: release() frees the slots
        # (including loading reservations) and the host copies, so a
        # hangup mid-transfer leaks nothing
        self.transfer.cancel_session(session_id)
        self.preloader.forget_session(session_id)
        if self.prefix_cache is not None:
            self._bank_detach(session_id, self.clock.now())
        rep = self.pool.release(session_id)
        if self.prefix_cache is not None:
            # own pages surviving via sharers/the index re-charge to
            # the cache; cache-charged pages whose last reference died
            # here freed with the release
            self.kv.cached_blocks += rep["orphaned"]
            self.kv.cached_blocks -= rep["freed_orphan"]
        self.kv.release_session(session_id)
        if self.prefix_cache is not None:
            self._refresh_shared_pins()
        self.sessions[session_id].ended = True
        self.monitor.on_page_movement(session_id, resident=0, offloaded=0)

    def abort(self, session_id: str) -> None:
        """Barge-in: drop the in-flight request. Committed pages (context
        + tokens already written) stay owned; in-flight lookahead pages
        are trimmed back to the pool."""
        for i, s in self.slot_state.items():
            if s is None or s.session_id != session_id:
                continue
            s.request.state = RequestState.ABORTED
            self.monitor.on_barge_in(session_id)
            self._close_turn(i, aborted=True)

    # --------------------------------------------------------- migration
    # Cross-replica migration (serving/fleet, DESIGN.md §12) rides the
    # same chunked ledger as eviction/preload: the source queues its
    # whole committed context as MIGRATE-tagged copy-then-free offload
    # chunks (drained across rounds while the user is still speaking),
    # and once everything is host-resident the session state transplants
    # wholesale to the destination engine, which pages it back in with
    # the ordinary speech-time preload machinery — so the on-path vs
    # off-path split of migration bytes needs no new accounting.

    def migrate_out_begin(self, session_id: str) -> int:
        """Queue the session's entire device-resident KV for host
        offload (copy-then-free, MIGRATE-tagged). The session must be
        idle (no live slot); any in-flight speech-time preload is
        cancelled first — its pages are about to leave this replica.
        Returns the number of pages queued for offload now (pages
        already offloaded or already offloading are not re-queued)."""
        sid = session_id
        sess = self.sessions[sid]
        assert not sess.ended, f"{sid} ended; nothing to migrate"
        assert all(s is None or s.session_id != sid
                   for s in self.slot_state.values()), \
            f"{sid}: migration requires an idle session (no live slot)"
        now = self.clock.now()
        self.preloader.cancel(sid, now)
        self.kv.cancel_reload(sid, now)
        s = self.pool.seq(sid)
        kvs = self.kv.session(sid)
        deep_copied = 0
        if self.prefix_cache is not None:
            # Shared pages cannot ride the copy-then-free ledger (their
            # slot must NOT free — sharers still need it hot). Private
            # pages the index holds are forgotten (plain again); truly
            # shared pages deep-copy to host synchronously and the
            # departing session drops its reference — the destination
            # re-resolves against its own radix index on later turns.
            self._forget_cached(
                [p for p in s.pages
                 if p >= 0 and self.pool.refcount[p] == 1
                 and p in self.pool.cache_held
                 and self.pool.page_owner[p] == sid])
            shared_lis = [li for li, p in enumerate(s.pages)
                          if p >= 0 and (self.pool.refcount[p] > 1
                                         or self.pool.page_owner[p] != sid)]
            if shared_lis:
                self._bank_detach(sid, now)
                for li in shared_lis:
                    phys = s.pages[li]
                    hk = np.asarray(self.k_pages[:, phys])
                    hv = np.asarray(self.v_pages[:, phys])
                    was_owner, freed = self.pool.detach_page(sid, li)
                    s.offloaded[li] = self.codec.encode(
                        np.stack([hk, hv]))
                    if was_owner:
                        # stays for its sharers, cache-charged now
                        kvs.hbm_blocks -= 1
                        self.kv.cached_blocks += 1
                    else:
                        kvs.shared_blocks -= 1
                        if freed:
                            # last reference to an orphan: the cache
                            # was paying and the slot just freed
                            self.kv.cached_blocks -= 1
                deep_copied = len(shared_lis)
                self._refresh_shared_pins()
                self._sync_page_counts(sid)
        lis = [li for li, p in enumerate(s.pages)
               if p >= 0 and li not in s.loading and li not in s.offloading]
        assert not s.loading and kvs.hbm_blocks == len(lis), \
            f"{sid}: accounting ({kvs.hbm_blocks}) disagrees with " \
            f"resident pages ({len(lis)}) at migrate-out"
        if lis:
            self.pool.mark_offloading(sid, lis)
            self.transfer.submit_offload(sid, lis, tag=MIGRATE)
            # accounting mirrors the eviction hook: the blocks leave
            # this replica's HBM budget now; copy-then-free keeps the
            # physical slots (and their usability) until chunks drain
            kvs.hbm_blocks = 0
            self._sync_page_counts(sid)
        return len(lis) + deep_copied

    def migrate_out_pending(self, session_id: str) -> int:
        """Pages still queued on the source's offload ledger."""
        return self.transfer.pending_offload_pages(session_id)

    def migrate_out_cancel(self, session_id: str) -> int:
        """Abandon a not-yet-handed-off migration zero-copy: queued
        offload chunks drop from the ledger and their pages stay
        resident (the bytes never left HBM — the same copy-then-free
        win as a reload racing an eviction). Pages whose chunks already
        drained stay host-resident; the session's next turn on *this*
        replica reloads them through the normal path. Returns pages
        restored to resident."""
        sid = session_id
        dropped = self.transfer.cancel_offload_pages(sid)
        restored = self.pool.cancel_offloading(sid)
        assert len(restored) == dropped, (sid, restored, dropped)
        if dropped:
            self.kv.session(sid).hbm_blocks += dropped
            self._sync_page_counts(sid)
        return dropped

    def migrate_out_finalize(self, session_id: str) -> dict:
        """Every page is host-resident: detach the session wholesale —
        PagedSession (history, turn stats), monitor view (reply-gap
        EMA, expected speech end), host page copies — and scrub every
        source-side table, exactly like a hangup except the state moves
        instead of dying. The returned dict feeds ``migrate_in_adopt``
        on the destination engine."""
        sid = session_id
        s = self.pool.seqs[sid]
        assert self.transfer.pending_offload_pages(sid) == 0 \
            and not s.offloading and not s.loading \
            and all(p == -1 for p in s.pages), \
            f"{sid}: migrate-out finalize before all chunks drained"
        state = {
            "session": self.sessions.pop(sid),
            "view": self.monitor.forget(sid),
            "n_pages": len(s.pages),
            "length": s.length,
            "host": dict(s.offloaded),
        }
        self.transfer.cancel_session(sid)     # clears split accumulators
        self.preloader.forget_session(sid)
        self.pool.release(sid)
        self.kv.release_session(sid)
        return state

    def migrate_in_adopt(self, session_id: str, state: dict) -> None:
        """Install a migrated session: pool entry fully host-resident
        (``pages[li] == -1`` everywhere), KV accounting with zero HBM
        blocks, interaction view transplanted so Eq. 4 and the preload
        window keep their learned state. The caller then fires
        ``preloader.on_speech_start`` so the page-in rides the remaining
        speech window like any preload (admission-checked, chunked,
        cancellable, OutOfPages-recoverable at turn start)."""
        sid = session_id
        assert sid not in self.sessions, f"{sid} already on this replica"
        self.sessions[sid] = state["session"]
        if state["view"] is not None:
            self.monitor.adopt(sid, state["view"])
        else:
            self.monitor.register(sid)
        self.pool.adopt(sid, state["n_pages"], state["length"],
                        state["host"])
        kvs = self.kv.session(sid)
        kvs.total_blocks = state["n_pages"]
        kvs.hbm_blocks = 0
        kvs.last_access = self.clock.now()
        self._sync_page_counts(sid)

    # ------------------------------------------------------------ rounds
    def active(self) -> List[PagedSlot]:
        return [s for s in self.slot_state.values()
                if s is not None and s.request.is_live()
                and s.request.generated < s.request.max_new_tokens]

    def step(self) -> List[int]:
        """One self-scheduled round: the engine's own scheduler picks the
        slots *and their token grants* (``chunk_for`` — a PREFILL slot
        gets its prefill chunk, a decode slot one token), then one
        fixed-batch paged round. Returns scheduled slot ids. (The
        gateway bypasses this and calls ``run_round`` with its own
        scheduler's decision — DESIGN.md §4.)"""
        self.clock.tick()
        act = self.active()
        if not act:
            return []
        sched_slots, grants = schedule_round(
            self.scheduler, self.kv, self.clock, self.slot_state, act,
            self.slots * (1 + self.spec_decode),
            block_size=self.page_size)
        if not sched_slots:
            return []
        self.run_round(grants)
        return sched_slots

    def run_round(self, chunks: Dict[int, int]) -> Dict[int, List[tuple]]:
        """Execute one already-scheduled round: ``chunks[slot]`` is the
        token budget the control plane granted that slot this round.
        A decode slot advances one token; a PREFILL slot (submit_turn)
        teacher-forces up to its chunk of prompt tokens.

        On the fused plane (``fused_step=True``, the default) the whole
        round — every slot's grant, C-token prefill chunks included —
        packs into **one jitted launch** (DESIGN.md §11): each slot's
        chunk KV is scattered in one paged write and every query token
        attends causally over history + chunk prefix. With
        ``fused_step=False`` chunks > 1 run as sequential single-token
        sub-batches in which every other granted slot participates only
        once — the per-token differential control.

        Returns per-slot event lists for the caller to stream out:
        ``("prefill", n_prefilled)``, ``("token", tok)`` (playable output
        token, the first of which marks TTFT), ``("finished", n_tokens)``.
        Safe to interleave with ``abort``/``submit_turn`` between calls
        (asyncio single-thread discipline: never called concurrently).

        Around the launch (between decode sub-batches on the per-token
        plane) the round drains up to ``transfer_chunks_per_round``
        queued transfer chunks — this is where a speech-time preload
        physically lands while other sessions keep decoding
        (DESIGN.md §10)."""
        if self.fused_step:
            return self._run_round_fused(chunks)
        return self._run_round_tokenwise(chunks)

    def _round_feeds(self, chunks: Dict[int, int]) -> Dict[int, tuple]:
        """The round's grants as token arrays: ``{slot: (sid, tokens)}``
        — a PREFILL slot's next chunk of prompt tokens, one pending
        token for a decode slot — growing each sequence once for its
        whole grant (plus one best-effort lookahead page). A slot whose
        mandatory growth hits pool pressure is held for the round
        (``pressure_holds``): it retries next round when pressure
        drains; scheduling moves WHEN tokens appear, never WHICH
        (§5.2), so holding is safe."""
        feeds: Dict[int, tuple] = {}
        for i, c in chunks.items():
            s = self.slot_state[i]
            if s is None or not s.request.is_live():
                continue
            r = s.request
            if r.phase == Phase.PREFILL:
                n = min(c, r.prompt_len - r.prefilled)
                if n > 0:
                    feeds[i] = (s.session_id,
                                np.asarray(s.prompt[r.prefilled:
                                                    r.prefilled + n],
                                           np.int32))
            elif c > 0 and r.generated < r.max_new_tokens:
                # a zero grant is "not scheduled this round" on both
                # planes — the planes' bit-exactness contract covers
                # every run_round input, not just scheduler outputs
                toks = [s.pending_token]
                if self.spec_decode > 0:
                    # the grant is an "up to 1+K" draft budget: the
                    # proposer fills as much of it as it can guess,
                    # capped so accepted tokens can never overshoot the
                    # turn's generation cap (frontier/cap accounting
                    # counts accepted tokens only — §16)
                    m = min(self.spec_decode, c - 1,
                            r.max_new_tokens - r.generated - 1)
                    if m > 0:
                        if hasattr(self.proposer, "session_id"):
                            self.proposer.session_id = s.session_id
                        hist = self.sessions[s.session_id].token_ids \
                            + [s.pending_token]
                        toks += [int(t) for t in
                                 self.proposer.propose(hist, m)[:m]]
                feeds[i] = (s.session_id, np.asarray(toks, np.int32))
        for i in list(feeds):
            sid, toks = feeds[i]
            sess = self.sessions[sid]
            try:
                self._grow(sid, sess.kv_len + len(toks))
                self._ensure_writable(sid)   # COW a shared write target
            except OutOfPages:
                # allocation failure mid-round: admission accounted
                # blocks that interaction events (speech protection, a
                # barge-in trim re-pinning pressure elsewhere) made
                # unreclaimable by the time this round allocates.
                del feeds[i]
                self.pressure_holds += 1
                continue
            # best-effort lookahead, hoisted to once per slot per round
            # (ISSUE 5 satellite): own the page past the whole grant
            # before any write crosses into it, so boundary tokens never
            # wait on allocation/eviction (these are the in-flight pages
            # a barge-in trims)
            self._grow(sid, sess.kv_len + len(toks) + self.page_size,
                       best_effort=True)
        return feeds

    def _run_round_fused(self, chunks: Dict[int, int]) \
            -> Dict[int, List[tuple]]:
        """One round = one launch: pack every grant into a padded
        [slots, Q] token batch and advance all of it in a single jitted
        fused step."""
        events: Dict[int, List[tuple]] = {i: [] for i in chunks}
        xfer_budget = self.transfer_chunks_per_round
        if xfer_budget > 0:
            xfer_budget -= self.drain_transfers(1)
        feeds = self._round_feeds(chunks)
        if feeds:
            out, outs = self._run_chunk_rows(feeds)
            for i, (sid, toks) in feeds.items():
                s = self.slot_state[i]
                sess = self.sessions[sid]
                n = len(toks)
                r = s.request
                if r.phase == Phase.PREFILL:
                    sess.kv_len += n
                    sess.token_ids += [int(t) for t in toks]
                    tok = int(np.argmax(out[i]))
                    r.prefilled += n
                    # same event stream as the per-token plane: one
                    # progress event per intermediate prompt token, and
                    # the chunk's last logits become the first output
                    # token iff the prompt completed this round
                    events[i] += [("prefill", r.prefilled - n + 1 + t)
                                  for t in range(n - (1 if r.done_prefill
                                                     else 0))]
                    if r.done_prefill:
                        r.phase = Phase.DECODE
                        r.first_output_time = self.clock.now()
                        s.pending_token = tok
                        s.tokens.append(tok)
                        sess.turn_stats[-1]["ttft_s"] = \
                            self.clock.now() - sess.turn_arrival
                        events[i].append(("token", tok))
                    continue
                # decode: without speculation the row fed exactly
                # [pending] and emits its one argmax; with it the row
                # fed [pending, d_1..d_m] and the verify launch's
                # per-position argmaxes accept the longest matching
                # draft prefix (the committed stream is exactly the
                # greedy one — §16)
                if outs is None:
                    accepted, emit = 0, [int(np.argmax(out[i]))]
                else:
                    row = outs[i]
                    accepted = 0
                    while accepted < n - 1 \
                            and int(toks[accepted + 1]) \
                            == int(row[accepted]):
                        accepted += 1
                    emit = [int(row[j]) for j in range(accepted + 1)]
                    if n > 1:
                        self.spec_rounds += 1
                        self.spec_drafted += n - 1
                        self.spec_accepted += accepted
                        self.spec_rejected += (n - 1) - accepted
                # commit pending + accepted drafts; rejected KV rolls
                # back (length clamp — pages stay owned, the garbage
                # slots are never attended and are overwritten before
                # any future attend; _close_turn's trim reclaims)
                sess.kv_len += 1 + accepted
                sess.token_ids += [int(t) for t in toks[:1 + accepted]]
                if 1 + accepted < n:
                    self.pool.rollback(sid, sess.kv_len)
                for tok in emit:
                    r.generated += 1
                    s.pending_token = tok
                    if r.generated < r.max_new_tokens:
                        s.tokens.append(tok)
                        events[i].append(("token", tok))
                    else:
                        r.state = RequestState.FINISHED
                        self._close_turn(i, aborted=False)
                        events[i].append(("finished", r.generated))
                        break
        if xfer_budget > 0:
            self.drain_transfers(xfer_budget)
        return events

    def _run_round_tokenwise(self, chunks: Dict[int, int]) \
            -> Dict[int, List[tuple]]:
        """The per-token plane (``fused_step=False``): chunks > 1 run as
        sequential single-token sub-batches — the differential control
        the fused plane is bit-exactness-tested against."""
        events: Dict[int, List[tuple]] = {i: [] for i in chunks}
        xfer_budget = self.transfer_chunks_per_round
        lookahead_done = set()
        for j in range(max(chunks.values(), default=0)):
            if xfer_budget > 0:
                xfer_budget -= self.drain_transfers(1)
            feeds = {}
            for i, c in chunks.items():
                s = self.slot_state[i]
                if s is None or not s.request.is_live():
                    continue
                r = s.request
                if r.phase == Phase.PREFILL:
                    if j < c and r.prefilled < r.prompt_len:
                        feeds[i] = (s.session_id,
                                    int(s.prompt[r.prefilled]))
                elif j == 0 and c > 0 \
                        and r.generated < r.max_new_tokens:
                    feeds[i] = (s.session_id, s.pending_token)
            if not feeds:
                break
            for i in list(feeds):
                s = self.slot_state[i]
                sess = self.sessions[s.session_id]
                try:
                    self._grow(s.session_id, sess.kv_len + 1)
                    self._ensure_writable(s.session_id)
                except OutOfPages:
                    # mid-chunk allocation failure: admission accounted
                    # blocks that interaction events (speech protection,
                    # a barge-in trim re-pinning pressure elsewhere)
                    # made unreclaimable by the time this sub-batch
                    # allocates. Hold the slot — it retries next round
                    # when pressure drains; scheduling moves WHEN tokens
                    # appear, never WHICH (§5.2), so holding is safe.
                    del feeds[i]
                    self.pressure_holds += 1
                    continue
                # best-effort lookahead, hoisted to once per slot per
                # round (ISSUE 5 satellite): cover the slot's remaining
                # grant plus the page past it, so the boundary token
                # never waits on allocation/eviction (these are the
                # in-flight pages a barge-in trims)
                if i not in lookahead_done:
                    lookahead_done.add(i)
                    r = s.request
                    rest = min(chunks[i] - j,
                               r.prompt_len - r.prefilled) \
                        if r.phase == Phase.PREFILL else 1
                    self._grow(s.session_id,
                               sess.kv_len + rest + self.page_size,
                               best_effort=True)
            if not feeds:
                continue                     # everything held this round
            out = self._run_rows(feeds)
            for i in feeds:
                s = self.slot_state[i]
                sess = self.sessions[s.session_id]
                sess.kv_len += 1
                sess.token_ids.append(int(feeds[i][1]))
                r = s.request
                tok = int(np.argmax(out[i]))
                if r.phase == Phase.PREFILL:
                    r.prefilled += 1
                    if r.done_prefill:
                        # the last prompt token's logits are the first
                        # output token — same contract as the sync paths
                        r.phase = Phase.DECODE
                        r.first_output_time = self.clock.now()
                        s.pending_token = tok
                        s.tokens.append(tok)
                        sess.turn_stats[-1]["ttft_s"] = \
                            self.clock.now() - sess.turn_arrival
                        events[i].append(("token", tok))
                    else:
                        events[i].append(("prefill", r.prefilled))
                else:
                    r.generated += 1
                    s.pending_token = tok
                    if r.generated < r.max_new_tokens:
                        s.tokens.append(tok)
                        events[i].append(("token", tok))
                    else:
                        r.state = RequestState.FINISHED
                        self._close_turn(i, aborted=False)
                        events[i].append(("finished", r.generated))
        if xfer_budget > 0:
            self.drain_transfers(xfer_budget)
        return events

    def _run_rows(self, feeds: Dict[int, tuple]) -> Dict[int, np.ndarray]:
        """Run one compiled step with `feeds[row] = (sid, token)`; other
        rows are padded to the scratch page. Returns per-row logits."""
        rows: List[Optional[tuple]] = [None] * self.slots
        tokens = np.zeros((self.slots,), np.int32)
        for i, (sid, tok) in feeds.items():
            rows[i] = (sid, self.sessions[sid].kv_len)
            tokens[i] = tok
        tabs: BatchTables = assemble(self.pool, rows, self.pages_per_seq,
                                     self.scratch_page)
        logits, self.k_pages, self.v_pages = self._step_fn(
            self.params, jnp.asarray(tokens),
            jnp.asarray(tabs.positions), self.k_pages, self.v_pages,
            jnp.asarray(tabs.block_tables), jnp.asarray(tabs.seq_lens),
            jnp.asarray(tabs.write_page), jnp.asarray(tabs.write_slot))
        logits = np.asarray(logits)
        if self.logit_tap is not None:
            for i, (sid, _) in feeds.items():
                self.logit_tap(sid, logits[i])
        return {i: logits[i] for i in feeds}

    def _run_chunk_rows(self, feeds: Dict[int, tuple]) -> tuple:
        """Run one fused step with ``feeds[row] = (sid, tokens)`` —
        up to Q consecutive tokens per row, padded (rows and token
        slots alike) onto the scratch page. Returns
        ``(logits, outs)``: each row's last-valid-token logits, plus
        each row's per-position argmaxes when the engine runs the
        speculative verify variant (None on the non-spec plane)."""
        q_tokens = _q_bucket(max(len(t) for _, t in feeds.values()))
        rows: List[Optional[tuple]] = [None] * self.slots
        tokens = np.zeros((self.slots, q_tokens), np.int32)
        for i, (sid, toks) in feeds.items():
            rows[i] = (sid, self.sessions[sid].kv_len, len(toks))
            tokens[i, :len(toks)] = toks
        tabs: FusedBatchTables = assemble_fused(
            self.pool, rows, q_tokens, self.pages_per_seq,
            self.scratch_page)
        args = (self.params, jnp.asarray(tokens),
                jnp.asarray(tabs.positions), self.k_pages, self.v_pages,
                jnp.asarray(tabs.block_tables), jnp.asarray(tabs.q_start),
                jnp.asarray(tabs.q_lens), jnp.asarray(tabs.write_pages),
                jnp.asarray(tabs.write_slots))
        if self._spec_fn is not None:
            logits, outs, self.k_pages, self.v_pages = \
                self._spec_fn(*args)
            outs = np.asarray(outs)
            out_rows = {i: outs[i] for i in feeds}
        else:
            logits, self.k_pages, self.v_pages = self._fused_fn(*args)
            out_rows = None
        self.fused_launches += 1
        logits = np.asarray(logits)
        if self.logit_tap is not None:
            for i, (sid, _) in feeds.items():
                self.logit_tap(sid, logits[i])
        return {i: logits[i] for i in feeds}, out_rows

    def _close_turn(self, slot: int, *, aborted: bool) -> None:
        s = self.slot_state[slot]
        sid = s.session_id
        sess = self.sessions[sid]
        now = self.clock.now()
        trimmed = self.pool.trim(sid, sess.kv_len)   # in-flight lookahead
        grown = len(self.pool.seq(sid).pages) - sess.base_pages
        self.kv.release_working(grown + trimmed)
        self.kv.commit_turn(sid, sess.kv_len, now)
        if self.prefix_cache is not None:
            self._register_prefix(sid)
        if not aborted:
            self.monitor.on_response_complete(sid)
        sess.history.append(list(s.tokens))
        sess.turn_stats[-1].update(generated=s.request.generated,
                                   aborted=aborted)
        self.slot_state[slot] = None
        self._sync_page_counts(sid)

    def run_to_completion(self, max_rounds: int = 10_000) -> Dict[str, list]:
        for _ in range(max_rounds):
            if not self.active():
                break
            self.step()
        if self.active():
            raise RoundLimitExceeded(
                f"{len(self.active())} slots still live after "
                f"{max_rounds} rounds")
        out = {}
        for sid, sess in self.sessions.items():
            if sess.history:
                out[sid] = sess.history[-1]
        for s in self.slot_state.values():
            if s is not None:
                out[s.session_id] = s.tokens
        return out

    # ------------------------------------------------------------ checks
    def check_invariants(self) -> None:
        """Pool/accounting consistency (exercised by tests)."""
        from collections import Counter
        # refcount conservation (the §13 property): every allocated
        # page's refcount equals its live block-table references, and
        # zero-ref pages are exactly the orphans the radix index holds
        refs = Counter(p for s in self.pool.seqs.values()
                       for p in s.pages if p >= 0)
        for p, c in self.pool.refcount.items():
            assert refs.get(p, 0) == c, \
                f"page {p}: refcount {c} != {refs.get(p, 0)} references"
            if c == 0:
                assert p in self.pool.cache_held, \
                    f"page {p}: zero refs and not cache-held — leaked"
        allocated = set(self.pool.refcount)
        assert set(refs).issubset(allocated)
        assert allocated.isdisjoint(self.pool.free), "free+allocated page"
        assert len(allocated) + self.pool.free_pages == self.num_pages
        assert self.pool.cache_held.issubset(allocated)
        if self.prefix_cache is not None:
            assert set(self.prefix_cache.by_phys) == self.pool.cache_held
            assert self.kv.cached_blocks == sum(
                1 for p in allocated
                if self.pool.page_owner[p] is None), \
                f"cached_blocks {self.kv.cached_blocks} != owner-less pages"
            for sid in self.pool.seqs:
                kvs = self.kv.sessions.get(sid)
                if kvs is not None:
                    assert kvs.shared_pinned_blocks == \
                        self.pool.shared_charged_pages(sid), \
                        f"{sid}: stale shared-pin count"
        else:
            assert self.kv.cached_blocks == 0 \
                and not self.pool.cache_held \
                and all(c == 1 for c in self.pool.refcount.values())
        # copy-then-free: an offloading page is accounting-evicted but
        # physically still owned until its chunk drains
        offloading = sum(len(s.offloading)
                         for s in self.pool.seqs.values())
        assert self.kv.used_blocks == len(allocated) - offloading, \
            f"accounting {self.kv.used_blocks} != physical " \
            f"{len(allocated)} - offloading {offloading}"
        # per-session page-state conservation (the ISSUE 4 property):
        # resident + in-flight + offloaded == committed, disjointly
        for sid, s in self.pool.seqs.items():
            resident = sum(1 for li, p in enumerate(s.pages)
                           if p >= 0 and li not in s.loading
                           and li not in s.offloading)
            assert s.loading.isdisjoint(s.offloading), sid
            assert all(li in s.offloaded for li in s.loading), sid
            pure_off = len(s.offloaded) - len(s.loading)
            assert resident + len(s.loading) + len(s.offloading) \
                + pure_off == len(s.pages), \
                f"{sid}: page states do not partition the page list"
        # ledger <-> pool bijection (queued chunks match the marks)
        self.transfer.check(self.pool)
        if self.layout is not None:
            sh = self.layout.page_sharding()
            assert self.k_pages.sharding.is_equivalent_to(sh,
                                                          self.k_pages.ndim) \
                and self.v_pages.sharding.is_equivalent_to(sh,
                                                           self.v_pages.ndim), \
                "page store drifted off its mesh sharding"


# ======================================================================
# demo driver (launch/serve.py --engine real and examples/)
# ======================================================================
def run_multiturn_demo(*, seed: int = 0, mesh=None,
                       fused_step: bool = True, log=print) -> dict:
    """A laptop-scale end-to-end conversation on the real data plane,
    walking the whole §5 mechanism:

    1. alice's turn 1 prefills+decodes; her reply keeps playing.
    2. bob's heavy session *physically* evicts alice's suffix pages to
       the DRAM tier under pool pressure.
    3. alice speaks again — the pool is saturated, so the preloader's
       bounded-background-work guard skips; her turn 2 takes the
       synchronous on-path reload (stall reported, zero re-prefill) and
       is then barged-in mid-decode; turn 3 resumes on committed pages.
    4. alice hangs up (pages freed) — when bob's user speaks next, the
       speech-time preload is admitted and reloads his pages *during*
       the utterance: his turn 2 starts warm (zero stall, zero
       re-prefill).

    Returns per-turn stats for both sessions.
    """
    from repro.configs import get_config, reduced
    from repro.models import init_params

    cfg = reduced(get_config("qwen2-1.5b"), layers=2, d_model=64,
                  vocab=503)
    params = init_params(cfg, jax.random.PRNGKey(seed))
    # pcie_gb_s scaled down with the laptop-scale pool (KB, not GB) so
    # transfer times land in the milliseconds the paper plots
    eng = PagedRealtimeEngine(cfg, params, slots=2, page_size=8,
                              pages_per_seq=9, num_pages=11,
                              pcie_gb_s=0.01, mesh=mesh,
                              fused_step=fused_step)
    rng = np.random.default_rng(seed)

    def prompt(n):
        return rng.integers(0, cfg.vocab_size, size=n)

    log(f"engine: {cfg.name} slots=2 page=8 pool={eng.num_pages} pages"
        + (f" layout={eng.layout}" if eng.layout else ""))
    # ---- alice turn 1: admitted, decoded to completion -------------
    eng.add_session("alice", prompt(28), max_new_tokens=10)
    eng.run_to_completion()
    eng.monitor.on_audio("alice", 30.0)     # long reply still playing
    log(f"alice turn 1: kv_len={eng.sessions['alice'].kv_len} "
        f"pages={eng.pool.resident_pages('alice')}")

    # ---- pool pressure: bob's growth evicts alice's suffix ---------
    eng.add_session("bob", prompt(30), max_new_tokens=26)
    eng.run_to_completion()
    eng.monitor.on_audio("bob", 60.0)
    res, off = eng.monitor.page_counts("alice")
    log(f"bob served: alice pages resident={res} offloaded-to-DRAM={off} "
        f"(evictions so far: {len(eng.offload_events)})")

    # ---- alice speaks: saturated pool -> preload guard skips -------
    eng.user_speech_start("alice", expected_dur_s=2.0)
    eng.clock.tick(2.0)                     # the utterance itself
    log(f"alice speaks: preload admitted={eng.preloader.stats.admitted} "
        f"skipped={eng.preloader.stats.skipped} (pool saturated -> "
        f"sync fallback on turn start)")

    # ---- alice turn 2: on-path reload, zero re-prefill; barge-in ---
    eng.start_turn("alice", prompt(6), max_new_tokens=12)
    for _ in range(4):
        eng.step()
    eng.barge_in("alice", expected_dur_s=1.0)
    eng.clock.tick(1.0)

    # ---- alice turn 3 resumes on committed pages -------------------
    eng.start_turn("alice", prompt(5), max_new_tokens=6)
    eng.run_to_completion()
    eng.check_invariants()

    # ---- alice hangs up; bob speaks -> preload admitted ------------
    eng.end_session("alice")
    log(f"alice hung up: pool free={eng.pool.free_pages} pages; "
        f"bob offloaded={eng.monitor.page_counts('bob')[1]}")
    eng.user_speech_start("bob", expected_dur_s=2.5)
    eng.clock.tick(2.5)
    res, off = eng.monitor.page_counts("bob")
    log(f"bob speaks: preload admitted={eng.preloader.stats.admitted} "
        f"hits pending; resident={res} offloaded={off}")

    # ---- bob turn 2: warm KV, zero stall, zero re-prefill ----------
    eng.start_turn("bob", prompt(6), max_new_tokens=6)
    eng.run_to_completion()
    eng.check_invariants()

    all_stats = {}
    log("")
    log(f"{'session':>8} {'turn':>4} {'ctx':>5} {'prompt':>6} {'gen':>4} "
        f"{'ttft_ms':>8} {'reload_ms':>9} {'re_prefill':>10} {'aborted':>7}")
    for sid in ("alice", "bob"):
        stats = eng.sessions[sid].turn_stats
        all_stats[sid] = stats
        for t in stats:
            log(f"{sid:>8} {t['turn']:4d} {t['context_tokens']:5d} "
                f"{t['prompt_tokens']:6d} {t['generated']:4d} "
                f"{t['ttft_s'] * 1e3:8.1f} "
                f"{t['reload_stall_s'] * 1e3:9.3f} "
                f"{t['re_prefill_tokens']:10d} {str(t['aborted']):>7}")
    log("")
    log(f"preload: {eng.preloader.stats}")
    log(f"pool: {eng.pool.stats()}  evictions={len(eng.offload_events)}")
    return {"turns": all_stats,
            "preload": vars(eng.preloader.stats),
            "pool": eng.pool.stats(),
            "offload_events": len(eng.offload_events)}

"""Event-driven serving harness.

Runs the REAL LiveServe control plane — ``UrgencyScheduler``, ``KVManager``,
``Preloader``, ``RuntimeMonitor`` execute verbatim — against a virtual
clock. Stage execution time comes from the pipeline cost model
(DESIGN.md §2: only the data plane's wall-clock is modelled; every policy
decision is made by the actual implementation under test).

Structure
  SessionDriver   client behavior: VAD speech, playback, barge-in, turns
  StageEngine     continuous batching loop per AR stage (thinker, talker)
  Vocoder         FIFO chunk server delivering audio fragments
  Orchestrator    stage graph + barge-in abort propagation (paper §3)
  Simulation      wires everything, collects Metrics
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.core.kv_manager import KVManager
from repro.core.monitor import RuntimeMonitor
from repro.core.preload import Preloader
from repro.core.scheduler import (FCFSScheduler, RoundBudget,
                                  SchedulerConfig, UrgencyScheduler)
from repro.core.session import Phase, Request, RequestState, Session, Turn
from repro.serving.costmodel import PipelineSpec, StageSpec
from repro.serving.metrics import Metrics, TurnRecord
from repro.serving.simclock import EventQueue, VirtualClock
from repro.serving.workload import WorkloadConfig, generate

__all__ = ["Metrics", "TurnRecord", "Simulation", "StageEngine",
           "Vocoder", "run_sim"]


# ======================================================================
class Vocoder:
    """Lightweight FIFO chunk server (colocated CNN module)."""

    def __init__(self, sim, chunk_cost_s: float):
        self.sim = sim
        self.chunk_cost_s = chunk_cost_s
        self.busy_until = 0.0

    def submit(self, session_id: str, turn_index: int, tokens: int,
               last: bool) -> None:
        now = self.sim.clock.now()
        start = max(now, self.busy_until)
        done = start + self.chunk_cost_s
        self.busy_until = done
        self.sim.events.push(
            done, lambda: self.sim.on_audio_chunk(session_id, turn_index,
                                                  tokens, last))


# ======================================================================
class StageEngine:
    """Continuous batching loop with pluggable ordering policy."""

    def __init__(self, sim, spec: StageSpec, scheduler, kv: KVManager):
        self.sim = sim
        self.spec = spec
        self.scheduler = scheduler
        self.kv = kv
        self.requests: Dict[int, Request] = {}
        self.busy = False
        self.working_blocks: Dict[int, int] = {}

    # ------------------------------------------------------------ queue
    def submit(self, req: Request) -> None:
        self.requests[req.req_id] = req
        if self.kv is not None:
            self.kv.pin(req.session_id)
        self.kick()

    def abort(self, req: Request) -> None:
        req.state = RequestState.ABORTED
        self.requests.pop(req.req_id, None)
        self._release_working(req)

    def _release_working(self, req: Request) -> None:
        blocks = self.working_blocks.pop(req.req_id, 0)
        if self.kv is not None and blocks:
            self.kv.release_working(blocks)

    def finish(self, req: Request) -> None:
        req.state = RequestState.FINISHED
        req.finish_time = self.sim.clock.now()
        self.requests.pop(req.req_id, None)
        self._release_working(req)

    # ------------------------------------------------------------ rounds
    def _ready(self, now: float) -> List[Request]:
        out = []
        for r in self.requests.values():
            if not r.is_live():
                continue
            if not self.sim.can_progress(self.spec.name, r, now):
                continue
            out.append(r)
        return out

    def kick(self) -> None:
        if self.busy:
            return
        now = self.sim.clock.now()
        if self._ready(now):
            self._start_round()

    def _start_round(self) -> None:
        now = self.sim.clock.now()
        ready = self._ready(now)
        if not ready:
            return
        avail = (self.kv.capacity - self.kv.working_blocks
                 if self.kv is not None else 1 << 30)
        budget = RoundBudget(token_budget=self.spec.token_budget,
                             free_kv_blocks=avail,
                             max_batch=self.spec.max_batch,
                             block_size=self.spec.block_size)
        decision = self.scheduler.schedule(ready, budget, now)
        if not decision.batch:
            wake = self.scheduler.hold_wake_s(decision)
            if wake is not None:
                # everything pace-held: re-kick when the earliest buffer
                # drains back to the pacing threshold (playback is 1 s/s)
                self.sim.events.push_in(wake, self.kick)
            return
        admitted, prefill_tokens, decode_n = [], 0, 0
        for r in decision.batch:
            chunk = decision.chunks[r.req_id]
            if self.kv is not None:
                have = self.working_blocks.get(r.req_id, 0)
                work_tokens = r.prefilled + r.generated
                need = self.kv.blocks_of(work_tokens + chunk) - have
                if need > 0 and not self.kv.try_allocate_working(need, now):
                    continue                    # preempted this round
                if need > 0:
                    self.working_blocks[r.req_id] = have + need
            r.state = RequestState.RUNNING
            admitted.append((r, chunk))
            if r.phase == Phase.PREFILL and not r.done_prefill:
                prefill_tokens += chunk
            else:
                decode_n += 1
        if not admitted:
            return
        c = self.spec.cost
        dur = (c.round_overhead_s + c.prefill_token_s * prefill_tokens
               + c.decode_token_s * decode_n)
        self.busy = True
        self.sim.events.push_in(dur, lambda: self._finish_round(admitted))
        if self.kv is not None:
            self.kv.log_residency(now)

    def _finish_round(self, admitted) -> None:
        now = self.sim.clock.now()
        for r, chunk in admitted:
            if r.state == RequestState.ABORTED:
                continue                        # barge-in discarded the work
            if r.phase == Phase.PREFILL and not r.done_prefill:
                r.prefilled += chunk
                if r.done_prefill:
                    r.phase = Phase.DECODE
            else:
                r.generated += 1
                if r.first_output_time is None:
                    r.first_output_time = now
                self.sim.on_token(self.spec.name, r)
            if r.state != RequestState.ABORTED:
                r.state = RequestState.WAITING
        self.busy = False
        self.sim.on_round_done(self.spec.name)
        self.kick()


# ======================================================================
class Simulation:
    """Full pipeline: clients -> thinker -> talker -> vocoder -> playback."""

    def __init__(self, pipeline: PipelineSpec, workload: WorkloadConfig, *,
                 policy: str = "liveserve", sched_cfg=None,
                 kv_policy: Optional[str] = None,
                 preload: Optional[bool] = None,
                 eviction_index: str = "heap",
                 seed: int = 0):
        """policy: liveserve | fcfs (+ kv_policy/preload overrides for
        ablations). Baselines: fcfs+lru = vLLM-Omni w/ offload,
        fcfs+none = vLLM-Omni-wo."""
        self.pipeline = pipeline
        self.clock = VirtualClock()
        self.events = EventQueue(self.clock)
        self.monitor = RuntimeMonitor(self.clock)
        self.metrics = Metrics()
        self.policy = policy
        live = policy == "liveserve"
        kv_policy = kv_policy if kv_policy is not None else (
            "next_use" if live else "lru")
        use_preload = preload if preload is not None else live

        self.sessions: Dict[str, Session] = {}
        self.turn_records: Dict[tuple, TurnRecord] = {}
        self.live_requests: Dict[tuple, Request] = {}   # (sid, stage)
        self.talker_limit: Dict[str, int] = {}          # sid -> avail tokens
        self.thinker_target: Dict[str, int] = {}
        self.audio_outstanding: Dict[str, int] = {}     # undelivered chunks
        self.barge_scheduled: Dict[tuple, bool] = {}

        self._turn_started: set = set()
        self._done_sessions: set = set()
        self.engines: Dict[str, StageEngine] = {}
        self.kvs: Dict[str, KVManager] = {}
        for st in pipeline.stages:
            kv = KVManager(
                capacity_blocks=st.kv_capacity_blocks,
                block_size=st.block_size,
                bytes_per_token=st.kv_bytes_per_token,
                monitor=self.monitor, policy=kv_policy,
                index_mode=eviction_index,
                pcie_gb_s=pipeline.pcie_gb_s, clock=self.clock)
            cfg = sched_cfg or SchedulerConfig()
            if live:
                sched = UrgencyScheduler(
                    cfg, self.monitor, stage=st.name,
                    buffer_estimator=self._make_buffer_est(st.name),
                    kv_occupancy=kv.occupancy,
                    kv_of_request=lambda r, _kv=kv:
                        float(_kv.session(r.session_id).total_blocks
                              + _kv.blocks_of(r.prefilled + r.generated)))
            else:
                sched = FCFSScheduler(self.monitor, stage=st.name)
            self.kvs[st.name] = kv
            self.engines[st.name] = StageEngine(self, st, sched, kv)
        self.preloaders = {
            name: Preloader(kv, self.monitor,
                            encode_delay_s=pipeline.encode_delay_s,
                            enabled=use_preload)
            for name, kv in self.kvs.items()}
        self.vocoder = Vocoder(self, pipeline.vocoder_chunk_s)

        self.workload_cfg = workload
        self._pending_sessions = generate(workload)
        self._active = 0
        self._started = 0
        self.seed = seed

    # ---------------------------------------------------------- helpers
    def _make_buffer_est(self, stage: str):
        apt = self.pipeline.audio_per_token_s
        spt = self.pipeline.speech_per_text

        def est(req: Request) -> Optional[float]:
            buf = self.monitor.playback_buffer_s(req.session_id)
            if buf is None:
                return None
            if stage == "thinker":
                talker = self.live_requests.get((req.session_id, "talker"))
                consumed = talker.generated if talker else 0
                backlog = max(0, req.generated * spt - consumed) * apt
                return buf + backlog
            # talker: client buffer + undelivered vocoder chunks
            chunks = self.audio_outstanding.get(req.session_id, 0)
            return buf + chunks * self.pipeline.vocoder_chunk * apt
        return est

    def rec(self, sid: str, turn: int) -> TurnRecord:
        key = (sid, turn)
        if key not in self.turn_records:
            self.turn_records[key] = TurnRecord(session_id=sid,
                                                turn_index=turn)
            self.metrics.turns.append(self.turn_records[key])
        return self.turn_records[key]

    # ---------------------------------------------------------- lifecycle
    def run(self, *, until: float = 3600.0) -> Metrics:
        cc = self.workload_cfg.concurrency
        n0 = cc if cc else len(self._pending_sessions)
        for _ in range(min(n0, len(self._pending_sessions))):
            self._launch_next_session()
        self.events.run(until=until)
        self.metrics.sim_end = self.clock.now()
        return self.metrics

    def _launch_next_session(self) -> None:
        if not self._pending_sessions:
            return
        s = self._pending_sessions.pop(0)
        self.sessions[s.session_id] = s
        self._active += 1
        self._started += 1
        start = (self.clock.now() if self.workload_cfg.concurrency
                 else max(self.clock.now(), s.arrival_time))
        self.events.push(start, lambda: self._speech_start(s, 0))

    def _session_done(self, s: Session) -> None:
        if s.session_id in self._done_sessions:
            return
        self._done_sessions.add(s.session_id)
        self._active -= 1
        self.metrics.completed_sessions += 1
        for kv in self.kvs.values():
            kv.unpin(s.session_id, self.clock.now())
        if self.workload_cfg.concurrency:
            self._launch_next_session()

    # ---------------------------------------------------------- turns
    def _speech_start(self, s: Session, turn_idx: int) -> None:
        if turn_idx >= len(s.turns):
            self._session_done(s)
            return
        if (s.session_id, turn_idx) in self._turn_started:
            return                        # stale duplicate (barge-in race)
        self._turn_started.add((s.session_id, turn_idx))
        s.current_turn = turn_idx
        turn = s.turns[turn_idx]
        now = self.clock.now()
        self.monitor.on_turn_start(s.session_id, turn_idx)
        dur = turn.speech_end            # speech duration stored there
        self.monitor.on_speech_start(s.session_id, expected_dur_s=dur)
        for pre in self.preloaders.values():
            pre.on_speech_start(s.session_id, now)
        self.events.push_in(dur, lambda: self._speech_end(s, turn_idx))

    def _speech_end(self, s: Session, turn_idx: int) -> None:
        self.monitor.on_speech_end(s.session_id)
        self.events.push_in(self.pipeline.encode_delay_s,
                            lambda: self._turn_arrival(s, turn_idx))

    def _turn_arrival(self, s: Session, turn_idx: int) -> None:
        now = self.clock.now()
        turn = s.turns[turn_idx]
        rec = self.rec(s.session_id, turn_idx)
        rec.speech_end = now - self.pipeline.encode_delay_s
        # KV reload on the critical path (or warm preload hit)
        stall = self.preloaders["thinker"].on_turn_ready(s.session_id, now)
        stall += self.preloaders["talker"].on_turn_ready(s.session_id, now)
        rec.reload_stall_s = stall
        rec.reload_off_path_s = sum(
            pre.pop_split(s.session_id)[1]
            for pre in self.preloaders.values())
        prompt = turn.prompt_len
        recompute = self.kvs["thinker"].recompute_tokens(s.session_id)
        if recompute:
            prompt += recompute          # 'none' policy re-prefills history
            kv = self.kvs["thinker"].session(s.session_id)
            kv.total_blocks -= kv.dram_blocks
            kv.discarded = False
        text_target = max(2, turn.response_tokens
                          // self.pipeline.speech_per_text)
        req = Request(session_id=s.session_id, stage="thinker",
                      turn_index=turn_idx, arrival_time=now + stall,
                      prompt_len=prompt, context_len=s.context_tokens,
                      max_new_tokens=text_target,
                      audio_per_token_s=self.pipeline.audio_per_token_s)
        self.thinker_target[s.session_id] = text_target
        self.live_requests[(s.session_id, "thinker")] = req
        if stall > 0:
            self.events.push_in(
                stall, lambda: self.engines["thinker"].submit(req))
        else:
            self.engines["thinker"].submit(req)

    # ---------------------------------------------------------- coupling
    def can_progress(self, stage: str, req: Request, now: float) -> bool:
        if now + 1e-12 < req.arrival_time:
            return False
        if req.phase == Phase.PREFILL and not req.done_prefill:
            return True
        if req.generated >= req.max_new_tokens:
            return False
        if stage == "talker":
            return req.generated < self.talker_limit.get(req.session_id, 0)
        return True

    def on_token(self, stage: str, req: Request) -> None:
        sid = req.session_id
        now = self.clock.now()
        s = self.sessions[sid]
        turn = s.turns[req.turn_index]
        rec = self.rec(sid, req.turn_index)
        if stage == "thinker":
            if rec.text_ttft is None:
                rec.text_ttft = now - rec.speech_end
            spt = self.pipeline.speech_per_text
            chunk = self.pipeline.thinker_chunk
            done = req.generated >= req.max_new_tokens
            ready_text = (req.generated if done
                          else (req.generated // chunk) * chunk)
            self.talker_limit[sid] = (turn.response_tokens if done else
                                      min(turn.response_tokens,
                                          ready_text * spt))
            if (sid, "talker") not in self.live_requests \
                    and ready_text > 0:
                t_req = Request(
                    session_id=sid, stage="talker",
                    turn_index=req.turn_index, arrival_time=now,
                    prompt_len=0, context_len=s.context_tokens,
                    max_new_tokens=turn.response_tokens,
                    audio_per_token_s=self.pipeline.audio_per_token_s)
                t_req.phase = Phase.DECODE
                self.live_requests[(sid, "talker")] = t_req
                self.engines["talker"].submit(t_req)
            else:
                self.engines["talker"].kick()
            if done:
                self.engines["thinker"].finish(req)
                self._commit_stage_kv("thinker", sid, req)
        elif stage == "talker":
            rec.talker_generated += 1
            vchunk = self.pipeline.vocoder_chunk
            done = req.generated >= req.max_new_tokens
            if req.generated % vchunk == 0 or done:
                pending = req.generated % vchunk or vchunk
                self.audio_outstanding[sid] = \
                    self.audio_outstanding.get(sid, 0) + 1
                self.vocoder.submit(sid, req.turn_index, pending, done)
            if done:
                self.engines["talker"].finish(req)
                self._commit_stage_kv("talker", sid, req)

    def _commit_stage_kv(self, stage: str, sid: str, req: Request) -> None:
        s = self.sessions[sid]
        kv = self.kvs[stage]
        total = req.context_len + req.prefilled + req.generated
        self.engines[stage]._release_working(req)
        kv.commit_turn(sid, total, self.clock.now())
        if stage == "thinker":
            s.context_tokens = total

    def on_round_done(self, stage: str) -> None:
        # cross-engine wakeups: talker may have become schedulable
        for e in self.engines.values():
            e.kick()

    # ---------------------------------------------------------- audio
    def on_audio_chunk(self, sid: str, turn_idx: int, tokens: int,
                       last: bool) -> None:
        now = self.clock.now()
        rec = self.rec(sid, turn_idx)
        if rec.barged:
            return                        # audio after abort is dropped
        self.audio_outstanding[sid] = max(
            0, self.audio_outstanding.get(sid, 0) - 1)
        dur = tokens * self.pipeline.audio_per_token_s
        if rec.ttfp is None:
            rec.ttfp = now - rec.speech_end
            s = self.sessions[sid]
            turn = s.turns[turn_idx]
            if turn.barge_in and not self.barge_scheduled.get(
                    (sid, turn_idx)):
                self.barge_scheduled[(sid, turn_idx)] = True
                self.events.push_in(
                    turn.barge_cut_s,
                    lambda: self._barge_in(sid, turn_idx))
        self.monitor.on_audio(sid, dur)
        rec.audio_delivered_s += dur
        if last:
            self._response_complete(sid, turn_idx)

    def _response_complete(self, sid: str, turn_idx: int) -> None:
        now = self.clock.now()
        rec = self.rec(sid, turn_idx)
        if rec.barged:
            return
        self.monitor.on_response_complete(sid)
        v = self.monitor.view(sid)
        rec.max_gap_s = v.playback.gap_s and v.playback.max_gap_s or 0.0
        rec.n_gaps = v.playback.n_gaps
        rec.gen_span_s = now - rec.speech_end - (rec.ttfp or 0.0)
        rec.completed = True
        rec.finish_time = now
        self.live_requests.pop((sid, "thinker"), None)
        self.live_requests.pop((sid, "talker"), None)
        # playback continues; next turn after it drains + think time
        s = self.sessions[sid]
        drain = v.playback.buffer_s(now)
        if turn_idx + 1 < len(s.turns):
            self.events.push_in(
                drain + s.think_time_s,
                lambda: self._speech_start(s, turn_idx + 1))
        else:
            self.events.push_in(drain, lambda: self._session_done(s))

    # ---------------------------------------------------------- barge-in
    def _barge_in(self, sid: str, turn_idx: int) -> None:
        now = self.clock.now()
        s = self.sessions[sid]
        if s.current_turn > turn_idx or sid in self._done_sessions:
            return                        # a later turn already started
        rec = self.rec(sid, turn_idx)
        if rec.completed and self.monitor.view(sid).playback.buffer_s(
                now) <= 0:
            return                        # playback already finished
        rec.barged = True
        v = self.monitor.view(sid)
        heard = v.playback.consumed_s(now)
        rec.audio_heard_s = heard
        heard_tokens = int(heard / self.pipeline.audio_per_token_s)
        rec.talker_wasted = max(0, rec.talker_generated - heard_tokens)
        # abort in-flight work, discard beyond playback point (paper §3)
        for stage in ("thinker", "talker"):
            req = self.live_requests.pop((sid, stage), None)
            if req is not None and req.is_live():
                self.engines[stage].abort(req)
                # KV up to the heard point is kept for the next turn
                if stage == "thinker":
                    kept = req.prefilled + min(
                        req.generated,
                        heard_tokens // self.pipeline.speech_per_text)
                    total = req.context_len + kept
                    self.kvs[stage].commit_turn(sid, total, now)
                    s.context_tokens = total
                else:
                    self.kvs[stage].commit_turn(
                        sid, req.context_len + heard_tokens, now)
        self.monitor.on_barge_in(sid)
        for pre in self.preloaders.values():
            pre.on_speech_start(sid, now)   # barge-in preload trigger
        rec.finish_time = now
        # the interrupting utterance becomes the next turn
        if turn_idx + 1 < len(s.turns):
            self._speech_start(s, turn_idx + 1)
        else:
            self._session_done(s)


# ======================================================================
def run_sim(pipeline: PipelineSpec, workload: WorkloadConfig, *,
            policy: str = "liveserve", until: float = 3600.0,
            **kw) -> Metrics:
    sim = Simulation(pipeline, workload, policy=policy, **kw)
    return sim.run(until=until)

"""Real-model realtime engine — the CPU-runnable data plane.

Drives an actual JAX model (prefill + slot-batched decode) under the
LiveServe control plane: each round the UrgencyScheduler picks which
sessions advance; unscheduled slots are held by rewinding their cache
length (their KV slot is overwritten on the next committed step, so
scheduling affects *when* tokens are produced, never *which* — the
paper's correctness contract, verified in tests/test_real_engine.py).

This is the TPU-idiomatic static-slot continuous batching of DESIGN.md §3
(JetStream-style): fixed decode batch, scheduler fills slots.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kv_manager import KVManager
from repro.core.monitor import RuntimeMonitor
from repro.core.scheduler import RoundBudget, SchedulerConfig, \
    UrgencyScheduler
from repro.core.session import Phase, Request, RequestState
from repro.models import decode_step, init_cache, init_params, prefill


class RoundLimitExceeded(RuntimeError):
    """``run_to_completion`` exhausted its round budget with work still
    live. Raised instead of returning normally so a scheduler live-lock
    (or a turn that never finishes) can't masquerade as a completed run
    in tests and benchmarks."""


@dataclass
class SlotState:
    session_id: str
    request: Request
    pending_token: int              # next token to feed
    tokens: List[int] = field(default_factory=list)
    working_blocks: int = 0         # KV blocks actually acquired


def schedule_round(scheduler, kv, clock, slot_state, act, token_budget, *,
                   block_size: int = 16):
    """One admission round, shared by both engines: free KV plus
    reclaimable idle KV (eviction frees it on demand) against the token
    budget. Returns (scheduled slot ids, per-slot token grants) — the
    scheduler's ``chunk_for`` decision, so a PREFILL slot's chunk grant
    survives the trip through the self-scheduled path (the dense engine
    ignores the grants; its slots are always DECODE)."""
    budget = RoundBudget(
        token_budget=token_budget,
        free_kv_blocks=kv.free_blocks
        + kv.reclaimable_blocks(clock.now()),
        block_size=block_size)
    decision = scheduler.schedule([s.request for s in act], budget,
                                  clock.now())
    sched_ids = {r.req_id: decision.chunks[r.req_id]
                 for r in decision.batch}
    slots = [i for i, s in slot_state.items()
             if s and s.request.req_id in sched_ids]
    return slots, {i: sched_ids[slot_state[i].request.req_id]
                   for i in slots}


class RealtimeLLMEngine:
    def __init__(self, cfg, params, *, slots: int = 4, capacity: int = 256,
                 clock=None, scheduler: Optional[UrgencyScheduler] = None,
                 kv: Optional[KVManager] = None):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.capacity = capacity
        self.clock = clock or _StepClock()
        self.monitor = RuntimeMonitor(self.clock)
        self.kv = kv or KVManager(
            capacity_blocks=slots * (capacity // 16) * 2, block_size=16,
            bytes_per_token=1024.0, monitor=self.monitor, clock=self.clock)
        self.scheduler = scheduler or UrgencyScheduler(
            SchedulerConfig(), self.monitor, stage="thinker",
            kv_occupancy=self.kv.occupancy)
        self.cache = init_cache(cfg, slots, capacity)
        self.slot_state: Dict[int, Optional[SlotState]] = {
            i: None for i in range(slots)}
        self._decode = jax.jit(
            lambda p, t, c: decode_step(cfg, p, t, c))

    # ------------------------------------------------------------ admit
    def free_slot(self) -> Optional[int]:
        for i, s in self.slot_state.items():
            if s is None:
                return i
        return None

    def add_session(self, session_id: str, prompt: np.ndarray,
                    max_new_tokens: int) -> int:
        """Prefill the prompt into a free slot; returns the slot id."""
        slot = self.free_slot()
        assert slot is not None, "no free decode slot"
        self.monitor.register(session_id)
        prompt = jnp.asarray(prompt, jnp.int32)[None, :]
        # slot-isolated prefill: run a B=1 prefill then graft into the slot
        c1 = init_cache(self.cfg, 1, self.capacity)
        logits, c1 = prefill(self.cfg, self.params, prompt, c1)
        self.cache = jax.tree.map(
            lambda buf, one: buf.at[_slot_index(buf, self.slots, slot)].set(
                one[0]) if buf.ndim >= 1 else buf,
            self.cache, _broadcast_like(c1, self.cache, self.slots))
        self.cache = _set_len(self.cache, slot, int(c1["len"][0]))
        req = Request(session_id=session_id, stage="thinker", turn_index=0,
                      arrival_time=self.clock.now(),
                      prompt_len=int(prompt.shape[1]),
                      max_new_tokens=max_new_tokens)
        req.phase = Phase.DECODE
        req.prefilled = req.prompt_len
        self.kv.pin(session_id)
        blocks = self.kv.blocks_of(req.prompt_len)
        got = blocks if self.kv.try_allocate_working(
            blocks, self.clock.now()) else 0
        tok = int(jnp.argmax(logits[0]))
        self.slot_state[slot] = SlotState(session_id, req, tok, [tok],
                                          working_blocks=got)
        return slot

    def abort(self, session_id: str) -> None:
        """Barge-in: drop the in-flight request, keep committed KV."""
        for i, s in self.slot_state.items():
            if s and s.session_id == session_id:
                s.request.state = RequestState.ABORTED
                self._commit(s)
                self.slot_state[i] = None

    def _commit(self, s: SlotState) -> None:
        """Turn over: the working allocation becomes committed session
        KV (releasing both would double-count the same blocks). Only
        blocks actually acquired are released — an allocation that
        failed at admission must not drain other sessions' share."""
        self.kv.release_working(s.working_blocks)
        self.kv.commit_turn(s.session_id, s.request.total_context,
                            self.clock.now())

    # ------------------------------------------------------------ rounds
    def active(self) -> List[SlotState]:
        return [s for s in self.slot_state.values()
                if s is not None and s.request.is_live()
                and s.request.generated < s.request.max_new_tokens]

    def step(self) -> List[int]:
        """One scheduling round + one batched decode. Returns scheduled
        slot ids."""
        self.clock.tick()
        act = self.active()
        if not act:
            return []
        sched_slots, _ = schedule_round(self.scheduler, self.kv,
                                        self.clock, self.slot_state, act,
                                        self.slots)
        if not sched_slots:
            return []
        tokens = jnp.asarray(
            [self.slot_state[i].pending_token
             if self.slot_state[i] else 0 for i in range(self.slots)],
            jnp.int32)
        mask = np.zeros((self.slots,), bool)
        mask[sched_slots] = True
        logits, new_cache = self._decode(self.params, tokens, self.cache)
        # hold unscheduled slots: rewind their cache length by one (their
        # stale KV entry is overwritten the next time they are scheduled)
        new_len = jnp.where(jnp.asarray(mask), new_cache["len"],
                            new_cache["len"] - 1)
        new_cache["len"] = new_len
        self.cache = new_cache
        nxt = jnp.argmax(logits, axis=-1)
        for i in sched_slots:
            s = self.slot_state[i]
            s.request.generated += 1
            if s.request.first_output_time is None:
                s.request.first_output_time = self.clock.now()
            tok = int(nxt[i])
            s.pending_token = tok
            if s.request.generated < s.request.max_new_tokens:
                s.tokens.append(tok)
            else:
                s.request.state = RequestState.FINISHED
                self._commit(s)
        return sched_slots

    def run_to_completion(self, max_rounds: int = 10_000) -> Dict[str, list]:
        for _ in range(max_rounds):
            if not self.active():
                break
            self.step()
        if self.active():
            raise RoundLimitExceeded(
                f"{len(self.active())} slots still live after "
                f"{max_rounds} rounds")
        return {s.session_id: s.tokens
                for s in self.slot_state.values() if s is not None}


# ---------------------------------------------------------------- helpers
class _StepClock:
    def __init__(self):
        self.t = 0.0

    def tick(self, dt: float = 0.01):
        self.t += dt

    def now(self):
        return self.t


def _slot_index(buf, slots: int, slot: int):
    """Cache leaves are [L, B, ...] or [B, ...]; find the B axis."""
    if buf.ndim >= 2 and buf.shape[1] == slots:
        return (slice(None), slot)
    return (slot,)


def _broadcast_like(one_cache, slot_cache, slots: int):
    """Pad a B=1 cache pytree so leaf shapes line up for grafting."""
    def pad(one, full):
        return one
    return jax.tree.map(pad, one_cache, slot_cache)


def _set_len(cache, slot: int, value: int):
    cache = dict(cache)
    cache["len"] = cache["len"].at[slot].set(value)
    return cache

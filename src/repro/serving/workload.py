"""Workload generators — the paper's three data sources + arrival processes.

- sharegpt: single-turn conversational prompts, wide prompt/response
  spread (ShareGPT-Chinese-English-90K-like length distributions).
- interactive: multi-turn voice sessions with think-time gaps and growing
  context (retained interaction traces of the paper).
- mixed: interactive sessions + video events with large prefill
  (StreamingBench-like media turns).

Arrivals: closed-loop concurrency bound c (the paper's frontier sweeps),
open-loop Poisson, and BurstGPT-like bursty arrivals (Gamma-modulated
rate spikes). Barge-in: per-request Bernoulli(p_bi), cut anchored at TTFP
plus a draw from the output-audio-duration distribution (§7.1).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

from repro.core.session import Session, Turn


@dataclass
class WorkloadConfig:
    kind: str = "sharegpt"           # sharegpt | interactive | mixed
    num_sessions: int = 32
    p_barge_in: float = 0.0
    seed: int = 0
    # closed loop
    concurrency: Optional[int] = None
    # open loop
    arrival: str = "poisson"         # poisson | burstgpt
    rate_rps: float = 2.0
    burst_factor: float = 4.0        # peak/mean rate for burstgpt
    burst_period_s: float = 20.0
    audio_per_token_s: float = 0.08
    # shared-system-prompt families: sessions are assigned round-robin
    # to K families; sessions in the same family open their first turn
    # with an identical ``family_prefix_len``-token seeded prefix
    # (drawn by ``family_prefix``), so the prefix cache can attach
    # later arrivals to the first session's committed pages. 0 = off.
    prompt_families: int = 0
    family_prefix_len: int = 0


def _lognormal(rng, mean, sigma, lo, hi):
    v = rng.lognormal(math.log(mean), sigma)
    return float(min(max(v, lo), hi))


def _make_turns(rng, cfg: WorkloadConfig, kind: str) -> List[Turn]:
    turns = []
    if kind == "sharegpt":
        n_turns = 1
    elif kind == "interactive":
        n_turns = int(rng.integers(3, 8))
    else:  # mixed: interactive with a chance of a video-heavy turn
        n_turns = int(rng.integers(2, 6))
    for i in range(n_turns):
        if kind == "sharegpt":
            prompt = int(_lognormal(rng, 600, 0.8, 40, 6000))
            resp_audio_s = _lognormal(rng, 22, 0.7, 3, 90)
        elif kind == "interactive":
            prompt = int(_lognormal(rng, 120, 0.6, 20, 1200))
            resp_audio_s = _lognormal(rng, 12, 0.6, 2, 60)
        else:
            video = rng.random() < 0.35
            prompt = int(_lognormal(rng, 4000 if video else 150, 0.5,
                                    30, 10000))
            resp_audio_s = _lognormal(rng, 15, 0.6, 2, 70)
        resp_tokens = max(8, int(resp_audio_s / cfg.audio_per_token_s))
        barge = rng.random() < cfg.p_barge_in
        cut = float(rng.uniform(0.15, 0.75)) * resp_audio_s if barge else 0.0
        speech_dur = _lognormal(rng, 2.5, 0.5, 0.6, 8.0)
        turns.append(Turn(index=i, speech_start=0.0, speech_end=speech_dur,
                          prompt_len=prompt, response_tokens=resp_tokens,
                          barge_in=barge, barge_cut_s=cut))
    return turns


def _arrival_times(rng, cfg: WorkloadConfig) -> List[float]:
    if cfg.concurrency is not None:
        # closed loop: session k>=c starts when an earlier one finishes;
        # the simulator handles gating, we just mark the first c at t=0.
        return [0.0] * cfg.num_sessions
    times, t = [], 0.0
    for i in range(cfg.num_sessions):
        if cfg.arrival == "poisson":
            t += rng.exponential(1.0 / cfg.rate_rps)
        else:  # burstgpt-like: rate modulated by a square burst wave
            phase = (t % cfg.burst_period_s) / cfg.burst_period_s
            rate = cfg.rate_rps * (cfg.burst_factor if phase < 0.3
                                   else max(0.1, (1 - 0.3 * cfg.burst_factor)
                                            / 0.7))
            t += rng.exponential(1.0 / max(rate, 1e-3))
        times.append(t)
    return times


def generate(cfg: WorkloadConfig) -> List[Session]:
    rng = np.random.default_rng(cfg.seed)
    arrivals = _arrival_times(rng, cfg)
    sessions = []
    for i, t0 in enumerate(arrivals):
        turns = _make_turns(rng, cfg, cfg.kind)
        think = _lognormal(rng, 2.0, 0.5, 0.5, 8.0)
        family = i % cfg.prompt_families if cfg.prompt_families > 0 else -1
        sessions.append(Session(
            session_id=f"s{i:04d}", turns=turns, arrival_time=t0,
            think_time_s=think, family=family))
    return sessions


def family_prefix(cfg: WorkloadConfig, family: int, vocab: int,
                  seed: int) -> np.ndarray:
    """The shared system-prompt tokens for one family: a seeded draw
    keyed on (seed, family) only, so every session in the family — and
    every engine/gateway replaying the same workload — prepends the
    exact same tokens to its first-turn prompt."""
    rng = np.random.default_rng([seed, 1_000_003 + family])
    return rng.integers(0, vocab,
                        size=cfg.family_prefix_len).astype(np.int32)

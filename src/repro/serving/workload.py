"""Workload generators — the paper's three data sources + arrival processes.

- sharegpt: single-turn conversational prompts, wide prompt/response
  spread (ShareGPT-Chinese-English-90K-like length distributions).
- interactive: multi-turn voice sessions with think-time gaps and growing
  context (retained interaction traces of the paper).
- mixed: interactive sessions + video events with large prefill
  (StreamingBench-like media turns).
- duplex: full-duplex periodic-frame sessions (Moshi/MiniCPM-o-like) —
  the turn request fires the instant speech starts and every output
  token carries a hard per-frame deadline (``frame_period_tokens``
  output-token durations per frame); no idle speech window exists.
- toolcall: agentic sessions whose turns may end in a tool call — the
  session idles with hot KV for ``tool_latency_s`` while the external
  tool runs, then resumes without a new utterance.
- handoff: multi-turn sessions that request a transfer to a different
  model config/replica between turns (rides the fleet MIGRATE path).

Arrivals: closed-loop concurrency bound c (the paper's frontier sweeps),
open-loop Poisson, and BurstGPT-like bursty arrivals (Gamma-modulated
rate spikes). Barge-in: per-request Bernoulli(p_bi), cut anchored at TTFP
plus a draw from the output-audio-duration distribution (§7.1).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

from repro.core.session import Session, Turn

# gap between a ToolCallResult and the resume TurnRequest — part of the
# trace's interpretation, so both the live client and the replay twin
# must read the same constant (the preload window a resume hides in)
TOOL_RESUME_GAP_S = 0.6


@dataclass
class WorkloadConfig:
    kind: str = "sharegpt"           # sharegpt | interactive | mixed |
    #                                  duplex | toolcall | handoff
    num_sessions: int = 32
    p_barge_in: float = 0.0
    seed: int = 0
    # closed loop
    concurrency: Optional[int] = None
    # open loop
    arrival: str = "poisson"         # poisson | burstgpt
    rate_rps: float = 2.0
    burst_factor: float = 4.0        # peak/mean rate for burstgpt
    burst_period_s: float = 20.0
    audio_per_token_s: float = 0.08
    # shared-system-prompt families: sessions are assigned round-robin
    # to K families; sessions in the same family open their first turn
    # with an identical ``family_prefix_len``-token seeded prefix
    # (drawn by ``family_prefix``), so the prefix cache can attach
    # later arrivals to the first session's committed pages. 0 = off.
    prompt_families: int = 0
    family_prefix_len: int = 0


def _lognormal(rng, mean, sigma, lo, hi):
    v = rng.lognormal(math.log(mean), sigma)
    return float(min(max(v, lo), hi))


def _make_turns(rng, cfg: WorkloadConfig, kind: str) -> List[Turn]:
    turns = []
    if kind == "sharegpt":
        n_turns = 1
    elif kind == "interactive":
        n_turns = int(rng.integers(3, 8))
    elif kind == "duplex":
        n_turns = int(rng.integers(1, 4))
    elif kind == "toolcall":
        n_turns = int(rng.integers(3, 6))
    elif kind == "handoff":
        n_turns = int(rng.integers(2, 5))
    else:  # mixed: interactive with a chance of a video-heavy turn
        n_turns = int(rng.integers(2, 6))
    for i in range(n_turns):
        frame_period = 0.0
        tool_call, tool_latency = False, 0.0
        handoff, handoff_target = False, 0
        if kind == "sharegpt":
            prompt = int(_lognormal(rng, 600, 0.8, 40, 6000))
            resp_audio_s = _lognormal(rng, 22, 0.7, 3, 90)
        elif kind == "interactive":
            prompt = int(_lognormal(rng, 120, 0.6, 20, 1200))
            resp_audio_s = _lognormal(rng, 12, 0.6, 2, 60)
        elif kind == "duplex":
            # full duplex: the request fires at speech start, frames tick
            # from the first output token — short prompts, no barge-in
            # (the user never yields the channel in the first place)
            prompt = int(_lognormal(rng, 40, 0.4, 8, 200))
            resp_audio_s = _lognormal(rng, 8, 0.5, 2, 30)
            frame_period = float(rng.uniform(2.0, 4.0))
        elif kind == "toolcall":
            prompt = int(_lognormal(rng, 120, 0.6, 20, 1200))
            resp_audio_s = _lognormal(rng, 10, 0.6, 2, 50)
            if i + 1 < n_turns:
                tool_call = rng.random() < 0.6
                tool_latency = _lognormal(rng, 2.5, 0.4, 0.8, 8.0)
                if not tool_call:
                    tool_latency = 0.0
        elif kind == "handoff":
            prompt = int(_lognormal(rng, 120, 0.6, 20, 1200))
            resp_audio_s = _lognormal(rng, 10, 0.6, 2, 50)
            if i >= 1:
                handoff = rng.random() < 0.5
                handoff_target = int(rng.integers(0, 8))
                if not handoff:
                    handoff_target = 0
        else:
            video = rng.random() < 0.35
            prompt = int(_lognormal(rng, 4000 if video else 150, 0.5,
                                    30, 10000))
            resp_audio_s = _lognormal(rng, 15, 0.6, 2, 70)
        resp_tokens = max(8, int(resp_audio_s / cfg.audio_per_token_s))
        barge = (rng.random() < cfg.p_barge_in) and kind != "duplex"
        cut = float(rng.uniform(0.15, 0.75)) * resp_audio_s if barge else 0.0
        speech_dur = _lognormal(rng, 2.5, 0.5, 0.6, 8.0)
        turns.append(Turn(index=i, speech_start=0.0, speech_end=speech_dur,
                          prompt_len=prompt, response_tokens=resp_tokens,
                          barge_in=barge, barge_cut_s=cut,
                          frame_period_tokens=frame_period,
                          tool_call=tool_call, tool_latency_s=tool_latency,
                          handoff=handoff, handoff_target=handoff_target))
    return turns


def _burst_wave(cfg: WorkloadConfig):
    """The burstgpt square wave as (duty, peak_rate, off_rate), derived
    so the time-averaged rate is exactly ``rate_rps`` (burst_factor is
    the documented peak/mean ratio). The nominal burst duty is 0.3 of
    the period; for burst_factor > 1/0.3 that would need a negative
    off-phase rate, so the duty shrinks to 1/burst_factor and the off
    phase goes silent instead."""
    bf = max(1.0, cfg.burst_factor)
    duty = min(0.3, 1.0 / bf)
    peak = cfg.rate_rps * bf
    off = cfg.rate_rps * max(0.0, 1.0 - duty * bf) / (1.0 - duty) \
        if duty < 1.0 else 0.0
    return duty, peak, off


def _next_burst_arrival(rng, cfg: WorkloadConfig, t: float) -> float:
    """Next arrival of the square-wave-modulated Poisson process after
    ``t``: draw a unit-mean exponential hazard target and integrate the
    piecewise-constant rate forward until it is met. Exact for any
    duty/peak/off triple, including a silent off phase."""
    duty, peak, off = _burst_wave(cfg)
    period = cfg.burst_period_s
    need = rng.exponential(1.0)
    while need > 1e-12:
        start = t - (t % period)
        in_burst = (t - start) < duty * period
        rate = peak if in_burst else off
        seg_end = start + (duty * period if in_burst else period)
        if rate <= 0.0:
            t = seg_end
            continue
        if need <= (seg_end - t) * rate:
            return t + need / rate
        need -= (seg_end - t) * rate
        t = seg_end
    return t


def _arrival_times(rng, cfg: WorkloadConfig) -> List[float]:
    if cfg.concurrency is not None:
        # closed loop: session k>=c starts when an earlier one finishes;
        # the simulator handles gating, we just mark the first c at t=0.
        return [0.0] * cfg.num_sessions
    times, t = [], 0.0
    for i in range(cfg.num_sessions):
        if cfg.arrival == "poisson":
            t += rng.exponential(1.0 / cfg.rate_rps)
        else:  # burstgpt-like: mean-conserving square-wave modulation
            t = _next_burst_arrival(rng, cfg, t)
        times.append(t)
    return times


def generate(cfg: WorkloadConfig) -> List[Session]:
    rng = np.random.default_rng(cfg.seed)
    arrivals = _arrival_times(rng, cfg)
    sessions = []
    for i, t0 in enumerate(arrivals):
        turns = _make_turns(rng, cfg, cfg.kind)
        think = _lognormal(rng, 2.0, 0.5, 0.5, 8.0)
        family = i % cfg.prompt_families if cfg.prompt_families > 0 else -1
        sessions.append(Session(
            session_id=f"s{i:04d}", turns=turns, arrival_time=t0,
            think_time_s=think, family=family))
    return sessions


def family_prefix(cfg: WorkloadConfig, family: int, vocab: int,
                  seed: int) -> np.ndarray:
    """The shared system-prompt tokens for one family: a seeded draw
    keyed on (seed, family) only, so every session in the family — and
    every engine/gateway replaying the same workload — prepends the
    exact same tokens to its first-turn prompt."""
    rng = np.random.default_rng([seed, 1_000_003 + family])
    return rng.integers(0, vocab,
                        size=cfg.family_prefix_len).astype(np.int32)

"""Session routing for the replica fleet (DESIGN.md §12).

The router owns three decisions, all appended to one auditable log the
fleet differential (tests/test_fleet_differential.py) compares between
the asyncio gateway and its virtual-time twin:

- ``("route", sid, replica)`` — admission: a new session lands on the
  least-pressured non-draining replica. Pressure is (sessions placed,
  live slots, -free pages), index-tiebroken; at connect time every
  replica is pristine, so routing degenerates to deterministic
  round-robin in trace order — which is exactly what makes the
  decision log twin-comparable.
- ``("drain", replica)`` / ``("recover", replica)`` — a replica stops
  taking new sessions. Either injected deterministically
  (``drain_after_routes``, used by the differential and the bench's
  forced-migration scenario) or decided by the hardened
  ``StragglerMitigator`` fed with per-replica round durations; the
  mitigator's consecutive-good-round forgiveness lifts a straggler
  drain again.
- ``("migrate", sid, src, dst)`` — at a speech start, a session placed
  on a draining replica moves to a non-draining replica in ring order
  from the source, offset by the session's admission index so a
  drained replica's sessions spread over the healthy ones instead of
  dog-piling its ring neighbour. Admission-index ring order (not
  pressure argmin) is deliberate: the destination choice must not
  depend on timing-sensitive cross-session pool state, or the twin and
  the live gateway would diverge on identical traces — route order is
  the one cross-session ordering both planes share.

``rebalance_margin`` adds live-only pressure migrations (source holds
``margin`` more sessions than the lightest replica); the differential
config leaves it None because its trigger *is* timing-sensitive — the
soak and unit tests cover it instead.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.distributed.fault_tolerance import StragglerMitigator
from repro.serving.fleet.replica_set import ReplicaSet


class SessionRouter:
    def __init__(self, replicas: ReplicaSet, *,
                 mitigator: Optional[StragglerMitigator] = None,
                 strike_threshold: int = 3,
                 drain_after_routes: Optional[Tuple[int, int]] = None,
                 rebalance_margin: Optional[int] = None):
        self.replicas = replicas
        self.mitigator = mitigator
        self.strike_threshold = strike_threshold
        self.drain_after_routes = drain_after_routes
        self.rebalance_margin = rebalance_margin
        self.placement: Dict[str, int] = {}
        self.route_index: Dict[str, int] = {}   # admission order
        self.open_count: List[int] = [0] * len(replicas)
        self.routed: List[int] = [0] * len(replicas)   # cumulative
        self.draining: set = set()
        self._straggler_drained: set = set()
        self.decisions: List[tuple] = []
        self.n_routes = 0

    # ------------------------------------------------------- admission
    def _pressure_key(self, i: int) -> tuple:
        return (self.open_count[i], self.replicas.live_slots(i),
                -self.replicas.free_pages(i), i)

    def _candidates(self) -> List[int]:
        c = [i for i in range(len(self.replicas))
             if i not in self.draining]
        return c or list(range(len(self.replicas)))

    def route(self, session_id: str) -> int:
        assert session_id not in self.placement, session_id
        i = min(self._candidates(), key=self._pressure_key)
        self.placement[session_id] = i
        self.route_index[session_id] = self.n_routes
        self.open_count[i] += 1
        self.routed[i] += 1
        self.decisions.append(("route", session_id, i))
        self.n_routes += 1
        if self.drain_after_routes is not None:
            r, n = self.drain_after_routes
            if self.n_routes == n:
                self.drain(r)
        return i

    def on_session_end(self, session_id: str) -> None:
        i = self.placement.pop(session_id, None)
        self.route_index.pop(session_id, None)
        if i is not None:
            self.open_count[i] -= 1

    # ------------------------------------------------------- migration
    def ring_next(self, src: int, skip: int = 0) -> Optional[int]:
        """The ``skip``-th non-draining replica in ring order after
        ``src`` (wrapping over the healthy set)."""
        cands = []
        n = len(self.replicas)
        for k in range(1, n):
            i = (src + k) % n
            if i not in self.draining:
                cands.append(i)
        if not cands:
            return None
        return cands[skip % len(cands)]

    def maybe_migrate(self, session_id: str) -> Optional[int]:
        """Decide (and log) a migration for an idle session at its
        speech start; returns the destination replica or None. The
        caller owns candidacy (idle, has KV, not already migrating) —
        this is pure policy."""
        src = self.placement[session_id]
        if src in self.draining:
            dst = self.ring_next(src,
                                 self.route_index.get(session_id, 0))
            if dst is not None:
                self.decisions.append(("migrate", session_id, src, dst))
                return dst
            return None
        if self.rebalance_margin is not None:
            dst = min(self._candidates(), key=self._pressure_key)
            if dst != src and self.open_count[src] \
                    - self.open_count[dst] >= self.rebalance_margin:
                self.decisions.append(("migrate", session_id, src, dst))
                return dst
        return None

    def request_handoff(self, session_id: str,
                        target: int) -> Optional[int]:
        """Client-requested agent handoff to a specific model config.
        Pure policy like ``maybe_migrate`` (the caller owns candidacy):
        the requested target maps onto the fleet modulo its size —
        deterministic from the trace alone, so the decision log stays
        twin-comparable — and self-moves or draining destinations are
        refused (the session simply stays put)."""
        src = self.placement[session_id]
        dst = target % len(self.replicas)
        if dst == src or dst in self.draining:
            return None
        self.decisions.append(("handoff", session_id, src, dst))
        return dst

    def on_migrated(self, session_id: str, dst: int) -> None:
        src = self.placement[session_id]
        self.placement[session_id] = dst
        self.open_count[src] -= 1
        self.open_count[dst] += 1

    # ----------------------------------------------- drain / straggler
    def drain(self, i: int) -> None:
        """Stop routing to replica ``i`` and mark its sessions for
        migration at their next speech start. The last healthy replica
        can never be drained — someone has to serve."""
        if i in self.draining \
                or len(self.draining) + 1 >= len(self.replicas):
            return
        self.draining.add(i)
        self.decisions.append(("drain", i))

    def recover(self, i: int) -> None:
        if i not in self.draining:
            return
        self.draining.discard(i)
        self._straggler_drained.discard(i)
        if self.mitigator is not None:
            self.mitigator.forget(f"replica{i}")
        self.decisions.append(("recover", i))

    def observe_round(self, i: int, duration_s: float) -> None:
        """Feed one executed round's duration into the straggler
        mitigator; drain the replica when it crosses the strike
        threshold, and lift a straggler drain once the mitigator's
        consecutive-good-round streak forgives it."""
        if self.mitigator is None:
            return
        src = f"replica{i}"
        self.mitigator.observe(src, duration_s)
        if i not in self.draining:
            if self.mitigator.should_evict(src, self.strike_threshold):
                self.drain(i)                # no-op on the last replica
                if i in self.draining:
                    self._straggler_drained.add(i)
        elif i in self._straggler_drained \
                and src not in self.mitigator.strikes:
            self.recover(i)

    # ------------------------------------------------------- queries
    def migration_decisions(self) -> List[tuple]:
        return [d for d in self.decisions if d[0] == "migrate"]

    def handoff_decisions(self) -> List[tuple]:
        return [d for d in self.decisions if d[0] == "handoff"]

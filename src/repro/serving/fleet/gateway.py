"""The asyncio fleet gateway (DESIGN.md §12).

A ``RealtimeGateway`` whose engine is a ``ReplicaSet``: the router picks
a replica at connect, every per-session path resolves through the
placement map (the base gateway's ``_eng`` hook), and each control
round runs Algorithm 1 once per replica over that replica's slots and
its share of the pending queue. Migration plans advance in ``_pump`` —
between event delivery and the round, atomic under the single-threaded
asyncio contract (DESIGN.md §4): a round, a barge-in abort, and a
migration state flip can never interleave.

Round durations (real ``perf_counter`` seconds per executed replica
round, plus any injected test lag) feed the router's straggler
mitigator; the virtual-time twin (fleet/replay.py) feeds a constant
``round_dt`` instead, which is why the differential config disables the
mitigator — wall time is the one input the twin cannot reproduce.
"""
from __future__ import annotations

import time
from typing import Dict, Optional, Tuple

from repro.distributed.fault_tolerance import StragglerMitigator
from repro.serving.gateway.events import (BargeIn, Hangup, SpeechStart,
                                          TurnRequest)
from repro.serving.gateway.gateway import (GatewayConfig, RealtimeGateway,
                                           build_scheduler, control_round)
from repro.serving.fleet.migration import (MigrationCoordinator,
                                           consider_handoff,
                                           consider_migration)
from repro.serving.fleet.replica_set import ReplicaSet
from repro.serving.fleet.router import SessionRouter
from repro.serving.metrics import Metrics


class FleetGateway(RealtimeGateway):
    def __init__(self, replicas: ReplicaSet, *,
                 cfg: Optional[GatewayConfig] = None,
                 mitigator: Optional[StragglerMitigator] = None,
                 strike_threshold: int = 3,
                 drain_after_routes: Optional[Tuple[int, int]] = None,
                 rebalance_margin: Optional[int] = None):
        self.replicas = replicas
        self.engine = replicas[0]       # single-engine compat surface
        self.cfg = cfg or GatewayConfig()
        self.clock = replicas.clock
        self._init_common()
        self.schedulers = [
            build_scheduler(self.cfg.policy, e.monitor, e.kv.occupancy,
                            chunk=self.sched_chunk(),
                            decode_chunk=max(1, min(
                                1 + getattr(e, "spec_decode", 0),
                                self.cfg.round_token_budget)),
                            sc=self.cfg.sched)
            for e in replicas]
        self.scheduler = self.schedulers[0]   # hold-wake estimates
        self.router = SessionRouter(
            replicas, mitigator=mitigator,
            strike_threshold=strike_threshold,
            drain_after_routes=drain_after_routes,
            rebalance_margin=rebalance_margin)
        self.migrator = MigrationCoordinator(replicas, self.router,
                                             self._metrics)
        # test hook: extra seconds added to replica i's observed round
        # durations (forced straggler injection for soak/bench)
        self.round_lag_s: Dict[int, float] = {}
        # peak pool occupancy per replica (end-state is always empty —
        # every session has hung up by the time metrics are read)
        self._peak_occ = [0.0] * len(replicas)

    # ------------------------------------------------ engine indirection
    def _eng(self, sid: str):
        return self.replicas[self.router.placement[sid]]

    def _engines(self):
        return tuple(self.replicas)

    # ------------------------------------------------------------ clients
    def connect(self, session_id: str):
        self.router.route(session_id)
        return super().connect(session_id)

    # ------------------------------------------------------------ events
    def _handle(self, ev) -> None:
        sid = ev.session_id
        now = self.clock.now()
        if isinstance(ev, SpeechStart):
            if consider_migration(self, sid):
                # migrating: speech telemetry still lands, but the
                # source preload must not fire — reloading the pages
                # would cancel the migration's own offload chunks
                self._eng(sid).monitor.on_speech_start(
                    sid, ev.expected_dur_s)
                return
        elif isinstance(ev, TurnRequest):
            self.migrator.demand_complete(sid, now)
        elif isinstance(ev, BargeIn):
            self.migrator.on_barge(sid, now)
        elif isinstance(ev, Hangup):
            self.migrator.on_hangup(sid, now)
        super()._handle(ev)
        if isinstance(ev, Hangup):
            self.router.on_session_end(sid)

    def _on_handoff(self, ev) -> None:
        # client-requested agent handoff: a targeted migration plan; the
        # following SpeechStart's consider_migration sees it and keeps
        # the source preload from re-paging the departing KV
        consider_handoff(self, ev.session_id, ev.target)

    # ------------------------------------------------------------ rounds
    def _record_admit(self, sid, r) -> None:
        super()._record_admit(sid, r)
        self.migrator.on_turn_admitted(sid, r, self._rec(sid))

    def _pump(self) -> None:
        self.migrator.pump(self.clock.now())

    def _round(self) -> bool:
        ran = False
        for i, eng in enumerate(self.replicas):
            pend = {sid: p for sid, p in self._pending.items()
                    if self.router.placement.get(sid) == i}
            before = set(pend)
            t0 = time.perf_counter()
            decision, chunks, admitted = control_round(
                eng, self.schedulers[i], pend,
                token_budget=self.cfg.round_token_budget,
                frontier_cap_s=self.cfg.frontier_cap_s,
                record_admit=self._record_admit)
            # control_round pops what it admitted (and re-inserts an
            # OutOfPages requeue); sync the filtered view back
            for sid in before - set(pend):
                self._pending.pop(sid, None)
            if decision is None:
                continue
            self.last_decision = decision
            if chunks:
                sids = {j: eng.slot_state[j].session_id for j in chunks}
                events = eng.run_round(chunks)
                self.rounds += 1
                self._dispatch(events, sids)
                self.router.observe_round(
                    i, time.perf_counter() - t0
                    + self.round_lag_s.get(i, 0.0))
                ran = True
            elif admitted:
                ran = True
            self._peak_occ[i] = max(
                self._peak_occ[i],
                1.0 - eng.pool.free_pages / eng.num_pages)
        return ran

    # ------------------------------------------------------------ metrics
    def metrics(self) -> Metrics:
        m = super().metrics()
        m.replica_occupancy = list(self._peak_occ)
        return m

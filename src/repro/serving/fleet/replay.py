"""Deterministic virtual-time twin of the fleet gateway
(DESIGN.md §12).

Same construction as ``gateway/replay.py`` vs the asyncio gateway, one
level up: the same ``SessionRouter`` and ``MigrationCoordinator`` code
drive the same per-replica ``control_round`` body on a driver-owned
``ReplayClock``. Routing happens for the whole trace up front — the
synchronous mirror of the asyncio load generator, whose session tasks
all connect in trace order before any event is processed — and rounds
feed the router a constant ``round_dt`` duration (the one signal wall
time produces that virtual time cannot), so differential configs keep
the straggler mitigator off and inject drains deterministically via
``drain_after_routes``.

The router's decision log — routes, drains, migrations — is the
comparison surface for tests/test_fleet_differential.py.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

from repro.core.scheduler import SchedulerConfig
from repro.distributed.fault_tolerance import StragglerMitigator
from repro.serving.fleet.migration import (MigrationCoordinator,
                                           consider_handoff,
                                           consider_migration)
from repro.serving.fleet.replica_set import ReplicaSet
from repro.serving.fleet.router import SessionRouter
from repro.serving.gateway.gateway import build_scheduler, control_round
from repro.serving.gateway.replay import (ReplayClock, ReplayConfig,
                                          ReplayGateway)
from repro.serving.workload import WorkloadConfig


class FleetReplayGateway(ReplayGateway):
    def __init__(self, replicas: ReplicaSet, workload: WorkloadConfig,
                 cfg: Optional[ReplayConfig] = None, *, seed: int = 0,
                 mitigator: Optional[StragglerMitigator] = None,
                 strike_threshold: int = 3,
                 drain_after_routes: Optional[Tuple[int, int]] = None,
                 rebalance_margin: Optional[int] = None):
        self.replicas = replicas
        super().__init__(replicas[0], workload, cfg, seed=seed)
        sc = self.cfg.sched or SchedulerConfig()
        chunk = max(1, min(self.cfg.prefill_chunk,
                           self.cfg.round_token_budget))
        self.schedulers = [
            build_scheduler(self.cfg.policy, e.monitor, e.kv.occupancy,
                            chunk=chunk, sc=sc)
            for e in replicas]
        self.router = SessionRouter(
            replicas, mitigator=mitigator,
            strike_threshold=strike_threshold,
            drain_after_routes=drain_after_routes,
            rebalance_margin=rebalance_margin)
        self.migrator = MigrationCoordinator(replicas, self.router,
                                             self.metrics)
        # route the whole trace up front, in trace order — the mirror
        # of the asyncio clients' connect-before-first-await discipline
        for s in self._trace:
            self.router.route(s.session_id)

    # ------------------------------------------------ engine indirection
    def _eng(self, sid: str):
        return self.replicas[self.router.placement[sid]]

    def _engines(self):
        return tuple(self.replicas)

    def _pump(self) -> None:
        self.migrator.pump(self.clock.now())

    # ----------------------------------------------------- client events
    def _handoff_request(self, sid: str, target: int) -> None:
        consider_handoff(self, sid, target)

    def _speech_start(self, s, ti: int) -> None:
        sid = s.session_id
        _, _, speech_dur, _, turn = self._clamped_turn(s, ti)
        if turn.handoff:
            self._handoff_request(sid, turn.handoff_target)
        if consider_migration(self, sid):
            # migrating (drain/rebalance or a just-started handoff):
            # telemetry only; the source preload must not fire (it
            # would cancel the migration's own offload chunks)
            if turn.frame_period_tokens > 0.0:
                self._eng(sid).monitor.on_speech_start(sid)
                self._push(self.clock.now(), self._turn_request, s, ti)
            else:
                self._eng(sid).monitor.on_speech_start(sid, speech_dur)
                self._push(self.clock.now() + speech_dur,
                           self._turn_request, s, ti)
            return
        super()._speech_start(s, ti)

    def _turn_request(self, s, ti: int, resume: bool = False) -> None:
        self.migrator.demand_complete(s.session_id, self.clock.now())
        super()._turn_request(s, ti, resume)

    def _barge(self, s, ti: int) -> None:
        self.migrator.on_barge(s.session_id, self.clock.now())
        super()._barge(s, ti)

    def _hangup(self, s) -> None:
        self.migrator.on_hangup(s.session_id, self.clock.now())
        super()._hangup(s)
        self.router.on_session_end(s.session_id)

    # ------------------------------------------------------------ rounds
    def _record_admit(self, sid, r) -> None:
        super()._record_admit(sid, r)
        self.migrator.on_turn_admitted(sid, r, self._rec(sid))

    def _round(self) -> bool:
        ran = False
        for i, eng in enumerate(self.replicas):
            pend = {sid: p for sid, p in self._pending.items()
                    if self.router.placement.get(sid) == i}
            before = set(pend)
            decision, chunks, admitted = control_round(
                eng, self.schedulers[i], pend,
                token_budget=self.cfg.round_token_budget,
                frontier_cap_s=self.cfg.frontier_cap_s,
                record_admit=self._record_admit)
            for sid in before - set(pend):
                self._pending.pop(sid, None)
            if decision is None:
                continue
            if chunks:
                sids = {j: eng.slot_state[j].session_id for j in chunks}
                events = eng.run_round(chunks)
                self.rounds += 1
                self._dispatch(events, sids)
                self.router.observe_round(i, self.cfg.round_dt)
                ran = True
            elif admitted:
                ran = True
        return ran

    def run(self, **kw):
        m = super().run(**kw)
        m.replica_occupancy = self.replicas.occupancy()
        return m


def run_fleet_replay(engine_factory, n_replicas: int,
                     workload: WorkloadConfig,
                     cfg: Optional[ReplayConfig] = None, *, seed: int = 0,
                     check_invariants: bool = True,
                     interconnect_gb_s: float = 50.0, **fleet_kw):
    """Build ``n_replicas`` engines on one ReplayClock via
    ``engine_factory(clock)``, replay the workload through the fleet
    twin, return (metrics, FleetReplayGateway)."""
    clock = ReplayClock()
    engines = [engine_factory(clock) for _ in range(n_replicas)]
    rs = ReplicaSet(engines, interconnect_gb_s=interconnect_gb_s)
    gw = FleetReplayGateway(rs, workload, cfg, seed=seed, **fleet_kw)

    def check() -> None:
        for e in engines:
            e.check_invariants()

    gw.run(check_every_round=check if check_invariants else None)
    return gw.metrics, gw

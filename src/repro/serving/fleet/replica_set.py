"""A fleet of data-parallel paged engines on one shared clock
(DESIGN.md §12).

Each replica is a full ``PagedRealtimeEngine`` — its own page pool, KV
accounting, transfer ledger, monitor, and preloader. Replicas sharing a
model config also share the jitted step executable (the engine's
``_STEP_FN_CACHE`` keys on config identity), so an N-replica fleet pays
one XLA compile, not N.

The ``interconnect`` models the replica-to-replica NIC the same way
``core/kv_manager.TransferChannel`` models PCIe: serialized shared
bandwidth, so concurrent migrations queue behind each other and their
modeled network seconds land in the migration on/off-path accounting.
"""
from __future__ import annotations

from typing import Iterator, List

from repro.core.kv_manager import TransferChannel


class ReplicaSet:
    def __init__(self, engines: List, *, interconnect_gb_s: float = 50.0):
        assert engines, "a fleet needs at least one replica"
        clock = engines[0].clock
        assert all(e.clock is clock for e in engines), \
            "replicas must share one clock (one serving timeline)"
        bb = engines[0].kv.channel.block_bytes
        assert all(e.kv.channel.block_bytes == bb for e in engines), \
            "replicas must share a page geometry (same KV bytes/page)"
        ws = engines[0].kv.channel.wire_scale
        assert all(e.kv.channel.wire_scale == ws for e in engines), \
            "replicas must share a KV wire format (same kv_quant)"
        self.engines = list(engines)
        self.clock = clock
        self.block_bytes = bb
        # MIGRATE chunks carry host copies already in wire format, so
        # the modeled NIC prices the same compressed bytes PCIe does
        self.interconnect = TransferChannel(interconnect_gb_s, bb,
                                            wire_scale=ws)

    def __len__(self) -> int:
        return len(self.engines)

    def __getitem__(self, i: int):
        return self.engines[i]

    def __iter__(self) -> Iterator:
        return iter(self.engines)

    # ------------------------------------------------- pressure signals
    def live_slots(self, i: int) -> int:
        return sum(1 for s in self.engines[i].slot_state.values()
                   if s is not None and s.request.is_live())

    def free_pages(self, i: int) -> int:
        return self.engines[i].pool.free_pages

    def occupancy(self) -> List[float]:
        return [1.0 - e.pool.free_pages / e.num_pages for e in self.engines]

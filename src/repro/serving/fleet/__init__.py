"""Replica fleet: data-parallel paged engines behind one gateway with
live cross-replica KV migration (DESIGN.md §12).

Layout:
  replica_set.py  N independent ``PagedRealtimeEngine`` instances on one
                  shared clock, plus the modeled replica interconnect
  router.py       session admission / affinity / pressure-aware load
                  balancing, drain + straggler handling — every routing
                  and migration decision, as an auditable log
  migration.py    the live-migration coordinator: drain -> network ->
                  landing plans over the engines' MIGRATE-tagged
                  transfer ledger, with the cancellation rules
  gateway.py      asyncio ``FleetGateway`` (a ``RealtimeGateway`` whose
                  per-session paths resolve through the router)
  replay.py       deterministic virtual-time fleet twin — the router
                  differential harness (tests/test_fleet_differential)
  harness.py      one-call end-to-end fleet runner (serve.py
                  --replicas N, benchmarks/gateway_bench.py, tests)
"""
from repro.serving.fleet.gateway import FleetGateway
from repro.serving.fleet.harness import build_fleet_gateway, \
    run_fleet_workload
from repro.serving.fleet.migration import (MigrationCoordinator,
                                           MigrationPlan)
from repro.serving.fleet.replay import FleetReplayGateway, run_fleet_replay
from repro.serving.fleet.replica_set import ReplicaSet
from repro.serving.fleet.router import SessionRouter

__all__ = [
    "ReplicaSet", "SessionRouter", "MigrationCoordinator",
    "MigrationPlan", "FleetGateway", "FleetReplayGateway",
    "build_fleet_gateway", "run_fleet_workload", "run_fleet_replay",
]

"""Live cross-replica KV migration (DESIGN.md §12).

A migration is a plan over the engines' existing chunked transfer
machinery — no third data path. It fires at a speech start (like the
§5.2 preload, it hides in the window where the user is talking and the
session cannot need its KV) and walks four states:

  DRAINING  the source queues its whole device-resident context as
            MIGRATE-tagged copy-then-free offload chunks
            (``migrate_out_begin``); they drain through the same
            per-round / idle-loop budgets as eviction traffic.
  NETWORK   every page is host-resident: the session state transplants
            wholesale (``migrate_out_finalize`` -> ``migrate_in_adopt``,
            placement flips at this instant) while the page payload
            rides the modeled replica interconnect.
  LANDING   the payload has arrived; the destination pages it back in
            with the ordinary speech-time preload, so the on/off-path
            split of the page-in needs no new accounting — it *is* a
            reload split.
  DONE      the session's next turn was admitted on the destination
            (``rec.migrated`` marks it for the bench's migrated-TTFP
            comparison) — or the user hung up after handoff.

Cancellation rules (all zero-copy on the not-yet-moved bytes):

  barge-in, pre-handoff   ``migrate_out_cancel`` — queued chunks drop
                          from the ledger, their pages stay resident;
                          the interrupting turn runs on the source.
  hangup, pre-handoff     plan cancelled; the normal hangup path frees
                          everything (the ledger's cancel-session +
                          pool release already leak nothing).
  turn request, pre-handoff   not a cancel: the drain completes on
                          demand, its residual (plus the network
                          window) charged on-path — mirroring the
                          synchronous-reload fallback.
  destination OutOfPages  at handoff the destination must have room
                          (free + reclaimable); otherwise the plan
                          cancels and the session stays on the source,
                          its already-drained pages simply
                          host-resident (next turn reloads them).
  barge/hangup, post-handoff   no cancel — the session is already the
                          destination's; the barge or hangup rides the
                          normal single-replica paths there.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

from repro.serving.fleet.replica_set import ReplicaSet
from repro.serving.fleet.router import SessionRouter

DRAINING = "draining"
NETWORK = "network"
LANDING = "landing"
DONE = "done"
CANCELLED = "cancelled"


@dataclass
class MigrationPlan:
    session_id: str
    src: int
    dst: int
    t_start: float
    pages: int = 0
    state: str = DRAINING
    net_done: float = 0.0
    reason: str = ""                   # cancellation reason, if any
    kind: str = "migrate"              # migrate | handoff (client-requested)


class MigrationCoordinator:
    def __init__(self, replicas: ReplicaSet, router: SessionRouter,
                 metrics):
        self.replicas = replicas
        self.router = router
        self.metrics = metrics
        self.plans: Dict[str, MigrationPlan] = {}
        self.log: List[MigrationPlan] = []

    # ------------------------------------------------------- lifecycle
    def start(self, session_id: str, src: int, dst: int,
              now: float, *, kind: str = "migrate") -> MigrationPlan:
        assert session_id not in self.plans, session_id
        pages = self.replicas[src].migrate_out_begin(session_id)
        plan = MigrationPlan(session_id, src, dst, now, pages=pages,
                             kind=kind)
        self.plans[session_id] = plan
        return plan

    def pump(self, now: float) -> None:
        """Advance every plan one observable step. Called by both fleet
        gateways between event delivery and the round, so state flips
        are atomic with rounds under the single-threaded contract."""
        for plan in list(self.plans.values()):
            if plan.state == DRAINING:
                src = self.replicas[plan.src]
                if src.migrate_out_pending(plan.session_id) == 0:
                    self._handoff(plan, now)
            elif plan.state == NETWORK and now >= plan.net_done:
                self._arrive(plan, now)

    def _handoff(self, plan: MigrationPlan, now: float) -> bool:
        """Source drain complete: transplant the session and put its
        pages on the wire. Returns False if the destination had no room
        (plan cancelled, session stays on the source)."""
        sid = plan.session_id
        src, dst = self.replicas[plan.src], self.replicas[plan.dst]
        if dst.kv.free_blocks + dst.kv.reclaimable_blocks(now) \
                < plan.pages:
            self._cancel(plan, reason="dst_pressure")
            return False
        tr = self.replicas.interconnect.submit(sid, plan.pages, now,
                                               background=True)
        plan.net_done = tr.done
        state = src.migrate_out_finalize(sid)
        dst.migrate_in_adopt(sid, state)
        self.router.on_migrated(sid, plan.dst)
        plan.state = NETWORK
        m = self.metrics
        m.migrations += 1
        if plan.kind == "handoff":
            m.handoffs += 1
        m.migration_bytes += \
            self.replicas.interconnect.wire_bytes(plan.pages)
        # drain + network seconds land off-path here; a demanded
        # completion reclassifies its residual below
        m.migration_off_path_s += \
            src.kv.channel.transfer_time(plan.pages) + (tr.done - now)
        return True

    def _arrive(self, plan: MigrationPlan, now: float,
                fire_preload: bool = True) -> None:
        """Payload landed on the destination: page it back in through
        the normal speech-time preload (admission-checked, chunked,
        cancellable, OutOfPages-recoverable at turn start). NOT
        ``user_speech_start`` — the speech already started on the
        source; re-announcing it would double-update the reply-gap
        EMA."""
        sid = plan.session_id
        plan.state = LANDING
        dst = self.replicas[plan.dst]
        sess = dst.sessions.get(sid)
        if fire_preload and sess is not None and not sess.ended \
                and all(s is None or s.session_id != sid
                        for s in dst.slot_state.values()):
            dst.preloader.on_speech_start(sid, now)

    def _reclass_on_path(self, s: float) -> None:
        if s <= 0.0:
            return
        self.metrics.migration_off_path_s -= s
        self.metrics.migration_on_path_s += s

    def demand_complete(self, session_id: str, now: float) -> None:
        """A turn request arrived before the migration finished: force
        it through (the decided move always completes on the natural
        trace — cancellation is reserved for barge/hangup/pressure),
        charging the drain residual and the network window on-path via
        the clock, exactly like a synchronous reload stall."""
        plan = self.plans.get(session_id)
        if plan is None:
            return
        clock = self.replicas.clock
        if plan.state == DRAINING:
            src = self.replicas[plan.src]
            pend = src.migrate_out_pending(session_id)
            src.transfer.drain_offloads_until(
                now, lambda: src.migrate_out_pending(session_id) == 0)
            if not self._handoff(plan, now):
                return                       # dst full: turn runs on src
            on_path = src.kv.channel.transfer_time(pend) \
                + max(0.0, plan.net_done - now)
            self._reclass_on_path(on_path)
            clock.tick(on_path)
            self._arrive(plan, clock.now(), fire_preload=False)
        elif plan.state == NETWORK:
            residual = max(0.0, plan.net_done - now)
            self._reclass_on_path(residual)
            clock.tick(residual)
            self._arrive(plan, clock.now(), fire_preload=False)
        # LANDING: nothing to force — turn admission settles the reload

    def on_turn_admitted(self, session_id: str, request, rec) -> None:
        """The migrated session's next turn bound to a destination
        slot: the admission's reload split *is* the migration page-in
        split. Completes the plan."""
        plan = self.plans.get(session_id)
        if plan is None or plan.state != LANDING:
            return
        self.metrics.migration_on_path_s += request.reload_stall_s
        self.metrics.migration_off_path_s += request.reload_off_path_s
        rec.migrated = True
        if plan.kind == "handoff":
            rec.handoff = True
        plan.state = DONE
        self.log.append(self.plans.pop(session_id))

    # ---------------------------------------------------- cancellation
    def on_barge(self, session_id: str, now: float) -> None:
        plan = self.plans.get(session_id)
        if plan is not None and plan.state == DRAINING:
            # the interrupting utterance becomes a turn on the source
            # almost immediately — cancelling beats paying the drain
            # residual on-path. Post-handoff the session already lives
            # on the destination; the barge rides normally there.
            self._cancel(plan, reason="barge")

    def on_hangup(self, session_id: str, now: float) -> None:
        plan = self.plans.get(session_id)
        if plan is None:
            return
        if plan.state == DRAINING:
            self._cancel(plan, reason="hangup")
        else:
            # bytes already moved; the session just ended before its
            # next turn — the migration itself completed
            plan.state = DONE
            self.log.append(self.plans.pop(session_id))

    def _cancel(self, plan: MigrationPlan, *, reason: str) -> None:
        src = self.replicas[plan.src]
        src.migrate_out_cancel(plan.session_id)
        plan.state = CANCELLED
        plan.reason = reason
        self.log.append(self.plans.pop(plan.session_id))

    # -------------------------------------------------------- queries
    def completed(self) -> List[MigrationPlan]:
        return [p for p in self.log if p.state == DONE]

    def cancelled(self) -> List[MigrationPlan]:
        return [p for p in self.log if p.state == CANCELLED]


def consider_migration(gw, session_id: str) -> bool:
    """Shared speech-start hook for both fleet gateways: candidacy
    check + router decision + plan start. Returns True iff the session
    has an active plan afterwards — the caller must then suppress the
    ordinary source-side preload (its pages are leaving; reloading them
    would cancel the migration's own offload chunks)."""
    mig, router = gw.migrator, gw.router
    if session_id in mig.plans:
        return True
    src = router.placement.get(session_id)
    if src is None:
        return False
    eng = gw.replicas[src]
    sess = eng.sessions.get(session_id)
    if sess is None or sess.ended or sess.kv_len == 0:
        return False                     # nothing to move yet
    if session_id in gw._pending:
        return False                     # a turn is already queued
    if any(s is not None and s.session_id == session_id
           for s in eng.slot_state.values()):
        return False                     # live turn: migration waits
    dst = router.maybe_migrate(session_id)
    if dst is None:
        return False
    mig.start(session_id, src, dst, gw.clock.now())
    return True


def consider_handoff(gw, session_id: str, target: int) -> bool:
    """Shared HandoffRequest hook for both fleet gateways: same
    candidacy rules as ``consider_migration`` (idle, has KV, no queued
    turn) but the destination is the client's requested model config,
    not a drain/rebalance decision. The transfer itself is the ordinary
    four-state migration plan, tagged kind='handoff'. Returns True iff
    a plan is active afterwards — the caller then suppresses the
    source-side preload at the following speech start (the plan's
    ``consider_migration`` short-circuit does that automatically)."""
    mig, router = gw.migrator, gw.router
    if session_id in mig.plans:
        return True                      # one move at a time
    src = router.placement.get(session_id)
    if src is None:
        return False
    eng = gw.replicas[src]
    sess = eng.sessions.get(session_id)
    if sess is None or sess.ended or sess.kv_len == 0:
        return False                     # nothing committed to hand off
    if session_id in gw._pending:
        return False
    if any(s is not None and s.session_id == session_id
           for s in eng.slot_state.values()):
        return False                     # live turn: the move must wait
    dst = router.request_handoff(session_id, target)
    if dst is None:
        return False
    mig.start(session_id, src, dst, gw.clock.now(), kind="handoff")
    return True

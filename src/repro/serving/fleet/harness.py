"""One-call end-to-end fleet runner (DESIGN.md §12).

Shared by ``launch/serve.py --replicas N``, ``benchmarks/
gateway_bench.py``'s fleet section, and the fleet tests: build N
laptop-scale engines on one ``ScaledWallClock`` (one XLA compile — the
jitted step is shared through the engine's config-keyed cache), put a
``FleetGateway`` in front, and replay a workload through the same
in-process clients the single-engine harness uses.
"""
from __future__ import annotations

from typing import Optional, Tuple

from repro.core.scheduler import SchedulerConfig
from repro.distributed.fault_tolerance import StragglerMitigator
from repro.serving.fleet.gateway import FleetGateway
from repro.serving.fleet.replica_set import ReplicaSet
from repro.serving.gateway.clock import ScaledWallClock
from repro.serving.gateway.gateway import GatewayConfig
from repro.serving.gateway.harness import (_warm_engine,
                                           run_gateway_workload,
                                           tiny_model)
from repro.serving.metrics import Metrics


def build_fleet_gateway(*, replicas: int = 3, policy: str = "liveserve",
                        scale: float = 8.0, slots: int = 8,
                        page_size: int = 8, pages_per_seq: int = 8,
                        num_pages: Optional[int] = None,
                        audio_per_token_s: float = 0.25,
                        round_token_budget: int = 16,
                        prefill_chunk: int = 16,
                        frontier_cap_s: Optional[float] = None,
                        sched_cfg: Optional[SchedulerConfig] = None,
                        model: Optional[tuple] = None, mesh=None,
                        seed: int = 0, preload_chunks: int = 1,
                        fused_step: bool = True,
                        prefix_cache: bool = False,
                        kv_quant: str = "fp32",
                        spec_decode: int = 0,
                        proposer=None,
                        autotune: Optional[str] = None,
                        interconnect_gb_s: float = 50.0,
                        mitigator: Optional[StragglerMitigator] = None,
                        strike_threshold: int = 3,
                        drain_after_routes: Optional[Tuple[int, int]] = None,
                        rebalance_margin: Optional[int] = None
                        ) -> FleetGateway:
    """N data-parallel engines behind one gateway. All engine knobs are
    per replica (each replica gets its own ``num_pages`` pool); ``mesh``
    composes — every replica shards its page store over the same mesh
    (DESIGN.md §9 inside §12)."""
    from repro.serving.paged_engine import PagedRealtimeEngine
    if autotune:
        from repro.kernels import autotune as at
        at.enable(autotune)
    cfg, params = model if model is not None else tiny_model(seed)
    clock = ScaledWallClock(scale)
    engines = [
        PagedRealtimeEngine(cfg, params, slots=slots,
                            page_size=page_size,
                            pages_per_seq=pages_per_seq,
                            num_pages=num_pages, clock=clock, mesh=mesh,
                            transfer_chunks_per_round=preload_chunks,
                            fused_step=fused_step,
                            prefix_cache=prefix_cache,
                            kv_quant=kv_quant,
                            spec_decode=spec_decode,
                            proposer=proposer)
        for _ in range(replicas)]
    # one warm-up warms the fleet: replicas share the jitted step
    # through the config-keyed cache
    _warm_engine(engines[0], min(prefill_chunk, round_token_budget))
    rs = ReplicaSet(engines, interconnect_gb_s=interconnect_gb_s)
    return FleetGateway(rs, cfg=GatewayConfig(
        policy=policy, audio_per_token_s=audio_per_token_s,
        round_token_budget=round_token_budget,
        prefill_chunk=prefill_chunk, frontier_cap_s=frontier_cap_s,
        sched=sched_cfg),
        mitigator=mitigator, strike_threshold=strike_threshold,
        drain_after_routes=drain_after_routes,
        rebalance_margin=rebalance_margin)


def run_fleet_workload(*, policy: str = "liveserve",
                       kind: str = "interactive", sessions: int = 12,
                       barge_in: float = 0.0, seed: int = 0,
                       arrival: str = "poisson", rate_rps: float = 2.0,
                       scale: float = 8.0, max_turns: int = 2,
                       max_prompt: int = 16, max_response: int = 12,
                       speech_scale: float = 1.0,
                       prompt_families: int = 0,
                       family_prefix_len: int = 0,
                       gateway: Optional[FleetGateway] = None,
                       timeout_s: Optional[float] = None,
                       **gw_kw) -> Tuple[Metrics, FleetGateway]:
    """Replay an open-loop workload through a fleet gateway; returns
    (metrics, gateway). The load path is the single-engine harness's —
    the fleet gateway is a ``RealtimeGateway`` to its clients."""
    if gateway is None:
        gateway = build_fleet_gateway(policy=policy, scale=scale,
                                      seed=seed, **gw_kw)
    else:
        assert not gw_kw, "gateway already built; engine kwargs ignored"
    return run_gateway_workload(
        policy=policy, kind=kind, sessions=sessions, barge_in=barge_in,
        seed=seed, arrival=arrival, rate_rps=rate_rps, scale=scale,
        max_turns=max_turns, max_prompt=max_prompt,
        max_response=max_response, speech_scale=speech_scale,
        prompt_families=prompt_families,
        family_prefix_len=family_prefix_len,
        gateway=gateway, timeout_s=timeout_s)

"""Serving metrics shared by the virtual-clock simulator and the
realtime gateway.

``TurnRecord`` / ``Metrics`` used to live inside ``serving/simulator.py``;
they are a standalone module so the gateway's collector produces the
*same object* (and therefore the same ``summary()`` schema) as the
simulator — sim-vs-real policy behavior is directly comparable, and a
summary-key drift between the two planes is impossible by construction.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Optional


@dataclass
class TurnRecord:
    session_id: str
    turn_index: int
    speech_end: float = 0.0
    ttfp: Optional[float] = None           # audio time-to-first-packet
    text_ttft: Optional[float] = None
    audio_delivered_s: float = 0.0
    audio_heard_s: float = 0.0
    gen_span_s: float = 0.0
    max_gap_s: float = 0.0
    n_gaps: int = 0
    talker_generated: int = 0
    talker_wasted: int = 0
    barged: bool = False
    reload_stall_s: float = 0.0            # on-path (turn-start) reload
    reload_off_path_s: float = 0.0         # reload hidden off the path
    prefix_hit_tokens: int = 0             # prompt tokens attached from the
    #                                        shared prefix cache (no prefill)
    prompt_tokens: int = 0                 # total prompt tokens this turn
    #                                        (prefilled + prefix hits)
    completed: bool = False
    finish_time: float = 0.0
    migrated: bool = False                 # turn started on a replica the
    #                                        session was live-migrated to
    # full-duplex frame accounting (zero on half-duplex turns)
    frames: int = 0                        # output frames emitted
    deadline_misses: int = 0               # frames past their deadline
    # agentic scenario markers
    tool_resumed: bool = False             # turn resumed a tool pause
    handoff: bool = False                  # turn started on a replica the
    #                                        client requested via handoff

    @property
    def continuous(self) -> bool:
        return self.max_gap_s <= 0.100

    @property
    def rtf(self) -> Optional[float]:
        if self.audio_delivered_s <= 0 or self.ttfp is None:
            return None
        return self.gen_span_s / self.audio_delivered_s


@dataclass
class Metrics:
    turns: List[TurnRecord] = field(default_factory=list)
    completed_sessions: int = 0
    sim_end: float = 0.0
    # fleet fields (serving/fleet) — zero/empty on single-engine planes
    # so the sim/gateway summary schema stays a strict dict diff
    migrations: int = 0                    # completed cross-replica moves
    migration_bytes: float = 0.0           # KV bytes moved between replicas
    migration_on_path_s: float = 0.0       # charged to a turn start
    migration_off_path_s: float = 0.0      # hidden in the speech window
    replica_occupancy: List[float] = field(default_factory=list)
    # shared-prefix fields (zero when the prefix cache is off, keeping
    # the sim/gateway summary schema a strict dict diff)
    pages_shared: int = 0                  # peak physical pages at rc > 1
    # KV wire-format fields (DESIGN.md §14) — zero on fp32 planes
    kv_wire_bytes_saved: float = 0.0       # logical minus wire bytes moved
    quant_token_flip_rate: float = 0.0     # quality-gate flip rate, if run
    # scenario-suite fields (DESIGN.md §15) — zero on plain workloads
    tool_pauses: int = 0                   # ToolCallStart events observed
    handoffs: int = 0                      # completed client-requested moves
    # speculative-decode fields (DESIGN.md §16) — zero at spec_decode=0
    spec_drafted: int = 0                  # draft tokens fed to verify
    spec_accepted: int = 0                 # drafts matching the argmax
    spec_rejected: int = 0                 # drafts rolled back
    spec_rounds: int = 0                   # verify rounds with >= 1 draft

    def ttfps(self):
        return sorted(t.ttfp for t in self.turns if t.ttfp is not None)

    def percentile(self, vals, p):
        if not vals:
            return float("nan")
        i = min(len(vals) - 1, int(math.ceil(p / 100 * len(vals))) - 1)
        return vals[max(0, i)]

    def p90_ttfp(self):
        return self.percentile(self.ttfps(), 90)

    def continuity(self):
        done = [t for t in self.turns
                if t.completed and not t.barged and t.ttfp is not None]
        if not done:
            return float("nan")
        return sum(t.continuous for t in done) / len(done)

    def waste_ratio(self):
        gen = sum(t.talker_generated for t in self.turns)
        waste = sum(t.talker_wasted for t in self.turns)
        return waste / gen if gen else 0.0

    def completed_rps(self):
        n = sum(1 for t in self.turns if t.completed or t.barged)
        return n / self.sim_end if self.sim_end > 0 else 0.0

    def reload_overlap_frac(self) -> float:
        """Fraction of modeled reload seconds completed off the turn
        critical path (speech-time preload chunks that drained before
        the turn started) — the paper's 'most reload work moves off the
        next-turn critical path' claim, as one number. 0.0 when the
        workload never reloaded (nothing was hidden — and a NaN would
        poison the summary-dict comparisons determinism tests rely
        on)."""
        on = sum(t.reload_stall_s for t in self.turns)
        off = sum(t.reload_off_path_s for t in self.turns)
        if on + off <= 0.0:
            return 0.0
        return off / (on + off)

    def migration_off_path(self) -> float:
        """Share of modeled migration seconds kept off the next-turn
        critical path (source drain + destination page-in during the
        speech window vs charged at turn start). Same 0.0-not-NaN
        convention as ``reload_overlap_frac``."""
        tot = self.migration_on_path_s + self.migration_off_path_s
        if tot <= 0.0:
            return 0.0
        return self.migration_off_path_s / tot

    def deadline_miss_rate(self) -> float:
        """Fraction of full-duplex output frames emitted past their
        per-frame deadline — the periodic-real-time analogue of TTFP.
        Same 0.0-not-NaN convention as ``reload_overlap_frac``."""
        frames = sum(t.frames for t in self.turns)
        if frames <= 0:
            return 0.0
        return sum(t.deadline_misses for t in self.turns) / frames

    def tool_pause_reloads(self) -> int:
        """Resume turns that had to move KV at all (evicted during the
        tool pause) — each is a resume-without-reprefill the protection
        state failed to make free."""
        return sum(1 for t in self.turns if t.tool_resumed
                   and t.reload_stall_s + t.reload_off_path_s > 0.0)

    def tool_resume_off_path(self) -> float:
        """Of the reload seconds spent resuming tool pauses, the share
        hidden in the tool-result gap (off the resume turn's critical
        path). Same 0.0-not-NaN convention as above."""
        on = sum(t.reload_stall_s for t in self.turns if t.tool_resumed)
        off = sum(t.reload_off_path_s for t in self.turns
                  if t.tool_resumed)
        if on + off <= 0.0:
            return 0.0
        return off / (on + off)

    def prefix_hit_frac(self) -> float:
        """Fraction of all prompt tokens served by attaching to the
        shared prefix cache instead of prefilling. Same 0.0-not-NaN
        convention as ``reload_overlap_frac``."""
        hit = sum(t.prefix_hit_tokens for t in self.turns)
        tot = sum(t.prompt_tokens for t in self.turns)
        if tot <= 0:
            return 0.0
        return hit / tot

    def spec_accept_rate(self) -> float:
        """Fraction of drafted tokens the verify step accepted. Same
        0.0-not-NaN convention as ``reload_overlap_frac``."""
        if self.spec_drafted <= 0:
            return 0.0
        return self.spec_accepted / self.spec_drafted

    def spec_tokens_per_launch(self) -> float:
        """Mean committed tokens per speculative verify launch
        (pending + accepted drafts); 1.0 is the non-spec floor, 0.0
        when speculation never ran (0.0-not-NaN convention)."""
        if self.spec_rounds <= 0:
            return 0.0
        return (self.spec_rounds + self.spec_accepted) / self.spec_rounds

    def summary(self) -> dict:
        tt = self.ttfps()
        rtfs = sorted(t.rtf for t in self.turns if t.rtf is not None)
        stalls = [t.reload_stall_s for t in self.turns]
        offs = [t.reload_off_path_s for t in self.turns]
        return {
            "turns": len(self.turns),
            "p50_ttfp": self.percentile(tt, 50),
            "p90_ttfp": self.percentile(tt, 90),
            "p95_ttfp": self.percentile(tt, 95),
            "continuity": self.continuity(),
            "waste_ratio": self.waste_ratio(),
            "completed_rps": self.completed_rps(),
            "p50_rtf": self.percentile(rtfs, 50),
            "p90_rtf": self.percentile(rtfs, 90),
            "mean_reload_stall": (sum(stalls) / len(stalls)
                                  if stalls else 0.0),
            "mean_reload_off_path": (sum(offs) / len(offs)
                                     if offs else 0.0),
            "reload_overlap_frac": self.reload_overlap_frac(),
            "migrations": self.migrations,
            "migration_bytes": self.migration_bytes,
            "migration_off_path_s": self.migration_off_path_s,
            "migration_off_path": self.migration_off_path(),
            "replica_occupancy": list(self.replica_occupancy),
            "prefix_hit_tokens": sum(t.prefix_hit_tokens
                                     for t in self.turns),
            "prefix_hit_frac": self.prefix_hit_frac(),
            "pages_shared": self.pages_shared,
            "kv_wire_bytes_saved": self.kv_wire_bytes_saved,
            "quant_token_flip_rate": self.quant_token_flip_rate,
            "deadline_miss_rate": self.deadline_miss_rate(),
            "frames": sum(t.frames for t in self.turns),
            "tool_pauses": self.tool_pauses,
            "tool_pause_reloads": self.tool_pause_reloads(),
            "tool_resume_off_path": self.tool_resume_off_path(),
            "handoffs": self.handoffs,
            "spec_drafted": self.spec_drafted,
            "spec_accepted": self.spec_accepted,
            "spec_rejected": self.spec_rejected,
            "spec_accept_rate": self.spec_accept_rate(),
            "spec_tokens_per_launch": self.spec_tokens_per_launch(),
        }

"""Virtual clock + event queue for the discrete-event serving harness."""
from __future__ import annotations

import heapq
import itertools
from typing import Callable, List, Tuple


class VirtualClock:
    def __init__(self, start: float = 0.0):
        self._now = start

    def now(self) -> float:
        return self._now

    def advance_to(self, t: float) -> None:
        assert t >= self._now - 1e-9, (t, self._now)
        self._now = max(self._now, t)


class EventQueue:
    def __init__(self, clock: VirtualClock):
        self.clock = clock
        self._heap: List[Tuple[float, int, Callable]] = []
        self._seq = itertools.count()

    def push(self, t: float, fn: Callable) -> None:
        heapq.heappush(self._heap, (t, next(self._seq), fn))

    def push_in(self, dt: float, fn: Callable) -> None:
        self.push(self.clock.now() + dt, fn)

    def empty(self) -> bool:
        return not self._heap

    def run(self, until: float = float("inf"), max_events: int = 10_000_000):
        n = 0
        while self._heap and n < max_events:
            t, _, fn = heapq.heappop(self._heap)
            if t > until:
                self.clock.advance_to(until)
                return
            self.clock.advance_to(t)
            fn()
            n += 1
        if n >= max_events:
            raise RuntimeError("event budget exceeded — likely a live-lock")

"""Multi-head latent attention (DeepSeek-V2, arXiv:2405.04434).

Prefill/train decompress the shared KV latent into per-head K/V and run
standard attention. Decode uses the published absorption trick: W_uk is
absorbed into the query and W_uv into the output so attention runs directly
against the [B, S, kv_lora] latent cache — this is what makes MLA KV blocks
small for the serving-side KV manager.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, attention_mask, dense_init, \
    gqa_attention, rms_norm, NEG_INF


def mla_init(key, cfg, dtype):
    m = cfg.mla
    d = cfg.d_model
    H = cfg.num_heads
    ks = jax.random.split(key, 8)
    return {
        "w_dq": dense_init(ks[0], (d, m.q_lora_rank), d, dtype),
        "q_norm": jnp.zeros((m.q_lora_rank,), dtype),
        "w_uq": dense_init(ks[1], (m.q_lora_rank, H,
                                   m.nope_head_dim + m.rope_head_dim),
                           m.q_lora_rank, dtype),
        "w_dkv": dense_init(ks[2], (d, m.kv_lora_rank + m.rope_head_dim),
                            d, dtype),
        "kv_norm": jnp.zeros((m.kv_lora_rank,), dtype),
        "w_uk": dense_init(ks[3], (m.kv_lora_rank, H, m.nope_head_dim),
                           m.kv_lora_rank, dtype),
        "w_uv": dense_init(ks[4], (m.kv_lora_rank, H, m.v_head_dim),
                           m.kv_lora_rank, dtype),
        "wo": dense_init(ks[5], (H, m.v_head_dim, d),
                         H * m.v_head_dim, dtype),
    }


def _project_q(params, cfg, x, positions):
    m = cfg.mla
    q_lat = rms_norm(x @ params["w_dq"], params["q_norm"], cfg.rms_eps)
    q = jnp.einsum("bsr,rhe->bshe", q_lat, params["w_uq"])
    q_nope = q[..., :m.nope_head_dim]
    q_rope = apply_rope(q[..., m.nope_head_dim:], positions, cfg.rope_theta)
    return q_nope, q_rope


def _project_kv_latent(params, cfg, x, positions):
    m = cfg.mla
    kv = x @ params["w_dkv"]
    ckv = rms_norm(kv[..., :m.kv_lora_rank], params["kv_norm"], cfg.rms_eps)
    k_rope = apply_rope(kv[..., None, m.kv_lora_rank:], positions,
                        cfg.rope_theta)[:, :, 0]          # [B, S, dr] shared
    return ckv, k_rope


def mla_forward(params, cfg, x, positions, mask, *, impl="einsum"):
    """Train/prefill path (decompressed). Returns (out, (ckv, k_rope))."""
    m = cfg.mla
    q_nope, q_rope = _project_q(params, cfg, x, positions)
    ckv, k_rope = _project_kv_latent(params, cfg, x, positions)
    k_nope = jnp.einsum("bsr,rhe->bshe", ckv, params["w_uk"])
    v = jnp.einsum("bsr,rhe->bshe", ckv, params["w_uv"])
    H = cfg.num_heads
    k_rope_h = jnp.broadcast_to(k_rope[:, :, None, :],
                                k_rope.shape[:2] + (H, m.rope_head_dim))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_h], axis=-1)
    scale = 1.0 / math.sqrt(m.nope_head_dim + m.rope_head_dim)
    if impl == "surrogate":   # see layers.gqa_attention docstring
        out = q[..., :m.v_head_dim] * scale \
            + jnp.mean(v, axis=1, keepdims=True)
    else:
        out = gqa_attention(q, k, v, mask, scale=scale)
    out = jnp.einsum("bshe,hed->bsd", out, params["wo"])
    return out, (ckv, k_rope)


def mla_decode(params, cfg, x, positions, ckv_cache, krope_cache, mask):
    """Absorbed decode. x [B, 1, d]; caches [B, S, r] / [B, S, dr]."""
    m = cfg.mla
    q_nope, q_rope = _project_q(params, cfg, x, positions)
    new_ckv, new_krope = _project_kv_latent(params, cfg, x, positions)
    scale = 1.0 / math.sqrt(m.nope_head_dim + m.rope_head_dim)
    # absorb W_uk into q: [B,1,H,r]
    q_abs = jnp.einsum("bqhe,rhe->bqhr", q_nope, params["w_uk"])
    logits = (jnp.einsum("bqhr,bkr->bhqk", q_abs, ckv_cache,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bqhe,bke->bhqk", q_rope, krope_cache,
                           preferred_element_type=jnp.float32)) * scale
    logits = jnp.where(mask[:, None, :, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(ckv_cache.dtype)
    o_lat = jnp.einsum("bhqk,bkr->bqhr", probs, ckv_cache)
    out = jnp.einsum("bqhr,rhe->bqhe", o_lat, params["w_uv"])
    out = jnp.einsum("bshe,hed->bsd", out, params["wo"])
    return out, (new_ckv, new_krope)

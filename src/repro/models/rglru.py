"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t), with
a_t = exp(-c * softplus(Lambda) * r_t), c = 8, and r/i sigmoid gates.
Gates use BLOCK-DIAGONAL weights (the published diagonalized RG-LRU) —
each of NUM_BLOCKS channel blocks is independent, which both matches the
reference implementation and makes the whole recurrence embarrassingly
shardable across the tensor-parallel axis.
Train/prefill uses an associative scan; decode is a single recurrence step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init
from repro.models.ssm import causal_conv1d

_C = 8.0
NUM_BLOCKS = 16


def rglru_init(key, d_model: int, width: int, conv_width: int, dtype):
    ks = jax.random.split(key, 6)
    nb = NUM_BLOCKS if width % NUM_BLOCKS == 0 else 1
    bw = width // nb
    return {
        "in_gate": dense_init(ks[0], (d_model, width), d_model, dtype),
        "in_rec": dense_init(ks[1], (d_model, width), d_model, dtype),
        "conv_w": (jax.random.normal(ks[2], (conv_width, width), jnp.float32)
                   * 0.1).astype(dtype),
        "conv_b": jnp.zeros((width,), dtype),
        "w_a": jax.vmap(lambda k: dense_init(k, (bw, bw), bw, jnp.float32))(
            jax.random.split(ks[3], nb)),
        "b_a": jnp.zeros((width,), jnp.float32),
        "w_x": jax.vmap(lambda k: dense_init(k, (bw, bw), bw, jnp.float32))(
            jax.random.split(ks[4], nb)),
        "b_x": jnp.zeros((width,), jnp.float32),
        # init so a ~ uniform decay in [0.9, 0.999]
        "lam": jnp.linspace(-2.0, 2.0, width, dtype=jnp.float32),
        "out": dense_init(ks[5], (width, d_model), width, dtype),
    }


def _block_linear(w, x):
    """Block-diagonal matmul: w [nb, bw, bw], x [..., nb*bw]."""
    nb, bw, _ = w.shape
    xb = x.reshape(x.shape[:-1] + (nb, bw))
    yb = jnp.einsum("...nk,nkj->...nj", xb, w)
    return yb.reshape(x.shape)


def _gates(params, x):
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(_block_linear(params["w_a"], xf) + params["b_a"])
    i = jax.nn.sigmoid(_block_linear(params["w_x"], xf) + params["b_x"])
    log_a = -_C * jax.nn.softplus(params["lam"]) * r      # [b, ., w]
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * (i * xf)
    return log_a, gated


def rglru_scan(params, x, h0=None):
    """x [b, s, w] -> (y [b, s, w] f32, h_last [b, w] f32)."""
    log_a, gated = _gates(params, x)
    a = jnp.exp(log_a)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    aa, y = jax.lax.associative_scan(combine, (a, gated), axis=1)
    if h0 is not None:
        y = y + aa * h0[:, None, :]
    return y, y[:, -1, :]


def rglru_step(params, x, h):
    """x [b, 1, w], h [b, w] -> (y [b, 1, w], h')."""
    log_a, gated = _gates(params, x)
    h = jnp.exp(log_a[:, 0]) * h + gated[:, 0]
    return h[:, None, :], h


def recurrent_block_forward(params, cfg, x, conv_cache=None, h0=None):
    """Full Griffin recurrent block: (gelu gate) * (conv -> RG-LRU)."""
    gate = jax.nn.gelu(x @ params["in_gate"])
    rec = x @ params["in_rec"]
    rec, conv_cache = causal_conv1d(rec, params["conv_w"], conv_cache)
    rec = rec + params["conv_b"]
    y, h_last = rglru_scan(params, rec, h0)
    out = (gate * y.astype(x.dtype)) @ params["out"]
    return out, (conv_cache, h_last)


def recurrent_block_decode(params, cfg, x, conv_cache, h):
    gate = jax.nn.gelu(x @ params["in_gate"])
    rec = x @ params["in_rec"]
    rec, conv_cache = causal_conv1d(rec, params["conv_w"], conv_cache)
    rec = rec + params["conv_b"]
    y, h = rglru_step(params, rec, h)
    out = (gate * y.astype(x.dtype)) @ params["out"]
    return out, (conv_cache, h)

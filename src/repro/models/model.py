"""Model assembly: init / forward / loss / prefill / decode for every
assigned architecture family.

Pure functions over param pytrees. Homogeneous stacks (dense, moe, ssm, vlm)
scan over layer-stacked params so HLO size and compile time are O(1) in
depth; the hybrid (RecurrentGemma) runs its published non-uniform
(rglru, rglru, local_attn) pattern as an unrolled loop; deepseek's leading
dense layer is unrolled before the MoE scan; whisper runs encoder and
decoder stacks with cross-attention.

KV caches are ring buffers of ``W`` slots (W = full capacity, or the
attention window for SWA/local archs — this is what makes long_500k decode
O(window) instead of O(seq)). ``kv_pos`` tracks absolute positions so masks
stay exact after wraparound.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rg
from repro.models import ssm as ssm_mod

MOE_AUX_COEF = 0.01
Z_LOSS_COEF = 1e-4

# Dry-run cost-analysis switch: XLA's HloCostAnalysis counts while-loop
# bodies ONCE, so the roofline pass re-lowers with fully unrolled layer
# scans (exact FLOP/byte/collective counts); production lowering keeps
# the scan (O(1) HLO size & compile time).
_SCAN_UNROLL = 1


def set_scan_unroll(v) -> None:
    global _SCAN_UNROLL
    _SCAN_UNROLL = v


def _scan(body, init, xs):
    return jax.lax.scan(body, init, xs, unroll=_SCAN_UNROLL)


# ======================================================================
# init
# ======================================================================
def _stacked(key, n, init_fn):
    return jax.vmap(init_fn)(jax.random.split(key, n))


def _dense_layer_init(key, cfg, dtype, *, d_ff=None, moe_layer=False):
    ks = jax.random.split(key, 4)
    p = {"ln1": jnp.zeros((cfg.d_model,), dtype),
         "ln2": jnp.zeros((cfg.d_model,), dtype)}
    if cfg.mla is not None:
        p["attn"] = mla_mod.mla_init(ks[0], cfg, dtype)
    else:
        p["attn"] = L.attn_init(ks[0], cfg, dtype)
    if moe_layer:
        p["moe"] = moe_mod.moe_init(ks[1], cfg, dtype)
    else:
        p["mlp"] = L.mlp_init(ks[1], cfg.d_model, d_ff or cfg.d_ff,
                              cfg.mlp_kind, dtype)
    return p


def _ssm_layer_init(key, cfg, dtype):
    return {"ln1": jnp.zeros((cfg.d_model,), dtype),
            "mixer": ssm_mod.mamba2_init(key, cfg, dtype)}


def _rglru_layer_init(key, cfg, dtype):
    ks = jax.random.split(key, 2)
    r = cfg.rglru
    return {"ln1": jnp.zeros((cfg.d_model,), dtype),
            "rec": rg.rglru_init(ks[0], cfg.d_model,
                                 r.lru_width or cfg.d_model,
                                 r.conv_width, dtype),
            "ln2": jnp.zeros((cfg.d_model,), dtype),
            "mlp": L.mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_kind,
                              dtype)}


def _xattn_layer_init(key, cfg, dtype):
    """whisper decoder layer: self-attn + cross-attn + mlp."""
    ks = jax.random.split(key, 3)
    p = _dense_layer_init(ks[0], cfg, dtype)
    p["ln_x"] = jnp.zeros((cfg.d_model,), dtype)
    p["xattn"] = L.attn_init(ks[1], cfg, dtype, mha=True)
    return p


def init_params(cfg, key):
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    params = {
        "embed": L.embed_init(ks[0], (cfg.vocab_size, cfg.d_model), dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = L.dense_init(
            ks[1], (cfg.d_model, cfg.vocab_size), cfg.d_model, dtype)

    fam = cfg.family
    if fam in ("dense", "vlm"):
        params["layers"] = _stacked(
            ks[2], cfg.num_layers,
            lambda k: _dense_layer_init(k, cfg, dtype))
    elif fam == "moe":
        nd = cfg.moe.first_dense_layers
        if nd:
            params["layers_pre"] = [
                _dense_layer_init(k, cfg, dtype, d_ff=cfg.moe.d_ff_dense)
                for k in jax.random.split(ks[3], nd)]
        params["layers"] = _stacked(
            ks[2], cfg.num_layers - nd,
            lambda k: _dense_layer_init(k, cfg, dtype, moe_layer=True))
    elif fam == "ssm":
        params["layers"] = _stacked(
            ks[2], cfg.num_layers, lambda k: _ssm_layer_init(k, cfg, dtype))
    elif fam == "hybrid":
        kinds = cfg.block_kinds()
        lks = jax.random.split(ks[2], cfg.num_layers)
        params["layers"] = [
            _rglru_layer_init(k, cfg, dtype) if kind == "rglru"
            else _dense_layer_init(k, cfg, dtype)
            for k, kind in zip(lks, kinds)]
    elif fam == "encdec":
        enc = cfg.encoder
        ed = enc.d_model or cfg.d_model
        params["layers"] = _stacked(
            ks[2], cfg.num_layers, lambda k: _xattn_layer_init(k, cfg, dtype))
        params["encoder"] = {
            "layers": _stacked(
                ks[4], enc.num_layers,
                lambda k: _dense_layer_init(k, cfg, dtype)),
            "final_norm": jnp.zeros((ed,), dtype),
        }
    else:
        raise ValueError(fam)
    return params


# ======================================================================
# shared pieces
# ======================================================================
def _embed(cfg, params, tokens):
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x


def _logits(cfg, params, x):
    x = L.rms_norm(x, params["final_norm"], cfg.rms_eps)
    w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = (x @ w.astype(x.dtype)).astype(jnp.float32)
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return logits


def _attn_nocache(cfg, lp, x, positions, mask, *, window=None):
    h = L.rms_norm(x, lp["ln1"], cfg.rms_eps)
    impl = cfg.attention_impl
    if cfg.mla is not None:
        a, _ = mla_mod.mla_forward(lp["attn"], cfg, h, positions, mask,
                                   impl=impl)
    else:
        q, k, v = L.attn_project_qkv(lp["attn"], cfg, h, positions)
        a = L.gqa_attention(q, k, v, mask, logit_softcap=None, impl=impl)
        a = L.attn_output(lp["attn"], a)
    return x + a


def _mlp_block(cfg, lp, x, mesh, d_ff_kind=None):
    h = L.rms_norm(x, lp["ln2"], cfg.rms_eps)
    if "moe" in lp:
        da = (tuple(n for n in mesh.axis_names if n != "model")
              if mesh is not None else ("data",))
        y, aux = moe_mod.moe_apply(lp["moe"], h, cfg, mesh, data_axes=da)
    else:
        y, aux = L.mlp_apply(lp["mlp"], h, d_ff_kind or cfg.mlp_kind), 0.0
    return x + y, aux


def _frontend_concat(cfg, x_tok, frontend_embeds):
    """Prepend stub modality embeddings (vlm). Returns x [B, S_total, d]."""
    if frontend_embeds is None:
        return x_tok
    return jnp.concatenate(
        [frontend_embeds.astype(x_tok.dtype), x_tok], axis=1)


# ======================================================================
# teacher-forcing forward (training graph)
# ======================================================================
def forward(cfg, params, tokens, *, frontend_embeds=None, prefix_len=None,
            enc_frames=None, mesh=None, remat: bool = False,
            seq_spec=None):
    """tokens [B, S_text] -> logits [B, S_total, V], aux loss scalar.

    seq_spec: optional NamedSharding for the residual stream at layer
    boundaries (Megatron-SP: the remat-saved activations shard their
    sequence dim over 'model', cutting live-activation HBM by the TP
    degree on the big train cells).
    """
    x = _embed(cfg, params, tokens)
    x = _frontend_concat(cfg, x, frontend_embeds)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    mask = L.attention_mask(positions, positions, causal=True,
                            window=cfg.sliding_window, prefix_len=prefix_len)
    fam = cfg.family

    def _sp(h):
        if seq_spec is not None:
            h = jax.lax.with_sharding_constraint(h, seq_spec)
        return h

    if fam in ("dense", "vlm", "moe"):
        def body(carry, lp):
            h = _attn_nocache(cfg, lp, _sp(carry), positions, mask)
            h, aux = _mlp_block(cfg, lp, h, mesh)
            return _sp(h), aux
        if remat:
            body = jax.checkpoint(body)
        for lp in params.get("layers_pre", []):
            x = _attn_nocache(cfg, lp, x, positions, mask)
            x, _ = _mlp_block(cfg, lp, x, mesh)
        x, auxs = _scan(body, x, params["layers"])
        aux = jnp.sum(auxs) if fam == "moe" else 0.0

    elif fam == "ssm":
        def body(carry, lp):
            h = L.rms_norm(carry, lp["ln1"], cfg.rms_eps)
            y, _ = ssm_mod.mamba2_forward(lp["mixer"], cfg, h)
            return carry + y, 0.0
        if remat:
            body = jax.checkpoint(body)
        x, _ = _scan(body, x, params["layers"])
        aux = 0.0

    elif fam == "hybrid":
        local_mask = L.attention_mask(
            positions, positions, causal=True,
            window=cfg.rglru.local_window)
        for lp, kind in zip(params["layers"], cfg.block_kinds()):
            if kind == "rglru":
                h = L.rms_norm(x, lp["ln1"], cfg.rms_eps)
                y, _ = rg.recurrent_block_forward(lp["rec"], cfg, h)
                x = x + y
            else:
                x = _attn_nocache(cfg, lp, x, positions, local_mask)
            x, _ = _mlp_block(cfg, lp, x, mesh)
        aux = 0.0

    elif fam == "encdec":
        enc_out = encode(cfg, params, enc_frames)
        F = enc_out.shape[1]
        x_pos = jnp.broadcast_to(jnp.arange(F, dtype=jnp.int32), (B, F))
        xmask = jnp.ones((B, S, F), bool)

        def body(carry, lp):
            h = _attn_nocache(cfg, lp, carry, positions, mask)
            g = L.rms_norm(h, lp["ln_x"], cfg.rms_eps)
            q = jnp.einsum("bsd,dhe->bshe", g, lp["xattn"]["wq"])
            k = jnp.einsum("bsd,dhe->bshe", enc_out, lp["xattn"]["wk"])
            v = jnp.einsum("bsd,dhe->bshe", enc_out, lp["xattn"]["wv"])
            a = L.gqa_attention(q, k, v, xmask, impl=cfg.attention_impl)
            h = h + L.attn_output(lp["xattn"], a)
            h, _ = _mlp_block(cfg, lp, h, mesh)
            return h, 0.0
        if remat:
            body = jax.checkpoint(body)
        x, _ = _scan(body, x, params["layers"])
        aux = 0.0
    else:
        raise ValueError(fam)

    return _logits(cfg, params, x), aux


def encode(cfg, params, frames):
    """whisper encoder over stub frame embeddings [B, F, d]."""
    enc = params["encoder"]
    B, F, d = frames.shape
    x = frames.astype(jnp.dtype(cfg.dtype)) \
        + L.sinusoidal_positions(F, d).astype(jnp.dtype(cfg.dtype))
    positions = jnp.broadcast_to(jnp.arange(F, dtype=jnp.int32), (B, F))
    mask = jnp.ones((B, F, F), bool)

    def body(carry, lp):
        h = L.rms_norm(carry, lp["ln1"], cfg.rms_eps)
        q, k, v = L.attn_project_qkv(lp["attn"], cfg, h, positions,
                                     rope=False)
        a = L.gqa_attention(q, k, v, mask, impl=cfg.attention_impl)
        h = carry + L.attn_output(lp["attn"], a)
        h, _ = _mlp_block(cfg, lp, h, None)
        return h, 0.0

    x, _ = _scan(body, x, enc["layers"])
    return L.rms_norm(x, enc["final_norm"], cfg.rms_eps)


def loss_fn(cfg, params, batch, *, mesh=None, remat: bool = False,
            seq_spec=None):
    """batch: tokens [B,S], labels [B,S], optional weights/frames/patches."""
    logits, aux = forward(
        cfg, params, batch["tokens"],
        frontend_embeds=batch.get("patches"),
        enc_frames=batch.get("frames"),
        prefix_len=batch.get("prefix_len"),
        mesh=mesh, remat=remat, seq_spec=seq_spec)
    labels = batch["labels"]
    # frontend positions carry no labels
    if logits.shape[1] != labels.shape[1]:
        logits = logits[:, logits.shape[1] - labels.shape[1]:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    w = batch.get("weights", jnp.ones_like(ll))
    denom = jnp.maximum(jnp.sum(w), 1.0)
    ce = -jnp.sum(ll * w) / denom
    # z-loss stabilizer
    z = jnp.sum(jnp.square(jax.scipy.special.logsumexp(logits, axis=-1)) * w)
    total = ce + Z_LOSS_COEF * z / denom + MOE_AUX_COEF * aux
    return total, {"ce": ce, "aux": aux}


# ======================================================================
# KV cache
# ======================================================================
def cache_window(cfg, capacity: int) -> int:
    if cfg.family == "hybrid":
        return min(capacity, cfg.rglru.local_window)
    if cfg.sliding_window is not None:
        return min(capacity, cfg.sliding_window)
    return capacity


def init_cache(cfg, batch: int, capacity: int, *, enc_frames: int = 0):
    """Allocate an empty decode cache (ring buffers of W slots)."""
    dtype = jnp.dtype(cfg.dtype)
    W = cache_window(cfg, capacity)
    hd = cfg.resolved_head_dim
    cache = {"len": jnp.zeros((batch,), jnp.int32)}
    fam = cfg.family
    n_att = cfg.num_layers
    if fam == "hybrid":
        kinds = cfg.block_kinds()
        n_att = sum(k == "local_attn" for k in kinds)
        n_rec = sum(k == "rglru" for k in kinds)
        w = cfg.rglru.lru_width or cfg.d_model
        cache["rec_h"] = jnp.zeros((n_rec, batch, w), jnp.float32)
        cache["rec_conv"] = jnp.zeros(
            (n_rec, batch, cfg.rglru.conv_width - 1, w), dtype)
    if fam == "ssm":
        cx_shape, cbc_shape, state_shape = ssm_mod.mamba2_state_shape(
            cfg, batch)
        cache["conv_x"] = jnp.zeros((cfg.num_layers,) + cx_shape, dtype)
        cache["conv_bc"] = jnp.zeros((cfg.num_layers,) + cbc_shape, dtype)
        cache["ssm_state"] = jnp.zeros((cfg.num_layers,) + state_shape,
                                       jnp.float32)
        return cache
    if cfg.mla is not None:
        m = cfg.mla
        cache["ckv"] = jnp.zeros((cfg.num_layers, batch, W, m.kv_lora_rank),
                                 dtype)
        cache["k_rope"] = jnp.zeros(
            (cfg.num_layers, batch, W, m.rope_head_dim), dtype)
    else:
        cache["k"] = jnp.zeros((n_att, batch, W, cfg.num_kv_heads, hd), dtype)
        cache["v"] = jnp.zeros((n_att, batch, W, cfg.num_kv_heads, hd), dtype)
    cache["kv_pos"] = jnp.full((batch, W), -1, jnp.int32)
    if fam == "encdec":
        cache["cross_k"] = jnp.zeros(
            (cfg.num_layers, batch, enc_frames, cfg.num_heads, hd), dtype)
        cache["cross_v"] = jnp.zeros_like(cache["cross_k"])
    return cache


def _ring_write(buf, slots, new):
    """buf [B, W, ...], slots [B, S], new [B, S, ...] -> updated buf."""
    B = buf.shape[0]
    b_idx = jnp.arange(B)[:, None]
    return buf.at[b_idx, slots].set(new.astype(buf.dtype), mode="drop")


def _decode_mask(cfg, q_pos, kv_pos, window):
    return L.attention_mask(q_pos, kv_pos, causal=True, window=window,
                            kv_valid=kv_pos >= 0)


# ======================================================================
# prefill
# ======================================================================
def prefill(cfg, params, tokens, cache, *, frontend_embeds=None,
            prefix_len=None, enc_frames=None, seq_lens=None, mesh=None):
    """Run the full prompt, fill the cache. Returns (last_logits [B,V], cache).

    Supports S > W (ring keeps the last W positions). ``seq_lens`` marks the
    true per-row prompt length (padded rows produce masked cache slots).
    """
    x = _embed(cfg, params, tokens)
    x = _frontend_concat(cfg, x, frontend_embeds)
    B, S, _ = x.shape
    if seq_lens is None:
        seq_lens = jnp.full((B,), S, jnp.int32)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    valid = positions < seq_lens[:, None]
    mask = L.attention_mask(positions, positions, causal=True,
                            window=cfg.sliding_window, prefix_len=prefix_len)
    mask = mask & valid[:, None, :]
    fam = cfg.family
    W = (cache["kv_pos"].shape[1] if "kv_pos" in cache
         else cache_window(cfg, S))
    # ring slots; positions outside the last-W window are dropped
    slots = jnp.where((positions >= S - W) & valid, positions % W, W)
    if W == S:
        # fresh full-capacity cache: the write is position-aligned, so an
        # element-wise select replaces the scatter (a scatter with global
        # batch indices forces SPMD to all-gather K/V over the data axis
        # -- 17 GB/layer-pair at prefill_32k; see EXPERIMENTS #Perf)
        def _pwrite(buf, new_vals):
            keep = valid.reshape(valid.shape + (1,) * (new_vals.ndim - 2))
            return jnp.where(keep, new_vals, 0).astype(buf.dtype)
    else:
        def _pwrite(buf, new_vals):
            return _ring_write(buf, slots, new_vals)

    if fam in ("dense", "vlm", "moe"):
        def body(carry, xs):
            lp, kc, vc = xs
            h = L.rms_norm(carry, lp["ln1"], cfg.rms_eps)
            if cfg.mla is not None:
                a, (ckv, kr) = mla_mod.mla_forward(
                    lp["attn"], cfg, h, positions, mask,
                    impl=cfg.attention_impl)
                kc = _pwrite(kc, ckv)
                vc = _pwrite(vc, kr)
            else:
                q, k, v = L.attn_project_qkv(lp["attn"], cfg, h, positions)
                a = L.gqa_attention(q, k, v, mask,
                                    impl=cfg.attention_impl)
                a = L.attn_output(lp["attn"], a)
                kc = _pwrite(kc, k)
                vc = _pwrite(vc, v)
            h = carry + a
            h, _ = _mlp_block(cfg, lp, h, mesh)
            return h, (kc, vc)

        for i, lp in enumerate(params.get("layers_pre", [])):
            names = ("ckv", "k_rope") if cfg.mla is not None else ("k", "v")
            x, (kc, vc) = body(x, (lp, cache[names[0]][i], cache[names[1]][i]))
            cache[names[0]] = cache[names[0]].at[i].set(kc)
            cache[names[1]] = cache[names[1]].at[i].set(vc)
        names = ("ckv", "k_rope") if cfg.mla is not None else ("k", "v")
        npre = len(params.get("layers_pre", []))
        x, (kcs, vcs) = _scan(
            body, x, (params["layers"], cache[names[0]][npre:],
                      cache[names[1]][npre:]))
        cache[names[0]] = (jnp.concatenate([cache[names[0]][:npre], kcs])
                           if npre else kcs)
        cache[names[1]] = (jnp.concatenate([cache[names[1]][:npre], vcs])
                           if npre else vcs)

    elif fam == "ssm":
        def body(carry, xs):
            lp, cxc, cbc, st = xs
            h = L.rms_norm(carry, lp["ln1"], cfg.rms_eps)
            y, (cxc, cbc, st) = ssm_mod.mamba2_forward(lp["mixer"], cfg, h)
            return carry + y, (cxc, cbc, st)
        x, (cxs, cbcs, states) = _scan(
            body, x, (params["layers"], cache["conv_x"], cache["conv_bc"],
                      cache["ssm_state"]))
        cache["conv_x"], cache["conv_bc"] = cxs, cbcs
        cache["ssm_state"] = states

    elif fam == "hybrid":
        local_mask = L.attention_mask(positions, positions, causal=True,
                                      window=cfg.rglru.local_window)
        local_mask = local_mask & valid[:, None, :]
        ai = ri = 0
        for lp, kind in zip(params["layers"], cfg.block_kinds()):
            if kind == "rglru":
                h = L.rms_norm(x, lp["ln1"], cfg.rms_eps)
                y, (cc, hl) = rg.recurrent_block_forward(lp["rec"], cfg, h)
                cache["rec_conv"] = cache["rec_conv"].at[ri].set(
                    cc.astype(cache["rec_conv"].dtype))
                cache["rec_h"] = cache["rec_h"].at[ri].set(hl)
                x = x + y
                ri += 1
            else:
                h = L.rms_norm(x, lp["ln1"], cfg.rms_eps)
                q, k, v = L.attn_project_qkv(lp["attn"], cfg, h, positions)
                a = L.gqa_attention(q, k, v, local_mask,
                                    impl=cfg.attention_impl)
                x = x + L.attn_output(lp["attn"], a)
                cache["k"] = cache["k"].at[ai].set(
                    _pwrite(cache["k"][ai], k))
                cache["v"] = cache["v"].at[ai].set(
                    _pwrite(cache["v"][ai], v))
                ai += 1
            x, _ = _mlp_block(cfg, lp, x, mesh)

    elif fam == "encdec":
        enc_out = encode(cfg, params, enc_frames)
        F = enc_out.shape[1]
        xmask = jnp.ones((B, S, F), bool)

        def body(carry, xs):
            lp, kc, vc = xs
            h = L.rms_norm(carry, lp["ln1"], cfg.rms_eps)
            q, k, v = L.attn_project_qkv(lp["attn"], cfg, h, positions)
            a = L.gqa_attention(q, k, v, mask, impl=cfg.attention_impl)
            h = carry + L.attn_output(lp["attn"], a)
            kc = _pwrite(kc, k)
            vc = _pwrite(vc, v)
            g = L.rms_norm(h, lp["ln_x"], cfg.rms_eps)
            qx = jnp.einsum("bsd,dhe->bshe", g, lp["xattn"]["wq"])
            kx = jnp.einsum("bsd,dhe->bshe", enc_out, lp["xattn"]["wk"])
            vx = jnp.einsum("bsd,dhe->bshe", enc_out, lp["xattn"]["wv"])
            a = L.gqa_attention(qx, kx, vx, xmask,
                                impl=cfg.attention_impl)
            h = h + L.attn_output(lp["xattn"], a)
            h, _ = _mlp_block(cfg, lp, h, mesh)
            return h, (kc, vc, kx, vx)

        x, (kcs, vcs, kxs, vxs) = _scan(
            body, x, (params["layers"], cache["k"], cache["v"]))
        cache["k"], cache["v"] = kcs, vcs
        cache["cross_k"], cache["cross_v"] = kxs, vxs
    else:
        raise ValueError(fam)

    if "kv_pos" in cache:
        kv_pos = jnp.where(
            (positions >= S - W) & valid, positions, -1)
        if W == S:
            cache["kv_pos"] = kv_pos
        else:
            cache["kv_pos"] = _ring_write(
                jnp.full_like(cache["kv_pos"], -1), slots, kv_pos)
    cache["len"] = seq_lens
    logits = _logits(cfg, params, x)
    last = jnp.take_along_axis(
        logits, (seq_lens - 1)[:, None, None].clip(0), axis=1)[:, 0]
    return last, cache


# ======================================================================
# decode
# ======================================================================
def decode_step(cfg, params, tokens, cache, *, mesh=None):
    """tokens [B] -> (logits [B, V], cache). One AR step per sequence."""
    B = tokens.shape[0]
    x = _embed(cfg, params, tokens[:, None])
    q_pos = cache["len"][:, None]                       # [B, 1]
    fam = cfg.family

    if fam == "ssm":
        def body(carry, xs):
            lp, cxc, cbc, st = xs
            h = L.rms_norm(carry, lp["ln1"], cfg.rms_eps)
            y, (cxc, cbc, st) = ssm_mod.mamba2_decode(lp["mixer"], cfg, h,
                                                      (cxc, cbc), st)
            return carry + y, (cxc, cbc, st)
        x, (cxs, cbcs, states) = _scan(
            body, x, (params["layers"], cache["conv_x"], cache["conv_bc"],
                      cache["ssm_state"]))
        cache["conv_x"], cache["conv_bc"] = cxs, cbcs
        cache["ssm_state"] = states
        cache["len"] = cache["len"] + 1
        return _logits(cfg, params, x)[:, 0], cache

    W = cache["kv_pos"].shape[1]
    slots = cache["len"][:, None] % W                   # [B, 1]
    window = (cfg.rglru.local_window if fam == "hybrid"
              else cfg.sliding_window)

    if fam in ("dense", "vlm", "moe"):
        def body(carry, xs):
            lp, kc, vc = xs
            h = L.rms_norm(carry, lp["ln1"], cfg.rms_eps)
            if cfg.mla is not None:
                hq = h
                new_ckv, new_kr = mla_mod._project_kv_latent(
                    lp["attn"], cfg, hq, q_pos)
                kc = _ring_write(kc, slots, new_ckv)
                vc = _ring_write(vc, slots, new_kr)
                kv_pos = cache["kv_pos"].at[
                    jnp.arange(B)[:, None], slots].set(q_pos)
                mask = _decode_mask(cfg, q_pos, kv_pos, window)
                a, _ = mla_mod.mla_decode(lp["attn"], cfg, hq, q_pos,
                                          kc, vc, mask)
            else:
                q, k, v = L.attn_project_qkv(lp["attn"], cfg, h, q_pos)
                kc = _ring_write(kc, slots, k)
                vc = _ring_write(vc, slots, v)
                kv_pos = cache["kv_pos"].at[
                    jnp.arange(B)[:, None], slots].set(q_pos)
                mask = _decode_mask(cfg, q_pos, kv_pos, window)
                a = L.gqa_attention(q, kc, vc, mask)
                a = L.attn_output(lp["attn"], a)
            h = carry + a
            h, _ = _mlp_block(cfg, lp, h, mesh)
            return h, (kc, vc)

        names = ("ckv", "k_rope") if cfg.mla is not None else ("k", "v")
        for i, lp in enumerate(params.get("layers_pre", [])):
            x, (kc, vc) = body(x, (lp, cache[names[0]][i],
                                   cache[names[1]][i]))
            cache[names[0]] = cache[names[0]].at[i].set(kc)
            cache[names[1]] = cache[names[1]].at[i].set(vc)
        npre = len(params.get("layers_pre", []))
        x, (kcs, vcs) = _scan(
            body, x, (params["layers"], cache[names[0]][npre:],
                      cache[names[1]][npre:]))
        cache[names[0]] = (jnp.concatenate([cache[names[0]][:npre], kcs])
                           if npre else kcs)
        cache[names[1]] = (jnp.concatenate([cache[names[1]][:npre], vcs])
                           if npre else vcs)

    elif fam == "hybrid":
        ai = ri = 0
        for lp, kind in zip(params["layers"], cfg.block_kinds()):
            h = L.rms_norm(x, lp["ln1"], cfg.rms_eps)
            if kind == "rglru":
                y, (cc, hh) = rg.recurrent_block_decode(
                    lp["rec"], cfg, h, cache["rec_conv"][ri],
                    cache["rec_h"][ri])
                cache["rec_conv"] = cache["rec_conv"].at[ri].set(
                    cc.astype(cache["rec_conv"].dtype))
                cache["rec_h"] = cache["rec_h"].at[ri].set(hh)
                x = x + y
                ri += 1
            else:
                q, k, v = L.attn_project_qkv(lp["attn"], cfg, h, q_pos)
                kc = _ring_write(cache["k"][ai], slots, k)
                vc = _ring_write(cache["v"][ai], slots, v)
                cache["k"] = cache["k"].at[ai].set(kc)
                cache["v"] = cache["v"].at[ai].set(vc)
                kv_pos = cache["kv_pos"].at[
                    jnp.arange(B)[:, None], slots].set(q_pos)
                mask = _decode_mask(cfg, q_pos, kv_pos, window)
                a = L.gqa_attention(q, kc, vc, mask)
                x = x + L.attn_output(lp["attn"], a)
                ai += 1
            x, _ = _mlp_block(cfg, lp, x, mesh)

    elif fam == "encdec":
        F = cache["cross_k"].shape[2]
        xmask = jnp.ones((B, 1, F), bool)

        def body(carry, xs):
            lp, kc, vc, kx, vx = xs
            h = L.rms_norm(carry, lp["ln1"], cfg.rms_eps)
            q, k, v = L.attn_project_qkv(lp["attn"], cfg, h, q_pos)
            kc = _ring_write(kc, slots, k)
            vc = _ring_write(vc, slots, v)
            kv_pos = cache["kv_pos"].at[
                jnp.arange(B)[:, None], slots].set(q_pos)
            mask = _decode_mask(cfg, q_pos, kv_pos, window)
            a = L.gqa_attention(q, kc, vc, mask)
            h = carry + L.attn_output(lp["attn"], a)
            g = L.rms_norm(h, lp["ln_x"], cfg.rms_eps)
            qx = jnp.einsum("bsd,dhe->bshe", g, lp["xattn"]["wq"])
            a = L.gqa_attention(qx, kx, vx, xmask)
            h = h + L.attn_output(lp["xattn"], a)
            h, _ = _mlp_block(cfg, lp, h, mesh)
            return h, (kc, vc)

        x, (kcs, vcs) = _scan(
            body, x, (params["layers"], cache["k"], cache["v"],
                      cache["cross_k"], cache["cross_v"]))
        cache["k"], cache["v"] = kcs, vcs
    else:
        raise ValueError(fam)

    if "kv_pos" in cache:
        cache["kv_pos"] = cache["kv_pos"].at[
            jnp.arange(B)[:, None], slots].set(q_pos)
    cache["len"] = cache["len"] + 1
    return _logits(cfg, params, x)[:, 0], cache

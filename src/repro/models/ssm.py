"""Mamba2 mixer — chunked state-space duality (SSD), pure jnp.

Port of the published minimal SSD algorithm (arXiv:2405.21060 listing 1) to
JAX. This is both the training/prefill path and the oracle the
``kernels/ssd_scan`` Pallas kernel is validated against.

Projections are kept as separate matrices (w_z / w_x / w_B / w_C / w_dt and
separate depthwise convs for x vs B/C) rather than one fused in_proj: the
x/dt/z paths are head-sharded under tensor parallelism while the grouped
B/C paths are replicated — a fused matrix cannot carry a mixed
PartitionSpec (DESIGN.md §8).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, rms_norm

NEG_INF = -1e30


def segsum(x):
    """x [..., T] -> lower-triangular segment sums [..., T, T] (log-space)."""
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool), k=0)
    return jnp.where(mask, seg, NEG_INF)


def ssd_chunked(X, A, B, C, chunk: int, initial_state=None):
    """Chunked SSD.

    X: [b, l, h, p] (pre-multiplied by dt), A: [b, l, h] log-decay (dt*A_cont),
    B, C: [b, l, h, n]. Returns (Y [b, l, h, p], final_state [b, h, p, n]).
    """
    b, l, h, p = X.shape
    n = B.shape[-1]
    assert l % chunk == 0, (l, chunk)
    nc = l // chunk
    Xc = X.reshape(b, nc, chunk, h, p)
    Ac = A.reshape(b, nc, chunk, h).transpose(0, 3, 1, 2)      # [b,h,c,l]
    Bc = B.reshape(b, nc, chunk, h, n)
    Cc = C.reshape(b, nc, chunk, h, n)
    A_cumsum = jnp.cumsum(Ac, axis=-1)                         # [b,h,c,l]

    # 1. intra-chunk (diagonal blocks)
    L = jnp.exp(segsum(Ac))                                    # [b,h,c,l,s]
    Y_diag = jnp.einsum("bclhn,bcshn,bhcls,bcshp->bclhp", Cc, Bc, L, Xc)

    # 2. per-chunk end states
    decay_states = jnp.exp(A_cumsum[..., -1:] - A_cumsum)      # [b,h,c,l]
    states = jnp.einsum("bclhn,bhcl,bclhp->bchpn", Bc, decay_states, Xc)

    # 3. inter-chunk recurrence
    if initial_state is None:
        initial_state = jnp.zeros_like(states[:, :1])
    else:
        initial_state = initial_state[:, None]                 # [b,1,h,p,n]
    states = jnp.concatenate([initial_state, states], axis=1)  # [b,nc+1,...]
    pad = jnp.pad(A_cumsum[..., -1], ((0, 0), (0, 0), (1, 0)))
    decay_chunk = jnp.exp(segsum(pad))                         # [b,h,nc+1,nc+1]
    new_states = jnp.einsum("bhzc,bchpn->bzhpn", decay_chunk, states)
    prev_states, final_state = new_states[:, :-1], new_states[:, -1]

    # 4. state -> output contribution
    state_decay_out = jnp.exp(A_cumsum)                        # [b,h,c,l]
    Y_off = jnp.einsum("bclhn,bchpn,bhcl->bclhp", Cc, prev_states,
                       state_decay_out)
    Y = (Y_diag + Y_off).reshape(b, l, h, p)
    return Y, final_state


def ssd_decode_step(state, x, dA, dBx_B, C):
    """Single-token recurrence. state [b,h,p,n], x [b,h,p], dA [b,h],
    dBx_B [b,h,n] (dt-scaled B), C [b,h,n]."""
    state = state * jnp.exp(dA)[..., None, None] \
        + x[..., :, None] * dBx_B[..., None, :]
    y = jnp.einsum("bhpn,bhn->bhp", state, C)
    return state, y


# ----------------------------------------------------------------- block
def causal_conv1d(x, w, cache=None):
    """Depthwise causal conv. x [b, l, ch], w [cw, ch].

    Returns (y [b, l, ch], new_cache [b, cw-1, ch]).
    """
    cw = w.shape[0]
    if cache is None:
        cache = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([cache, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(cw))
    new_cache = xp[:, -(cw - 1):, :] if cw > 1 else cache
    return y, new_cache


def mamba2_init(key, cfg, dtype):
    s = cfg.ssm
    d = cfg.d_model
    d_in = d * s.expand
    nheads = d_in // s.head_dim
    gn = s.num_groups * s.state_dim
    ks = jax.random.split(key, 8)
    return {
        "w_z": dense_init(ks[0], (d, d_in), d, dtype),
        "w_x": dense_init(ks[1], (d, d_in), d, dtype),
        "w_B": dense_init(ks[2], (d, gn), d, dtype),
        "w_C": dense_init(ks[3], (d, gn), d, dtype),
        "w_dt": dense_init(ks[4], (d, nheads), d, dtype),
        "conv_x": (jax.random.normal(ks[5], (s.conv_width, d_in),
                                     jnp.float32) * 0.1).astype(dtype),
        "conv_x_b": jnp.zeros((d_in,), dtype),
        "conv_bc": (jax.random.normal(ks[6], (s.conv_width, 2 * gn),
                                      jnp.float32) * 0.1).astype(dtype),
        "conv_bc_b": jnp.zeros((2 * gn,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nheads, dtype=jnp.float32)),
        "D": jnp.ones((nheads,), jnp.float32),
        "dt_bias": jnp.zeros((nheads,), jnp.float32),
        "norm": jnp.zeros((d_in,), dtype),
        "out_proj": dense_init(ks[7], (d_in, d), d_in, dtype),
    }


def _project(params, cfg, u, conv_x_cache, conv_bc_cache):
    """Shared projection + conv for forward/decode."""
    s = cfg.ssm
    gn = s.num_groups * s.state_dim
    nheads = (cfg.d_model * s.expand) // s.head_dim
    z = u @ params["w_z"]
    x = u @ params["w_x"]
    bc = jnp.concatenate([u @ params["w_B"], u @ params["w_C"]], axis=-1)
    dt_raw = u @ params["w_dt"]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    x, conv_x_cache = causal_conv1d(x, params["conv_x"], conv_x_cache)
    x = jax.nn.silu(x + params["conv_x_b"])
    bc, conv_bc_cache = causal_conv1d(bc, params["conv_bc"], conv_bc_cache)
    bc = jax.nn.silu(bc + params["conv_bc_b"])
    B, C = bc[..., :gn], bc[..., gn:]
    return z, x, B, C, dt, conv_x_cache, conv_bc_cache


def mamba2_forward(params, cfg, u, conv_caches=None, ssm_state=None):
    """u [b, l, d] -> (y [b, l, d], (conv_x_c, conv_bc_c, ssm_state))."""
    s = cfg.ssm
    b, l, d = u.shape
    d_in = d * s.expand
    nheads = d_in // s.head_dim
    cxc, cbc = conv_caches if conv_caches is not None else (None, None)
    z, x, B, C, dt, cxc, cbc = _project(params, cfg, u, cxc, cbc)
    x = x.reshape(b, l, nheads, s.head_dim)
    rep = nheads // s.num_groups
    Bh = jnp.repeat(B.reshape(b, l, s.num_groups, s.state_dim), rep, axis=2)
    Ch = jnp.repeat(C.reshape(b, l, s.num_groups, s.state_dim), rep, axis=2)
    A = -jnp.exp(params["A_log"])                              # [h]
    chunk = min(s.chunk_size, l)
    pad = (-l) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bh = jnp.pad(Bh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Ch = jnp.pad(Ch, ((0, 0), (0, pad), (0, 0), (0, 0)))
    X = (x.astype(jnp.float32) * dt[..., None])
    Y, ssm_state = ssd_chunked(X, dt * A, Bh.astype(jnp.float32),
                               Ch.astype(jnp.float32), chunk,
                               initial_state=ssm_state)
    Y = Y[:, :l]
    x = x[:, :l]
    Y = Y + params["D"][:, None] * x.astype(jnp.float32)
    y = Y.reshape(b, l, d_in).astype(u.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], cfg.rms_eps)
    return y @ params["out_proj"], (cxc, cbc, ssm_state)


def mamba2_decode(params, cfg, u, conv_caches, ssm_state):
    """u [b, 1, d] single-token step with recurrent state update."""
    s = cfg.ssm
    b = u.shape[0]
    d_in = cfg.d_model * s.expand
    nheads = d_in // s.head_dim
    cxc, cbc = conv_caches
    z, x, B, C, dt, cxc, cbc = _project(params, cfg, u, cxc, cbc)
    x = x.reshape(b, nheads, s.head_dim).astype(jnp.float32)
    rep = nheads // s.num_groups
    Bh = jnp.repeat(B.reshape(b, s.num_groups, s.state_dim), rep, axis=1)
    Ch = jnp.repeat(C.reshape(b, s.num_groups, s.state_dim), rep, axis=1)
    dt1 = dt[:, 0]                                             # [b, h]
    A = -jnp.exp(params["A_log"])
    ssm_state, y = ssd_decode_step(
        ssm_state, x * dt1[..., None], dt1 * A,
        Bh.astype(jnp.float32), Ch.astype(jnp.float32))
    y = y + params["D"][:, None] * x
    y = y.reshape(b, 1, d_in).astype(u.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], cfg.rms_eps)
    return y @ params["out_proj"], (cxc, cbc, ssm_state)


def mamba2_state_shape(cfg, batch: int):
    s = cfg.ssm
    d_in = cfg.d_model * s.expand
    nheads = d_in // s.head_dim
    gn = s.num_groups * s.state_dim
    return ((batch, s.conv_width - 1, d_in),        # conv_x cache
            (batch, s.conv_width - 1, 2 * gn),      # conv_bc cache
            (batch, nheads, s.head_dim, s.state_dim))

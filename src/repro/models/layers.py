"""Core transformer layers, written as pure functions over param pytrees.

Everything here lowers cleanly under pjit (einsum/jnp only — the Pallas
kernels in ``repro.kernels`` are the TPU runtime path and are swapped in at
the serving-engine level, never in the dry-run graph, because XLA:CPU cannot
cost-model custom calls).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


# ----------------------------------------------------------------- init
def dense_init(key, shape, in_axis_size, dtype):
    scale = 1.0 / math.sqrt(in_axis_size)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            ).astype(dtype)


# ----------------------------------------------------------------- norms
def rms_norm(x, weight, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dt)


def layer_norm(x, weight, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * weight + bias).astype(dt)


# ----------------------------------------------------------------- rope
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float):
    """x: [B, S, H, D]; positions: [B, S] (absolute)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # [D/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [B,S,D/2]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(length: int, d_model: int):
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d_model, 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, dim / d_model)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


# ----------------------------------------------------------------- attention
NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def attention_mask(q_pos, kv_pos, *, causal: bool,
                   window: Optional[int] = None,
                   kv_valid=None, prefix_len=None):
    """Boolean [B, Sq, Skv] mask (True = attend).

    q_pos: [B, Sq] absolute positions; kv_pos: [B, Skv].
    window: sliding-window size (q - k < window).
    prefix_len: [B] prefix-LM boundary — bidirectional within the prefix.
    """
    q = q_pos[:, :, None]
    k = kv_pos[:, None, :]
    mask = jnp.ones(jnp.broadcast_shapes(q.shape, k.shape), dtype=bool)
    if causal:
        c = k <= q
        if prefix_len is not None:
            c = c | (k < prefix_len[:, None, None])
        mask = mask & c
    if window is not None:
        mask = mask & (q - k < window)
    if kv_valid is not None:
        mask = mask & kv_valid[:, None, :]
    return mask


def gqa_attention(q, k, v, mask, *, logit_softcap: Optional[float] = None,
                  scale: Optional[float] = None, impl: str = "einsum"):
    """Grouped-query attention.

    q: [B, Sq, Hq, D], k/v: [B, Skv, Hkv, D], mask: [B, Sq, Skv] bool.
    Returns [B, Sq, Hq, D].

    impl='surrogate' replaces the S^2 logits chain with a shape-preserving
    stand-in that only streams Q/K/V/O — used by the dry-run perf pass to
    measure the non-attention byte load of a cell (the TPU runtime path
    computes real attention in the Pallas flash kernel, whose HBM traffic
    is exactly this Q/K/V/O streaming; XLA cannot cost-model the custom
    call, so the surrogate lowering bounds it empirically).
    """
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    if impl == "surrogate":
        kv = jnp.mean(k + v, axis=1, keepdims=True)          # reads K+V
        out = q * jnp.asarray(scale, q.dtype) + jnp.repeat(
            kv, G, axis=2)[:, :1]                            # reads Q
        return out.reshape(B, Sq, Hq, v.shape[-1]) if D == v.shape[-1] \
            else jnp.repeat(out[..., :1], v.shape[-1], axis=-1)
    qg = q.reshape(B, Sq, Hkv, G, D)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32) * scale
    if logit_softcap is not None:
        logits = jnp.tanh(logits / logit_softcap) * logit_softcap
    logits = jnp.where(mask[:, None, None, :, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v)
    return out.reshape(B, Sq, Hq, v.shape[-1])


# ----------------------------------------------------------------- mlp
def mlp_apply(params, x, kind: str):
    if kind in ("swiglu", "geglu"):
        act = jax.nn.silu if kind == "swiglu" else jax.nn.gelu
        h = act(x @ params["w_gate"]) * (x @ params["w_up"])
        return h @ params["w_down"]
    if kind == "squared_relu":
        h = jnp.square(jax.nn.relu(x @ params["w_up"]))
        return h @ params["w_down"]
    if kind == "gelu":
        h = jax.nn.gelu(x @ params["w_up"] + params["b_up"])
        return h @ params["w_down"] + params["b_down"]
    raise ValueError(f"unknown mlp kind {kind!r}")


def mlp_init(key, d_model: int, d_ff: int, kind: str, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    if kind in ("swiglu", "geglu"):
        return {
            "w_gate": dense_init(k1, (d_model, d_ff), d_model, dtype),
            "w_up": dense_init(k2, (d_model, d_ff), d_model, dtype),
            "w_down": dense_init(k3, (d_ff, d_model), d_ff, dtype),
        }
    if kind == "squared_relu":
        return {
            "w_up": dense_init(k1, (d_model, d_ff), d_model, dtype),
            "w_down": dense_init(k2, (d_ff, d_model), d_ff, dtype),
        }
    if kind == "gelu":
        return {
            "w_up": dense_init(k1, (d_model, d_ff), d_model, dtype),
            "b_up": jnp.zeros((d_ff,), dtype),
            "w_down": dense_init(k2, (d_ff, d_model), d_ff, dtype),
            "b_down": jnp.zeros((d_model,), dtype),
        }
    raise ValueError(kind)


# ----------------------------------------------------------------- attn block
def attn_init(key, cfg, dtype, *, d_model=None, mha=False):
    d = d_model or cfg.d_model
    hd = cfg.resolved_head_dim
    hq = cfg.num_heads
    hkv = hq if mha else cfg.num_kv_heads
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], (d, hq, hd), d, dtype),
        "wk": dense_init(ks[1], (d, hkv, hd), d, dtype),
        "wv": dense_init(ks[2], (d, hkv, hd), d, dtype),
        "wo": dense_init(ks[3], (hq, hd, d), hq * hd, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq, hd), dtype)
        p["bk"] = jnp.zeros((hkv, hd), dtype)
        p["bv"] = jnp.zeros((hkv, hd), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def attn_project_qkv(params, cfg, x, positions, *, rope=True):
    """Project + (optionally) rope. Returns q [B,S,Hq,D], k/v [B,S,Hkv,D]."""
    q = jnp.einsum("bsd,dhe->bshe", x, params["wq"])
    k = jnp.einsum("bsd,dhe->bshe", x, params["wk"])
    v = jnp.einsum("bsd,dhe->bshe", x, params["wv"])
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.rms_eps)
        k = rms_norm(k, params["k_norm"], cfg.rms_eps)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_output(params, out):
    return jnp.einsum("bshe,hed->bsd", out, params["wo"])

"""Mixture-of-Experts layer.

Two execution paths with identical numerics:

- ``moe_local``: single-device / pjit-friendly. Dispatch is gather-based
  (argsort routing -> [E, C] token-index table), so HLO FLOPs are
  proportional to top_k (no one-hot einsum blow-up).
- ``moe_ep``: explicit expert parallelism under ``shard_map``. Tokens are
  routed locally per (data, model) shard, exchanged with two
  ``all_to_all`` collectives over the expert ('model') axis (DeepSeek-style
  EP), and the output restored with one ``all_gather``.

Capacity-factor dropping matches the published dropping implementations
(tokens beyond an expert's capacity fall back to the residual path).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import dense_init, mlp_apply, mlp_init

# Expert tensor-parallelism over the 'data' axis (decode_2d layouts):
# valid only when activations are replicated across 'data' (big-model
# decode), where the d_ff-sharded expert GEMM + psum replaces the
# prohibitive per-step gather of d-sharded expert weights.
_EXPERT_TP = False


def set_expert_tp(v: bool) -> None:
    global _EXPERT_TP
    _EXPERT_TP = v


# ----------------------------------------------------------------- init
def moe_init(key, cfg, dtype):
    m = cfg.moe
    d = cfg.d_model
    f = m.d_ff_expert
    ks = jax.random.split(key, 5)
    ek = jax.random.split(ks[0], m.num_experts)
    p = {
        "router": dense_init(ks[1], (d, m.num_experts), d, jnp.float32),
        "w_gate": jax.vmap(lambda k: dense_init(k, (d, f), d, dtype))(ek),
        "w_up": jax.vmap(
            lambda k: dense_init(k, (d, f), d, dtype))(
                jax.random.split(ks[2], m.num_experts)),
        "w_down": jax.vmap(
            lambda k: dense_init(k, (f, d), f, dtype))(
                jax.random.split(ks[3], m.num_experts)),
    }
    if m.num_shared_experts:
        p["shared"] = mlp_init(ks[4], d, f * m.num_shared_experts,
                               "swiglu", dtype)
    return p


# ----------------------------------------------------------------- routing
def route(router_w, x2d, top_k: int, *, normalize: bool = True):
    """x2d [T, d] -> (weights [T,k] f32, sel [T,k] i32, aux_loss scalar)."""
    logits = x2d.astype(jnp.float32) @ router_w
    probs = jax.nn.softmax(logits, axis=-1)
    weights, sel = jax.lax.top_k(probs, top_k)
    if normalize:
        weights = weights / (jnp.sum(weights, axis=-1, keepdims=True) + 1e-9)
    # Switch-style load-balance loss: E * sum_e f_e * P_e
    E = router_w.shape[-1]
    f_e = jnp.mean(jax.nn.one_hot(sel, E, dtype=jnp.float32), axis=(0, 1))
    p_e = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(f_e * p_e)
    return weights, sel, aux


def _capacity(tokens: int, top_k: int, num_experts: int, cf: float) -> int:
    c = int(math.ceil(tokens * top_k / num_experts * cf))
    return max(4, -(-c // 4) * 4)  # round up to a multiple of 4


def build_dispatch(sel, weights, num_experts: int, capacity: int):
    """argsort-based dispatch tables.

    Returns (tok_idx [E, C] int32 — index into the padded token array where
    row ``T`` is the zero pad; w [E, C] f32 combine weights, 0 on empties).
    """
    T, k = sel.shape
    flat_e = sel.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)            # slots sorted by expert
    sorted_e = flat_e[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(num_experts))
    rank = jnp.arange(T * k, dtype=jnp.int32) - starts[sorted_e]
    keep = rank < capacity
    dest = jnp.where(keep, sorted_e * capacity + rank, num_experts * capacity)
    tok_of_slot = (order // k).astype(jnp.int32)
    w_of_slot = weights.reshape(-1)[order]
    tok_idx = jnp.full((num_experts * capacity + 1,), T, jnp.int32)
    tok_idx = tok_idx.at[dest].set(jnp.where(keep, tok_of_slot, T))
    w_tab = jnp.zeros((num_experts * capacity + 1,), jnp.float32)
    w_tab = w_tab.at[dest].set(jnp.where(keep, w_of_slot, 0.0))
    return (tok_idx[:-1].reshape(num_experts, capacity),
            w_tab[:-1].reshape(num_experts, capacity))


def expert_ffn(params, xe):
    """xe [E, C, d] with per-expert stacked weights."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, params["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", xe, params["w_up"])
    return jnp.einsum("ecf,efd->ecd", h, params["w_down"])


# ----------------------------------------------------------------- local path
def moe_local(params, x, cfg):
    """x [B, S, d] -> (y, aux_loss). Single-shard reference path."""
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    x2 = x.reshape(T, d)
    weights, sel, aux = route(params["router"], x2, m.top_k)
    C = _capacity(T, m.top_k, m.num_experts, m.capacity_factor)
    tok_idx, w_tab = build_dispatch(sel, weights, m.num_experts, C)
    x_pad = jnp.concatenate([x2, jnp.zeros((1, d), x2.dtype)], axis=0)
    xe = x_pad[tok_idx]                                  # [E, C, d] gather
    ye = expert_ffn(params, xe)
    y = jnp.zeros((T + 1, d), x2.dtype)
    y = y.at[tok_idx.reshape(-1)].add(
        (ye * w_tab[..., None].astype(ye.dtype)).reshape(-1, d))
    y = y[:T].reshape(B, S, d)
    if m.num_shared_experts:
        y = y + mlp_apply(params["shared"], x, "swiglu")
    return y, aux


# ----------------------------------------------------------------- EP path
def moe_ep(params, x, cfg, mesh, *, data_axes=("data",), model_axis="model"):
    """Explicit expert-parallel MoE under shard_map.

    x: [B, S, d] sharded batch->data_axes, d replicated over model_axis.
    Expert weights sharded over model_axis on the expert dim.
    """
    m = cfg.moe
    B, S, d = x.shape
    M = mesh.shape[model_axis]
    DPS = tuple(data_axes)

    shared = params.get("shared")
    core = {k: params[k] for k in ("router", "w_gate", "w_up", "w_down")}

    expert_tp = _EXPERT_TP
    if expert_tp:
        # weights: experts over model, d_ff over data; tokens replicated
        in_specs = (
            {"router": P(), "w_gate": P(model_axis, None, "data"),
             "w_up": P(model_axis, None, "data"),
             "w_down": P(model_axis, "data", None)},
            P(None, None, None),
        )
    else:
        in_specs = (
            {"router": P(), "w_gate": P(model_axis), "w_up": P(model_axis),
             "w_down": P(model_axis)},
            P(DPS, None, None),
        )

    def body(pl, x_loc):
        b_loc, s, _ = x_loc.shape
        t = b_loc * s
        tm = -(-t // M)                                  # ceil
        x2 = x_loc.reshape(t, d)
        if tm * M > t:
            x2 = jnp.concatenate(
                [x2, jnp.zeros((tm * M - t, d), x2.dtype)], axis=0)
        m_idx = jax.lax.axis_index(model_axis)
        x_slice = jax.lax.dynamic_slice_in_dim(x2, m_idx * tm, tm)
        weights, sel, aux = route(pl["router"], x_slice, m.top_k)
        C = _capacity(tm, m.top_k, m.num_experts, m.capacity_factor)
        tok_idx, w_tab = build_dispatch(sel, weights, m.num_experts, C)
        x_pad = jnp.concatenate([x_slice, jnp.zeros((1, d), x2.dtype)], 0)
        xe = x_pad[tok_idx]                              # [E, C, d]
        # dispatch: expert dim scattered across the model axis
        xe = jax.lax.all_to_all(xe, model_axis, split_axis=0, concat_axis=1,
                                tiled=True)              # [E/M, C*M, d]
        ye = expert_ffn(pl, xe)
        if expert_tp:
            # d_ff was sharded over 'data': finish the contraction
            ye = jax.lax.psum(ye, "data")
        ye = jax.lax.all_to_all(ye, model_axis, split_axis=1, concat_axis=0,
                                tiled=True)              # [E, C, d]
        y = jnp.zeros((tm + 1, d), x2.dtype)
        y = y.at[tok_idx.reshape(-1)].add(
            (ye * w_tab[..., None].astype(ye.dtype)).reshape(-1, d))
        y = jax.lax.all_gather(y[:tm], model_axis, axis=0, tiled=True)
        aux = jax.lax.pmean(aux, model_axis)
        for ax in DPS:
            aux = jax.lax.pmean(aux, ax)
        return y[:t].reshape(b_loc, s, d), aux

    out_spec = P(None, None, None) if expert_tp else P(DPS, None, None)
    from repro.compat import shard_map
    y, aux = shard_map(
        body, mesh=mesh, in_specs=in_specs,
        out_specs=(out_spec, P()), check_vma=False)(core, x)
    if m.num_shared_experts:
        y = y + mlp_apply(params["shared"] if shared is None else shared,
                          x, "swiglu")
    return y, aux


def moe_apply(params, x, cfg, mesh=None, *, data_axes=("data",),
              model_axis="model"):
    if mesh is None:
        return moe_local(params, x, cfg)
    return moe_ep(params, x, cfg, mesh, data_axes=data_axes,
                  model_axis=model_axis)

from repro.models.model import (  # noqa: F401
    decode_step, forward, init_cache, init_params, loss_fn, prefill,
)

"""Shared int8 block quantizer: the KV wire codec and the gradient
all-reduce's quantization core (DESIGN.md §14).

One scheme, two call sites:

- ``distributed/compression.py`` quantizes gradient blocks on device
  (jax) for the int8 all-reduce — it imports ``_pad_blocks`` and
  ``block_scale`` from here so the two tiers can never drift.
- The paged KV offload path quantizes page payloads on host (numpy)
  before they enter the DRAM tier: ``KVWireCodec`` encodes a page's
  ``[2, L, page, Hkv, hd]`` host stack to ``(int8 payload, fp32 block
  scales)`` at offload time and decodes it as the reload chunk lands.

Scheme (per BLOCK-element block):

  scale = max(|x|, eps) / 127        q = clip(round(x / scale), ±127)

The epsilon guards the *max*, not the quotient: adding it after the
division (the old compression.py bug) inflated every scale so the
max-magnitude element no longer mapped to ±127 and the worst-case
round-trip error exceeded scale/2. With the guard on the max, the
error bound  |decode(encode(x)) - x| <= scale / 2  is tight, exact
zeros survive the round trip exactly (round(0) * scale == 0), and the
KV quality gate's tolerances (tests/test_quality_gate.py) hold.

Wire size: BLOCK int8 lanes + one fp32 scale per block, so an int8
page costs ``(1 + 4/BLOCK)`` bytes per element against ``itemsize``
for the native dtype — ``wire_scale`` ~ 0.254 for fp32 KV. The modeled
PCIe channel multiplies by this factor (``TransferChannel.wire_scale``)
so chunk sizing, reload stall accounting, and ``reload_overlap_frac``
all see the compressed size.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Union

import numpy as np

BLOCK = 256
EPS = 1e-12

KV_WIRE_FORMATS = ("fp32", "int8")


# ---------------------------------------------------------------- jax side
def _pad_blocks(flat):
    """Pad a flat jax array to a BLOCK multiple and reshape to
    [nb, BLOCK]. Returns (blocks, pad). Pad lanes are zeros: they can
    never raise a block's max, and decoders slice them off by the
    original size."""
    import jax.numpy as jnp
    pad = (-flat.size) % BLOCK
    return jnp.pad(flat, (0, pad)).reshape(-1, BLOCK), pad


def block_scale(maxabs, eps: float = EPS):
    """Per-block scale from per-block max magnitudes (jax). The epsilon
    guards the max (an all-zero block would otherwise divide by zero);
    it must NOT be added after the division — that inflates every
    scale and loosens the round-trip error bound."""
    import jax.numpy as jnp
    return jnp.maximum(maxabs, eps) / 127.0


# --------------------------------------------------------------- host side
@dataclass
class QuantizedPage:
    """One KV page's host copy in int8 wire format: ``q`` [nb, BLOCK]
    int8 payload, ``scales`` [nb] fp32 shared block scales, plus the
    original shape/dtype for decode. Opaque to the pool's host store —
    conservation, cancellation, and migration handoff treat it exactly
    like the fp32 ndarray it replaces."""
    q: np.ndarray
    scales: np.ndarray
    shape: tuple
    dtype: np.dtype

    @property
    def nbytes(self) -> int:
        return self.q.nbytes + self.scales.nbytes


def encode_page(host: np.ndarray, eps: float = EPS) -> QuantizedPage:
    """int8-encode a host array with BLOCK-granular fp32 scales."""
    flat = np.asarray(host, np.float32).reshape(-1)
    pad = (-flat.size) % BLOCK
    if pad:
        flat = np.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scales = np.maximum(np.abs(blocks).max(axis=1), eps) \
        .astype(np.float32) / 127.0
    q = np.clip(np.rint(blocks / scales[:, None]), -127, 127) \
        .astype(np.int8)
    return QuantizedPage(q=q, scales=scales, shape=tuple(host.shape),
                         dtype=np.dtype(host.dtype))


def decode_page(page: QuantizedPage) -> np.ndarray:
    """Inverse of ``encode_page`` (up to <= scale/2 per element)."""
    flat = page.q.astype(np.float32) * page.scales[:, None]
    n = int(np.prod(page.shape))
    return flat.reshape(-1)[:n].reshape(page.shape).astype(page.dtype)


def decode_host(obj: Union[np.ndarray, QuantizedPage]) -> np.ndarray:
    """Decode a host-store entry whatever its wire format: pass fp32
    ndarrays through untouched (bit-exact), dequantize QuantizedPage.
    The pool's synchronous reload fallback and the engine's chunk io
    both route through this, so a host store can even hold mixed
    formats (e.g. pages adopted from a migration)."""
    if isinstance(obj, QuantizedPage):
        return decode_page(obj)
    return obj


class KVWireCodec:
    """The offload path's wire-format choice, threaded from
    ``PagedRealtimeEngine(kv_quant=...)`` down to the pool and the
    modeled channel. ``fp32`` is the identity codec (the bit-exact
    differential control — 'fp32' meaning the KV store's native dtype,
    untouched); ``int8`` block-quantizes every host copy."""

    def __init__(self, fmt: str = "fp32"):
        if fmt not in KV_WIRE_FORMATS:
            raise ValueError(
                f"kv_quant must be one of {KV_WIRE_FORMATS}, got {fmt!r}")
        self.fmt = fmt

    def encode(self, host: np.ndarray):
        if self.fmt == "fp32":
            return host
        return encode_page(host)

    def decode(self, obj) -> np.ndarray:
        return decode_host(obj)

    def wire_scale(self, dtype) -> float:
        """Wire bytes per logical byte: the factor the modeled PCIe
        channel multiplies into ``transfer_time`` so every consumer
        (chunk sizing, preload admission, stall settlement, fleet
        migration) prices the compressed payload. Includes the fp32
        scale overhead (4 bytes per BLOCK elements)."""
        if self.fmt == "fp32":
            return 1.0
        return (1.0 + 4.0 / BLOCK) / np.dtype(dtype).itemsize

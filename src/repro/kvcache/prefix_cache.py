"""Radix prefix cache over refcounted pool pages (DESIGN.md §13).

A page-granular trie keyed on token ids. Each node is one physical
page of committed, frozen KV: full interior nodes carry exactly
``page_size`` tokens; at most one *partial* child per node carries a
shorter committed tail. A new session looks up its prompt, attaches to
the longest indexed prefix (``PagedPool.attach_prefix``), and starts
prefill at the first uncached token — the fused kernel's per-row
``q_start`` already renders rows from any offset, so a partial-page hit
is safe: positions past the matched length are masked by ``seq_lens``
and simply overwritten when the attacher appends (after COW if the
page is still shared).

The cache holds *non-refcount* references: registering a page marks it
``cache_held`` in the pool but does not bump its refcount, so the
conservation invariant stays exactly "sum(refcounts) == live
block-table references". A page whose last sequence reference dies
survives at refcount 0 while indexed; ``reclaim`` frees such orphans
leaves-first under memory pressure, farthest banked next-use first
(min-over-sharers Eq.4: while any sharer lives the page is not
reclaimable at all, so the banked value only matters once every sharer
detached — the last detacher's estimate, with protection extended to
the max over sharers' TTLs).

Chains may mix pages registered by different sessions: KV for the same
token prefix is bit-identical regardless of which session computed it
(PR 5's chunk-schedule invariance), so a lookup that walks session A's
full pages into session B's deeper nodes attaches bit-exact state.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple


class _Node:
    __slots__ = ("tokens", "phys", "children", "partial", "parent",
                 "banked_next_use", "banked_protect")

    def __init__(self, tokens: Tuple[int, ...], phys: Optional[int],
                 parent: Optional["_Node"]):
        self.tokens = tokens
        self.phys = phys
        self.children: Dict[Tuple[int, ...], "_Node"] = {}
        self.partial: Optional["_Node"] = None
        self.parent = parent
        self.banked_next_use = 0.0
        self.banked_protect = -1.0


def _lcp(a: Sequence[int], b: Sequence[int]) -> int:
    n = min(len(a), len(b))
    i = 0
    while i < n and int(a[i]) == int(b[i]):
        i += 1
    return i


class PrefixCache:
    def __init__(self, page_size: int):
        self.page_size = page_size
        self.root = _Node((), None, None)
        self.by_phys: Dict[int, _Node] = {}
        # telemetry
        self.lookups = 0
        self.hits = 0
        self.hit_tokens = 0

    def __len__(self) -> int:
        return len(self.by_phys)

    @staticmethod
    def _kids(node: _Node) -> List[_Node]:
        out = list(node.children.values())
        if node.partial is not None:
            out.append(node.partial)
        return out

    # ---------------------------------------------------------- lookup
    def lookup(self, tokens: Sequence[int]) -> Tuple[int, List[int]]:
        """Longest indexed prefix of ``tokens``: greedy exact full-page
        walk, then the best partial match (longest common prefix over
        the stopping level's children, full or partial). Returns
        (matched token count, physical pages covering them)."""
        self.lookups += 1
        ps = self.page_size
        node = self.root
        matched = 0
        phys: List[int] = []
        i = 0
        n = len(tokens)
        while True:
            if n - i >= ps:
                child = node.children.get(
                    tuple(int(t) for t in tokens[i:i + ps]))
                if child is not None:
                    phys.append(child.phys)
                    matched += ps
                    i += ps
                    node = child
                    continue
            best_j, best_p = 0, None
            for c in self._kids(node):
                j = _lcp(tokens[i:], c.tokens)
                if j > best_j:
                    best_j, best_p = j, c.phys
            if best_j > 0:
                phys.append(best_p)
                matched += best_j
            return matched, phys

    # -------------------------------------------------------- register
    def register(self, tokens: Sequence[int], pages: Sequence[int],
                 *, est: float = 0.0, protect: float = -1.0) -> List[int]:
        """Index a committed chain: ``tokens`` is the full token-id
        history, ``pages`` the sequence's physical pages (prefix-first;
        non-resident entries stop the walk). When a full-page tuple is
        already indexed under a *different* physical page, the existing
        node wins and the walk recurses into its children — our page
        stays private and offloadable. A partial tail registered under
        the same physical page extends monotonically and promotes to a
        full node when the page fills. Returns the newly indexed
        physical pages (the caller marks them ``cache_held``)."""
        ps = self.page_size
        node = self.root
        newly: List[int] = []
        n_full = len(tokens) // ps
        for k in range(n_full):
            if k >= len(pages) or pages[k] < 0:
                return newly
            phys = pages[k]
            tup = tuple(int(t) for t in tokens[k * ps:(k + 1) * ps])
            child = node.children.get(tup)
            if child is None:
                if node.partial is not None and node.partial.phys == phys:
                    # the partially-committed page filled up: promote
                    self._drop_node(node.partial)
                if phys in self.by_phys:
                    return newly        # indexed elsewhere: stop
                child = _Node(tup, phys, node)
                child.banked_next_use = est
                child.banked_protect = protect
                node.children[tup] = child
                self.by_phys[phys] = child
                newly.append(phys)
            node = child
        rem = len(tokens) - n_full * ps
        if rem > 0 and n_full < len(pages) and pages[n_full] >= 0:
            phys = pages[n_full]
            tup = tuple(int(t) for t in tokens[n_full * ps:])
            p = node.partial
            if p is None:
                if phys not in self.by_phys:
                    p = _Node(tup, phys, node)
                    node.partial = p
                    self.by_phys[phys] = p
                    newly.append(phys)
            elif p.phys == phys and len(tup) > len(p.tokens):
                p.tokens = tup          # same page grew: extend
            # a different phys loses: first registration wins the slot
        return newly

    # ---------------------------------------------------------- forget
    def _drop_node(self, node: _Node) -> List[int]:
        """Unlink a node AND its subtree (descendants become
        unreachable) from the index. Returns every physical page
        dropped."""
        out: List[int] = []
        stack = [node]
        while stack:
            n = stack.pop()
            out.append(n.phys)
            del self.by_phys[n.phys]
            stack.extend(n.children.values())
            if n.partial is not None:
                stack.append(n.partial)
        par = node.parent
        if par.partial is node:
            par.partial = None
        else:
            del par.children[node.tokens]
        return out

    def forget_phys(self, phys: Sequence[int]) -> List[int]:
        """The pool is about to offload (or migrate away) these pages:
        remove their nodes and entire subtrees from the index. Returns
        all dropped physical pages — the caller releases the zero-ref
        ones (``PagedPool.cache_release``)."""
        dropped: List[int] = []
        for p in phys:
            n = self.by_phys.get(p)
            if n is not None:
                dropped.extend(self._drop_node(n))
        return dropped

    # -------------------------------------------------------- eviction
    def on_detach(self, phys: Sequence[int], *, est: float,
                  protect: float) -> None:
        """A sharer released/migrated: bank its Eq.4 next-use estimate
        (last detacher wins — with every sharer gone it is the freshest
        min-over-sharers) and extend protection to the max over
        sharers' TTLs."""
        for p in phys:
            n = self.by_phys.get(p)
            if n is not None:
                n.banked_next_use = est
                n.banked_protect = max(n.banked_protect, protect)

    def reclaim(self, n: int, now: float,
                refcount: Dict[int, int]) -> List[int]:
        """Free up to ``n`` orphan pages (refcount 0, protection
        lapsed), leaves-first so chains stay contiguous, farthest
        banked next-use first. Returns the physical pages to free."""
        freed: List[int] = []
        while len(freed) < n:
            best = None
            for node in self.by_phys.values():
                if node.children or node.partial is not None:
                    continue
                if refcount.get(node.phys, 0) != 0:
                    continue
                if now < node.banked_protect:
                    continue
                if best is None \
                        or node.banked_next_use > best.banked_next_use:
                    best = node
            if best is None:
                break
            self._drop_node(best)       # a leaf drops exactly itself
            freed.append(best.phys)
        return freed

    def reclaimable(self, now: float, refcount: Dict[int, int]) -> int:
        """How many pages ``reclaim`` could free right now: nodes whose
        ENTIRE subtree is orphaned and unprotected (leaves-first
        cascade reaches a node only after its descendants drop)."""

        def walk(node: _Node):
            free = refcount.get(node.phys, 0) == 0 \
                and now >= node.banked_protect
            size, drop = 1, 0
            for k in self._kids(node):
                kf, ksz, kd = walk(k)
                free = free and kf
                size += ksz
                drop += kd
            return (True, size, size) if free else (False, size, drop)

        return sum(walk(k)[2] for k in self._kids(self.root))

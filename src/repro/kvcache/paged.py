"""Host-side paged KV block pool — the allocator under the Pallas
``paged_attention`` kernel and the LiveServe KV manager.

The pool owns fixed-size pages of device KV storage
([num_pages, page_size, Hkv, hd] per layer); sequences own ordered page
lists (prefix-first, matching §5.1's suffix-first eviction). Block tables
([B, pages_per_seq] int32) are built per decode batch and handed to the
kernel via scalar prefetch. A DRAM tier holds offloaded page *contents*
(host numpy) so evict/reload round-trips are bit-exact.

This is hardware-agnostic bookkeeping: the LiveServe policies decide
*which* sessions' pages move; this module moves them.

It is also *layout*-agnostic (DESIGN.md §9): when the device page store
is tensor-sharded over a mesh's 'model' axis, physical page ids and the
block tables built from them are unchanged — the sharded dims (KV heads
or page slots) are never indexed here. ``offload_suffix``'s
``kv_pages[phys]`` read gathers the full logical page across shards
(``np.asarray`` on a sharded jax array), and ``reload``'s batched
scatter writes it back through the same functional update, so the DRAM
tier always stores whole logical pages and an engine can evict on one
mesh and (after a checkpoint-style move) reload on another.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np


class OutOfPages(RuntimeError):
    pass


@dataclass
class SeqPages:
    seq_id: str
    pages: List[int] = field(default_factory=list)   # prefix-first order
    length: int = 0                                   # tokens written
    offloaded: Dict[int, np.ndarray] = field(default_factory=dict)
    # offloaded: logical page index (position in `pages`) -> host copy;
    # an offloaded slot keeps -1 in `pages`.


class PagedPool:
    def __init__(self, num_pages: int, page_size: int):
        self.num_pages = num_pages
        self.page_size = page_size
        self.free: List[int] = list(range(num_pages - 1, -1, -1))
        self.seqs: Dict[str, SeqPages] = {}

    # ------------------------------------------------------------ alloc
    @property
    def free_pages(self) -> int:
        return len(self.free)

    def seq(self, seq_id: str) -> SeqPages:
        s = self.seqs.get(seq_id)
        if s is None:
            s = SeqPages(seq_id)
            self.seqs[seq_id] = s
        return s

    def pages_for(self, tokens: int) -> int:
        return -(-tokens // self.page_size)

    def ensure_capacity(self, seq_id: str, new_length: int) -> List[int]:
        """Grow a sequence to hold new_length tokens; returns newly
        allocated physical pages."""
        s = self.seq(seq_id)
        need = self.pages_for(new_length) - len(s.pages)
        out = []
        for _ in range(max(0, need)):
            if not self.free:
                raise OutOfPages(f"pool exhausted growing {seq_id}")
            p = self.free.pop()
            s.pages.append(p)
            out.append(p)
        s.length = max(s.length, new_length)
        return out

    def trim(self, seq_id: str, length: int) -> int:
        """Shrink a sequence's page list to what `length` tokens need,
        freeing trailing pages (in-flight lookahead pages on barge-in,
        §5.2 — committed pages are untouched). Returns pages freed."""
        s = self.seq(seq_id)
        keep = self.pages_for(length)
        freed = 0
        while len(s.pages) > keep:
            phys = s.pages.pop()
            s.offloaded.pop(len(s.pages), None)
            if phys >= 0:
                self.free.append(phys)
                freed += 1
        s.length = min(s.length, length)
        return freed

    def release(self, seq_id: str) -> None:
        s = self.seqs.pop(seq_id, None)
        if s is None:
            return
        for p in s.pages:
            if p >= 0:
                self.free.append(p)

    # ------------------------------------------------------------ tables
    def block_table(self, seq_ids: List[str], pages_per_seq: int,
                    *, pad_page: int = 0) -> np.ndarray:
        """[B, pages_per_seq] int32 for the paged_attention kernel.
        Raises if any sequence has offloaded pages (must reload first —
        the correctness contract of §5.2's sync-fallback path)."""
        bt = np.full((len(seq_ids), pages_per_seq), pad_page, np.int32)
        for i, sid in enumerate(seq_ids):
            s = self.seq(sid)
            if s.offloaded:
                raise RuntimeError(f"{sid} has offloaded pages")
            n = min(len(s.pages), pages_per_seq)
            bt[i, :n] = s.pages[:n]
        return bt

    def seq_lens(self, seq_ids: List[str]) -> np.ndarray:
        return np.array([self.seq(s).length for s in seq_ids], np.int32)

    # ------------------------------------------------------------ tiers
    def offload_suffix(self, seq_id: str, n_pages: int, kv_pages) -> int:
        """Move the LAST n_pages of a sequence to host (suffix-first,
        §5.1). kv_pages: device array [num_pages, page, Hkv, hd] (or a
        pytree leaf); contents copied to host. Returns pages freed."""
        s = self.seq(seq_id)
        resident = [i for i, p in enumerate(s.pages) if p >= 0]
        take = resident[-n_pages:] if n_pages else []
        for li in reversed(take):
            phys = s.pages[li]
            s.offloaded[li] = np.asarray(kv_pages[phys])
            s.pages[li] = -1
            self.free.append(phys)
        return len(take)

    def reload(self, seq_id: str, kv_pages):
        """Bring offloaded pages back. Returns (updated kv_pages, loaded
        page count). kv_pages is a jax array (or adapter); the update is
        functional and batched — one scatter for all pages, not one full
        array copy per page (this sits on the sync-fallback critical
        path). All-or-nothing: raises before moving anything if the pool
        cannot hold every offloaded page."""
        s = self.seq(seq_id)
        logical = sorted(s.offloaded)
        if not logical:
            return kv_pages, 0
        if len(self.free) < len(logical):
            raise OutOfPages(f"pool exhausted reloading {seq_id}")
        phys = [self.free.pop() for _ in logical]
        kv_pages = kv_pages.at[np.asarray(phys)].set(
            np.stack([s.offloaded[li] for li in logical]))
        for li, p in zip(logical, phys):
            s.pages[li] = p
        s.offloaded.clear()
        return kv_pages, len(logical)

    def resident_pages(self, seq_id: str) -> int:
        return sum(1 for p in self.seq(seq_id).pages if p >= 0)

    def stats(self) -> dict:
        return {
            "free": self.free_pages,
            "used": self.num_pages - self.free_pages,
            "seqs": len(self.seqs),
            "offloaded_pages": sum(len(s.offloaded)
                                   for s in self.seqs.values()),
        }

"""Host-side paged KV block pool — the allocator under the Pallas
``paged_attention`` kernel and the LiveServe KV manager.

The pool owns fixed-size pages of device KV storage
([num_pages, page_size, Hkv, hd] per layer); sequences own ordered page
lists (prefix-first, matching §5.1's suffix-first eviction). Block tables
([B, pages_per_seq] int32) are built per decode batch and handed to the
kernel via scalar prefetch. A DRAM tier holds offloaded page *contents*
(host numpy) so evict/reload round-trips are bit-exact.

This is hardware-agnostic bookkeeping: the LiveServe policies decide
*which* sessions' pages move; this module moves them.

It is also *layout*-agnostic (DESIGN.md §9): when the device page store
is tensor-sharded over a mesh's 'model' axis, physical page ids and the
block tables built from them are unchanged — the sharded dims (KV heads
or page slots) are never indexed here. ``offload_suffix``'s
``kv_pages[phys]`` read gathers the full logical page across shards
(``np.asarray`` on a sharded jax array), and ``reload``'s batched
scatter writes it back through the same functional update, so the DRAM
tier always stores whole logical pages and an engine can evict on one
mesh and (after a checkpoint-style move) reload on another.

Shared-prefix pages (DESIGN.md §13): every allocated physical page
carries a refcount — the number of sequences whose page list references
it. ``attach_prefix`` points a fresh sequence at another sequence's
committed pages (refcount goes up, no bytes move); ``cow`` swaps a
shared page for a private copy when a writer must append into it. Each
page is *charged* to exactly one accountant: its owner session
(``page_owner[p] == sid``) or the prefix cache (``page_owner[p] is
None`` — a COW'd-away or orphaned page kept alive by sharers or by the
radix index, ``cache_held``). The transfer tiers only ever move private
pages: ``mark_offloading`` asserts refcount == 1 and not cache-held, so
a page some sharer still needs hot can never leave HBM.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np


class OutOfPages(RuntimeError):
    pass


@dataclass
class SeqPages:
    seq_id: str
    pages: List[int] = field(default_factory=list)   # prefix-first order
    length: int = 0                                   # tokens written
    offloaded: Dict[int, object] = field(default_factory=dict)
    # offloaded: logical page index (position in `pages`) -> host copy
    # (a raw ndarray, or a quant.QuantizedPage on the int8 wire format
    # — opaque here); an offloaded slot keeps -1 in `pages`.
    #
    # In-flight transfer marks (the async chunked transfer engine,
    # DESIGN.md §10). Each logical page is in exactly one state:
    #   resident    pages[li] >= 0, li not in loading/offloading
    #   offloading  pages[li] >= 0, li in offloading — device contents
    #               still valid/usable; host copy not yet durable
    #               (copy-then-free: the slot frees when the chunk
    #               drains)
    #   loading     pages[li] >= 0 (slot reserved), li in loading AND
    #               li in offloaded — host copy is the source of truth,
    #               device contents not yet arrived
    #   offloaded   pages[li] == -1, li in offloaded only
    loading: set = field(default_factory=set)
    offloading: set = field(default_factory=set)


class PagedPool:
    def __init__(self, num_pages: int, page_size: int, codec=None):
        self.num_pages = num_pages
        self.page_size = page_size
        # KV wire codec (DESIGN.md §14): when set, the synchronous
        # offload wrapper encodes host copies (int8 payload + fp32
        # block scales) and every reload path decodes them. Host-store
        # entries are otherwise opaque — the page-state machine,
        # conservation checks, and migration handoff never look inside.
        self.codec = codec
        self.free: List[int] = list(range(num_pages - 1, -1, -1))
        self.seqs: Dict[str, SeqPages] = {}
        # Shared-prefix bookkeeping (DESIGN.md §13). Every *allocated*
        # physical page has a refcount entry (== number of sequence page
        # lists referencing it; 0 only for pages kept alive purely by
        # the radix index) and a charging owner: the session whose KV
        # accountant pays for it, or None once the owner released/COW'd
        # it away (the prefix cache pays — `cached_blocks` in
        # KVManager). `cache_held` marks pages registered in the radix
        # index: they survive refcount 0 until the cache forgets them.
        self.refcount: Dict[int, int] = {}
        self.page_owner: Dict[int, Optional[str]] = {}
        self.cache_held: set = set()

    # ------------------------------------------------------------ alloc
    @property
    def free_pages(self) -> int:
        return len(self.free)

    def _alloc_page(self, seq_id: str) -> int:
        p = self.free.pop()
        self.refcount[p] = 1
        self.page_owner[p] = seq_id
        return p

    def _free_slot(self, p: int) -> None:
        del self.refcount[p]
        del self.page_owner[p]
        self.free.append(p)

    def seq(self, seq_id: str) -> SeqPages:
        s = self.seqs.get(seq_id)
        if s is None:
            s = SeqPages(seq_id)
            self.seqs[seq_id] = s
        return s

    def pages_for(self, tokens: int) -> int:
        return -(-tokens // self.page_size)

    def ensure_capacity(self, seq_id: str, new_length: int) -> List[int]:
        """Grow a sequence to hold new_length tokens; returns newly
        allocated physical pages."""
        s = self.seq(seq_id)
        need = self.pages_for(new_length) - len(s.pages)
        out = []
        for _ in range(max(0, need)):
            if not self.free:
                raise OutOfPages(f"pool exhausted growing {seq_id}")
            p = self._alloc_page(seq_id)
            s.pages.append(p)
            out.append(p)
        s.length = max(s.length, new_length)
        return out

    def trim(self, seq_id: str, length: int) -> int:
        """Shrink a sequence's page list to what `length` tokens need,
        freeing trailing pages (in-flight lookahead pages on barge-in,
        §5.2 — committed pages are untouched). Returns pages freed."""
        s = self.seq(seq_id)
        keep = self.pages_for(length)
        freed = 0
        while len(s.pages) > keep:
            li = len(s.pages) - 1
            assert li not in s.loading and li not in s.offloading, \
                f"{seq_id}: trim would drop page {li} mid-transfer " \
                "(transfers run only for idle sessions; trim only on " \
                "the live turn's lookahead)"
            phys = s.pages.pop()
            s.offloaded.pop(len(s.pages), None)
            if phys >= 0:
                assert self.refcount[phys] == 1 \
                    and phys not in self.cache_held \
                    and self.page_owner[phys] == seq_id, \
                    f"{seq_id}: trim reached a shared/cached page " \
                    f"{phys} — only private lookahead pages trim"
                self._free_slot(phys)
                freed += 1
        s.length = min(s.length, length)
        return freed

    def rollback(self, seq_id: str, length: int) -> None:
        """Logical rollback of rejected speculative writes (DESIGN.md
        §16): clamp the sequence's token length back to ``length``
        without touching pages. Draft KV landed beyond ``length`` is
        garbage the attention mask never reads (seq_lens derive from
        the committed ``kv_len``), the next round's writes overwrite
        the same slots, and ``trim`` at turn close reclaims any whole
        trailing pages the final length doesn't need — so rollback is
        O(1) and conservation holds by the same page-state partition
        the invariant checker already enforces."""
        s = self.seq(seq_id)
        s.length = min(s.length, length)

    def release(self, seq_id: str) -> Dict[str, int]:
        """Drop a sequence's references. Returns an accounting report:
        ``freed_own`` private pages returned to the free list,
        ``freed_orphan`` cache-charged (owner-less) pages whose last
        reference died here, ``orphaned`` own pages that survive via
        other sharers or the radix index — their charge moves to the
        prefix cache (owner -> None)."""
        s = self.seqs.pop(seq_id, None)
        rep = {"freed_own": 0, "freed_orphan": 0, "orphaned": 0}
        if s is None:
            return rep
        for p in s.pages:
            if p < 0:
                continue
            owner = self.page_owner[p]
            self.refcount[p] -= 1
            if self.refcount[p] == 0 and p not in self.cache_held:
                self._free_slot(p)
                if owner is None:
                    rep["freed_orphan"] += 1
                else:
                    rep["freed_own"] += 1
            elif owner == seq_id:
                self.page_owner[p] = None
                rep["orphaned"] += 1
        return rep

    def adopt(self, seq_id: str, n_pages: int, length: int,
              offloaded: Dict[int, object]) -> SeqPages:
        """Install a sequence arriving from another pool (cross-replica
        migration handoff). Every page lands host-resident — the source
        drained its chunked offloads before the handoff — so adoption
        allocates nothing here; the destination's reload machinery pages
        the KV back in on its own clock."""
        assert seq_id not in self.seqs, f"{seq_id} already placed"
        assert set(offloaded) == set(range(n_pages)), \
            f"{seq_id}: handoff requires a full host copy " \
            f"({sorted(offloaded)} vs {n_pages} pages)"
        s = SeqPages(seq_id, pages=[-1] * n_pages, length=length,
                     offloaded=dict(offloaded))
        self.seqs[seq_id] = s
        return s

    # ------------------------------------------------- shared prefixes
    def attach_prefix(self, seq_id: str, phys: List[int],
                      length: int) -> None:
        """Point a FRESH sequence at already-resident pages holding its
        first ``length`` tokens (prefix-cache hit): each page's refcount
        goes up, no bytes move, and the pages stay charged to whoever
        pays for them today — the attacher's accountant records them as
        ``shared_blocks``."""
        s = self.seq(seq_id)
        assert not s.pages and s.length == 0 and not s.offloaded, \
            f"{seq_id}: attach_prefix only on an empty sequence"
        for p in phys:
            assert p in self.refcount, f"page {p} not allocated"
            self.refcount[p] += 1
        s.pages.extend(phys)
        s.length = length

    def cow(self, seq_id: str, li: int):
        """Copy-on-write: the writer must append into logical page
        ``li`` but shares its physical page. Allocate a private page,
        repoint, drop the shared ref. Returns (old_phys, new_phys,
        was_owner); the caller copies the device bytes old -> new and,
        when ``was_owner``, re-charges the old page to the prefix cache
        (its owner slot becomes None)."""
        s = self.seqs[seq_id]
        old = s.pages[li]
        assert old >= 0 and li not in s.loading and li not in s.offloading
        assert self.refcount[old] > 1, \
            f"{seq_id}: page {old} not shared — write in place"
        if not self.free:
            raise OutOfPages(f"pool exhausted COWing {seq_id}")
        new = self._alloc_page(seq_id)
        s.pages[li] = new
        self.refcount[old] -= 1
        was_owner = self.page_owner[old] == seq_id
        if was_owner:
            self.page_owner[old] = None
        return old, new, was_owner

    def detach_page(self, seq_id: str, li: int):
        """Drop one page reference without the offload machinery
        (migration deep-copy: the departing session keeps a host copy
        in ``offloaded`` and leaves the physical page to its sharers /
        the cache). Returns (was_owner, freed) — freed only when the
        last reference was this one and the radix index does not hold
        the page either."""
        s = self.seqs[seq_id]
        p = s.pages[li]
        assert p >= 0 and li not in s.loading and li not in s.offloading
        was_owner = self.page_owner[p] == seq_id
        self.refcount[p] -= 1
        freed = False
        if self.refcount[p] == 0 and p not in self.cache_held:
            self._free_slot(p)
            freed = True
        elif was_owner:
            self.page_owner[p] = None
        s.pages[li] = -1
        return was_owner, freed

    def cache_release(self, phys: List[int]) -> int:
        """The radix index forgot these pages: any that no sequence
        still references free now. Returns pages freed (all of them had
        owner None — the cache was paying)."""
        freed = 0
        for p in phys:
            self.cache_held.discard(p)
            if self.refcount.get(p) == 0:
                assert self.page_owner[p] is None
                self._free_slot(p)
                freed += 1
        return freed

    def shared_charged_pages(self, seq_id: str) -> int:
        """Own pages other sequences currently share (refcount > 1 and
        charged to this sequence) — pinned in HBM while any sharer
        needs them, so excluded from this session's evictable count."""
        s = self.seqs.get(seq_id)
        if s is None:
            return 0
        return sum(1 for p in s.pages
                   if p >= 0 and self.refcount[p] > 1
                   and self.page_owner[p] == seq_id)

    def shared_pages(self) -> int:
        """Physical pages with more than one live reference."""
        return sum(1 for c in self.refcount.values() if c > 1)

    # ------------------------------------------------------------ tables
    def block_table(self, seq_ids: List[str], pages_per_seq: int,
                    *, pad_page: int = 0) -> np.ndarray:
        """[B, pages_per_seq] int32 for the paged_attention kernel.
        Raises if any sequence has offloaded pages (must reload first —
        the correctness contract of §5.2's sync-fallback path)."""
        bt = np.full((len(seq_ids), pages_per_seq), pad_page, np.int32)
        for i, sid in enumerate(seq_ids):
            s = self.seq(sid)
            if s.offloaded:
                raise RuntimeError(f"{sid} has offloaded pages")
            n = min(len(s.pages), pages_per_seq)
            bt[i, :n] = s.pages[:n]
        return bt

    def seq_lens(self, seq_ids: List[str]) -> np.ndarray:
        return np.array([self.seq(s).length for s in seq_ids], np.int32)

    # ------------------------------------------------------------ tiers
    #
    # Chunk-grained primitives for the async transfer engine
    # (core/transfer_engine.py): begin_* flips accounting state and
    # reserves/marks slots; complete_* moves the bytes for one chunk;
    # cancel_* reverts marks without moving anything. The legacy
    # whole-session `offload_suffix`/`reload` below are begin+complete
    # in one call (the synchronous path, still used by pool tests and
    # the non-async engine mode).

    def begin_reload(self, seq_id: str) -> List[int]:
        """Reserve a physical slot for every offloaded page and mark it
        ``loading``. All-or-nothing: raises before mutating if the pool
        cannot hold them all. Returns the logical indices needing a
        host->device transfer, prefix-first. (Pages whose offload is
        still in flight are NOT included — cancel those with
        ``cancel_offloading`` first: their bytes never left HBM.)"""
        s = self.seq(seq_id)
        logical = sorted(li for li in s.offloaded if li not in s.loading)
        if len(self.free) < len(logical):
            raise OutOfPages(f"pool exhausted reloading {seq_id}")
        for li in logical:
            s.pages[li] = self._alloc_page(seq_id)
            s.loading.add(li)
        return logical

    def complete_reload(self, seq_id: str, logical: List[int], kv_pages,
                        staged=None):
        """Land one reload chunk: scatter the host copies into their
        reserved slots (one batched functional update), clear the
        ``loading`` marks, drop the host copies. ``staged`` overrides
        the source with an already-device-resident [n, 2, L, ...] stack
        (the engine stages it to time only the transferred bytes).
        Returns the updated kv_pages."""
        s = self.seq(seq_id)
        if not logical:
            return kv_pages
        phys = [s.pages[li] for li in logical]
        if staged is not None:
            src = staged
        else:
            from repro.kvcache.quant import decode_host
            src = np.stack([decode_host(s.offloaded[li])
                            for li in logical])
        kv_pages = kv_pages.at[np.asarray(phys)].set(src)
        for li in logical:
            assert li in s.loading, f"{seq_id}: page {li} not loading"
            s.loading.remove(li)
            del s.offloaded[li]
        return kv_pages

    def cancel_loading(self, seq_id: str,
                       logical: Optional[List[int]] = None) -> int:
        """Un-reserve loading pages (eviction of a loading session,
        burst cancel, hangup): the slot returns to the free list, the
        host copy stays authoritative in ``offloaded``. Zero-copy —
        the contents never arrived. Returns pages cancelled."""
        s = self.seq(seq_id)
        take = sorted(s.loading) if logical is None else list(logical)
        for li in take:
            assert li in s.loading, f"{seq_id}: page {li} not loading"
            self._free_slot(s.pages[li])
            s.pages[li] = -1
            s.loading.remove(li)
        return len(take)

    def evictable_suffix(self, seq_id: str, n_pages: int):
        """Pick the LAST ``n_pages`` the eviction policy can free
        (suffix-first, §5.1), split by how they free: ``cancel_lis``
        are loading pages (cancel the in-flight reload — free
        immediately, zero copy) and ``offload_lis`` are resident pages
        (need a device->host copy). Pages already offloading are
        skipped — their blocks were accounted by an earlier pass — and
        so is any page this sequence does not privately own: a page
        with refcount > 1 (a sharer still needs it hot) or charged to
        another accountant (an attached prefix — the owner session or
        the prefix cache pays for it, and this session has no host copy
        to write). The caller's evictable budget already excludes both
        (``hbm - shared_pinned`` counts exactly the private own
        pages)."""
        s = self.seq(seq_id)
        cancel_lis, offload_lis = [], []
        for li in range(len(s.pages) - 1, -1, -1):
            if len(cancel_lis) + len(offload_lis) >= n_pages:
                break
            if s.pages[li] < 0 or li in s.offloading:
                continue
            if self.refcount[s.pages[li]] > 1 \
                    or self.page_owner[s.pages[li]] != seq_id:
                continue
            if li in s.loading:
                cancel_lis.append(li)
            else:
                offload_lis.append(li)
        return cancel_lis, offload_lis

    def mark_offloading(self, seq_id: str, logical: List[int]) -> None:
        """Copy-then-free step 1: the pages stay resident and usable;
        the slot frees only when ``complete_offload`` lands the copy."""
        s = self.seq(seq_id)
        for li in logical:
            assert s.pages[li] >= 0 and li not in s.loading \
                and li not in s.offloading, \
                f"{seq_id}: page {li} not plain-resident"
            assert self.refcount[s.pages[li]] == 1 \
                and s.pages[li] not in self.cache_held, \
                f"{seq_id}: page {s.pages[li]} is shared/cached — " \
                "never offload a page a sharer still needs hot " \
                "(forget it in the radix index first)"
            s.offloading.add(li)

    def complete_offload(self, seq_id: str,
                         copies: Dict[int, np.ndarray]) -> int:
        """Copy-then-free step 2: the host copies are durable — record
        them and free the physical slots. Returns pages freed."""
        s = self.seq(seq_id)
        for li, host in copies.items():
            assert li in s.offloading, f"{seq_id}: page {li} not offloading"
            s.offloaded[li] = host
            self._free_slot(s.pages[li])
            s.pages[li] = -1
            s.offloading.remove(li)
        return len(copies)

    def cancel_offloading(self, seq_id: str,
                          logical: Optional[List[int]] = None) -> List[int]:
        """A reload/turn arrived before the copy drained: keep the pages
        resident (their device contents never left). Returns the logical
        indices whose offload was cancelled."""
        s = self.seq(seq_id)
        take = sorted(s.offloading) if logical is None else list(logical)
        for li in take:
            assert li in s.offloading, f"{seq_id}: page {li} not offloading"
            s.offloading.remove(li)
        return take

    # --------------------------------------------- synchronous wrappers
    def offload_suffix(self, seq_id: str, n_pages: int, kv_pages) -> int:
        """Move the LAST n_pages of a sequence to host (suffix-first,
        §5.1), synchronously: begin + complete in one call. kv_pages:
        device array [num_pages, page, Hkv, hd] (or a pytree leaf).
        Loading pages in the suffix are cancelled instead of copied
        (their contents only exist on the host). Returns pages freed."""
        cancel_lis, offload_lis = self.evictable_suffix(seq_id, n_pages)
        self.cancel_loading(seq_id, cancel_lis)
        self.mark_offloading(seq_id, offload_lis)
        s = self.seq(seq_id)
        enc = self.codec.encode if self.codec is not None \
            else (lambda a: a)
        self.complete_offload(
            seq_id, {li: enc(np.asarray(kv_pages[s.pages[li]]))
                     for li in offload_lis})
        return len(cancel_lis) + len(offload_lis)

    def reload(self, seq_id: str, kv_pages):
        """Bring offloaded pages back, synchronously. Returns (updated
        kv_pages, restored page count — transfers plus cancelled
        in-flight offloads). The scatter is functional and batched (one
        update for all pages); all-or-nothing on free space."""
        cancelled = self.cancel_offloading(seq_id)
        logical = self.begin_reload(seq_id)
        kv_pages = self.complete_reload(seq_id, logical, kv_pages)
        return kv_pages, len(logical) + len(cancelled)

    def resident_pages(self, seq_id: str) -> int:
        """Usable-resident pages: excludes loading reservations (their
        contents are still in flight), includes offloading pages (still
        valid on device until the copy drains). Read-only: an unknown
        or released sequence reports 0 without creating a ghost entry
        (callers probe sessions the pool may have dropped)."""
        s = self.seqs.get(seq_id)
        if s is None:
            return 0
        return sum(1 for li, p in enumerate(s.pages)
                   if p >= 0 and li not in s.loading)

    def inflight_pages(self, seq_id: str):
        """(loading, offloading) page counts for one sequence."""
        s = self.seq(seq_id)
        return len(s.loading), len(s.offloading)

    def stats(self) -> dict:
        return {
            "free": self.free_pages,
            "used": self.num_pages - self.free_pages,
            "seqs": len(self.seqs),
            "offloaded_pages": sum(len(s.offloaded)
                                   for s in self.seqs.values()),
            "loading_pages": sum(len(s.loading)
                                 for s in self.seqs.values()),
            "offloading_pages": sum(len(s.offloading)
                                    for s in self.seqs.values()),
            "shared_pages": self.shared_pages(),
            "cached_pages": len(self.cache_held),
        }

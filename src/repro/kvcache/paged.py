"""Host-side paged KV block pool — the allocator under the Pallas
``paged_attention`` kernel and the LiveServe KV manager.

The pool owns fixed-size pages of device KV storage
([num_pages, page_size, Hkv, hd] per layer); sequences own ordered page
lists (prefix-first, matching §5.1's suffix-first eviction). Block tables
([B, pages_per_seq] int32) are built per decode batch and handed to the
kernel via scalar prefetch. A DRAM tier holds offloaded page *contents*
(host numpy) so evict/reload round-trips are bit-exact.

This is hardware-agnostic bookkeeping: the LiveServe policies decide
*which* sessions' pages move; this module moves them.

It is also *layout*-agnostic (DESIGN.md §9): when the device page store
is tensor-sharded over a mesh's 'model' axis, physical page ids and the
block tables built from them are unchanged — the sharded dims (KV heads
or page slots) are never indexed here. ``offload_suffix``'s
``kv_pages[phys]`` read gathers the full logical page across shards
(``np.asarray`` on a sharded jax array), and ``reload``'s batched
scatter writes it back through the same functional update, so the DRAM
tier always stores whole logical pages and an engine can evict on one
mesh and (after a checkpoint-style move) reload on another.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np


class OutOfPages(RuntimeError):
    pass


@dataclass
class SeqPages:
    seq_id: str
    pages: List[int] = field(default_factory=list)   # prefix-first order
    length: int = 0                                   # tokens written
    offloaded: Dict[int, np.ndarray] = field(default_factory=dict)
    # offloaded: logical page index (position in `pages`) -> host copy;
    # an offloaded slot keeps -1 in `pages`.
    #
    # In-flight transfer marks (the async chunked transfer engine,
    # DESIGN.md §10). Each logical page is in exactly one state:
    #   resident    pages[li] >= 0, li not in loading/offloading
    #   offloading  pages[li] >= 0, li in offloading — device contents
    #               still valid/usable; host copy not yet durable
    #               (copy-then-free: the slot frees when the chunk
    #               drains)
    #   loading     pages[li] >= 0 (slot reserved), li in loading AND
    #               li in offloaded — host copy is the source of truth,
    #               device contents not yet arrived
    #   offloaded   pages[li] == -1, li in offloaded only
    loading: set = field(default_factory=set)
    offloading: set = field(default_factory=set)


class PagedPool:
    def __init__(self, num_pages: int, page_size: int):
        self.num_pages = num_pages
        self.page_size = page_size
        self.free: List[int] = list(range(num_pages - 1, -1, -1))
        self.seqs: Dict[str, SeqPages] = {}

    # ------------------------------------------------------------ alloc
    @property
    def free_pages(self) -> int:
        return len(self.free)

    def seq(self, seq_id: str) -> SeqPages:
        s = self.seqs.get(seq_id)
        if s is None:
            s = SeqPages(seq_id)
            self.seqs[seq_id] = s
        return s

    def pages_for(self, tokens: int) -> int:
        return -(-tokens // self.page_size)

    def ensure_capacity(self, seq_id: str, new_length: int) -> List[int]:
        """Grow a sequence to hold new_length tokens; returns newly
        allocated physical pages."""
        s = self.seq(seq_id)
        need = self.pages_for(new_length) - len(s.pages)
        out = []
        for _ in range(max(0, need)):
            if not self.free:
                raise OutOfPages(f"pool exhausted growing {seq_id}")
            p = self.free.pop()
            s.pages.append(p)
            out.append(p)
        s.length = max(s.length, new_length)
        return out

    def trim(self, seq_id: str, length: int) -> int:
        """Shrink a sequence's page list to what `length` tokens need,
        freeing trailing pages (in-flight lookahead pages on barge-in,
        §5.2 — committed pages are untouched). Returns pages freed."""
        s = self.seq(seq_id)
        keep = self.pages_for(length)
        freed = 0
        while len(s.pages) > keep:
            li = len(s.pages) - 1
            assert li not in s.loading and li not in s.offloading, \
                f"{seq_id}: trim would drop page {li} mid-transfer " \
                "(transfers run only for idle sessions; trim only on " \
                "the live turn's lookahead)"
            phys = s.pages.pop()
            s.offloaded.pop(len(s.pages), None)
            if phys >= 0:
                self.free.append(phys)
                freed += 1
        s.length = min(s.length, length)
        return freed

    def release(self, seq_id: str) -> None:
        s = self.seqs.pop(seq_id, None)
        if s is None:
            return
        for p in s.pages:
            if p >= 0:
                self.free.append(p)

    def adopt(self, seq_id: str, n_pages: int, length: int,
              offloaded: Dict[int, np.ndarray]) -> SeqPages:
        """Install a sequence arriving from another pool (cross-replica
        migration handoff). Every page lands host-resident — the source
        drained its chunked offloads before the handoff — so adoption
        allocates nothing here; the destination's reload machinery pages
        the KV back in on its own clock."""
        assert seq_id not in self.seqs, f"{seq_id} already placed"
        assert set(offloaded) == set(range(n_pages)), \
            f"{seq_id}: handoff requires a full host copy " \
            f"({sorted(offloaded)} vs {n_pages} pages)"
        s = SeqPages(seq_id, pages=[-1] * n_pages, length=length,
                     offloaded=dict(offloaded))
        self.seqs[seq_id] = s
        return s

    # ------------------------------------------------------------ tables
    def block_table(self, seq_ids: List[str], pages_per_seq: int,
                    *, pad_page: int = 0) -> np.ndarray:
        """[B, pages_per_seq] int32 for the paged_attention kernel.
        Raises if any sequence has offloaded pages (must reload first —
        the correctness contract of §5.2's sync-fallback path)."""
        bt = np.full((len(seq_ids), pages_per_seq), pad_page, np.int32)
        for i, sid in enumerate(seq_ids):
            s = self.seq(sid)
            if s.offloaded:
                raise RuntimeError(f"{sid} has offloaded pages")
            n = min(len(s.pages), pages_per_seq)
            bt[i, :n] = s.pages[:n]
        return bt

    def seq_lens(self, seq_ids: List[str]) -> np.ndarray:
        return np.array([self.seq(s).length for s in seq_ids], np.int32)

    # ------------------------------------------------------------ tiers
    #
    # Chunk-grained primitives for the async transfer engine
    # (core/transfer_engine.py): begin_* flips accounting state and
    # reserves/marks slots; complete_* moves the bytes for one chunk;
    # cancel_* reverts marks without moving anything. The legacy
    # whole-session `offload_suffix`/`reload` below are begin+complete
    # in one call (the synchronous path, still used by pool tests and
    # the non-async engine mode).

    def begin_reload(self, seq_id: str) -> List[int]:
        """Reserve a physical slot for every offloaded page and mark it
        ``loading``. All-or-nothing: raises before mutating if the pool
        cannot hold them all. Returns the logical indices needing a
        host->device transfer, prefix-first. (Pages whose offload is
        still in flight are NOT included — cancel those with
        ``cancel_offloading`` first: their bytes never left HBM.)"""
        s = self.seq(seq_id)
        logical = sorted(li for li in s.offloaded if li not in s.loading)
        if len(self.free) < len(logical):
            raise OutOfPages(f"pool exhausted reloading {seq_id}")
        for li in logical:
            s.pages[li] = self.free.pop()
            s.loading.add(li)
        return logical

    def complete_reload(self, seq_id: str, logical: List[int], kv_pages,
                        staged=None):
        """Land one reload chunk: scatter the host copies into their
        reserved slots (one batched functional update), clear the
        ``loading`` marks, drop the host copies. ``staged`` overrides
        the source with an already-device-resident [n, 2, L, ...] stack
        (the engine stages it to time only the transferred bytes).
        Returns the updated kv_pages."""
        s = self.seq(seq_id)
        if not logical:
            return kv_pages
        phys = [s.pages[li] for li in logical]
        src = staged if staged is not None \
            else np.stack([s.offloaded[li] for li in logical])
        kv_pages = kv_pages.at[np.asarray(phys)].set(src)
        for li in logical:
            assert li in s.loading, f"{seq_id}: page {li} not loading"
            s.loading.remove(li)
            del s.offloaded[li]
        return kv_pages

    def cancel_loading(self, seq_id: str,
                       logical: Optional[List[int]] = None) -> int:
        """Un-reserve loading pages (eviction of a loading session,
        burst cancel, hangup): the slot returns to the free list, the
        host copy stays authoritative in ``offloaded``. Zero-copy —
        the contents never arrived. Returns pages cancelled."""
        s = self.seq(seq_id)
        take = sorted(s.loading) if logical is None else list(logical)
        for li in take:
            assert li in s.loading, f"{seq_id}: page {li} not loading"
            self.free.append(s.pages[li])
            s.pages[li] = -1
            s.loading.remove(li)
        return len(take)

    def evictable_suffix(self, seq_id: str, n_pages: int):
        """Pick the LAST ``n_pages`` the eviction policy can free
        (suffix-first, §5.1), split by how they free: ``cancel_lis``
        are loading pages (cancel the in-flight reload — free
        immediately, zero copy) and ``offload_lis`` are resident pages
        (need a device->host copy). Pages already offloading are
        skipped — their blocks were accounted by an earlier pass."""
        s = self.seq(seq_id)
        cancel_lis, offload_lis = [], []
        for li in range(len(s.pages) - 1, -1, -1):
            if len(cancel_lis) + len(offload_lis) >= n_pages:
                break
            if s.pages[li] < 0 or li in s.offloading:
                continue
            if li in s.loading:
                cancel_lis.append(li)
            else:
                offload_lis.append(li)
        return cancel_lis, offload_lis

    def mark_offloading(self, seq_id: str, logical: List[int]) -> None:
        """Copy-then-free step 1: the pages stay resident and usable;
        the slot frees only when ``complete_offload`` lands the copy."""
        s = self.seq(seq_id)
        for li in logical:
            assert s.pages[li] >= 0 and li not in s.loading \
                and li not in s.offloading, \
                f"{seq_id}: page {li} not plain-resident"
            s.offloading.add(li)

    def complete_offload(self, seq_id: str,
                         copies: Dict[int, np.ndarray]) -> int:
        """Copy-then-free step 2: the host copies are durable — record
        them and free the physical slots. Returns pages freed."""
        s = self.seq(seq_id)
        for li, host in copies.items():
            assert li in s.offloading, f"{seq_id}: page {li} not offloading"
            s.offloaded[li] = host
            self.free.append(s.pages[li])
            s.pages[li] = -1
            s.offloading.remove(li)
        return len(copies)

    def cancel_offloading(self, seq_id: str,
                          logical: Optional[List[int]] = None) -> List[int]:
        """A reload/turn arrived before the copy drained: keep the pages
        resident (their device contents never left). Returns the logical
        indices whose offload was cancelled."""
        s = self.seq(seq_id)
        take = sorted(s.offloading) if logical is None else list(logical)
        for li in take:
            assert li in s.offloading, f"{seq_id}: page {li} not offloading"
            s.offloading.remove(li)
        return take

    # --------------------------------------------- synchronous wrappers
    def offload_suffix(self, seq_id: str, n_pages: int, kv_pages) -> int:
        """Move the LAST n_pages of a sequence to host (suffix-first,
        §5.1), synchronously: begin + complete in one call. kv_pages:
        device array [num_pages, page, Hkv, hd] (or a pytree leaf).
        Loading pages in the suffix are cancelled instead of copied
        (their contents only exist on the host). Returns pages freed."""
        cancel_lis, offload_lis = self.evictable_suffix(seq_id, n_pages)
        self.cancel_loading(seq_id, cancel_lis)
        self.mark_offloading(seq_id, offload_lis)
        s = self.seq(seq_id)
        self.complete_offload(
            seq_id, {li: np.asarray(kv_pages[s.pages[li]])
                     for li in offload_lis})
        return len(cancel_lis) + len(offload_lis)

    def reload(self, seq_id: str, kv_pages):
        """Bring offloaded pages back, synchronously. Returns (updated
        kv_pages, restored page count — transfers plus cancelled
        in-flight offloads). The scatter is functional and batched (one
        update for all pages); all-or-nothing on free space."""
        cancelled = self.cancel_offloading(seq_id)
        logical = self.begin_reload(seq_id)
        kv_pages = self.complete_reload(seq_id, logical, kv_pages)
        return kv_pages, len(logical) + len(cancelled)

    def resident_pages(self, seq_id: str) -> int:
        """Usable-resident pages: excludes loading reservations (their
        contents are still in flight), includes offloading pages (still
        valid on device until the copy drains). Read-only: an unknown
        or released sequence reports 0 without creating a ghost entry
        (callers probe sessions the pool may have dropped)."""
        s = self.seqs.get(seq_id)
        if s is None:
            return 0
        return sum(1 for li, p in enumerate(s.pages)
                   if p >= 0 and li not in s.loading)

    def inflight_pages(self, seq_id: str):
        """(loading, offloading) page counts for one sequence."""
        s = self.seq(seq_id)
        return len(s.loading), len(s.offloading)

    def stats(self) -> dict:
        return {
            "free": self.free_pages,
            "used": self.num_pages - self.free_pages,
            "seqs": len(self.seqs),
            "offloaded_pages": sum(len(s.offloaded)
                                   for s in self.seqs.values()),
            "loading_pages": sum(len(s.loading)
                                 for s in self.seqs.values()),
            "offloading_pages": sum(len(s.offloading)
                                    for s in self.seqs.values()),
        }

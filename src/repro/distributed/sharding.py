"""Per-family sharding rules (DESIGN.md §8).

Rules map parameter-tree paths to PartitionSpecs over a ('data','model')
(+ optional leading 'pod') mesh:

- head / d_ff / expert / vocab dimensions shard over 'model' *when
  divisible* (non-divisible dims fall back to replication automatically —
  e.g. qwen2's 12 heads on a 16-way axis);
- for configs whose per-model-shard weights would blow HBM (>= FSDP_GB per
  chip), the d_model/contraction dims additionally shard over 'data'
  (FSDP/ZeRO-3 at rest; XLA:SPMD inserts the per-layer gathers);
- batch shards over all data axes; decode KV caches shard their *sequence*
  dim over 'model' (kv_heads are never divisible by 16 in the assigned
  archs), which turns decode attention into a distributed-softmax;
- Mamba2/RG-LRU shard heads/channels over 'model' (the block-diagonal
  RG-LRU gates and per-head SSD make this fully local).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

FSDP_BYTES = 4e9       # per-chip weight budget before FSDP kicks in
# (4 GB: with bf16 params + fp32 Adafactor master at rest, a non-FSDP
# layout already costs 3x this per chip — phi3.5-moe at 5.25 GB/chip
# weights peaked at 18.8 GB > the 16 GB v5e without it)


def needs_fsdp(cfg, mesh) -> bool:
    model_shards = mesh.shape["model"]
    return cfg.num_params() * 2 / model_shards > FSDP_BYTES


def axis_size(mesh, *names) -> int:
    return int(np.prod([mesh.shape[n] for n in names]))


class ShardingRules:
    def __init__(self, cfg, mesh, *, mode: str = "train",
                 fsdp: Optional[bool] = None, expert_tp: bool = False):
        self.cfg = cfg
        self.mesh = mesh
        self.mode = mode
        self.expert_tp = expert_tp
        self.data_axes = tuple(n for n in mesh.axis_names if n != "model")
        self.fsdp = needs_fsdp(cfg, mesh) if fsdp is None else fsdp
        self.M = mesh.shape["model"]
        self.D = axis_size(mesh, *self.data_axes)

    # ------------------------------------------------------------ helpers
    def _m(self, dim_size: int):
        """'model' if divisible else replicate."""
        return "model" if dim_size % self.M == 0 else None

    def _d(self, dim_size: int):
        """FSDP at-rest sharding of contraction dims over 'data'."""
        if not self.fsdp:
            return None
        return ("data" if dim_size % self.mesh.shape["data"] == 0
                else None)

    def _b(self, dim_size: int):
        """Batch dim over data axes when divisible (long_500k has B=1)."""
        if self.decode_2d:
            return None                 # 2D-TP decode replicates batch
        return self.data_axes if dim_size % self.D == 0 else None

    @property
    def decode_2d(self) -> bool:
        """Big-model decode: weights 2D-sharded (d x heads), batch
        replicated, KV sequence sharded over BOTH axes — avoids per-token
        FSDP weight gathers (DESIGN.md §8)."""
        return self.fsdp and self.mode == "decode"

    def _seq(self, w: int):
        """KV ring sequence dim sharding."""
        if self.decode_2d and w % (self.D * self.M) == 0:
            return tuple(self.data_axes) + ("model",)
        return "model" if w % self.M == 0 else None

    # ------------------------------------------------------------ params
    def param_spec(self, path: str, shape) -> P:
        c = self.cfg
        nd = len(shape)
        leaf = path.split("/")[-1]

        if leaf in ("embed",):                       # [V, d]
            return P(self._m(shape[0]), self._d(shape[1]))
        if leaf == "unembed":                        # [d, V]
            return P(self._d(shape[0]), self._m(shape[1]))
        if "attn" in path or "xattn" in path:
            if leaf == "wq":                         # [d, H, hd]
                return P(self._d(shape[0]), self._m(shape[1]), None)
            if leaf in ("wk", "wv"):                 # [d, Hkv, hd] (small)
                return P(self._d(shape[0]), self._m(shape[1]), None)
            if leaf == "wo":                         # [H, hd, d]
                return P(self._m(shape[0]), None, self._d(shape[2]))
            if leaf in ("bq", "bk", "bv"):           # [H, hd]
                return P(self._m(shape[0]), None)
            # MLA pieces
            if leaf == "w_dq":
                return P(self._d(shape[0]), None)
            if leaf == "w_uq":                       # [ql, H, e]
                return P(None, self._m(shape[1]), None)
            if leaf == "w_dkv":
                return P(self._d(shape[0]), None)
            if leaf in ("w_uk", "w_uv"):             # [r, H, e]
                return P(None, self._m(shape[1]), None)
        if "moe" in path:
            if leaf == "router":
                return P(None, None)
            if leaf in ("w_gate", "w_up") and nd == 3:   # [E, d, f]
                if self.expert_tp:
                    return P(self._m(shape[0]), None,
                             "data" if shape[2] % self.mesh.shape["data"]
                             == 0 else None)
                return P(self._m(shape[0]), self._d(shape[1]), None)
            if leaf == "w_down" and nd == 3:             # [E, f, d]
                if self.expert_tp:
                    return P(self._m(shape[0]),
                             "data" if shape[1] % self.mesh.shape["data"]
                             == 0 else None, None)
                return P(self._m(shape[0]), None, self._d(shape[2]))
        if "mixer" in path:                          # mamba2
            if leaf in ("w_z", "w_x"):               # [d, d_in]
                return P(self._d(shape[0]), self._m(shape[1]))
            if leaf in ("w_B", "w_C"):               # [d, gn] small
                return P(self._d(shape[0]), None)
            if leaf == "w_dt":                       # [d, nheads]
                return P(self._d(shape[0]), self._m(shape[1]))
            if leaf in ("conv_x", "conv_x_b"):
                return P(*([None] * (nd - 1)), self._m(shape[-1]))
            if leaf in ("conv_bc", "conv_bc_b"):
                return P(*([None] * nd))
            if leaf in ("A_log", "D", "dt_bias"):    # [nheads]
                return P(self._m(shape[0]))
            if leaf == "norm":                       # [d_in]
                return P(self._m(shape[0]))
            if leaf == "out_proj":                   # [d_in, d]
                return P(self._m(shape[0]), self._d(shape[1]))
        if "rec" in path.split("/"):                 # rg-lru
            if leaf in ("in_gate", "in_rec"):        # [d, w]
                return P(self._d(shape[0]), self._m(shape[1]))
            if leaf == "conv_w":
                return P(None, self._m(shape[1]))
            if leaf in ("conv_b", "b_a", "b_x", "lam"):
                return P(self._m(shape[0]))
            if leaf in ("w_a", "w_x"):               # [nb, bw, bw]
                return P(self._m(shape[0]), None, None)
            if leaf == "out":                        # [w, d]
                return P(self._m(shape[0]), self._d(shape[1]))
        if "mlp" in path or "shared" in path:
            if leaf in ("w_gate", "w_up") and nd == 2:
                if self.expert_tp and "shared" in path:
                    return P(None, self._m(shape[1]))
                return P(self._d(shape[0]), self._m(shape[1]))
            if leaf == "w_down" and nd == 2:
                if self.expert_tp and "shared" in path:
                    return P(self._m(shape[0]), None)
                return P(self._m(shape[0]), self._d(shape[1]))
            if leaf in ("b_up",):
                return P(self._m(shape[0]))
        # norms, scalars, everything else: replicated
        return P(*([None] * nd))

    def params(self, shapes) -> dict:
        """shapes: pytree of ShapeDtypeStruct -> pytree of NamedSharding."""
        def spec(path, leaf):
            p = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                         for k in path)
            # stacked-layer leading dim (from scan stacking / expert vmap
            # handled above) — detect the layer-stack dim and skip it
            s = self.param_spec(p, leaf.shape)
            return s
        return jax.tree_util.tree_map_with_path(
            lambda kp, x: jax.NamedSharding(
                self.mesh, self._stacked_fix(kp, x)), shapes)

    def _stacked_fix(self, kp, leaf) -> P:
        """Layer-scanned params carry a leading [L] dim not present in the
        per-layer rule table: match on the trailing dims. Adafactor
        second-moment leaves (v / vr / vc) inherit the parent parameter's
        spec (vr drops the last dim, vc the second-to-last)."""
        parts = [str(getattr(k, "key", getattr(k, "idx", k))) for k in kp]
        tail = parts[-1]
        fac = tail if tail in ("v", "vr", "vc") and len(parts) > 1 else None
        if fac:
            parts = parts[:-1]
        path = "/".join(parts)
        in_stack = "layers/" in path and "layers_pre" not in path \
            and self.cfg.family != "hybrid"
        shape = tuple(leaf.shape)
        if fac == "vr":
            shape = shape + (1,)          # reconstruct param rank
        elif fac == "vc":
            shape = shape[:-1] + (1, shape[-1])
        pshape = shape[1:] if in_stack and len(shape) >= 1 else shape
        spec = self.param_spec(path, pshape)
        if in_stack:
            spec = P(None, *spec)
        if fac == "vr":
            spec = P(*spec[:-1])
        elif fac == "vc":
            spec = P(*(spec[:-2] + (spec[-1],)))
        return spec

    # ------------------------------------------------------------ batch
    def batch(self, shapes) -> dict:
        def spec(kp, x):
            # tokens/labels/weights [B, S]; frames/patches [B, F, d]
            return jax.NamedSharding(
                self.mesh, P(self._b(x.shape[0]),
                             *([None] * (x.ndim - 1))))
        return jax.tree_util.tree_map_with_path(spec, shapes)

    def token_sharding(self, batch: int):
        """Decode-step token vector [B]."""
        return jax.NamedSharding(self.mesh, P(self._b(batch)))

    def logits_sharding(self, batch: int):
        """Serve-step output logits [B, V]."""
        return jax.NamedSharding(
            self.mesh, P(self._b(batch),
                         self._m(self.cfg.vocab_size)))

    # ------------------------------------------------------------ cache
    def cache(self, shapes) -> dict:
        """Decode/prefill cache: batch over data (when divisible); KV ring
        sequence over 'model' (over both axes in 2D-TP decode); SSM heads /
        RG-LRU channels over model."""
        def b(x):
            return self._b(x.shape[1])

        def spec(kp, x):
            name = str(getattr(kp[-1], "key", kp[-1]))
            if name == "len":
                return jax.NamedSharding(self.mesh, P(self._b(x.shape[0])))
            if name == "kv_pos":                       # [B, W]
                return jax.NamedSharding(
                    self.mesh, P(self._b(x.shape[0]), self._seq(x.shape[1])))
            if name in ("k", "v"):                     # [L, B, W, Hkv, hd]
                return jax.NamedSharding(
                    self.mesh, P(None, b(x), self._seq(x.shape[2]),
                                 None, None))
            if name in ("ckv", "k_rope"):              # [L, B, W, r]
                return jax.NamedSharding(
                    self.mesh, P(None, b(x), self._seq(x.shape[2]), None))
            if name in ("cross_k", "cross_v"):         # [L, B, F, H, hd]
                return jax.NamedSharding(
                    self.mesh,
                    P(None, b(x), None, self._m(x.shape[3]), None))
            if name == "ssm_state":                    # [L, B, H, p, n]
                return jax.NamedSharding(
                    self.mesh, P(None, b(x), self._m(x.shape[2]),
                                 None, None))
            if name in ("conv_x",):                    # [L, B, cw-1, d_in]
                return jax.NamedSharding(
                    self.mesh, P(None, b(x), None, self._m(x.shape[3])))
            if name == "conv_bc":
                return jax.NamedSharding(
                    self.mesh, P(None, b(x), None, None))
            if name == "rec_h":                        # [Lr, B, w]
                return jax.NamedSharding(
                    self.mesh, P(None, b(x), self._m(x.shape[2])))
            if name == "rec_conv":                     # [Lr, B, cw-1, w]
                return jax.NamedSharding(
                    self.mesh, P(None, b(x), None, self._m(x.shape[3])))
            return jax.NamedSharding(self.mesh,
                                     P(*([None] * x.ndim)))
        return jax.tree_util.tree_map_with_path(spec, shapes)

    # ------------------------------------------------------------ opt
    def opt_state(self, shapes) -> dict:
        """Optimizer state mirrors param sharding (moments/master share the
        param layout -> ZeRO follows from fsdp at-rest sharding)."""
        return self.params(shapes)

    def activation_spec(self) -> P:
        """Residual-stream constraint for training: batch over data, seq
        over 'model' (Megatron-style sequence parallelism for the saved
        activations)."""
        return P(self.data_axes, "model", None)

"""Gradient compression for the data-parallel reduction.

int8 block-quantized all-reduce with error feedback. Scheme (per leaf):

  1. shared block scale   s = max(pmax(max|g + e|), eps) / 127   (tiny collective)
  2. local quantization   q_i = round((g_i + e_i) / s)    int8
  3. integer reduction    Q = psum(q_i)                   (8x less traffic)
  4. decode               g_hat = Q * s / N
  5. error feedback       e_i' = (g_i + e_i) - q_i * s

Only the int8 payload crosses the DP ('pod') axis — 8x less DCI traffic
than an f32 all-reduce; error feedback keeps the long-run bias bounded
(1-bit-Adam-family argument).

The quantization core (``BLOCK``, ``_pad_blocks``, ``block_scale``)
lives in ``kvcache/quant.py`` — the same scheme encodes KV pages on the
offload path (DESIGN.md §14), and sharing it keeps the two tiers from
drifting. The epsilon guards the block max there, not the quotient:
``pmax(...) / 127 + eps`` (the old form) inflated every scale, so
max-magnitude values no longer hit ±127 and the worst-case error
exceeded scale/2.

Calling convention: each leaf carries the per-shard gradients stacked on a
leading axis of size N = mesh.shape[axis] (i.e. the local grads *before*
any cross-shard reduction). Returns (mean gradient [...], updated error
feedback [N, ...]).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kvcache.quant import BLOCK, _pad_blocks, block_scale

__all__ = ["BLOCK", "compressed_psum", "wire_bytes"]


def compressed_psum(grads, mesh, axis: str, errors=None):
    """Mean-reduce stacked per-shard grads over mesh axis with int8 wire
    format + error feedback."""
    if errors is None:
        errors = jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def leaf_reduce(g, e):
        shape = g.shape[1:]

        def body(g_loc, e_loc):
            x = g_loc[0].astype(jnp.float32) + e_loc[0]
            blocks, _ = _pad_blocks(x.reshape(-1))
            local_max = jnp.max(jnp.abs(blocks), axis=1)
            scale = block_scale(jax.lax.pmax(local_max, axis))  # [nb]
            q = jnp.clip(jnp.round(blocks / scale[:, None]),
                         -127, 127).astype(jnp.int8)
            n = jax.lax.psum(jnp.ones((), jnp.float32), axis)
            total = jax.lax.psum(q.astype(jnp.int32), axis)
            mean = (total.astype(jnp.float32) * scale[:, None] / n)
            mean = mean.reshape(-1)[:x.size].reshape(shape)
            deq = (q.astype(jnp.float32)
                   * scale[:, None]).reshape(-1)[:x.size].reshape(shape)
            return mean, (x - deq)[None]

        from repro.compat import shard_map
        f = shard_map(
            body, mesh=mesh,
            in_specs=(P(axis, *([None] * len(shape))),) * 2,
            out_specs=(P(*([None] * len(shape))),
                       P(axis, *([None] * len(shape)))),
            check_vma=False)
        return f(g, e)

    out = jax.tree.map(leaf_reduce, grads, errors)
    red = jax.tree.map(lambda t: t[0], out,
                       is_leaf=lambda x: isinstance(x, tuple))
    err = jax.tree.map(lambda t: t[1], out,
                       is_leaf=lambda x: isinstance(x, tuple))
    return red, err


def wire_bytes(grads) -> int:
    """int8 payload bytes per shard per reduction (telemetry)."""
    return sum(int(jnp.size(g[0])) for g in jax.tree.leaves(grads))

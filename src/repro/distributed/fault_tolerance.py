"""Fault tolerance & elasticity for 1000+-node operation (DESIGN.md §8).

- ``run_resilient``: checkpoint/restart supervisor — the training driver
  restarts from the last atomic checkpoint after a (simulated or real)
  failure; the paper-scale deployment maps each restart onto a fresh
  jax.distributed initialization.
- ``elastic_rescale``: rebuild the mesh with fewer/more data-parallel
  replicas and re-place checkpointed state onto it (host-side numpy ->
  device_put with the new shardings). Batch is re-sharded by the next
  step's in_shardings; optimizer state follows param specs.
- ``StragglerMitigator``: per-round deadline tracking for the serving
  engines / data loaders — a round exceeding ``deadline_factor`` x the
  rolling median marks the source straggling; callers shrink the next
  round or re-route (the serving engine drops the straggler's request to
  the next round instead of blocking the batch).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional

import jax
import numpy as np

from repro.training.checkpoint import restore_checkpoint, save_checkpoint


def run_resilient(train_once: Callable[[int], int], *, max_restarts: int = 3,
                  on_failure: Optional[Callable] = None) -> int:
    """Run ``train_once(start_step) -> last_step`` with restart-on-failure.

    ``train_once`` is expected to checkpoint; a raised exception triggers
    restore-from-latest and retry (the checkpoint/restart contract).
    """
    restarts = 0
    start = 0
    while True:
        try:
            return train_once(start)
        except Exception as e:           # noqa: BLE001 — supervisor
            restarts += 1
            if on_failure is not None:
                start = on_failure(e, restarts)
            if restarts > max_restarts:
                raise


def elastic_rescale(ckpt_dir: str, make_mesh: Callable[[], "jax.sharding.Mesh"],
                    make_shardings: Callable):
    """Restore the latest checkpoint onto a rebuilt (resized) mesh.

    ``make_shardings(mesh, tree_shapes) -> pytree of NamedSharding``.
    Returns (tree, step, mesh).
    """
    tree_host, step = restore_checkpoint(ckpt_dir)
    mesh = make_mesh()
    shardings = make_shardings(mesh, tree_host)
    tree, step = restore_checkpoint(ckpt_dir, step, shardings=shardings)
    return tree, step, mesh


@dataclass
class StragglerMitigator:
    """Per-round deadline tracking with a genuine recovery path.

    A round exceeding ``deadline_factor`` x the rolling median earns the
    source a strike. A single round back under the deadline does NOT
    erase the record — an alternating slow/fast straggler must still
    accumulate — but ``recover_after`` *consecutive* under-deadline
    rounds reset the source to a clean slate. ``forget`` drops a source
    that was drained/replaced so its history cannot leak onto a fresh
    replica reusing the name.
    """
    deadline_factor: float = 3.0
    window: int = 32
    min_samples: int = 8
    recover_after: int = 2
    durations: List[float] = field(default_factory=list)
    strikes: dict = field(default_factory=dict)
    good_streak: dict = field(default_factory=dict)

    def observe(self, source: str, duration_s: float) -> bool:
        """Record a round duration; True if `source` is straggling."""
        self.durations.append(duration_s)
        if len(self.durations) > self.window:
            self.durations.pop(0)
        med = float(np.median(self.durations))
        if (len(self.durations) >= self.min_samples
                and duration_s > self.deadline_factor * med):
            self.strikes[source] = self.strikes.get(source, 0) + 1
            self.good_streak.pop(source, None)
            return True
        if source in self.strikes:
            streak = self.good_streak.get(source, 0) + 1
            if streak >= self.recover_after:
                self.forget(source)
            else:
                self.good_streak[source] = streak
        return False

    def should_evict(self, source: str, threshold: int = 3) -> bool:
        return self.strikes.get(source, 0) >= threshold

    def forget(self, source: str) -> None:
        """Clean slate for ``source`` (drained / replaced replica)."""
        self.strikes.pop(source, None)
        self.good_streak.pop(source, None)

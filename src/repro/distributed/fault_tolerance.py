"""Fault tolerance & elasticity for 1000+-node operation (DESIGN.md §8).

- ``run_resilient``: checkpoint/restart supervisor — the training driver
  restarts from the last atomic checkpoint after a (simulated or real)
  failure; the paper-scale deployment maps each restart onto a fresh
  jax.distributed initialization.
- ``elastic_rescale``: rebuild the mesh with fewer/more data-parallel
  replicas and re-place checkpointed state onto it (host-side numpy ->
  device_put with the new shardings). Batch is re-sharded by the next
  step's in_shardings; optimizer state follows param specs.
- ``StragglerMitigator``: per-round deadline tracking for the serving
  engines / data loaders — a round exceeding ``deadline_factor`` x the
  rolling median marks the source straggling; callers shrink the next
  round or re-route (the serving engine drops the straggler's request to
  the next round instead of blocking the batch).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional

import jax
import numpy as np

from repro.training.checkpoint import restore_checkpoint, save_checkpoint


def run_resilient(train_once: Callable[[int], int], *, max_restarts: int = 3,
                  on_failure: Optional[Callable] = None) -> int:
    """Run ``train_once(start_step) -> last_step`` with restart-on-failure.

    ``train_once`` is expected to checkpoint; a raised exception triggers
    restore-from-latest and retry (the checkpoint/restart contract).
    """
    restarts = 0
    start = 0
    while True:
        try:
            return train_once(start)
        except Exception as e:           # noqa: BLE001 — supervisor
            restarts += 1
            if on_failure is not None:
                start = on_failure(e, restarts)
            if restarts > max_restarts:
                raise


def elastic_rescale(ckpt_dir: str, make_mesh: Callable[[], "jax.sharding.Mesh"],
                    make_shardings: Callable):
    """Restore the latest checkpoint onto a rebuilt (resized) mesh.

    ``make_shardings(mesh, tree_shapes) -> pytree of NamedSharding``.
    Returns (tree, step, mesh).
    """
    tree_host, step = restore_checkpoint(ckpt_dir)
    mesh = make_mesh()
    shardings = make_shardings(mesh, tree_host)
    tree, step = restore_checkpoint(ckpt_dir, step, shardings=shardings)
    return tree, step, mesh


@dataclass
class StragglerMitigator:
    deadline_factor: float = 3.0
    window: int = 32
    durations: List[float] = field(default_factory=list)
    strikes: dict = field(default_factory=dict)

    def observe(self, source: str, duration_s: float) -> bool:
        """Record a round duration; True if `source` is straggling."""
        self.durations.append(duration_s)
        if len(self.durations) > self.window:
            self.durations.pop(0)
        med = float(np.median(self.durations))
        if len(self.durations) >= 8 and duration_s > self.deadline_factor * med:
            self.strikes[source] = self.strikes.get(source, 0) + 1
            return True
        self.strikes.pop(source, None)
        return False

    def should_evict(self, source: str, threshold: int = 3) -> bool:
        return self.strikes.get(source, 0) >= threshold

"""Tensor-sharded paged KV plane (DESIGN.md §9).

Shards the serving engine's page store over the ``'model'`` axis of a
``('data', 'model')`` mesh, following the same divisibility rules as the
parameter sharding in ``distributed/sharding.py``:

- ``heads``  — KV heads divide the model axis: each shard owns
  ``Hkv / M`` heads of every page. Attention is fully local per shard
  (softmax is per head); shards' outputs are re-joined with an
  ``all_gather`` over the head dim before the output projection.
- ``slots``  — heads do not divide but the page size does (the common
  case for the assigned archs, whose 2-8 KV heads never divide a
  16-way axis — the rule table's "sequence over 'model'" branch): each
  shard owns ``page / M`` token slots of every physical page. A shard
  computes a *partial* online softmax over its slots
  (``return_stats`` in the kernel) and the shards merge exactly:
  ``m* = pmax(m)``, ``w_s = l_s * exp(m_s - m*)``,
  ``o = psum(o_s * w_s) / psum(w_s)``.
- ``replicated`` — neither divides: fall back to full replication
  (every shard computes everything), mirroring ``ShardingRules._m``.

Block tables, tokens, and all model weights stay **replicated** across
'model' (and across 'data'): the paged plane's scaling target is KV
memory and attention bandwidth, which dominate realtime multi-turn
serving; weight tensor-parallelism composes later via
``ShardingRules``. The decode batch is likewise replicated over 'data'
— every shard runs the same fixed-slot batch, so the host-side control
plane (pool, block tables, KV manager) is identical with and without a
mesh and the offload/reload hooks move *sharded* pages through plain
``np.asarray`` gathers / ``device_put`` scatters.

Cross-session page sharing (DESIGN.md §13) is placement-stable by
construction: attaching to a cached prefix only repoints block tables
at existing physical ids — no page contents move, so each shard keeps
serving exactly the head/slot slice it already owns. COW allocates a
fresh page whose writes land through the same re-committed functional
updates; shared pages never enter the transfer ledger (the pool refuses
to mark a refcount>1 page offloading), so sharing cannot strand a
shard's slice on the host.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.kernels.flash_prefill import flash_prefill
from repro.kernels.paged_attention import paged_attention, \
    paged_prefill_attention


class PagedKVLayout:
    """How one engine's page store [L, P+1, page, Hkv, hd] shards."""

    def __init__(self, cfg, mesh, page_size: int):
        assert "model" in mesh.axis_names, mesh.axis_names
        self.cfg = cfg
        self.mesh = mesh
        self.page_size = page_size
        self.M = int(mesh.shape["model"])
        if cfg.num_kv_heads % self.M == 0:
            self.kind = "heads"
        elif page_size % self.M == 0:
            self.kind = "slots"
        else:
            self.kind = "replicated"

    def __repr__(self):
        return (f"PagedKVLayout(kind={self.kind!r}, M={self.M}, "
                f"mesh={dict(self.mesh.shape)})")

    # ------------------------------------------------------------ specs
    def page_pspec(self, *, with_layers: bool = True) -> P:
        """PartitionSpec for [L, P+1, page, Hkv, hd] (or the 4D
        kernel-level [P, page, Hkv, hd] with ``with_layers=False``)."""
        lead = (None,) if with_layers else ()
        if self.kind == "heads":
            return P(*lead, None, None, "model")
        if self.kind == "slots":
            return P(*lead, None, "model")
        return P()

    def page_sharding(self, *, with_layers: bool = True) -> NamedSharding:
        return NamedSharding(self.mesh,
                             self.page_pspec(with_layers=with_layers))

    @property
    def replicated(self) -> NamedSharding:
        return NamedSharding(self.mesh, P())

    def stage_host_chunk(self, host_chunk):
        """Stage one transfer-engine reload chunk ([n, 2, L, page, Hkv,
        hd] host stack) onto the mesh. The chunk is replicated — it
        indexes whole logical pages, and the follow-up page-store
        scatter plus the engine's placement re-commit
        (``_place_pages``) land it on the layout's exact sharding. The
        caller blocks on *this* buffer to time the transferred bytes
        alone (never on the sharded page store, whose readiness drags
        in unrelated decode work — DESIGN.md §10)."""
        return jax.device_put(host_chunk, self.replicated)

    # ------------------------------------------------------- shard body
    def write_token(self, kc, vc, k, v, write_page, write_slot):
        """Per-shard page write of one token per batch row.

        Runs *inside* shard_map: ``kc``/``vc`` are local shards
        [P+1, page_local, Hkv_local, hd]; ``k``/``v`` [B, Hkv, hd] are
        the full (replicated) projections; ``write_page``/``write_slot``
        [B] i32 are global coordinates.
        """
        if self.kind == "heads":
            idx = jax.lax.axis_index("model")
            hloc = kc.shape[2]
            k = jax.lax.dynamic_slice_in_dim(k, idx * hloc, hloc, axis=1)
            v = jax.lax.dynamic_slice_in_dim(v, idx * hloc, hloc, axis=1)
            return (kc.at[write_page, write_slot].set(k),
                    vc.at[write_page, write_slot].set(v))
        if self.kind == "slots":
            idx = jax.lax.axis_index("model")
            psl = kc.shape[1]
            own = (write_slot // psl) == idx
            loc = write_slot % psl
            keep = own[:, None, None]
            k = jnp.where(keep, k, kc[write_page, loc])
            v = jnp.where(keep, v, vc[write_page, loc])
            return kc.at[write_page, loc].set(k), vc.at[write_page, loc].set(v)
        return (kc.at[write_page, write_slot].set(k),
                vc.at[write_page, write_slot].set(v))

    def attend(self, q, kc, vc, block_tables, seq_lens, *,
               interpret: bool = False):
        """Per-shard paged attention + cross-shard combine.

        Runs *inside* shard_map: ``q`` [B, Hq, D] is the full
        (replicated) query; ``kc``/``vc`` are local page shards
        [P+1, page_local, Hkv_local, hd]. Returns the full [B, Hq, D]
        attention output, identical on every shard.
        """
        if self.kind == "heads":
            idx = jax.lax.axis_index("model")
            hq_loc = q.shape[1] // self.M
            q_loc = jax.lax.dynamic_slice_in_dim(q, idx * hq_loc, hq_loc,
                                                 axis=1)
            a = paged_attention(q_loc, kc, vc, block_tables, seq_lens,
                                interpret=interpret)
            return jax.lax.all_gather(a, "model", axis=1, tiled=True)
        if self.kind == "slots":
            idx = jax.lax.axis_index("model")
            psl = kc.shape[1]
            # the shard's slots sit at global offset idx*psl inside each
            # page; shifting seq_lens is equivalent to offsetting every
            # local position (masking is the only use of positions here)
            sl_eff = seq_lens - idx * psl
            o, m, l = paged_attention(
                q, kc, vc, block_tables, sl_eff,
                pos_stride=self.page_size, return_stats=True,
                interpret=interpret)
            m_star = jax.lax.pmax(m, "model")
            w = l * jnp.exp(m - m_star)                    # [B, Hq] f32
            den = jax.lax.psum(w, "model")
            num = jax.lax.psum(o.astype(jnp.float32) * w[..., None],
                               "model")
            a = num / jnp.maximum(den, 1e-30)[..., None]
            return a.astype(q.dtype)
        return paged_attention(q, kc, vc, block_tables, seq_lens,
                               interpret=interpret)

    # ------------------------------------------------- fused chunk plane
    def write_chunk(self, kc, vc, k, v, write_pages, write_slots):
        """Per-shard page write of a whole round's token chunks
        (DESIGN.md §11).

        Runs *inside* shard_map: ``kc``/``vc`` are local shards
        [P+1, page_local, Hkv_local, hd]; ``k``/``v`` [B, Q, Hkv, hd]
        are the full (replicated) projections; ``write_pages``/
        ``write_slots`` [B, Q] i32 are global coordinates.
        """
        if self.kind == "heads":
            idx = jax.lax.axis_index("model")
            hloc = kc.shape[2]
            k = jax.lax.dynamic_slice_in_dim(k, idx * hloc, hloc, axis=2)
            v = jax.lax.dynamic_slice_in_dim(v, idx * hloc, hloc, axis=2)
            return (kc.at[write_pages, write_slots].set(k),
                    vc.at[write_pages, write_slots].set(v))
        if self.kind == "slots":
            # tokens another shard owns are redirected to the scratch
            # page (the store's last physical page) instead of the
            # single-token plane's where-keep write-back: a chunk longer
            # than page_local would otherwise write back a *stale* copy
            # of the same local (page, slot) an owned token targets in
            # the same scatter, and duplicate-index resolution order is
            # implementation-defined
            idx = jax.lax.axis_index("model")
            psl = kc.shape[1]
            own = (write_slots // psl) == idx
            loc = write_slots % psl
            wp = jnp.where(own, write_pages, kc.shape[0] - 1)
            return kc.at[wp, loc].set(k), vc.at[wp, loc].set(v)
        return (kc.at[write_pages, write_slots].set(k),
                vc.at[write_pages, write_slots].set(v))

    def attend_chunk(self, q, kc, vc, block_tables, q_start, q_lens, *,
                     interpret: bool = False):
        """Per-shard fused multi-token attention + cross-shard combine.

        Runs *inside* shard_map: ``q`` [B, Q, Hq, D] is the full
        (replicated) query chunk; ``kc``/``vc`` are local page shards.
        Returns the full [B, Q, Hq, D] attention output, identical on
        every shard.
        """
        if self.kind == "heads":
            idx = jax.lax.axis_index("model")
            hq_loc = q.shape[2] // self.M
            q_loc = jax.lax.dynamic_slice_in_dim(q, idx * hq_loc, hq_loc,
                                                 axis=2)
            a = paged_prefill_attention(q_loc, kc, vc, block_tables,
                                        q_start, q_lens,
                                        interpret=interpret)
            return jax.lax.all_gather(a, "model", axis=2, tiled=True)
        if self.kind == "slots":
            idx = jax.lax.axis_index("model")
            psl = kc.shape[1]
            # the shard's slots sit at global offset idx*psl inside each
            # page; shifting the *traced* q_start shifts every masking
            # comparison (causal limit and derived seq_len alike), which
            # is equivalent to offsetting every local kv position —
            # pos_offset is static and cannot carry the traced idx
            o, m, l = paged_prefill_attention(
                q, kc, vc, block_tables, q_start - idx * psl, q_lens,
                pos_stride=self.page_size, return_stats=True,
                interpret=interpret)
            m_star = jax.lax.pmax(m, "model")          # [B, Q, Hq] f32
            w = l * jnp.exp(m - m_star)
            den = jax.lax.psum(w, "model")
            num = jax.lax.psum(o.astype(jnp.float32) * w[..., None],
                               "model")
            a = num / jnp.maximum(den, 1e-30)[..., None]
            return a.astype(q.dtype)
        return paged_prefill_attention(q, kc, vc, block_tables, q_start,
                                       q_lens, interpret=interpret)


# ======================================================================
# shard_map wrappers
# ======================================================================
def sharded_paged_attention(layout: PagedKVLayout, q, k_pages, v_pages,
                            block_tables, seq_lens, *,
                            interpret: bool = False):
    """Global-view sharded paged attention: q [B, Hq, D] and
    block_tables/seq_lens replicated; k_pages/v_pages [P, page, Hkv, D]
    sharded per the layout. Drop-in equal to ``paged_attention``."""
    spec = layout.page_pspec(with_layers=False)
    rep = P()

    def body(q, kp, vp, bt, sl):
        return layout.attend(q, kp, vp, bt, sl, interpret=interpret)

    f = shard_map(body, mesh=layout.mesh,
                  in_specs=(rep, spec, spec, rep, rep), out_specs=rep,
                  check_vma=False)
    return f(q, k_pages, v_pages, block_tables, seq_lens)


def sharded_flash_prefill(layout: PagedKVLayout, q, k, v, *,
                          causal: bool = True, window=None,
                          q_offset: int = 0, block_q: int = 128,
                          block_kv: int = 128, interpret: bool = False):
    """shard_map-wrapped chunked-prefill flash attention.

    Heads shard over 'model' when divisible (fully local — softmax is
    per head); otherwise every shard computes the full call (the
    replication fallback). q [B, Hq, Sq, D]; k/v [B, Hkv, Skv, D].

    Kernel-level building block, parity-pinned by
    tests/test_sharded_plane.py but not yet on an engine path: the
    engine's turn-0 prefill currently runs the replicated dense forward
    and grafts into sharded pages, and turn-N prefill teacher-forces
    through the sharded decode step. Wiring this into a chunked sharded
    prefill is the follow-up that makes long-prompt admission scale
    with the mesh (DESIGN.md §9)."""
    kernel = functools.partial(flash_prefill, causal=causal, window=window,
                               q_offset=q_offset, block_q=block_q,
                               block_kv=block_kv, interpret=interpret)
    Hq, Hkv = q.shape[1], k.shape[1]
    if Hq % layout.M == 0 and Hkv % layout.M == 0:
        spec = P(None, "model")
        f = shard_map(kernel, mesh=layout.mesh,
                      in_specs=(spec, spec, spec), out_specs=spec,
                      check_vma=False)
    else:
        rep = P()
        f = shard_map(kernel, mesh=layout.mesh,
                      in_specs=(rep, rep, rep), out_specs=rep,
                      check_vma=False)
    return f(q, k, v)


def make_sharded_step(cfg, layout: PagedKVLayout, *,
                      interpret: bool = False):
    """The sharded twin of ``serving.paged_engine.paged_decode_step``:
    one jitted shard_map over the whole step — weights/tokens/tables
    replicated in, pages sharded in/out, logits replicated out. The
    body is the *same* ``paged_decode_step`` code path with this
    layout's write/attend plane swapped in, so sharded and single-
    device engines cannot drift."""
    from repro.serving.paged_engine import paged_decode_step

    body = functools.partial(paged_decode_step, cfg, interpret=interpret,
                             plane=layout)
    spec = layout.page_pspec(with_layers=True)
    rep = P()
    f = shard_map(
        body, mesh=layout.mesh,
        in_specs=(rep, rep, rep, spec, spec, rep, rep, rep, rep),
        out_specs=(rep, spec, spec),
        check_vma=False)
    return jax.jit(f)


def make_sharded_fused_step(cfg, layout: PagedKVLayout, *,
                            interpret: bool = False):
    """The sharded twin of ``serving.paged_engine.paged_fused_step``
    (DESIGN.md §11): one jitted shard_map over the whole fused round —
    weights / token chunks / tables / q_start / q_lens replicated in,
    pages sharded in/out, last-token logits replicated out. Same body,
    same no-drift argument as ``make_sharded_step``."""
    from repro.serving.paged_engine import paged_fused_step

    body = functools.partial(paged_fused_step, cfg, interpret=interpret,
                             plane=layout)
    spec = layout.page_pspec(with_layers=True)
    rep = P()
    f = shard_map(
        body, mesh=layout.mesh,
        in_specs=(rep, rep, rep, spec, spec, rep, rep, rep, rep, rep),
        out_specs=(rep, spec, spec),
        check_vma=False)
    return jax.jit(f)


def make_sharded_spec_step(cfg, layout: PagedKVLayout, *,
                           interpret: bool = False):
    """The sharded twin of the speculative fused round (DESIGN.md §16):
    ``paged_fused_step(..., spec=True)`` under the same shard_map as
    ``make_sharded_fused_step``, with the extra replicated per-position
    argmax output ``outs [B, Q]``. The verify math is the identical
    fused body — only the logits slice/argmax tail differs — so the
    no-drift argument carries over unchanged."""
    from repro.serving.paged_engine import paged_fused_step

    body = functools.partial(paged_fused_step, cfg, interpret=interpret,
                             plane=layout, spec=True)
    spec = layout.page_pspec(with_layers=True)
    rep = P()
    f = shard_map(
        body, mesh=layout.mesh,
        in_specs=(rep, rep, rep, spec, spec, rep, rep, rep, rep, rep),
        out_specs=(rep, rep, spec, spec),
        check_vma=False)
    return jax.jit(f)

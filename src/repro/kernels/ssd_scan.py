"""Mamba2 SSD chunked scan — Pallas TPU kernel.

TPU adaptation of the SSD algorithm (arXiv:2405.21060): the GPU version
leans on warp-level scans; here each (batch, head) runs the chunk
recurrence *sequentially over the grid's innermost axis* while the
intra-chunk quadratic term is dense matmul work for the MXU:

  per chunk c:   L    = exp(segsum(dA_c))            [cs, cs]  (masked)
                 Ydiag= ((C_c B_c^T) * L) X_c        MXU
                 Yoff = (C_c * exp(cum)) state_c     MXU
                 state= decay_total * state + (B_c * decay_end)^T X_c

The inter-chunk state [P, N] persists in a VMEM scratch accumulator across
grid steps — no HBM round-trip for the recurrence (this is the part the
GPU implementation does via global-memory chunk states).

Grid: (B, H, num_chunks), chunks innermost/sequential.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(x_ref, da_ref, b_ref, c_ref, y_ref, st_out_ref, state_ref, *,
            cs: int, num_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0, 0].astype(jnp.float32)       # [cs, P]
    da = da_ref[0, 0, 0].astype(jnp.float32)     # [cs]
    bm = b_ref[0, 0, 0].astype(jnp.float32)      # [cs, N]
    cm = c_ref[0, 0, 0].astype(jnp.float32)      # [cs, N]

    cum = jnp.cumsum(da)                         # [cs]
    # intra-chunk decay matrix L[i, j] = exp(cum_i - cum_j) for j <= i
    seg = cum[:, None] - cum[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (cs, cs), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (cs, cs), 1)
    Lmat = jnp.where(tri, jnp.exp(seg), 0.0)
    scores = (cm @ bm.T) * Lmat                  # [cs, cs]
    y = scores @ x                               # intra-chunk
    # contribution of the carried state
    decay_in = jnp.exp(cum)[:, None]             # [cs, 1]
    y = y + (cm * decay_in) @ state_ref[...].T   # [cs, N]@[N, P]
    y_ref[0, 0, 0] = y.astype(y_ref.dtype)
    # state update
    total = jnp.exp(cum[-1])
    decay_end = jnp.exp(cum[-1] - cum)[:, None]  # [cs, 1]
    new_state = (x.T @ (bm * decay_end))         # [P, N]
    state_ref[...] = state_ref[...] * total + new_state

    @pl.when(ci == num_chunks - 1)
    def _finalize():
        st_out_ref[0, 0] = state_ref[...].astype(st_out_ref.dtype)


def ssd_scan(X, dA, B_mat, C_mat, *, chunk: int = 64,
             interpret: bool = False):
    """X [B, L, H, P] (dt-scaled), dA [B, L, H], B_mat/C_mat [B, L, H, N].

    Returns (Y [B, L, H, P], final_state [B, H, P, N] f32).
    """
    b, l, h, p = X.shape
    n = B_mat.shape[-1]
    cs = min(chunk, l)
    assert l % cs == 0, (l, cs)
    nc = l // cs
    # [B, H, nc, cs, ...] layouts so each grid step reads one chunk tile
    Xc = X.transpose(0, 2, 1, 3).reshape(b, h, nc, cs, p)
    dAc = dA.transpose(0, 2, 1).reshape(b, h, nc, cs)
    Bc = B_mat.transpose(0, 2, 1, 3).reshape(b, h, nc, cs, n)
    Cc = C_mat.transpose(0, 2, 1, 3).reshape(b, h, nc, cs, n)
    kernel = functools.partial(_kernel, cs=cs, num_chunks=nc)
    y, st = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, cs, p), lambda i, j, c: (i, j, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, cs), lambda i, j, c: (i, j, c, 0)),
            pl.BlockSpec((1, 1, 1, cs, n), lambda i, j, c: (i, j, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, cs, n), lambda i, j, c: (i, j, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, cs, p), lambda i, j, c: (i, j, c, 0, 0)),
            pl.BlockSpec((1, 1, p, n), lambda i, j, c: (i, j, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, nc, cs, p), X.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(Xc, dAc, Bc, Cc)
    Y = y.reshape(b, h, l, p).transpose(0, 2, 1, 3)
    return Y, st
